"""Logical sharding rules + elastic restore (cross-mesh checkpoint)."""
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.logical import DEFAULT_RULES, LogicalRules


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("data",))


class TestRules:
    def test_spec_basic(self, mesh1):
        rules = LogicalRules(mesh1)
        assert rules.spec("batch", "seq") == P("data", None)

    def test_missing_axis_dropped(self, mesh1):
        rules = LogicalRules(mesh1)     # no 'model' axis on this mesh
        assert rules.spec("batch", "heads") == P("data", None)

    def test_axis_used_once(self, mesh1):
        rules = LogicalRules(mesh1)
        # both dims map to data — second one must degrade to None
        assert rules.spec("batch", "embed") == P("data", None)

    def test_divisibility_fallback(self):
        # a 16-way data axis cannot shard batch=1 or heads=56 evenly;
        # LogicalRules only reads axis_names/devices.shape, so a stub mesh
        # stands in for real multi-device hardware
        class FakeDevices:
            shape = (16, 16)

        class FakeMesh:
            axis_names = ("data", "model")
            devices = FakeDevices()

        rules = LogicalRules(FakeMesh())
        assert rules.spec("batch", "seq", shape=(1, 64)) == P(None, None)
        assert rules.spec("batch", "seq", shape=(64, 64)) == \
            P("data", None)
        # 56 heads don't divide 16 → replicated; 64 do → sharded
        assert rules.spec("embed", "heads", shape=(128, 56)) == \
            P("data", None)
        assert rules.spec("embed", "heads", shape=(128, 64)) == \
            P("data", "model")

    def test_unknown_logical_raises(self, mesh1):
        with pytest.raises(KeyError):
            LogicalRules(mesh1).spec("nonsense")

    def test_tuple_rule_prefix(self):
        # multi-axis rule keeps only the dividing prefix
        assert DEFAULT_RULES["batch"] == ("pod", "data")


@pytest.mark.slow
class TestElasticRestore:
    """Checkpoint written on a (4,2) mesh restores onto (2,2) — subprocess
    with 8 forced host devices (the test process keeps 1 device)."""

    def test_cross_mesh_restore(self, tmp_path):
        script = tmp_path / "elastic_probe.py"
        script.write_text(f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import Box, Checkpoint
from repro.core.elastic import shrink_mesh, reshard
from repro.core.env import CraftEnv

env = CraftEnv.capture({{"CRAFT_CP_PATH": r"{tmp_path}/pfs",
                         "CRAFT_USE_SCR": "0"}})
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
x = jnp.arange(64.0).reshape(8, 8)
xa = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))
box = Box(xa)
cp = Checkpoint("el", env=env)
cp.add("x", box)
cp.commit()
cp.update_and_write()

# --- shrink: 2 "hosts" lost -> 4 devices usable, same TP degree
mesh_b = shrink_mesh(4, model_parallel=2)
xb = jax.device_put(jnp.zeros((8, 8)),
                    NamedSharding(mesh_b, P("data", "model")))
box2 = Box(xb)
cp2 = Checkpoint("el", env=env)
cp2.add("x", box2)
cp2.commit()
assert cp2.restart_if_needed()
np.testing.assert_array_equal(np.asarray(box2.value), np.asarray(x))
assert box2.value.sharding.mesh.devices.size == 4

# --- live reshard helper
y, _ = reshard({{"w": box2.value}}, {{"w": ("batch", "embed")}}, mesh_b)
np.testing.assert_array_equal(np.asarray(y["w"]), np.asarray(x))
print("OK")
""")
        r = subprocess.run([sys.executable, str(script)], cwd="/root/repo",
                           capture_output=True, text=True, timeout=300)
        assert "OK" in r.stdout, (r.stdout[-800:], r.stderr[-2000:])


@pytest.mark.slow
class TestTinyDryRun:
    """A reduced-config dry-run cell on an 8-device forced mesh: the full
    specs/lower/compile path plus roofline extraction, end to end."""

    def test_tiny_cell_compiles(self, tmp_path):
        script = tmp_path / "dry_probe.py"
        script.write_text("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax
from repro.configs import ShapeSpec
from repro.launch.specs import build_step
from repro.analysis import roofline as R

mesh = jax.make_mesh((4, 2), ("data", "model"))
for kind, name in (("train", "tiny_train"), ("prefill", "tiny_prefill"),
                   ("decode", "tiny_decode")):
    shape = ShapeSpec(name, seq_len=64, global_batch=4, kind=kind)
    built = build_step("zamba2-2.7b", shape, mesh, tiny=True)
    compiled = built.lower(mesh).compile()
    rep = R.analyze(compiled.as_text())
    assert rep.flops > 0, (kind, rep.as_dict())
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes > 0
print("OK")
""")
        r = subprocess.run([sys.executable, str(script)], cwd="/root/repo",
                           capture_output=True, text=True, timeout=560)
        assert "OK" in r.stdout, (r.stdout[-800:], r.stderr[-2500:])
