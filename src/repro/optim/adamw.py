"""AdamW on pytrees, ZeRO-sharded, with an 8-bit state option.

ZeRO sharding falls out of the logical-axis system: the optimizer moments
carry the *same* logical dims as their parameter, so under the FSDP rules
(``embed`` → data axis) both parameters and moments are sharded across the
data-parallel axis — ZeRO-2/3 placement without bespoke machinery.

8-bit moments (``state_bits=8``): blockwise absmax int8 quantization
(block = last axis) of m and v, dequantized on use — the standard
bitsandbytes-style trade that cuts optimizer HBM 4× (the difference between
fitting and not fitting the 671B/1T MoE cells on a 16 GB v5e, see
EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_bits: int = 32          # 32 or 8
    master_fp32: bool = True      # keep an fp32 master copy of bf16 params


def warmup_cosine(cfg: OptimConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    frac = jnp.clip(
        (step - cfg.warmup_steps)
        / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


# ----------------------------------------------------------- int8 moments
def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


# ----------------------------------------------------------- init / specs
def adamw_init(params, cfg: OptimConfig):
    def moment(p):
        if cfg.state_bits == 8 and p.ndim >= 1 and p.shape[-1] >= 4:
            q = jnp.zeros(p.shape, jnp.int8)
            s = jnp.zeros((*p.shape[:-1], 1), jnp.float32)
            return {"q": q, "scale": s}
        return jnp.zeros(p.shape, jnp.float32)

    state = {
        "m": jax.tree_util.tree_map(moment, params),
        "v": jax.tree_util.tree_map(moment, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params)
    return state


def opt_state_logical(param_logical_tree, cfg: OptimConfig, params=None):
    """Logical dims for the optimizer state (moments shard like params)."""
    is_dims = lambda x: isinstance(x, tuple) and all(
        isinstance(d, (str, type(None))) for d in x)

    def moment_dims(dims, p=None):
        if cfg.state_bits == 8 and p is not None and p.ndim >= 1 \
                and p.shape[-1] >= 4:
            return {"q": dims, "scale": dims}
        return dims

    if params is not None and cfg.state_bits == 8:
        mtree = jax.tree_util.tree_map(
            moment_dims, param_logical_tree, params, is_leaf=is_dims)
    else:
        mtree = param_logical_tree
    out = {"m": mtree, "v": mtree, "count": ()}
    if cfg.master_fp32:
        out["master"] = param_logical_tree
    return out


# ----------------------------------------------------------------- update
def _global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(grads, state, params, cfg: OptimConfig,
                 lr: Optional[jnp.ndarray] = None):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    if lr is None:
        lr = warmup_cosine(cfg, state["count"])
    gnorm = _global_norm(grads)
    scale = jnp.where(gnorm > cfg.clip_norm, cfg.clip_norm / gnorm, 1.0)

    def is_q(x):
        return isinstance(x, dict) and set(x) == {"q", "scale"}

    def load(mom):
        return _dequantize(mom["q"], mom["scale"]) if is_q(mom) else mom

    def store(val, proto):
        if is_q(proto):
            q, s = _quantize(val)
            return {"q": q, "scale": s}
        return val

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)
    masters = state.get("master", params)

    new_params, new_m, new_v, new_master = {}, {}, {}, {}
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_master = treedef.flatten_up_to(masters)

    out_p, out_m, out_v, out_master = [], [], [], []
    for p, g, m0, v0, w in zip(flat_p, flat_g, flat_m, flat_v, flat_master):
        g = g.astype(jnp.float32) * scale
        m = b1 * load(m0) + (1 - b1) * g
        v = b2 * load(v0) + (1 - b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        wf = w.astype(jnp.float32)
        wf = wf - lr * (update + cfg.weight_decay * wf)
        out_p.append(wf.astype(p.dtype))
        out_m.append(store(m, m0))
        out_v.append(store(v, v0))
        out_master.append(wf)

    new_params = jax.tree_util.tree_unflatten(treedef, out_p)
    new_state = {
        "m": jax.tree_util.tree_unflatten(treedef, out_m),
        "v": jax.tree_util.tree_unflatten(treedef, out_v),
        "count": count,
    }
    if "master" in state:
        new_state["master"] = jax.tree_util.tree_unflatten(treedef, out_master)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
