"""Versioned, atomic checkpoint storage (paper §2.6).

Directory layout (paper Fig. 4):

    <base>/<cpName>/
        meta.json            -- latest complete version, history, checksums
        v-<K>/               -- one directory per checkpoint version
            <key>/...        -- one subdirectory per checkpointable object

Atomicity protocol: a version is staged in ``.tmp-v-<K>-<nonce>/``, every file
is fsync'd, the directory is atomically renamed to ``v-<K>``, and only then is
``meta.json`` updated (itself via tmp+rename).  A crash at any point leaves
either the previous complete version or a garbage ``.tmp-*`` dir that is swept
on the next run — never a torn checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import uuid
import zlib
from pathlib import Path
from typing import Optional

import numpy as np

try:  # optional transparent compression (beyond-paper extension)
    import zstandard as _zstd
except ImportError:  # pragma: no cover
    _zstd = None

from repro.core.cpbase import CheckpointError, IOContext

_MAGIC = b"CRFT"


def _dtype_to_name(dt: np.dtype) -> str:
    return np.dtype(dt).name  # e.g. "float32", "bfloat16" (ml_dtypes)


def _dtype_from_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # registered by jax; covers bfloat16 / fp8 etc.

        return np.dtype(getattr(ml_dtypes, name))


# --------------------------------------------------------------------------
# low-level file codec: length-prefixed numpy buffers with optional zstd +
# crc32, fsync'd.  One .bin file per array keeps node-tier writes parallel.
# --------------------------------------------------------------------------
def write_array(path: Path, arr: np.ndarray, ctx: IOContext) -> None:
    arr = np.ascontiguousarray(arr)
    payload = arr.tobytes()
    if ctx.compress == "zstd":
        if _zstd is None:  # pragma: no cover
            raise CheckpointError("CRAFT_COMPRESS=zstd but zstandard missing")
        payload = _zstd.ZstdCompressor(level=3).compress(payload)
    header = json.dumps(
        {
            "dtype": _dtype_to_name(arr.dtype),
            "shape": list(arr.shape),
            "compress": ctx.compress,
        }
    ).encode()
    digest = zlib.crc32(payload) if ctx.checksum == "crc32" else 0
    tmp = path.with_name(f".tmp-{path.name}-{uuid.uuid4().hex[:8]}")
    with open(tmp, "wb") as fh:
        fh.write(_MAGIC)
        fh.write(len(header).to_bytes(8, "little"))
        fh.write(header)
        fh.write(digest.to_bytes(8, "little"))
        fh.write(payload)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)
    ctx.record_checksum(path.name, digest)


def read_array(path: Path, ctx: IOContext) -> np.ndarray:
    if not path.exists():
        raise CheckpointError(f"missing checkpoint file {path}")
    with open(path, "rb") as fh:
        if fh.read(4) != _MAGIC:
            raise CheckpointError(f"bad magic in {path}")
        hlen = int.from_bytes(fh.read(8), "little")
        header = json.loads(fh.read(hlen).decode())
        digest = int.from_bytes(fh.read(8), "little")
        payload = fh.read()
    if ctx.checksum == "crc32" and digest and zlib.crc32(payload) != digest:
        raise CheckpointError(f"checksum mismatch in {path}")
    if header["compress"] == "zstd":
        if _zstd is None:  # pragma: no cover
            raise CheckpointError("file is zstd-compressed but zstandard missing")
        payload = _zstd.ZstdDecompressor().decompress(payload)
    arr = np.frombuffer(bytearray(payload), dtype=_dtype_from_name(header["dtype"]))
    return arr.reshape(header["shape"])


def write_json(path: Path, obj) -> None:
    tmp = path.with_name(f".tmp-{path.name}-{uuid.uuid4().hex[:8]}")
    with open(tmp, "w") as fh:
        json.dump(obj, fh, indent=1)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)


def read_json(path: Path):
    with open(path) as fh:
        return json.load(fh)


def fsync_dir(path: Path) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


# --------------------------------------------------------------------------
# version store
# --------------------------------------------------------------------------
class VersionStore:
    """One checkpoint name's versioned directory tree on one storage tier.

    Multi-process coordination: all processes of ``comm`` share one staging
    directory per version (deterministic name, rank-distinct file names
    inside); ``publish()`` barriers, then rank 0 alone performs the atomic
    rename + metadata commit, then barriers again so no process reads a
    version before it is complete.
    """

    def __init__(
        self, base: Path, name: str, keep_versions: int = 2, comm=None,
        sweep: bool = True,
    ):
        self.root = Path(base) / name
        self.keep_versions = max(1, keep_versions)
        self.comm = comm
        self.root.mkdir(parents=True, exist_ok=True)
        if sweep and self._rank() == 0:
            self._sweep_tmp()

    def _rank(self) -> int:
        return 0 if self.comm is None else self.comm.rank

    def _barrier(self) -> None:
        if self.comm is not None:
            self.comm.barrier()

    # -- staging ------------------------------------------------------------
    def stage(self, version: int) -> Path:
        tmp = self.root / f".tmp-v-{version}"
        tmp.mkdir(parents=True, exist_ok=True)
        return tmp

    def publish(self, staged: Path, version: int, extra_meta: Optional[dict] = None) -> None:
        self._barrier()  # every process finished writing its files
        if self._rank() == 0:
            final = self.root / f"v-{version}"
            if final.exists():  # re-write of same version (e.g. retry)
                shutil.rmtree(final)
            os.replace(staged, final)
            fsync_dir(self.root)
            meta = self.meta()
            versions = sorted(set(meta.get("versions", [])) | {version})
            meta.update(
                {
                    "latest": version,
                    "versions": versions,
                    **(extra_meta or {}),
                }
            )
            write_json(self.root / "meta.json", meta)
            self._retire(versions)
        self._barrier()  # version visible to everyone from here on

    def abort(self, staged: Path) -> None:
        shutil.rmtree(staged, ignore_errors=True)

    # -- reading ------------------------------------------------------------
    def meta(self) -> dict:
        p = self.root / "meta.json"
        if p.exists():
            try:
                return read_json(p)
            except (json.JSONDecodeError, OSError):
                return {}
        return {}

    def latest_version(self) -> int:
        """Latest *complete* version, 0 if none (paper: CP-version counter)."""
        meta = self.meta()
        for v in sorted(meta.get("versions", []), reverse=True):
            if (self.root / f"v-{v}").is_dir():
                return v
        return 0

    def version_dir(self, version: int) -> Path:
        return self.root / f"v-{version}"

    # -- invalidation (nested checkpoints, paper §2.5) -----------------------
    def invalidate_all(self) -> None:
        meta = self.meta()
        for v in meta.get("versions", []):
            shutil.rmtree(self.root / f"v-{v}", ignore_errors=True)
        meta["versions"] = []
        meta["latest"] = 0
        write_json(self.root / "meta.json", meta)

    # -- housekeeping --------------------------------------------------------
    def _retire(self, versions) -> None:
        for v in versions[: -self.keep_versions]:
            shutil.rmtree(self.root / f"v-{v}", ignore_errors=True)
        kept = versions[-self.keep_versions:]
        meta = self.meta()
        meta["versions"] = kept
        write_json(self.root / "meta.json", meta)

    def _sweep_tmp(self) -> None:
        for junk in self.root.glob(".tmp-*"):
            shutil.rmtree(junk, ignore_errors=True)
