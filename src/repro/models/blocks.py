"""Decoder blocks assembled from the attention / ffn / ssm modules."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import mlp_apply, mlp_init, mlp_logical, rms_norm


# ---------------------------------------------------------------- transformer
def tblock_init(key, cfg, d_ff: Optional[int] = None, use_moe: bool = False):
    k1, k2 = jax.random.split(key)
    if cfg.attn_type == "mla":
        a = attn.mla_init(k1, cfg)
    else:
        a = attn.gqa_init(k1, cfg)
    if use_moe:
        f = moe_mod.moe_init(k2, cfg)
    else:
        f = mlp_init(k2, cfg, d_ff=d_ff)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
        "attn": a,
        "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
        "ffn": f,
    }


def tblock_logical(cfg, use_moe: bool = False):
    a = attn.mla_logical(cfg) if cfg.attn_type == "mla" else attn.gqa_logical(cfg)
    f = moe_mod.moe_logical(cfg) if use_moe else mlp_logical(cfg)
    return {"ln1": ("embed_act",), "attn": a, "ln2": ("embed_act",), "ffn": f}


def tblock_apply(params, x, cfg, positions, cache=None, use_moe: bool = False):
    """Returns (y, new_cache, aux_loss)."""
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, new_cache = attn.mla_apply(params["attn"], h, cfg, positions, cache)
    else:
        a, new_cache = attn.gqa_apply(params["attn"], h, cfg, positions, cache)
    x = x + a
    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    if use_moe:
        f, aux = moe_mod.moe_apply(params["ffn"], h, cfg)
    else:
        f, aux = mlp_apply(params["ffn"], h), jnp.zeros((), jnp.float32)
    return x + f, new_cache, aux


# ---------------------------------------------------------------- ssm block
def sblock_init(key, cfg):
    m = (ssm_mod.mamba2_init if cfg.ssm_type == "mamba2"
         else ssm_mod.mamba1_init)(key, cfg)
    return {"ln": jnp.ones((cfg.d_model,), cfg.dtype), "ssm": m}


def sblock_logical(cfg):
    m = (ssm_mod.mamba2_logical if cfg.ssm_type == "mamba2"
         else ssm_mod.mamba1_logical)(cfg)
    return {"ln": ("embed_act",), "ssm": m}


def sblock_apply(params, x, cfg, cache=None):
    h = rms_norm(x, params["ln"], cfg.norm_eps)
    apply = (ssm_mod.mamba2_apply if cfg.ssm_type == "mamba2"
             else ssm_mod.mamba1_apply)
    y, new_cache = apply(params["ssm"], h, cfg, cache)
    return x + y, new_cache, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------- cache ctors
def tblock_cache_init(cfg, batch: int, max_len: int, dtype):
    if cfg.attn_type == "mla":
        return attn.mla_cache_init(cfg, batch, max_len, dtype)
    return attn.gqa_cache_init(cfg, batch, max_len, dtype)


def tblock_cache_logical(cfg):
    if cfg.attn_type == "mla":
        return attn.mla_cache_logical(cfg)
    return attn.gqa_cache_logical(cfg)


def sblock_cache_init(cfg, batch: int, dtype):
    return (ssm_mod.mamba2_cache_init if cfg.ssm_type == "mamba2"
            else ssm_mod.mamba1_cache_init)(cfg, batch, dtype)


def sblock_cache_logical(cfg):
    return (ssm_mod.mamba2_cache_logical if cfg.ssm_type == "mamba2"
            else ssm_mod.mamba1_cache_logical)(cfg)
