"""Pure-jnp oracle for the XOR-parity kernel.

Parity of a group of equal-length ``uint32`` buffers is the elementwise XOR
across the group dimension.  Reconstruction of a lost member is the same
operation applied to (parity, surviving members) — XOR is its own inverse.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp


def xor_reduce_ref(stacked: jnp.ndarray) -> jnp.ndarray:
    """XOR-reduce over axis 0 of a ``(G, N) uint32`` array."""
    if stacked.ndim != 2:
        raise ValueError(f"expected (G, N), got {stacked.shape}")
    if stacked.dtype != jnp.uint32:
        raise TypeError(f"expected uint32, got {stacked.dtype}")
    rows = [stacked[g] for g in range(stacked.shape[0])]
    return functools.reduce(jnp.bitwise_xor, rows)
