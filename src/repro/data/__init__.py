from repro.data.pipeline import SyntheticTokens, DataCursor  # noqa: F401
