"""ULFM-semantics communicator (simulator backend) + AFT zones (paper §3)."""
import threading
import time

import numpy as np
import pytest

from repro.core import Box, Checkpoint, ShardCp
from repro.core.aft import AftAbortedError, aft_zone
from repro.core.comm import ProcFailedError, RevokedError
from repro.core.comm_sim import SimComm, SimWorld
from repro.core.elastic import block_index
from repro.core.env import CraftEnv


def _env(**kw):
    base = {"CRAFT_COMM_RECOVERY_POLICY": "NON-SHRINKING"}
    base.update(kw)
    return CraftEnv.capture(base)


class TestCollectives:
    def test_allreduce_sum(self):
        world = SimWorld(4, env=_env())
        out = world.run(lambda c: c.allreduce(c.rank + 1, op="sum"))
        assert set(out.values()) == {10}

    def test_allreduce_min_max(self):
        world = SimWorld(3, env=_env())
        out = world.run(lambda c: (c.allreduce(c.rank, "min"),
                                   c.allreduce(c.rank, "max")))
        assert set(out.values()) == {(0, 2)}

    def test_bcast(self):
        world = SimWorld(4, env=_env())
        out = world.run(lambda c: c.bcast(c.rank * 11, root=2))
        assert set(out.values()) == {22}

    def test_channels_are_independent(self):
        """Two channels used in different per-rank order must not deadlock
        (the checkpoint writer thread's barrier runs on its own channel)."""
        world = SimWorld(2, env=_env())

        def fn(c):
            results = {}

            def writer():
                results["w"] = c.allreduce(1, channel="cp:writer")

            t = threading.Thread(target=writer)
            t.start()
            results["m"] = c.allreduce(2, channel="main")
            t.join(timeout=10)
            return (results["m"], results["w"])

        out = world.run(fn)
        assert set(out.values()) == {(4, 2)}


class TestFailureDetection:
    def test_dead_rank_breaks_collective(self):
        world = SimWorld(3, env=_env())

        def fn(c):
            if c.rank == 0:
                world.kill(1)
            # rank 1 dies at its next comm call; others see ProcFailedError
            try:
                for _ in range(50):
                    c.barrier()
                    time.sleep(0.005)
                return "no failure seen"
            except ProcFailedError:
                return "detected"

        out = world.run(fn)
        assert set(out.values()) == {"detected"}

    def test_revoke_poisons_everyone(self):
        world = SimWorld(4, env=_env())

        def fn(c):
            if c.rank == 2:
                c.revoke()
                return "revoker"
            try:
                while True:
                    c.barrier()
            except (RevokedError, ProcFailedError):
                return "revoked"

        out = world.run(fn)
        assert sorted(out.values()) == ["revoked"] * 3 + ["revoker"]

    def test_agree_works_among_survivors(self):
        world = SimWorld(3, env=_env())

        def fn(c):
            if c.rank == 0:
                world.kill(2)
                time.sleep(0.02)
            try:
                c.barrier()
            except ProcFailedError:
                pass
            return c.agree(True)

        out = world.run(fn)
        assert all(out.values())


class TestRecovery:
    @staticmethod
    def _resilient_loop(world, policy, iters=20):
        """Every member (survivor or replacement) runs the same loop: do
        ``iters`` barriers on the current epoch, recovering on failure and
        RESTARTING the loop — so collective sequences match per epoch."""

        def fn(c):
            recovered = False
            while True:
                try:
                    if c.rank == 0 and c.epoch == 0:
                        world.kill(world.n_procs - 1)
                    for _ in range(iters):
                        c.barrier()
                        time.sleep(0.002)
                    return ("recovered" if recovered else "fresh", c.size,
                            c.last_recovery_stats())
                except (ProcFailedError, RevokedError):
                    try:
                        c.revoke()
                    except Exception:
                        pass
                    c = c.recover(policy=policy)
                    recovered = True

        return fn

    @pytest.mark.parametrize("policy", ["SHRINKING", "NON-SHRINKING"])
    def test_recover_after_kill(self, policy):
        world = SimWorld(4, procs_per_node=2, spare_nodes=1,
                         env=_env(CRAFT_COMM_RECOVERY_POLICY=policy))
        out = world.run(self._resilient_loop(world, policy), timeout=120)
        want = 3 if policy == "SHRINKING" else 4
        assert {v[1] for v in out.values()} == {want}
        assert any(v[0] == "recovered" for v in out.values())

    def test_recovery_stats_phases(self):
        """Paper Table 3's five phases are all reported."""
        world = SimWorld(4, spare_nodes=1, env=_env())
        out = world.run(self._resilient_loop(world, "NON-SHRINKING"),
                        timeout=120)
        stats = next(v[2] for v in out.values() if v[0] == "recovered")
        for phase in ("revoke_shrink_s", "spawn_info_s", "spawn_merge_s",
                      "redistribute_s", "resource_mgmt_s"):
            assert phase in stats, stats
        assert stats.get("failed") == [3]


class TestAftZone:
    def test_body_reruns_until_success(self):
        world = SimWorld(3, spare_nodes=1, env=_env())
        attempts = {}

        def body_factory(world):
            def fn(c):
                def body(comm):
                    attempts.setdefault(comm.rank, 0)
                    attempts[comm.rank] += 1
                    if comm.epoch == 0 and comm.rank == 0 \
                            and attempts[0] == 1:
                        world.kill(1)
                    for _ in range(30):
                        comm.barrier()
                        time.sleep(0.002)
                    return ("done", comm.size)

                return aft_zone(c, body, env=_env())
            return fn

        out = world.run(body_factory(world), timeout=120)
        assert all(v == ("done", 3) for v in out.values())
        # at least one member retried
        assert max(attempts.values()) >= 2

    def test_zone_gives_up_after_max_recoveries(self):
        world = SimWorld(2, env=_env())

        def fn(c):
            def body(comm):
                raise ProcFailedError("synthetic", failed=[0])

            try:
                aft_zone(c, body, max_recoveries=2, env=_env(
                    CRAFT_COMM_RECOVERY_POLICY="SHRINKING"))
            except (AftAbortedError, ProcFailedError, RevokedError):
                return "aborted"
            return "unexpected"

        out = world.run(fn, timeout=60)
        assert "aborted" in set(out.values())

    def test_nonshrinking_replacement_hydrates_from_peer_memory(self, tmp_path):
        """Kill k ranks mid-epoch under NON-SHRINKING: the spawned
        replacements restore their shard from surviving peers' RAM-fabric
        replicas — restore tier "mem", ZERO pfs reads, zero physical read
        bytes — and the fabric is re-protected (replica slots reseeded)."""
        src = (np.arange(13 * 5, dtype=np.float32).reshape(13, 5) + 1.5)
        env = _env(
            CRAFT_CP_PATH=str(tmp_path / "pfs"),
            CRAFT_TIER_CHAIN="mem,pfs",
            CRAFT_MEM_REPLICAS="2",
            CRAFT_MEM_SCRATCH=str(tmp_path / "shm"),
            CRAFT_USE_SCR="0",
            CRAFT_IO_WORKERS="1",
        )
        world = SimWorld(4, spare_nodes=2, env=env)
        restores = {}   # (rank, epoch, is_replacement) -> restore telemetry
        reseeds = []    # mem_reseeded from each member's recovery stats

        def body(comm):
            cp = Checkpoint("state", comm, env=env)
            it = Box(0)
            idx = block_index(src.shape, comm.rank, comm.size)
            wbox = Box(np.zeros_like(src[idx]))
            cp.add("it", it)
            cp.add("w", ShardCp(wbox, src.shape, idx))
            cp.commit()
            if cp.restart_if_needed():
                restores[(comm.rank, comm.epoch, comm.is_replacement())] = {
                    "tier": cp.stats["restore_tier"],
                    "pfs_reads": cp.stats["tier_reads"].get("pfs", 0),
                    "read_bytes": cp.stats["restore_read_bytes"],
                    "block_ok": np.array_equal(wbox.value, src[idx]),
                    "it": it.value,
                }
            while it.value < 5:
                it.value += 1
                np.copyto(wbox.value, src[idx])
                cp.update_and_write()
                if comm.rank == 0 and comm.epoch == 0 and it.value == 2:
                    world.kill(2)
                    world.kill(3)
                comm.barrier()
                time.sleep(0.002)
            cp.close()
            return ("done", comm.size)

        def fn(c):
            return aft_zone(
                c, body, env=env,
                on_recovery=lambda comm, stats: reseeds.append(
                    stats.get("mem_reseeded", 0)))

        out = world.run(fn, timeout=180)
        assert all(v == ("done", 4) for v in out.values())
        # the spawned replacements hydrated purely from peer memory
        repl = {k: v for k, v in restores.items() if k[2]}
        assert repl, restores
        for info in repl.values():
            assert info["tier"] == "mem", info
            assert info["pfs_reads"] == 0, info
            assert info["read_bytes"] == 0, info
            assert info["block_ok"] and info["it"] >= 1, info
        # the fabric was re-protected: someone reseeded replica slots
        assert sum(reseeds) > 0, reseeds

    def test_shrinking_zone_result(self):
        world = SimWorld(4, env=_env(CRAFT_COMM_RECOVERY_POLICY="SHRINKING"))

        def fn(c):
            def body(comm):
                if comm.epoch == 0:
                    if comm.rank == 0:
                        world.kill(3)
                    for _ in range(100):
                        comm.barrier()
                        time.sleep(0.002)
                return comm.size

            return aft_zone(c, body, env=_env(
                CRAFT_COMM_RECOVERY_POLICY="SHRINKING"))

        out = world.run(fn, timeout=120)
        assert set(out.values()) == {3}
