"""CpBase — the extension point of the CRAFT checkpoint library.

The paper's design (Fig. 2): every checkpointable data type derives from a
base class with three pure-virtual functions, ``read()``, ``write()`` and
``update()``.  The ``Checkpoint`` class holds a map of named CpBase objects
and drives those three calls.

JAX adaptation: ``update()`` is where device state becomes host state — for a
``jax.Array`` it snapshots the addressable shards (device→host DMA overlaps
with subsequent compute on TPU).  ``write()``/``read()`` are pure host-side
file IO and can therefore run on the asynchronous writer thread.
"""
from __future__ import annotations

import abc
import dataclasses
import threading
from pathlib import Path
from typing import Callable, Optional, Sequence


@dataclasses.dataclass
class IOContext:
    """Context threaded through every read/write call.

    ``proc_rank`` / ``proc_count`` identify the writing process (paper: rank
    embedded in process-local file names); ``compress``/``checksum`` select the
    codec, and ``checksum_db`` collects per-file digests for the manifest.

    Codec pipeline fields (on-disk format v1): ``codec_version`` picks the
    array file format (0 = legacy monolithic blob, 1 = chunked), and
    ``chunk_bytes`` the chunk granularity.  ``fanout``, when set, is a
    ``fanout(jobs) -> results`` callable backed by the IO worker pool; the
    storage layer routes independent per-array and per-chunk work through it,
    so reads/writes issued from several threads share one ``IOContext`` —
    hence the lock around ``checksum_db`` updates.
    """

    proc_rank: int = 0
    proc_count: int = 1
    compress: str = "none"          # none | zstd
    checksum: str = "crc32"         # crc32 | fletcher | none
    # Per-file digest manifest: filled at write (keyed by path relative to
    # ``rel_root``), persisted into the version metadata at publish; restore
    # checks every manifest file is present before reading (payload integrity
    # itself is verified by the in-file digests).
    checksum_db: Optional[dict] = None
    rel_root: Optional[Path] = None      # staging root the manifest keys on
    codec_version: int = 1          # 0 = legacy blob, 1 = chunked
    chunk_bytes: int = 4 * 1024 * 1024
    # Parallel fanout hook: fanout(list[callable]) -> list of results, in
    # order.  None means "run inline" (no pool available).
    fanout: Optional[Callable[[Sequence[Callable]], list]] = None
    # Restore-time hook: maps a stored global numpy array onto the live
    # sharding/topology (elastic restore).  Installed by jax-aware types.
    device_put: Optional[Callable] = None
    # Memory-tier fast path: maps str(path) of an array file to its already-
    # decoded (read-only) ndarray; ``storage.read_array`` serves hits without
    # touching the filesystem or re-running the codec.  Installed by
    # ``MemStore.read_ctx_overrides`` (payloads are digest-verified at
    # publish, so no re-verification happens on this path).
    array_cache: Optional[dict] = None
    # --- delta codec (on-disk format v2) -----------------------------------
    # Write side: ``delta_prev`` maps each file's manifest name to the chunk
    # manifest of the previous version on the *same tier*
    # ({"rdigests", "ulens", "nbytes", "chunk_bytes"}); a chunk whose raw
    # digest matches is recorded as a ``{ref: delta_base}`` entry instead of
    # being re-encoded and re-written.  ``chunks_db`` collects the manifests
    # of the version being written so the next version can diff against it.
    delta_prev: Optional[dict] = None
    delta_base: int = 0
    chunks_db: Optional[dict] = None
    # Read side: version → materialized directory of every delta-base version
    # the chain needs; refs resolve against ``base_dirs[ref] / relpath`` where
    # relpath is the file's path relative to ``rel_root``.
    base_dirs: Optional[dict] = None
    # Physical-IO accounting: {"bytes", "chunks", "ref_chunks"} actually
    # written, filled by the codec (delta savings show up here, while
    # ``Checkpoint.stats['bytes_written']`` stays the logical payload size).
    io_stats: Optional[dict] = None
    # --- zstd tuning (CRAFT_ZSTD_LEVEL / CRAFT_ZSTD_GATE_BITS) --------------
    # Compression level for the per-worker compressor cache, and the
    # per-chunk compressibility gate: a chunk whose order-0 nibble-entropy
    # estimate is >= ``zstd_gate_bits`` bits/byte is stored raw (chunk meta
    # ``"enc": "raw"``) instead of run through zstd.  0 disables the gate.
    zstd_level: int = 3
    zstd_gate_bits: float = 0.0
    # --- elastic reshard-on-restore (CRAFT_RESHARD) -------------------------
    # Read side: additional version roots whose shard files complement
    # ``rel_root`` (node-tier N→M restores: other nodes' v-<K> trees,
    # reachable over the shared FS).  Checkpointables union the shard
    # manifests across rel_root + aux_dirs; delta refs inside an aux file
    # resolve against *that* root's sibling base dirs, not ``base_dirs``.
    aux_dirs: Optional[tuple] = None
    # Assembly strategy for sharded global arrays: "auto" range-reads only
    # when the restoring extent is a strict sub-extent of the global array
    # (or shards live in aux dirs), "range" always range-reads, "full"
    # forces the legacy whole-array assembly.
    reshard: str = "auto"
    # --- device-resident snapshot path (CRAFT_DEVICE_SNAPSHOT) --------------
    # Precomputed chunk metadata, keyed like ``checksum_db`` (manifest name):
    # {"nbytes", "chunk_bytes", "rdigests", "dirty", "entropy_bits"} produced
    # by the fused snapshot kernel at ``update()`` time.  The array writers
    # consume these instead of re-digesting on the host, after validating
    # that the chunk grid matches (a tier override of ``chunk_bytes`` or a
    # reshaped array falls back to the host path transparently).
    device_meta: Optional[dict] = None
    # --- resilient IO (CRAFT_CHAOS / CRAFT_IO_RETRIES) ----------------------
    # Fault-injection scope for the tier this context writes/reads
    # (``chaos.ChaosScope`` or None): the file helpers in ``storage.py`` call
    # ``chaos.check("write"/"read", ...)`` before touching the filesystem and
    # honor ``chaos.torn_limit`` for partial-write injection.
    chaos: Optional[object] = None
    # Transient-error retry budget per file operation (exponential backoff
    # with jitter, base delay ``io_retry_backoff_ms``); 0 = fail fast.
    io_retries: int = 0
    io_retry_backoff_ms: float = 25.0
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False
    )

    def record_checksum(self, rel_name: str, digest: int) -> None:
        if self.checksum_db is not None:
            with self._lock:
                self.checksum_db[rel_name] = digest

    def record_device_meta(self, rel_name: str, meta: dict) -> None:
        """Attach device-produced chunk metadata for the file about to be
        written under ``rel_name`` (called by checkpointables just before
        ``storage.write_array``; same-thread, the lock guards cross-item
        fanout writes into the shared dict)."""
        if self.device_meta is not None:
            with self._lock:
                self.device_meta[rel_name] = meta

    def lookup_device_meta(self, rel_name: str, nbytes: int,
                           chunk_bytes: int, n_chunks: int) -> Optional[dict]:
        """Device metadata for ``rel_name`` iff its chunk grid matches the
        write about to happen — otherwise None (host fallback)."""
        if self.device_meta is None:
            return None
        with self._lock:
            meta = self.device_meta.get(rel_name)
        if meta is None:
            return None
        if (int(meta.get("nbytes", -1)) != int(nbytes)
                or int(meta.get("chunk_bytes", -1)) != int(chunk_bytes)
                or len(meta.get("rdigests", ())) != int(n_chunks)):
            return None
        return meta

    def record_chunks(self, rel_name: str, manifest: dict) -> None:
        """Collect one file's chunk manifest for the next version's diff."""
        if self.chunks_db is not None:
            with self._lock:
                self.chunks_db[rel_name] = manifest

    def record_io(self, nbytes: int, chunks: int = 0, ref_chunks: int = 0) -> None:
        """Account bytes/chunks physically written (vs skipped as refs)."""
        if self.io_stats is not None:
            with self._lock:
                self.io_stats["bytes"] = self.io_stats.get("bytes", 0) + nbytes
                self.io_stats["chunks"] = self.io_stats.get("chunks", 0) + chunks
                self.io_stats["ref_chunks"] = (
                    self.io_stats.get("ref_chunks", 0) + ref_chunks
                )

    def record_retry(self) -> None:
        """Account one transient-error retry (surfaces in
        ``Checkpoint.stats['retries']`` and the ``io_retries`` counter)."""
        from repro.core import metrics

        metrics.inc("io_retries")
        if self.io_stats is not None:
            with self._lock:
                self.io_stats["retries"] = self.io_stats.get("retries", 0) + 1

    def record_read(self, nbytes: int) -> None:
        """Account payload bytes physically fetched at restore (range reads
        report only the chunks they touched — the elastic-restore savings
        show up as ``io_stats['read_bytes']`` < the full payload size)."""
        if self.io_stats is not None:
            with self._lock:
                self.io_stats["read_bytes"] = (
                    self.io_stats.get("read_bytes", 0) + nbytes
                )


class CpBase(abc.ABC):
    """Base class of every checkpointable data type (paper Fig. 2).

    Subclasses implement:
      * ``update()`` — refresh the internal write-buffer from the live data
        (only used for copy-based asynchronous checkpointing; synchronous
        writes may fold this into ``write()``).
      * ``write(dir_path, ctx)`` — serialize the buffer into ``dir_path``.
      * ``read(dir_path, ctx)`` — restore the live data from ``dir_path``.
    """

    #: When True the object snapshots into a private buffer on ``update()``
    #: so the live data can be mutated while the writer thread runs.
    needs_copy_for_async: bool = True

    @abc.abstractmethod
    def update(self) -> None:
        """Snapshot live data into the write buffer (async copy mode)."""

    @abc.abstractmethod
    def write(self, dir_path: Path, ctx: IOContext) -> None:
        """Serialize the (buffered) data under ``dir_path``."""

    @abc.abstractmethod
    def read(self, dir_path: Path, ctx: IOContext) -> None:
        """Restore live data from ``dir_path`` (raises on missing/corrupt)."""

    def nbytes(self) -> int:
        """Approximate checkpoint payload size (for tier policy / stats)."""
        return 0


class CheckpointError(RuntimeError):
    """Raised on unreadable / corrupt / inconsistent checkpoint data."""
