"""CRAFT environment variables (paper Table 2), with read-once semantics.

The paper reads these variables exactly once — either at the definition of a
``Checkpoint`` object or at the start of an AFT zone — so changing them mid-run
has no effect.  We mirror that: ``CraftEnv.capture()`` snapshots the
environment; each ``Checkpoint`` / AFT zone stores its own snapshot.
"""
from __future__ import annotations

import dataclasses
import os
import signal as _signal
from pathlib import Path
from typing import Optional

# Paper Table 2 names.  CRAFT_USE_SCR is kept as an alias for the node-level
# tier toggle (SCR is the paper's node-level backend; ours is built in).
_TRUE = {"1", "true", "yes", "on"}
_FALSE = {"0", "false", "no", "off"}


def _bool(env: dict, key: str, default: bool) -> bool:
    raw = env.get(key)
    if raw is None:
        return default
    raw = raw.strip().lower()
    if raw in _TRUE:
        return True
    if raw in _FALSE:
        return False
    raise ValueError(f"{key}={raw!r}: expected one of {_TRUE | _FALSE}")


@dataclasses.dataclass(frozen=True)
class CraftEnv:
    """Snapshot of every CRAFT_* control knob (paper Table 2 + extensions)."""

    # --- paper Table 2 ---------------------------------------------------
    cp_path: Path                    # CRAFT_CP_PATH        (default: $PWD)
    enable: bool                     # CRAFT_ENABLE         (default: 1)
    write_async: bool                # CRAFT_WRITE_ASYNC    (default: 0)
    write_async_zero_copy: bool      # CRAFT_WRITE_ASYNC_ZERO_COPY (default: 0)
    async_thread_pin_cpulist: tuple  # CRAFT_ASYNC_THREAD_PIN_CPULIST ("10_20")
    use_node_level: bool             # CRAFT_USE_SCR / CRAFT_USE_NODE_LEVEL (1)
    read_cp_on_restart: bool         # CRAFT_READ_CP_ON_RESTART (default: 1)
    comm_recovery_policy: str        # CRAFT_COMM_RECOVERY_POLICY:
                                     # NON-SHRINKING (default) | SHRINKING
    comm_spawn_policy: str           # CRAFT_COMM_SPAWN_POLICY:
                                     # NO-REUSE (default) | REUSE
    # --- TPU-era extensions (documented in DESIGN.md §2) ------------------
    node_cp_path: Optional[Path]     # CRAFT_NODE_CP_PATH   (node-tier dir)
    node_redundancy: str             # CRAFT_NODE_REDUNDANCY:
                                     # LOCAL|PARTNER|XOR|RS
    xor_group_size: int              # CRAFT_XOR_GROUP_SIZE (default: 8;
                                     # also the RS group size k)
    rs_parity: int                   # CRAFT_RS_PARITY: parity buffers m per
                                     # RS group — survives any m simultaneous
                                     # member losses (default: 2)
    pfs_every: int                   # CRAFT_PFS_EVERY: every k-th version also
                                     # lands on the PFS tier (default: 1)
    keep_versions: int               # CRAFT_KEEP_VERSIONS (default: 2)
    compress: str                    # CRAFT_COMPRESS: none|zstd (default none)
    zstd_level: int                  # CRAFT_ZSTD_LEVEL: zstd compression level
                                     # (default 3; compressors are built once
                                     # per IO worker, not once per chunk)
    zstd_gate_bits: float            # CRAFT_ZSTD_GATE_BITS: per-chunk
                                     # compressibility gate — chunks whose
                                     # order-0 nibble-entropy estimate is >=
                                     # this many bits/byte are stored raw
                                     # instead of zstd-compressed (default
                                     # 7.95; 0 disables the gate)
    checksum: str                    # CRAFT_CHECKSUM: crc32|fletcher|none
                                     # (default crc32; v1 files always store
                                     # the kernel fletcher digest when on)
    codec_version: int               # CRAFT_CODEC_VERSION: 0 legacy | 1 chunked
                                     # | 2 chunk-delta (incremental)
    chunk_bytes: int                 # CRAFT_CHUNK_BYTES (default 4 MiB)
    io_workers: int                  # CRAFT_IO_WORKERS: writer pool size
    delta: bool                      # CRAFT_DELTA: skip unchanged chunks by
                                     # diffing against the previous version
                                     # (implies codec v2; default off)
    delta_max_chain: int             # CRAFT_DELTA_MAX_CHAIN: max versions in
                                     # a delta chain before a full rewrite
                                     # (compaction; default 4)
    device_snapshot: bool            # CRAFT_DEVICE_SNAPSHOT: fused on-device
                                     # snapshot pipeline — per-chunk digests,
                                     # dirty mask and compressibility gate are
                                     # computed on the accelerator and only
                                     # dirty chunks cross device→host
                                     # (default off)
    # --- elastic restore (docs/architecture.md §elastic restore) -----------
    reshard: str                     # CRAFT_RESHARD: auto|range|full — N→M
                                     # restore assembly strategy (auto range-
                                     # reads only when the restoring extent
                                     # is a sub-extent of the global array or
                                     # shards live in peer version trees)
    elastic_hydrate: bool            # CRAFT_ELASTIC_HYDRATE: after a mem-tier
                                     # restore, re-seed the restoring rank's
                                     # RAM-fabric slots from surviving peer
                                     # replicas so replacement ranks rejoin
                                     # the redundancy group (default: 1)
    # --- memory tier (docs/architecture.md §memory tier) -------------------
    tier_chain: tuple                # CRAFT_TIER_CHAIN: ordered subset of
                                     # mem,node,pfs (default "node,pfs";
                                     # "mem,node,pfs" enables the RAM tier)
    mem_replicas: int                # CRAFT_MEM_REPLICAS: peer copies of each
                                     # rank's shards (round-robin, default 1)
    mem_budget_bytes: int            # CRAFT_MEM_BUDGET_BYTES: per-rank RAM
                                     # cap for the memory tier (0 = unlimited)
    mem_scratch: Optional[Path]      # CRAFT_MEM_SCRATCH: staging/materialize
                                     # dir (default /dev/shm when writable)
    # --- adaptive scheduler (docs/tuning.md) -------------------------------
    tier_every: tuple                # CRAFT_TIER_EVERY: per-tier cadence spec,
                                     # "mem:1,node:8,pfs:64" counts, "auto" =
                                     # Young/Daly intervals; empty = legacy
                                     # (every version + CRAFT_PFS_EVERY)
    mtbf_seconds: float              # CRAFT_MTBF_SECONDS: mean time between
                                     # failures feeding the Daly formula
                                     # (0 = use the communicator's empirical
                                     # rate, else a 1-day default)
    walltime_seconds: float          # CRAFT_WALLTIME_SECONDS: job walltime
                                     # budget; the policy lands one final full
                                     # checkpoint before it expires (0 = off)
    walltime_margin_seconds: float   # CRAFT_WALLTIME_MARGIN_SECONDS: safety
                                     # margin subtracted from the walltime on
                                     # top of the estimated write cost
    cp_signal: tuple                 # CRAFT_CP_SIGNAL: signal names (e.g.
                                     # "SIGTERM,SIGUSR1") that trigger a
                                     # synchronous flush of the deepest tier
                                     # (batch-scheduler preemption notice)
    # --- integrity scrubber (core/scrubber.py) -----------------------------
    scrub_every: float               # CRAFT_SCRUB_EVERY: seconds between
                                     # background scrub slices, run in idle
                                     # checkpoint opportunities (0 = no
                                     # background scrubbing; repair-on-read
                                     # stays active)
    scrub_bytes_per_s: float         # CRAFT_SCRUB_BYTES_PER_S: scrub IO
                                     # throttle — bytes verified per second,
                                     # accumulated between slices
                                     # (0 = unthrottled)
    # --- chaos + resilient IO (core/chaos.py / core/health.py) -------------
    chaos: str                       # CRAFT_CHAOS: fault-injection spec,
                                     # "slot:fault:k=v+k=v,..." rules (or
                                     # "on" to arm the engine with no rules;
                                     # empty = chaos off)
    chaos_seed: int                  # CRAFT_CHAOS_SEED: seed for the
                                     # per-operation injection RNG so fault
                                     # schedules replay bit-identically
    io_retries: int                  # CRAFT_IO_RETRIES: retry attempts for
                                     # transient tier IO errors (EIO/EAGAIN/
                                     # EINTR/ETIMEDOUT) per operation
    io_backoff_ms: float             # CRAFT_IO_BACKOFF_MS: base retry delay,
                                     # doubled per attempt with +-50% jitter
    io_deadline_s: float             # CRAFT_IO_DEADLINE_S: wall-clock budget
                                     # per tier write before it is abandoned
                                     # as hung (0 = no deadline)
    breaker_threshold: int           # CRAFT_BREAKER_THRESHOLD: consecutive
                                     # tier failures before its circuit
                                     # breaker opens and writes degrade to
                                     # the next chain level
    breaker_cooldown_s: float        # CRAFT_BREAKER_COOLDOWN_S: seconds an
                                     # open breaker waits before admitting a
                                     # half-open health probe
    # --- trace recording + auto-tuning (core/trace.py / core/tune.py) ------
    trace_path: str                  # CRAFT_TRACE: JSONL run-trace output
                                     # path; empty = recorder stays the
                                     # module-level no-op (zero overhead)
    tune_online: bool                # CRAFT_TUNE_ONLINE: periodically
                                     # re-solve per-tier cadences inside
                                     # CheckpointPolicy from live write-cost
                                     # EWMAs + the empirical failure log
                                     # (default off)
    tune_every_s: float              # CRAFT_TUNE_EVERY_S: seconds between
                                     # online re-tuning solves (default 60)
    # --- live telemetry plane (core/metrics.py / core/telemetry.py) --------
    metrics: bool                    # CRAFT_METRICS: arm the process-global
                                     # metrics registry (counters/gauges/
                                     # histograms); unset = every hook is a
                                     # single no-op call (default off)
    metrics_port: int                # CRAFT_METRICS_PORT: serve Prometheus
                                     # text at /metrics and JSON at /healthz
                                     # on this port (0 picks an ephemeral
                                     # port; -1 = exporter off, default)

    def tier_every_for(self, slot: str):
        """Cadence spec for a chain slot: int count, "auto", or None (legacy).

        A bare ``CRAFT_TIER_EVERY=auto`` applies to every slot (stored under
        the ``*`` wildcard); otherwise only explicitly named slots are
        overridden and the rest keep their legacy default.
        """
        spec = dict(self.tier_every)
        return spec.get(slot, spec.get("*"))

    @staticmethod
    def capture(environ: Optional[dict] = None) -> "CraftEnv":
        env = dict(os.environ if environ is None else environ)
        pin_raw = env.get("CRAFT_ASYNC_THREAD_PIN_CPULIST", "").strip()
        pin = tuple(int(tok) for tok in pin_raw.split("_") if tok) if pin_raw else ()
        use_node = _bool(env, "CRAFT_USE_SCR", True) and _bool(
            env, "CRAFT_USE_NODE_LEVEL", True
        )
        recovery = env.get("CRAFT_COMM_RECOVERY_POLICY", "NON-SHRINKING").upper()
        if recovery not in ("NON-SHRINKING", "SHRINKING"):
            raise ValueError(f"CRAFT_COMM_RECOVERY_POLICY={recovery!r}")
        spawn = env.get("CRAFT_COMM_SPAWN_POLICY", "NO-REUSE").upper()
        if spawn not in ("NO-REUSE", "REUSE"):
            raise ValueError(f"CRAFT_COMM_SPAWN_POLICY={spawn!r}")
        node_path = env.get("CRAFT_NODE_CP_PATH")
        redundancy = env.get("CRAFT_NODE_REDUNDANCY", "PARTNER").upper()
        if redundancy not in ("LOCAL", "PARTNER", "XOR", "RS"):
            raise ValueError(f"CRAFT_NODE_REDUNDANCY={redundancy!r}")
        rs_parity = int(env.get("CRAFT_RS_PARITY", "2"))
        if rs_parity < 1:
            raise ValueError(f"CRAFT_RS_PARITY={rs_parity!r}")
        compress = env.get("CRAFT_COMPRESS", "none").lower()
        if compress not in ("none", "zstd"):
            raise ValueError(f"CRAFT_COMPRESS={compress!r}")
        zstd_level = int(env.get("CRAFT_ZSTD_LEVEL", "3"))
        if not 1 <= zstd_level <= 22:
            raise ValueError(f"CRAFT_ZSTD_LEVEL={zstd_level!r}: expected 1..22")
        zstd_gate_bits = float(env.get("CRAFT_ZSTD_GATE_BITS", "7.95"))
        if not 0 <= zstd_gate_bits <= 8:
            raise ValueError(
                f"CRAFT_ZSTD_GATE_BITS={zstd_gate_bits!r}: expected 0..8")
        checksum = env.get("CRAFT_CHECKSUM", "crc32").lower()
        if checksum not in ("crc32", "fletcher", "none"):
            raise ValueError(f"CRAFT_CHECKSUM={checksum!r}")
        codec_version = int(env.get("CRAFT_CODEC_VERSION", "1"))
        if codec_version not in (0, 1, 2):
            raise ValueError(f"CRAFT_CODEC_VERSION={codec_version!r}")
        delta = _bool(env, "CRAFT_DELTA", codec_version == 2)
        if delta and codec_version == 0:
            raise ValueError(
                "CRAFT_DELTA=1 needs the chunked codec "
                "(CRAFT_CODEC_VERSION >= 1, got 0)"
            )
        if delta:
            codec_version = 2        # delta writes are format v2
        delta_max_chain = int(env.get("CRAFT_DELTA_MAX_CHAIN", "4"))
        if delta_max_chain < 1:
            raise ValueError(f"CRAFT_DELTA_MAX_CHAIN={delta_max_chain!r}")
        device_snapshot = _bool(env, "CRAFT_DEVICE_SNAPSHOT", False)
        chunk_bytes = int(env.get("CRAFT_CHUNK_BYTES", str(4 * 1024 * 1024)))
        if chunk_bytes <= 0:
            raise ValueError(f"CRAFT_CHUNK_BYTES={chunk_bytes!r}")
        reshard = env.get("CRAFT_RESHARD", "auto").lower()
        if reshard not in ("auto", "range", "full"):
            raise ValueError(
                f"CRAFT_RESHARD={reshard!r}: expected auto|range|full")
        elastic_hydrate = _bool(env, "CRAFT_ELASTIC_HYDRATE", True)
        chain_raw = env.get("CRAFT_TIER_CHAIN", "node,pfs").lower()
        tier_chain = tuple(t.strip() for t in chain_raw.split(",") if t.strip())
        if not tier_chain or len(set(tier_chain)) != len(tier_chain) or not (
            set(tier_chain) <= {"mem", "node", "pfs"}
        ):
            raise ValueError(
                f"CRAFT_TIER_CHAIN={chain_raw!r}: expected an ordered, "
                "duplicate-free subset of mem,node,pfs"
            )
        mem_replicas = int(env.get("CRAFT_MEM_REPLICAS", "1"))
        if mem_replicas < 0:
            raise ValueError(f"CRAFT_MEM_REPLICAS={mem_replicas!r}")
        mem_budget = int(env.get("CRAFT_MEM_BUDGET_BYTES", "0"))
        if mem_budget < 0:
            raise ValueError(f"CRAFT_MEM_BUDGET_BYTES={mem_budget!r}")
        mem_scratch = env.get("CRAFT_MEM_SCRATCH")
        tier_every = _parse_tier_every(env.get("CRAFT_TIER_EVERY", ""))
        mtbf_seconds = float(env.get("CRAFT_MTBF_SECONDS", "0"))
        if mtbf_seconds < 0:
            raise ValueError(f"CRAFT_MTBF_SECONDS={mtbf_seconds!r}")
        walltime_seconds = float(env.get("CRAFT_WALLTIME_SECONDS", "0"))
        if walltime_seconds < 0:
            raise ValueError(f"CRAFT_WALLTIME_SECONDS={walltime_seconds!r}")
        walltime_margin = float(env.get("CRAFT_WALLTIME_MARGIN_SECONDS", "60"))
        if walltime_margin < 0:
            raise ValueError(
                f"CRAFT_WALLTIME_MARGIN_SECONDS={walltime_margin!r}")
        cp_signal = _parse_cp_signal(env.get("CRAFT_CP_SIGNAL", ""))
        scrub_every = float(env.get("CRAFT_SCRUB_EVERY", "0"))
        if scrub_every < 0:
            raise ValueError(f"CRAFT_SCRUB_EVERY={scrub_every!r}")
        scrub_bytes_per_s = float(env.get("CRAFT_SCRUB_BYTES_PER_S", "0"))
        if scrub_bytes_per_s < 0:
            raise ValueError(
                f"CRAFT_SCRUB_BYTES_PER_S={scrub_bytes_per_s!r}")
        chaos = env.get("CRAFT_CHAOS", "").strip()
        if chaos:
            # validate eagerly so typos fail at capture, not mid-write
            from repro.core.chaos import parse_chaos_spec
            parse_chaos_spec(chaos)
        chaos_seed = int(env.get("CRAFT_CHAOS_SEED", "0"))
        io_retries = int(env.get("CRAFT_IO_RETRIES", "2"))
        if io_retries < 0:
            raise ValueError(f"CRAFT_IO_RETRIES={io_retries!r}")
        io_backoff_ms = float(env.get("CRAFT_IO_BACKOFF_MS", "25"))
        if io_backoff_ms < 0:
            raise ValueError(f"CRAFT_IO_BACKOFF_MS={io_backoff_ms!r}")
        io_deadline_s = float(env.get("CRAFT_IO_DEADLINE_S", "0"))
        if io_deadline_s < 0:
            raise ValueError(f"CRAFT_IO_DEADLINE_S={io_deadline_s!r}")
        breaker_threshold = int(env.get("CRAFT_BREAKER_THRESHOLD", "3"))
        if breaker_threshold < 1:
            raise ValueError(f"CRAFT_BREAKER_THRESHOLD={breaker_threshold!r}")
        breaker_cooldown_s = float(env.get("CRAFT_BREAKER_COOLDOWN_S", "30"))
        if breaker_cooldown_s < 0:
            raise ValueError(f"CRAFT_BREAKER_COOLDOWN_S={breaker_cooldown_s!r}")
        trace_path = env.get("CRAFT_TRACE", "").strip()
        tune_online = _bool(env, "CRAFT_TUNE_ONLINE", False)
        tune_every_s = float(env.get("CRAFT_TUNE_EVERY_S", "60"))
        if tune_every_s <= 0:
            raise ValueError(f"CRAFT_TUNE_EVERY_S={tune_every_s!r}")
        metrics = _bool(env, "CRAFT_METRICS", False)
        metrics_port_raw = env.get("CRAFT_METRICS_PORT", "").strip()
        metrics_port = int(metrics_port_raw) if metrics_port_raw else -1
        if metrics_port < -1 or metrics_port > 65535:
            raise ValueError(f"CRAFT_METRICS_PORT={metrics_port!r}")
        if metrics_port >= 0:
            metrics = True      # an exporter implies an armed registry
        io_workers_raw = env.get("CRAFT_IO_WORKERS")
        if io_workers_raw is None:
            io_workers = min(4, os.cpu_count() or 1)
        else:
            io_workers = int(io_workers_raw)
        if io_workers < 1:
            raise ValueError(f"CRAFT_IO_WORKERS={io_workers!r}")
        return CraftEnv(
            cp_path=Path(env.get("CRAFT_CP_PATH", os.getcwd())),
            enable=_bool(env, "CRAFT_ENABLE", True),
            write_async=_bool(env, "CRAFT_WRITE_ASYNC", False),
            write_async_zero_copy=_bool(env, "CRAFT_WRITE_ASYNC_ZERO_COPY", False),
            async_thread_pin_cpulist=pin,
            use_node_level=use_node,
            read_cp_on_restart=_bool(env, "CRAFT_READ_CP_ON_RESTART", True),
            comm_recovery_policy=recovery,
            comm_spawn_policy=spawn,
            node_cp_path=Path(node_path) if node_path else None,
            node_redundancy=redundancy,
            xor_group_size=int(env.get("CRAFT_XOR_GROUP_SIZE", "8")),
            rs_parity=rs_parity,
            pfs_every=int(env.get("CRAFT_PFS_EVERY", "1")),
            keep_versions=int(env.get("CRAFT_KEEP_VERSIONS", "2")),
            compress=compress,
            zstd_level=zstd_level,
            zstd_gate_bits=zstd_gate_bits,
            checksum=checksum,
            codec_version=codec_version,
            chunk_bytes=chunk_bytes,
            io_workers=io_workers,
            delta=delta,
            delta_max_chain=delta_max_chain,
            device_snapshot=device_snapshot,
            reshard=reshard,
            elastic_hydrate=elastic_hydrate,
            tier_chain=tier_chain,
            mem_replicas=mem_replicas,
            mem_budget_bytes=mem_budget,
            mem_scratch=Path(mem_scratch) if mem_scratch else None,
            tier_every=tier_every,
            mtbf_seconds=mtbf_seconds,
            walltime_seconds=walltime_seconds,
            walltime_margin_seconds=walltime_margin,
            cp_signal=cp_signal,
            scrub_every=scrub_every,
            scrub_bytes_per_s=scrub_bytes_per_s,
            chaos=chaos,
            chaos_seed=chaos_seed,
            io_retries=io_retries,
            io_backoff_ms=io_backoff_ms,
            io_deadline_s=io_deadline_s,
            breaker_threshold=breaker_threshold,
            breaker_cooldown_s=breaker_cooldown_s,
            trace_path=trace_path,
            tune_online=tune_online,
            tune_every_s=tune_every_s,
            metrics=metrics,
            metrics_port=metrics_port,
        )


_AUTO = {"auto", "daly"}


def _parse_tier_every(raw: str) -> tuple:
    """``CRAFT_TIER_EVERY`` → ((slot, count|"auto"), ...).

    Accepted forms: ``auto`` (every chained tier on Daly intervals),
    ``mem:1,node:8,pfs:64`` (write counts per tier), and mixtures like
    ``node:8,pfs:auto``.  Counts are per *checkpoint opportunity* (calls that
    pass the ``cp_freq`` gate), so a sparse deep tier never starves.
    """
    raw = raw.strip().lower()
    if not raw:
        return ()
    if raw in _AUTO:
        return (("*", "auto"),)
    out = []
    for tok in raw.split(","):
        tok = tok.strip()
        if not tok:
            continue
        if ":" not in tok:
            raise ValueError(
                f"CRAFT_TIER_EVERY entry {tok!r}: expected slot:count or "
                "slot:auto (or a bare 'auto' for every tier)"
            )
        slot, val = (s.strip() for s in tok.split(":", 1))
        if slot not in ("mem", "node", "pfs"):
            raise ValueError(f"CRAFT_TIER_EVERY slot {slot!r}: "
                             "expected one of mem,node,pfs")
        if val in _AUTO:
            out.append((slot, "auto"))
        else:
            count = int(val)
            if count < 1:
                raise ValueError(f"CRAFT_TIER_EVERY {slot}:{val}: count >= 1")
            out.append((slot, count))
    slots = [s for s, _ in out]
    if len(set(slots)) != len(slots):
        raise ValueError(f"CRAFT_TIER_EVERY={raw!r}: duplicate slot")
    return tuple(out)


def _parse_cp_signal(raw: str) -> tuple:
    """``CRAFT_CP_SIGNAL`` → tuple of validated signal names ("SIGTERM", …)."""
    names = []
    for tok in raw.replace(";", ",").split(","):
        tok = tok.strip().upper()
        if not tok:
            continue
        if not tok.startswith("SIG"):
            tok = "SIG" + tok
        if not isinstance(getattr(_signal, tok, None), _signal.Signals):
            raise ValueError(f"CRAFT_CP_SIGNAL: unknown signal {tok!r}")
        names.append(tok)
    if len(set(names)) != len(names):
        raise ValueError(f"CRAFT_CP_SIGNAL={raw!r}: duplicate signal")
    return tuple(names)
