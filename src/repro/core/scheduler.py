"""Adaptive multi-level checkpoint scheduling (paper §2 ``needCheckpoint`` /
``updateAndWrite``, §4 overhead analysis).

The paper exposes *when* to checkpoint as the dominant cost knob but leaves
the decision to a fixed ``iteration % frequency`` modulo.  With three tiers
of wildly different write cost (mem ≪ node ≪ pfs) and a delta codec whose
cost varies with the dirty fraction, a fixed frequency is always wrong for
at least one tier.  :class:`CheckpointPolicy` replaces the modulo with a
per-tier decision, each step, of *whether* to checkpoint and *to which
tiers*:

* **cost model** — every landed write feeds an EWMA on its
  :class:`~repro.core.tiers.StorageTier` (seeded by the first full write;
  the RAM tier carries a cheap prior), so the schedule tracks the delta
  codec's actual cost, not the nominal payload size;
* **Young/Daly intervals** — ``CRAFT_TIER_EVERY=auto`` derives each tier's
  interval from its write cost δ and the MTBF M
  (:func:`daly_interval`); M comes from ``CRAFT_MTBF_SECONDS``, else from
  the communicator's empirical failure rate
  (``CollectiveEngine.empirical_mtbf``), else a 1-day default;
* **per-tier cadences** — ``CRAFT_TIER_EVERY=mem:1,node:8,pfs:64`` counts
  checkpoint opportunities per tier (the generalization of
  ``CRAFT_PFS_EVERY`` to the whole chain);
* **backpressure** — when the async writer queue is saturated the policy
  stretches intervals instead of stacking versions behind a slow tier;
* **preemption** — ``CRAFT_CP_SIGNAL=SIGTERM`` installs a handler that
  forces a synchronous, full (non-delta) flush of the deepest tier at the
  next step (batch-scheduler preemption notice);
* **walltime guard** — ``CRAFT_WALLTIME_SECONDS`` (+ margin + estimated
  write cost) schedules one final full checkpoint before the job dies;
* **recovery reset** — an AFT recovery bumps a process-wide epoch
  (:func:`notify_recovery`); every live policy then resets its estimators
  and forces its next write to be full (survivor tiers may have holes).

Tuning guide with worked examples: ``docs/tuning.md``.
"""
from __future__ import annotations

import dataclasses
import math
import signal as _signal
import threading
import time
from typing import Callable, Dict, Optional, Tuple

from repro.core import metrics, trace
from repro.core.env import CraftEnv

#: Fallback MTBF when neither ``CRAFT_MTBF_SECONDS`` nor an empirical rate
#: is available (one day — conservative for a single-node run).
DEFAULT_MTBF_SECONDS = 86400.0

#: Job-start reference for the walltime guard.  Captured at import (the
#: ``repro.core`` package imports this module, so effectively at program
#: start) — a batch scheduler's walltime clock starts at launch, not at
#: ``Checkpoint.commit()``, and setup time before commit() must count
#: against ``CRAFT_WALLTIME_SECONDS``.
_JOB_T0 = time.monotonic()

# ---------------------------------------------------------------------------
# process-wide recovery epoch
# ---------------------------------------------------------------------------
_EPOCH_LOCK = threading.Lock()
_RECOVERY_EPOCH = 0


def notify_recovery(stats: Optional[dict] = None) -> int:
    """Record that an AFT recovery happened (called by ``aft``); every
    live :class:`CheckpointPolicy` notices at its next decision, resets its
    cost estimators, and forces a full (non-delta) write."""
    global _RECOVERY_EPOCH
    trace.TRACER.emit("recovery")
    with _EPOCH_LOCK:
        _RECOVERY_EPOCH += 1
        return _RECOVERY_EPOCH


def recovery_epoch() -> int:
    with _EPOCH_LOCK:
        return _RECOVERY_EPOCH


# ---------------------------------------------------------------------------
# the Young/Daly optimum
# ---------------------------------------------------------------------------
def daly_interval(write_cost: float, mtbf: float) -> float:
    """Daly's higher-order optimum checkpoint interval (seconds of compute
    between checkpoints) for write cost ``write_cost`` (δ) and ``mtbf`` (M).

    For δ < 2M:  T = √(2δM)·[1 + ⅓·√(δ/2M) + (δ/2M)/9] − δ  (Daly 2006,
    reducing to Young's √(2δM) first-order form for δ ≪ M); for δ ≥ 2M the
    optimum saturates at T = M.  Monotonically increasing in δ over the
    useful range: a costlier tier checkpoints less often.
    """
    if write_cost <= 0.0:
        return 0.0
    if mtbf <= 0.0 or math.isinf(mtbf):
        return math.inf
    if write_cost >= 2.0 * mtbf:
        # saturation; the write-cost floor keeps this branch continuous and
        # monotone with the formula below (which floors the same way)
        return max(mtbf, write_cost)
    ratio = write_cost / (2.0 * mtbf)
    t = math.sqrt(2.0 * write_cost * mtbf) * (
        1.0 + math.sqrt(ratio) / 3.0 + ratio / 9.0
    ) - write_cost
    # never checkpoint more often than one write takes to land
    return max(t, write_cost)


@dataclasses.dataclass(frozen=True)
class Decision:
    """One step's scheduling verdict, consumed by ``Checkpoint``."""

    write: bool                      # write a new version at all?
    tiers: Tuple[str, ...] = ()      # chain slots this version lands on
    full: bool = False               # bypass the delta codec (self-contained)
    sync: bool = False               # inline write + drained async lane
    final: bool = False              # the walltime guard's last checkpoint
    reason: str = ""                 # "cadence" | "preempt" | "walltime" | …


_SKIP = Decision(write=False)


class CheckpointPolicy:
    """Per-checkpoint scheduler: decides, each step, whether to write and to
    which tiers (the paper's ``needCheckpoint()`` made cost-aware).

    ``stores`` maps chain slots (``"mem"``/``"node"``/``"pfs"``, in
    ``CRAFT_TIER_EVERY`` order) to the live :class:`StorageTier` objects —
    the policy reads each tier's write-cost EWMA from the tier itself.
    ``clock`` is injectable for deterministic tests and simulated sweeps;
    ``backpressure`` returns the async writer's queue depth;
    ``mtbf_fn`` returns the communicator's empirical MTBF (or ``None``).
    """

    def __init__(
        self,
        env: CraftEnv,
        stores: Dict[str, object],
        *,
        clock: Callable[[], float] = time.monotonic,
        backpressure: Optional[Callable[[], int]] = None,
        mtbf_fn: Optional[Callable[[], Optional[float]]] = None,
    ):
        self.env = env
        self._stores = dict(stores)
        self._chain: Tuple[str, ...] = tuple(stores)
        self._clock = clock
        self._backpressure = backpressure or (lambda: 0)
        self._mtbf_fn = mtbf_fn
        now = clock()
        # walltime elapses from job start (module import) on the real clock;
        # an injected clock (tests, simulations) starts at policy creation
        self._t_start = _JOB_T0 if clock is time.monotonic else now
        self._last_write_t = {slot: now for slot in self._chain}
        self._ticks = 0                       # checkpoint opportunities seen
        self._deferred: set = set()           # count-cadence hits delayed by
        #                                       backpressure, owed at the next
        #                                       un-saturated opportunity
        self._degraded: set = set()           # slots whose scheduled write was
        #                                       degraded away (breaker open /
        #                                       tier fault): owed every
        #                                       opportunity until a write
        #                                       actually lands there
        self._last_iteration: Optional[int] = None
        self._last_opportunity: Optional[int] = None
        self._last_tick_t: Optional[float] = None
        self._step_ewma: Optional[float] = None
        self._step_direct = False     # a driver feeds measured step times
        self._preempt = threading.Event()
        self._preempt_flushed = False
        self._final_written = False
        self._force_full = False
        self._seen_epoch = recovery_epoch()
        self._installed: list = []            # [(signum, previous handler)]
        self._cadence = self._resolve_cadence()
        # integrity scrubbing rides idle checkpoint opportunities: the first
        # slice is only due a full CRAFT_SCRUB_EVERY after policy creation,
        # so startup (restore, first writes) is never competing with scrub IO
        self._last_scrub_t = now
        # online re-tuning (CRAFT_TUNE_ONLINE): first solve is only due a
        # full CRAFT_TUNE_EVERY_S after policy creation, once live EWMAs
        # and a step estimate exist
        self._last_retune_t = now
        self._trace_inputs: Tuple = (None, 1, 1, 0)
        self.stats = {
            "decisions": 0, "writes": 0, "skips": 0,
            "preempt_flushes": 0, "final_writes": 0,
            "backpressure_stretches": 0, "recovery_resets": 0,
            "scrub_slices": 0, "online_retunes": 0,
        }

    # ------------------------------------------------------------- cadences
    def _resolve_cadence(self) -> Dict[str, object]:
        """Per-slot cadence: an int opportunity count or "auto" (Daly).

        Without ``CRAFT_TIER_EVERY`` the legacy semantics are preserved
        exactly: every chained tier writes every version, except the PFS
        tier which honors ``CRAFT_PFS_EVERY`` when a node tier shields it.
        """
        cadence: Dict[str, object] = {}
        for slot in self._chain:
            spec = self.env.tier_every_for(slot)
            if spec is None:
                if slot == "pfs" and "node" in self._chain \
                        and self.env.pfs_every > 1:
                    spec = self.env.pfs_every
                else:
                    spec = 1
            cadence[slot] = spec
        return cadence

    @property
    def chain(self) -> Tuple[str, ...]:
        return self._chain

    def cadence(self, slot: str):
        return self._cadence.get(slot)

    # ---------------------------------------------------------------- costs
    def tier_cost(self, slot: str) -> Optional[float]:
        store = self._stores.get(slot)
        if store is None:
            return None
        return store.write_cost()

    def mtbf(self) -> float:
        """MTBF feeding Daly: configured > empirical > 1-day default."""
        if self.env.mtbf_seconds > 0:
            return self.env.mtbf_seconds
        if self._mtbf_fn is not None:
            try:
                emp = self._mtbf_fn()
            except Exception:
                emp = None
            if emp is not None and emp > 0:
                return float(emp)
        return DEFAULT_MTBF_SECONDS

    def interval_seconds(self, slot: str) -> float:
        """This tier's Daly interval given its current cost estimate; 0.0
        while the cost is unknown (schedule the seeding write immediately)."""
        cost = self.tier_cost(slot)
        if cost is None:
            return 0.0
        return daly_interval(cost, self.mtbf())

    def step_seconds(self) -> Optional[float]:
        """EWMA of the application's step duration (observed from the gaps
        between decisions, or fed directly via :meth:`observe_step_seconds`)."""
        return self._step_ewma

    def observe_step_seconds(self, seconds: float) -> None:
        """Direct step-duration measurement (e.g. the train loop's timer) —
        overrides the decision-gap inference."""
        if seconds <= 0:
            return
        trace.TRACER.emit("step", seconds=seconds)
        self._step_direct = True
        prev = self._step_ewma
        self._step_ewma = seconds if prev is None else (
            0.8 * prev + 0.2 * seconds)

    # ------------------------------------------------------------- triggers
    def trigger_preemption(self) -> None:
        """Arm the preemption flush (what the signal handler does; tests and
        schedulers without signals call this directly)."""
        self._preempt.set()

    @property
    def preempted(self) -> bool:
        return self._preempt.is_set()

    @property
    def should_stop(self) -> bool:
        """The application should exit its loop: the preemption flush landed
        or the walltime guard wrote its final checkpoint."""
        return self._preempt_flushed or self._final_written

    def install_signal_handlers(self) -> None:
        """Install ``CRAFT_CP_SIGNAL`` handlers (main thread only — a no-op
        elsewhere, matching CPython's signal constraints)."""
        for name in self.env.cp_signal:
            signum = getattr(_signal, name)
            try:
                old = _signal.signal(signum, self._on_signal)
            except ValueError:       # not the main thread
                return
            self._installed.append((signum, old))

    def uninstall_signal_handlers(self) -> None:
        installed, self._installed = self._installed, []
        for signum, old in installed:
            try:
                _signal.signal(signum, old)
            except (ValueError, TypeError):
                pass

    def _on_signal(self, signum, frame) -> None:   # signal-safe: sets a flag
        self._preempt.set()

    # ------------------------------------------------------------ scrubbing
    def scrub_due(self) -> bool:
        """Should an integrity-scrub slice run now?  True only in an idle
        window: ``CRAFT_SCRUB_EVERY`` elapsed since the last slice, no
        preemption pending, and the async writer queue drained (scrub IO
        must never stretch a checkpoint landing)."""
        if self.env.scrub_every <= 0:
            return False
        if self._preempt.is_set() or self._final_written:
            return False
        if self._backpressure() > 0:
            return False
        return self._clock() - self._last_scrub_t >= self.env.scrub_every

    def note_scrub(self) -> None:
        """A scrub slice was scheduled — restart the scrub interval clock."""
        self._last_scrub_t = self._clock()
        self.stats["scrub_slices"] += 1

    # ------------------------------------------------------------- recovery
    def _maybe_reset_on_recovery(self) -> None:
        epoch = recovery_epoch()
        if epoch == self._seen_epoch:
            return
        self._seen_epoch = epoch
        self.reset_estimators()

    def reset_estimators(self) -> None:
        """Post-recovery reset: drop every tier's learned cost and force the
        next write full (survivor tiers may have holes).  Public so the
        trace replayer (:mod:`repro.core.simulate`) can apply a recorded
        recovery without touching the process-wide epoch."""
        for store in self._stores.values():
            store.reset_cost()
        self._force_full = True
        self.stats["recovery_resets"] += 1

    def notify_restore(self) -> None:
        """A restore just completed: restart every tier's interval clock so
        the resumed run doesn't immediately re-write what it just read."""
        now = self._clock()
        for slot in self._chain:
            self._last_write_t[slot] = now

    # ------------------------------------------------------------- decision
    def need_checkpoint(
        self,
        iteration: Optional[int] = None,
        cp_freq: int = 1,
        *,
        next_version: int = 1,
    ) -> Decision:
        """The scheduling decision for this step (paper ``needCheckpoint()``).

        Idempotent within a step: the opportunity counter advances once per
        distinct ``iteration``, so probing the decision and then writing
        (the paper's ``needCheckpoint()`` → ``updateAndWrite()`` pattern)
        never double-counts (``Checkpoint`` additionally caches it).
        """
        now = self._clock()
        self._observe_tick(now, iteration)
        self._maybe_reset_on_recovery()
        self._maybe_retune(now)
        self.stats["decisions"] += 1
        # one backpressure reading per decision (also what the trace
        # records, so a replayed policy sees the identical input)
        pending = max(0, int(self._backpressure()))
        self._trace_inputs = (iteration, cp_freq, next_version, pending)

        # external triggers trump every cadence gate
        if self._preempt.is_set() and not self._preempt_flushed:
            return self._emit(Decision(
                write=True, tiers=(self._deepest(),), full=True, sync=True,
                reason="preempt",
            ))
        if self._walltime_due(now):
            return self._emit(Decision(
                write=True, tiers=self._chain, full=True, sync=True,
                final=True, reason="walltime",
            ))

        # the paper's frequency gate still applies when the caller uses it
        if iteration is not None and cp_freq > 1 and iteration % cp_freq != 0:
            return self._emit(_SKIP)
        if not self._chain:
            return self._emit(_SKIP)

        stretch = 1.0 + pending
        adaptive = bool(self.env.tier_every)
        if adaptive and pending > 0:
            self.stats["backpressure_stretches"] += 1

        # one opportunity per distinct iteration past the cp_freq gate
        if iteration is None or iteration != self._last_opportunity:
            self._ticks += 1
            self._last_opportunity = iteration
        ticks = self._ticks
        due = []
        for slot in self._chain:
            if slot in self._degraded:
                # its last scheduled write never landed (routed to a deeper
                # tier) — keep scheduling it until one does
                due.append(slot)
                continue
            spec = self._cadence[slot]
            if spec == "auto":
                interval = self.interval_seconds(slot) * stretch
                if now - self._last_write_t[slot] >= interval:
                    due.append(slot)
            elif adaptive:
                # opportunity-count cadence; a saturated writer queue defers
                # the hit — it is owed (not skipped) at the next opportunity
                # where the queue has drained
                hit = ticks % int(spec) == 0
                if pending > 0:
                    if hit:
                        self._deferred.add(slot)
                elif hit or slot in self._deferred:
                    due.append(slot)
            else:
                # legacy, version-number based (bit-compatible with the old
                # `pfs_every` modulo)
                if int(spec) <= 1 or next_version % int(spec) == 0:
                    due.append(slot)
        if not due:
            return self._emit(_SKIP)
        full = self._force_full
        return self._emit(Decision(
            write=True, tiers=tuple(due), full=full,
            reason="recovery-full" if full else "cadence",
        ))

    def record_written(self, decision: Decision, version: int) -> None:
        """Advance cadence state after ``Checkpoint`` scheduled the write."""
        if not decision.write:
            return
        trace.TRACER.emit("scheduled", version=version,
                          tiers=list(decision.tiers), reason=decision.reason)
        now = self._clock()
        for slot in decision.tiers:
            if slot in self._degraded:
                # the write was routed away from this tier — landing on a
                # deeper tier must not satisfy this tier's cadence
                continue
            self._last_write_t[slot] = now
            self._deferred.discard(slot)
        if decision.reason == "preempt":
            self._preempt_flushed = True
            self.stats["preempt_flushes"] += 1
        if decision.final:
            self._final_written = True
            self.stats["final_writes"] += 1
        self._force_full = False
        self.stats["writes"] += 1

    # ------------------------------------------- degraded-mode notifications
    def note_degraded(self, slot: str) -> None:
        """``Checkpoint`` degraded a scheduled write away from ``slot``
        (circuit breaker open, or the tier write failed).  The slot becomes
        overdue — and stays owed at every opportunity — until a write lands
        on it again (:meth:`note_tier_written`)."""
        if slot not in self._chain:
            return
        trace.TRACER.emit("degraded", slot=slot)
        self._degraded.add(slot)
        self._last_write_t[slot] = -math.inf
        metrics.set_gauge("policy_degraded_slots", len(self._degraded))

    def note_tier_written(self, slot: str) -> None:
        """A write actually landed on ``slot`` (called by ``Checkpoint`` on
        tier-write success — the authoritative cadence reset, unlike
        :meth:`record_written` which only sees the *scheduled* tier set)."""
        self._degraded.discard(slot)
        self._deferred.discard(slot)
        metrics.set_gauge("policy_degraded_slots", len(self._degraded))
        if slot in self._last_write_t:
            self._last_write_t[slot] = self._clock()

    def degraded_slots(self) -> Tuple[str, ...]:
        return tuple(s for s in self._chain if s in self._degraded)

    # -------------------------------------------------- online re-tuning
    def _maybe_retune(self, now: float) -> None:
        """``CRAFT_TUNE_ONLINE``: periodically re-solve the count cadences
        from live write-cost EWMAs and the empirical MTBF — the offline
        ``craft tune`` solve, folded into the running policy.

        Only count cadences under ``CRAFT_TIER_EVERY`` are touched ("auto"
        slots already re-derive their Daly interval every decision; the
        legacy version-modulo mode keeps its bit-compatible behavior), and
        only once a step-duration estimate exists to convert seconds into
        checkpoint opportunities.
        """
        if not (self.env.tune_online and self.env.tier_every):
            return
        if now - self._last_retune_t < self.env.tune_every_s:
            return
        self._last_retune_t = now
        step = self._step_ewma
        if not step or step <= 0:
            return
        mtbf = self.mtbf()
        changed = {}
        for slot in self._chain:
            spec = self._cadence.get(slot)
            if not isinstance(spec, int):
                continue
            cost = self.tier_cost(slot)
            if cost is None or cost <= 0:
                continue
            interval = daly_interval(cost, mtbf)
            if not math.isfinite(interval):
                continue
            count = max(1, int(round(interval / step)))
            if count != spec:
                self._cadence[slot] = count
                changed[slot] = count
        if changed:
            self.stats["online_retunes"] += 1
            metrics.inc("policy_retunes")
            trace.TRACER.emit("retune", cadence={
                s: self._cadence[s] for s in self._chain})

    # ------------------------------------------------------------ internals
    def _emit(self, d: Decision) -> Decision:
        if not d.write:
            self.stats["skips"] += 1
        if metrics.REGISTRY.enabled:   # skip the kwargs build on the no-op
            metrics.inc("policy_decisions", reason=d.reason or "skip",
                        write="true" if d.write else "false")
        tr = trace.TRACER
        if tr.enabled:
            it, cp_freq, next_version, pending = self._trace_inputs
            tr.emit(
                "decision", it=it, cp_freq=cp_freq,
                next_version=next_version, pending=pending,
                write=d.write, tiers=list(d.tiers), full=d.full,
                sync=d.sync, final=d.final, reason=d.reason,
            )
        return d

    def _deepest(self) -> str:
        return self._chain[-1] if self._chain else "pfs"

    def _walltime_due(self, now: float) -> bool:
        wt = self.env.walltime_seconds
        if wt <= 0 or self._final_written:
            return False
        est_write = sum(self.tier_cost(s) or 0.0 for s in self._chain)
        # decisions happen once per step: if this one doesn't fire, the next
        # chance is a full step away — budget for it too
        est_step = self._step_ewma or 0.0
        deadline = wt - self.env.walltime_margin_seconds - est_write - est_step
        return (now - self._t_start) >= deadline

    def _observe_tick(self, now: float, iteration: Optional[int]) -> None:
        """Infer step duration from the EWMA of gaps between successive
        decisions (distinct iterations only, so probing twice is free).
        Inference stops as soon as a driver feeds measured step times via
        :meth:`observe_step_seconds` — gaps include checkpoint-write time,
        direct measurements don't."""
        if iteration is not None and iteration == self._last_iteration:
            return
        if self._last_tick_t is not None and not self._step_direct:
            gap = now - self._last_tick_t
            if gap > 0:
                prev = self._step_ewma
                self._step_ewma = gap if prev is None else (
                    0.8 * prev + 0.2 * gap)
        self._last_tick_t = now
        self._last_iteration = iteration
