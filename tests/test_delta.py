"""Incremental chunk-delta checkpointing (codec v2, CRAFT_DELTA=1).

Covers the delta write path (refs for clean chunks, byte savings), the
chain-aware restore (bit-identical to a full-codec restore, including across
mem→node→pfs tier failover), base-version pinning in retention, compaction
at CRAFT_DELTA_MAX_CHAIN, the cross-codec version matrix (v0/v1/v2 written
in any order), and the explicit errors for broken chains.
"""
import json
import shutil

import numpy as np
import pytest

from repro.core import Box, Checkpoint, MemFabric
from repro.core import storage, tiers
from repro.core.cpbase import CheckpointError, IOContext
from repro.core.env import CraftEnv
from repro.core.mem_level import MemStore


CHUNK = 64          # tiny chunks so a few hundred bytes span many chunks


def _env(tmp_path, **extra):
    base = {
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_USE_SCR": "0",
        "CRAFT_DELTA": "1",
        "CRAFT_CHUNK_BYTES": str(CHUNK),
        "CRAFT_KEEP_VERSIONS": "8",
    }
    base.update(extra)
    return CraftEnv.capture(base)


def _header(path):
    raw = path.read_bytes()
    hlen = int.from_bytes(raw[4:12], "little")
    return json.loads(raw[12:12 + hlen])


def _refs(path):
    return [c["ref"] for c in _header(path)["chunks"] if "ref" in c]


def _tree_bytes(root):
    return sum(p.stat().st_size for p in root.rglob("*") if p.is_file())


def _write_versions(env, name, payloads, key="a"):
    """Write one version per payload (in-place mutation of a live array)."""
    arr = payloads[0].copy()
    cp = Checkpoint(name, env=env)
    cp.add(key, arr)
    cp.commit()
    for p in payloads:
        arr[...] = p
        cp.update_and_write()
    cp.close()
    return cp


def _restore(env, name, shape, dtype=np.uint8, key="a"):
    arr = np.zeros(shape, dtype=dtype)
    cp = Checkpoint(name, env=env)
    cp.add(key, arr)
    cp.commit()
    assert cp.restart_if_needed()
    cp.close()
    return arr, cp


class TestDeltaWrite:
    def test_clean_chunks_become_refs(self, tmp_path, rng):
        env = _env(tmp_path)
        base = rng.integers(0, 255, 4 * CHUNK, dtype=np.uint8)
        v2 = base.copy()
        v2[0] ^= 0xFF          # dirty only the first chunk
        cp = _write_versions(env, "d", [base, v2])
        f = env.cp_path / "d" / "v-2" / "a" / "array.bin"
        header = _header(f)
        assert header["fmt"] == 2
        kinds = ["ref" if "ref" in c else "lit" for c in header["chunks"]]
        assert kinds == ["lit", "ref", "ref", "ref"]
        assert _refs(f) == [1, 1, 1]
        assert cp.stats["delta_chunks_skipped"] == 3

    def test_delta_bytes_at_10pct_dirty_are_5x_smaller(self, tmp_path, rng):
        """The acceptance bar: ≤10% dirty chunks ⇒ ≥5x fewer bytes written.

        Uses chunks big enough that payload dominates the per-chunk header
        entries, as in any realistic configuration (the default is 4 MiB)."""
        chunk, n_chunks = 4096, 40
        base = rng.integers(0, 255, n_chunks * chunk, dtype=np.uint8)
        dirty = base.copy()
        for c in range(4):                   # 10% of 40 chunks
            dirty[c * 10 * chunk] ^= 0xFF
        env = _env(tmp_path, CRAFT_CHUNK_BYTES=str(chunk))
        _write_versions(env, "d", [base, dirty])
        root = env.cp_path / "d"
        full_b = _tree_bytes(root / "v-1")
        delta_b = _tree_bytes(root / "v-2")
        assert full_b >= 5 * delta_b, (full_b, delta_b)

    def test_all_dirty_writes_no_refs(self, tmp_path, rng):
        env = _env(tmp_path)
        a = rng.integers(0, 255, 4 * CHUNK, dtype=np.uint8)
        b = rng.integers(0, 255, 4 * CHUNK, dtype=np.uint8)
        _write_versions(env, "d", [a, b])
        f = env.cp_path / "d" / "v-2" / "a" / "array.bin"
        assert _refs(f) == []
        deps = json.loads(
            (env.cp_path / "d" / "v-2" / "deltadeps-0.json").read_text())
        assert deps["deps"] == []            # self-contained, nothing pinned

    def test_shape_change_falls_back_to_full(self, tmp_path, rng):
        env = _env(tmp_path)
        arr = rng.integers(0, 255, 4 * CHUNK, dtype=np.uint8)
        box = Box({"w": arr})
        cp = Checkpoint("d", env=env)
        cp.add("s", box)
        cp.commit()
        cp.update_and_write()
        box.value = {"w": rng.integers(0, 255, 6 * CHUNK, dtype=np.uint8)}
        cp.update_and_write()                # regridded — must not delta
        cp.close()
        leaf = next((env.cp_path / "d" / "v-2" / "s").glob("leaf*.bin"))
        assert _refs(leaf) == []


class TestDeltaRestore:
    def test_chain_restore_bit_identical(self, tmp_path, rng):
        env = _env(tmp_path)
        payloads = [rng.integers(0, 255, 6 * CHUNK, dtype=np.uint8)]
        for v in range(2):                   # two deltas on top of the full
            p = payloads[-1].copy()
            p[v * CHUNK] ^= 0xFF
            payloads.append(p)
        _write_versions(env, "d", payloads)
        f = env.cp_path / "d" / "v-3" / "a" / "array.bin"
        assert _refs(f)                      # head really is a delta
        restored, cp = _restore(env, "d", payloads[-1].shape)
        assert cp.version == 3
        assert restored.tobytes() == payloads[-1].tobytes()

    def test_delta_restore_equals_full_codec_restore(self, tmp_path, rng):
        """The same logical state, written delta and written full, restores
        to byte-identical content."""
        payloads = [rng.integers(0, 255, 6 * CHUNK, dtype=np.uint8)]
        p = payloads[0].copy()
        p[2 * CHUNK + 7] ^= 0x55
        payloads.append(p)
        env_d = _env(tmp_path, CRAFT_CP_PATH=str(tmp_path / "pfs_d"))
        env_f = _env(tmp_path, CRAFT_CP_PATH=str(tmp_path / "pfs_f"),
                     CRAFT_DELTA="0", CRAFT_CODEC_VERSION="1")
        _write_versions(env_d, "d", payloads)
        _write_versions(env_f, "d", payloads)
        a_d, _ = _restore(env_d, "d", payloads[-1].shape)
        a_f, _ = _restore(env_f, "d", payloads[-1].shape)
        assert a_d.tobytes() == a_f.tobytes()

    def test_missing_base_is_explicit_checkpoint_error(self, tmp_path, rng):
        env = _env(tmp_path)
        base = rng.integers(0, 255, 4 * CHUNK, dtype=np.uint8)
        v2 = base.copy()
        v2[0] ^= 1
        _write_versions(env, "d", [base, v2])
        root = env.cp_path / "d"
        shutil.rmtree(root / "v-1")          # break the chain behind retire
        arr = np.zeros(base.shape, dtype=np.uint8)
        cp = Checkpoint("d", env=env)
        cp.add("a", arr)
        cp.commit()
        # agreement sees the broken chain and refuses v-2; nothing else is
        # restorable so this is a clean "no checkpoint" start, not a crash
        assert not cp.restart_if_needed()
        cp.close()

    def test_raw_reader_without_chain_raises_explicitly(self, tmp_path, rng):
        env = _env(tmp_path)
        base = rng.integers(0, 255, 4 * CHUNK, dtype=np.uint8)
        v2 = base.copy()
        v2[0] ^= 1
        _write_versions(env, "d", [base, v2])
        f = env.cp_path / "d" / "v-2" / "a" / "array.bin"
        with pytest.raises(CheckpointError, match="delta ref|base"):
            storage.read_array(f, IOContext())


class TestCrossCodecMatrix:
    """State written v0/v1/v2 in any order restores correctly."""

    @pytest.mark.parametrize("order", [
        ("0", "1", "2"), ("2", "1", "0"), ("1", "2", "0"),
        ("0", "2", "2"), ("2", "0", "2"), ("2", "2", "2"),
    ])
    def test_mixed_codec_versions(self, tmp_path, rng, order):
        shape = (6 * CHUNK,)
        state = rng.integers(0, 255, shape, dtype=np.uint8)
        expected = None
        for i, codec in enumerate(order):
            env = _env(tmp_path, CRAFT_CODEC_VERSION=codec,
                       CRAFT_DELTA="1" if codec == "2" else "0")
            arr = np.zeros(shape, dtype=np.uint8)
            cp = Checkpoint("mx", env=env)
            cp.add("a", arr)
            cp.commit()
            if i:
                assert cp.restart_if_needed()
                assert arr.tobytes() == expected
            arr[...] = state
            arr[i * CHUNK] ^= 0xFF           # mutate a different chunk each time
            expected = arr.tobytes()
            cp.update_and_write()
            cp.close()
        final, _ = _restore(_env(tmp_path), "mx", shape)
        assert final.tobytes() == expected


class TestCompaction:
    def test_full_rewrite_at_max_chain(self, tmp_path, rng):
        env = _env(tmp_path, CRAFT_DELTA_MAX_CHAIN="3")
        arr = rng.integers(0, 255, 4 * CHUNK, dtype=np.uint8)
        cp = Checkpoint("d", env=env)
        cp.add("a", arr)
        cp.commit()
        for v in range(1, 8):
            arr[0] = v
            cp.update_and_write()
        cp.close()
        root = env.cp_path / "d"
        deps = {
            p.parent.name: json.loads(p.read_text())["deps"]
            for p in root.glob("v-*/deltadeps-0.json")
        }
        # chain of 3 (full, delta, delta), then compaction restarts it
        assert deps["v-1"] == [] and deps["v-4"] == [] and deps["v-7"] == []
        assert deps["v-2"] == [1] and deps["v-3"] == [1, 2]
        assert cp.stats["delta_compactions"] >= 2
        restored, cp2 = _restore(env, "d", arr.shape)
        assert cp2.version == 7
        assert restored.tobytes() == arr.tobytes()


class TestPinning:
    def test_retire_never_drops_referenced_bases(self, tmp_path, rng):
        env = _env(tmp_path, CRAFT_KEEP_VERSIONS="2",
                   CRAFT_DELTA_MAX_CHAIN="8")
        arr = rng.integers(0, 255, 4 * CHUNK, dtype=np.uint8)
        cp = Checkpoint("d", env=env)
        cp.add("a", arr)
        cp.commit()
        for v in range(1, 6):
            arr[0] = v
            cp.update_and_write()
        cp.close()
        root = env.cp_path / "d"
        kept = sorted(int(p.name[2:]) for p in root.glob("v-*"))
        # v-5's chain reaches all the way to the full v-1: everything pinned
        assert kept == [1, 2, 3, 4, 5]
        meta = json.loads((root / "meta.json").read_text())
        assert meta["versions"] == kept      # metadata advertises pinned dirs
        restored, _ = _restore(env, "d", arr.shape)
        assert restored[0] == 5

    def test_unpinned_versions_still_retire(self, tmp_path, rng):
        env = _env(tmp_path, CRAFT_KEEP_VERSIONS="2",
                   CRAFT_DELTA_MAX_CHAIN="2")
        arr = rng.integers(0, 255, 4 * CHUNK, dtype=np.uint8)
        cp = Checkpoint("d", env=env)
        cp.add("a", arr)
        cp.commit()
        for v in range(1, 6):                # chain resets every 2 versions
            arr[0] = v
            cp.update_and_write()
        cp.close()
        root = env.cp_path / "d"
        kept = sorted(int(p.name[2:]) for p in root.glob("v-*"))
        assert kept[-1] == 5 and len(kept) <= 3   # old chains actually gone

    def test_read_delta_deps_ignores_garbage(self, tmp_path):
        vdir = tmp_path / "v-3"
        vdir.mkdir()
        (vdir / "deltadeps-0.json").write_text('{"deps": [1, 2]}')
        (vdir / "deltadeps-1.json").write_text("not json")
        assert tiers.read_delta_deps(vdir) == {1, 2}


class TestTierFailover:
    def _env3(self, tmp_path, **extra):
        return _env(
            tmp_path,
            CRAFT_USE_SCR="1",
            CRAFT_NODE_CP_PATH=str(tmp_path / "node"),
            CRAFT_NODE_REDUNDANCY="LOCAL",
            CRAFT_TIER_CHAIN="mem,node,pfs",
            CRAFT_MEM_SCRATCH=str(tmp_path / "shm"),
            **extra,
        )

    def _chain_state(self, tmp_path, rng):
        env = self._env3(tmp_path)
        arr = rng.integers(0, 255, 6 * CHUNK, dtype=np.uint8)
        cp = Checkpoint("fo", env=env)
        cp.add("a", arr)
        cp.commit()
        cp.update_and_write()
        arr[0] ^= 1
        cp.update_and_write()                # v2 is a delta on every disk tier
        cp.close()
        return env, arr.copy()

    def test_delta_chain_restores_after_mem_then_node_loss(
            self, tmp_path, rng):
        env, expected = self._chain_state(tmp_path, rng)
        # mem alive: fastest tier serves the (decoded, full) state
        a, cp = _restore(env, "fo", expected.shape)
        assert cp.stats["restore_tier"] == "mem"
        assert a.tobytes() == expected.tobytes()
        # RAM gone: the node tier resolves the delta chain
        MemFabric.instance().reset()
        a, cp = _restore(env, "fo", expected.shape)
        assert cp.stats["restore_tier"] == "node"
        assert a.tobytes() == expected.tobytes()
        # node tier gone too: PFS resolves the same chain
        MemFabric.instance().reset()
        shutil.rmtree(tmp_path / "node")
        a, cp = _restore(env, "fo", expected.shape)
        assert cp.stats["restore_tier"] == "pfs"
        assert a.tobytes() == expected.tobytes()

    def test_mem_restore_primes_first_write_as_delta(self, tmp_path, rng):
        """After a RAM restore the diff digests come straight from the
        decoded shards — the first resumed write already skips clean
        chunks, with zero disk reads for the digest pass."""
        env, expected = self._chain_state(tmp_path, rng)
        arr = np.zeros(expected.shape, dtype=np.uint8)
        cp = Checkpoint("fo", env=env)
        cp.add("a", arr)
        cp.commit()
        assert cp.restart_if_needed()
        assert cp.stats["restore_tier"] == "mem"
        arr[CHUNK] ^= 1                      # dirty exactly one chunk
        cp.update_and_write()
        cp.close()
        assert cp.stats["delta_chunks_skipped"] > 0
        f = env.cp_path / "fo" / "v-3" / "a" / "array.bin"
        assert len(_refs(f)) == 5            # 6 chunks, 1 dirty
        # and the delta written against RAM-served digests restores exactly
        MemFabric.instance().reset()
        a, _ = _restore(env, "fo", expected.shape)
        assert a.tobytes() == arr.tobytes()

    def test_partner_mirror_recovers_whole_delta_chain(self, tmp_path, rng):
        """Losing a node must recover the delta head *and* its bases from the
        partner mirror before the chain can be decoded."""
        from tests.test_node_level import FakeComm

        def env_for(rank_unused):
            return _env(
                tmp_path,
                CRAFT_USE_SCR="1",
                CRAFT_NODE_CP_PATH=str(tmp_path / "node"),
                CRAFT_NODE_REDUNDANCY="PARTNER",
                CRAFT_PFS_EVERY="100",       # node tier only
            )

        n_nodes = 2
        payload = {r: rng.integers(0, 255, 4 * CHUNK, dtype=np.uint8)
                   for r in range(n_nodes)}
        cps = {}
        for rank in range(n_nodes):
            cp = Checkpoint("pm", FakeComm(rank, n_nodes), env=env_for(rank))
            cp.add("arr", payload[rank])
            cp.commit()
            cps[rank] = cp
        for version in range(2):             # v-1 full, v-2 delta
            for rank in range(n_nodes):
                payload[rank][0] ^= 0xFF
                cps[rank].update_and_write()
        expected = payload[0].copy()
        for cp in cps.values():
            cp.close()
        f = tmp_path / "node" / "node-0" / "pm" / "v-2" / "arr" / "array.bin"
        assert _refs(f) == [1, 1, 1]
        shutil.rmtree(tmp_path / "node" / "node-0" / "pm")  # node 0 dies
        arr = np.zeros(expected.shape, dtype=np.uint8)
        cp = Checkpoint("pm", FakeComm(0, n_nodes), env=env_for(0))
        cp.add("arr", arr)
        cp.commit()
        assert cp.restart_if_needed()
        cp.close()
        assert cp.stats["restore_tier"] == "node"
        assert cp.version == 2
        assert arr.tobytes() == expected.tobytes()

    def test_mem_chunk_digests_match_codec(self, tmp_path, rng):
        env = self._env3(tmp_path)
        arr = rng.integers(0, 255, 5 * CHUNK + 13, dtype=np.uint8)
        cp = Checkpoint("cd", env=env)
        cp.add("a", arr)
        cp.commit()
        cp.update_and_write()
        cp.close()
        mem = MemStore("cd", cp.comm, env)
        served = mem.chunk_digests(1, CHUNK)
        assert served is not None and "a/array.bin" in served
        f = env.cp_path / "cd" / "v-1" / "a" / "array.bin"
        header = _header(f)
        assert served["a/array.bin"]["rdigests"] == [
            c["rdigest"] for c in header["chunks"]]


class TestBenchmarkScenario:
    def test_delta_write_scenario_registered(self):
        from benchmarks import cr_overhead

        assert "delta_write" in cr_overhead._SCENARIOS
        assert "codec_throughput" in cr_overhead._SCENARIOS
