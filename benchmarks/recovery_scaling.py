"""Paper Figs. 5/6 + Table 3: communication-recovery overhead scaling,
plus the memory-tier restore comparison (docs/architecture.md §memory tier)
and the RS erasure-coding cost/rebuild profile.

Fig. 5  — recovery time vs #procs for SHRINKING / NON-SHRINKING(REUSE) /
          NON-SHRINKING(NO-REUSE), 2 procs per node.
Fig. 6  — recovery time vs procs-per-node at a fixed node count.
Table 3 — per-phase breakdown of one NON-SHRINKING NO-REUSE recovery at the
          largest size.
mem_restore — end-to-end ``restart_if_needed()`` latency for the same state
          served by the memory tier (RAM shards, publish-time verified,
          array-cache fast path) vs the PFS tier (file IO + full codec
          decode + per-chunk digest verification); reports the speedup.
rs_repair — node-tier redundancy cost model: RS(k, m) encode throughput for
          m=1,2 vs the XOR parity and PARTNER mirror baselines, and rebuild
          latency for one and two simultaneous member losses
          (docs/architecture.md §redundancy & integrity).

Scenario CLI (mirrors ``cr_overhead.py``)::

    PYTHONPATH=src:. python benchmarks/recovery_scaling.py \
        [rs_repair mem_restore ...] [--full] [--json OUT.json]

The SimComm backend reproduces the recovery *bookkeeping* at sizes beyond
what one CPU can host as real processes (threads as ranks); the real-process
path is exercised by tests/test_runtime.py and examples/train_cluster.py.
"""
from __future__ import annotations

import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit
from repro.core import Box, Checkpoint
from repro.core.comm import ProcFailedError, RevokedError
from repro.core.comm_sim import SimWorld
from repro.core.env import CraftEnv
from repro.core.mem_level import MemFabric


def _recover_once(n_procs: int, ppn: int, policy: str, spawn: str) -> dict:
    env = CraftEnv.capture({
        "CRAFT_COMM_RECOVERY_POLICY": policy,
        "CRAFT_COMM_SPAWN_POLICY": spawn,
    })
    world = SimWorld(n_procs, procs_per_node=ppn, spare_nodes=2, env=env)
    victim = n_procs - 1

    def fn(comm):
        recovered = {}
        while True:
            try:
                if comm.rank == 0 and comm.epoch == 0:
                    world.kill(victim)
                for _ in range(3):
                    comm.barrier()
                return recovered
            except (ProcFailedError, RevokedError):
                try:
                    comm.revoke()
                except Exception:
                    pass
                t0 = time.perf_counter()
                comm = comm.recover(policy=policy)
                recovered = dict(comm.last_recovery_stats())
                recovered["wall_s"] = time.perf_counter() - t0

    out = world.run(fn, timeout=600)
    stats = [v for v in out.values() if v]
    stats.sort(key=lambda s: -s.get("wall_s", 0.0))
    return stats[0] if stats else {}


def fig5(sizes, ppn=2) -> None:
    for n in sizes:
        for policy, spawn in (("SHRINKING", "NO-REUSE"),
                              ("NON-SHRINKING", "REUSE"),
                              ("NON-SHRINKING", "NO-REUSE")):
            s = _recover_once(n, ppn, policy, spawn)
            emit("fig5_recovery_scaling", f"{policy}/{spawn}",
                 round(s.get("wall_s", float("nan")), 5), "s", procs=n)


def fig6(n_nodes, ppns) -> None:
    for ppn in ppns:
        s = _recover_once(n_nodes * ppn, ppn, "NON-SHRINKING", "NO-REUSE")
        emit("fig6_procs_per_node", f"ppn{ppn}",
             round(s.get("wall_s", float("nan")), 5), "s",
             nodes=n_nodes, procs=n_nodes * ppn)


def table3(n_procs, ppn=2) -> None:
    s = _recover_once(n_procs, ppn, "NON-SHRINKING", "NO-REUSE")
    for phase in ("revoke_shrink_s", "spawn_info_s", "spawn_merge_s",
                  "redistribute_s", "resource_mgmt_s"):
        emit("table3_recovery_breakdown", phase,
             round(s.get(phase, float("nan")), 6), "s", procs=n_procs)


def _train_state(n_layers: int, leaf_kb: int) -> dict:
    """A model-shaped pytree: many weight tensors + small biases, the state
    profile a real training job checkpoints every few minutes."""
    rng = np.random.default_rng(0)
    n = leaf_kb * 1024 // 8
    return {
        f"layer{i}": {"w": rng.random(n), "b": rng.random(64)}
        for i in range(n_layers)
    }


def _restore_once(base: Path, chain: str, n_layers: int, leaf_kb: int,
                  repeats: int) -> float:
    """Median ``restart_if_needed()`` wall time for one tier configuration.

    The same train state is written once through ``chain``; each measurement
    restores into a fresh ``Checkpoint`` so the in-memory CP-version counter
    doesn't short-circuit the read.  Codec settings stay at their defaults
    (chunked v1, digest verification on) for both tiers.
    """
    env = CraftEnv.capture({
        "CRAFT_CP_PATH": str(base / "pfs"),
        "CRAFT_USE_SCR": "0",
        "CRAFT_TIER_CHAIN": chain,
        "CRAFT_MEM_SCRATCH": str(base / "shm"),
    })
    state = Box(_train_state(n_layers, leaf_kb))
    name = f"restore-{chain.replace(',', '-')}"
    cp = Checkpoint(name, env=env)
    cp.add("state", state)
    cp.add("it", Box(1))
    cp.commit()
    cp.update_and_write()
    cp.close()

    times = []
    for _ in range(repeats):
        target = Box(_train_state(n_layers, leaf_kb))
        target.value["layer0"]["w"][:] = 0.0
        rcp = Checkpoint(name, env=env)
        rcp.add("state", target)
        rcp.add("it", Box(0))
        rcp.commit()
        t0 = time.perf_counter()
        assert rcp.restart_if_needed()
        times.append(time.perf_counter() - t0)
        assert rcp.stats["restore_tier"] == chain.split(",")[0]
        assert target.value["layer0"]["w"][0] == state.value["layer0"]["w"][0]
        rcp.close()
    return sorted(times)[len(times) // 2]


def mem_restore(n_layers: int = 128, leaf_kb: int = 256,
                repeats: int = 5) -> float:
    """Memory-tier vs PFS restore of the same state; returns the speedup."""
    base = Path(tempfile.mkdtemp(prefix="craft-memrestore-"))
    mb = n_layers * leaf_kb // 1024
    try:
        MemFabric.instance().reset()
        mem_s = _restore_once(base, "mem,pfs", n_layers, leaf_kb, repeats)
        MemFabric.instance().reset()     # drop RAM: forces the PFS path
        pfs_s = _restore_once(base, "pfs", n_layers, leaf_kb, repeats)
    finally:
        MemFabric.instance().reset()
        shutil.rmtree(base, ignore_errors=True)
    speedup = pfs_s / mem_s if mem_s > 0 else float("inf")
    emit("mem_restore", "mem_tier", round(mem_s, 5), "s",
         layers=n_layers, mb=mb)
    emit("mem_restore", "pfs_tier", round(pfs_s, 5), "s",
         layers=n_layers, mb=mb)
    emit("mem_restore", "speedup", round(speedup, 2), "x",
         layers=n_layers, mb=mb)
    return speedup


def rs_repair(full: bool = False) -> None:
    """RS(k, m) erasure coding vs PARTNER/XOR: encode cost + rebuild time.

    Buffer-level (the node tier's unit of work is one member's concatenated
    payload): PARTNER is a full payload copy per member, XOR one parity
    buffer per group (single-loss tolerance), RS(k, m) m parity buffers
    (any-m-loss tolerance).  Encode throughput is reported over the k·B
    group payload; rebuild times cover one lost member (PARTNER copy-back /
    XOR reconstruct / RS solve) and two lost members (RS m=2 only — the
    configurations below it cannot rebuild that at all).
    """
    from repro.kernels.rs_erasure import ops as rs_ops
    from repro.kernels.xor_parity import ops as xor_ops

    k = 8
    mb = 16 if full else 8
    nbytes = mb * 1024 * 1024
    rng = np.random.default_rng(0)
    bufs = [rng.integers(0, 256, nbytes, dtype=np.uint8) for _ in range(k)]
    sizes = [nbytes] * k
    group_mb = k * mb
    repeats = 3

    def best(fn):
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        return min(times)

    # -- encode cost ---------------------------------------------------------
    t_partner = best(lambda: [bytes(b) for b in bufs])    # full mirror copy
    emit("rs_repair", "encode_partner", round(group_mb / t_partner, 1),
         "MB/s", k=k, payload_mb=group_mb, tolerates=1)
    t_xor = best(lambda: xor_ops.parity_of_buffers(bufs))
    emit("rs_repair", "encode_xor", round(group_mb / t_xor, 1),
         "MB/s", k=k, payload_mb=group_mb, tolerates=1)
    for m in (1, 2):
        t_rs = best(lambda m=m: rs_ops.encode_parity(bufs, m))
        emit("rs_repair", f"encode_rs_m{m}", round(group_mb / t_rs, 1),
             "MB/s", k=k, payload_mb=group_mb, tolerates=m)

    # -- rebuild: one lost member -------------------------------------------
    xor_parity = xor_ops.parity_of_buffers(bufs)
    rs1 = rs_ops.encode_parity(bufs, 1)
    rs2 = rs_ops.encode_parity(bufs, 2)
    survivors = [b for i, b in enumerate(bufs) if i != 3]
    t = best(lambda: bytes(bufs[3]))                      # partner copy-back
    emit("rs_repair", "rebuild1_partner", round(t, 5), "s", lost=1)
    t = best(lambda: xor_ops.reconstruct_member(xor_parity, survivors,
                                                nbytes))
    emit("rs_repair", "rebuild1_xor", round(t, 5), "s", lost=1)
    present1 = {i: b for i, b in enumerate(bufs) if i != 3}
    t = best(lambda: rs_ops.decode_lost(k, 1, present1, {0: rs1[0]}, sizes))
    emit("rs_repair", "rebuild1_rs_m1", round(t, 5), "s", lost=1)

    # -- rebuild: two lost members (RS m=2 territory) ------------------------
    present2 = {i: b for i, b in enumerate(bufs) if i not in (2, 5)}
    t = best(lambda: rs_ops.decode_lost(
        k, 2, present2, {0: rs2[0], 1: rs2[1]}, sizes))
    emit("rs_repair", "rebuild2_rs_m2", round(t, 5), "s", lost=2)
    out = rs_ops.decode_lost(k, 2, present2, {0: rs2[0], 1: rs2[1]}, sizes)
    ok = all(out[i] == bufs[i].tobytes() for i in (2, 5))
    emit("rs_repair", "rebuild2_bit_identical", int(ok), "bool", lost=2)


def main(full: bool = False) -> None:
    sizes = [8, 16, 32, 64, 128] + ([256, 512] if full else [])
    fig5(sizes)
    fig6(16, [1, 2, 4, 8])
    table3(sizes[-1])
    mem_restore(n_layers=256 if full else 128)
    rs_repair(full)


def _spawn_merge_scenario(full: bool) -> None:
    """Fig. 7 spawn+merge + replacement hydration, runnable from this
    module's CLI too (lazy import: spawn_merge imports this module)."""
    from benchmarks import spawn_merge

    spawn_merge._SCENARIOS["fig7"](full)
    spawn_merge._SCENARIOS["hydration"](full)


_SCENARIOS = {
    "fig5": lambda full: fig5([8, 16, 32] + ([64, 128] if full else [])),
    "spawn_merge": _spawn_merge_scenario,
    "fig6": lambda full: fig6(16, [1, 2, 4, 8]),
    "table3": lambda full: table3(128 if full else 32),
    "mem_restore": lambda full: mem_restore(
        n_layers=256 if full else 128),
    "rs_repair": rs_repair,
    "all": main,
}


if __name__ == "__main__":
    from benchmarks.common import run_scenarios

    run_scenarios(_SCENARIOS, main)
