"""Memory tier (MemStore): replicated RAM shards, failure injection, budget.

The scenarios mirror the node-tier tests one level up the latency hierarchy:
roundtrip through RAM, restore after a rank's RAM is lost (replica path,
digest-verified), replica insufficiency falling back to the disk tiers, the
collective budget refusal, and the AFT shrink-recovery path that restores
from peer memory with the disk tiers entirely absent.
"""
import shutil

import numpy as np
import pytest

from repro.core import Box, Checkpoint, CheckpointError, MemFabric, aft_zone
from repro.core.comm_sim import SimWorld
from repro.core.env import CraftEnv
from repro.core.mem_level import MemStore, MemTierError


class FakeComm:
    """Single-process stand-in: rank r of n, one rank per node."""

    def __init__(self, rank, size):
        self._rank, self._size = rank, size

    @property
    def rank(self):
        return self._rank

    @property
    def size(self):
        return self._size

    def node_id(self):
        return self._rank

    def procs_per_node(self):
        return 1

    def barrier(self, channel="main"):
        pass

    def allreduce(self, v, op="sum", channel="main"):
        return v

    def allreduce_min(self, v):
        return v

    def bcast(self, v, root=0, channel="main"):
        return v


def _env(tmp_path, **extra):
    base = {
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_NODE_CP_PATH": str(tmp_path / "node"),
        "CRAFT_NODE_REDUNDANCY": "LOCAL",
        "CRAFT_TIER_CHAIN": "mem,node,pfs",
        "CRAFT_MEM_SCRATCH": str(tmp_path / "shm"),
        "CRAFT_MEM_REPLICAS": "1",
    }
    base.update(extra)
    return CraftEnv.capture(base)


def _write_all_ranks(tmp_path, n, value_of, **extra):
    env = _env(tmp_path, **extra)
    for rank in range(n):
        cp = Checkpoint("mt", FakeComm(rank, n), env=env)
        cp.add("arr", np.full((32,), value_of(rank)))
        cp.add("it", Box(7))
        cp.commit()
        cp.update_and_write()
        cp.close()
    return env


def _read_rank(tmp_path, rank, n, env):
    arr = np.zeros((32,))
    it = Box(0)
    cp = Checkpoint("mt", FakeComm(rank, n), env=env)
    cp.add("arr", arr)
    cp.add("it", it)
    cp.commit()
    assert cp.restart_if_needed()
    cp.close()
    return arr, it.value, cp.stats["restore_tier"]


class TestRoundtrip:
    def test_restores_from_ram_with_disk_gone(self, tmp_path):
        env = _write_all_ranks(tmp_path, 4, lambda r: float(r + 1))
        # wipe BOTH disk tiers: the only remaining copy is in process RAM
        shutil.rmtree(tmp_path / "pfs")
        shutil.rmtree(tmp_path / "node")
        for rank in range(4):
            arr, it, tier = _read_rank(tmp_path, rank, 4, env)
            assert tier == "mem"
            assert np.all(arr == rank + 1)
            assert it == 7

    def test_keep_versions_retires_old_ram_versions(self, tmp_path):
        env = _env(tmp_path, CRAFT_KEEP_VERSIONS="2")
        b = Box(0)
        cp = Checkpoint("mt", FakeComm(0, 1), env=env)
        cp.add("x", b)
        cp.commit()
        for i in range(1, 5):
            b.value = i
            cp.update_and_write()
        cp.close()
        fabric = MemFabric.instance()
        assert sorted(fabric.versions("mt")) == [3, 4]

    def test_restored_pytree_leaf_is_writable(self, tmp_path):
        """Array-cache hits are read-only views; leaves handed back to the
        application must be owned, writable copies."""
        env = _env(tmp_path)
        state = Box(np.arange(8.0))
        cp = Checkpoint("mt", FakeComm(0, 1), env=env)
        cp.add("state", state)
        cp.commit()
        cp.update_and_write()
        cp.close()
        fresh = Box(np.zeros(8))
        cp2 = Checkpoint("mt", FakeComm(0, 1), env=env)
        cp2.add("state", fresh)
        cp2.commit()
        assert cp2.restart_if_needed()
        assert cp2.stats["restore_tier"] == "mem"
        fresh.value[0] = 99.0            # must not raise / corrupt the fabric
        cp2.close()
        again = Box(np.zeros(8))
        cp3 = Checkpoint("mt", FakeComm(0, 1), env=env)
        cp3.add("state", again)
        cp3.commit()
        assert cp3.restart_if_needed()
        assert again.value[0] == 0.0     # fabric copy untouched by the write
        cp3.close()


class TestReplicaRecovery:
    def test_dead_ranks_ram_served_by_replica(self, tmp_path):
        env = _write_all_ranks(tmp_path, 4, lambda r: float(10 * (r + 1)))
        shutil.rmtree(tmp_path / "pfs")
        shutil.rmtree(tmp_path / "node")
        # rank 2 fail-stops: its shards and held replicas vanish
        MemFabric.instance().drop_rank(2)
        # every survivor (and rank 2's blank replacement) still restores the
        # full state — rank 2's shards come from rank 3's replica slot
        for rank in range(4):
            arr, it, tier = _read_rank(tmp_path, rank, 4, env)
            assert tier == "mem"
            assert np.all(arr == 10 * (rank + 1))

    def test_replica_digest_mismatch_rejected(self, tmp_path):
        env = _write_all_ranks(tmp_path, 2, lambda r: float(r))
        shutil.rmtree(tmp_path / "pfs")
        shutil.rmtree(tmp_path / "node")
        fabric = MemFabric.instance()
        fabric.drop_rank(0)
        # corrupt rank 0's replica (held in rank 1's slot) behind the digest
        mv = fabric.lookup("mt", 0, 1)[0]
        entry = next(e for e in mv.files.values() if e.array is not None)
        tampered = entry.array.copy()
        tampered[0] += 1.0
        entry.array = tampered
        cp = Checkpoint("mt", FakeComm(0, 2), env=env)
        cp.add("arr", np.zeros((32,)))
        cp.add("it", Box(0))
        cp.commit()
        with pytest.raises(CheckpointError, match="digest mismatch"):
            cp.restart_if_needed()
        cp.close()

    def test_insufficient_replicas_fall_back_to_disk(self, tmp_path):
        # R=1: losing two adjacent ranks makes rank 1's shards unreachable
        env = _write_all_ranks(tmp_path, 4, lambda r: float(r + 5))
        fabric = MemFabric.instance()
        fabric.drop_rank(1)
        fabric.drop_rank(2)   # held rank 1's only replica
        arr, it, tier = _read_rank(tmp_path, 0, 4, env)
        assert tier == "node"          # next tier in the chain
        assert np.all(arr == 5.0)
        assert it == 7


class TestBudget:
    def test_budget_exceeded_falls_back_to_node_tier(self, tmp_path):
        env = _write_all_ranks(
            tmp_path, 2, lambda r: float(r), CRAFT_MEM_BUDGET_BYTES="64"
        )
        assert MemFabric.instance().versions("mt") == {}
        arr, it, tier = _read_rank(tmp_path, 0, 2, env)
        assert tier == "node"
        assert np.all(arr == 0.0)

    def test_budget_skip_counts_and_disk_still_written(self, tmp_path):
        env = _env(tmp_path, CRAFT_MEM_BUDGET_BYTES="64")
        cp = Checkpoint("mt", FakeComm(0, 1), env=env)
        cp.add("arr", np.zeros((64,)))
        cp.commit()
        cp.update_and_write()
        cp.close()
        assert cp.stats["mem_skipped"] == 1
        assert cp.stats["mem_writes"] == 0
        assert cp.stats["node_writes"] == 1
        assert cp.stats["pfs_writes"] == 1

    def test_budget_admits_within_cap(self, tmp_path):
        env = _env(tmp_path, CRAFT_MEM_BUDGET_BYTES=str(1 << 20))
        cp = Checkpoint("mt", FakeComm(0, 1), env=env)
        cp.add("arr", np.zeros((64,)))
        cp.commit()
        cp.update_and_write()
        cp.close()
        assert cp.stats["mem_writes"] == 1
        assert cp.stats["mem_skipped"] == 0


class TestEnvKnobs:
    def test_tier_chain_validation(self):
        with pytest.raises(ValueError):
            CraftEnv.capture({"CRAFT_TIER_CHAIN": "mem,disk"})
        with pytest.raises(ValueError):
            CraftEnv.capture({"CRAFT_TIER_CHAIN": "mem,mem"})
        with pytest.raises(ValueError):
            CraftEnv.capture({"CRAFT_TIER_CHAIN": ""})
        assert CraftEnv.capture({}).tier_chain == ("node", "pfs")
        assert CraftEnv.capture(
            {"CRAFT_TIER_CHAIN": "mem,node,pfs"}
        ).tier_chain == ("mem", "node", "pfs")

    def test_mem_knob_validation(self):
        with pytest.raises(ValueError):
            CraftEnv.capture({"CRAFT_MEM_REPLICAS": "-1"})
        with pytest.raises(ValueError):
            CraftEnv.capture({"CRAFT_MEM_BUDGET_BYTES": "-5"})
        env = CraftEnv.capture({})
        assert env.mem_replicas == 1
        assert env.mem_budget_bytes == 0

    def test_replicas_clamped_to_world(self, tmp_path):
        env = _env(tmp_path, CRAFT_MEM_REPLICAS="9")
        store = MemStore("clamp", FakeComm(0, 3), env)
        assert store.replicas == 2
        assert store._holders(0) == [0, 1, 2]


class TestAftShrinkRecovery:
    """Satellite: kill a rank in comm_sim; survivors restore the full state
    from peer replicas without reading any on-disk version (no disk tiers
    are configured at all), then finish the computation."""

    def test_survivors_restore_from_peer_memory_zero_disk(self, tmp_path):
        env = CraftEnv.capture({
            "CRAFT_TIER_CHAIN": "mem",           # no disk tier exists
            "CRAFT_MEM_REPLICAS": "1",
            "CRAFT_MEM_SCRATCH": str(tmp_path / "shm"),
            "CRAFT_COMM_RECOVERY_POLICY": "SHRINKING",
            "CRAFT_IO_WORKERS": "1",
        })
        world = SimWorld(4, env=env)

        def fn(c):
            def body(comm):
                it = Box(0)
                state = Box(np.zeros(8))
                cp = Checkpoint("aftmem", comm, env=env)
                cp.add("it", it)
                cp.add("state", state)
                cp.commit()
                restored = cp.restart_if_needed()
                while it.value < 6:
                    it.value += 1
                    state.value = state.value + 1.0
                    cp.update_and_write()
                    if it.value == 3 and comm.epoch == 0 and comm.rank == 0:
                        world.kill(3)
                cp.close()
                return (restored, cp.stats["restore_tier"], it.value,
                        float(np.sum(state.value)), comm.size)

            return aft_zone(c, body, env=env)

        out = world.run(fn, timeout=120)
        assert len(out) == 3                      # the killed rank is gone
        for restored, tier, it, total, size in out.values():
            assert restored and tier == "mem"
            assert (it, total, size) == (6, 48.0, 3)
        # nothing was ever staged to a disk tier
        assert not (tmp_path / "pfs").exists()
        assert not (tmp_path / "node").exists()

    def test_killed_ranks_fabric_slot_dropped(self, tmp_path):
        env = CraftEnv.capture({
            "CRAFT_TIER_CHAIN": "mem",
            "CRAFT_MEM_REPLICAS": "0",   # no replicas: kill leaves nothing
            "CRAFT_MEM_SCRATCH": str(tmp_path / "shm"),
            "CRAFT_IO_WORKERS": "1",
        })
        world = SimWorld(2, env=env)
        fabric = MemFabric.instance()

        def fn(c):
            cp = Checkpoint("hook", c, env=env)
            cp.add("x", Box(c.rank))
            cp.commit()
            cp.update_and_write()
            cp.close()
            c.barrier()
            if c.rank == 0:
                world.kill(1)
                return fabric.lookup("hook", 1, 1)[0] is None
            try:
                while True:
                    c.barrier()
            except Exception:
                return "peer failure seen"

        out = world.run(fn, timeout=60)
        assert out.get("u0") is True
