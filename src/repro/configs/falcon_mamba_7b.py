"""falcon-mamba-7b — pure Mamba1 (attention-free) LM.

[arXiv:2410.05355; unverified]  64L d_model=4096 vocab=65024
ssm_state=16; mamba1 arch: expand 2 → d_inner 8192, conv 4,
dt_rank = ceil(4096/16) = 256.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, vocab=65024,
    attn_type="none", d_ff=0,
    ssm_type="mamba1", ssm_state=16, ssm_expand=2, ssm_conv=4,
    tie_embeddings=False,
)

TINY = CONFIG.replace(
    n_layers=3, d_model=64, vocab=256, ssm_state=8, ssm_chunk=16,
    dt_rank=8,
)
