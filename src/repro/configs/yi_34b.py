"""yi-34b — llama-arch dense decoder with GQA.

[arXiv:2403.04652; hf]  60L d_model=7168 56H (kv=8) d_ff=20480
vocab=64000, rope theta 5e6.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="yi-34b", family="dense",
    n_layers=60, d_model=7168, vocab=64000,
    attn_type="gqa", n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, rope_theta=5e6,
    tie_embeddings=False,
)

TINY = CONFIG.replace(
    n_layers=2, d_model=64, vocab=512, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128,
)
