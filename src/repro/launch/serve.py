"""Serving driver: batched prefill + decode with a restartable decode loop.

The CRAFT angle on serving: a long decode (the assigned ``long_500k`` shape
decodes against a 524k-token context) is exactly the kind of hours-long,
loses-everything-on-failure loop the paper targets.  The KV/SSM cache, the
position counter and the generated tokens are all CRAFT-checkpointable, so
``serve`` periodically checkpoints the decode state and a restarted run
resumes mid-generation instead of re-prefilling.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --batch 4 --prompt-len 32 --gen 64 --cp-freq 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Box, Checkpoint
from repro.models import model as M
from repro.train.steps import make_decode_step, make_prefill


@dataclasses.dataclass
class ServeConfig:
    arch: str = "h2o-danube-1.8b"
    tiny: bool = True
    batch: int = 4
    prompt_len: int = 32
    gen_tokens: int = 64
    cp_freq: int = 0            # 0 = no decode checkpointing
    cp_name: str = "serve"
    seed: int = 0
    temperature: float = 0.0    # 0 = greedy


def run(sc: ServeConfig, comm=None, env=None, params=None,
        fail_at_token: Optional[int] = None) -> Dict:
    """Prefill a synthetic prompt batch, decode ``gen_tokens`` greedily.

    Returns {"tokens": (B, gen) np.ndarray, "prefill_s", "decode_s",
    "resumed_at": int}.  ``fail_at_token`` raises after that many generated
    tokens (restartability tests re-call ``run`` and assert resumption).
    """
    cfg = get_config(sc.arch, tiny=sc.tiny)
    if params is None:
        params = M.init_params(jax.random.PRNGKey(sc.seed), cfg)
    max_len = sc.prompt_len + sc.gen_tokens + (
        cfg.n_patches if cfg.frontend else 0)
    rng = np.random.default_rng(sc.seed)
    prompts = rng.integers(0, cfg.vocab, (sc.batch, sc.prompt_len),
                           dtype=np.int32)
    embeds = None
    if cfg.frontend:
        stub = np.random.default_rng(sc.seed + 1).standard_normal(
            (sc.batch, cfg.n_patches, cfg.d_model)).astype(np.float32)
        embeds = jnp.asarray(stub, cfg.dtype)

    prefill = jax.jit(make_prefill(cfg, sc.batch, max_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    t0 = time.perf_counter()
    if embeds is not None:
        cache, logits = prefill(params, jnp.asarray(prompts), embeds)
        pos0 = sc.prompt_len + cfg.n_patches
    else:
        cache, logits = prefill(params, jnp.asarray(prompts))
        pos0 = sc.prompt_len
    jax.block_until_ready(logits)
    prefill_s = time.perf_counter() - t0

    cache_box = Box(cache)
    tok_box = Box(np.zeros((sc.batch, sc.gen_tokens), np.int32))
    i_box = Box(0)

    cp = None
    resumed_at = 0
    if sc.cp_freq:
        cp = Checkpoint(sc.cp_name, comm, env=env)
        cp.add("cache", cache_box)
        cp.add("generated", tok_box)
        cp.add("i", i_box)
        cp.commit()
        if cp.restart_if_needed():
            resumed_at = i_box.value

    def sample(lg, i) -> jnp.ndarray:
        if sc.temperature <= 0.0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            jax.random.fold_in(jax.random.PRNGKey(sc.seed), i),
            lg / sc.temperature).astype(jnp.int32)

    if resumed_at > 0:
        next_tok = jnp.asarray(tok_box.value[:, resumed_at - 1])
    else:
        next_tok = sample(logits, 0)

    t0 = time.perf_counter()
    i = i_box.value
    while i < sc.gen_tokens:
        cache_box.value, logits = decode(
            params, cache_box.value, next_tok[:, None], jnp.int32(pos0 + i))
        next_tok = sample(logits, i + 1)
        tok_box.value[:, i] = np.asarray(next_tok)
        i += 1
        i_box.value = i
        if cp is not None:
            cp.update_and_write(i, sc.cp_freq)
        if fail_at_token is not None and i == fail_at_token:
            if cp is not None:
                cp.wait()
                cp.close()
            raise RuntimeError(f"injected failure at token {i}")
    decode_s = time.perf_counter() - t0
    if cp is not None:
        cp.wait()
        cp.close()
    return {"tokens": tok_box.value, "prefill_s": prefill_s,
            "decode_s": decode_s, "resumed_at": resumed_at}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--cp-freq", type=int, default=0)
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics + /healthz on this port (k8s "
                         "liveness probe; same as CRAFT_METRICS_PORT)")
    args = ap.parse_args()
    if args.metrics_port is not None:
        # Start the exporter up front so the replica answers its liveness
        # probe during prefill, before any Checkpoint commits.
        from repro.core import metrics, telemetry

        metrics.install()
        port = telemetry.start(args.metrics_port)
        print(f"telemetry: /metrics + /healthz on port {port}")
    sc = ServeConfig(arch=args.arch, tiny=args.tiny, batch=args.batch,
                     prompt_len=args.prompt_len, gen_tokens=args.gen,
                     cp_freq=args.cp_freq)
    out = run(sc)
    print(f"prefill {out['prefill_s']:.2f}s, decode {out['decode_s']:.2f}s "
          f"({sc.gen_tokens} tokens), resumed_at={out['resumed_at']}")
    print("first sequence:", out["tokens"][0][:16], "...")


if __name__ == "__main__":
    main()
