"""Chaos soak: a seeded fault schedule hammering the tier chain for a fixed
wall-clock budget, ending in a verified bit-identical restore.

The soak drives a delta-coded, async checkpoint loop on a node+pfs chain
while a deterministic schedule of fault windows (transient EIO bursts, a
persistent PFS outage with breaker re-admission, torn writes, ENOSPC, and
latency stalls) opens and closes around it.  At the end every fault is
cleared, one final full write fences, and a *fresh* Checkpoint (separate
store objects, no shared state) restores and compares bit-for-bit.

Scenarios
---------
soak      seeded fault soak (default 60 s; CRAFT_SOAK_SECONDS overrides,
          ``--full`` doubles it) ending with a verified restore
overhead  fault-free write-path overhead of the chaos/retry/breaker
          machinery: hooks armed-but-idle vs compiled out entirely
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit, run_scenarios
from repro.core import Checkpoint
from repro.core.env import CraftEnv

_MB = 1 << 20

# (start_frac, end_frac, spec) — fractions of the soak budget.  The windows
# deliberately overlap tier outages with transient noise on the other tier.
_SCHEDULE = [
    (0.05, 0.20, "pfs:eio:p=0.3"),                 # transient PFS noise
    (0.25, 0.50, "pfs:erofs:p=1"),                 # hard PFS outage
    (0.30, 0.45, "node:eio:p=0.15"),               # noise on the fallback
    (0.55, 0.65, "node:stall:ms=25+p=0.5"),        # slow node tier
    (0.70, 0.80, "pfs:torn:p=0.4"),                # torn PFS writes
    (0.85, 0.90, "pfs:enospc:count=2"),            # space pressure
]


def _mk_env(base: Path, seed: int) -> CraftEnv:
    return CraftEnv.capture({
        "CRAFT_CP_PATH": str(base / "pfs"),
        "CRAFT_NODE_CP_PATH": str(base / "node"),
        "CRAFT_CHAOS": "on",
        "CRAFT_CHAOS_SEED": str(seed),
        "CRAFT_DELTA": "1",
        "CRAFT_WRITE_ASYNC": "1",
        "CRAFT_IO_RETRIES": "2",
        "CRAFT_IO_BACKOFF_MS": "5",
        "CRAFT_IO_DEADLINE_S": "20",
        "CRAFT_BREAKER_THRESHOLD": "2",
        "CRAFT_BREAKER_COOLDOWN_S": "1",
        "CRAFT_KEEP_VERSIONS": "3",
    })


def soak(full: bool) -> None:
    seconds = float(os.environ.get("CRAFT_SOAK_SECONDS",
                                   "120" if full else "60"))
    seed = int(os.environ.get("CRAFT_CHAOS_SEED", "1234"))
    rng = np.random.default_rng(seed)
    base = Path(tempfile.mkdtemp(prefix="craft-chaos-soak-"))
    arr = rng.standard_normal((4 * _MB // 8,))     # 4 MiB of float64

    cp = Checkpoint("soak", env=_mk_env(base, seed))
    cp.add("state", arr)
    cp.commit()
    engine = cp.chaos

    t0 = time.perf_counter()
    active = [False] * len(_SCHEDULE)
    writes = failures = 0
    while (now := time.perf_counter() - t0) < seconds:
        frac = now / seconds
        for i, (lo, hi, spec) in enumerate(_SCHEDULE):
            if not active[i] and lo <= frac < hi:
                engine.add(spec)
                active[i] = True
            elif active[i] and frac >= hi:
                fault = spec.split(":")[1]
                engine.clear(spec.split(":")[0], fault)
                active[i] = False
        # one "training step": mutate a slice, then checkpoint
        at = rng.integers(0, arr.size - 1024)
        arr[at:at + 1024] = rng.standard_normal(1024)
        try:
            cp.update_and_write()
            writes += 1
        except Exception:
            failures += 1          # all-tiers-down window: survive, go on
        time.sleep(0.01)

    engine.clear()                 # calm seas for the final fence
    arr[:1024] = np.arange(1024, dtype=arr.dtype)
    cp.update_and_write()
    cp.wait()
    final = arr.copy()
    version = cp.version
    st = dict(cp.stats)
    cp.close()

    # fresh process analog: new Checkpoint, new stores, restore + compare
    out = np.zeros_like(final)
    cp2 = Checkpoint("soak", env=_mk_env(base, seed))
    cp2.add("state", out)
    cp2.commit()
    restored = cp2.restart_if_needed()
    identical = bool(restored and np.array_equal(out, final))
    cp2.close()
    shutil.rmtree(base, ignore_errors=True)

    emit("chaos_soak", "soak_seconds", round(seconds, 1), "s", seed=seed)
    emit("chaos_soak", "writes_ok", writes, "count")
    emit("chaos_soak", "writes_failed", failures, "count")
    emit("chaos_soak", "final_version", version, "version")
    emit("chaos_soak", "injections", sum(
        v for k, v in engine.stats.items() if k != "ops"), "count")
    for key in ("retries", "breaker_trips", "degraded_writes",
                "abandoned_writes", "enospc_retires"):
        emit("chaos_soak", key, st.get(key, 0), "count")
    emit("chaos_soak", "restore_bit_identical", int(identical), "bool")
    if not identical:
        raise SystemExit("chaos soak FAILED: restore not bit-identical")


def overhead(full: bool) -> None:
    """Fault-free cost of the resilience machinery on the write path."""
    n_iter = 40 if full else 15
    arr = np.random.default_rng(0).standard_normal((8 * _MB // 8,))

    def loop(extra: dict) -> float:
        base = Path(tempfile.mkdtemp(prefix="craft-chaos-ovh-"))
        env = CraftEnv.capture({
            "CRAFT_CP_PATH": str(base / "pfs"),
            "CRAFT_USE_SCR": "0",
            **extra,
        })
        cp = Checkpoint("ovh", env=env)
        cp.add("state", arr)
        cp.commit()
        cp.update_and_write()              # warm the path
        t0 = time.perf_counter()
        for _ in range(n_iter):
            arr[:64] += 1.0
            cp.update_and_write()
        dt = time.perf_counter() - t0
        cp.close()
        shutil.rmtree(base, ignore_errors=True)
        return dt / n_iter

    bare = loop({})
    armed = loop({"CRAFT_CHAOS": "on", "CRAFT_IO_RETRIES": "2",
                  "CRAFT_IO_DEADLINE_S": "60"})
    pct = 100.0 * (armed - bare) / bare if bare else 0.0
    emit("chaos_soak", "write_s_bare", round(bare, 5), "s/write")
    emit("chaos_soak", "write_s_armed", round(armed, 5), "s/write")
    emit("chaos_soak", "armed_overhead", round(pct, 2), "%")


if __name__ == "__main__":
    run_scenarios({"soak": soak, "overhead": overhead},
                  default=soak)
