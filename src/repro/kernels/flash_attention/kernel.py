"""Pallas TPU kernel: blocked flash attention (forward), online softmax.

TPU mapping (adapted from the CUDA flash-attention blocking to MXU/VMEM):

  * grid = (B, Hq, Lq/bq, Lk/bk) — the last axis iterates sequentially on
    TPU, so the running max / denominator / output tiles live in VMEM
    scratch and carry across the k-block sweep of one q block.
  * q tile (bq, Dqk) and k/v tiles (bk, Dqk)/(bk, Dv) are VMEM-resident;
    bq = bk = 128 aligns both MXU matmuls ((bq,D)x(D,bk) and (bq,bk)x(bk,Dv))
    to 128-multiples.
  * GQA folds into the BlockSpec index maps: query head h reads kv head
    ``h // group`` — no repeated K/V materialization in HBM.
  * causal and sliding-window masking are positional; fully-masked k blocks
    are skipped with ``pl.when`` (their DMA still streams, the FLOPs don't).
  * fp32 accumulation regardless of input dtype (bf16 in, fp32 softmax).

Memory: scratch = acc (bq, Dv) + m,l (bq, 128) fp32 ≈ 128·(128+256)·4 ≈
0.2 MiB; tiles ≈ 3·128·D·2 ≈ 0.2 MiB at D=256 — comfortably inside VMEM
with room for double-buffered pipelining.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30
_LANES = 128


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, bq: int, bk: int, n_k: int, causal: bool, window: Optional[int],
    sm_scale: float, q_offset: int, kv_len: int,
):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    iq = pl.program_id(2)
    q_start = q_offset + iq * bq
    k_start = ik * bk
    # block-level skip tests (static shapes, dynamic start indices)
    live = k_start < kv_len
    if causal:
        live &= k_start <= q_start + (bq - 1)
    if window is not None:
        live &= (k_start + bk - 1) > (q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, Dqk)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, Dqk)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * sm_scale                                    # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...][:, :1]                      # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                          # (bq, bk)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_new = alpha * l_scr[...][:, :1] + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                               # (bq, Dv)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_scr[...][:, :1]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "sm_scale", "q_offset", "kv_len",
        "bq", "bk", "interpret",
    ),
)
def flash_attention(
    q: jnp.ndarray,                    # (B, Hq, Lq, Dqk)
    k: jnp.ndarray,                    # (B, Hkv, Lk, Dqk)
    v: jnp.ndarray,                    # (B, Hkv, Lk, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    q_offset: int = 0,
    kv_len: Optional[int] = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, lq, dqk = q.shape
    _, hkv, lk, dv = v.shape
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    group = hq // hkv
    if lq % bq or lk % bk:
        raise ValueError(
            f"Lq={lq}, Lk={lk} must be multiples of bq={bq}, bk={bk} "
            "(ops.py pads)"
        )
    if sm_scale is None:
        sm_scale = dqk ** -0.5
    if kv_len is None:
        kv_len = lk
    n_k = lk // bk
    grid = (b, hq, lq // bq, n_k)
    kernel = functools.partial(
        _flash_kernel,
        bq=bq, bk=bk, n_k=n_k, causal=causal, window=window,
        sm_scale=float(sm_scale), q_offset=int(q_offset), kv_len=int(kv_len),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dqk), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec(
                (1, 1, bk, dqk),
                lambda b_, h, i, j, g=group: (b_, h // g, j, 0),
            ),
            pl.BlockSpec(
                (1, 1, bk, dv),
                lambda b_, h, i, j, g=group: (b_, h // g, j, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, lq, dv), q.dtype),
        scratch_shapes=[
            _vmem((bq, _LANES), jnp.float32),   # running row-max m
            _vmem((bq, _LANES), jnp.float32),   # running denominator l
            _vmem((bq, dv), jnp.float32),       # fp32 output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
