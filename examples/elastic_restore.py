import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))
# 8 placeholder devices so this single-process example can demonstrate
# cross-mesh restore (must precede any jax import).

"""Elastic restore — shrinking recovery with automatic resharding.

Beyond-paper extension (DESIGN.md §2): the paper's shrinking recovery
leaves 'redistributing the domain' to the user; CRAFT-JAX's checkpoint
manifest is topology-independent, so the same training state written on a
4×2 mesh restores onto the 2×2 mesh that remains after two hosts fail —
every leaf is resharded automatically onto the live sharding.

    PYTHONPATH=src python examples/elastic_restore.py
"""
import numpy as np

import jax
from jax.sharding import NamedSharding

from repro.core import Box, Checkpoint
from repro.core.elastic import dp_degree, shrink_mesh
from repro.core.env import CraftEnv
from repro.configs import get_config
from repro.models import model as M
from repro.sharding.logical import LogicalRules, shard_specs


def params_on_mesh(cfg, mesh):
    rules = LogicalRules(mesh)
    shapes = jax.eval_shape(lambda k: M.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    specs = shard_specs(rules, M.param_logical(cfg), shapes)
    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    with jax.set_mesh(mesh):
        return jax.jit(lambda k: M.init_params(k, cfg),
                       out_shardings=shardings)(jax.random.PRNGKey(0))


def main() -> None:
    env = CraftEnv.capture({"CRAFT_CP_PATH": "craft-elastic",
                            "CRAFT_USE_SCR": "0"})
    cfg = get_config("h2o-danube-1.8b", tiny=True)

    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    params_a = params_on_mesh(cfg, mesh_a)
    print(f"wrote state on mesh {dict(zip(mesh_a.axis_names, mesh_a.devices.shape))} "
          f"(DP degree {dp_degree(mesh_a)})")
    box = Box(params_a)
    cp = Checkpoint("elastic", env=env)
    cp.add("params", box)
    cp.commit()
    cp.update_and_write()

    # --- two hosts fail; shrinking recovery keeps the 2-way TP groups ----
    mesh_b = shrink_mesh(4, model_parallel=2)
    print(f"shrunk to mesh {dict(zip(mesh_b.axis_names, mesh_b.devices.shape))} "
          f"(DP degree {dp_degree(mesh_b)})")
    params_b = params_on_mesh(cfg, mesh_b)   # fresh state on the new mesh
    box2 = Box(params_b)
    cp2 = Checkpoint("elastic", env=env)
    cp2.add("params", box2)
    cp2.commit()
    assert cp2.restart_if_needed()

    # verify: same global values, new placement
    flat_a = jax.tree_util.tree_leaves(params_a)
    flat_b = jax.tree_util.tree_leaves(box2.value)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))
    n_dev = {d for leaf in flat_b for d in leaf.sharding.device_set}
    print(f"restored {len(flat_b)} leaves onto {len(n_dev)} devices — "
          "elastic restore OK")


if __name__ == "__main__":
    main()
