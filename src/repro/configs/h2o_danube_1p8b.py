"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; hf]  24L d_model=2560 32H (kv=8) d_ff=6912
vocab=32000, SWA window 4096 → the KV cache is bounded by the window,
which is what makes the ``long_500k`` decode shape runnable.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, vocab=32000,
    attn_type="gqa", n_heads=32, n_kv_heads=8, head_dim=80,
    d_ff=6912, window=4096,
    tie_embeddings=False,
)

TINY = CONFIG.replace(
    n_layers=2, d_model=64, vocab=512, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, window=32,
)
