"""FTComm — fault-tolerant communicator with ULFM semantics (paper §3.1).

ULFM-MPI gives CRAFT three primitives on top of plain MPI:

  * ``MPIX_Comm_revoke``  — any single member can invalidate the communicator
    (asymmetric call; everyone else learns at their next operation),
  * ``MPIX_Comm_shrink``  — collective consensus producing a healthy
    communicator without the failed members,
  * ``MPIX_Comm_agree``   — fault-tolerant agreement among survivors,

plus the error codes ``MPIX_ERR_PROC_FAILED`` / ``MPIX_ERR_REVOKED``.

TPU/JAX adaptation (DESIGN.md §2): there is no fault-tolerant runtime inside
a jitted program — a failed host kills that process.  Failure *detection*
therefore lives at the runtime layer (connection EOF / heartbeat timeout /
collective deadlines — straggler mitigation), and the ULFM *semantics*
(revoke → shrink → agree ordering, shrinking vs non-shrinking recovery,
spawn with REUSE / NO-REUSE node policies) are preserved exactly in two
backends:

  * :mod:`repro.core.comm_sim` — deterministic in-process simulator
    (threads), used by unit tests and large-scale recovery benchmarks,
  * :mod:`repro.runtime` — a real multi-process cluster where ``kill -9`` of
    a worker is the paper's fail-stop fault model.
"""
from __future__ import annotations

import abc
from typing import Any, Callable, List, Optional


class CommError(RuntimeError):
    """Base class of communicator errors."""


class ProcFailedError(CommError):
    """A peer process failed (ULFM: MPIX_ERR_PROC_FAILED)."""

    def __init__(self, msg: str = "", failed: Optional[List[int]] = None):
        super().__init__(msg or f"process failure detected (failed={failed})")
        self.failed = list(failed or [])


class RevokedError(CommError):
    """The communicator was revoked (ULFM: MPIX_ERR_REVOKED)."""


class KilledError(BaseException):
    """Raised inside a simulated rank that was killed (not catchable as
    Exception so user code cannot accidentally swallow its own death)."""


class FTComm(abc.ABC):
    """Protocol shared by the simulator and the multiprocessing backend."""

    # --- identity -----------------------------------------------------------
    @property
    @abc.abstractmethod
    def rank(self) -> int: ...

    @property
    @abc.abstractmethod
    def size(self) -> int: ...

    @abc.abstractmethod
    def node_id(self) -> int: ...

    @abc.abstractmethod
    def procs_per_node(self) -> int: ...

    @property
    def epoch(self) -> int:
        return 0

    # --- collectives ----------------------------------------------------------
    @abc.abstractmethod
    def barrier(self, channel: str = "main") -> None: ...

    @abc.abstractmethod
    def allreduce(self, value, op: str = "sum", channel: str = "main"): ...

    def allreduce_min(self, value):
        return self.allreduce(value, op="min")

    def allreduce_sum(self, value):
        return self.allreduce(value, op="sum")

    def allreduce_max(self, value):
        return self.allreduce(value, op="max")

    @abc.abstractmethod
    def bcast(self, value, root: int = 0, channel: str = "main"): ...

    # --- ULFM extensions --------------------------------------------------------
    @abc.abstractmethod
    def revoke(self) -> None:
        """Invalidate the current epoch (asymmetric, any member may call)."""

    @abc.abstractmethod
    def agree(self, flag: bool = True) -> bool:
        """Fault-tolerant agreement among live members (logical AND)."""

    @abc.abstractmethod
    def recover(self, policy: Optional[str] = None) -> "FTComm":
        """Repair the communicator after failure; returns the healthy comm.

        ``policy``: SHRINKING or NON-SHRINKING (default: the environment's
        CRAFT_COMM_RECOVERY_POLICY).  Collective over the surviving members;
        newly spawned replacements join during the call (non-shrinking).
        """

    # --- introspection -----------------------------------------------------------
    def failed_ranks(self) -> List[int]:
        return []

    def last_recovery_stats(self) -> dict:
        """Per-phase timing of the most recent recovery (paper Table 3)."""
        return {}

    @property
    def default_recovery_policy(self) -> Optional[str]:
        """Backend-configured recovery policy, if any (overrides env)."""
        return None

    def is_replacement(self) -> bool:
        """True if this process was spawned to replace a failed rank."""
        return False

    def fault_domain(self) -> Optional[Any]:
        """Backend object observing rank deaths, if any.

        A fault domain exposes ``add_kill_hook(fn)``; ``fn(rank)`` fires when
        a rank is fail-stopped, *before* peers detect the failure.  The
        memory tier uses it to model RAM loss (a dead process's shards and
        the replicas it held vanish).  Backends without in-process fault
        injection (real clusters — the OS reclaims the RAM for us) return
        None.
        """
        return None


class ChannelComm:
    """Proxy routing every collective onto a fixed named channel.

    Collectives are matched per (epoch, channel, sequence); giving each
    ``Checkpoint`` its own channel lets the asynchronous writer thread
    barrier concurrently with the application's own collectives on "main"
    without sequence interleaving (which would deadlock an SPMD program).
    """

    def __init__(self, comm: FTComm, channel: str):
        self._comm = comm
        self._channel = channel

    def __getattr__(self, name: str) -> Any:
        return getattr(self._comm, name)

    @property
    def rank(self) -> int:
        return self._comm.rank

    @property
    def size(self) -> int:
        return self._comm.size

    def barrier(self, channel: Optional[str] = None) -> None:
        self._comm.barrier(channel=channel or self._channel)

    def allreduce(self, value, op: str = "sum", channel: Optional[str] = None):
        return self._comm.allreduce(value, op=op, channel=channel or self._channel)

    def allreduce_min(self, value):
        return self.allreduce(value, op="min")

    def allreduce_sum(self, value):
        return self.allreduce(value, op="sum")

    def allreduce_max(self, value):
        return self.allreduce(value, op="max")

    def bcast(self, value, root: int = 0, channel: Optional[str] = None):
        return self._comm.bcast(value, root=root, channel=channel or self._channel)


class NullComm(FTComm):
    """Single-process communicator (rank 0 of 1); every op is a no-op."""

    @property
    def rank(self) -> int:
        return 0

    @property
    def size(self) -> int:
        return 1

    def node_id(self) -> int:
        return 0

    def procs_per_node(self) -> int:
        return 1

    def barrier(self, channel: str = "main") -> None:
        pass

    def allreduce(self, value, op: str = "sum", channel: str = "main"):
        return value

    def bcast(self, value, root: int = 0, channel: str = "main"):
        return value

    def revoke(self) -> None:
        pass

    def agree(self, flag: bool = True) -> bool:
        return bool(flag)

    def recover(self, policy: Optional[str] = None) -> "NullComm":
        return self
