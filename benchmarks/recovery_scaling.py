"""Paper Figs. 5/6 + Table 3: communication-recovery overhead scaling.

Fig. 5  — recovery time vs #procs for SHRINKING / NON-SHRINKING(REUSE) /
          NON-SHRINKING(NO-REUSE), 2 procs per node.
Fig. 6  — recovery time vs procs-per-node at a fixed node count.
Table 3 — per-phase breakdown of one NON-SHRINKING NO-REUSE recovery at the
          largest size.

The SimComm backend reproduces the recovery *bookkeeping* at sizes beyond
what one CPU can host as real processes (threads as ranks); the real-process
path is exercised by tests/test_runtime.py and examples/train_cluster.py.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.comm import ProcFailedError, RevokedError
from repro.core.comm_sim import SimWorld
from repro.core.env import CraftEnv


def _recover_once(n_procs: int, ppn: int, policy: str, spawn: str) -> dict:
    env = CraftEnv.capture({
        "CRAFT_COMM_RECOVERY_POLICY": policy,
        "CRAFT_COMM_SPAWN_POLICY": spawn,
    })
    world = SimWorld(n_procs, procs_per_node=ppn, spare_nodes=2, env=env)
    victim = n_procs - 1

    def fn(comm):
        recovered = {}
        while True:
            try:
                if comm.rank == 0 and comm.epoch == 0:
                    world.kill(victim)
                for _ in range(3):
                    comm.barrier()
                return recovered
            except (ProcFailedError, RevokedError):
                try:
                    comm.revoke()
                except Exception:
                    pass
                t0 = time.perf_counter()
                comm = comm.recover(policy=policy)
                recovered = dict(comm.last_recovery_stats())
                recovered["wall_s"] = time.perf_counter() - t0

    out = world.run(fn, timeout=600)
    stats = [v for v in out.values() if v]
    stats.sort(key=lambda s: -s.get("wall_s", 0.0))
    return stats[0] if stats else {}


def fig5(sizes, ppn=2) -> None:
    for n in sizes:
        for policy, spawn in (("SHRINKING", "NO-REUSE"),
                              ("NON-SHRINKING", "REUSE"),
                              ("NON-SHRINKING", "NO-REUSE")):
            s = _recover_once(n, ppn, policy, spawn)
            emit("fig5_recovery_scaling", f"{policy}/{spawn}",
                 round(s.get("wall_s", float("nan")), 5), "s", procs=n)


def fig6(n_nodes, ppns) -> None:
    for ppn in ppns:
        s = _recover_once(n_nodes * ppn, ppn, "NON-SHRINKING", "NO-REUSE")
        emit("fig6_procs_per_node", f"ppn{ppn}",
             round(s.get("wall_s", float("nan")), 5), "s",
             nodes=n_nodes, procs=n_nodes * ppn)


def table3(n_procs, ppn=2) -> None:
    s = _recover_once(n_procs, ppn, "NON-SHRINKING", "NO-REUSE")
    for phase in ("revoke_shrink_s", "spawn_info_s", "spawn_merge_s",
                  "redistribute_s", "resource_mgmt_s"):
        emit("table3_recovery_breakdown", phase,
             round(s.get(phase, float("nan")), 6), "s", procs=n_procs)


def main(full: bool = False) -> None:
    sizes = [8, 16, 32, 64, 128] + ([256, 512] if full else [])
    fig5(sizes)
    fig6(16, [1, 2, 4, 8])
    table3(sizes[-1])


if __name__ == "__main__":
    main()
