"""Paper Table 4: checkpoint overhead — none / sync PFS / async PFS / node.

Lanczos benchmark (paper §6.2 setup, scaled to this container): fixed
iteration count, fixed checkpoint frequency; report total runtime, %
overhead vs the no-checkpoint baseline, and average time per checkpoint.

The paper's ordering to reproduce:  sync > async > node-level overhead.
Storage mapping on this container: the "PFS" tier is the disk-backed
filesystem; the node tier writes to /dev/shm — the honest analog of the
paper's node-local (RAM/SSD) storage vs parallel-filesystem split on a
single host.
"""
from __future__ import annotations

import os

import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit
from repro.apps.lanczos import GrapheneConfig, run_lanczos
from repro.core import Checkpoint
from repro.core.env import CraftEnv


def _run(mode: str, base: Path, cfg, n_iter, cp_freq, extra_work_s):
    d = base / mode
    envmap = {
        "CRAFT_CP_PATH": str(d / "pfs"),
        "CRAFT_USE_SCR": "0",
    }
    if mode == "none":
        envmap["CRAFT_ENABLE"] = "0"
    elif mode == "sync_pfs":
        pass
    elif mode == "async_pfs":
        envmap["CRAFT_WRITE_ASYNC"] = "1"
    elif mode == "node_level":
        shm = Path("/dev/shm") if Path("/dev/shm").is_dir() else (d / "node")
        envmap.update({
            "CRAFT_USE_SCR": "1",
            "CRAFT_NODE_CP_PATH": str(shm / f"craft-node-{os.getpid()}"),
            "CRAFT_NODE_REDUNDANCY": "LOCAL",
            "CRAFT_PFS_EVERY": "1000000",      # node tier only
        })
    env = CraftEnv.capture(envmap)
    res = run_lanczos(cfg, n_iter=n_iter,
                      cp_freq=(0 if mode == "none" else cp_freq),
                      cp_name=f"l_{mode}", env=env,
                      extra_work_s=extra_work_s)
    return res


def _codec_write(base: Path, label: str, arrays, versions: int, envmap) -> float:
    """Write ``versions`` checkpoint versions; return best per-version seconds."""
    env = CraftEnv.capture({
        "CRAFT_CP_PATH": str(base / label),
        "CRAFT_USE_SCR": "0",
        "CRAFT_KEEP_VERSIONS": "2",
        **envmap,
    })
    cp = Checkpoint(f"codec_{label}", env=env)
    for k, a in arrays.items():
        cp.add(k, a)
    cp.commit()
    best = float("inf")
    try:
        for _ in range(versions):
            t0 = time.perf_counter()
            cp.update_and_write()
            cp.wait()
            best = min(best, time.perf_counter() - t0)
    finally:
        cp.close()
    return best


def codec_throughput(full: bool = False) -> None:
    """Chunked+parallel (codec v1, worker pool) vs legacy single-thread (v0).

    Same multi-array checkpoint, same host, same tier — the measured delta is
    purely the write-path refactor: chunked encode fanout + parallel per-array
    flush vs one monolithic ``tobytes``+crc32 blob at a time on one thread.
    """
    rng = np.random.default_rng(0)
    n_arrays = 8
    mb = 16 if full else 8
    arrays = {
        f"a{i}": rng.standard_normal((mb * 1024 * 1024 // 4,)).astype(np.float32)
        for i in range(n_arrays)
    }
    total_mb = n_arrays * mb
    versions = 4 if full else 3
    base = Path(tempfile.mkdtemp(prefix="craft-codec-"))
    try:
        legacy_s = _codec_write(
            base, "legacy", arrays, versions,
            {"CRAFT_CODEC_VERSION": "0", "CRAFT_IO_WORKERS": "1"})
        chunked_s = _codec_write(
            base, "chunked", arrays, versions, {"CRAFT_CODEC_VERSION": "1"})
        emit("codec_throughput", "legacy_write", round(total_mb / legacy_s, 1),
             "MB/s", codec="v0", workers=1)
        emit("codec_throughput", "chunked_write", round(total_mb / chunked_s, 1),
             "MB/s", codec="v1",
             workers=CraftEnv.capture({}).io_workers)
        emit("codec_throughput", "speedup", round(legacy_s / chunked_s, 2), "x")
    finally:
        shutil.rmtree(base, ignore_errors=True)


def _tree_bytes(root: Path) -> int:
    return sum(p.stat().st_size for p in root.rglob("*") if p.is_file())


def delta_write(full: bool = False) -> None:
    """Incremental (codec v2, ``CRAFT_DELTA=1``) vs full v1 writes while the
    dirty fraction of the train state sweeps 1% → 100%.

    Model of a training loop: a multi-array state is checkpointed every
    version, but only ``dirty_frac`` of its chunks changed since the last
    version (frozen layers, embedding tables, cold optimizer moments).  The
    delta codec digests every chunk (the change detector) and writes only the
    dirty ones; reported are the bytes that physically land in the version
    directory and the best commit latency, against the same state written
    through the full v1 codec.
    """
    rng = np.random.default_rng(7)
    # Payload sized so IO dominates the commit (the cost delta writes avoid);
    # at tiny payloads per-version fixed costs (fsync, publish) flatten the
    # measured gain long before the bytes stop shrinking.
    n_arrays = 8
    mb = 24 if full else 16
    chunk_bytes = 256 * 1024    # ≥64 chunks/array so a 1% sweep is realizable
    versions = 4 if full else 3

    def fresh_state():
        return {
            f"a{i}": rng.standard_normal(
                (mb * 1024 * 1024 // 4,)).astype(np.float32)
            for i in range(n_arrays)
        }

    def run(label: str, base: Path, dirty_frac: float, envmap: dict):
        arrays = fresh_state()
        env = CraftEnv.capture({
            "CRAFT_CP_PATH": str(base),
            "CRAFT_USE_SCR": "0",
            "CRAFT_KEEP_VERSIONS": str(versions + 4),
            "CRAFT_CHUNK_BYTES": str(chunk_bytes),
            **envmap,
        })
        cp = Checkpoint(f"delta_{label}", env=env)
        for k, a in arrays.items():
            cp.add(k, a)
        cp.commit()
        n_chunks = max(1, arrays["a0"].nbytes // chunk_bytes)
        n_dirty = max(1, int(round(dirty_frac * n_chunks)))
        best_s, last_bytes = float("inf"), 0
        try:
            cp.update_and_write()      # version 1: always a full write
            cp.wait()
            for v in range(2, versions + 2):
                for a in arrays.values():    # touch n_dirty chunks per array
                    for c in range(n_dirty):
                        off = (c * n_chunks // n_dirty) * chunk_bytes // 4
                        a[off] += 1.0
                t0 = time.perf_counter()
                cp.update_and_write()
                cp.wait()
                best_s = min(best_s, time.perf_counter() - t0)
                last_bytes = _tree_bytes(env.cp_path / f"delta_{label}" / f"v-{v}")
        finally:
            cp.close()
        return best_s, last_bytes

    base = Path(tempfile.mkdtemp(prefix="craft-delta-"))
    total_mb = n_arrays * mb
    n_chunks = mb * 1024 * 1024 // chunk_bytes
    try:
        for frac in (0.01, 0.10, 0.50, 1.00):
            tag = f"{int(frac * 100)}pct"
            # the realized fraction is quantized to whole chunks — report it
            # so the artifact never claims a cleaner state than was written
            realized = max(1, int(round(frac * n_chunks))) / n_chunks
            rpct = round(100 * realized, 2)
            full_s, full_b = run(f"v1_{tag}", base / f"v1_{tag}", frac,
                                 {"CRAFT_CODEC_VERSION": "1"})
            delta_s, delta_b = run(f"v2_{tag}", base / f"v2_{tag}", frac,
                                   {"CRAFT_DELTA": "1"})
            emit("delta_write", f"bytes_full_{tag}", full_b, "B",
                 dirty_pct=rpct, payload_mb=total_mb)
            emit("delta_write", f"bytes_delta_{tag}", delta_b, "B",
                 dirty_pct=rpct, payload_mb=total_mb)
            emit("delta_write", f"bytes_ratio_{tag}",
                 round(full_b / max(1, delta_b), 2), "x", dirty_pct=rpct)
            emit("delta_write", f"commit_full_{tag}", round(full_s, 5), "s",
                 dirty_pct=rpct)
            emit("delta_write", f"commit_delta_{tag}", round(delta_s, 5), "s",
                 dirty_pct=rpct)
            emit("delta_write", f"commit_speedup_{tag}",
                 round(full_s / max(1e-9, delta_s), 2), "x", dirty_pct=rpct)
    finally:
        shutil.rmtree(base, ignore_errors=True)


def main(full: bool = False) -> None:
    codec_throughput(full)
    # checkpoint payload = 2 Lanczos vectors (nx·ny·2 fp32) ≈ 17 MB at 1024²
    # — big enough that write time is visible against ~ms-scale iterations
    cfg = GrapheneConfig(nx=1024 if full else 768,
                         ny=1024 if full else 768, disorder=0.3)
    n_iter = 200 if full else 120
    cp_freq = 20 if full else 15
    extra = 0.0
    base = Path(tempfile.mkdtemp(prefix="craft-table4-"))
    import shutil as _sh
    try:
        results = {}
        for mode in ("none", "sync_pfs", "async_pfs", "node_level"):
            res = _run(mode, base, cfg, n_iter, cp_freq, extra)
            results[mode] = res
            emit("table4_cr_overhead", f"{mode}_runtime",
                 round(res.wall_s, 4), "s")
        base_t = results["none"].wall_s
        for mode in ("sync_pfs", "async_pfs", "node_level"):
            res = results[mode]
            ov = 100.0 * (res.wall_s - base_t) / base_t
            n_cp = max(1, res.cp_stats.get("writes", 1))
            emit("table4_cr_overhead", f"{mode}_overhead",
                 round(ov, 2), "%")
            emit("table4_cr_overhead", f"{mode}_time_per_cp",
                 round(res.cp_stats.get("write_seconds", 0.0) / n_cp, 5),
                 "s")
        # correctness guard: all modes converge to the same eigenvalue
        eigs = {m: r.eigenvalue for m, r in results.items()}
        spread = max(eigs.values()) - min(eigs.values())
        emit("table4_cr_overhead", "eigenvalue_spread", f"{spread:.2e}", "")
    finally:
        shutil.rmtree(base, ignore_errors=True)
        _sh.rmtree(Path("/dev/shm") / f"craft-node-{os.getpid()}",
                   ignore_errors=True)


def _schedule_overhead(full: bool = False) -> None:
    """Scheduler sweep + preemption-flush proof (benchmarks/schedule_overhead
    .py) — registered here so one invocation can land every scenario in a
    single ``--json`` artifact (the CI bench-smoke job's BENCH_cr.json)."""
    from benchmarks.schedule_overhead import main as sched_main

    sched_main(full)


_SCENARIOS = {
    "codec_throughput": codec_throughput,
    "delta_write": delta_write,
    "schedule_overhead": _schedule_overhead,
    "table4": main,
}


if __name__ == "__main__":
    from benchmarks.common import run_scenarios

    run_scenarios(_SCENARIOS, main)
