"""Multi-process fault-tolerant runtime (the paper's testbed analog).

A :class:`~repro.runtime.cluster.Cluster` runs N worker *processes* grouped
into logical *nodes* (``procs_per_node``) with an optional spare-node pool.
The coordinator (threads in the launching process — the role a job scheduler
/ Borg-Pathways control plane plays on a real fleet) mediates collectives,
detects fail-stop failures via connection EOF + heartbeat staleness +
collective deadlines (straggler mitigation), and executes the ULFM recovery
recipe with REUSE / NO-REUSE spawn policies.

Fault model (paper §5.3): ``cluster.kill(rank)`` / ``cluster.kill_node(n)``
deliver SIGKILL — the paper's ``pkill -9`` — and in-application injection is
available by raising from the worker fn.
"""
from repro.runtime.cluster import Cluster  # noqa: F401
