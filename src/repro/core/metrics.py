"""Live telemetry plane: a dependency-free metrics registry (``CRAFT_METRICS``).

Where :mod:`repro.core.trace` records a *post-hoc* event log for the
record → replay → tune loop, this module keeps *live* aggregates — the
counters, gauges and histograms a fleet operator scrapes while the job is
running (served by :mod:`repro.core.telemetry` at ``/metrics``; rendered
interactively by ``python -m repro.top``).

Design mirrors ``trace.py`` exactly:

* a module-global :data:`REGISTRY` that stays the no-op
  :class:`_NullRegistry` until :func:`install` — when ``CRAFT_METRICS`` is
  unset every hook is a single dynamic call that immediately returns (no
  branching, no locking, no string formatting; ``benchmarks/cr_overhead.py
  metrics_overhead`` keeps the armed-vs-off delta on the scoreboard);
* process-global, because one process may run several ``Checkpoint``
  objects plus an async writer plus a scrubber thread, and the exporter
  needs one coherent scrape of all of them;
* thread-safe via one cheap lock (instruments are plain floats; the lock
  is held for a dict update only).

Instrument model (a deliberately tiny Prometheus subset):

=============  ==========================================================
counter        monotonically increasing float (``inc``); cross-rank merge
               is a **sum**
gauge          last-written float (``set_gauge``); cross-rank merge keeps
               the **max** (worst-case semantics: oldest pending write,
               most-open breaker, deepest queue)
histogram      fixed-bucket cumulative counts + sum + count (``observe``);
               cross-rank merge sums bucket-wise
=============  ==========================================================

Series are keyed by ``(name, sorted(labels))`` just like Prometheus, so
``craft_tier_write_seconds_sum{slot="pfs"}`` and ``...{slot="mem"}`` are
independent series of one metric.

Cross-rank aggregation rides the existing comm fabric: :func:`aggregate`
allgathers each rank's :func:`snapshot` (``op="list"`` — the same
mechanism ``MemStore.publish`` uses) and merges, so rank 0 sees fleet
totals.  Collectives run over *live* members only, which makes the merge
tolerant of dead ranks after an AFT recovery for free.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "REGISTRY", "inc", "set_gauge", "observe", "enabled",
    "install", "uninstall", "maybe_install_from_env",
    "snapshot", "merge", "render_prometheus", "aggregate",
    "MetricsRegistry", "StatsView", "DEFAULT_BUCKETS",
]

#: Fixed histogram buckets (seconds): IO latencies on the CR path span
#: sub-millisecond RAM publishes to multi-second degraded PFS writes.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0, 30.0,
)

_Key = Tuple[str, Tuple[Tuple[str, str], ...]]


def _key(name: str, labels: Dict[str, str]) -> _Key:
    if not labels:
        return (name, ())
    return (name, tuple(sorted((k, str(v)) for k, v in labels.items())))


class _NullRegistry:
    """The ``CRAFT_METRICS``-unset registry: every hook is a no-op."""

    enabled = False

    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        return None

    def set_gauge(self, name: str, value: float, **labels) -> None:
        return None

    def observe(self, name: str, value: float, **labels) -> None:
        return None

    def snapshot(self) -> dict:
        return {"counters": {}, "gauges": {}, "histograms": {}}


class MetricsRegistry:
    """Lock-cheap in-process store of counters/gauges/histograms."""

    enabled = True

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS):
        self._lock = threading.Lock()
        self.buckets: Tuple[float, ...] = tuple(sorted(buckets))
        self._counters: Dict[_Key, float] = {}
        self._gauges: Dict[_Key, float] = {}
        # histogram value: [bucket_counts..., +Inf_count] , sum, count
        self._hists: Dict[_Key, Tuple[List[int], float, int]] = {}

    # ------------------------------------------------------------ writes
    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        k = _key(name, labels)
        with self._lock:
            self._counters[k] = self._counters.get(k, 0.0) + value

    def set_gauge(self, name: str, value: float, **labels) -> None:
        k = _key(name, labels)
        with self._lock:
            self._gauges[k] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        k = _key(name, labels)
        v = float(value)
        with self._lock:
            ent = self._hists.get(k)
            if ent is None:
                ent = ([0] * (len(self.buckets) + 1), 0.0, 0)
            counts, total, n = ent
            counts = list(counts)
            for i, ub in enumerate(self.buckets):
                if v <= ub:
                    counts[i] += 1
                    break
            else:
                counts[-1] += 1
            self._hists[k] = (counts, total + v, n + 1)

    # ------------------------------------------------------------- reads
    def snapshot(self) -> dict:
        """A plain-dict copy safe to merge/serialize (keys re-encoded as
        ``name|k=v|k=v`` strings so the snapshot survives JSON)."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = {k: (list(c), s, n) for k, (c, s, n) in self._hists.items()}
        return {
            "buckets": list(self.buckets),
            "counters": {_flat(k): v for k, v in counters.items()},
            "gauges": {_flat(k): v for k, v in gauges.items()},
            "histograms": {
                _flat(k): {"counts": c, "sum": s, "count": n}
                for k, (c, s, n) in hists.items()
            },
        }


def _flat(key: _Key) -> str:
    name, labels = key
    if not labels:
        return name
    return name + "|" + "|".join(f"{k}={v}" for k, v in labels)


def _unflat(flat: str) -> Tuple[str, Tuple[Tuple[str, str], ...]]:
    parts = flat.split("|")
    labels = tuple(tuple(p.split("=", 1)) for p in parts[1:])
    return parts[0], labels  # type: ignore[return-value]


#: The process-wide registry.  Hooks call the module-level helpers (which
#: read :data:`REGISTRY` at call time, so early importers see later installs).
REGISTRY = _NullRegistry()


def inc(name: str, value: float = 1.0, **labels) -> None:
    REGISTRY.inc(name, value, **labels)


def set_gauge(name: str, value: float, **labels) -> None:
    REGISTRY.set_gauge(name, value, **labels)


def observe(name: str, value: float, **labels) -> None:
    REGISTRY.observe(name, value, **labels)


def enabled() -> bool:
    return REGISTRY.enabled


def install() -> "MetricsRegistry":
    """Arm the registry (idempotent: an armed registry keeps its series)."""
    global REGISTRY
    if not REGISTRY.enabled:
        REGISTRY = MetricsRegistry()
    return REGISTRY  # type: ignore[return-value]


def uninstall() -> None:
    """Back to the no-op registry (tests; end of a metered benchmark)."""
    global REGISTRY
    REGISTRY = _NullRegistry()


def maybe_install_from_env(env) -> None:
    """Arm the registry when the captured env asks for it
    (``Checkpoint.commit()`` calls this — the read-once contract)."""
    if getattr(env, "metrics", False):
        install()


def snapshot() -> dict:
    return REGISTRY.snapshot()


# --------------------------------------------------------------------- merge
def merge(snapshots: Iterable[dict]) -> dict:
    """Merge per-rank snapshots into fleet totals: counters and histogram
    buckets **sum**; gauges keep the **max** (worst-case-wins semantics)."""
    out = {"buckets": None, "counters": {}, "gauges": {}, "histograms": {}}
    for snap in snapshots:
        if not snap:
            continue
        if out["buckets"] is None:
            out["buckets"] = snap.get("buckets")
        for k, v in snap.get("counters", {}).items():
            out["counters"][k] = out["counters"].get(k, 0.0) + v
        for k, v in snap.get("gauges", {}).items():
            prev = out["gauges"].get(k)
            out["gauges"][k] = v if prev is None else max(prev, v)
        for k, h in snap.get("histograms", {}).items():
            prev = out["histograms"].get(k)
            if prev is None:
                out["histograms"][k] = {
                    "counts": list(h["counts"]),
                    "sum": h["sum"], "count": h["count"],
                }
            else:
                prev["counts"] = [a + b for a, b
                                  in zip(prev["counts"], h["counts"])]
                prev["sum"] += h["sum"]
                prev["count"] += h["count"]
    if out["buckets"] is None:
        out["buckets"] = list(DEFAULT_BUCKETS)
    return out


def aggregate(comm, snap: Optional[dict] = None) -> dict:
    """Allgather every live rank's snapshot over ``comm`` and merge.

    Uses ``op="list"`` (the MemStore.publish mechanism); post-AFT the
    collective only spans surviving members, so dead ranks simply drop out
    of the fleet totals.  Falls back to the local snapshot if the fabric
    is broken mid-recovery.
    """
    if snap is None:
        snap = snapshot()
    if comm is None or getattr(comm, "size", 1) <= 1:
        return merge([snap])
    try:
        gathered = comm.allreduce(snap, op="list")
    except Exception:
        return merge([snap])
    if not isinstance(gathered, list):
        gathered = [gathered]
    return merge(g for g in gathered if isinstance(g, dict))


# ---------------------------------------------------------------- rendering
def render_prometheus(snap: dict, prefix: str = "craft_") -> str:
    """Render a snapshot (local or merged) in Prometheus text exposition
    format, stdlib only."""
    lines: List[str] = []
    buckets = snap.get("buckets") or list(DEFAULT_BUCKETS)

    def series(flat: str) -> Tuple[str, str]:
        name, labels = _unflat(flat)
        lab = ",".join(f'{k}="{_esc(v)}"' for k, v in labels)
        return prefix + name, ("{" + lab + "}") if lab else ""

    seen_type: Dict[str, str] = {}

    def header(full_name: str, typ: str) -> None:
        if seen_type.get(full_name) != typ:
            seen_type[full_name] = typ
            lines.append(f"# TYPE {full_name} {typ}")

    for flat in sorted(snap.get("counters", {})):
        full, lab = series(flat)
        header(full + "_total", "counter")
        lines.append(f"{full}_total{lab} {_fmt(snap['counters'][flat])}")
    for flat in sorted(snap.get("gauges", {})):
        full, lab = series(flat)
        header(full, "gauge")
        lines.append(f"{full}{lab} {_fmt(snap['gauges'][flat])}")
    for flat in sorted(snap.get("histograms", {})):
        full, lab = series(flat)
        h = snap["histograms"][flat]
        header(full, "histogram")
        base = lab[1:-1] if lab else ""
        cum = 0
        for i, ub in enumerate(buckets):
            cum += h["counts"][i]
            le = _fmt(ub)
            extra = f'{base},le="{le}"' if base else f'le="{le}"'
            lines.append(f"{full}_bucket{{{extra}}} {cum}")
        cum += h["counts"][len(buckets)]
        extra = f'{base},le="+Inf"' if base else 'le="+Inf"'
        lines.append(f"{full}_bucket{{{extra}}} {cum}")
        lines.append(f"{full}_sum{lab} {_fmt(h['sum'])}")
        lines.append(f"{full}_count{lab} {h['count']}")
    return "\n".join(lines) + ("\n" if lines else "")


def _esc(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _fmt(v) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def parse_prometheus(text: str) -> Dict[str, Dict[str, float]]:
    """Parse Prometheus text back into ``{metric: {label_str: value}}`` —
    the scrape round-trip used by tests and ``repro.top``."""
    out: Dict[str, Dict[str, float]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        try:
            series, val = line.rsplit(" ", 1)
            if "{" in series:
                name, rest = series.split("{", 1)
                labels = rest.rstrip("}")
            else:
                name, labels = series, ""
            out.setdefault(name, {})[labels] = float(val)
        except ValueError:
            continue
    return out


# -------------------------------------------------------------- StatsView
class StatsView(dict):
    """``Checkpoint.stats``: a real dict (full back-compat for tests and
    callers that iterate/copy it) whose numeric writes are mirrored into
    the global registry as ``cp_<key>`` counters/gauges.

    The mirror is one dynamic no-op call when ``CRAFT_METRICS`` is unset —
    same overhead contract as a bare ``trace.emit``.  Non-numeric values
    (``restore_tier``, the nested ``tier_reads`` dict) stay local-only.
    Monotone growth (``writes`` going 3 → 4) mirrors as a counter *delta*
    so the cross-rank merge sums to true fleet totals; a shrink or a fresh
    non-monotone set (``restore_read_bytes``) mirrors as a gauge.
    """

    def __init__(self, name: str, *args, prefix: str = "cp_",
                 label: str = "cp", **kw):
        super().__init__(*args, **kw)
        self._name = name
        self._prefix = prefix
        self._label = label

    def __setitem__(self, key, value):
        if isinstance(value, (int, float)) and not isinstance(value, bool):
            prev = super().get(key, 0)
            if (isinstance(prev, (int, float)) and not isinstance(prev, bool)
                    and value >= prev):
                if value > prev:
                    REGISTRY.inc(self._prefix + key, value - prev,
                                 **{self._label: self._name})
            else:
                REGISTRY.set_gauge(self._prefix + key, value,
                                   **{self._label: self._name})
        super().__setitem__(key, value)

    def inc(self, key, delta=1):
        """``stats.inc("writes")`` — the one-liner replacing scattered
        ``stats[k] += 1``; routes through ``__setitem__`` so the registry
        mirror sees the delta exactly once."""
        self[key] = self.get(key, 0) + delta
