"""Pallas TPU kernel: fused snapshot pass (digest + dirty mask + histogram).

The device-resident write path (``CRAFT_DEVICE_SNAPSHOT``) needs three
per-chunk facts before any checkpoint byte leaves HBM: the Fletcher digest
(storage integrity + the delta codec's change detector), whether the chunk
differs from the previous snapshot (so only dirty chunks cross the
interconnect), and a byte-nibble histogram (the order-0 entropy estimate
that gates zstd vs raw).  Computing them in one fused pass costs a single
read of the shard instead of three.

TPU mapping: the shard's uint32 words are viewed as
(n_chunks * rows_per_chunk, 128) so every tile is lane-aligned; the grid is
(chunk, row_block) with the row_block axis innermost, each step computing
the tile-local sums/counts on the VPU and accumulating into a (1, 19) block
that every step of a chunk maps to the same location (the checksum kernel's
reduction-across-grid idiom, widened).  The digest offset shift uses the
associative blocking identity ``s2 += offset * s1``; the dirty flag is
resolved on the chunk's final row block by comparing the accumulated digest
against the previous snapshot's digest table, which stays device-resident
between checkpoints.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.snapshot.ref import HIST_BINS, META_COLS

_LANES = 128


def _snapshot_kernel(x_ref, prev_ref, out_ref, *,
                     block_rows: int, rpb: int, with_hist: bool):
    j = pl.program_id(1)                       # row block within the chunk
    tile = x_ref[...]                          # (block_rows, 128)
    row = jax.lax.broadcasted_iota(jnp.uint32, tile.shape, 0)
    lane = jax.lax.broadcasted_iota(jnp.uint32, tile.shape, 1)
    local_pos1 = row * jnp.uint32(_LANES) + lane + jnp.uint32(1)   # 1-based
    s1 = jnp.sum(tile, dtype=jnp.uint32)
    offset = jnp.uint32(j) * jnp.uint32(block_rows * _LANES)
    s2 = jnp.sum(tile * local_pos1, dtype=jnp.uint32) + offset * s1
    parts = [s1, s2, jnp.uint32(0)]            # dirty resolved on last block
    if with_hist:
        nibs = [(tile >> jnp.uint32(sh)) & jnp.uint32(0xF)
                for sh in range(0, 32, 4)]
        for k in range(HIST_BINS):
            c = jnp.uint32(0)
            for nib in nibs:
                c = c + jnp.sum((nib == jnp.uint32(k)).astype(jnp.uint32),
                                dtype=jnp.uint32)
            parts.append(c)
    contrib = jnp.stack(parts).reshape(1, len(parts))

    @pl.when(j == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = out_ref[...] + contrib

    @pl.when(j == rpb - 1)
    def _finish():
        acc = out_ref[...]
        dirty = (
            (acc[0, 0] != prev_ref[0, 0]) | (acc[0, 1] != prev_ref[0, 1])
        ).astype(jnp.uint32)
        col = jax.lax.broadcasted_iota(jnp.uint32, acc.shape, 1)
        out_ref[...] = acc + jnp.where(col == 2, dirty, jnp.uint32(0))


@functools.partial(
    jax.jit, static_argnames=("block_rows", "with_hist", "interpret"))
def snapshot(
    x2: jnp.ndarray, prev: jnp.ndarray, *, block_rows: int = 512,
    with_hist: bool = True, interpret: bool = False,
) -> jnp.ndarray:
    """Fused per-chunk [s1, s2, dirty, hist…] of a (n_chunks, wpc) uint32
    matrix (see ref.py for the definition).  ``wpc`` must be a multiple of
    128 and ``wpc // 128`` a multiple of ``block_rows`` (ops.py zero-pads and
    picks a dividing block size — zero words are digest-neutral and their
    histogram counts are corrected on the host from the known pad length).
    """
    if x2.ndim != 2 or x2.dtype != jnp.uint32:
        raise TypeError(f"expected 2-D uint32, got {x2.shape} {x2.dtype}")
    n_chunks, wpc = x2.shape
    if prev.shape != (n_chunks, 2) or prev.dtype != jnp.uint32:
        raise TypeError(
            f"expected ({n_chunks}, 2) uint32 prev digests, got "
            f"{prev.shape} {prev.dtype}"
        )
    if wpc % _LANES:
        raise ValueError(f"wpc={wpc} must be a multiple of {_LANES}")
    rows = wpc // _LANES
    if rows % block_rows:
        raise ValueError(
            f"rows_per_chunk={rows} must be a multiple of block_rows="
            f"{block_rows}"
        )
    rpb = rows // block_rows
    width = META_COLS if with_hist else 3
    x3 = x2.reshape(n_chunks * rows, _LANES)
    out = pl.pallas_call(
        functools.partial(_snapshot_kernel, block_rows=block_rows, rpb=rpb,
                          with_hist=with_hist),
        grid=(n_chunks, rpb),
        in_specs=[
            pl.BlockSpec((block_rows, _LANES), lambda i, j: (i * rpb + j, 0)),
            pl.BlockSpec((1, 2), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, width), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, width), jnp.uint32),
        interpret=interpret,
    )(x3, prev)
    return out
