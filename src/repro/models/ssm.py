"""Selective state-space blocks: Mamba1 (falcon-mamba) and Mamba2 (zamba2).

TPU adaptation of the CUDA selective-scan: the original fuses the recurrence
into one kernel to avoid materializing per-timestep states.  On TPU we use a
**chunked associative scan**: ``lax.scan`` over chunks of ``cfg.ssm_chunk``
timesteps carries the (B, ..., d_state) state across chunks, and inside a
chunk ``lax.associative_scan`` parallelizes the recurrence on the VPU.  Live
scan buffers are O(B · chunk · d_inner · d_state) instead of O(B · L · ...),
an 8–16× activation-memory reduction at L=4k — the knob shows up directly in
the dry-run memory term (§Perf).

Recurrence (both variants):  h_t = a_t ⊙ h_{t-1} + b_t,
  a_t = exp(Δ_t A)        (elementwise decay)
  b_t = Δ_t · B_t ⊗ x_t   (input injection)
  y_t = C_t · h_t + D x_t

Mamba1: per-channel A (d_inner, d_state), Δ from a low-rank projection.
Mamba2: scalar A per head (SSD), B/C shared across head groups.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.sharding.activations import constrain

Cache = dict


def _assoc_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def _chunk_inputs(arrs, chunk: int):
    """Reshape (B, L, ...) arrays to (nc, B, chunk, ...), zero-padded."""
    B, L = arrs[0].shape[0], arrs[0].shape[1]
    pad = (-L) % chunk
    out = []
    for a in arrs:
        if pad:
            widths = [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2)
            a = jnp.pad(a, widths)
        nc = (L + pad) // chunk
        out.append(jnp.moveaxis(
            a.reshape(B, nc, chunk, *a.shape[2:]), 1, 0))
    return out, (L + pad) // chunk


def _fused_ssd_scan(dtx, bh, ch, dt, A, h0, chunk: int, state_dims=()):
    """Fused chunked selective scan (the mamba recurrence):

        h_t = exp(dt_t * A) (.) h_{t-1} + dtx_t (x) bh_t
        y_t = <h_t, ch_t>_state

    dtx: (B, L, *head) = Delta_t*x_t;  bh/ch: (B, L, [*head,] st);
    dt: (B, L, di) (mamba1) or (B, L, nh) (mamba2);
    A:  (di, st) per-channel-per-state (mamba1) or (nh,) scalar (mamba2).

    Everything L-length and state-ranked — the (B, L, ..., st) decay,
    injection and hidden-state tensors of the naive formulation — is built
    *per chunk inside the scan body* and contracted away before the next
    chunk, so HBM never holds an L-by-state tensor (EXPERIMENTS.md §Perf
    iterations 1.2/3.1).  On the TPU target this body is the Pallas
    ``ssm_scan`` kernel (kernels/ssm_scan); the ``pallas_equiv_ssm`` scope
    lets the roofline charge kernel-boundary IO only.

    Zero padding of the tail chunk is exact: dt=0 gives decay exp(0)=1 and
    injection 0 (state preserved), and padded-step outputs are sliced off.

    Returns (y (B, L, *head), h_last (B, *head, st)).
    """
    B, L = dtx.shape[0], dtx.shape[1]
    chunk = min(chunk, L)
    (dtx_c, bh_c, ch_c, dt_c), nc = _chunk_inputs(
        [dtx, bh, ch, dt], chunk)
    if state_dims:
        bd = ("batch", *state_dims)
        h0 = constrain(h0, *bd[: h0.ndim])

    # jax.checkpoint: without it the scan's backward stacks every chunk's
    # (B, c, *head, st) hidden states back into HBM (the dry-run measured
    # those stacks as the dominant remaining traffic, §Perf iter. 1.3);
    # with it, backward recomputes a chunk from its 4 small inputs + the
    # (B, *head, st) carry — exactly what the Pallas kernel's VJP does.
    @jax.checkpoint
    def body(h, xs):
        with jax.named_scope("pallas_equiv_ssm"):
            dtx_k, bh_k, ch_k, dt_k = xs
            if dtx_k.ndim == 3:   # mamba1: dtx (B,c,di); A (di,st)
                decay = jnp.exp(dt_k[..., None] * A[None, None])
                inject = dtx_k[..., None] * bh_k[:, :, None, :]
                a_k = decay                                  # (B,c,di,st)
            else:                 # mamba2: dtx (B,c,nh,hd); A (nh,)
                decay = jnp.exp(dt_k * A[None, None])        # (B,c,nh)
                inject = dtx_k[..., None] * bh_k[:, :, :, None, :]
                a_k = jnp.broadcast_to(
                    decay[..., None, None], inject.shape)
            prod, acc = jax.lax.associative_scan(
                _assoc_combine, (a_k, inject), axis=1)
            h_all = prod * h[:, None] + acc
            y_k = (jnp.einsum("bcds,bcs->bcd", h_all, ch_k)
                   if dtx_k.ndim == 3
                   else jnp.einsum("bchds,bchs->bchd", h_all, ch_k))
            return h_all[:, -1], y_k

    h_last, y_c = jax.lax.scan(body, h0, (dtx_c, bh_c, ch_c, dt_c))
    y = jnp.moveaxis(y_c, 0, 1).reshape(
        B, nc * chunk, *y_c.shape[3:])[:, :L]
    return y, h_last


def _chunked_linear_scan(a, b, h0, chunk: int, state_dims=()):
    """Scan h_t = a_t h_{t-1} + b_t over axis=1 (length L) in chunks.

    a, b: (B, L, ...state dims); h0: (B, ...state dims).
    ``state_dims``: logical names of the state dims (sharding constraints
    for the scan inputs/carry — GSPMD left alone replicates them).
    Returns (h_all (B, L, ...), h_last (B, ...)).
    """
    B, L = a.shape[0], a.shape[1]
    chunk = min(chunk, L)
    pad = (-L) % chunk
    if pad:
        # identity-extend: a=1, b=0 steps leave the state untouched
        widths = [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2)
        a = jnp.pad(a, widths, constant_values=1.0)
        b = jnp.pad(b, widths)
    lp = L + pad
    nc = lp // chunk
    state_shape = a.shape[2:]
    a_c = a.reshape(B, nc, chunk, *state_shape).swapaxes(0, 1)
    b_c = b.reshape(B, nc, chunk, *state_shape).swapaxes(0, 1)
    if state_dims:
        sd = (None, "batch", None, *state_dims)
        a_c = constrain(a_c, *sd)
        b_c = constrain(b_c, *sd)
        h0 = constrain(h0, "batch", *state_dims)

    def step(h, ab):
        a_k, b_k = ab                                     # (B, chunk, ...)
        prod, acc = jax.lax.associative_scan(
            _assoc_combine, (a_k, b_k), axis=1
        )
        h_t = prod * h[:, None] + acc                     # (B, chunk, ...)
        return h_t[:, -1], h_t

    h_last, h_all = jax.lax.scan(step, h0, (a_c, b_c))
    h_all = h_all.swapaxes(0, 1).reshape(B, lp, *state_shape)[:, :L]
    return h_all, h_last


def _causal_conv(x, w, b, state: Optional[jnp.ndarray]):
    """Depthwise causal conv1d.  x: (B, L, C); w: (K, C); b: (C,).

    ``state``: (B, K-1, C) carry of the previous K-1 inputs (decode), or None
    (training: left-zero padding).  Returns (y, new_state).
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                # (B, K-1+L, C)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None] for i in range(k)
    ) + b[None, None]
    new_state = xp[:, -(k - 1):, :] if k > 1 else jnp.zeros(
        (x.shape[0], 0, x.shape[2]), x.dtype
    )
    return y, new_state


# =========================================================================
# Mamba1
# =========================================================================
def mamba1_init(key, cfg):
    d, di, st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr, kc = cfg.dt_rank_eff, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    a_init = jnp.broadcast_to(
        jnp.arange(1, st + 1, dtype=jnp.float32)[None], (di, st)
    )
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), d, cfg.dtype),
        "conv_w": dense_init(ks[1], (kc, di), kc, cfg.dtype),
        "conv_b": jnp.zeros((di,), cfg.dtype),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * st), di, cfg.dtype),
        "dt_proj": dense_init(ks[3], (dtr, di), dtr, cfg.dtype),
        "dt_bias": jnp.full((di,), -4.0, cfg.dtype),   # softplus ≈ small Δ
        "A_log": jnp.log(a_init).astype(jnp.float32),  # fp32 for stability
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), di, cfg.dtype),
    }


def mamba1_logical(cfg):
    return {
        "in_proj": ("embed", "ssm_inner"),
        "conv_w": ("conv", "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "x_proj": ("ssm_inner", None),
        "dt_proj": ("dt_rank", "ssm_inner"),
        "dt_bias": ("ssm_inner",),
        "A_log": ("ssm_inner", "ssm_state"),
        "D": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }


def mamba1_cache_init(cfg, batch: int, dtype) -> Cache:
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def mamba1_cache_logical(cfg):
    return {
        "conv": ("batch", "conv", "ssm_inner"),
        "h": ("batch", "ssm_inner", "ssm_state"),
        "pos": (),
    }


def mamba1_apply(
    params, x: jnp.ndarray, cfg, cache: Optional[Cache] = None,
) -> Tuple[jnp.ndarray, Optional[Cache]]:
    b, l, _ = x.shape
    di, st, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_eff
    xz = jnp.einsum("bld,de->ble", x, params["in_proj"])
    xs, z = xz[..., :di], xz[..., di:]
    conv_state = cache["conv"] if cache is not None else None
    xs, new_conv = _causal_conv(xs, params["conv_w"], params["conv_b"],
                                conv_state)
    xs = jax.nn.silu(xs.astype(jnp.float32))
    dbc = jnp.einsum("ble,ef->blf", xs.astype(cfg.dtype), params["x_proj"])
    dt = jax.nn.softplus(
        jnp.einsum("blr,re->ble", dbc[..., :dtr], params["dt_proj"])
        .astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )                                                     # (B, L, di)
    bmat = dbc[..., dtr : dtr + st].astype(jnp.float32)   # (B, L, st)
    cmat = dbc[..., dtr + st :].astype(jnp.float32)       # (B, L, st)
    a_mat = -jnp.exp(params["A_log"].astype(jnp.float32)) # (di, st)

    h0 = (cache["h"] if cache is not None
          else jnp.zeros((b, di, st), jnp.float32))
    # fused chunk scan: the (B,L,di,st) decay/injection/state tensors only
    # ever exist chunk-locally (§Perf iteration 3.1)
    y, h_last = _fused_ssd_scan(
        dt * xs, bmat, cmat, dt, a_mat, h0, cfg.ssm_chunk,
        state_dims=("ssm_inner", "ssm_state"))
    y = y + params["D"].astype(jnp.float32)[None, None] * xs
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("ble,ed->bld", y.astype(cfg.dtype), params["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "h": h_last, "pos": cache["pos"] + l}
    return out, new_cache


# =========================================================================
# Mamba2 (SSD): scalar decay per head, grouped B/C
# =========================================================================
def mamba2_init(key, cfg):
    d, di, st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh, g, kc = cfg.ssm_heads, cfg.ssm_groups, cfg.ssm_conv
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * g * st + nh
    conv_dim = di + 2 * g * st
    return {
        "in_proj": dense_init(ks[0], (d, d_in_proj), d, cfg.dtype),
        "conv_w": dense_init(ks[1], (kc, conv_dim), kc, cfg.dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.dtype),
        "dt_bias": jnp.full((nh,), -4.0, jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_w": jnp.ones((di,), cfg.dtype),
        "out_proj": dense_init(ks[2], (di, d), di, cfg.dtype),
    }


def mamba2_logical(cfg):
    return {
        "in_proj": ("embed", "ssm_inner"),
        "conv_w": ("conv", "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "dt_bias": ("ssm_heads",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "norm_w": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }


def mamba2_cache_init(cfg, batch: int, dtype) -> Cache:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "h": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        ),
        "pos": jnp.zeros((), jnp.int32),
    }


def mamba2_cache_logical(cfg):
    return {
        "conv": ("batch", "conv", "ssm_inner"),
        "h": ("batch", "ssm_heads", None, "ssm_state"),
        "pos": (),
    }


def mamba2_apply(
    params, x: jnp.ndarray, cfg, cache: Optional[Cache] = None,
) -> Tuple[jnp.ndarray, Optional[Cache]]:
    b, l, _ = x.shape
    di, st = cfg.d_inner, cfg.ssm_state
    nh, hd, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups
    proj = jnp.einsum("bld,de->ble", x, params["in_proj"])
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * g * st]
    dt = proj[..., di + di + 2 * g * st :]                # (B, L, nh)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state)
    xbc = jax.nn.silu(xbc.astype(jnp.float32))
    xs = xbc[..., :di].reshape(b, l, nh, hd)              # (B,L,nh,hd)
    bmat = xbc[..., di : di + g * st].reshape(b, l, g, st)
    cmat = xbc[..., di + g * st :].reshape(b, l, g, st)
    heads_per_group = nh // g
    bh = jnp.repeat(bmat, heads_per_group, axis=2)        # (B,L,nh,st)
    ch = jnp.repeat(cmat, heads_per_group, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None])
    a = -jnp.exp(params["A_log"])                         # (nh,)
    h0 = (cache["h"] if cache is not None
          else jnp.zeros((b, nh, hd, st), jnp.float32))
    # fused chunk scan: no (B,L,nh,hd,st) tensor in HBM (§Perf iter. 1.2)
    y, h_last = _fused_ssd_scan(
        dt[..., None] * xs, bh, ch, dt, a, h0, cfg.ssm_chunk,
        state_dims=("ssm_heads", None, "ssm_state"))
    y = y + params["D"][None, None, :, None] * xs
    y = y.reshape(b, l, di)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    gated = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(gated), axis=-1, keepdims=True)
    y = gated * jax.lax.rsqrt(var + cfg.norm_eps) \
        * params["norm_w"].astype(jnp.float32)[None, None]
    out = jnp.einsum("ble,ed->bld", y.astype(cfg.dtype), params["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "h": h_last, "pos": cache["pos"] + l}
    return out, new_cache
