"""Checkpoint/restart semantics (paper §2: Listings 2/5, Table 2 knobs)."""
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Box, Checkpoint, CheckpointError, CpBase
from repro.core.env import CraftEnv


def make_cp(name, env, data=None):
    cp = Checkpoint(name, env=env)
    for k, v in (data or {}).items():
        cp.add(k, v)
    return cp


# ---------------------------------------------------------------- lifecycle
class TestLifecycle:
    def test_add_after_commit_raises(self, env):
        cp = make_cp("c", env, {"x": Box(1)})
        cp.commit()
        with pytest.raises(CheckpointError, match="committed"):
            cp.add("y", Box(2))

    def test_write_before_commit_raises(self, env):
        cp = make_cp("c", env, {"x": Box(1)})
        with pytest.raises(CheckpointError, match="commit"):
            cp.update_and_write()

    def test_empty_commit_raises(self, env):
        with pytest.raises(CheckpointError, match="no data"):
            Checkpoint("c", env=env).commit()

    def test_duplicate_key_raises(self, env):
        cp = make_cp("c", env, {"x": Box(1)})
        with pytest.raises(CheckpointError, match="duplicate"):
            cp.add("x", Box(2))

    def test_bad_names_raise(self, env):
        with pytest.raises(ValueError):
            Checkpoint("a/b", env=env)
        cp = Checkpoint("ok", env=env)
        with pytest.raises(ValueError):
            cp.add("k/ey", Box(1))

    def test_immutable_pod_needs_box(self, env):
        cp = Checkpoint("c", env=env)
        with pytest.raises(TypeError, match="Box"):
            cp.add("x", 3)
        with pytest.raises(TypeError, match="Box"):
            cp.add("x", jnp.zeros((2,)))


# ------------------------------------------------------------ round-tripping
class TestRoundTrip:
    def test_pod_types(self, env):
        boxes = {
            "i": Box(42), "f": Box(3.25), "c": Box(1 + 2j),
            "b": Box(True), "s": Box("craft"),
        }
        cp = make_cp("pods", env, boxes)
        cp.commit()
        cp.update_and_write()

        boxes2 = {k: Box(type(b.value)()) for k, b in boxes.items()}
        cp2 = make_cp("pods", env, boxes2)
        cp2.commit()
        assert cp2.restart_if_needed()
        for k in boxes:
            assert boxes2[k].value == boxes[k].value, k

    def test_ndarray_in_place(self, env, rng):
        arr = rng.standard_normal((7, 5))
        ref = arr.copy()
        cp = make_cp("nd", env, {"a": arr})
        cp.commit()
        cp.update_and_write()

        arr2 = np.zeros_like(arr)
        cp2 = make_cp("nd", env, {"a": arr2})
        cp2.commit()
        assert cp2.restart_if_needed()
        np.testing.assert_array_equal(arr2, ref)

    def test_multiarray_column(self, env, rng):
        arr = rng.standard_normal((6, 4))
        cp = make_cp("col", env)
        cp.add("a", arr, to_cp_col=2)
        cp.commit()
        cp.update_and_write()

        arr2 = np.zeros_like(arr)
        cp2 = make_cp("col", env)
        cp2.add("a", arr2, to_cp_col=2)
        cp2.commit()
        assert cp2.restart_if_needed()
        np.testing.assert_array_equal(arr2[:, 2], arr[:, 2])
        assert np.all(arr2[:, [0, 1, 3]] == 0)   # only the column was saved

    def test_jax_array(self, env):
        x = jnp.arange(24, dtype=jnp.float32).reshape(4, 6) * 1.5
        box = Box(x)
        cp = make_cp("jx", env, {"x": box})
        cp.commit()
        cp.update_and_write()

        box2 = Box(jnp.zeros_like(x))
        cp2 = make_cp("jx", env, {"x": box2})
        cp2.commit()
        assert cp2.restart_if_needed()
        np.testing.assert_array_equal(np.asarray(box2.value), np.asarray(x))

    def test_jax_bfloat16(self, env):
        x = jnp.asarray([[1.5, -2.25], [0.125, 7.0]], jnp.bfloat16)
        box = Box(x)
        cp = make_cp("bf", env, {"x": box})
        cp.commit()
        cp.update_and_write()
        box2 = Box(jnp.zeros_like(x))
        cp2 = make_cp("bf", env, {"x": box2})
        cp2.commit()
        assert cp2.restart_if_needed()
        np.testing.assert_array_equal(
            np.asarray(box2.value, np.float32), np.asarray(x, np.float32))

    def test_pytree(self, env, rng):
        tree = {"w": jnp.asarray(rng.standard_normal((3, 3)), jnp.float32),
                "b": np.arange(3.0), "meta": {"step": 11, "name": "x"}}
        box = Box(tree)
        cp = make_cp("tree", env, {"t": box})
        cp.commit()
        cp.update_and_write()

        blank = {"w": jnp.zeros((3, 3)), "b": np.zeros(3),
                 "meta": {"step": 0, "name": ""}}
        box2 = Box(blank)
        cp2 = make_cp("tree", env, {"t": box2})
        cp2.commit()
        assert cp2.restart_if_needed()
        assert box2.value["meta"] == {"step": 11, "name": "x"}
        np.testing.assert_allclose(np.asarray(box2.value["w"]),
                                   np.asarray(tree["w"]))

    def test_shape_mismatch_raises(self, env, rng):
        arr = rng.standard_normal((4, 4))
        cp = make_cp("mm", env, {"a": arr})
        cp.commit()
        cp.update_and_write()
        cp2 = make_cp("mm", env, {"a": np.zeros((5, 5))})
        cp2.commit()
        with pytest.raises(CheckpointError):
            cp2.restart_if_needed()


# ---------------------------------------------------------------- versioning
class TestVersions:
    def test_freq_gate(self, env):
        b = Box(0)
        cp = make_cp("fr", env, {"x": b})
        cp.commit()
        wrote = [cp.update_and_write(i, cp_freq=10) for i in range(1, 31)]
        assert sum(wrote) == 3
        assert cp.version == 3

    def test_latest_version_wins(self, env):
        b = Box(0)
        cp = make_cp("v", env, {"x": b})
        cp.commit()
        for i in range(1, 4):
            b.value = i * 100
            cp.update_and_write()

        b2 = Box(-1)
        cp2 = make_cp("v", env, {"x": b2})
        cp2.commit()
        assert cp2.restart_if_needed()
        assert b2.value == 300
        assert cp2.version == 3

    def test_retention(self, env):
        b = Box(0)
        cp = make_cp("keep", env, {"x": b})
        cp.commit()
        for i in range(5):
            cp.update_and_write()
        vdirs = sorted((Path(env.cp_path) / "keep").glob("v-*"))
        assert len(vdirs) <= env.keep_versions

    def test_restart_skips_when_disabled(self, tmp_path):
        env1 = CraftEnv.capture({"CRAFT_CP_PATH": str(tmp_path)})
        b = Box(7)
        cp = make_cp("d", env1, {"x": b})
        cp.commit()
        cp.update_and_write()

        env2 = CraftEnv.capture({
            "CRAFT_CP_PATH": str(tmp_path),
            "CRAFT_READ_CP_ON_RESTART": "0",
        })
        b2 = Box(-1)
        cp2 = make_cp("d", env2, {"x": b2})
        cp2.commit()
        assert not cp2.restart_if_needed()
        assert b2.value == -1

    def test_craft_enable_off_is_noop(self, tmp_path):
        env0 = CraftEnv.capture({
            "CRAFT_CP_PATH": str(tmp_path), "CRAFT_ENABLE": "0"})
        b = Box(1)
        cp = make_cp("off", env0, {"x": b})
        cp.commit()
        assert not cp.update_and_write()
        assert not any(Path(tmp_path).glob("off/v-*"))


# ----------------------------------------------------------------- async
class TestAsync:
    def _env(self, tmp_path, **extra):
        return CraftEnv.capture({
            "CRAFT_CP_PATH": str(tmp_path), "CRAFT_USE_SCR": "0", **extra})

    def test_async_copy_mode(self, tmp_path):
        env = self._env(tmp_path, CRAFT_WRITE_ASYNC="1")
        arr = np.ones((256, 256))
        cp = make_cp("as", env, {"a": arr})
        cp.commit()
        cp.update_and_write()
        # mutate immediately — the copy-based snapshot must be isolated
        arr[:] = -1.0
        cp.wait()
        arr2 = np.zeros_like(arr)
        cp2 = make_cp("as", CraftEnv.capture(
            {"CRAFT_CP_PATH": str(tmp_path), "CRAFT_USE_SCR": "0"}),
            {"a": arr2})
        cp2.commit()
        assert cp2.restart_if_needed()
        assert np.all(arr2 == 1.0)     # pre-mutation snapshot was written
        cp.close()

    def test_zero_copy_needs_wait(self, tmp_path):
        env = self._env(tmp_path, CRAFT_WRITE_ASYNC="1",
                        CRAFT_WRITE_ASYNC_ZERO_COPY="1")
        b = Box(123)
        cp = make_cp("zc", env, {"x": b})
        cp.commit()
        cp.update_and_write()
        cp.wait()                       # paper's fence before mutation
        b.value = 456
        b2 = Box(0)
        cp2 = make_cp("zc", CraftEnv.capture(
            {"CRAFT_CP_PATH": str(tmp_path), "CRAFT_USE_SCR": "0"}),
            {"x": b2})
        cp2.commit()
        assert cp2.restart_if_needed()
        assert b2.value == 123
        cp.close()

    def test_async_many_versions_ordered(self, tmp_path):
        env = self._env(tmp_path, CRAFT_WRITE_ASYNC="1")
        b = Box(0)
        cp = make_cp("seq", env, {"x": b})
        cp.commit()
        for i in range(1, 8):
            b.value = i
            cp.update_and_write()
        cp.wait()
        cp.close()
        b2 = Box(-1)
        cp2 = make_cp("seq", CraftEnv.capture(
            {"CRAFT_CP_PATH": str(tmp_path), "CRAFT_USE_SCR": "0"}),
            {"x": b2})
        cp2.commit()
        assert cp2.restart_if_needed()
        assert b2.value == 7


# ------------------------------------------------------------ extension API
class rectDomain:
    """Paper Listing 3's example class."""

    def __init__(self, length, width):
        self.length = length
        self.width = width
        self.val = np.zeros(length * width)


class CpRectDomain(CpBase):
    """Paper Listing 4's wrapper (read/write/update of an opaque class)."""

    def __init__(self, dom: rectDomain):
        self.dom = dom
        self._buf = dom.val.copy()

    def update(self):
        np.copyto(self._buf, self.dom.val)

    def write(self, dir_path, ctx):
        from repro.core import storage  # noqa: F401
        from repro.core.storage import write_array, write_json
        write_json(dir_path / "dims.json",
                   {"l": self.dom.length, "w": self.dom.width})
        write_array(dir_path / "val.bin", self._buf, ctx)

    def read(self, dir_path, ctx):
        from repro.core.storage import read_array, read_json
        dims = read_json(dir_path / "dims.json")
        assert (dims["l"], dims["w"]) == (self.dom.length, self.dom.width)
        self.dom.val[...] = read_array(dir_path / "val.bin", ctx)

    def nbytes(self):
        return self._buf.nbytes


class TestExtension:
    def test_cpbase_wrapper(self, env):
        dom = rectDomain(3, 4)
        dom.val[:] = np.arange(12.0)
        cp = Checkpoint("rect", env=env)
        cp.add("dom", CpRectDomain(dom))
        cp.commit()
        cp.update_and_write()

        dom2 = rectDomain(3, 4)
        cp2 = Checkpoint("rect", env=env)
        cp2.add("dom", CpRectDomain(dom2))
        cp2.commit()
        assert cp2.restart_if_needed()
        np.testing.assert_array_equal(dom2.val, np.arange(12.0))

    def test_register_adapter(self, env):
        from repro.core.checkpointables import register_adapter

        class Handle:
            def __init__(self, v):
                self.v = v

        register_adapter(
            lambda o: isinstance(o, Handle),
            lambda o: __import__(
                "repro.core.checkpointables", fromlist=["FuncCp"]
            ).FuncCp(lambda: o.v, lambda nv: setattr(o, "v", nv)))
        h = Handle(5)
        cp = Checkpoint("h", env=env)
        cp.add("h", h)
        cp.commit()
        cp.update_and_write()
        h2 = Handle(0)
        cp2 = Checkpoint("h", env=env)
        cp2.add("h", h2)
        cp2.commit()
        assert cp2.restart_if_needed()
        assert h2.v == 5


# ------------------------------------------------------------ integrity
class TestIntegrity:
    def test_corruption_detected(self, env_pfs_only, rng):
        env = env_pfs_only
        arr = rng.standard_normal((64,))
        cp = make_cp("cor", env, {"a": arr})
        cp.commit()
        cp.update_and_write()
        # flip bytes in the stored payload
        (bin_file,) = (Path(env.cp_path) / "cor" / "v-1" / "a").glob("*.bin")
        raw = bytearray(bin_file.read_bytes())
        raw[-8] ^= 0xFF
        bin_file.write_bytes(bytes(raw))

        cp2 = make_cp("cor", env, {"a": np.zeros(64)})
        cp2.commit()
        with pytest.raises(CheckpointError):
            cp2.restart_if_needed()

    def test_torn_tmp_dir_swept(self, env_pfs_only):
        env = env_pfs_only
        b = Box(1)
        cp = make_cp("torn", env, {"x": b})
        cp.commit()
        cp.update_and_write()
        fake = Path(env.cp_path) / "torn" / ".tmp-v-9-deadbeef"
        fake.mkdir(parents=True)
        (fake / "junk").write_text("x")
        cp2 = make_cp("torn", env, {"x": Box(0)})
        cp2.commit()
        assert cp2.restart_if_needed()
        assert cp2.version == 1
