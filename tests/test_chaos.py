"""Chaos matrix: every injectable storage fault class, on every tier, must
leave the checkpoint either recovered (retry/degrade/re-admit) or cleanly
failed — never serving stale or torn bytes.

Covers the tentpole subsystem of the robustness PR:

* transient faults (EIO / torn write / stall) × {node, pfs} × codecs
  v0/v1/v2 — absorbed by the retry layer, restore bit-identical;
* a persistent PFS outage mid-run — the circuit breaker trips, writes
  degrade to the node tier, the fault clearing re-admits the PFS with a
  forced *full* (non-delta) write, and the final restore is bit-identical;
* ``ENOSPC`` — one emergency retention squeeze frees space and the write
  lands;
* crash-at-point — the staging dir survives (like a real process death),
  is swept on the next start, and the previous version restores;
* hang + ``CRAFT_IO_DEADLINE_S`` — the hung tier write is abandoned, the
  version lands on the remaining tier, the job is not wedged;
* seeded replay determinism — same spec + seed ⇒ identical injection log.
"""
import numpy as np
import pytest

from repro.core import Checkpoint
from repro.core.chaos import ChaosCrash, ChaosEngine, parse_chaos_spec
from repro.core.env import CraftEnv
from repro.core.health import CircuitBreaker


def _env(tmp_path, **extra):
    envmap = {
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_NODE_CP_PATH": str(tmp_path / "node"),
        "CRAFT_IO_BACKOFF_MS": "1",
        **{k: str(v) for k, v in extra.items()},
    }
    return CraftEnv.capture(envmap)


def _mk(tmp_path, arr, name="cx", **extra):
    cp = Checkpoint(name, env=_env(tmp_path, **extra))
    cp.add("arr", arr)
    cp.commit()
    return cp


def _restore(tmp_path, shape, name="cx", **extra):
    out = np.zeros(shape)
    cp = _mk(tmp_path, out, name=name, **extra)
    assert cp.restart_if_needed()
    cp.close()
    return out, cp


# ---------------------------------------------------------------- spec layer
def test_spec_parsing_and_validation():
    rules = parse_chaos_spec("pfs:eio:p=0.05,node:stall:ms=500")
    assert [(r.slot, r.fault) for r in rules] == \
        [("pfs", "eio"), ("node", "stall")]
    assert rules[0].p == 0.05 and rules[1].ms == 500.0
    r = parse_chaos_spec("*:erofs:p=1+after=4+count=2")[0]
    assert (r.slot, r.after, r.count) == ("*", 4, 2)
    assert parse_chaos_spec("on") == [] and parse_chaos_spec("") == []
    for bad in ("pfs", "pfs:frobnicate", "disk:eio", "pfs:eio:p=2",
                "pfs:stall", "pfs:eio:wat=1", "pfs:eio:p"):
        with pytest.raises(ValueError):
            parse_chaos_spec(bad)


def test_env_validates_chaos_spec_eagerly(tmp_path):
    with pytest.raises(ValueError):
        _env(tmp_path, CRAFT_CHAOS="pfs:frobnicate")
    assert _env(tmp_path, CRAFT_CHAOS="pfs:eio:p=0.5").chaos


def test_replay_determinism():
    """Same spec + seed ⇒ bit-identical injection schedule."""
    def drive(engine):
        for i in range(200):
            slot = ("pfs", "node", "mem")[i % 3]
            try:
                engine.check(slot, "write", nbytes=i)
            except OSError:
                pass
        return list(engine.log)

    spec = "pfs:eio:p=0.2,node:eio:p=0.1+after=20"
    a = drive(ChaosEngine(spec, seed=7))
    b = drive(ChaosEngine(spec, seed=7))
    assert a == b and a                      # identical and non-empty
    c = drive(ChaosEngine(spec, seed=8))
    assert a != c                            # the seed matters


# ------------------------------------------------------------- breaker layer
def test_circuit_breaker_state_machine():
    t = [0.0]
    br = CircuitBreaker(threshold=2, cooldown_s=10.0, clock=lambda: t[0])
    assert br.allow() and br.state == "closed"
    assert not br.record_failure()           # 1/2
    assert br.record_failure()               # 2/2 -> trips
    assert br.state == "open" and not br.allow()
    t[0] = 5.0
    assert not br.allow()                    # cooldown not elapsed
    t[0] = 10.0
    assert br.allow() and br.state == "half_open"
    assert not br.allow()                    # single probe admitted
    assert br.record_failure()               # failed probe -> re-opens
    assert br.state == "open"
    t[0] = 20.0
    assert br.allow()                        # next probe window
    br.record_success()
    assert br.state == "closed" and br.allow()


# -------------------------------------------------------- transient recovery
@pytest.mark.parametrize("tier", ["node", "pfs"])
@pytest.mark.parametrize("codec", [0, 1, 2])
@pytest.mark.parametrize("fault", ["eio:count=2", "torn:count=1",
                                   "stall:ms=10+count=2"])
def test_transient_fault_matrix(tmp_path, tier, codec, fault):
    """Each transient fault class × each disk tier × each codec: the retry
    layer absorbs the fault and the restore is bit-identical."""
    arr = np.arange(512, dtype=np.float64)
    kw = dict(CRAFT_CODEC_VERSION=codec, CRAFT_CHAOS="on",
              CRAFT_IO_RETRIES=3)
    if codec == 2:
        kw["CRAFT_DELTA"] = 1
    cp = _mk(tmp_path, arr, **kw)
    arr[...] = 1.25
    assert cp.update_and_write()
    cp.chaos.add(f"{tier}:{fault}")
    arr[...] = 2.5
    assert cp.update_and_write()
    st = dict(cp.stats)
    cp.close()
    if "stall" not in fault:
        assert st["retries"] >= 1, st
    assert st["degraded_writes"] == 0        # absorbed, not degraded
    out, cp2 = _restore(tmp_path, arr.shape, **dict(kw, CRAFT_CHAOS=""))
    assert cp2.version == 2
    np.testing.assert_array_equal(out, np.full(arr.shape, 2.5))


def test_read_side_transient_fault_retries(tmp_path):
    arr = np.arange(256, dtype=np.float32)
    cp = _mk(tmp_path, arr)
    arr[...] = 9.0
    assert cp.update_and_write()
    cp.close()
    out = np.zeros(arr.shape, dtype=np.float32)
    cp2 = _mk(tmp_path, out, CRAFT_CHAOS="node:eio:count=1+op=read,"
                                         "pfs:eio:count=1+op=read",
              CRAFT_IO_RETRIES=2)
    assert cp2.restart_if_needed()
    assert cp2.stats["retries"] >= 1
    cp2.close()
    np.testing.assert_array_equal(out, np.full(arr.shape, 9.0, np.float32))


# --------------------------------------------- persistent outage + breaker
def test_pfs_outage_degrades_then_readmits_with_full_write(tmp_path):
    """The acceptance scenario: a persistent PFS outage mid-run — training
    keeps checkpointing to the node tier, the breaker re-admits the PFS
    after the fault clears with a forced full (non-delta) write, and the
    final restore is bit-identical."""
    from repro.core import storage, tiers

    arr = np.arange(1024, dtype=np.float64)
    cp = _mk(tmp_path, arr, CRAFT_CHAOS="on", CRAFT_DELTA=1,
             CRAFT_BREAKER_THRESHOLD=1, CRAFT_BREAKER_COOLDOWN_S=0,
             CRAFT_IO_RETRIES=0)
    arr[...] = 1.0
    assert cp.update_and_write()             # v1 lands everywhere
    pfs = storage.VersionStore(cp.env.cp_path, "cx", sweep=False)
    assert pfs.latest_version() == 1

    cp.chaos.add("pfs:erofs:p=1")            # the PFS goes read-only
    for val in (2.0, 3.0, 4.0):
        arr[...] = val
        assert cp.update_and_write()         # training continues
    assert cp.stats["breaker_trips"] >= 1
    assert cp.stats["degraded_writes"] >= 2
    assert cp.health["pfs"].state == "open"
    assert pfs.latest_version() == 1         # nothing crossed the outage
    assert cp.stats["node_writes"] == 4      # node tier kept every version

    # mid-outage restore: served by the node tier, bit-identical
    out, cp_mid = _restore(tmp_path, arr.shape, CRAFT_DELTA=1)
    assert cp_mid.version == 4
    assert cp_mid.stats["restore_tier"] == "node"
    np.testing.assert_array_equal(out, np.full(arr.shape, 4.0))

    cp.chaos.clear("pfs")                    # the outage ends
    arr[...] = 5.0
    assert cp.update_and_write()             # re-admission write
    assert cp.health["pfs"].state == "closed"
    assert pfs.latest_version() == 5
    # forced full: the re-admission version is self-contained — no delta
    # deps recorded, no ref chunks pointing across the outage
    vdir = pfs.version_dir(5)
    assert not tiers.read_delta_deps(vdir)
    for p in sorted(q for q in vdir.rglob("*.bin")):
        mf = storage.read_chunk_manifest(p)
        if mf is not None:
            assert all("ref" not in c for c in mf["chunks"]), p
    cp.close()

    out5, cp5 = _restore(tmp_path, arr.shape, CRAFT_DELTA=1)
    assert cp5.version == 5
    np.testing.assert_array_equal(out5, np.full(arr.shape, 5.0))


def test_readmission_rides_a_cheap_probe_not_the_version_write(tmp_path):
    """While the outage persists, a past-cooldown attempt costs exactly one
    metadata touch (the half-open probe) — the full version write is never
    gambled on a tier the probe just saw fail."""
    import time as _time

    from repro.core import storage

    arr = np.arange(256, dtype=np.float64)
    cp = _mk(tmp_path, arr, CRAFT_CHAOS="on", CRAFT_IO_RETRIES=0,
             CRAFT_BREAKER_THRESHOLD=1, CRAFT_BREAKER_COOLDOWN_S=0.05)
    arr[...] = 1.0
    assert cp.update_and_write()
    cp.chaos.add("pfs:erofs:p=1")
    arr[...] = 2.0
    assert cp.update_and_write()             # trips
    assert cp.health["pfs"].state == "open"

    _time.sleep(0.1)                         # cooldown elapses, fault persists
    ops_before = cp.chaos.op_count("pfs", "write")
    arr[...] = 3.0
    assert cp.update_and_write()
    assert cp.chaos.op_count("pfs", "write") - ops_before == 1
    assert cp.health["pfs"].state == "open"  # failed probe re-opened it
    pfs = storage.VersionStore(cp.env.cp_path, "cx", sweep=False)
    assert pfs.latest_version() == 1

    cp.chaos.clear("pfs")
    _time.sleep(0.1)
    arr[...] = 4.0
    assert cp.update_and_write()             # probe re-closes, write lands
    assert cp.health["pfs"].state == "closed"
    assert pfs.latest_version() == 4
    cp.close()


def test_degraded_tier_stays_on_policy_radar(tmp_path):
    """A write routed away from a tier must not satisfy that tier's cadence:
    the slot stays due until a write actually lands on it."""
    arr = np.arange(64, dtype=np.float32)
    cp = _mk(tmp_path, arr, CRAFT_CHAOS="on",
             CRAFT_BREAKER_THRESHOLD=1, CRAFT_BREAKER_COOLDOWN_S=0,
             CRAFT_TIER_EVERY="node:1,pfs:4", CRAFT_IO_RETRIES=0)
    cp.chaos.add("pfs:erofs:p=1")
    for it in range(1, 9):
        arr[...] = it
        cp.update_and_write(it)
    # pfs was scheduled at ticks 4 and 8, degraded both times, and stayed
    # owed at every opportunity in between
    assert "pfs" in cp.policy.degraded_slots()
    assert cp.stats["degraded_writes"] >= 2
    cp.chaos.clear("pfs")
    arr[...] = 99.0
    cp.update_and_write(9)                   # owed slot fires immediately
    assert cp.policy.degraded_slots() == ()
    from repro.core import storage
    assert storage.VersionStore(cp.env.cp_path, "cx",
                                sweep=False).latest_version() == cp.version
    cp.close()


def test_mem_tier_fault_degrades_to_disk(tmp_path):
    """A faulty RAM fabric degrades writes down the chain instead of
    failing the job; restore falls through to the disk tiers."""
    arr = np.arange(128, dtype=np.float64)
    cp = _mk(tmp_path, arr, CRAFT_TIER_CHAIN="mem,node,pfs",
             CRAFT_CHAOS="on", CRAFT_BREAKER_THRESHOLD=1,
             CRAFT_BREAKER_COOLDOWN_S=3600, CRAFT_IO_RETRIES=0)
    cp.chaos.add("mem:eio:p=1+op=fabric")
    arr[...] = 7.5
    assert cp.update_and_write()
    assert cp.stats["degraded_writes"] >= 1
    assert cp.stats["mem_writes"] == 0
    assert cp.stats["node_writes"] == 1      # the payload still landed
    assert cp.health["mem"].state == "open"
    cp.close()
    out, cp2 = _restore(tmp_path, arr.shape, CRAFT_TIER_CHAIN="mem,node,pfs")
    assert cp2.stats["restore_tier"] in ("node", "pfs")
    np.testing.assert_array_equal(out, np.full(arr.shape, 7.5))


# ---------------------------------------------------------------- ENOSPC
def test_enospc_triggers_emergency_retire_and_retries(tmp_path):
    arr = np.arange(256, dtype=np.float64)
    cp = _mk(tmp_path, arr, CRAFT_CHAOS="on", CRAFT_IO_RETRIES=0,
             CRAFT_USE_SCR=0, CRAFT_KEEP_VERSIONS=3)
    for val in (1.0, 2.0):
        arr[...] = val
        assert cp.update_and_write()         # two retire-eligible versions
    cp.chaos.add("pfs:enospc:count=1")
    arr[...] = 3.0
    assert cp.update_and_write()             # retire freed space, retry landed
    assert cp.stats["enospc_retires"] == 1
    assert cp.stats["degraded_writes"] == 0
    from repro.core import storage
    store = storage.VersionStore(cp.env.cp_path, "cx", sweep=False)
    assert store.latest_version() == 3
    assert not store.version_dir(1).is_dir()  # v1 was sacrificed
    cp.close()
    out, _ = _restore(tmp_path, arr.shape, CRAFT_USE_SCR=0)
    np.testing.assert_array_equal(out, np.full(arr.shape, 3.0))


# ----------------------------------------------------------- crash-at-point
@pytest.mark.parametrize("codec", [0, 1])
def test_crash_at_point_leaves_previous_version_restorable(tmp_path, codec):
    """A simulated process death mid-write: the staging dir survives (no
    in-process cleanup, like a real crash), the next start sweeps it, and
    the previous version restores bit-identically."""
    arr = np.arange(512, dtype=np.float64)
    kw = dict(CRAFT_CODEC_VERSION=codec, CRAFT_CHAOS="on", CRAFT_USE_SCR=0)
    cp = _mk(tmp_path, arr, **kw)
    arr[...] = 1.0
    assert cp.update_and_write()             # v1 lands cleanly
    nxt = cp.chaos.op_count("pfs", "write")
    cp.chaos.add(f"pfs:crash:at={nxt}")      # die on the very next file write
    arr[...] = 2.0
    with pytest.raises(ChaosCrash):
        cp.update_and_write()
    root = cp.env.cp_path / "cx"
    assert list(root.glob(".tmp-*"))         # staging survives the "death"

    out = np.zeros(arr.shape)
    cp2 = _mk(tmp_path, out, **dict(kw, CRAFT_CHAOS=""))
    assert cp2.restart_if_needed()
    assert cp2.version == 1
    assert not list(root.glob(".tmp-*"))     # swept on start
    np.testing.assert_array_equal(out, np.full(arr.shape, 1.0))
    cp2.close()


def test_all_tiers_down_raises_and_serves_no_stale_bytes(tmp_path):
    """When every tier fails the write, the caller sees the error, the
    version counter does not advance, and a restore still serves the last
    complete version — never torn or stale bytes."""
    arr = np.arange(256, dtype=np.float64)
    cp = _mk(tmp_path, arr, CRAFT_CHAOS="on", CRAFT_USE_SCR=0,
             CRAFT_IO_RETRIES=1)
    arr[...] = 1.0
    assert cp.update_and_write()
    cp.chaos.add("pfs:torn:p=1")             # every attempt tears
    arr[...] = 2.0
    with pytest.raises(OSError):
        cp.update_and_write()
    assert cp.version == 1                   # did not advance
    assert cp.stats["retries"] >= 1
    cp.close()
    out, cp2 = _restore(tmp_path, arr.shape, CRAFT_USE_SCR=0)
    assert cp2.version == 1
    np.testing.assert_array_equal(out, np.full(arr.shape, 1.0))


# -------------------------------------------------------- hang + deadline
def test_hung_write_is_abandoned_not_wedged(tmp_path):
    """An indefinite hang on the node tier is cut off by the write deadline:
    the version lands on the PFS, ``abandoned_writes`` counts it, and the
    async fence returns instead of wedging."""
    arr = np.arange(128, dtype=np.float64)
    cp = _mk(tmp_path, arr, CRAFT_CHAOS="on", CRAFT_WRITE_ASYNC=1,
             CRAFT_IO_DEADLINE_S=0.5, CRAFT_IO_RETRIES=0,
             CRAFT_BREAKER_THRESHOLD=1, CRAFT_BREAKER_COOLDOWN_S=3600)
    cp.chaos.add("node:hang:count=1")
    arr[...] = 4.0
    assert cp.update_and_write()
    cp.wait()                                # returns: the hang was abandoned
    assert cp.stats["abandoned_writes"] == 1
    assert cp.stats["degraded_writes"] >= 1
    from repro.core import storage
    assert storage.VersionStore(cp.env.cp_path, "cx",
                                sweep=False).latest_version() == 1
    cp.close()                               # releases the parked hang
    out, _ = _restore(tmp_path, arr.shape)
    np.testing.assert_array_equal(out, np.full(arr.shape, 4.0))


# ------------------------------------------------------------- async context
def test_async_failure_carries_version_and_tier_context(tmp_path):
    """An async write failure surfaced at the fence names the tier, version
    and array that died (satellite: no more context-free late errors)."""
    from repro.core.cpbase import CheckpointError

    arr = np.arange(64, dtype=np.float64)
    cp = _mk(tmp_path, arr, CRAFT_CHAOS="on", CRAFT_WRITE_ASYNC=1,
             CRAFT_USE_SCR=0, CRAFT_IO_RETRIES=0)
    arr[...] = 1.0
    assert cp.update_and_write()
    cp.wait()
    cp.chaos.add("pfs:eio:p=1")
    arr[...] = 2.0
    assert cp.update_and_write()
    with pytest.raises(OSError, match=r"pfs tier v-2 array 'arr'"):
        cp.wait()
    cp.close()
