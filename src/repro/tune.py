"""``python -m repro.tune`` — the ``craft tune`` CLI.

Record a run with ``CRAFT_TRACE=run.jsonl``, then::

    python -m repro.tune --trace run.jsonl --json BENCH_tune.json

prints the recommended ``CRAFT_*`` env block and writes a scorecard
artifact in the shared ``BENCH_*.json`` record shape (``benchmarks/
common.py``).  ``--fail-on-regression`` exits non-zero if the recommended
config's simulated overhead exceeds the as-run config's — the CI
``tune-smoke`` job's end-to-end invariant.  See ``docs/tuning.md``.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core.simulate import load_trace, summarize
from repro.core.tune import recommend_env_block, tune


def _records(result: dict) -> list:
    """The scorecard as BENCH_*.json records (bench/name/value/unit rows)."""
    rows = []

    def emit(name, value, unit, **extra):
        rows.append({"bench": "craft_tune", "name": name, "value": value,
                     "unit": unit, **extra})

    for side in ("as_run", "recommended"):
        rep = result[side]
        emit(f"{side}_overhead", rep["overhead_seconds"], "s",
             config=rep["overrides"] or "as-run")
        emit(f"{side}_overhead_fraction", rep["overhead_fraction"], "ratio")
        emit(f"{side}_writes", rep["writes"], "count")
        emit(f"{side}_failures", rep["failures"], "count")
    emit("improvement", result["improvement_pct"], "%")
    emit("evaluations", result["evaluations"], "count")
    emit("mtbf", result["mtbf_seconds"], "s")
    emit("mean_step", result["mean_step_seconds"], "s")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="Auto-tune CRAFT checkpoint policy knobs from a "
                    "CRAFT_TRACE recording.")
    ap.add_argument("--trace", required=True,
                    help="JSONL trace recorded with CRAFT_TRACE=<path>")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the scorecard as BENCH-style JSON records")
    ap.add_argument("--seed", type=int, default=0,
                    help="failure-stream seed (default 0; deterministic)")
    ap.add_argument("--horizon", type=int, default=None,
                    help="simulated steps per candidate (default: "
                         "max(1000, 2x recorded))")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 if the recommendation scores worse than "
                         "the as-run config")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the human-readable report")
    args = ap.parse_args(argv)

    events = load_trace(args.trace)
    summary = summarize(events)
    result = tune(summary, seed=args.seed, horizon_steps=args.horizon)

    if not args.quiet:
        rec, base = result["recommended"], result["as_run"]
        print(f"trace: {args.trace} ({len(events)} events, "
              f"mtbf {result['mtbf_seconds']}s, "
              f"step {result['mean_step_seconds']}s)")
        print(f"as-run     : overhead {base['overhead_seconds']}s "
              f"({100 * base['overhead_fraction']:.2f}% of compute), "
              f"{base['writes']} writes, {base['failures']} failures")
        print(f"recommended: overhead {rec['overhead_seconds']}s "
              f"({100 * rec['overhead_fraction']:.2f}% of compute), "
              f"{rec['writes']} writes, {rec['failures']} failures")
        print(f"improvement: {result['improvement_pct']}% "
              f"({result['evaluations']} configs simulated)")
        print()
        print(recommend_env_block(result))

    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump(_records(result), fh, indent=1)
        if not args.quiet:
            print(f"\nwrote scorecard to {args.json_out}")

    if args.fail_on_regression and (
            result["recommended"]["overhead_seconds"]
            > result["as_run"]["overhead_seconds"] + 1e-9):
        print("REGRESSION: recommended config scores worse than as-run",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
