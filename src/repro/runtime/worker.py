"""Worker process bootstrap + ProcComm (the worker-side FTComm).

Each worker connects to the coordinator socket, announces itself (rank,
epoch, replacement flag), and then runs the user function ``fn(comm)``.
``ProcComm`` is thread-safe: a receiver thread demultiplexes replies by
request id so the application's main thread and the checkpoint writer
thread can have RPCs in flight concurrently; a heartbeat thread keeps the
coordinator's staleness monitor fed.
"""
from __future__ import annotations

import itertools
import os
import queue
import threading
import traceback
from collections import defaultdict
from multiprocessing.connection import Client
from typing import Dict, List, Optional

from repro.core.comm import FTComm, ProcFailedError, RevokedError

_AUTHKEY = b"craft-cluster"


class CoordinatorLostError(RuntimeError):
    """The coordinator connection died — the job is over for this worker."""


class ProcComm(FTComm):
    def __init__(self, address: str, rank: int, node: int, eid: int,
                 replacement: bool, recovery_policy: str = "NON-SHRINKING",
                 size: Optional[int] = None, hb_interval: float = 0.2):
        self._conn = Client(address, family="AF_UNIX", authkey=_AUTHKEY)
        self._send_lock = threading.Lock()
        self._ids = itertools.count(1)
        self._waiters: Dict[int, "queue.Queue"] = {}
        self._waiters_lock = threading.Lock()
        self._closed = threading.Event()
        self._rank = rank
        self._node = node
        self._eid = eid
        self._size = size
        self._replacement = replacement
        self._recovery_policy = recovery_policy
        self._seq = defaultdict(int)
        self._last_recovery: dict = {}
        self._recv_thread = threading.Thread(
            target=self._recv_loop, name="craft-rpc-recv", daemon=True
        )
        self._recv_thread.start()
        hello = self._rpc(
            {"op": "hello", "rank": rank, "eid": eid, "replacement": replacement}
        )
        self._ppn = hello["ppn"]
        if hb_interval:
            threading.Thread(
                target=self._hb_loop, args=(hb_interval,),
                name="craft-hb", daemon=True,
            ).start()

    # -------------------------------------------------------------- transport
    def _recv_loop(self) -> None:
        try:
            while not self._closed.is_set():
                msg = self._conn.recv()
                with self._waiters_lock:
                    q = self._waiters.pop(msg.get("id"), None)
                if q is not None:
                    q.put(msg)
        except (EOFError, OSError):
            self._closed.set()
            with self._waiters_lock:
                for q in self._waiters.values():
                    q.put({"err": ("coordinator_lost", None)})
                self._waiters.clear()

    def _hb_loop(self, interval: float) -> None:
        while not self._closed.is_set():
            try:
                with self._send_lock:
                    self._conn.send({"op": "hb"})
            except (OSError, BrokenPipeError):
                return
            self._closed.wait(interval)

    def _rpc(self, msg: dict):
        if self._closed.is_set():
            raise CoordinatorLostError()
        mid = next(self._ids)
        msg["id"] = mid
        q: "queue.Queue" = queue.Queue()
        with self._waiters_lock:
            self._waiters[mid] = q
        with self._send_lock:
            self._conn.send(msg)
        reply = q.get()
        if "ok" in reply:
            return reply["ok"]
        kind, info = reply["err"]
        if kind == "proc_failed":
            raise ProcFailedError(failed=info)
        if kind == "revoked":
            raise RevokedError()
        if kind == "coordinator_lost":
            raise CoordinatorLostError()
        raise RuntimeError(f"coordinator error: {kind}: {info}")

    def _next_seq(self, channel: str) -> int:
        key = (self._eid, channel)
        s = self._seq[key]
        self._seq[key] = s + 1
        return s

    # -------------------------------------------------------------- identity
    @property
    def rank(self) -> int:
        return self._rank

    @property
    def size(self) -> int:
        return self._size

    @property
    def epoch(self) -> int:
        return self._eid

    def node_id(self) -> int:
        return self._node

    def procs_per_node(self) -> int:
        return self._ppn

    # ------------------------------------------------------------ collectives
    def barrier(self, channel: str = "main") -> None:
        self._rpc({"op": "barrier", "rank": self._rank, "eid": self._eid,
                   "channel": channel, "seq": self._next_seq(channel)})

    def allreduce(self, value, op: str = "sum", channel: str = "main"):
        return self._rpc({"op": "allreduce", "reduce": op, "value": value,
                          "rank": self._rank, "eid": self._eid,
                          "channel": channel, "seq": self._next_seq(channel)})

    def bcast(self, value, root: int = 0, channel: str = "main"):
        return self._rpc({"op": "bcast", "value": value, "root": root,
                          "rank": self._rank, "eid": self._eid,
                          "channel": channel, "seq": self._next_seq(channel)})

    # ------------------------------------------------------------ ULFM calls
    def revoke(self) -> None:
        self._rpc({"op": "revoke", "eid": self._eid})

    def agree(self, flag: bool = True) -> bool:
        return self._rpc({"op": "agree", "value": bool(flag),
                          "rank": self._rank, "eid": self._eid,
                          "seq": self._next_seq("__agree")})

    def recover(self, policy: Optional[str] = None) -> "ProcComm":
        policy = (policy or self._recovery_policy).upper()
        view = self._rpc({"op": "recover", "rank": self._rank,
                          "eid": self._eid, "policy": policy})
        self._eid = view["eid"]
        self._rank = view["rank"]
        self._size = view["size"]
        self._node = view["node"]
        self._seq = defaultdict(int)
        self._last_recovery = view["stats"]
        self._replacement = False
        return self

    def failed_ranks(self) -> List[int]:
        return self._rpc({"op": "failed_ranks", "eid": self._eid})

    def last_recovery_stats(self) -> dict:
        return dict(self._last_recovery)

    @property
    def default_recovery_policy(self):
        return self._recovery_policy

    def is_replacement(self) -> bool:
        return self._replacement

    # ------------------------------------------------------------ lifecycle
    def send_result(self, value) -> None:
        self._rpc({"op": "result", "value": value})

    def send_error(self, text: str) -> None:
        try:
            self._rpc({"op": "error", "text": text})
        except Exception:
            pass

    def close(self) -> None:
        self._closed.set()
        try:
            self._conn.close()
        except OSError:
            pass


def worker_entry(address: str, rank: int, node: int, eid: int,
                 replacement: bool, fn, args: tuple,
                 env_overrides: dict, config: dict) -> None:
    """Entry point of every worker process (initial and respawned)."""
    os.environ.update(env_overrides or {})
    size = config["n_procs"]
    comm = ProcComm(
        address, rank, node, eid, replacement,
        recovery_policy=config.get("recovery_policy", "NON-SHRINKING"),
        size=size,
        hb_interval=config.get("hb_interval", 0.2),
    )
    try:
        result = fn(comm, *args)
        comm.send_result(result)
    except CoordinatorLostError:
        os._exit(1)
    except BaseException:
        comm.send_error(traceback.format_exc())
        comm.close()
        os._exit(1)
    finally:
        comm.close()
