"""Elastic remesh: shrink-recovery resharding (beyond-paper, DESIGN.md §2).

The paper's shrinking recovery leaves domain redistribution to the user.
Here the checkpoint manifest is topology-independent (shard files + global
indices), so after a shrink the framework itself can rebuild a smaller mesh
and restore the same global state resharded — "the user redistributes the
domain" done automatically.

The data-parallel axis absorbs the shrink (every DP slice holds a full
model replica group, so dropping DP slices never strands a weight shard);
the model axis is preserved.  ``shrink_mesh`` computes the largest valid
mesh for the surviving host count; ``reshard`` moves a live pytree onto it.
A restore-from-checkpoint needs no special code at all: build the state on
the new mesh and ``Checkpoint.restart_if_needed()`` — the checkpointables
``device_put`` every leaf onto the live (new-mesh) sharding.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.sharding.logical import LogicalRules, shard_specs


def shrink_mesh(n_devices: int, model_parallel: int,
                axis_names: Tuple[str, ...] = ("data", "model")) -> Mesh:
    """Largest (data, model) mesh with the given TP degree that fits
    ``n_devices`` devices.  Raises if fewer than one model group survives."""
    if n_devices < model_parallel:
        raise ValueError(
            f"{n_devices} devices cannot hold one {model_parallel}-way "
            "model-parallel group — shrink recovery impossible; use "
            "non-shrinking recovery with spare nodes instead")
    data = n_devices // model_parallel
    devs = jax.devices()[: data * model_parallel]
    import numpy as np

    arr = np.array(devs).reshape(data, model_parallel)
    return Mesh(arr, axis_names)


def reshard(tree, logical_tree, new_mesh: Mesh,
            rules: Optional[LogicalRules] = None):
    """Move a live pytree onto ``new_mesh`` under the same logical rules."""
    rules = rules or LogicalRules(new_mesh)
    specs = shard_specs(rules, logical_tree, tree)
    return jax.tree_util.tree_map(
        lambda x, sp: jax.device_put(x, NamedSharding(new_mesh, sp)),
        tree, specs,
        is_leaf=lambda x: isinstance(x, jax.Array)), specs


def dp_degree(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("data", 1) * sizes.get("pod", 1)
