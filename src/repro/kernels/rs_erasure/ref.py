"""Pure-jnp oracle for the Reed–Solomon GF(2^8) matmul kernel.

The erasure code works in GF(2^8) with the AES reduction polynomial
``x^8 + x^4 + x^3 + x + 1`` (0x11B).  Addition is XOR; multiplication is
implemented here the classic way — log/exp table lookups with generator 3
(``a·b = exp[log a + log b]``, the exp table doubled so the index sum needs
no mod-255) — which is exactly the form the systems literature calls a
"log-table matmul".  The Pallas kernel computes the *same field product*
without gathers (bit-decomposed xtime chains, see kernel.py); the two must
agree bit for bit, which tests/test_rs_erasure.py asserts.

Tables are built once at import with plain numpy and exposed both as numpy
(host-side matrix algebra in ops.py) and as jnp constants (this oracle).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

_POLY = 0x11B      # AES field: x^8 + x^4 + x^3 + x + 1
_GENERATOR = 3     # 2 is not primitive mod 0x11B; 3 is


def _build_tables():
    exp = np.zeros(510, dtype=np.uint8)
    log = np.zeros(256, dtype=np.uint8)   # log[0] is undefined (guarded)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        # multiply by the generator 3: x*2 ^ x, reduced by the field poly
        x2 = x << 1
        if x2 & 0x100:
            x2 ^= _POLY
        x = x2 ^ x
    exp[255:] = exp[:255]                 # doubled: no mod on log sums
    return exp, log


GF_EXP, GF_LOG = _build_tables()
_GF_EXP_J = jnp.asarray(GF_EXP)
_GF_LOG_J = jnp.asarray(GF_LOG)


def gf_matmul_ref(stacked: jnp.ndarray, matrix) -> jnp.ndarray:
    """GF(2^8) matrix product of a static byte matrix with stacked buffers.

    ``stacked`` is ``(G, N) uint8`` (one row per group member), ``matrix`` a
    nested tuple/array of shape ``(R, G)`` with entries in 0..255.  Returns
    ``(R, N) uint8`` where ``out[r] = XOR_i matrix[r][i] · stacked[i]`` —
    Reed–Solomon encode, syndrome computation and erasure solve are all this
    one primitive with different matrices.
    """
    if stacked.ndim != 2:
        raise ValueError(f"expected (G, N), got {stacked.shape}")
    if stacked.dtype != jnp.uint8:
        raise TypeError(f"expected uint8, got {stacked.dtype}")
    mat = np.asarray(matrix, dtype=np.uint8)
    if mat.ndim != 2 or mat.shape[1] != stacked.shape[0]:
        raise ValueError(f"matrix {mat.shape} does not match G={stacked.shape[0]}")
    logs = _GF_LOG_J[stacked].astype(jnp.int32)        # (G, N)
    rows = []
    for r in range(mat.shape[0]):
        acc = jnp.zeros(stacked.shape[1], dtype=jnp.uint8)
        for i in range(mat.shape[1]):
            c = int(mat[r, i])
            if c == 0:
                continue
            if c == 1:
                acc = acc ^ stacked[i]
                continue
            prod = _GF_EXP_J[int(GF_LOG[c]) + logs[i]]
            prod = jnp.where(stacked[i] == 0, jnp.uint8(0), prod)
            acc = acc ^ prod
        rows.append(acc)
    return jnp.stack(rows)
