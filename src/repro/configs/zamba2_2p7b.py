"""zamba2-2.7b — hybrid Mamba2 + weight-shared attention blocks.

[arXiv:2411.15242; hf]  54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000 ssm_state=64.  Mamba2 backbone (expand 2 → d_inner 5120,
head_dim 64 → 80 SSD heads); one weight-SHARED transformer block applied
after every 6 mamba blocks (9 applications).  Deviation from the released
model (noted in DESIGN.md): the shared block consumes d_model, not the
concat(hidden, embedding) variant, and per-application LoRA deltas are
omitted.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, vocab=32000,
    attn_type="gqa", n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240,
    ssm_type="mamba2", ssm_state=64, ssm_expand=2, ssm_conv=4,
    ssm_head_dim=64, ssm_groups=1,
    shared_attn_every=6,
    tie_embeddings=True,
)

TINY = CONFIG.replace(
    n_layers=6, d_model=64, vocab=512, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, ssm_state=16, ssm_head_dim=16,
    shared_attn_every=3, ssm_chunk=16,
)
