"""Live telemetry plane (PR 10): registry semantics, the unset no-op fast
path, cross-rank aggregation (dead-rank tolerant), the /metrics + /healthz
exporter, and ``python -m repro.top``."""
import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import Checkpoint, metrics, telemetry
from repro.core.comm import ProcFailedError, RevokedError
from repro.core.comm_sim import SimWorld
from repro.core.env import CraftEnv
from repro.core.metrics import (MetricsRegistry, StatsView, merge,
                                parse_prometheus, render_prometheus)


@pytest.fixture(autouse=True)
def _telemetry_isolation():
    """Process-global registry/exporter must never leak across tests."""
    yield
    telemetry.stop()
    metrics.uninstall()


def _env(tmp_path, **extra):
    envmap = {
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_NODE_CP_PATH": str(tmp_path / "node"),
        "CRAFT_IO_BACKOFF_MS": "1",
        **{k: str(v) for k, v in extra.items()},
    }
    return CraftEnv.capture(envmap)


def _mk(tmp_path, arr, name="mx", **extra):
    cp = Checkpoint(name, env=_env(tmp_path, **extra))
    cp.add("arr", arr)
    cp.commit()
    return cp


def _get(url, timeout=5.0):
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        return resp.status, resp.read().decode("utf-8")


# ------------------------------------------------------- registry semantics
class TestRegistry:
    def test_counters_gauges_histograms(self):
        reg = MetricsRegistry(buckets=(0.1, 1.0))
        reg.inc("writes")
        reg.inc("writes", 2.0)
        reg.inc("writes", 1.0, slot="pfs")
        reg.set_gauge("pending", 3)
        reg.set_gauge("pending", 1)           # last write wins
        reg.observe("lat", 0.05)
        reg.observe("lat", 0.5)
        reg.observe("lat", 99.0)              # lands in +Inf
        snap = reg.snapshot()
        assert snap["counters"]["writes"] == 3.0
        assert snap["counters"]["writes|slot=pfs"] == 1.0
        assert snap["gauges"]["pending"] == 1.0
        h = snap["histograms"]["lat"]
        assert h["counts"] == [1, 1, 1] and h["count"] == 3
        assert h["sum"] == pytest.approx(99.55)

    def test_label_order_is_canonical(self):
        reg = MetricsRegistry()
        reg.inc("x", 1, b="2", a="1")
        reg.inc("x", 1, a="1", b="2")
        assert reg.snapshot()["counters"]["x|a=1|b=2"] == 2.0

    def test_merge_sums_counters_and_maxes_gauges(self):
        a = MetricsRegistry(buckets=(1.0,))
        b = MetricsRegistry(buckets=(1.0,))
        a.inc("writes", 2); b.inc("writes", 3)
        a.set_gauge("oldest", 0.5); b.set_gauge("oldest", 4.5)
        a.observe("lat", 0.1); b.observe("lat", 2.0)
        m = merge([a.snapshot(), b.snapshot()])
        assert m["counters"]["writes"] == 5.0
        assert m["gauges"]["oldest"] == 4.5   # worst-case wins
        assert m["histograms"]["lat"]["counts"] == [1, 1]
        assert m["histograms"]["lat"]["count"] == 2

    def test_thread_safety_under_contention(self):
        reg = MetricsRegistry()

        def spin():
            for _ in range(500):
                reg.inc("n")
                reg.observe("h", 0.01)

        threads = [threading.Thread(target=spin) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        snap = reg.snapshot()
        assert snap["counters"]["n"] == 2000.0
        assert snap["histograms"]["h"]["count"] == 2000


# ------------------------------------------------------------ no-op fast path
class TestNoOpFastPath:
    def test_unset_env_leaves_null_registry(self, tmp_path):
        env = _env(tmp_path)
        assert env.metrics is False and env.metrics_port == -1
        metrics.maybe_install_from_env(env)
        assert not metrics.enabled()
        metrics.inc("writes")                 # all no-ops, no state
        metrics.set_gauge("g", 1)
        metrics.observe("h", 1.0)
        assert metrics.snapshot()["counters"] == {}

    def test_port_implies_metrics(self):
        env = CraftEnv.capture({"CRAFT_METRICS_PORT": "0"})
        assert env.metrics is True and env.metrics_port == 0

    def test_statsview_is_a_plain_dict_when_off(self):
        sv = StatsView("cp", {"writes": 0, "tier_reads": {}})
        sv.inc("writes")
        sv["writes"] += 1
        sv["tier_reads"]["pfs"] = 3           # nested non-numeric untouched
        assert dict(sv) == {"writes": 2, "tier_reads": {"pfs": 3}}

    def test_statsview_mirrors_when_armed(self):
        reg = metrics.install()
        sv = StatsView("mycp", {"writes": 0, "restore_read_bytes": 0})
        sv.inc("writes")
        sv["writes"] += 2                     # bare += mirrors the delta too
        sv["restore_read_bytes"] = 100
        sv["restore_read_bytes"] = 40         # shrink → gauge semantics
        snap = reg.snapshot()
        assert snap["counters"]["cp_writes|cp=mycp"] == 3.0
        assert snap["gauges"]["cp_restore_read_bytes|cp=mycp"] == 40.0

    def test_checkpoint_stats_dict_back_compat(self, tmp_path):
        arr = np.arange(64, dtype=np.float64)
        cp = _mk(tmp_path, arr)
        assert cp.update_and_write()
        st = dict(cp.stats)                   # copyable, iterable, plain
        assert st["writes"] == 1 and st["restore_tier"] is None
        cp.close()


# -------------------------------------------------------- cross-rank merge
class TestAggregate:
    def test_single_rank_aggregate_is_local(self):
        reg = MetricsRegistry()
        reg.inc("writes", 7)
        m = metrics.aggregate(None, reg.snapshot())
        assert m["counters"]["writes"] == 7.0

    def test_simworld_merge_with_dead_rank(self):
        env = CraftEnv.capture({"CRAFT_COMM_RECOVERY_POLICY": "SHRINKING"})
        world = SimWorld(3, env=env)

        def fn(c):
            reg = MetricsRegistry()
            reg.inc("writes", c.rank + 1)     # ranks contribute 1, 2, 3
            reg.set_gauge("oldest", float(c.rank))
            while True:
                try:
                    if c.rank == 0 and c.epoch == 0:
                        world.kill(2)
                        time.sleep(0.02)
                    c.barrier()
                    return metrics.aggregate(c, reg.snapshot())
                except (ProcFailedError, RevokedError):
                    try:
                        c.revoke()
                    except Exception:
                        pass
                    c = c.recover(policy="SHRINKING")

        out = world.run(fn, timeout=60)
        assert len(out) == 2                  # rank 2 died
        for m in out.values():
            # fleet totals span the survivors only: 1 + 2, max gauge 1.0
            assert m["counters"]["writes"] == 3.0
            assert m["gauges"]["oldest"] == 1.0


# ------------------------------------------------------------- exporter
class TestExporter:
    def test_scrape_round_trip(self, tmp_path):
        arr = np.arange(256, dtype=np.float64)
        cp = _mk(tmp_path, arr, CRAFT_METRICS_PORT=0,
                 CRAFT_TIER_EVERY="pfs:1")
        for it in range(4):
            arr += 1.0
            cp.update_and_write(it)
        cp.wait()
        port = telemetry.port()
        assert port is not None
        status, text = _get(f"http://localhost:{port}/metrics")
        assert status == 200
        parsed = parse_prometheus(text)
        assert parsed["craft_cp_writes_total"]['cp="mx"'] == 4.0
        # histogram exposition: bucket counts are cumulative and end at +Inf
        buckets = [(lab, v) for lab, v in
                   parsed["craft_tier_write_seconds_bucket"].items()]
        assert any('le="+Inf"' in lab for lab, _ in buckets)
        # the parsed scrape must agree with the in-process registry
        snap = metrics.snapshot()
        assert parsed["craft_cp_writes_total"]['cp="mx"'] == \
            snap["counters"]["cp_writes|cp=mx"]
        cp.close()

    def test_render_parse_identity(self):
        reg = MetricsRegistry(buckets=(0.5, 1.0))
        reg.inc("a", 2, slot="pfs")
        reg.set_gauge("b", 1.5)
        reg.observe("c", 0.2)
        text = render_prometheus(reg.snapshot())
        parsed = parse_prometheus(text)
        assert parsed["craft_a_total"]['slot="pfs"'] == 2.0
        assert parsed["craft_b"][""] == 1.5
        assert parsed["craft_c_count"][""] == 1.0
        assert parsed["craft_c_sum"][""] == 0.2

    def test_unknown_path_404(self):
        telemetry.start(0)
        port = telemetry.port()
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(f"http://localhost:{port}/nope")
        assert ei.value.code == 404

    def test_healthz_degraded_then_healthy_under_chaos(self, tmp_path):
        """The acceptance transition: a PFS outage opens the breaker and
        /healthz flips to 503; clearing the fault re-admits the tier and
        /healthz flips back to 200."""
        arr = np.arange(512, dtype=np.float64)
        cp = _mk(tmp_path, arr, CRAFT_METRICS_PORT=0, CRAFT_CHAOS="on",
                 CRAFT_BREAKER_THRESHOLD=1, CRAFT_BREAKER_COOLDOWN_S=0,
                 CRAFT_IO_RETRIES=0)
        port = telemetry.port()
        arr[...] = 1.0
        assert cp.update_and_write()
        status, body = _get(f"http://localhost:{port}/healthz")
        doc = json.loads(body)
        assert status == 200 and doc["status"] == "ok"
        assert doc["checkpoints"]["mx"]["breakers"]["pfs"]["state"] == \
            "closed"
        assert doc["checkpoints"]["mx"]["version"] == 1
        assert doc["checkpoints"]["mx"]["last_write_age_s"] is not None

        cp.chaos.add("pfs:erofs:p=1")         # persistent outage
        for val in (2.0, 3.0):
            arr[...] = val
            assert cp.update_and_write()      # degrades to the node tier
        assert cp.health["pfs"].state == "open"
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(f"http://localhost:{port}/healthz")
        assert ei.value.code == 503
        doc = json.loads(ei.value.read().decode("utf-8"))
        assert doc["status"] == "unhealthy"
        assert doc["checkpoints"]["mx"]["breakers"]["pfs"]["state"] == "open"
        assert doc["checkpoints"]["mx"]["degraded_writes"] >= 2

        # scrape agrees with the stats the chaos run accumulated
        _, text = _get(f"http://localhost:{port}/metrics")
        parsed = parse_prometheus(text)
        assert parsed["craft_cp_breaker_trips_total"]['cp="mx"'] == \
            cp.stats["breaker_trips"]
        assert parsed["craft_cp_degraded_writes_total"]['cp="mx"'] == \
            cp.stats["degraded_writes"]

        cp.chaos.clear("pfs")                 # outage ends; re-admission
        arr[...] = 4.0
        assert cp.update_and_write()
        assert cp.health["pfs"].state == "closed"
        status, body = _get(f"http://localhost:{port}/healthz")
        assert status == 200
        assert json.loads(body)["status"] == "ok"
        cp.close()

    def test_breaker_state_gauge(self, tmp_path):
        arr = np.arange(128, dtype=np.float64)
        cp = _mk(tmp_path, arr, CRAFT_METRICS=1, CRAFT_CHAOS="on",
                 CRAFT_BREAKER_THRESHOLD=1, CRAFT_BREAKER_COOLDOWN_S=3600,
                 CRAFT_IO_RETRIES=0)
        cp.chaos.add("pfs:eio:p=1")
        arr[...] = 1.0
        assert cp.update_and_write()
        snap = metrics.snapshot()
        assert snap["gauges"]["breaker_state|slot=pfs"] == 2.0   # open
        assert snap["counters"]["breaker_trips|slot=pfs"] == 1.0
        cp.close()


# ----------------------------------------------------------------- craft top
class TestTop:
    def test_renders_from_trace_file(self, tmp_path):
        from repro import top

        trace_path = tmp_path / "run.jsonl"
        events = [
            {"t": 0.0, "kind": "config"},
            {"t": 0.1, "kind": "decision", "write": False, "reason": None},
            {"t": 0.2, "kind": "decision", "write": True,
             "reason": "cadence"},
            {"t": 0.3, "kind": "tier_write", "slot": "pfs", "version": 1,
             "seconds": 0.02, "nbytes": 4096},
            {"t": 0.35, "kind": "scheduled", "version": 1},
            {"t": 0.4, "kind": "breaker", "slot": "pfs"},
            {"t": 0.5, "kind": "degraded", "slot": "pfs"},
            {"t": 0.6, "kind": "restore", "slot": "node", "version": 1,
             "seconds": 0.01, "read_bytes": 4096},
            {"t": 0.7, "kind": "async_stall", "age_s": 2.5,
             "deadline_s": 1.0},
        ]
        trace_path.write_text(
            "\n".join(json.dumps(e) for e in events) + "\n"
            + '{"torn line'  # a live file's torn tail must not crash top
        )
        m = top.model_from_trace(str(trace_path))
        assert m["tiers"]["pfs"]["writes"] == 1
        assert m["decisions"] == {"skip": 1, "cadence": 1}
        assert m["breakers"]["pfs"] == "open"
        assert m["restores"]["node"] == 1
        assert m["async"]["stalls"] == 1
        out = top.render(m, color=False)
        assert "pfs" in out and "cadence" in out and "4.0 KiB" in out
        assert top.main(["--trace", str(trace_path), "--once",
                         "--no-color"]) == 0

    def test_renders_from_live_endpoint(self, tmp_path):
        from repro import top

        arr = np.arange(128, dtype=np.float64)
        cp = _mk(tmp_path, arr, CRAFT_METRICS_PORT=0,
                 CRAFT_TIER_EVERY="pfs:1")
        for it in range(3):
            arr += 1.0
            cp.update_and_write(it)
        cp.wait()
        url = f"http://localhost:{telemetry.port()}"
        m = top.model_from_url(url)
        assert m["status"] == "ok"
        assert m["tiers"]["pfs"]["writes"] == 3
        out = top.render(m, color=False)
        assert "status: ok" in out and "pfs" in out
        cp.close()


# ----------------------------------------------------- trace close race fix
class TestTraceRace:
    def test_emit_during_uninstall_never_tears(self, tmp_path):
        from repro.core import trace

        path = tmp_path / "race.jsonl"
        stop = threading.Event()

        def hammer():
            while not stop.is_set():
                trace.emit("step", seconds=0.001)

        threads = [threading.Thread(target=hammer) for _ in range(4)]
        for t in threads:
            t.start()
        for _ in range(20):                   # install/uninstall churn
            trace.install(str(path))
            time.sleep(0.001)
            trace.uninstall()
        stop.set()
        for t in threads:
            t.join()
        for line in path.read_text().splitlines():
            json.loads(line)                  # every line is whole JSON

    def test_close_is_idempotent(self, tmp_path):
        from repro.core.trace import JsonlTracer

        tr = JsonlTracer(str(tmp_path / "t.jsonl"))
        tr.emit("a")
        tr.close()
        tr.close()                            # second close: no raise
        tr.emit("b")                          # post-close emit: swallowed
        assert len((tmp_path / "t.jsonl").read_text().splitlines()) == 1


# ----------------------------------------------------- async stall watchdog
class TestStallWatchdog:
    def test_oldest_pending_and_warning(self):
        from repro.core.async_writer import AsyncWriter

        reg = metrics.install()
        w = AsyncWriter(workers=1, name="wd")
        gate = threading.Event()
        w.submit(gate.wait, label="slow v-1")
        time.sleep(0.05)
        assert w.oldest_pending_s() >= 0.04
        age = w.check_stall(deadline_s=0.01)
        assert age > 0.01
        assert w.stats["stall_warnings"] == 1
        w.check_stall(deadline_s=0.01)        # same job: warn exactly once
        assert w.stats["stall_warnings"] == 1
        snap = reg.snapshot()
        assert snap["counters"]["async_stall_warnings"] == 1.0
        assert snap["gauges"]["async_oldest_pending_s"] > 0.01
        gate.set()
        w.wait()
        assert w.oldest_pending_s() == 0.0
        w.close()

    def test_drained_lane_reports_zero(self):
        from repro.core.async_writer import AsyncWriter

        w = AsyncWriter(workers=1, name="wd2")
        w.submit(lambda: None)
        w.wait()
        assert w.check_stall(deadline_s=0.001) == 0.0
        assert w.stats["stall_warnings"] == 0
        w.close()
