"""Pure-jnp oracle for the fused snapshot pass (digest + dirty + histogram).

One pass over a device-resident shard, viewed as a (n_chunks, words_per_chunk)
uint32 matrix, produces per chunk (row):

    s1      = sum_j x[j]                      (mod 2^32)
    s2      = sum_j (j + 1) * x[j]            (mod 2^32)
    dirty   = (s1 != prev_s1) | (s2 != prev_s2)
    hist[k] = # of nibbles (both 4-bit halves of every byte) equal to k

laid out as uint32 columns ``[s1, s2, dirty, hist[0..15]]`` (or just the
first three with ``with_hist=False``).  The digest columns are bit-identical
to the ``kernels.checksum`` digest of the same chunk's bytes — zero padding
is digest-neutral, both sums ignore zero words — which is what lets the
storage layer consume them in place of its host-side digest pass.  The
histogram is kept as raw integer counts (the entropy estimate that gates
zstd is derived on the host, see ``ops.chunk_entropy_bits``) so kernel and
oracle compare exactly, with no float reduction-order hazards.
"""
from __future__ import annotations

import jax.numpy as jnp

HIST_BINS = 16
META_COLS = 3 + HIST_BINS       # [s1, s2, dirty, hist[0..15]]


def snapshot_ref(x2: jnp.ndarray, prev: jnp.ndarray, *,
                 with_hist: bool = True) -> jnp.ndarray:
    """Fused per-chunk metadata of a (n_chunks, wpc) uint32 matrix.

    ``prev`` is the previous snapshot's (n_chunks, 2) digest table (zeros on
    the first snapshot — callers ignore the dirty column then).  Returns a
    (n_chunks, 19) uint32 matrix (or (n_chunks, 3) without the histogram).
    """
    if x2.ndim != 2 or x2.dtype != jnp.uint32:
        raise TypeError(f"expected 2-D uint32, got {x2.shape} {x2.dtype}")
    if prev.shape != (x2.shape[0], 2) or prev.dtype != jnp.uint32:
        raise TypeError(
            f"expected ({x2.shape[0]}, 2) uint32 prev digests, got "
            f"{prev.shape} {prev.dtype}"
        )
    idx = jnp.arange(x2.shape[1], dtype=jnp.uint32)[None, :] + jnp.uint32(1)
    s1 = jnp.sum(x2, axis=1, dtype=jnp.uint32)
    s2 = jnp.sum(x2 * idx, axis=1, dtype=jnp.uint32)
    dirty = ((s1 != prev[:, 0]) | (s2 != prev[:, 1])).astype(jnp.uint32)
    cols = [s1, s2, dirty]
    if with_hist:
        nibs = [(x2 >> jnp.uint32(sh)) & jnp.uint32(0xF)
                for sh in range(0, 32, 4)]
        for k in range(HIST_BINS):
            c = jnp.zeros_like(s1)
            for nib in nibs:
                c = c + jnp.sum((nib == jnp.uint32(k)).astype(jnp.uint32),
                                axis=1, dtype=jnp.uint32)
            cols.append(c)
    return jnp.stack(cols, axis=1)
