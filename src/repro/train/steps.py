"""Step builders: training (grad-accum, clip, MoE aux, MTP) and serving.

``make_train_step(cfg, opt_cfg)`` returns a pure function

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)

suitable for ``jax.jit`` with in/out shardings.  Microbatch gradient
accumulation (``microbatches > 1``) runs a ``lax.scan`` over microbatch
slices — under XLA's scheduler the per-microbatch gradient all-reduce
overlaps the next microbatch's compute, the standard DP comm/compute
overlap.

Serving: ``make_prefill`` builds the KV/SSM caches from the prompt in one
shot; ``make_decode_step`` advances one token against a static-size cache.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.common import ModelConfig
from repro.optim.adamw import OptimConfig, adamw_update
from repro.sharding.activations import constrain, constrain_tree

IGNORE = -100


class StepTimer:
    """Host-side wall-clock EWMA of the train-step duration.

    Feeds the checkpoint scheduler's rework model: the policy converts its
    Daly intervals (seconds) into a schedule, and drivers report the measured
    step time via ``policy.observe_step_seconds(timer.tick())`` so the
    estimate tracks the real loop instead of being inferred from decision
    gaps (which include checkpoint-write time).
    """

    def __init__(self, alpha: float = 0.2, clock=time.perf_counter):
        self._alpha = alpha
        self._clock = clock
        self._last_t: Optional[float] = None
        self.last: Optional[float] = None     # most recent step, seconds
        self.ewma: Optional[float] = None     # smoothed step seconds

    def tick(self) -> Optional[float]:
        """Mark a step boundary; returns the seconds since the previous tick
        (None on the first call)."""
        now = self._clock()
        if self._last_t is None:
            self._last_t = now
            return None
        dt = now - self._last_t
        self._last_t = now
        self.observe(dt)
        return dt

    def observe(self, seconds: float) -> None:
        """Feed an explicitly measured step duration (drivers that time the
        compute section directly, excluding checkpoint writes)."""
        if seconds <= 0:
            return
        self.last = seconds
        self.ewma = seconds if self.ewma is None else (
            (1.0 - self._alpha) * self.ewma + self._alpha * seconds)


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1
    moe_aux_weight: float = 0.01
    mtp_weight: float = 0.3
    grad_dtype: Optional[str] = None     # e.g. "bfloat16" for compressed DP
    loss_chunk: int = 128                # seq positions per CE chunk


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Masked mean CE; label == IGNORE positions are excluded."""
    nll, n = _ce_sums(logits, labels)
    return nll / jnp.maximum(n, 1.0)


def _ce_sums(logits, labels):
    """(sum of NLL over non-IGNORE positions, count of those positions)."""
    mask = (labels != IGNORE).astype(jnp.float32)
    safe = jnp.where(labels == IGNORE, 0, labels)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * mask), jnp.sum(mask)


def chunked_cross_entropy(hidden, labels, unembed_fn, chunk: int):
    """Masked mean CE without materializing (B, L, V) logits.

    ``lax.scan`` over sequence chunks; each chunk unembeds (B, c, V),
    reduces, and is dropped.  ``jax.checkpoint`` on the chunk body keeps
    the backward pass from saving per-chunk logits as residuals — it
    recomputes them (the standard memory/compute trade; the recompute is
    one extra unembed matmul per chunk).
    """
    b, l, _ = hidden.shape
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)),
                         constant_values=IGNORE)
    nc = (l + pad) // chunk
    h_c = jnp.moveaxis(hidden.reshape(b, nc, chunk, -1), 1, 0)
    y_c = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(carry, hl):
        h, y = hl
        logits = constrain(unembed_fn(h), "batch", "seq", "vocab")
        nll, n = _ce_sums(logits, y)
        return (carry[0] + nll, carry[1] + n), None

    (nll, n), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (h_c, y_c))
    return nll / jnp.maximum(n, 1.0)


def _loss_fn(params, cfg: ModelConfig, scfg: TrainStepConfig, batch):
    tokens = batch["tokens"]
    labels = batch["labels"]
    embeds = batch.get("embeds")
    hidden, _, aux = M.forward_hidden(
        params, cfg, tokens=tokens, embeds=embeds)
    if embeds is not None:
        # modality-stub positions carry no next-token loss
        pad = jnp.full(embeds.shape[:2], IGNORE, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    unembed_fn = lambda h: M.unembed(params, cfg, h)
    loss = chunked_cross_entropy(hidden, labels, unembed_fn, scfg.loss_chunk)
    total = loss + scfg.moe_aux_weight * aux
    if cfg.mtp:
        # predict token t+2 from (embed_t, embed(token_{t+1})) — one MTP
        # depth over embeddings (deepseek's shallowest MTP variant)
        b, l = tokens.shape
        positions = jnp.arange(l)
        next_tokens = jnp.concatenate(
            [tokens[:, 1:], tokens[:, -1:]], axis=1)
        mtp_h = M.mtp_hidden(params, cfg, _embed_hidden(params, cfg, tokens,
                                                        embeds),
                             next_tokens, positions)
        mtp_labels = jnp.concatenate(
            [labels[:, embeds.shape[1] if embeds is not None else 0:][:, 1:],
             jnp.full((b, 1), IGNORE, labels.dtype)], axis=1)
        if embeds is not None:
            pad = jnp.full(embeds.shape[:2], IGNORE, labels.dtype)
            mtp_labels = jnp.concatenate([pad, mtp_labels], axis=1)
        mtp_loss = chunked_cross_entropy(
            mtp_h, mtp_labels, unembed_fn, scfg.loss_chunk)
        total = total + scfg.mtp_weight * mtp_loss
    return total, {"loss": loss, "aux": aux}


def _embed_hidden(params, cfg, tokens, embeds):
    """Final-layer hidden states for the MTP head (cheap re-embed)."""
    # For MTP we need the backbone's final hidden; forward() returns logits,
    # so recompute the pre-logits hidden by calling the stack once more is
    # wasteful — instead MTP consumes the token embeddings directly (one
    # MTP depth over embeddings; a faithful-enough single-depth MTP).
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(cfg.dtype))
    if tokens is not None:
        from repro.models.layers import embed_apply
        parts.append(embed_apply(params["embed"], tokens))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)


def make_train_step(cfg: ModelConfig, opt_cfg: OptimConfig,
                    scfg: Optional[TrainStepConfig] = None):
    scfg = scfg or TrainStepConfig()

    param_dims = M.param_logical(cfg)

    def single_grads(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            _loss_fn, has_aux=True)(params, cfg, scfg, batch)
        # declare the target (= parameter) sharding at the production site
        # so GSPMD reduce-scatters instead of all-reduce + slice
        grads = constrain_tree(grads, param_dims)
        if scfg.grad_dtype:
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(scfg.grad_dtype), grads)
        return loss, parts, grads

    def train_step(params, opt_state, batch):
        if scfg.microbatches <= 1:
            loss, parts, grads = single_grads(params, batch)
        else:
            mb = scfg.microbatches

            def slice_mb(x):
                b = x.shape[0]
                return x.reshape(mb, b // mb, *x.shape[1:])

            mb_batch = {k: slice_mb(v) for k, v in batch.items()}

            def body(acc, mbatch):
                l, p, g = single_grads(params, mbatch)
                acc_g, acc_l = acc
                acc_g = jax.tree_util.tree_map(jnp.add, acc_g, g)
                return (acc_g, acc_l + l), p

            zero_g = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape,
                                    scfg.grad_dtype or jnp.float32),
                params)
            (grads, loss_sum), parts_all = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32)), mb_batch)
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
            loss = loss_sum / mb
            parts = jax.tree_util.tree_map(lambda x: x[-1], parts_all)
        new_params, new_state, om = adamw_update(grads, opt_state, params,
                                                 opt_cfg)
        metrics = {"loss": loss, **parts, **om}
        return new_params, new_state, metrics

    return train_step


# ==========================================================================
# serving
# ==========================================================================
def make_prefill(cfg: ModelConfig, batch: int, max_len: int):
    """prefill(params, tokens, [embeds]) -> (cache, last_logits).

    Only the final position is unembedded — the (B, L, V) prompt logits
    tensor is never materialized (at prefill_32k it would be ~TB-scale).
    """

    def prefill(params, tokens, embeds=None):
        cache = M.init_cache(cfg, batch, max_len)
        hidden, cache, _ = M.forward_hidden(
            params, cfg, tokens=tokens, embeds=embeds, cache=cache,
            pos0=jnp.zeros((), jnp.int32))
        logits = M.unembed(params, cfg, hidden[:, -1:])
        return cache, logits[:, -1]

    return prefill


def make_decode_step(cfg: ModelConfig):
    """decode(params, cache, tokens (B,1), pos scalar) -> (cache, logits)."""

    def decode(params, cache, tokens, pos):
        logits, cache, _ = M.forward(
            params, cfg, tokens=tokens, cache=cache, pos0=pos)
        return cache, logits[:, -1]

    return decode
