"""Versioned, atomic checkpoint storage (paper §2.6) + the array codec.

Directory layout (paper Fig. 4):

    <base>/<cpName>/
        meta.json            -- latest complete version, history, checksums
        v-<K>/               -- one directory per checkpoint version
            <key>/...        -- one subdirectory per checkpointable object

Atomicity protocol: a version is staged in ``.tmp-v-<K>/``, every file is
fsync'd, the directory is atomically renamed to ``v-<K>``, and only then is
``meta.json`` updated (itself via tmp+rename).  A crash at any point leaves
either the previous complete version or a garbage ``.tmp-*`` dir that is swept
on the next run — never a torn checkpoint.  The shared directory mechanics
live in :mod:`repro.core.tiers`; :class:`VersionStore` is the concrete
:class:`~repro.core.tiers.StorageTier` used for the PFS path and as the local
store of the node tier.

On-disk array format (one ``.bin`` file per array / shard)
----------------------------------------------------------

Every file starts ``CRFT`` + u64(header_len) + JSON header.  The header's
``fmt`` field selects the codec:

* **v0 (legacy, fmt absent)** — monolithic: u64 crc32 digest, then the whole
  payload (optionally zstd-compressed) as one blob.  Still readable; written
  only when ``IOContext.codec_version == 0``.
* **v1 (chunked, fmt=1)** — the payload is split into fixed-size chunks
  (default 4 MiB, ``CRAFT_CHUNK_BYTES``).  Each chunk is independently
  compressed (zstd, when available and enabled) and digested with the blocked
  Fletcher checksum from ``repro.kernels.checksum`` — Pallas on TPU, the
  jitted reference on CPU — instead of host zlib.  The header records per
  chunk ``{clen, ulen, digest}`` so a reader can verify integrity chunk by
  chunk and reject truncated files explicitly.  Chunk *encoding* fans out
  across the IO worker pool via ``IOContext.fanout``.
* **v2 (chunk-delta, fmt=2)** — the incremental codec (``CRAFT_DELTA``).
  Every chunk's *raw* bytes are digested first (``rdigest``); a chunk whose
  raw digest matches the previous version's manifest (threaded in via
  ``IOContext.delta_prev``) is recorded as ``{ref: <base_version>, ulen,
  rdigest}`` and **its bytes are not written** — a mostly-clean array costs
  one digest pass plus a small manifest instead of a full encode + IO.
  Dirty chunks are stored exactly like v1 literals (``{clen, ulen, digest,
  rdigest}``).  At read time refs resolve against ``IOContext.base_dirs``:
  the same relative path inside the base version's directory, chasing at
  most the chain length (``CRAFT_DELTA_MAX_CHAIN`` bounds it via
  compaction); a missing base raises an explicit :class:`CheckpointError`.
  A delta-chain restore is bit-identical to a full-codec restore.
"""
from __future__ import annotations

import dataclasses
import errno
import json
import os
import shutil
import threading
import uuid
import zlib
from pathlib import Path
from typing import List, Optional

import numpy as np

try:  # optional transparent compression (beyond-paper extension)
    import zstandard as _zstd
except ImportError:  # pragma: no cover
    _zstd = None

from repro.core import tiers
from repro.core.cpbase import CheckpointError, IOContext
from repro.core.tiers import StorageTier, fsync_dir  # re-export (legacy API)

_MAGIC = b"CRFT"
CODEC_V0 = 0
CODEC_V1 = 1
CODEC_V2 = 2
DEFAULT_CHUNK_BYTES = 4 * 1024 * 1024
_MAX_REF_HOPS = 64       # hard bound on delta-chain chasing (cycle guard)


def _dtype_to_name(dt: np.dtype) -> str:
    return np.dtype(dt).name  # e.g. "float32", "bfloat16" (ml_dtypes)


def _dtype_from_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # registered by jax; covers bfloat16 / fp8 etc.

        return np.dtype(getattr(ml_dtypes, name))


# Per-worker (de)compressor reuse: constructing a ZstdCompressor per chunk
# costs more than compressing a small chunk.  zstandard objects are not safe
# for concurrent use, so the cache is thread-local (one instance per IO
# worker per level); keying on id(_zstd) keeps the cache coherent when tests
# swap the module in.
_zstd_tls = threading.local()


def _compressor(level: int):
    cache = getattr(_zstd_tls, "cache", None)
    if cache is None:
        cache = _zstd_tls.cache = {}
    key = ("c", id(_zstd), int(level))
    c = cache.get(key)
    if c is None:
        c = cache[key] = _zstd.ZstdCompressor(level=int(level))
    return c


def _decompressor():
    cache = getattr(_zstd_tls, "cache", None)
    if cache is None:
        cache = _zstd_tls.cache = {}
    key = ("d", id(_zstd))
    d = cache.get(key)
    if d is None:
        d = cache[key] = _zstd.ZstdDecompressor()
    return d


def _gate_allows_zstd(i: int, raw, ctx: IOContext, dm: Optional[dict]) -> bool:
    """Per-chunk compressibility gate (CRAFT_ZSTD_GATE_BITS): skip the zstd
    attempt when the chunk's order-0 entropy estimate says the bytes look
    incompressible.  The estimate comes from the device snapshot's fused
    histogram when available, else from a host nibble count — both are far
    cheaper than a doomed compress pass."""
    bits = float(ctx.zstd_gate_bits)
    if bits <= 0:
        return True
    from repro.kernels.snapshot import ops as snapshot_ops

    if dm is not None and dm.get("entropy_bits") is not None:
        return float(dm["entropy_bits"][i]) < bits
    hist = snapshot_ops.host_nibble_hist(raw)
    return float(snapshot_ops.chunk_entropy_bits(hist[None])[0]) < bits


def _digest_chunk(data) -> List[int]:
    """Blocked Fletcher digest [s1, s2] via the checksum kernel ops."""
    from repro.kernels.checksum import ops as checksum_ops

    s1, s2 = checksum_ops.digest_bytes(data)
    return [int(s1), int(s2)]


def _digest_all_chunks(flat, chunk_bytes: int) -> List[List[int]]:
    """Batched per-chunk digests (one device dispatch for the whole array)."""
    from repro.kernels.checksum import ops as checksum_ops

    return checksum_ops.digest_chunks(flat, chunk_bytes)


def _as_byte_view(arr: np.ndarray) -> np.ndarray:
    """Contiguous flat uint8 view of an array (copy only if non-contiguous)."""
    arr = np.ascontiguousarray(arr)
    if arr.nbytes == 0:
        return np.empty(0, dtype=np.uint8)
    return arr.reshape(-1).view(np.uint8).reshape(-1)


def _manifest_name(path: Path, ctx: IOContext) -> str:
    """Checksum-manifest key: path relative to the staging root (collision-
    free across checkpoint keys), falling back to the bare file name."""
    if ctx.rel_root is not None:
        try:
            return str(path.relative_to(ctx.rel_root))
        except ValueError:
            pass
    return path.name


def run_jobs(jobs, ctx: IOContext) -> list:
    """Run independent IO jobs through ``ctx.fanout`` when available, else
    inline — the single dispatch point for per-array and per-chunk fanout."""
    if ctx.fanout is not None and len(jobs) > 1:
        return ctx.fanout(jobs)
    return [job() for job in jobs]


def _retrying(fn, ctx: IOContext):
    """Run a file operation under the context's transient-retry policy."""
    if not ctx.io_retries:
        return fn()
    from repro.core import health

    return health.retry_call(fn, ctx.io_retries, ctx.io_retry_backoff_ms,
                             on_retry=ctx.record_retry)


def _atomic_write_file(path: Path, parts, ctx: IOContext) -> None:
    """tmp → write parts → fsync → rename, with chaos + retry.

    All fault handling for array/manifest payload files funnels through
    here: the chaos gate runs per attempt (a ``count=N`` EIO rule is
    consumed by retries), a ``torn`` rule writes only a byte prefix of the
    tmp file and fails the attempt (the ``.tmp-`` name is the reason a torn
    file can never be confused with a published one), and transient errors
    retry with backoff.  Encoding happened before this call — retries redo
    only the file IO, never the codec work.
    """
    total = sum(len(p) for p in parts)

    def attempt():
        if ctx.chaos is not None:
            ctx.chaos.check("write", nbytes=total, path=path)
        tmp = path.with_name(f".tmp-{path.name}-{uuid.uuid4().hex[:8]}")
        torn = ctx.chaos.torn_limit(total) if ctx.chaos is not None else None
        try:
            with open(tmp, "wb") as fh:
                if torn is not None:
                    budget = torn
                    for part in parts:
                        cut = memoryview(part)[:budget]
                        fh.write(cut)
                        budget -= len(cut)
                        if budget <= 0:
                            break
                    fh.flush()
                    raise OSError(
                        errno.EIO,
                        f"chaos: torn write ({torn}/{total} bytes) {path}")
                for part in parts:
                    fh.write(part)
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, path)
        except BaseException:
            tmp.unlink(missing_ok=True)
            raise

    _retrying(attempt, ctx)


# --------------------------------------------------------------------------
# array codec — v1 chunked writer, v0 legacy writer, version-dispatching reader
# --------------------------------------------------------------------------
def write_array(path: Path, arr: np.ndarray, ctx: IOContext) -> None:
    """Serialize ``arr`` to ``path`` using the codec ``ctx`` selects."""
    if ctx.codec_version == CODEC_V0:
        _write_array_v0(path, arr, ctx)
    elif ctx.codec_version == CODEC_V1:
        _write_array_v1(path, arr, ctx)
    else:
        _write_array_v2(path, arr, ctx)


def _write_array_v0(path: Path, arr: np.ndarray, ctx: IOContext) -> None:
    arr = np.ascontiguousarray(arr)
    if ctx.compress == "zstd":
        if _zstd is None:  # pragma: no cover
            raise CheckpointError("CRAFT_COMPRESS=zstd but zstandard missing")
        payload = _compressor(ctx.zstd_level).compress(arr.tobytes())
    else:
        # uncompressed: digest + write straight off the byte view — tobytes()
        # would copy the whole payload for nothing
        payload = _as_byte_view(arr)
    header = json.dumps(
        {
            "dtype": _dtype_to_name(arr.dtype),
            "shape": list(arr.shape),
            "compress": ctx.compress,
        }
    ).encode()
    digest = zlib.crc32(payload) if ctx.checksum != "none" else 0
    _atomic_write_file(
        path,
        [_MAGIC, len(header).to_bytes(8, "little"), header,
         digest.to_bytes(8, "little"), payload],
        ctx,
    )
    ctx.record_checksum(_manifest_name(path, ctx), digest)
    ctx.record_io(len(payload), chunks=1)


def _write_array_v1(path: Path, arr: np.ndarray, ctx: IOContext) -> None:
    shape = list(np.shape(arr))  # before ascontiguousarray 0-d→1-d promotion
    arr = np.ascontiguousarray(arr)
    flat = _as_byte_view(arr)
    chunk_bytes = max(1, int(ctx.chunk_bytes))
    compress = ctx.compress
    if compress == "zstd" and _zstd is None:  # pragma: no cover
        raise CheckpointError("CRAFT_COMPRESS=zstd but zstandard missing")
    want_digest = ctx.checksum != "none"
    n = flat.size
    offsets = list(range(0, n, chunk_bytes)) if n else []
    dm = ctx.lookup_device_meta(
        _manifest_name(path, ctx), n, chunk_bytes, len(offsets))

    # Uncompressed chunks are digested over their raw bytes: the device
    # snapshot's fused digests serve directly when present, else the whole
    # array goes through one batched kernel dispatch; compressed chunks are
    # digested post-compression inside the fanout jobs.
    if want_digest and compress != "zstd" and n:
        raw_digests = (dm["rdigests"] if dm is not None
                       else _digest_all_chunks(flat, chunk_bytes))
    else:
        raw_digests = []

    def encode(i: int, off: int):
        raw = flat[off: off + chunk_bytes]
        if compress == "zstd" and _gate_allows_zstd(i, raw, ctx, dm):
            # the compressor reads the buffer protocol directly — no
            # tobytes() copy of the uncompressed chunk
            stored = _compressor(ctx.zstd_level).compress(raw)
            digest = _digest_chunk(stored) if want_digest else [0, 0]
        elif compress == "zstd":
            # gated: incompressible-looking chunk stored raw inside the
            # zstd file; its stored-bytes digest is the raw digest
            stored = memoryview(raw)
            digest = ([int(d) for d in dm["rdigests"][i]] if dm is not None
                      else _digest_chunk(raw)) if want_digest else [0, 0]
            return stored, {"clen": len(stored), "ulen": int(raw.size),
                            "digest": digest, "enc": "raw"}
        else:
            stored = memoryview(raw)
            digest = ([int(d) for d in raw_digests[i]]
                      if want_digest else [0, 0])
        return stored, {"clen": len(stored), "ulen": int(raw.size),
                        "digest": digest}

    encoded = run_jobs(
        [lambda i=i, off=off: encode(i, off)
         for i, off in enumerate(offsets)], ctx)
    chunks_meta = [meta for _, meta in encoded]
    header = json.dumps(
        {
            "fmt": CODEC_V1,
            "dtype": _dtype_to_name(arr.dtype),
            "shape": shape,
            "compress": compress,
            "checksum": "fletcher" if want_digest else "none",
            "chunk_bytes": chunk_bytes,
            "nbytes": int(n),
            "chunks": chunks_meta,
        }
    ).encode()
    _atomic_write_file(
        path,
        [_MAGIC, len(header).to_bytes(8, "little"), header,
         *(stored for stored, _ in encoded)],
        ctx,
    )
    # whole-file digest for the manifest: fold per-chunk digests
    folded = 0
    for meta in chunks_meta:
        folded = zlib.crc32(
            meta["digest"][0].to_bytes(4, "little")
            + meta["digest"][1].to_bytes(4, "little"),
            folded,
        )
    ctx.record_checksum(_manifest_name(path, ctx), folded)
    ctx.record_io(sum(m["clen"] for m in chunks_meta), chunks=len(chunks_meta))


def _write_array_v2(path: Path, arr: np.ndarray, ctx: IOContext) -> None:
    """Chunk-delta writer (fmt=2): digest every chunk, diff against the
    previous version's manifest, store only the dirty chunks.

    The raw-chunk digest pass runs even with ``ctx.checksum == "none"`` —
    it *is* the change detector — and fans out across the worker pool with
    the dirty-chunk encodes (one job per chunk via ``run_jobs``).
    """
    shape = list(np.shape(arr))  # before ascontiguousarray 0-d→1-d promotion
    arr = np.ascontiguousarray(arr)
    flat = _as_byte_view(arr)
    chunk_bytes = max(1, int(ctx.chunk_bytes))
    compress = ctx.compress
    if compress == "zstd" and _zstd is None:  # pragma: no cover
        raise CheckpointError("CRAFT_COMPRESS=zstd but zstandard missing")
    n = flat.size
    offsets = list(range(0, n, chunk_bytes)) if n else []
    rel = _manifest_name(path, ctx)
    # Previous-version manifest for this file — usable only when the byte
    # layout is unchanged (same total size, same chunk grid); a reshaped or
    # regridded array falls back to a full literal write.
    prev = None
    if ctx.delta_prev is not None:
        cand = ctx.delta_prev.get(rel)
        if (
            cand is not None
            and int(cand.get("nbytes", -1)) == int(n)
            and int(cand.get("chunk_bytes", -1)) == chunk_bytes
            and len(cand.get("rdigests", ())) == len(offsets)
        ):
            prev = cand

    # Change-detection pass: the fused device snapshot already digested
    # every chunk next to the data — consume those digests when the grid
    # matches; otherwise digest every raw chunk in one batched kernel
    # dispatch.  This is the whole per-version cost of a clean chunk.
    dm = ctx.lookup_device_meta(rel, n, chunk_bytes, len(offsets))
    raw_digests = (dm["rdigests"] if dm is not None
                   else (_digest_all_chunks(flat, chunk_bytes) if n else []))

    def encode(i: int, off: int):
        raw = flat[off: off + chunk_bytes]
        rdigest = [int(d) for d in raw_digests[i]]
        if prev is not None and list(prev["rdigests"][i]) == rdigest:
            # clean chunk: reference the base version instead of re-writing
            return None, {"ref": int(ctx.delta_base), "ulen": int(raw.size),
                          "rdigest": rdigest}
        if compress == "zstd" and _gate_allows_zstd(i, raw, ctx, dm):
            stored = _compressor(ctx.zstd_level).compress(raw)
            digest = _digest_chunk(stored)
        elif compress == "zstd":
            # gated raw chunk inside a zstd file: stored == raw bytes
            stored = memoryview(raw)
            return stored, {"clen": len(stored), "ulen": int(raw.size),
                            "digest": rdigest, "rdigest": rdigest,
                            "enc": "raw"}
        else:
            stored = memoryview(raw)
            digest = rdigest          # stored bytes == raw bytes
        return stored, {"clen": len(stored), "ulen": int(raw.size),
                        "digest": digest, "rdigest": rdigest}

    encoded = run_jobs(
        [lambda i=i, off=off: encode(i, off)
         for i, off in enumerate(offsets)], ctx)
    chunks_meta = [meta for _, meta in encoded]
    header = json.dumps(
        {
            "fmt": CODEC_V2,
            "dtype": _dtype_to_name(arr.dtype),
            "shape": shape,
            "compress": compress,
            "checksum": "fletcher",   # v2 always digests (delta detector)
            "chunk_bytes": chunk_bytes,
            "nbytes": int(n),
            "chunks": chunks_meta,
        }
    ).encode()
    _atomic_write_file(
        path,
        [_MAGIC, len(header).to_bytes(8, "little"), header,
         *(stored for stored, _ in encoded if stored is not None)],
        ctx,
    )
    # manifest digest: fold the raw digests (stable across literal/ref form)
    folded = 0
    for meta in chunks_meta:
        folded = zlib.crc32(
            meta["rdigest"][0].to_bytes(4, "little")
            + meta["rdigest"][1].to_bytes(4, "little"),
            folded,
        )
    ctx.record_checksum(rel, folded)
    n_ref = sum(1 for m in chunks_meta if "ref" in m)
    ctx.record_chunks(rel, {
        "rdigests": [m["rdigest"] for m in chunks_meta],
        "ulens": [m["ulen"] for m in chunks_meta],
        "nbytes": int(n),
        "chunk_bytes": chunk_bytes,
        "refs": n_ref,
    })
    ctx.record_io(sum(m.get("clen", 0) for m in chunks_meta),
                  chunks=len(chunks_meta), ref_chunks=n_ref)


def read_array(path: Path, ctx: IOContext) -> np.ndarray:
    """Read an array written by any codec version (v0 legacy or v1 chunked).

    When ``ctx.array_cache`` holds a decoded array for ``path`` (memory-tier
    restore), it is returned directly as a read-only view — callers that need
    ownership of the buffer must copy.
    """
    if ctx.array_cache is not None:
        hit = ctx.array_cache.get(str(path))
        if hit is not None:
            view = hit.view()
            view.setflags(write=False)
            return view
    if not path.exists():
        raise CheckpointError(f"missing checkpoint file {path}")

    def attempt():
        if ctx.chaos is not None:
            ctx.chaos.check("read", path=path)
        with open(path, "rb") as fh:
            header = _parse_stream_header(fh, path)
            fmt = header.get("fmt", CODEC_V0)
            if fmt == CODEC_V0:
                return _read_payload_v0(fh, header, path, ctx)
            if fmt == CODEC_V1:
                return _read_payload_v1(fh, header, path, ctx)
            if fmt == CODEC_V2:
                return _read_payload_v2(fh, header, path, ctx)
            raise CheckpointError(
                f"{path}: format v{fmt} is newer than this reader understands"
            )

    arr = _retrying(attempt, ctx)
    ctx.record_read(int(arr.nbytes))
    return arr


def _parse_stream_header(fh, path: Path) -> dict:
    """Parse magic + length-prefixed JSON header; fh is left at the payload."""
    if fh.read(4) != _MAGIC:
        raise CheckpointError(f"bad magic in {path}")
    raw_hlen = fh.read(8)
    if len(raw_hlen) != 8:
        raise CheckpointError(f"truncated header in {path}")
    hlen = int.from_bytes(raw_hlen, "little")
    raw_header = fh.read(hlen)
    if len(raw_header) != hlen:
        raise CheckpointError(f"truncated header in {path}")
    try:
        return json.loads(raw_header.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as exc:
        raise CheckpointError(f"corrupt header in {path}: {exc}") from exc


def read_chunk_manifest(path: Path) -> Optional[dict]:
    """Header-only read of a chunked array file (delta-diff priming).

    Returns ``{"fmt", "chunk_bytes", "nbytes", "compress", "chunks"}`` for a
    v1/v2 file, or None when the file is not a chunked CRFT array (v0 blobs,
    JSON manifests, foreign files).  Never reads the payload.
    """
    try:
        with open(path, "rb") as fh:
            if fh.read(4) != _MAGIC:
                return None
            fh.seek(0)
            header = _parse_stream_header(fh, path)
    except (OSError, CheckpointError):
        return None
    if header.get("fmt", CODEC_V0) not in (CODEC_V1, CODEC_V2):
        return None
    return {
        "fmt": header["fmt"],
        "chunk_bytes": int(header.get("chunk_bytes", 0)),
        "nbytes": int(header.get("nbytes", 0)),
        "compress": header.get("compress", "none"),
        "checksum": header.get("checksum", "none"),
        "chunks": header.get("chunks", []),
    }


def _restore_shape(payload: bytes, header: dict, path: Path) -> np.ndarray:
    dtype = _dtype_from_name(header["dtype"])
    shape = header["shape"]
    expected = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
    if len(payload) != expected:
        raise CheckpointError(
            f"truncated payload in {path}: got {len(payload)} bytes, "
            f"expected {expected} for {header['dtype']}{tuple(shape)}"
        )
    arr = np.frombuffer(bytearray(payload), dtype=dtype)
    return arr.reshape(shape)


def _read_payload_v0(fh, header: dict, path: Path, ctx: IOContext) -> np.ndarray:
    raw_digest = fh.read(8)
    if len(raw_digest) != 8:
        raise CheckpointError(f"truncated payload in {path}")
    digest = int.from_bytes(raw_digest, "little")
    payload = fh.read()
    if ctx.checksum != "none" and digest and zlib.crc32(payload) != digest:
        raise CheckpointError(f"checksum mismatch in {path}")
    if header["compress"] == "zstd":
        if _zstd is None:  # pragma: no cover
            raise CheckpointError("file is zstd-compressed but zstandard missing")
        try:
            payload = _decompressor().decompress(payload)
        except _zstd.ZstdError as exc:
            raise CheckpointError(f"corrupt zstd payload in {path}: {exc}") from exc
    return _restore_shape(payload, header, path)


def _read_payload_v1(fh, header: dict, path: Path, ctx: IOContext) -> np.ndarray:
    verify = ctx.checksum != "none" and header.get("checksum", "none") != "none"
    # phase 1: sequential file IO — read every chunk's stored bytes
    raw_chunks = []
    for i, meta in enumerate(header["chunks"]):
        stored = fh.read(meta["clen"])
        if len(stored) != meta["clen"]:
            raise CheckpointError(
                f"truncated payload in {path}: chunk {i} got "
                f"{len(stored)}/{meta['clen']} bytes"
            )
        raw_chunks.append(stored)
    if fh.read(1):
        raise CheckpointError(f"trailing bytes after last chunk in {path}")

    # phase 2: digest verification + decompression fan out across the pool
    def decode(i: int) -> bytes:
        stored, meta = raw_chunks[i], header["chunks"][i]
        if verify and _digest_chunk(stored) != list(meta["digest"]):
            raise CheckpointError(f"checksum mismatch in {path} (chunk {i})")
        if header["compress"] == "zstd" and meta.get("enc") != "raw":
            if _zstd is None:  # pragma: no cover
                raise CheckpointError(
                    "file is zstd-compressed but zstandard missing")
            try:
                stored = _decompressor().decompress(stored)
            except _zstd.ZstdError as exc:
                raise CheckpointError(
                    f"corrupt zstd chunk {i} in {path}: {exc}"
                ) from exc
        if len(stored) != meta["ulen"]:
            raise CheckpointError(
                f"corrupt chunk {i} in {path}: inflated to {len(stored)} "
                f"bytes, expected {meta['ulen']}"
            )
        return stored

    parts = run_jobs(
        [lambda i=i: decode(i) for i in range(len(raw_chunks))], ctx)
    out = b"".join(parts)
    if len(out) != header["nbytes"]:
        raise CheckpointError(
            f"truncated payload in {path}: got {len(out)} bytes, "
            f"expected {header['nbytes']}"
        )
    return _restore_shape(out, header, path)


def _decompress_chunk(stored: bytes, compress: str, path: Path, i: int,
                      meta: Optional[dict] = None) -> bytes:
    if compress != "zstd" or (meta is not None and meta.get("enc") == "raw"):
        return stored
    if _zstd is None:  # pragma: no cover
        raise CheckpointError("file is zstd-compressed but zstandard missing")
    try:
        return _decompressor().decompress(stored)
    except _zstd.ZstdError as exc:
        raise CheckpointError(f"corrupt zstd chunk {i} in {path}: {exc}") from exc


def _read_payload_v2(fh, header: dict, path: Path, ctx: IOContext) -> np.ndarray:
    """Delta-aware reader: literal chunks come from this file, ref chunks are
    resolved from the base versions' copies of the same relative path."""
    verify = ctx.checksum != "none"
    chunks = header["chunks"]
    # phase 1: sequential file IO — slurp every *literal* chunk's bytes
    raw_chunks: List[Optional[bytes]] = []
    for i, meta in enumerate(chunks):
        if "ref" in meta:
            raw_chunks.append(None)
            continue
        stored = fh.read(meta["clen"])
        if len(stored) != meta["clen"]:
            raise CheckpointError(
                f"truncated payload in {path}: chunk {i} got "
                f"{len(stored)}/{meta['clen']} bytes"
            )
        raw_chunks.append(stored)
    if fh.read(1):
        raise CheckpointError(f"trailing bytes after last chunk in {path}")

    # phase 2: verify/decompress literals and resolve refs across the pool
    hcache: dict = {}   # str(base file) -> (header, per-chunk payload offsets)
    rel = None
    if ctx.rel_root is not None:
        try:
            rel = path.relative_to(ctx.rel_root)
        except ValueError:
            rel = None

    def decode(i: int) -> bytes:
        meta = chunks[i]
        if "ref" in meta:
            return _resolve_ref_chunk(
                rel, path, ctx, int(meta["ref"]), i, int(meta["ulen"]),
                list(meta["rdigest"]), verify, hcache)
        stored = raw_chunks[i]
        if verify and _digest_chunk(stored) != list(meta["digest"]):
            raise CheckpointError(f"checksum mismatch in {path} (chunk {i})")
        out = _decompress_chunk(stored, header["compress"], path, i, meta)
        if len(out) != meta["ulen"]:
            raise CheckpointError(
                f"corrupt chunk {i} in {path}: inflated to {len(out)} "
                f"bytes, expected {meta['ulen']}"
            )
        return out

    parts = run_jobs([lambda i=i: decode(i) for i in range(len(chunks))], ctx)
    out = b"".join(parts)
    if len(out) != header["nbytes"]:
        raise CheckpointError(
            f"truncated payload in {path}: got {len(out)} bytes, "
            f"expected {header['nbytes']}"
        )
    return _restore_shape(out, header, path)


def _resolve_ref_chunk(
    rel: Optional[Path], orig_path: Path, ctx: IOContext, version: int,
    idx: int, ulen: int, rdigest: list, verify: bool, hcache: dict,
    hops: int = 0,
) -> bytes:
    """Fetch chunk ``idx`` from the base version's copy of the same file,
    chasing further refs down the chain; every failure mode is an explicit
    :class:`CheckpointError` naming the broken base."""
    if hops > _MAX_REF_HOPS:
        raise CheckpointError(
            f"{orig_path}: delta chain exceeds {_MAX_REF_HOPS} hops at chunk "
            f"{idx} (corrupt chain)"
        )
    if ctx.base_dirs is None or rel is None:
        raise CheckpointError(
            f"{orig_path}: chunk {idx} is a delta ref to base v-{version} but "
            "no base-version directories are available (read the file through "
            "Checkpoint, which materializes the chain)"
        )
    bdir = ctx.base_dirs.get(int(version))
    if bdir is None:
        raise CheckpointError(
            f"{orig_path}: delta base v-{version} is absent from the chain "
            f"(have {sorted(ctx.base_dirs)})"
        )
    bpath = Path(bdir) / rel
    cached = hcache.get(str(bpath))
    if cached is None:
        if not bpath.exists():
            raise CheckpointError(
                f"{orig_path}: delta base file {bpath} is missing "
                f"(base v-{version} incomplete)"
            )
        with open(bpath, "rb") as bfh:
            bheader = _parse_stream_header(bfh, bpath)
            data_off = bfh.tell()
        if bheader.get("fmt", CODEC_V0) not in (CODEC_V1, CODEC_V2):
            raise CheckpointError(
                f"{orig_path}: delta base {bpath} is not a chunked array file"
            )
        offs = []
        off = data_off
        for c in bheader["chunks"]:
            offs.append(off)
            off += int(c.get("clen", 0))
        cached = (bheader, offs)
        hcache[str(bpath)] = cached
    bheader, offs = cached
    bchunks = bheader["chunks"]
    if idx >= len(bchunks) or int(bchunks[idx].get("ulen", -1)) != ulen:
        raise CheckpointError(
            f"{orig_path}: delta base {bpath} chunk grid mismatch at chunk "
            f"{idx} (chain corrupt)"
        )
    bmeta = bchunks[idx]
    if "ref" in bmeta:      # the base chunk is itself a ref — keep chasing
        return _resolve_ref_chunk(rel, orig_path, ctx, int(bmeta["ref"]),
                                  idx, ulen, rdigest, verify, hcache, hops + 1)
    with open(bpath, "rb") as bfh:
        bfh.seek(offs[idx])
        stored = bfh.read(int(bmeta["clen"]))
    if len(stored) != int(bmeta["clen"]):
        raise CheckpointError(
            f"truncated delta base chunk {idx} in {bpath}")
    if verify and _digest_chunk(stored) != list(bmeta["digest"]):
        raise CheckpointError(
            f"checksum mismatch in delta base {bpath} (chunk {idx})")
    out = _decompress_chunk(stored, bheader.get("compress", "none"),
                            bpath, idx, bmeta)
    if len(out) != ulen:
        raise CheckpointError(
            f"corrupt delta base chunk {idx} in {bpath}: inflated to "
            f"{len(out)} bytes, expected {ulen}"
        )
    if verify:
        # bit-identity guard: the resolved raw bytes must match the digest
        # the referring version recorded.  For an uncompressed (or gated-
        # raw) base chunk the stored digest already is the raw digest
        # (metadata compare only).
        raw_dig = (list(bmeta["digest"])
                   if bheader.get("compress", "none") != "zstd"
                   or bmeta.get("enc") == "raw"
                   else _digest_chunk(out))
        if raw_dig != list(rdigest):
            raise CheckpointError(
                f"delta ref mismatch: base {bpath} chunk {idx} content "
                "diverged from the referring version's digest (stale base)"
            )
    return out


# --------------------------------------------------------------------------
# chunk-range reads — the elastic reshard-on-restore primitive
# --------------------------------------------------------------------------
class ChunkRangeReader:
    """Byte-range reads of one array file's *uncompressed payload*.

    The elastic restore path maps a restoring rank's global shard extent
    onto the writing topology's per-file chunk grids; this reader serves the
    resulting byte ranges by verifying/decoding only the chunks a range
    overlaps:

    * **v1/v2 files** never pay a full decode — each touched chunk is read
      at its payload offset, digest-checked, decompressed, and cached for
      subsequent ranges; v2 ``ref`` chunks are chased through the delta base
      versions with the same machinery as the full reader.
    * **memory-tier hits** (``ctx.array_cache``) slice the decoded array
      already resident in RAM — no file IO at all.
    * **v0 monolithic blobs** have no chunk grid: the first range triggers
      one full decode (digest over the whole payload) which later ranges
      slice.

    ``rel``/``base_dirs`` override the delta-ref resolution root for files
    living under a *peer* node's version tree (``IOContext.aux_dirs``),
    where ``ctx.rel_root``/``ctx.base_dirs`` would point at the wrong tree.
    Thread-safe: range reads may fan out across the IO worker pool.
    """

    def __init__(self, path: Path, ctx: IOContext,
                 rel: Optional[Path] = None,
                 base_dirs: Optional[dict] = None):
        self.path = Path(path)
        self.ctx = ctx
        self._lock = threading.Lock()
        self._chunk_cache: dict = {}     # chunk idx -> decoded bytes
        self._hcache: dict = {}          # delta-base header/offset cache
        self._flat: Optional[np.ndarray] = None   # whole decoded payload
        self.header: Optional[dict] = None
        if ctx.array_cache is not None:
            hit = ctx.array_cache.get(str(self.path))
            if hit is not None:
                self._flat = _as_byte_view(hit)
                self.nbytes = int(self._flat.size)
                return
        if not self.path.exists():
            raise CheckpointError(f"missing checkpoint file {self.path}")
        with open(self.path, "rb") as fh:
            self.header = _parse_stream_header(fh, self.path)
            data_off = fh.tell()
        fmt = self.header.get("fmt", CODEC_V0)
        if fmt == CODEC_V0:
            dtype = _dtype_from_name(self.header["dtype"])
            self.nbytes = int(
                np.prod(self.header["shape"], dtype=np.int64)) * dtype.itemsize
            self._offs: List[int] = []
        elif fmt in (CODEC_V1, CODEC_V2):
            self.nbytes = int(self.header["nbytes"])
            # per-chunk *stored* offsets: header end + cumulative clen
            # (ref chunks store no bytes — clen defaults to 0)
            self._offs = []
            off = data_off
            for c in self.header["chunks"]:
                self._offs.append(off)
                off += int(c.get("clen", 0))
        else:
            raise CheckpointError(
                f"{self.path}: format v{fmt} is newer than this reader "
                "understands"
            )
        # delta-ref resolution context: explicit rel/base_dirs for aux-dir
        # files, else derived from the ctx the way the full reader does
        if rel is not None:
            self._rel: Optional[Path] = Path(rel)
        elif ctx.rel_root is not None:
            try:
                self._rel = self.path.relative_to(ctx.rel_root)
            except ValueError:
                self._rel = None
        else:
            self._rel = None
        eff_bases = base_dirs if base_dirs is not None else ctx.base_dirs
        self._ref_ctx = (ctx if eff_bases is ctx.base_dirs
                         else dataclasses.replace(ctx, base_dirs=eff_bases))

    def read(self, start: int, stop: int) -> memoryview:
        """Payload bytes [start, stop) — decoding only what the range needs."""
        start, stop = int(start), int(stop)
        if not 0 <= start <= stop <= self.nbytes:
            raise CheckpointError(
                f"{self.path}: range [{start}, {stop}) outside payload of "
                f"{self.nbytes} bytes"
            )
        if start == stop:
            return memoryview(b"")
        if self._flat is None and self.header.get("fmt", CODEC_V0) == CODEC_V0:
            self._decode_v0()
        if self._flat is not None:
            return memoryview(self._flat[start:stop])
        cb = max(1, int(self.header["chunk_bytes"]))
        first, last = start // cb, (stop - 1) // cb
        parts = []
        for i in range(first, last + 1):
            data = self._chunk(i)
            lo = start - i * cb if i == first else 0
            hi = stop - i * cb if i == last else len(data)
            parts.append(data[lo:hi] if (lo, hi) != (0, len(data)) else data)
        if len(parts) == 1:
            return memoryview(parts[0])
        return memoryview(b"".join(parts))

    def _decode_v0(self) -> None:
        with self._lock:
            if self._flat is not None:
                return
            with open(self.path, "rb") as fh:
                header = _parse_stream_header(fh, self.path)
                arr = _read_payload_v0(fh, header, self.path, self.ctx)
            self.ctx.record_read(int(arr.nbytes))
            self._flat = _as_byte_view(arr)

    def _chunk(self, i: int) -> bytes:
        with self._lock:
            data = self._chunk_cache.get(i)
        if data is not None:
            return data
        meta = self.header["chunks"][i]
        cb = max(1, int(self.header["chunk_bytes"]))
        expect = min(cb, self.nbytes - i * cb)
        if int(meta["ulen"]) != expect:
            raise CheckpointError(
                f"{self.path}: chunk {i} grid mismatch (ulen "
                f"{meta['ulen']} vs expected {expect})"
            )
        verify = (self.ctx.checksum != "none"
                  and self.header.get("checksum", "none") != "none")
        if "ref" in meta:
            data = _resolve_ref_chunk(
                self._rel, self.path, self._ref_ctx, int(meta["ref"]), i,
                int(meta["ulen"]), list(meta["rdigest"]), verify,
                self._hcache)
        else:
            with open(self.path, "rb") as fh:
                fh.seek(self._offs[i])
                stored = fh.read(int(meta["clen"]))
            if len(stored) != int(meta["clen"]):
                raise CheckpointError(
                    f"truncated payload in {self.path}: chunk {i} got "
                    f"{len(stored)}/{meta['clen']} bytes"
                )
            if verify and _digest_chunk(stored) != list(meta["digest"]):
                raise CheckpointError(
                    f"checksum mismatch in {self.path} (chunk {i})")
            data = _decompress_chunk(
                stored, self.header.get("compress", "none"),
                self.path, i, meta)
            if len(data) != int(meta["ulen"]):
                raise CheckpointError(
                    f"corrupt chunk {i} in {self.path}: inflated to "
                    f"{len(data)} bytes, expected {meta['ulen']}"
                )
        self.ctx.record_read(len(data))
        with self._lock:
            self._chunk_cache[i] = data
        return data


def write_json(path: Path, obj, ctx: Optional[IOContext] = None) -> None:
    """Atomic JSON write: tmp + fsync + rename + parent-dir fsync.

    Manifests (``meta.json``, ``deltadeps-*.json``) gate restore decisions,
    so they get the full durability treatment — including the directory
    fsync that makes the rename itself crash-safe.  With a ``ctx`` the
    write also runs under its chaos/retry policy like array payloads.
    """
    payload = json.dumps(obj, indent=1).encode()

    def attempt():
        if ctx is not None and ctx.chaos is not None:
            ctx.chaos.check("write", nbytes=len(payload), path=path)
        tmp = path.with_name(f".tmp-{path.name}-{uuid.uuid4().hex[:8]}")
        try:
            with open(tmp, "wb") as fh:
                fh.write(payload)
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, path)
        except BaseException:
            tmp.unlink(missing_ok=True)
            raise
        tiers.fsync_dir(path.parent)

    if ctx is not None:
        _retrying(attempt, ctx)
    else:
        attempt()


def read_json(path: Path):
    with open(path) as fh:
        return json.load(fh)


# --------------------------------------------------------------------------
# version store — the concrete StorageTier over a plain directory tree
# --------------------------------------------------------------------------
class VersionStore(StorageTier):
    """One checkpoint name's versioned directory tree on one storage tier.

    Multi-process coordination: all processes of ``comm`` share one staging
    directory per version (deterministic name, rank-distinct file names
    inside); ``publish()`` barriers, then rank 0 alone performs the atomic
    rename + metadata commit, then barriers again so no process reads a
    version before it is complete.
    """

    label = "pfs"

    def __init__(
        self, base: Path, name: str, keep_versions: int = 2, comm=None,
        sweep: bool = True,
    ):
        self.root = Path(base) / name
        self.keep_versions = max(1, keep_versions)
        self.comm = comm
        self.root.mkdir(parents=True, exist_ok=True)
        if sweep and self._rank() == 0:
            tiers.sweep_tmp_dirs(self.root)

    def _rank(self) -> int:
        return 0 if self.comm is None else self.comm.rank

    def _barrier(self) -> None:
        if self.comm is not None:
            self.comm.barrier()

    # -- staging ------------------------------------------------------------
    def stage(self, version: int) -> Path:
        tmp = self.root / tiers.staging_dir_name(version)
        tmp.mkdir(parents=True, exist_ok=True)
        return tmp

    def publish(self, staged: Path, version: int, extra_meta: Optional[dict] = None) -> None:
        self._chaos_check("publish", path=staged)
        self._barrier()  # every process finished writing its files
        if self._rank() == 0:
            tiers.atomic_publish_dir(staged, self.root / tiers.version_dir_name(version))
            meta = self.meta()
            versions = sorted(set(meta.get("versions", [])) | {version})
            meta.update(
                {
                    "latest": version,
                    "versions": versions,
                    **(extra_meta or {}),
                }
            )
            write_json(self.root / "meta.json", meta)
            self._retire()
        self._barrier()  # version visible to everyone from here on

    def abort(self, staged: Path) -> None:
        shutil.rmtree(staged, ignore_errors=True)

    # -- reading ------------------------------------------------------------
    def meta(self) -> dict:
        p = self.root / "meta.json"
        if p.exists():
            try:
                return read_json(p)
            except (json.JSONDecodeError, OSError):
                return {}
        return {}

    def latest_version(self) -> int:
        """Latest *complete* version, 0 if none (paper: CP-version counter)."""
        meta = self.meta()
        for v in sorted(meta.get("versions", []), reverse=True):
            if (self.root / tiers.version_dir_name(v)).is_dir():
                return v
        return 0

    def version_dir(self, version: int) -> Path:
        return self.root / tiers.version_dir_name(version)

    def forget_version(self, version: int) -> None:
        """Quarantine one unrepairable version: drop its directory and its
        metadata entries so ``latest_version`` / restore agreement fall back
        to an older intact version instead of re-reading rot (the scrubber's
        last resort when no repair source exists)."""
        shutil.rmtree(self.root / tiers.version_dir_name(version),
                      ignore_errors=True)
        meta = self.meta()
        versions = [v for v in meta.get("versions", []) if v != version]
        meta["versions"] = versions
        if meta.get("latest") == version:
            meta["latest"] = max(versions, default=0)
        write_json(self.root / "meta.json", meta)

    # -- invalidation (nested checkpoints, paper §2.5) -----------------------
    def invalidate_all(self) -> None:
        meta = self.meta()
        for v in meta.get("versions", []):
            shutil.rmtree(self.root / tiers.version_dir_name(v), ignore_errors=True)
        meta["versions"] = []
        meta["latest"] = 0
        write_json(self.root / "meta.json", meta)

    # -- housekeeping --------------------------------------------------------
    def _retire(self) -> None:
        kept = tiers.retire_version_dirs(self.root, self.keep_versions)
        meta = self.meta()
        meta["versions"] = kept
        write_json(self.root / "meta.json", meta)

    def retire_for_space(self) -> bool:
        """ENOSPC emergency: squeeze retention to the newest version (plus
        pinned delta bases) and retract the dropped versions from meta."""
        before = {v for v, _ in tiers.list_version_dirs(self.root)}
        if len(before) <= 1:
            return False
        kept = tiers.retire_version_dirs(self.root, keep=1)
        meta = self.meta()
        meta["versions"] = kept
        write_json(self.root / "meta.json", meta)
        return set(kept) != before
