"""The ``Checkpoint`` class — CRAFT's user-facing CR interface (paper §2.2).

Life cycle (paper Listing 2):

    cp = Checkpoint("myCP", comm)          # directories named by cpName
    cp.add("iteration", it_box)            # gather checkpointables
    cp.add("params", params_box)
    cp.commit()                            # freeze — no further add()
    cp.restart_if_needed()                 # read latest version, if any
    while ...:
        ...
        if cp.need_checkpoint(iteration):  # the policy decides when/where
            cp.update_and_write(iteration)

Scheduling: every committed checkpoint owns a
:class:`~repro.core.scheduler.CheckpointPolicy` that decides, per step,
whether to write and to which tiers — per-tier cadences or Young/Daly
intervals (``CRAFT_TIER_EVERY``), preemption signals (``CRAFT_CP_SIGNAL``),
and a walltime guard (``CRAFT_WALLTIME_SECONDS``); see ``docs/tuning.md``.
The raw ``cp.update_and_write(iteration, cp_freq)`` modulo idiom from earlier
revisions still works — ``cp_freq`` is applied as a frequency gate on top of
the policy — but it is a **deprecated idiom**: new code should rely on the
policy knobs (or probe ``need_checkpoint()``) instead of hand-rolled
``iteration % freq`` checks; the two-argument form is kept for paper parity
and back-compat.

Tiers (``CRAFT_TIER_CHAIN``, fastest first): the optional **memory tier**
(RAM shards replicated onto peer ranks — rapid post-shrink recovery), the
**node tier** (fast node-local storage with partner/XOR redundancy — the SCR
analog) when enabled, and every ``pfs_every``-th version additionally lands
on the **PFS tier** (the durable parallel file system).  Reads drain the
chain in order; writes go through to every chained tier (the memory tier is
skipped for a version when its budget is exceeded — :class:`MemTierError` is
collective, so the fallback is consistent across ranks).
``disable_node_level()`` is the paper's ``disableSCR()``.

Asynchrony (paper §2.4): with ``CRAFT_WRITE_ASYNC=1`` the device→host
snapshot (``update()``) happens inline and the file IO runs on a dedicated
writer thread; with ``CRAFT_WRITE_ASYNC_ZERO_COPY=1`` even the snapshot runs
on the writer thread and the caller must ``wait()`` before mutating the data.
"""
from __future__ import annotations

import dataclasses
import errno
import os
import time
from pathlib import Path
from typing import Dict, Optional

from repro.core import (checkpointables, metrics, nested, storage, telemetry,
                        tiers, trace)
from repro.core.async_writer import AsyncWriter
from repro.core.comm import ChannelComm, NullComm
from repro.core.cpbase import CheckpointError, CpBase, IOContext
from repro.core.env import CraftEnv


class Checkpoint:
    """A named collection of checkpointable objects (paper Fig. 2 ``cpMap``)."""

    def __init__(
        self,
        name: str,
        comm=None,
        env: Optional[CraftEnv] = None,
        node_store_factory=None,
        clock=time.monotonic,
    ):
        if not name or "/" in name or name.startswith("."):
            raise ValueError(f"checkpoint name must be a valid directory name: {name!r}")
        self.name = name
        base_comm = comm if comm is not None else NullComm()
        # All checkpoint coordination runs on a dedicated collective channel
        # so writer-thread barriers never interleave with user collectives.
        self.comm = ChannelComm(base_comm, f"cp:{name}")
        # paper §4.1: env is read exactly once, at Checkpoint definition
        self.env = env if env is not None else CraftEnv.capture()
        self._map: Dict[str, CpBase] = {}
        self._committed = False
        self._closed = False
        self._version = 0                     # in-memory CP-version counter
        self._node_enabled = self.env.use_node_level
        self._node_store_factory = node_store_factory
        self._pfs: Optional[storage.VersionStore] = None
        self._node = None
        self._mem = None
        self._writer: Optional[AsyncWriter] = None
        # scheduling (core/scheduler.py): built at commit() once the tier
        # chain exists; ``clock`` is injectable for deterministic tests
        self._clock = clock
        self._policy = None
        self._scrubber = None
        self._decision_cache = None   # (iteration, version, Decision)
        # resilience plane: the fault injector (CRAFT_CHAOS, None when off)
        # and per-slot circuit breakers (core/health.py), built at commit()
        self._chaos = None
        self._health: Dict[str, object] = {}
        # Per-tier-slot delta state: the chunk manifests of the last version
        # written to (or restored from) that tier, diffed against at the next
        # write.  {"version", "deps": set, "files": {rel: manifest}}
        self._delta_state: Dict[str, dict] = {}
        self._last_write_t = None    # monotonic stamp of the last landed
                                     # version (telemetry /healthz age)
        # StatsView: a plain dict to every existing caller, but numeric
        # writes mirror into the live metrics registry (CRAFT_METRICS) as
        # cp_* series labelled with this checkpoint's name
        self.stats = metrics.StatsView(name, {
            "writes": 0,
            "mem_writes": 0,
            "mem_skipped": 0,
            "node_writes": 0,
            "pfs_writes": 0,
            "bytes_written": 0,       # logical payload size (all tiers)
            "tier_bytes_written": 0,  # bytes physically written by the codec
            "delta_chunks_total": 0,
            "delta_chunks_skipped": 0,   # chunks written as refs, not bytes
            "delta_compactions": 0,
            "write_seconds": 0.0,
            "reads": 0,
            "read_seconds": 0.0,
            "restore_tier": None,     # label of the tier the last read used
            "tier_reads": {},         # successful restores per tier label
            "restore_read_bytes": 0,  # payload bytes the last restore fetched
                                      # (range reads < full payload on N→M)
            "mem_rehydrations": 0,    # fabric slots re-seeded after mem
                                      # restores (CRAFT_ELASTIC_HYDRATE)
            "preempt_flushes": 0,     # CRAFT_CP_SIGNAL-triggered sync flushes
            "final_writes": 0,        # walltime-guard final full checkpoints
            "read_repairs": 0,        # restores saved by repair-on-read
            "retries": 0,             # transient IO errors absorbed by the
                                      # retry/backoff layer (CRAFT_IO_RETRIES)
            "breaker_trips": 0,       # circuit-breaker CLOSED/HALF_OPEN→OPEN
                                      # transitions across all tiers
            "degraded_writes": 0,     # scheduled tier writes skipped or lost
                                      # to a fault and routed down the chain
            "abandoned_writes": 0,    # hung writes cut off by the
                                      # CRAFT_IO_DEADLINE_S watchdog
            "enospc_retires": 0,      # emergency retention squeezes that
                                      # freed space for a write in flight
        })

    # ------------------------------------------------------------------ add
    def add(self, key: str, obj, **kw) -> None:
        """Register a checkpointable under ``key`` (paper's overloaded add())."""
        if self._committed:
            raise CheckpointError(
                f"Checkpoint {self.name!r} is committed — add() is frozen "
                "(create a new Checkpoint for additional data, paper §2.2)"
            )
        if not key or "/" in key or key.startswith("."):
            raise ValueError(f"checkpoint key must be a valid file name: {key!r}")
        if key in self._map:
            raise CheckpointError(f"duplicate checkpoint key {key!r}")
        # Device-resident snapshot path (CRAFT_DEVICE_SNAPSHOT): jax-backed
        # checkpointables get a fused on-device digest/dirty/entropy pass at
        # update() time, keyed to the same chunk grid the codec writes.
        kw.setdefault("device_snapshot", self.env.device_snapshot)
        kw.setdefault("chunk_bytes", self.env.chunk_bytes)
        # The entropy histogram only feeds the zstd gate — skip the extra
        # device work entirely when no write can consult it.
        kw.setdefault("device_hist", self.env.compress == "zstd"
                      and self.env.zstd_gate_bits > 0)
        self._map[key] = checkpointables.wrap(obj, **kw)

    # --------------------------------------------------------------- commit
    def commit(self) -> None:
        if self._committed:
            raise CheckpointError(f"Checkpoint {self.name!r} already committed")
        if not self._map:
            raise CheckpointError(f"Checkpoint {self.name!r} has no data")
        self._committed = True
        if not self.env.enable:
            return
        # Arm the run-trace recorder (CRAFT_TRACE) and stamp the trace with
        # the knobs this checkpoint was captured under — the replayer
        # re-captures a CraftEnv from exactly this snapshot.
        trace.maybe_install_from_env(self.env)
        # Arm the live telemetry plane (CRAFT_METRICS / CRAFT_METRICS_PORT):
        # the metrics registry, the /metrics + /healthz exporter, and this
        # checkpoint's /healthz registration (weak — no lifetime extension).
        metrics.maybe_install_from_env(self.env)
        telemetry.maybe_start_from_env(self.env)
        telemetry.register_checkpoint(self)
        trace.TRACER.emit(
            "config",
            name=self.name,
            **trace.env_snapshot(self.env, payload_bytes=self.nbytes(),
                                 comm_size=self.comm.size),
        )
        chain = self.env.tier_chain
        if "pfs" in chain:
            self._pfs = storage.VersionStore(
                self.env.cp_path,
                self.name,
                keep_versions=self.env.keep_versions,
                comm=self.comm,
            )
        if "node" in chain and self._node_enabled \
                and self._node_store_factory is not None:
            self._node = self._node_store_factory(self)
        elif "node" in chain and self._node_enabled \
                and self.env.node_cp_path is not None:
            from repro.core.node_level import NodeStore

            self._node = NodeStore(
                base=self.env.node_cp_path,
                name=self.name,
                comm=self.comm,
                env=self.env,
            )
        if "mem" in chain:
            from repro.core.mem_level import MemStore

            self._mem = MemStore(self.name, self.comm, self.env)
        if (
            self.env.write_async
            or self.env.write_async_zero_copy
            or self.env.io_workers > 1
        ):
            # The ordered lane serializes versions (async modes); the worker
            # pool fans out per-array/per-chunk IO — also used in sync mode.
            self._writer = AsyncWriter(
                workers=self.env.io_workers,
                pin_cpulist=self.env.async_thread_pin_cpulist,
                name=f"craft-writer-{self.name}",
            )
        if self.env.chaos:
            from repro.core.chaos import ChaosEngine

            self._chaos = ChaosEngine(self.env.chaos, seed=self.env.chaos_seed)
            for store, slot, _ in self._chained_stores():
                store.chaos_scope = self._chaos.scope(slot)
        from repro.core.health import TierHealth

        self._health = {
            slot: TierHealth(
                slot,
                threshold=self.env.breaker_threshold,
                cooldown_s=self.env.breaker_cooldown_s,
                clock=self._clock,
            )
            for _, slot, _ in self._chained_stores()
        }
        from repro.core.scheduler import CheckpointPolicy

        stores = {slot: store for store, slot, _ in self._chained_stores()}
        writer = self._writer
        self._policy = CheckpointPolicy(
            self.env,
            stores,
            clock=self._clock,
            backpressure=(lambda: writer.pending) if writer is not None
            else None,
            # the simulator/runtime communicators expose an empirical MTBF
            # from their failure log; plain NullComm does not (→ None)
            mtbf_fn=getattr(self.comm, "empirical_mtbf", None),
        )
        if self.env.cp_signal:
            self._policy.install_signal_handlers()
        from repro.core.scrubber import Scrubber

        # always built: repair-on-read works even when background scrubbing
        # (CRAFT_SCRUB_EVERY) is off — the policy gates the idle slices
        self._scrubber = Scrubber(self)

    # ----------------------------------------------------- nested (subCP())
    def sub_cp(self, child: "Checkpoint") -> None:
        """Declare ``child`` a nested checkpoint of ``self`` (paper §2.5)."""
        nested.GLOBAL_REGISTRY.link(self, child)

    def disable_node_level(self) -> None:
        """Keep this checkpoint off the node tier (paper ``disableSCR()``)."""
        if self._committed:
            raise CheckpointError("disable_node_level() must precede commit()")
        self._node_enabled = False

    def invalidate(self) -> None:
        """Wipe every stored version of this checkpoint (nested-child wipe)."""
        self._delta_state.clear()
        for store, _, _ in self._chained_stores():
            store.invalidate_all()

    def _chained_stores(self):
        """[(store, chain_slot, store.label)] in CRAFT_TIER_CHAIN order.

        The chain slot ("mem"/"node"/"pfs") selects write/read *semantics*
        (best-effort RAM, every-version node, pfs_every-gated PFS) even for
        factory-injected stores; ``store.label`` is the display name feeding
        stats["restore_tier"] and restore-error reports.
        """
        by_slot = {"mem": self._mem, "node": self._node, "pfs": self._pfs}
        return [
            (by_slot[slot], slot, by_slot[slot].label)
            for slot in self.env.tier_chain
            if by_slot[slot] is not None
        ]

    # ---------------------------------------------------------------- write
    def update_and_write(
        self, iteration: Optional[int] = None, cp_freq: int = 1
    ) -> bool:
        """Write a new checkpoint version if the policy schedules one.

        ``cp_freq`` is the paper's fixed-frequency gate, applied on top of
        the policy (deprecated idiom — prefer the ``CRAFT_TIER_EVERY`` /
        Daly knobs; see the module docstring).  Returns True when a version
        was (or began being) written.
        """
        decision = self._decide(iteration, cp_freq)
        if not decision.write:
            return False
        version = self._version + 1

        if decision.sync:
            # preemption / walltime flush: drain in-flight versions, then
            # write inline so the version is durable before returning.
            if self._writer is not None:
                self._writer.wait()
            self._snapshot_and_write(version, decision)
        elif self.env.write_async_zero_copy:
            # zero-copy: snapshot *and* IO on the writer thread; the caller
            # must wait() before mutating live data (paper §2.4).
            self._writer.submit(
                lambda v=version, d=decision: self._snapshot_and_write(v, d),
                label=f"{self.name} v-{version}")
        elif self.env.write_async:
            # copy-based: snapshot inline (cheap D2H), IO on writer thread.
            self._update_all()
            self._writer.submit(
                lambda v=version, d=decision: self._write_version(v, d),
                label=f"{self.name} v-{version}")
        else:
            # synchronous: IO inline — the writer (if any) only serves
            # run_parallel fanout of per-array/per-chunk jobs.
            self._update_all()
            self._write_version(version, decision)
        self._version = version
        self._last_write_t = self._clock()
        metrics.set_gauge("cp_version", version, cp=self.name)
        self._policy.record_written(decision, version)
        if decision.reason == "preempt":
            self.stats.inc("preempt_flushes")
        if decision.final:
            self.stats.inc("final_writes")
        return True

    # ------------------------------------------------------------ scheduling
    @property
    def policy(self):
        """The :class:`CheckpointPolicy` deciding when/where to write
        (``None`` before commit() or when checkpointing is disabled)."""
        return self._policy

    @property
    def scrubber(self):
        """The :class:`~repro.core.scrubber.Scrubber` guarding this
        checkpoint's tiers (``None`` before commit()/when disabled).  Call
        ``scrubber.scan_once()`` for a synchronous full integrity pass."""
        return self._scrubber

    @property
    def should_stop(self) -> bool:
        """The application should exit its loop: a preemption flush landed
        or the walltime guard wrote its final checkpoint."""
        return self._policy is not None and self._policy.should_stop

    def need_checkpoint(
        self, iteration: Optional[int] = None, cp_freq: int = 1
    ) -> bool:
        """Should this step checkpoint?  (paper §2 ``needCheckpoint()``.)

        Delegates to the :class:`CheckpointPolicy`; the decision is cached so
        the canonical ``if cp.need_checkpoint(it): cp.update_and_write(it)``
        pattern evaluates the policy exactly once per step.
        """
        return self._decide(iteration, cp_freq).write

    def _decide(self, iteration: Optional[int], cp_freq: int):
        from repro.core.scheduler import Decision

        self._require_committed()
        if not self.env.enable or self._policy is None:
            return Decision(write=False)
        cached = self._decision_cache
        if cached is not None and cached[0] == iteration \
                and cached[1] == self._version:
            return cached[2]
        d = self._policy.need_checkpoint(
            iteration, cp_freq, next_version=self._version + 1)
        # a skip with no iteration key would never invalidate (the version
        # does not advance) — recompute those instead of pinning the cache
        if d.write or iteration is not None:
            self._decision_cache = (iteration, self._version, d)
        if not d.write and self._scrubber is not None:
            # skipped steps are the scrubber's idle windows (throttled by
            # CRAFT_SCRUB_EVERY / CRAFT_SCRUB_BYTES_PER_S via the policy)
            self._scrubber.opportunity()
        # Async stall watchdog: heartbeat gauge + one warning per job that
        # outlives CRAFT_IO_DEADLINE_S — only when some observer is armed.
        if self._writer is not None and (metrics.REGISTRY.enabled
                                         or trace.TRACER.enabled):
            self._writer.check_stall(self.env.io_deadline_s)
        return d

    def _update_all(self) -> None:
        for item in self._map.values():
            item.update()

    def _snapshot_and_write(self, version: int, decision=None) -> None:
        self._update_all()
        self._write_version(version, decision)

    def _write_version(self, version: int, decision=None) -> None:
        """Write ``version`` to the scheduled tiers, degrading around faults.

        Per tier: an open circuit breaker skips the tier outright; a write
        failure (after the storage layer's transient retries) records a
        breaker failure and, either way, the tier's payload is *routed* to
        the next chain level so the version still lands somewhere durable.
        A degraded tier's delta state is dropped — its next successful write
        (breaker re-admission) is forced full, so no delta chain ever spans
        an outage.  ``ENOSPC`` gets one emergency retention squeeze + retry
        before degrading.  Only when *no* tier lands does the last error
        propagate (the caller keeps the previous version; the in-memory
        version counter does not advance).
        """
        from repro.core import health as health_mod
        from repro.core.chaos import ChaosCrash
        from repro.core.mem_level import MemTierError

        t0 = time.perf_counter()
        wrote_bytes = sum(item.nbytes() for item in self._map.values())
        # the policy picked the tier set; a missing decision (internal
        # callers) falls back to the legacy every-tier + pfs_every gating
        if decision is not None:
            slots = set(decision.tiers)
            force_full = decision.full
        else:
            to_pfs = (
                self._node is None
                or self.env.pfs_every <= 1
                or version % self.env.pfs_every == 0
            )
            slots = {s for _, s, _ in self._chained_stores()
                     if s != "pfs" or to_pfs}
            force_full = False
        # cheap half-open probes first: a tripped tier past its cooldown is
        # re-admitted (or re-opened) by a metadata touch, never by gambling
        # the full version write below.  Degraded slots keep the policy
        # always-due, so the scrubber's idle windows cannot reach a tripped
        # tier — the front of the write is its other probe ride.
        self._probe_tiers()
        landed = []
        routed = False        # a shallower tier's payload needs a new home
        last_exc: Optional[BaseException] = None
        for store, slot, _ in self._chained_stores():
            if slot not in slots and not routed:
                continue
            health = self._health.get(slot)
            if health is not None and not health.allow():
                # breaker open: skip without touching the (known-bad) tier
                self._note_degraded(slot)
                routed = True
                continue
            # a degraded slot's next write is self-contained (no delta base
            # from before the outage) — force full for routed targets too
            tier_full = force_full or routed or slot not in slots
            ts = time.perf_counter()
            try:
                io_stats = self._write_store_guarded(
                    store, version, slot, tier_full)
            except MemTierError:
                # the RAM tier is best-effort write-through: a collective
                # budget refusal skips it, the durable tiers still land
                self.stats.inc("mem_skipped")
                continue
            except ChaosCrash:
                raise             # simulated process death: no cleanup
            except Exception as exc:
                if isinstance(exc, OSError) and exc.errno == errno.ENOSPC \
                        and getattr(store, "retire_for_space",
                                    lambda: False)():
                    self.stats.inc("enospc_retires")
                    try:
                        io_stats = self._write_store_guarded(
                            store, version, slot, tier_full)
                    except ChaosCrash:
                        raise
                    except Exception as exc2:
                        exc = exc2
                    else:
                        exc = None
                if exc is not None:
                    last_exc = exc
                    if isinstance(exc, health_mod.WriteDeadlineExceeded):
                        self.stats.inc("abandoned_writes")
                    if health is not None and health.record_failure(exc):
                        self.stats.inc("breaker_trips")
                        trace.TRACER.emit("breaker", slot=slot)
                    self._note_degraded(slot)
                    routed = True
                    continue
            # tier write landed
            if health is not None:
                health.record_success()
            if self._policy is not None:
                self._policy.note_tier_written(slot)
            landed.append(slot)
            routed = False
            self.stats.inc(f"{slot}_writes")
            # feed the scheduler's per-tier cost model (EWMA on the tier)
            store.record_write(time.perf_counter() - ts, wrote_bytes)
            trace.TRACER.emit(
                "tier_write",
                version=version,
                slot=slot,
                seconds=round(time.perf_counter() - ts, 6),
                nbytes=wrote_bytes,
                phys_bytes=(io_stats or {}).get("bytes", 0),
                chunks=(io_stats or {}).get("chunks", 0),
                ref_chunks=(io_stats or {}).get("ref_chunks", 0),
                full=bool(tier_full),
            )
        if not landed and last_exc is not None:
            # nothing landed anywhere: surface the failure unchanged so the
            # caller sees the original error type (and the version counter
            # stays on the last complete version)
            raise last_exc
        # Parent published ⇒ children are now inconsistent (paper Table 1).
        nested.GLOBAL_REGISTRY.invalidate_children(self)
        self.stats.inc("writes")
        self.stats.inc("bytes_written", wrote_bytes)
        self.stats.inc("write_seconds", time.perf_counter() - t0)

    def _note_degraded(self, slot: str) -> None:
        """Bookkeeping for a tier write that did not land on its tier."""
        self.stats.inc("degraded_writes")
        # no delta chain crosses an outage: the tier's next successful
        # write diffs against nothing, i.e. is a forced full write
        self._delta_state.pop(slot, None)
        if self._policy is not None:
            self._policy.note_degraded(slot)

    def _write_store_guarded(self, store, version: int, slot: str,
                             force_full: bool) -> None:
        """One tier write, under the ``CRAFT_IO_DEADLINE_S`` watchdog: a
        write that exceeds the deadline is abandoned (the helper thread may
        stay hung; it can only abort its own staging dir, never publish)
        instead of wedging the sequencer or a sync commit.  Returns the
        write's codec ``io_stats`` dict."""
        deadline = self.env.io_deadline_s
        if deadline > 0:
            from repro.core.health import call_with_deadline

            return call_with_deadline(
                lambda: self._write_to_store(store, version, slot, force_full),
                deadline, name=f"{self.name} {slot} v-{version}")
        return self._write_to_store(store, version, slot, force_full)

    def _delta_plan(self, slot: str, force_full: bool = False) -> Optional[dict]:
        """Delta state to diff against for this write, or None for a full
        write.  ``force_full`` (preemption flush, walltime final write,
        post-recovery write) always produces a self-contained version.
        Compaction: when the prospective chain (this version + the
        previous version + its recorded bases) would exceed
        ``CRAFT_DELTA_MAX_CHAIN`` versions, fall back to a self-contained
        write so restore/retention never walk unbounded chains."""
        if force_full or not self.env.delta or slot == "mem":
            return None
        state = self._delta_state.get(slot)
        if state is None:
            return None
        prospective = {state["version"]} | set(state["deps"])
        if 1 + len(prospective) > self.env.delta_max_chain:
            self.stats.inc("delta_compactions")
            return None
        return state

    def _write_to_store(self, store, version: int, slot: str = "pfs",
                        force_full: bool = False) -> dict:
        staged = store.stage(version)
        delta_state = self._delta_plan(slot, force_full)
        delta_on = self.env.delta and slot != "mem"
        try:
            checksums: dict = {}
            chunks_db: dict = {}
            io_stats: dict = {}
            ctx = IOContext(
                proc_rank=self.comm.rank,
                proc_count=self.comm.size,
                compress=self.env.compress,
                checksum=self.env.checksum,
                checksum_db=checksums,
                rel_root=staged,
                codec_version=self.env.codec_version,
                chunk_bytes=self.env.chunk_bytes,
                fanout=self._writer.run_parallel if self._writer else None,
                delta_prev=delta_state["files"] if delta_state else None,
                delta_base=delta_state["version"] if delta_state else 0,
                chunks_db=chunks_db if delta_on else None,
                io_stats=io_stats,
                zstd_level=self.env.zstd_level,
                zstd_gate_bits=self.env.zstd_gate_bits,
                device_meta={} if self.env.device_snapshot else None,
                chaos=getattr(store, "chaos_scope", None),
                io_retries=self.env.io_retries,
                io_retry_backoff_ms=self.env.io_backoff_ms,
            )
            overrides = store.write_ctx_overrides()
            if overrides:
                ctx = dataclasses.replace(ctx, **overrides)
            # Independent checkpointables flush in parallel across the IO
            # pool; publish() below is the barrier that preserves per-version
            # ordering (a version is only promoted once every file landed).
            jobs = []
            for key, item in self._map.items():
                sub = staged / key
                sub.mkdir(parents=True, exist_ok=True)
                jobs.append(
                    lambda item=item, sub=sub, key=key:
                    self._run_item_write(item, sub, ctx, slot, version, key))
            storage.run_jobs(jobs, ctx)
            deps: set = set()
            if delta_on:
                # Any ref chunk chains this version on the previous one (and,
                # transitively, on its bases); record the dependency set in
                # the version dir so retention pins bases and restore can
                # check chain completeness without opening array headers.
                if delta_state is not None and any(
                    m.get("refs", 0) for m in chunks_db.values()
                ):
                    deps = {delta_state["version"]} | set(delta_state["deps"])
                storage.write_json(
                    staged / tiers.delta_deps_name(self.comm.rank),
                    {"version": version, "deps": sorted(deps)},
                    ctx=ctx,
                )
            store.publish(
                staged,
                version,
                extra_meta={
                    "keys": sorted(self._map),
                    "codec": self.env.codec_version,
                    # rank 0's view of the per-file digest manifest; restore
                    # checks these files exist before reading the version
                    "checksums": checksums,
                    **({"delta_deps": sorted(deps)} if delta_on else {}),
                },
            )
        except BaseException as exc:
            from repro.core.chaos import ChaosCrash

            # a simulated process death leaves its staging dir behind — the
            # crash-consistency protocol (tmp sweep on next start) owns the
            # cleanup, exactly as after a real crash
            if not isinstance(exc, ChaosCrash):
                store.abort(staged)
            self.stats.inc("retries", io_stats.get("retries", 0))
            raise
        if delta_on:
            self._delta_state[slot] = {
                "version": version, "deps": deps, "files": chunks_db,
            }
        self.stats.inc("tier_bytes_written", io_stats.get("bytes", 0))
        self.stats.inc("delta_chunks_total", io_stats.get("chunks", 0))
        self.stats.inc("delta_chunks_skipped", io_stats.get("ref_chunks", 0))
        self.stats.inc("retries", io_stats.get("retries", 0))
        # per-tier codec series (the delta hit rate is ref_chunks / chunks)
        metrics.inc("tier_phys_bytes", io_stats.get("bytes", 0), slot=slot)
        metrics.inc("tier_chunks", io_stats.get("chunks", 0), slot=slot)
        metrics.inc("tier_ref_chunks", io_stats.get("ref_chunks", 0),
                    slot=slot)
        return io_stats

    def _run_item_write(self, item, sub: Path, ctx: IOContext,
                        slot: str, version: int, key: str) -> None:
        """One checkpointable's write with failure context attached: the
        tier, version and array id ride along on the re-raised error (an
        async failure otherwise surfaces at a later fence with no hint
        where it happened).  OSError keeps its type and errno — callers
        dispatch on them (transient retry, ENOSPC handling)."""
        try:
            item.write(sub, ctx)
        except OSError as exc:
            msg = (f"{slot} tier v-{version} array {key!r}: "
                   f"{exc.strerror or exc}")
            wrapped = type(exc)(exc.errno, msg) if exc.errno is not None \
                else type(exc)(msg)
            raise wrapped from exc
        except CheckpointError as exc:
            raise type(exc)(
                f"{slot} tier v-{version} array {key!r}: {exc}") from exc

    # ----------------------------------------------------------------- read
    def restart_if_needed(self, iteration_box=None) -> bool:
        """Restore the latest consistent version, if any (paper Listing 2).

        Nested semantics (paper §2.5): a non-zero in-memory CP-version means
        this is a successive (inner-loop) call of an already-running program —
        return immediately without reading.

        ``iteration_box`` is accepted for signature parity with the paper's
        ``restartIfNeeded(&iteration)``; the iteration should normally simply
        be one of the added checkpointables.
        """
        self._require_committed()
        if not self.env.enable or not self.env.read_cp_on_restart:
            return False
        if self._version != 0:
            return False  # successive nested-loop call — not a restart
        version = self._agree_version()
        if version <= 0:
            return False
        t0 = time.perf_counter()
        self._read_version(version)
        self._version = version
        self.stats.inc("reads")
        self.stats.inc("read_seconds", time.perf_counter() - t0)
        if self._policy is not None:
            # restart the per-tier interval clocks so the resumed run does
            # not immediately re-write the version it just read
            self._policy.notify_restore()
        return True

    def _agree_version(self) -> int:
        """All processes must restore the same version: min over the best
        *chain-complete* version of each tier, so every rank falls back
        together when a delta version's base chain is gone somewhere."""
        local = 0
        for store, _, _ in self._chained_stores():
            local = max(local, self._restorable_version(store))
        return self.comm.allreduce_min(local)

    def _restorable_version(self, store) -> int:
        """Newest version of ``store`` whose full delta-base chain is present.

        Versions whose directory is not locally visible (e.g. a node-tier
        version recoverable from a partner/parity peer) are trusted here and
        re-validated after materialization in ``_read_version``.
        """
        latest = store.latest_version()
        if latest <= 0:
            return 0
        meta = store.meta() if hasattr(store, "meta") else {}
        candidates = sorted(
            {int(v) for v in meta.get("versions", [])} | {latest},
            reverse=True,
        )
        for version in candidates:
            if version > latest:
                continue
            vdir = Path(store.version_dir(version))
            if not vdir.is_dir():
                if version == latest:
                    return version  # the store claims it (peer-recoverable,
                    #                 e.g. node mirror/XOR) — validated at read
                continue            # stale metadata entry — skip
            deps = tiers.read_delta_deps(vdir)
            if all(Path(store.version_dir(b)).is_dir() for b in deps):
                return version
        return 0

    def _read_version(self, version: int) -> None:
        base_ctx = IOContext(
            proc_rank=self.comm.rank,
            proc_count=self.comm.size,
            compress=self.env.compress,
            checksum=self.env.checksum,
            codec_version=self.env.codec_version,
            chunk_bytes=self.env.chunk_bytes,
            fanout=self._writer.run_parallel if self._writer else None,
            reshard=self.env.reshard,
        )
        errors = []
        for store, slot, label in self._chained_stores():
            for attempt in (0, 1):
                err = self._read_from_store(
                    store, slot, label, version, base_ctx)
                if err is None:
                    return
                # Repair-on-read: a failed verification hands the tier to
                # the scrubber (redundancy rebuild / peer-tier re-encode /
                # quarantine) and the read retries once — a restore never
                # falls through while a same-tier repair is possible.
                if attempt == 0 and self._scrubber is not None \
                        and self._scrubber.repair_version(store, slot, version):
                    self.stats.inc("read_repairs")
                    continue
                errors.append(err)
                break
        raise CheckpointError(
            f"could not restore {self.name!r} v-{version}: " + "; ".join(errors)
        )

    def _read_from_store(self, store, slot, label, version, base_ctx):
        """One tier's restore attempt; returns None on success, else the
        error string to report (the caller may repair and retry once)."""
        ts = time.perf_counter()
        try:
            # may trigger replica / partner / XOR / RS recovery; an
            # unrecoverable tier falls through to the next one (the
            # base-class materialize is a plain local-dir check)
            vdir = store.materialize(version)
        except CheckpointError as exc:
            return f"{label}: {exc}"
        if vdir is None or not Path(vdir).is_dir():
            return f"{label}: version v-{version} not present"
        missing = self._manifest_missing(store, Path(vdir), version)
        if missing:
            return f"{label}: v-{version} incomplete, missing {missing[:3]}"
        # Delta chain: every base version the v2 refs resolve through
        # must be materialized on this same tier before reading; a hole
        # in the chain fails this tier explicitly (no decode crash).
        try:
            base_dirs = self._materialize_chain(store, Path(vdir), version)
        except CheckpointError as exc:
            return f"{label}: v-{version} {exc}"
        overrides = dict(store.read_ctx_overrides(version))
        overrides.setdefault("rel_root", Path(vdir))
        overrides.setdefault("chaos", getattr(store, "chaos_scope", None))
        overrides.setdefault("io_retries", self.env.io_retries)
        overrides.setdefault("io_retry_backoff_ms", self.env.io_backoff_ms)
        if base_dirs:
            overrides.setdefault("base_dirs", base_dirs)
        # Elastic N→M: peer version roots this tier can reach (node tier on a
        # shared FS) complement the materialized dir's shard files.
        aux = store.aux_read_dirs(version) \
            if hasattr(store, "aux_read_dirs") else []
        if aux:
            overrides.setdefault(
                "aux_dirs", tuple(Path(a) for a in aux))
        overrides["io_stats"] = {}
        ctx = dataclasses.replace(base_ctx, **overrides)
        try:
            # independent items restore in parallel (chunk digest checks
            # and decompression fan out across the same pool underneath)
            storage.run_jobs(
                [
                    lambda key=key, item=item: item.read(Path(vdir) / key, ctx)
                    for key, item in self._map.items()
                ],
                ctx,
            )
        except (CheckpointError, OSError) as exc:
            self.stats.inc("retries", (ctx.io_stats or {}).get("retries", 0))
            return f"{label}: {exc}"
        self.stats.inc("retries", (ctx.io_stats or {}).get("retries", 0))
        self.stats["restore_tier"] = label
        self.stats["tier_reads"][label] = \
            self.stats["tier_reads"].get(label, 0) + 1
        self.stats["restore_read_bytes"] = \
            (ctx.io_stats or {}).get("read_bytes", 0)
        metrics.inc("restores", slot=slot)
        metrics.observe("restore_seconds", time.perf_counter() - ts,
                        slot=slot)
        metrics.inc("restore_read_bytes",
                    self.stats["restore_read_bytes"], slot=slot)
        trace.TRACER.emit(
            "restore",
            version=version,
            tier=label,
            slot=slot,
            seconds=round(time.perf_counter() - ts, 6),
            read_bytes=self.stats["restore_read_bytes"],
        )
        if slot == "mem" and self.env.elastic_hydrate \
                and hasattr(store, "rehydrate"):
            # Replacement-rank hydration: a rank that restored from peer
            # replicas re-seeds its own fabric slots so the redundancy
            # group is whole again — all without touching disk.
            self.stats.inc("mem_rehydrations", store.rehydrate(version))
        self._prime_delta_state(version, restored_slot=slot)
        return None

    def _materialize_chain(self, store, vdir: Path, version: int) -> dict:
        """Materialize every delta-base version ``vdir`` depends on; returns
        {base_version: Path}.  Raises :class:`CheckpointError` naming the
        first base that is absent from this tier."""
        deps = tiers.read_delta_deps(vdir)
        base_dirs = {}
        for base in sorted(deps, reverse=True):
            try:
                bdir = store.materialize(base)
            except CheckpointError as exc:
                raise CheckpointError(
                    f"delta base v-{base} unrecoverable: {exc}"
                ) from exc
            if bdir is None or not Path(bdir).is_dir():
                raise CheckpointError(
                    f"delta base v-{base} is missing (chain broken — the "
                    "version cannot be reassembled on this tier)"
                )
            base_dirs[base] = Path(bdir)
        return base_dirs

    def _prime_delta_state(self, version: int, restored_slot: str) -> None:
        """Seed per-tier delta state after a restore so the *first* write of
        the resumed run can already skip clean chunks.

        The chunk digests come from the memory tier's decoded shards when the
        restore was served from RAM (no disk read at all); otherwise from a
        header-only scan of each disk tier's version directory.  Only tiers
        that locally hold ``version`` are primed — a tier without it simply
        does a full write next time.
        """
        if not self.env.delta:
            return
        mem_files = None
        if restored_slot == "mem" and self._mem is not None:
            mem_files = self._mem.chunk_digests(version, self.env.chunk_bytes)
        for store, slot, _ in self._chained_stores():
            if slot == "mem":
                continue
            vdir = Path(store.version_dir(version))
            if not vdir.is_dir():
                continue
            files = mem_files if mem_files is not None \
                else self._delta_files_from_dir(vdir)
            if not files:
                continue
            self._delta_state[slot] = {
                "version": version,
                "deps": tiers.read_delta_deps(vdir),
                "files": files,
            }

    def _delta_files_from_dir(self, vdir: Path) -> dict:
        """Header-only chunk-manifest scan of a version directory (disk-tier
        delta priming).  Files whose raw digests are unknowable (v0 blobs,
        compressed v1 chunks digest post-compression bytes) are skipped and
        will simply be full-written next version."""
        files = {}
        for p in sorted(q for q in vdir.rglob("*") if q.is_file()):
            mf = storage.read_chunk_manifest(p)
            if mf is None or mf["chunk_bytes"] != self.env.chunk_bytes:
                continue
            if mf["fmt"] == storage.CODEC_V1 and mf["compress"] == "zstd":
                continue    # v1+zstd digests the compressed bytes — no rdigest
            if mf["checksum"] == "none":
                continue    # written without digests — nothing to diff
            chunks = mf["chunks"]
            rdigests = [list(c.get("rdigest", c.get("digest", [0, 0])))
                        for c in chunks]
            files[str(p.relative_to(vdir))] = {
                "rdigests": rdigests,
                "ulens": [int(c["ulen"]) for c in chunks],
                "nbytes": mf["nbytes"],
                "chunk_bytes": mf["chunk_bytes"],
            }
        return files

    @staticmethod
    def _manifest_missing(store, vdir: Path, version: int) -> list:
        """Manifest files absent from ``vdir`` (the metadata's file-set check).

        The stored checksum manifest describes the *latest* published version
        only, so older versions (and stores without metadata) skip the check;
        per-file payload integrity is still verified by the in-file digests.
        """
        meta = store.meta() if hasattr(store, "meta") else {}
        if meta.get("latest") != version:
            return []
        return [
            rel for rel in meta.get("checksums", {})
            if not (vdir / rel).exists()
        ]

    # ------------------------------------------------------- health probing
    def _probe_tiers(self) -> None:
        """Half-open probes for tripped tiers, ridden on the scrubber's idle
        windows: a cheap touch/fsync/unlink in the tier root (the chaos gate
        sees it as a write, so a still-faulty tier fails the probe) decides
        re-admission without risking a real version write."""
        for store, slot, _ in self._chained_stores():
            health = self._health.get(slot)
            if health is None or not health.probe_due():
                continue
            if not health.allow():       # another probe is already in flight
                continue
            try:
                self._probe_store(store, slot)
            except Exception as exc:
                if health.record_failure(exc):
                    self.stats.inc("breaker_trips")
                    trace.TRACER.emit("breaker", slot=slot)
            else:
                health.record_success()

    def _probe_store(self, store, slot: str) -> None:
        scope = getattr(store, "chaos_scope", None)
        if scope is not None:
            scope.check("write", path="<health-probe>")
        if slot == "mem":
            return                       # RAM fabric: the gate is the probe
        root = Path(store.version_dir(0)).parent
        root.mkdir(parents=True, exist_ok=True)
        probe = root / f".probe-{self.comm.rank}"
        try:
            with open(probe, "wb") as fh:
                fh.write(b"craft-probe")
                fh.flush()
                os.fsync(fh.fileno())
        finally:
            probe.unlink(missing_ok=True)

    @property
    def chaos(self):
        """The live :class:`~repro.core.chaos.ChaosEngine` (``None`` unless
        ``CRAFT_CHAOS`` armed one at commit) — tests and soak harnesses add
        or clear fault rules on it mid-run."""
        return self._chaos

    @property
    def health(self) -> Dict[str, object]:
        """Per-slot :class:`~repro.core.health.TierHealth` (breaker state)."""
        return self._health

    # ----------------------------------------------------------------- misc
    @property
    def version(self) -> int:
        return self._version

    @property
    def committed(self) -> bool:
        return self._committed

    def keys(self):
        return sorted(self._map)

    def nbytes(self) -> int:
        return sum(item.nbytes() for item in self._map.values())

    def wait(self) -> None:
        """Fence for asynchronous writes (paper ``Checkpoint::wait()``)."""
        if self._writer is not None:
            self._writer.wait()

    def close(self) -> None:
        if self._closed:
            return
        if self._policy is not None:
            self._policy.uninstall_signal_handlers()
        if self._chaos is not None:
            # unblock injected hangs so abandoned writer threads can die
            # (they fail their op and abort their staging; never publish)
            self._chaos.release()
        if self._writer is not None:
            self._writer.close()
        self._closed = True

    def __enter__(self) -> "Checkpoint":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # pragma: no cover
        try:
            self.close()
        except Exception:
            pass

    def _require_committed(self) -> None:
        if not self._committed:
            raise CheckpointError(
                f"Checkpoint {self.name!r} not committed — call commit() first"
            )
