"""Attention modules: GQA (with optional sliding window) and MLA.

Train path (no cache) routes through :func:`repro.kernels.flash_attention.
ops.attention` — the Pallas flash kernel on TPU, the jnp reference on CPU.
Decode path attends over a static-size cache with a dynamic length mask
(GEMV-bound; the flash kernel buys nothing there).

Caches:
  * GQA: ``{"k","v": (B, Hkv, M, hd), "pos"}`` — M = max_len, or M = window
    for SWA (rolling slots: slot = pos % window, which is exactly the entry
    leaving the window).
  * MLA: ``{"ckv": (B, M, kv_lora), "krope": (B, M, rope_dim), "pos"}`` —
    the deepseek compressed-latent cache; per-head K/V are re-expanded from
    the latent on use (the paper-faithful formulation; the absorbed-matmul
    decode optimization is a §Perf hillclimb in EXPERIMENTS.md).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import attention as flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.layers import apply_rope, dense_init, rms_norm
from repro.sharding.activations import constrain

Cache = dict


# =========================================================================
# GQA (llama-family; covers MHA when n_kv_heads == n_heads) + SWA option
# =========================================================================
def gqa_init(key, cfg):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, h, hd), d, cfg.dtype),
        "wk": dense_init(k2, (d, hkv, hd), d, cfg.dtype),
        "wv": dense_init(k3, (d, hkv, hd), d, cfg.dtype),
        "wo": dense_init(k4, (h, hd, d), h * hd, cfg.dtype),
    }


def gqa_logical(cfg):
    return {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }


def gqa_cache_init(cfg, batch: int, max_len: int, dtype) -> Cache:
    m = min(max_len, cfg.window) if cfg.window else max_len
    return {
        "k": jnp.zeros((batch, cfg.n_kv_heads, m, cfg.hd), dtype),
        "v": jnp.zeros((batch, cfg.n_kv_heads, m, cfg.hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def gqa_cache_logical(cfg):
    return {
        "k": ("batch", "kv_heads", "seq", "head_dim"),
        "v": ("batch", "kv_heads", "seq", "head_dim"),
        "pos": (),
    }


def gqa_apply(
    params, x: jnp.ndarray, cfg, positions: jnp.ndarray,
    cache: Optional[Cache] = None,
) -> Tuple[jnp.ndarray, Optional[Cache]]:
    b, l, _ = x.shape
    q = jnp.einsum("bld,dhk->bhlk", x, params["wq"])
    k = jnp.einsum("bld,dhk->bhlk", x, params["wk"])
    v = jnp.einsum("bld,dhk->bhlk", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "heads", None, "head_dim")
    k = constrain(k, "batch", "kv_heads", None, "head_dim")
    v = constrain(v, "batch", "kv_heads", None, "head_dim")

    if cache is None:
        y = flash_attention(q, k, v, causal=True, window=cfg.window)
        new_cache = None
    else:
        m = cache["k"].shape[2]
        pos = cache["pos"]
        rolling = cfg.window is not None and m == cfg.window
        if rolling:
            # keep only the newest min(l, m) entries (unique slots)
            keep = min(l, m)
            slots = (pos + l - keep + jnp.arange(keep)) % m
            ck = _scatter_seq(cache["k"], k[:, :, -keep:], slots)
            cv = _scatter_seq(cache["v"], v[:, :, -keep:], slots)
            if l == 1:
                # decode: every valid slot is inside the newest query's
                # window (the overwritten slot is exactly the one leaving it)
                kv_len = jnp.minimum(pos + 1, m)
                y = attention_ref(q, ck, cv, causal=False, kv_len=kv_len)
            else:
                # single-shot prefill (pos == 0 assumed; chunked SWA prefill
                # would additionally need the previous window from the cache)
                y = flash_attention(q, k, v, causal=True, window=cfg.window)
        else:
            slots = pos + jnp.arange(l)
            ck = _scatter_seq(cache["k"], k, slots)
            cv = _scatter_seq(cache["v"], v, slots)
            if l > 1:
                # single-shot prefill (pos == 0): attention over the chunk
                # itself via the blocked/flash path — O(L·D) memory
                y = flash_attention(q, k, v, causal=True, window=cfg.window)
            else:
                y = attention_ref(q, ck, cv, causal=True, q_offset=pos,
                                  kv_len=pos + l)
        new_cache = {"k": ck, "v": cv, "pos": pos + l}
    out = jnp.einsum("bhlk,hkd->bld", y, params["wo"])
    return out, new_cache


def _scatter_seq(cache_kv: jnp.ndarray, new: jnp.ndarray, slots: jnp.ndarray):
    """Write new (B,H,L,D) entries into cache (B,H,M,D) at ``slots``."""
    return cache_kv.at[:, :, slots, :].set(new.astype(cache_kv.dtype))


# =========================================================================
# MLA — multi-head latent attention (deepseek-v3 / kimi-k2 family)
# =========================================================================
def mla_init(key, cfg):
    d, h = cfg.d_model, cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    keys = jax.random.split(key, 6)
    params = {
        "wkv_a": dense_init(keys[0], (d, cfg.kv_lora_rank + cfg.qk_rope_dim),
                            d, cfg.dtype),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), cfg.dtype),
        "wkv_b": dense_init(keys[1],
                            (cfg.kv_lora_rank, h, cfg.qk_nope_dim + cfg.v_head_dim),
                            cfg.kv_lora_rank, cfg.dtype),
        "wo": dense_init(keys[2], (h, cfg.v_head_dim, d),
                         h * cfg.v_head_dim, cfg.dtype),
    }
    if cfg.q_lora_rank:
        params["wq_a"] = dense_init(keys[3], (d, cfg.q_lora_rank), d, cfg.dtype)
        params["q_norm"] = jnp.ones((cfg.q_lora_rank,), cfg.dtype)
        params["wq_b"] = dense_init(keys[4], (cfg.q_lora_rank, h, qk),
                                    cfg.q_lora_rank, cfg.dtype)
    else:
        params["wq"] = dense_init(keys[5], (d, h, qk), d, cfg.dtype)
    return params


def mla_logical(cfg):
    out = {
        "wkv_a": ("embed", "latent"),
        "kv_norm": (None,),
        "wkv_b": ("latent", "heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.q_lora_rank:
        out["wq_a"] = ("embed", "latent")
        out["q_norm"] = (None,)
        out["wq_b"] = ("latent", "heads", "head_dim")
    else:
        out["wq"] = ("embed", "heads", "head_dim")
    return out


def mla_cache_init(cfg, batch: int, max_len: int, dtype) -> Cache:
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def mla_cache_logical(cfg):
    return {
        "ckv": ("batch", "seq", "latent"),
        "krope": ("batch", "seq", None),
        "pos": (),
    }


def _mla_q(params, x, cfg, positions):
    if cfg.q_lora_rank:
        cq = jnp.einsum("bld,dr->blr", x, params["wq_a"])
        cq = rms_norm(cq, params["q_norm"], cfg.norm_eps)
        q = jnp.einsum("blr,rhk->bhlk", cq, params["wq_b"])
    else:
        q = jnp.einsum("bld,dhk->bhlk", x, params["wq"])
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_dim:], positions, cfg.rope_theta)
    return jnp.concatenate([q_nope, q_rope], axis=-1)


def _mla_expand_kv(params, ckv, krope, cfg):
    """Re-expand per-head K/V from the compressed latent (paper-faithful)."""
    k_nope = jnp.einsum("blr,rhk->bhlk", ckv,
                        params["wkv_b"][..., : cfg.qk_nope_dim])
    v = jnp.einsum("blr,rhk->bhlk", ckv,
                   params["wkv_b"][..., cfg.qk_nope_dim:])
    k_rope = jnp.broadcast_to(
        krope[:, None], (krope.shape[0], cfg.n_heads, krope.shape[1],
                         cfg.qk_rope_dim)
    ).astype(k_nope.dtype)
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    return k, v


def mla_apply(
    params, x: jnp.ndarray, cfg, positions: jnp.ndarray,
    cache: Optional[Cache] = None,
) -> Tuple[jnp.ndarray, Optional[Cache]]:
    b, l, _ = x.shape
    sm_scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    q = _mla_q(params, x, cfg, positions)

    ckv_full = jnp.einsum("bld,dr->blr", x, params["wkv_a"])
    ckv = rms_norm(ckv_full[..., : cfg.kv_lora_rank], params["kv_norm"],
                   cfg.norm_eps)
    krope = apply_rope(
        ckv_full[..., cfg.kv_lora_rank:][:, None], positions, cfg.rope_theta
    )[:, 0]

    if cache is None:
        k, v = _mla_expand_kv(params, ckv, krope, cfg)
        y = flash_attention(q, k, v, causal=True, sm_scale=sm_scale)
        new_cache = None
    else:
        pos = cache["pos"]
        slots = pos + jnp.arange(l)
        cc = cache["ckv"].at[:, slots, :].set(ckv.astype(cache["ckv"].dtype))
        cr = cache["krope"].at[:, slots, :].set(
            krope.astype(cache["krope"].dtype))
        new_cache = {"ckv": cc, "krope": cr, "pos": pos + l}
        if l > 1:
            # single-shot prefill (pos == 0): expand only the chunk's K/V
            k, v = _mla_expand_kv(params, ckv, krope, cfg)
            y = flash_attention(q, k, v, causal=True, sm_scale=sm_scale)
        elif cfg.mla_absorb:
            # absorbed-matmul decode (§Perf iteration 4.1): fold wkv_b into
            # the query/output sides and attend directly over the latent
            # cache — the (B, H, L_ctx, d) per-head K/V re-expansion
            # (hundreds of GB of HBM traffic at decode_32k) never
            # materializes.
            y = _mla_absorbed_decode(params, q, cc, cr, cfg, sm_scale, pos)
        else:
            # paper-faithful latent re-expansion (baseline path, see
            # EXPERIMENTS.md §Perf 4.1)
            k, v = _mla_expand_kv(params, cc, cr, cfg)
            y = attention_ref(q, k, v, causal=True, sm_scale=sm_scale,
                              q_offset=pos, kv_len=pos + l)
    out = jnp.einsum("bhlk,hkd->bld", y, params["wo"])
    return out, new_cache


def _mla_absorbed_decode(params, q, ckv_cache, krope_cache, cfg, sm_scale,
                         pos):
    """Decode attention in latent space (deepseek's absorbed formulation).

    scores  = q_nope·(W_k c) + q_rope·k_rope  =  (W_k^T q_nope)·c + ...
    context = W_v^T (sum_t p_t c_t)

    Per step this costs O(H·(nope+v)·R) weight-absorption matmuls plus
    O(H·M·R) latent attention — no (B, H, M, ·) expanded K/V tensor.
    Identical math to the expanded path (tests assert equality).
    Returns y (B, H, 1, v_head_dim).
    """
    nope = cfg.qk_nope_dim
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    wk = params["wkv_b"][..., :nope]                  # (R, H, nope)
    wv = params["wkv_b"][..., nope:]                  # (R, H, v)
    # fold W_k into the query: (B, H, 1, nope) -> (B, H, 1, R)
    q_lat = jnp.einsum("bhln,rhn->bhlr", q_nope, wk)
    s = jnp.einsum("bhlr,bmr->bhlm", q_lat, ckv_cache) \
        + jnp.einsum("bhlp,bmp->bhlm", q_rope,
                     krope_cache.astype(q_rope.dtype))
    s = s.astype(jnp.float32) * sm_scale              # (B, H, 1, M)
    m = ckv_cache.shape[1]
    valid = jnp.arange(m)[None, None, None] <= pos    # causal over cache
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(ckv_cache.dtype)
    ctx_lat = jnp.einsum("bhlm,bmr->bhlr", p, ckv_cache)
    # unfold W_v on the way out: (B, H, 1, R) -> (B, H, 1, v)
    return jnp.einsum("bhlr,rhv->bhlv", ctx_lat, wv)
