"""Kernel micro-benchmarks: blocked flash vs naive ref, xor parity, checksum.

On this CPU container the Pallas kernels only run in interpret mode
(Python-speed, not meaningful to time), so wall-clock rows compare the
*jitted* blocked/reference implementations; the Pallas kernels' correctness
is covered by tests/test_kernels.py and their TPU roofline by §Roofline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels.checksum import ops as ck_ops
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.xor_parity import ops as xor_ops


def _time(fn, *args, reps=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6   # µs


def flash(full: bool) -> None:
    b, h, d = 1, 4, 64
    for l in ([512, 1024] + ([2048] if full else [])):
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (b, h, l, d), jnp.float32)
        k = jax.random.normal(key, (b, h, l, d), jnp.float32)
        v = jax.random.normal(key, (b, h, l, d), jnp.float32)
        blocked_t = _time(jax.jit(
            lambda q, k, v: fa_ops.attention(q, k, v, causal=True)), q, k, v)
        ref_t = _time(jax.jit(
            lambda q, k, v: attention_ref(q, k, v, causal=True)), q, k, v)
        emit("kernel_flash", f"blocked_L{l}", round(blocked_t, 1), "us")
        emit("kernel_flash", f"naive_ref_L{l}", round(ref_t, 1), "us")


def xor(full: bool) -> None:
    for n in ([1 << 20] + ([1 << 24] if full else [])):
        rng = np.random.default_rng(0)
        stacked = jnp.asarray(
            rng.integers(0, 2 ** 32, (8, n), dtype=np.uint32))
        t = _time(lambda s: xor_ops.xor_reduce(s, use_pallas=False), stacked)
        emit("kernel_xor", f"reduce_8x{n}", round(t, 1), "us")
        gbps = 8 * n * 4 / (t / 1e6) / 1e9
        emit("kernel_xor", f"reduce_8x{n}_bw", round(gbps, 2), "GB/s")


def rs_erasure(full: bool) -> None:
    """GF(2^8) matmul (jitted log/exp-table ref path) for m=1, 2 parity rows."""
    from repro.kernels.rs_erasure import ops as rs_ops

    for n in ([1 << 20] + ([1 << 23] if full else [])):
        rng = np.random.default_rng(0)
        stacked = rng.integers(0, 2 ** 32, (8, n), dtype=np.uint32)
        for m in (1, 2):
            mat = tuple(tuple(int(c) for c in row)
                        for row in rs_ops.rs_matrix(8, m))
            t = _time(lambda s, mat=mat: rs_ops.gf_matmul(
                s, mat, use_pallas=False), stacked)
            emit("kernel_rs", f"encode_m{m}_8x{n}", round(t, 1), "us")
            gbps = 8 * n * 4 / (t / 1e6) / 1e9
            emit("kernel_rs", f"encode_m{m}_8x{n}_bw", round(gbps, 2), "GB/s")


def checksum(full: bool) -> None:
    for nbytes in ([1 << 22] + ([1 << 26] if full else [])):
        rng = np.random.default_rng(0)
        words = jnp.asarray(
            rng.integers(0, 2 ** 32, nbytes // 4, dtype=np.uint32))
        t = _time(lambda w: ck_ops.digest_array(w, use_pallas=False), words)
        emit("kernel_checksum", f"digest_{nbytes}B", round(t, 1), "us")


def snapshot(full: bool) -> None:
    """Fused per-chunk snapshot metadata (digest + dirty + histogram) vs the
    plain per-chunk digest pass, and the numpy CPU-backend twin."""
    from repro.kernels.snapshot import ops as snap_ops

    chunk_bytes = 256 * 1024
    for nbytes in ([1 << 24] + ([1 << 27] if full else [])):
        rng = np.random.default_rng(0)
        raw = rng.integers(0, 2 ** 32, nbytes // 4, dtype=np.uint32)
        n_chunks = nbytes // chunk_bytes
        words2 = jnp.asarray(raw.reshape(n_chunks, chunk_bytes // 4))
        prev = jnp.zeros((n_chunks, 2), jnp.uint32)
        for with_hist in (False, True):
            t = _time(lambda w, p, h=with_hist: snap_ops.snapshot_chunks(
                w, p, with_hist=h, use_pallas=False), words2, prev)
            tag = "hist" if with_hist else "nohist"
            emit("kernel_snapshot", f"fused_{tag}_{nbytes}B",
                 round(t, 1), "us")
            gbps = nbytes / (t / 1e6) / 1e9
            emit("kernel_snapshot", f"fused_{tag}_{nbytes}B_bw",
                 round(gbps, 2), "GB/s")
        host_bytes = raw.view(np.uint8)
        prev_np = np.zeros((n_chunks, 2), np.uint32)
        t = _time(lambda b, p: snap_ops.snapshot_host(b, chunk_bytes, p),
                  host_bytes, prev_np)
        emit("kernel_snapshot", f"host_np_{nbytes}B", round(t, 1), "us")
        emit("kernel_snapshot", f"host_np_{nbytes}B_bw",
             round(nbytes / (t / 1e6) / 1e9, 2), "GB/s")


def main(full: bool = False) -> None:
    flash(full)
    xor(full)
    rs_erasure(full)
    checksum(full)
    snapshot(full)


if __name__ == "__main__":
    main()
