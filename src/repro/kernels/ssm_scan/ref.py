"""Pure-jnp oracle for the selective scan (naive, L-length state tensors)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def ssd_scan_ref(dtx, bh, ch, dt, A, h0):
    """mamba2 reference.  dtx (B,L,nh,hd); bh/ch (B,L,nh,st); dt (B,L,nh);
    A (nh,); h0 (B,nh,hd,st).  Returns (y, h_last)."""
    decay = jnp.exp(dt.astype(jnp.float32) * A[None, None])   # (B,L,nh)
    inject = (dtx.astype(jnp.float32)[..., None]
              * bh.astype(jnp.float32)[:, :, :, None, :])     # (B,L,nh,hd,st)
    a_full = jnp.broadcast_to(decay[..., None, None], inject.shape)
    prod, acc = jax.lax.associative_scan(_combine, (a_full, inject), axis=1)
    h_all = prod * h0.astype(jnp.float32)[:, None] + acc
    y = jnp.einsum("blhds,blhs->blhd", h_all,
                   ch.astype(jnp.float32)).astype(dtx.dtype)
    return y, h_all[:, -1]


def s6_scan_ref(dtx, bh, ch, dt, A, h0):
    """mamba1 reference.  dtx/dt (B,L,di); bh/ch (B,L,st); A (di,st);
    h0 (B,di,st).  Returns (y, h_last)."""
    decay = jnp.exp(dt.astype(jnp.float32)[..., None]
                    * A[None, None])                          # (B,L,di,st)
    inject = (dtx.astype(jnp.float32)[..., None]
              * bh.astype(jnp.float32)[:, :, None, :])        # (B,L,di,st)
    prod, acc = jax.lax.associative_scan(_combine, (decay, inject), axis=1)
    h_all = prod * h0.astype(jnp.float32)[:, None] + acc
    y = jnp.einsum("blds,bls->bld", h_all,
                   ch.astype(jnp.float32)).astype(dtx.dtype)
    return y, h_all[:, -1]
