"""Public ops for XOR parity: padding, byte<->u32 views, backend dispatch.

``parity_of_buffers`` / ``reconstruct_member`` operate on raw byte buffers
(host ``bytes``/``np.uint8``), which is what the node-level checkpoint tier
stores.  On TPU the heavy XOR runs in the Pallas kernel; on CPU hosts the
jitted jnp reference is used (the Pallas interpreter would be Python-speed).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.xor_parity.kernel import xor_reduce as xor_reduce_pallas
from repro.kernels.xor_parity.ref import xor_reduce_ref

_LANE = 512  # pad byte payloads to 512 B = 128 uint32 lanes


def _pad_to_u32(buffers: Sequence[np.ndarray], n_pad: int) -> np.ndarray:
    """Stack uint8 buffers into a (G, n_pad/4) uint32 matrix, zero-padded.

    Buffers that already are exactly ``n_pad`` bytes (bytes-likes included —
    ``np.frombuffer`` is zero-copy) are viewed, not staged through a padded
    copy; only short or non-contiguous buffers pay for a zero-filled row.
    A single full-size buffer therefore stacks with no host copy at all.
    Shared with the RS erasure ops (``kernels/rs_erasure``), whose payloads
    go through the same u32-lane padding.
    """
    rows = []
    for b in buffers:
        if isinstance(b, (bytes, bytearray, memoryview)):
            arr = np.frombuffer(b, dtype=np.uint8)
        else:
            arr = np.ascontiguousarray(b).reshape(-1).view(np.uint8)
        if arr.size != n_pad:
            row = np.zeros(n_pad, dtype=np.uint8)
            row[: arr.size] = arr
            arr = row
        rows.append(arr.view(np.uint32))
    if len(rows) == 1:
        return rows[0].reshape(1, -1)
    return np.stack(rows)


def padded_len(nbytes: int) -> int:
    return ((nbytes + _LANE - 1) // _LANE) * _LANE


def xor_reduce(stacked: jnp.ndarray, *, use_pallas: bool = None) -> jnp.ndarray:
    """Dispatch: Pallas kernel on TPU, jitted reference elsewhere."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        n = stacked.shape[1]
        block = 16384 if n % 16384 == 0 else 128
        return xor_reduce_pallas(stacked, block_n=block)
    return jax.jit(xor_reduce_ref)(stacked)


def parity_of_buffers(buffers: Sequence) -> bytes:
    """XOR parity of a group of byte buffers (zero-padded to equal length)."""
    if not buffers:
        raise ValueError("empty parity group")
    sizes = [len(b) if isinstance(b, (bytes, bytearray)) else b.nbytes for b in buffers]
    n_pad = padded_len(max(sizes))
    stacked = jnp.asarray(_pad_to_u32(buffers, n_pad))
    parity = np.asarray(xor_reduce(stacked))
    return parity.view(np.uint8).tobytes()


def reconstruct_member(
    parity: bytes, survivors: Sequence, lost_size: int
) -> bytes:
    """Recover a lost member: XOR(parity, survivors...), truncated to size."""
    bufs: List = [parity, *survivors]
    n_pad = padded_len(max(len(parity), *(
        len(b) if isinstance(b, (bytes, bytearray)) else b.nbytes for b in bufs
    )))
    stacked = jnp.asarray(_pad_to_u32(bufs, n_pad))
    member = np.asarray(xor_reduce(stacked)).view(np.uint8).tobytes()
    return member[:lost_size]
