"""deepseek-v3-671b — MoE with MLA attention and MTP.

[arXiv:2412.19437; hf]  61L d_model=7168 128H d_ff(expert)=2048
vocab=129280; MoE: 1 shared + 256 routed experts, top-8; first 3 layers
dense (d_ff 18432, from the public config); MLA: q_lora 1536,
kv_lora 512, qk = 128 nope + 64 rope, v 128; multi-token prediction
(1 MTP depth).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, vocab=129280,
    attn_type="mla", n_heads=128,
    q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    d_ff=18432, dense_d_ff=18432, first_dense_layers=3,
    n_experts=256, top_k=8, n_shared_experts=1, moe_d_ff=2048,
    mtp=True,
    tie_embeddings=False,
)

TINY = CONFIG.replace(
    n_layers=4, d_model=64, vocab=512, n_heads=4,
    q_lora_rank=32, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16, d_ff=128, dense_d_ff=128, first_dense_layers=1,
    n_experts=8, top_k=2, moe_d_ff=64,
)
