"""Public checksum ops: byte-buffer digests with backend dispatch."""
from __future__ import annotations

from typing import Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.checksum.kernel import checksum as checksum_pallas
from repro.kernels.checksum.ref import checksum_ref

_BLOCK_BYTES = 512 * 128 * 4  # block_rows=512 tiles of 128 uint32 lanes


def digest_array(x: jnp.ndarray, *, use_pallas: bool = None) -> Tuple[int, int]:
    """(s1, s2) digest of a 1-D uint32 array (padded to block multiple)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    n = x.shape[0]
    block_elems = _BLOCK_BYTES // 4
    pad = (-n) % block_elems
    if pad:
        x = jnp.pad(x, (0, pad))
    if use_pallas:
        out = checksum_pallas(x)
    else:
        out = jax.jit(checksum_ref)(x)
    s1, s2 = np.asarray(out)
    return int(s1), int(s2)


def digest_bytes(buf: Union[bytes, bytearray, np.ndarray]) -> Tuple[int, int]:
    """(s1, s2) digest of a raw byte buffer (zero-padded to 4-byte words)."""
    arr = (
        np.frombuffer(buf, dtype=np.uint8)
        if isinstance(buf, (bytes, bytearray))
        else np.ascontiguousarray(buf).view(np.uint8).ravel()
    )
    pad = (-arr.size) % 4
    if pad:
        arr = np.concatenate([arr, np.zeros(pad, dtype=np.uint8)])
    words = arr.view(np.uint32)
    return digest_array(jnp.asarray(words))
