"""CRAFT core: application-level checkpoint/restart + automatic fault
tolerance (the paper's contribution as a composable library).

Public surface:
    Checkpoint, Box           — paper Listing 2 API
    CpBase, register_adapter  — extension mechanism (paper §2.3)
    aft_zone, AftZone         — AFT_BEGIN/AFT_END analog (paper §3)
    FTComm + backends         — ULFM-semantics communicator
    CraftEnv                  — paper Table 2 environment variables
    StorageTier               — storage backend interface (tiers & codec)
    trace / simulate / tune   — record → replay → auto-tune loop
    metrics / telemetry       — live telemetry plane (/metrics, /healthz)
"""
from repro.core import metrics, telemetry
from repro.core.aft import AftAbortedError, AftZone, aft_zone
from repro.core.checkpoint import Checkpoint
from repro.core.checkpointables import (
    Box, FuncCp, JaxArrayCp, NdArrayCp, PodCp, PytreeCp, ShardCp,
    register_adapter,
)
from repro.core.comm import (
    CommError, FTComm, NullComm, ProcFailedError, RevokedError,
)
from repro.core.cpbase import CheckpointError, CpBase, IOContext
from repro.core.env import CraftEnv
from repro.core.mem_level import MemFabric, MemStore, MemTierError
from repro.core.scheduler import CheckpointPolicy, Decision, daly_interval
from repro.core.tiers import StorageTier

__all__ = [
    "AftAbortedError", "AftZone", "aft_zone",
    "Checkpoint", "Box", "FuncCp", "JaxArrayCp", "NdArrayCp", "PodCp",
    "PytreeCp", "ShardCp", "register_adapter",
    "CommError", "FTComm", "NullComm", "ProcFailedError", "RevokedError",
    "CheckpointError", "CpBase", "IOContext", "CraftEnv", "StorageTier",
    "MemFabric", "MemStore", "MemTierError",
    "CheckpointPolicy", "Decision", "daly_interval",
    "metrics", "telemetry",
]
