"""Pallas TPU kernel: GF(2^8) Reed–Solomon matmul (erasure encode/decode).

The node tier's RS redundancy (``CRAFT_NODE_REDUNDANCY=RS``) multiplies a
static byte matrix with a group of stacked payload buffers over GF(2^8)
(poly 0x11B): encode applies the (m, k) parity matrix, decode applies a
syndrome matrix and then the inverted erasure submatrix — all three are one
``out[r] = XOR_i matrix[r][i] · stacked[i]`` primitive.

TPU mapping.  The textbook log/exp-table product is a gather per byte —
the one operation the VPU is worst at.  Because the matrix entries are
*static* (the coding matrix is fixed at trace time), each constant multiply
is instead unrolled into its xtime chain: ``c·x = XOR_{b: bit b of c}
xtime^b(x)`` where ``xtime`` is the field's multiply-by-2.  On bytes packed
four-per-uint32 lane, xtime is three VPU ops (SWAR: shift masked to stop
cross-byte bleed, conditional 0x1B reduction selected by the high bit), so
a constant multiply costs ≤ 7·3 + 7 bitwise ops and the whole (R, G) matmul
is a static unroll of pure VPU work — no tables, no gathers, no MXU.

Like ``xor_parity`` (the m=1 special case of this kernel, where the parity
row is all ones and every term degenerates to a plain XOR), the group
dimension is small and the payload huge, so the grid tiles N into
VMEM-resident ``(G, block_n)`` uint32 blocks; ``block_n`` multiples of 128
match the (8, 128) int32 VREG tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xtime_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Field multiply-by-2 of bytes packed 4-per-uint32 lane (SWAR).

    The left shift is masked with 0xFEFEFEFE so bit 7 of one byte never
    bleeds into bit 0 of the next; the 0x1B reduction is added exactly to
    the bytes whose high bit was set (their 0/1 mask times 0x1B stays
    inside its own byte, so the uint32 multiply is carry-free).
    """
    hi = jnp.right_shift(x, 7) & jnp.uint32(0x01010101)
    return (jnp.left_shift(x, 1) & jnp.uint32(0xFEFEFEFE)) ^ (hi * jnp.uint32(0x1B))


def _gf_mul_const(c: int, x: jnp.ndarray) -> jnp.ndarray:
    """``c · x`` in GF(2^8) for a static constant c, bytes packed in uint32."""
    if c == 0:
        return jnp.zeros_like(x)
    acc = None
    term = x
    bits = c
    while bits:
        if bits & 1:
            acc = term if acc is None else acc ^ term
        bits >>= 1
        if bits:
            term = _xtime_u32(term)
    return acc


def _gf_matmul_kernel(stacked_ref, out_ref, *, matrix):
    """Apply the static (R, G) byte matrix to the (G, block_n) uint32 tile."""
    tile = stacked_ref[...]
    rows = []
    for r in range(len(matrix)):
        acc = None
        for i, c in enumerate(matrix[r]):
            if c == 0:
                continue
            term = (tile[i:i + 1] if c == 1
                    else _gf_mul_const(c, tile[i:i + 1]))
            acc = term if acc is None else acc ^ term
        if acc is None:                       # all-zero matrix row
            acc = jnp.zeros_like(tile[0:1])
        rows.append(acc)
    out_ref[...] = jnp.concatenate(rows, axis=0)


@functools.partial(jax.jit, static_argnames=("matrix", "block_n", "interpret"))
def gf_matmul(
    stacked: jnp.ndarray, *, matrix, block_n: int = 16384,
    interpret: bool = False,
) -> jnp.ndarray:
    """GF(2^8) product of a static byte matrix with ``(G, N) uint32`` buffers.

    ``matrix`` must be a hashable nested tuple of ints shaped (R, G) with
    entries in 0..255 (it is traced away into the unrolled kernel body).
    N must be a multiple of ``block_n`` (callers pad); ``block_n`` a multiple
    of 128.  Returns ``(R, N) uint32`` — bytes of ``XOR_i matrix[r][i] ·
    member_i`` packed exactly like the input.
    """
    if stacked.ndim != 2:
        raise ValueError(f"expected (G, N), got {stacked.shape}")
    if stacked.dtype != jnp.uint32:
        raise TypeError(f"expected uint32, got {stacked.dtype}")
    g, n = stacked.shape
    mat = tuple(tuple(int(c) for c in row) for row in matrix)
    if not mat or any(len(row) != g for row in mat):
        raise ValueError(f"matrix shape does not match G={g}")
    if any(not 0 <= c <= 255 for row in mat for c in row):
        raise ValueError("matrix entries must be GF(2^8) bytes (0..255)")
    if block_n % 128:
        raise ValueError(f"block_n={block_n} must be a multiple of 128")
    if n % block_n:
        raise ValueError(f"N={n} must be a multiple of block_n={block_n}")
    r = len(mat)
    grid = (n // block_n,)
    out = pl.pallas_call(
        functools.partial(_gf_matmul_kernel, matrix=mat),
        grid=grid,
        in_specs=[pl.BlockSpec((g, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((r, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.uint32),
        interpret=interpret,
    )(stacked)
    return out
