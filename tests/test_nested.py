"""Nested checkpoints: the paper's Table 1 consistency semantics, exactly."""
import pytest

from repro.core import Box, Checkpoint
from repro.core.env import CraftEnv


def _mk(tmp_path):
    return CraftEnv.capture({
        "CRAFT_CP_PATH": str(tmp_path), "CRAFT_USE_SCR": "0"})


def nested_program(env, fail_stage):
    """Paper Listing 7 with nL1iter=2, L1cpFreq=1, nL2iter=30, L2cpFreq=10.

    ``fail_stage`` ∈ I..V — the failure points of paper Fig. 3.  Returns the
    (CL1 versions on disk, CL2 versions on disk) snapshot at failure, i.e.
    what a restart would see.
    """
    l1_box, l2_box = Box(0), Box(0)
    cl1 = Checkpoint("CL1", env=env)
    cl1.add("l1", l1_box)
    cl1.commit()
    cl2 = Checkpoint("CL2", env=env)
    cl2.add("l2", l2_box)
    cl2.commit()
    cl1.sub_cp(cl2)

    # stage I: before anything is written
    if fail_stage == "I":
        return cl1.version, cl2._pfs.latest_version()
    for l1 in range(1, 3):
        for l2 in range(1, 31):
            l2_box.value = l2
            cl2.update_and_write(l2, 10)
            if fail_stage == "II" and (l1, l2) == (1, 10):
                return cl1._pfs.latest_version(), cl2._pfs.latest_version()
            if fail_stage == "III" and (l1, l2) == (1, 20):
                return cl1._pfs.latest_version(), cl2._pfs.latest_version()
        l1_box.value = l1
        cl1.update_and_write(l1, 1)
        if fail_stage == "IV" and l1 == 1:
            return cl1._pfs.latest_version(), cl2._pfs.latest_version()
        if fail_stage == "V" and l1 == 1:
            # continue into the next outer iteration a bit
            for l2 in range(1, 11):
                l2_box.value = l2
                cl2.update_and_write(l2, 10)
            return cl1._pfs.latest_version(), cl2._pfs.latest_version()
    return cl1._pfs.latest_version(), cl2._pfs.latest_version()


# paper Table 1: stage -> the (l1, l2) state a restarted run must resume
# from.  0 means "no checkpoint read — start fresh".  Stage IV is the
# consistency trap: the stale CL2 (l2=30 of the previous outer iteration)
# must have been invalidated when CL1-v1 was published.
TABLE_1 = {
    "I": (0, 0),
    "II": (0, 10),
    "III": (0, 20),
    "IV": (1, 0),
    "V": (1, 10),
}


@pytest.mark.parametrize("stage", list(TABLE_1))
def test_table_1(tmp_path, stage):
    env = _mk(tmp_path)
    nested_program(env, stage)

    # restart: rebuild both checkpoints, read what is consistent
    l1_box, l2_box = Box(0), Box(0)
    cl1 = Checkpoint("CL1", env=env)
    cl1.add("l1", l1_box)
    cl1.commit()
    cl2 = Checkpoint("CL2", env=env)
    cl2.add("l2", l2_box)
    cl2.commit()
    cl1.sub_cp(cl2)
    cl1.restart_if_needed()
    cl2.restart_if_needed()
    assert (l1_box.value, l2_box.value) == TABLE_1[stage]


def test_restart_consistency_after_parent_write(tmp_path):
    """Stage IV end-to-end: restart must resume (l1=1, l2 fresh), never the
    stale CL2-v30."""
    env = _mk(tmp_path)
    nested_program(env, "IV")

    l1_box, l2_box = Box(0), Box(0)
    cl1 = Checkpoint("CL1", env=env)
    cl1.add("l1", l1_box)
    cl1.commit()
    cl2 = Checkpoint("CL2", env=env)
    cl2.add("l2", l2_box)
    cl2.commit()
    cl1.sub_cp(cl2)
    assert cl1.restart_if_needed()
    assert not cl2.restart_if_needed()   # invalidated by parent publish
    assert (l1_box.value, l2_box.value) == (1, 0)


def test_inner_restart_only_reads_once(tmp_path):
    """Paper §2.5: restartIfNeeded() of the inner CP is called every outer
    iteration but only the first call of a restarted run reads."""
    env = _mk(tmp_path)
    b = Box(0)
    cp = Checkpoint("inner", env=env)
    cp.add("x", b)
    cp.commit()
    b.value = 5
    cp.update_and_write()

    b2 = Box(0)
    cp2 = Checkpoint("inner", env=env)
    cp2.add("x", b2)
    cp2.commit()
    assert cp2.restart_if_needed()       # first call reads v-1
    assert b2.value == 5
    b2.value = 99
    assert not cp2.restart_if_needed()   # successive call: no re-read
    assert b2.value == 99


def test_subcp_cycle_rejected(tmp_path):
    env = _mk(tmp_path)
    a = Checkpoint("A", env=env)
    a.add("x", Box(1))
    a.commit()
    b = Checkpoint("B", env=env)
    b.add("x", Box(1))
    b.commit()
    a.sub_cp(b)
    with pytest.raises(ValueError, match="cycle"):
        b.sub_cp(a)
    with pytest.raises(ValueError, match="own"):
        a.sub_cp(a)


def test_multilevel_grandchild_invalidation(tmp_path):
    env = _mk(tmp_path)
    boxes = [Box(0), Box(0), Box(0)]
    cps = []
    for i, name in enumerate(("L1", "L2", "L3")):
        cp = Checkpoint(name, env=env)
        cp.add("x", boxes[i])
        cp.commit()
        cps.append(cp)
    cps[0].sub_cp(cps[1])
    cps[1].sub_cp(cps[2])
    cps[2].update_and_write()
    cps[1].update_and_write()    # parent of L3 → invalidates L3
    assert cps[2]._pfs.latest_version() == 0
    cps[2].update_and_write()
    cps[0].update_and_write()    # grandparent → invalidates L2 AND L3
    assert cps[1]._pfs.latest_version() == 0
    assert cps[2]._pfs.latest_version() == 0
    assert cps[0]._pfs.latest_version() == 1
