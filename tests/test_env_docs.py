"""docs/env_reference.md must stay in sync with core/env.py.

Two-way check: every ``CRAFT_*`` knob the code reads is documented as a
table row, and no table row documents a knob the code no longer mentions.
A third check walks the ``CraftEnv`` dataclass itself: every field must
name at least one ``CRAFT_*`` knob in its declaration comment, and that
knob must have a doc row — so adding a field without documenting it fails
even if the knob string appears elsewhere in the file.
"""
import dataclasses
import re
from pathlib import Path

from repro.core.env import CraftEnv

REPO = Path(__file__).resolve().parent.parent
ENV_PY = REPO / "src" / "repro" / "core" / "env.py"
DOC = REPO / "docs" / "env_reference.md"

_KNOB = re.compile(r"CRAFT_[A-Z0-9_]+")


def _code_knobs() -> set:
    return set(_KNOB.findall(ENV_PY.read_text()))


def _doc_row_knobs() -> set:
    rows = set()
    for line in DOC.read_text().splitlines():
        if line.startswith("| `CRAFT_"):
            rows.update(_KNOB.findall(line.split("|")[1]))
    return rows


def test_every_code_knob_documented():
    missing = _code_knobs() - _doc_row_knobs()
    assert not missing, (
        f"knobs read by core/env.py but missing from docs/env_reference.md "
        f"tables: {sorted(missing)}"
    )


def test_no_stale_doc_entries():
    stale = _doc_row_knobs() - _code_knobs()
    assert not stale, (
        f"docs/env_reference.md documents knobs core/env.py no longer "
        f"mentions: {sorted(stale)}"
    )


def test_doc_has_rows():
    assert len(_doc_row_knobs()) >= 20   # sanity: the table parser works


def _field_knobs() -> dict:
    """{dataclass field -> set of CRAFT_* knobs named in its declaration}.

    Parses the ``CraftEnv`` class body: a field's block runs from its
    ``name: type`` line to the next field (or the end of the annotations),
    so continuation comments count toward the field they annotate.
    """
    src = ENV_PY.read_text()
    body = src.split("class CraftEnv", 1)[1]
    field_names = [f.name for f in dataclasses.fields(CraftEnv)]
    blocks: dict = {}
    current = None
    for line in body.splitlines():
        decl = re.match(r"\s{4}(\w+):", line)
        if decl and decl.group(1) in field_names:
            current = decl.group(1)
            blocks[current] = set()
        elif line.strip().startswith(("def ", "@staticmethod", "return ")):
            current = None
        if current is not None:
            blocks[current].update(_KNOB.findall(line))
    return blocks


def test_every_env_field_names_a_documented_knob():
    rows = _doc_row_knobs()
    blocks = _field_knobs()
    missing_comment = [f.name for f in dataclasses.fields(CraftEnv)
                       if not blocks.get(f.name)]
    assert not missing_comment, (
        f"CraftEnv fields without a CRAFT_* knob named in their declaration "
        f"comment: {missing_comment}"
    )
    undocumented = {f: sorted(knobs - rows)
                    for f, knobs in blocks.items() if knobs - rows}
    assert not undocumented, (
        f"CraftEnv fields whose knobs lack a docs/env_reference.md row: "
        f"{undocumented}"
    )
