"""Roofline HLO analyzer: trip counts, dot FLOPs, collectives, VMEM scopes.

The analyzer's whole point is fixing XLA cost-analysis' count-scan-body-once
behavior, so the key test compiles a scan and checks the ×N multiplication.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import roofline as R


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


class TestFlops:
    def test_single_dot(self):
        a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
        rep = R.analyze(_compile(lambda x, y: x @ y, a, b).as_text())
        assert rep.flops == 2 * 64 * 128 * 32

    def test_scan_multiplies_by_trip_count(self):
        n = 9

        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, ws)
            return y

        x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
        ws = jax.ShapeDtypeStruct((n, 32, 32), jnp.float32)
        compiled = _compile(f, x, ws)
        rep = R.analyze(compiled.as_text())
        want = n * 2 * 32 * 32 * 32
        assert rep.flops == want
        # XLA's own counter reports one body (the bug we fix); newer jax
        # returns one cost dict per device instead of a bare dict
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        xla = cost["flops"]
        assert xla < want / 2

    def test_nested_scan(self):
        def f(x, ws):
            def outer(c, w):
                def inner(ci, _):
                    return ci @ w, None
                ci, _ = jax.lax.scan(inner, c, jnp.arange(3))
                return ci, None
            y, _ = jax.lax.scan(outer, x, ws)
            return y

        x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
        ws = jax.ShapeDtypeStruct((4, 16, 16), jnp.float32)
        rep = R.analyze(_compile(f, x, ws).as_text())
        assert rep.flops == 4 * 3 * 2 * 16 ** 3


class TestHbmBytes:
    def test_elementwise_traffic(self):
        x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
        rep = R.analyze(_compile(lambda a: jnp.tanh(a) * 2 + 1, x).as_text())
        nbytes = 1024 * 1024 * 4
        # roughly read + write (fusions may add small copies)
        assert nbytes * 1.5 <= rep.hbm_bytes <= nbytes * 4

    def test_scan_stack_writes_counted_per_slice(self):
        """A scan saving per-iteration outputs must charge the slice, not
        the whole stacked buffer, per iteration."""
        n, m = 16, 256

        def f(x):
            def body(c, _):
                c = jnp.sin(c)
                return c, c
            _, ys = jax.lax.scan(body, x, None, length=n)
            return ys

        x = jax.ShapeDtypeStruct((m, m), jnp.float32)
        rep = R.analyze(_compile(f, x).as_text())
        slice_bytes = m * m * 4
        # per iteration ≈ read c + write c + write ys slice (+ fusion
        # copies); the failure mode being guarded is charging the WHOLE
        # (n, m, m) stack per iteration (n× overcount)
        assert rep.hbm_bytes < n * slice_bytes * 10
        assert rep.hbm_bytes > n * slice_bytes * 1.5


class TestParser:
    def test_tuple_types_with_index_comments(self):
        line = ("  %while.163 = (s32[], f32[256,1,2,4096]{3,2,1,0}, "
                "/*index=5*/f32[4,256,1,1024,80]{4,3,2,1,0}) "
                "while(%tuple.1), condition=%cond.1, body=%body.1")
        op = R._parse_op(line)
        assert op is not None and op.opcode == "while"
        assert "body.1" in op.line

    def test_dtype_layout_T_not_an_opcode(self):
        line = ("  %copy.1 = f32[64,512]{1,0:T(8,128)} copy(%x)")
        op = R._parse_op(line)
        assert op.opcode == "copy"

    def test_shape_bytes(self):
        assert R._shape_bytes("bf16[4,8]{1,0}") == 64
        assert R._shape_bytes("(s32[], f32[2,2])") == 4 + 16
        assert R._shape_bytes("pred[16]") == 16


@pytest.mark.slow
class TestSharded:
    """Collective accounting needs >1 device — run in a subprocess with
    forced host devices (never force devices in the test process itself)."""

    def test_collectives_counted(self, tmp_path):
        import subprocess
        import sys
        script = tmp_path / "probe.py"
        script.write_text("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import sys
sys.path.insert(0, "src")
from repro.analysis import roofline as R

mesh = jax.make_mesh((8,), ("d",))
xsh = NamedSharding(mesh, P("d", None))
x = jax.ShapeDtypeStruct((1024, 64), jnp.float32, sharding=xsh)
rep = R.analyze(jax.jit(
    lambda a: a.sum(), in_shardings=(xsh,), out_shardings=None
).lower(x).compile().as_text())
assert rep.collective_bytes > 0, rep.as_dict()
assert "all-reduce" in rep.collective_by_kind
print("OK")
""")
        r = subprocess.run([sys.executable, str(script)], cwd="/root/repo",
                           capture_output=True, text=True, timeout=300)
        assert "OK" in r.stdout, r.stderr[-2000:]
