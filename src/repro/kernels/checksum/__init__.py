from repro.kernels.checksum.ops import digest_array, digest_bytes  # noqa: F401
