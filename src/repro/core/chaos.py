"""Seeded, deterministic storage-fault injection (``CRAFT_CHAOS``).

Multi-level checkpointing is only as good as its behavior when a level
*misbehaves* — yet the only faults the harness could historically inject
were rank death (``comm_sim`` kill hooks) and at-rest corruption
(``scrubber.corrupt_file``).  The chaos engine closes the gap: every fault
class a storage tier can throw at the library is injectable *in band*, on
the live IO paths, and replays bit-identically from a seed.

Fault classes
-------------

============  ==============================================================
``eio``       transient ``OSError(EIO)`` — the retry layer's bread and butter
``erofs``     persistent ``OSError(EROFS)`` — a tier gone read-only (breaker)
``enospc``    ``OSError(ENOSPC)`` — out of space (triggers emergency retire)
``stall``     latency injection: sleep ``ms`` before the operation proceeds
``hang``      indefinite hang (until :meth:`ChaosEngine.release` / a safety
              cap) — what ``CRAFT_IO_DEADLINE_S`` exists to abandon
``torn``      partial write: only a prefix of the file's bytes reach the
              ``.tmp`` file, then ``OSError(EIO)`` — the crash-consistency
              protocol must never let such a file become visible
``crash``     :class:`ChaosCrash` (a ``BaseException``) at an exact
              operation index — simulated process death; staging is *not*
              aborted, exactly like a real crash, so the next start's
              ``sweep_tmp_dirs`` and the atomic-rename protocol are what
              keep the previous version restorable
============  ==============================================================

Spec grammar (``CRAFT_CHAOS``)
------------------------------

Comma-separated rules, each ``slot:fault[:param=value[+param=value...]]``::

    CRAFT_CHAOS="pfs:eio:p=0.05,node:stall:ms=500"
    CRAFT_CHAOS="pfs:erofs:p=1+after=40"
    CRAFT_CHAOS="node:crash:at=17"
    CRAFT_CHAOS="on"                  # engine armed, no rules (tests add
                                      # rules mid-run via ChaosEngine.add)

``slot`` is a chain slot (``mem``/``node``/``pfs``) or ``*``.  Params:

* ``p``      — injection probability per matching operation (default 1.0)
* ``ms``     — stall duration (``stall`` only)
* ``after``  — skip the first N matching operations (fault starts mid-run)
* ``count``  — inject at most N times, then the rule goes inert
* ``at``     — inject exactly at matching-operation index N (``crash``)
* ``op``     — restrict to one operation kind (``read``/``write``/
  ``publish``/``replicate``/``fabric``)

Determinism
-----------

Every IO call site asks its :class:`ChaosScope` (one per tier slot) whether
to inject.  The engine keys a per-``(slot, op)`` operation counter, and the
injection draw for operation *i* uses an RNG seeded from
``(seed, slot, op, i)`` — so the same spec + seed + operation sequence
injects the same faults at the same points, bit-identically, regardless of
wall-clock time or thread scheduling *within* one operation stream.  (With
probabilistic rules across *concurrently racing* streams the interleaving
itself must be deterministic for full replay — the tests drive deterministic
sequences; ``count``/``at``/``after`` rules are replay-safe even under
concurrency per stream.)

The engine records every injection in :attr:`ChaosEngine.log` (bounded) —
the replay-determinism test simply compares two runs' logs.
"""
from __future__ import annotations

import errno
import threading
import time
import zlib
from typing import Dict, List, Optional, Tuple

_OPS = ("read", "write", "publish", "replicate", "fabric")
_FAULTS = ("eio", "erofs", "enospc", "stall", "hang", "torn", "crash")
#: Which fault classes apply to read operations too (the rest are
#: write-side: a read-only filesystem still serves reads).
_READ_FAULTS = ("eio", "stall", "hang", "crash")
_LOG_CAP = 8192
#: Safety cap on an un-released ``hang`` so an abandoned writer thread can
#: never outlive a test session.
_HANG_CAP_S = 600.0


class ChaosCrash(BaseException):
    """Simulated process death at an injection point.

    Deliberately a ``BaseException``: nothing on the write path may catch
    it, clean up staging, or degrade around it — a real crash would not
    have either.  Recovery is the *next* process's job (tmp sweep + the
    atomic-rename protocol).
    """


class ChaosRule:
    """One parsed ``slot:fault:params`` rule."""

    __slots__ = ("slot", "fault", "p", "ms", "after", "count", "at", "op",
                 "injected")

    def __init__(self, slot: str, fault: str, params: Dict[str, str]):
        if fault not in _FAULTS:
            raise ValueError(
                f"CRAFT_CHAOS fault {fault!r}: expected one of {_FAULTS}")
        if slot != "*" and slot not in ("mem", "node", "pfs"):
            raise ValueError(
                f"CRAFT_CHAOS slot {slot!r}: expected mem|node|pfs|*")
        self.slot = slot
        self.fault = fault
        self.p = 1.0
        self.ms = 0.0
        self.after = 0
        self.count: Optional[int] = None
        self.at: Optional[int] = None
        self.op: Optional[str] = None
        self.injected = 0
        for key, val in params.items():
            if key == "p":
                self.p = float(val)
                if not 0.0 <= self.p <= 1.0:
                    raise ValueError(f"CRAFT_CHAOS p={val!r}: expected 0..1")
            elif key == "ms":
                self.ms = float(val)
                if self.ms < 0:
                    raise ValueError(f"CRAFT_CHAOS ms={val!r}")
            elif key == "after":
                self.after = int(val)
            elif key == "count":
                self.count = int(val)
            elif key == "at":
                self.at = int(val)
            elif key == "op":
                if val not in _OPS:
                    raise ValueError(
                        f"CRAFT_CHAOS op={val!r}: expected one of {_OPS}")
                self.op = val
            else:
                raise ValueError(f"CRAFT_CHAOS: unknown param {key!r}")
        if fault == "stall" and self.ms <= 0:
            raise ValueError("CRAFT_CHAOS stall needs ms=<duration>")

    def matches(self, slot: str, op: str, index: int, draw: float) -> bool:
        """Should this rule inject on matching-op ``index`` with RNG ``draw``?"""
        if self.slot != "*" and self.slot != slot:
            return False
        if self.op is not None and self.op != op:
            return False
        if op == "read" and self.fault not in _READ_FAULTS:
            return False
        if self.count is not None and self.injected >= self.count:
            return False
        if self.at is not None:
            return index == self.at
        if index < self.after:
            return False
        return draw < self.p

    def spec(self) -> str:
        parts = [self.slot, self.fault]
        params = []
        if self.p != 1.0:
            params.append(f"p={self.p}")
        if self.ms:
            params.append(f"ms={self.ms:g}")
        if self.after:
            params.append(f"after={self.after}")
        if self.count is not None:
            params.append(f"count={self.count}")
        if self.at is not None:
            params.append(f"at={self.at}")
        if self.op is not None:
            params.append(f"op={self.op}")
        if params:
            parts.append("+".join(params))
        return ":".join(parts)


def parse_chaos_spec(raw: str) -> List[ChaosRule]:
    """``CRAFT_CHAOS`` string → rule list (raises ``ValueError`` on typos)."""
    raw = (raw or "").strip()
    if not raw or raw.lower() in ("on", "1", "true"):
        return []
    rules = []
    for tok in raw.replace(";", ",").split(","):
        tok = tok.strip()
        if not tok:
            continue
        fields = tok.split(":")
        if len(fields) < 2 or len(fields) > 3:
            raise ValueError(
                f"CRAFT_CHAOS rule {tok!r}: expected slot:fault[:k=v[+k=v]]")
        slot, fault = fields[0].strip().lower(), fields[1].strip().lower()
        params: Dict[str, str] = {}
        if len(fields) == 3:
            for kv in fields[2].split("+"):
                kv = kv.strip()
                if not kv:
                    continue
                if "=" not in kv:
                    raise ValueError(
                        f"CRAFT_CHAOS rule {tok!r}: param {kv!r} is not k=v")
                k, v = kv.split("=", 1)
                params[k.strip().lower()] = v.strip()
        rules.append(ChaosRule(slot, fault, params))
    return rules


def _draw(seed: int, slot: str, op: str, index: int) -> float:
    """Deterministic uniform [0, 1) draw for one operation — a pure function
    of (seed, slot, op, index), so replays are bit-identical."""
    key = f"{seed}:{slot}:{op}:{index}".encode()
    return (zlib.crc32(key) & 0xFFFFFFFF) / 4294967296.0


class ChaosEngine:
    """Process-local fault injector shared by every tier of one checkpoint.

    Thread-safe: IO call sites run on the sequencer, the worker pool, and
    deadline helper threads concurrently.  ``clear()`` lifts faults at
    runtime (the "outage ends" event); ``release()`` unblocks in-flight
    ``hang`` faults so abandoned writer threads can die.
    """

    def __init__(self, spec: str = "", seed: int = 0,
                 sleep=time.sleep):
        self.rules: List[ChaosRule] = parse_chaos_spec(spec)
        self.seed = int(seed)
        self._sleep = sleep
        self._lock = threading.Lock()
        self._counters: Dict[Tuple[str, str], int] = {}
        self._released = threading.Event()
        self.log: List[str] = []          # "slot:op:index:fault" per injection
        self.stats: Dict[str, int] = {f: 0 for f in _FAULTS}
        self.stats["ops"] = 0

    # -- rule management ----------------------------------------------------
    def add(self, spec: str) -> None:
        """Arm additional rules mid-run (soak schedules, outage tests)."""
        fresh = parse_chaos_spec(spec)
        with self._lock:
            self.rules.extend(fresh)

    def clear(self, slot: Optional[str] = None,
              fault: Optional[str] = None) -> int:
        """Lift matching rules (``None`` matches everything); returns the
        number removed.  This is the "fault cleared" event the breaker's
        half-open probe discovers."""
        with self._lock:
            keep, dropped = [], 0
            for r in self.rules:
                if (slot is None or r.slot == slot) and \
                        (fault is None or r.fault == fault):
                    dropped += 1
                else:
                    keep.append(r)
            self.rules = keep
        return dropped

    def release(self) -> None:
        """Unblock every in-flight (and future) ``hang`` — hung operations
        then fail with ``EIO`` instead of publishing stale state late."""
        self._released.set()

    def op_count(self, slot: str, op: str) -> int:
        """Operations observed so far for (slot, op) — lets tests aim an
        ``at=N`` crash rule at a precise future operation."""
        with self._lock:
            return self._counters.get((slot, op), 0)

    def scope(self, slot: str) -> "ChaosScope":
        return ChaosScope(self, slot)

    # -- injection ----------------------------------------------------------
    def check(self, slot: str, op: str, nbytes: int = 0, path=None) -> None:
        """Fault gate for one IO operation; raises / stalls per the rules."""
        fault, rule, index = self._pick(slot, op)
        if fault is None:
            return
        where = f"{slot}:{op}" + (f" {path}" if path is not None else "")
        if fault == "stall":
            self._sleep(min(rule.ms, 60_000.0) / 1000.0)
            return
        if fault == "hang":
            # park until release() or the safety cap, then fail the op —
            # a hung write must never complete late and publish stale state
            self._released.wait(timeout=_HANG_CAP_S)
            raise OSError(errno.EIO, f"chaos: hung io abandoned ({where})")
        if fault == "crash":
            raise ChaosCrash(f"chaos: crash-at-point ({where}, op {index})")
        if fault == "eio":
            raise OSError(errno.EIO, f"chaos: transient EIO ({where})")
        if fault == "erofs":
            raise OSError(errno.EROFS, f"chaos: read-only tier ({where})")
        if fault == "enospc":
            raise OSError(errno.ENOSPC, f"chaos: no space left ({where})")

    def torn_limit(self, slot: str, total: int) -> Optional[int]:
        """Byte prefix a ``torn`` rule allows for this write, else None.

        Counted on the dedicated ``(slot, "torn")`` stream so torn draws
        never perturb the ``write`` stream's indices."""
        fault, rule, index = self._pick(slot, "torn", faults=("torn",))
        if fault is None:
            return None
        # deterministic tear point: at least 1 byte short, at most half gone
        frac = 0.5 + _draw(self.seed ^ 0x7EA2, slot, "torn", index) / 2.0
        return max(0, min(total - 1, int(total * frac)))

    def _pick(self, slot: str, op: str, faults=None):
        with self._lock:
            key = (slot, op)
            index = self._counters.get(key, 0)
            self._counters[key] = index + 1
            self.stats["ops"] += 1
            draw = _draw(self.seed, slot, op, index)
            for rule in self.rules:
                if faults is not None and rule.fault not in faults:
                    continue
                if faults is None and rule.fault == "torn":
                    continue          # torn is drawn via torn_limit()
                if rule.matches(slot, op, index, draw):
                    rule.injected += 1
                    self.stats[rule.fault] += 1
                    if len(self.log) < _LOG_CAP:
                        self.log.append(f"{slot}:{op}:{index}:{rule.fault}")
                    return rule.fault, rule, index
        return None, None, index


class ChaosScope:
    """A :class:`ChaosEngine` bound to one tier slot — what the IO paths
    carry (via ``IOContext.chaos`` / ``StorageTier.chaos_scope``)."""

    __slots__ = ("engine", "slot")

    def __init__(self, engine: ChaosEngine, slot: str):
        self.engine = engine
        self.slot = slot

    def check(self, op: str, nbytes: int = 0, path=None) -> None:
        self.engine.check(self.slot, op, nbytes=nbytes, path=path)

    def torn_limit(self, total: int) -> Optional[int]:
        return self.engine.torn_limit(self.slot, total)
