"""Checkpoint-schedule overhead sweep: fixed frequency vs Daly vs per-tier.

Total checkpointing overhead = write cost + rework (compute redone after a
failure because it post-dated the last restorable version).  The paper's §4
analysis makes frequency the dominant knob; this sweep makes the trade
measurable.  The experiment runs the *real* :class:`CheckpointPolicy` on a
simulated clock (deterministic, seconds of wall time for hours of simulated
compute): per-tier write costs are modeled (mem ≪ node ≪ pfs), failures are
drawn from an exponential MTBF process with a fixed seed, a failure wipes
the memory tier and rolls work back to the newest node/PFS version, and the
policy sees exactly what it would see in production — measured write costs
via ``record_write`` EWMAs, a recovery-epoch bump per failure, restored
interval clocks.

Schedules compared on identical failure traces:

* ``fixed_N`` — the classic single-level idiom: PFS write every N steps;
* ``tiered``  — fixed per-tier cadence ``mem:1,node:8,pfs:64``;
* ``daly_pfs`` / ``daly_tiered`` — ``CRAFT_TIER_EVERY=auto`` intervals.

``preempt_flush`` additionally proves the preemption path end-to-end with
real IO: async delta writes, a SIGTERM-style trigger, one synchronous full
flush, and a bit-identical restore in a fresh process-equivalent.

    PYTHONPATH=src:. python benchmarks/schedule_overhead.py
    PYTHONPATH=src:. python benchmarks/cr_overhead.py schedule_overhead
"""
from __future__ import annotations

import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit
from repro.core import Checkpoint, CraftEnv
from repro.core import scheduler as sched
from repro.core.scheduler import CheckpointPolicy
from repro.core.tiers import StorageTier

#: Simulated per-version write cost (seconds) of each tier — the mem ≪ node
#: ≪ pfs ordering measured by cr_overhead/table4 on this container, scaled
#: to a cluster-ish PFS latency so the trade is visible.
TIER_COSTS = {"mem": 0.02, "node": 0.2, "pfs": 2.0}
STEP_SECONDS = 1.0
MTBF_SECONDS = 1000.0
RESTART_SECONDS = 30.0         # fixed relaunch+restore penalty per failure


class _SimTier(StorageTier):
    """Cost-model-only tier: the policy reads write_cost()/record_write()
    from the StorageTier base; the storage surface is never exercised."""

    def __init__(self, slot: str, sim_cost: float):
        self.label = slot
        self.sim_cost = sim_cost

    def stage(self, version):            # pragma: no cover - unused surface
        raise NotImplementedError

    def publish(self, staged, version, extra_meta=None):  # pragma: no cover
        raise NotImplementedError

    def abort(self, staged):             # pragma: no cover - unused surface
        raise NotImplementedError

    def latest_version(self) -> int:
        return 0

    def version_dir(self, version):      # pragma: no cover - unused surface
        raise NotImplementedError

    def invalidate_all(self) -> None:
        pass


def _failure_times(rng, horizon_s: float):
    """Deterministic absolute failure times over the horizon (Poisson)."""
    times, t = [], 0.0
    while t < horizon_s:
        t += float(rng.exponential(MTBF_SECONDS))
        times.append(t)
    return times


def simulate(envmap: dict, tier_costs: dict, n_steps: int,
             failure_times) -> dict:
    """Run one schedule over the shared failure trace; returns overheads."""
    env = CraftEnv.capture({
        "CRAFT_CP_PATH": "/unused",
        "CRAFT_MTBF_SECONDS": str(MTBF_SECONDS),
        **envmap,
    })
    clk = {"t": 0.0}
    stores = {slot: _SimTier(slot, c) for slot, c in tier_costs.items()}
    policy = CheckpointPolicy(env, stores, clock=lambda: clk["t"])
    goal = n_steps * STEP_SECONDS
    work = 0.0                             # completed compute seconds
    snap = {slot: 0.0 for slot in stores}  # work snapshot held per tier
    fails = list(failure_times)
    write_s = rework_s = restart_s = 0.0
    n_writes = {slot: 0 for slot in stores}
    n_failures = 0
    it, version = 0, 0
    while work < goal:
        if fails and clk["t"] >= fails[0]:
            fails.pop(0)
            n_failures += 1
            # the memory tier dies with the process; roll back to the
            # newest durable (node/pfs) version
            durable = max((snap[s] for s in stores if s != "mem"),
                          default=0.0)
            rework_s += work - durable
            work = durable
            snap = {slot: durable for slot in stores}
            clk["t"] += RESTART_SECONDS
            restart_s += RESTART_SECONDS
            sched.notify_recovery()        # what aft.py does per recovery
            policy.notify_restore()
            continue
        it += 1
        clk["t"] += STEP_SECONDS
        work += STEP_SECONDS
        d = policy.need_checkpoint(it, next_version=version + 1)
        if d.write:
            version += 1
            for slot in d.tiers:
                cost = stores[slot].sim_cost
                clk["t"] += cost
                write_s += cost
                stores[slot].record_write(cost)
                snap[slot] = work
                n_writes[slot] += 1
            policy.record_written(d, version)
    return {
        "overhead_s": clk["t"] - goal,
        "write_s": write_s,
        "rework_s": rework_s,
        "restart_s": restart_s,
        "failures": n_failures,
        "writes": dict(n_writes),
    }


def schedule_overhead(full: bool = False) -> None:
    n_steps = 8000 if full else 4000
    rng = np.random.default_rng(42)
    # shared trace, long enough for the slowest schedule
    fails = _failure_times(rng, horizon_s=n_steps * STEP_SECONDS * 4)

    pfs_only = {"pfs": TIER_COSTS["pfs"]}
    schedules = []
    for freq in (5, 25, 100, 400):
        schedules.append((f"fixed_{freq}",
                          {"CRAFT_TIER_EVERY": f"pfs:{freq}"}, pfs_only))
    schedules.append(("tiered",
                      {"CRAFT_TIER_EVERY": "mem:1,node:8,pfs:64"},
                      TIER_COSTS))
    schedules.append(("daly_pfs", {"CRAFT_TIER_EVERY": "auto"}, pfs_only))
    schedules.append(("daly_tiered", {"CRAFT_TIER_EVERY": "auto"},
                      TIER_COSTS))

    results = {}
    for name, envmap, costs in schedules:
        r = simulate(envmap, costs, n_steps, fails)
        results[name] = r
        emit("schedule_overhead", f"{name}_overhead", round(r["overhead_s"], 1),
             "s", write_s=round(r["write_s"], 1),
             rework_s=round(r["rework_s"], 1), failures=r["failures"],
             writes=";".join(f"{k}:{v}" for k, v in r["writes"].items()))
    fixed = {k: v["overhead_s"] for k, v in results.items()
             if k.startswith("fixed_")}
    best_fixed = min(fixed, key=fixed.get)
    for adaptive in ("daly_pfs", "daly_tiered", "tiered"):
        ratio = fixed[best_fixed] / max(1e-9, results[adaptive]["overhead_s"])
        emit("schedule_overhead", f"{adaptive}_vs_best_fixed",
             round(ratio, 2), "x", best_fixed=best_fixed)
        beaten = sum(results[adaptive]["overhead_s"] < v
                     for v in fixed.values())
        emit("schedule_overhead", f"{adaptive}_beats_fixed_points",
             beaten, "count", of=len(fixed))


def preempt_flush(full: bool = False) -> None:
    """SIGTERM-style trigger → one synchronous full flush → bit-identical
    restore (the acceptance proof, with real IO and the delta codec on)."""
    rng = np.random.default_rng(3)
    mb = 8 if full else 4
    arrays = {f"a{i}": rng.standard_normal((mb * 1024 * 1024 // 4,))
              .astype(np.float32) for i in range(4)}
    base = Path(tempfile.mkdtemp(prefix="craft-preempt-"))
    envmap = {
        "CRAFT_CP_PATH": str(base),
        "CRAFT_USE_SCR": "0",
        "CRAFT_WRITE_ASYNC": "1",
        "CRAFT_DELTA": "1",
        "CRAFT_CHUNK_BYTES": str(256 * 1024),
    }
    try:
        cp = Checkpoint("preempt", env=CraftEnv.capture(envmap))
        for k, a in arrays.items():
            cp.add(k, a)
        cp.commit()
        cp.update_and_write()              # v1: async full write
        for a in arrays.values():          # sparse update → v2 is a delta
            a[::4096] += 1.0
        cp.update_and_write()
        for a in arrays.values():          # state the flush must capture
            a[::2048] -= 0.5
        expect = {k: a.copy() for k, a in arrays.items()}
        cp.policy.trigger_preemption()     # what the SIGTERM handler does
        t0 = time.perf_counter()
        wrote = cp.update_and_write()      # sync: drains the async queue too
        flush_s = time.perf_counter() - t0
        final_version = cp.version
        cp.close()
        emit("schedule_overhead", "preempt_flush_latency",
             round(flush_s, 4), "s", version=final_version,
             wrote=int(wrote))
        # fresh "job": restore and compare bit-for-bit
        restored = {k: np.zeros_like(a) for k, a in arrays.items()}
        cp2 = Checkpoint("preempt", env=CraftEnv.capture(envmap))
        for k, a in restored.items():
            cp2.add(k, a)
        cp2.commit()
        cp2.restart_if_needed()
        identical = all(np.array_equal(restored[k], expect[k])
                        for k in arrays)
        cp2.close()
        emit("schedule_overhead", "preempt_restore_identical",
             int(identical), "bool", restored_version=cp2.version)
        if not identical:
            raise SystemExit("preempt flush did not restore bit-identically")
    finally:
        shutil.rmtree(base, ignore_errors=True)


def main(full: bool = False) -> None:
    schedule_overhead(full)
    preempt_flush(full)


_SCENARIOS = {
    "schedule_overhead": schedule_overhead,
    "preempt_flush": preempt_flush,
}


if __name__ == "__main__":
    import sys

    argv = sys.argv[1:]
    run_full = "--full" in argv
    json_out = None
    if "--json" in argv:
        at = argv.index("--json")
        if at + 1 >= len(argv) or argv[at + 1].startswith("-"):
            raise SystemExit("--json needs an output path")
        json_out = argv[at + 1]
    names = [a for a in argv if not a.startswith("-")
             and (json_out is None or a != json_out)]
    bad = [n for n in names if n not in _SCENARIOS]
    if bad:
        raise SystemExit(
            f"unknown scenario(s) {bad}; choose from {sorted(_SCENARIOS)}")
    for nm in (names or list(_SCENARIOS)):
        _SCENARIOS[nm](run_full)
    if json_out:
        from benchmarks.common import dump_json

        dump_json(json_out)
