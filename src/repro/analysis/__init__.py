"""Roofline analysis: three-term model derived from the compiled dry-run."""
