"""Asynchronous checkpoint writing (paper §2.4).

The paper dedicates one writer thread per process (``std::async``) with two
modes:

* **copy-based** (``CRAFT_WRITE_ASYNC=1``): ``update()`` snapshots each
  checkpointable into a private buffer, then file IO runs on the writer
  thread while the application keeps computing.
* **zero-copy** (``CRAFT_WRITE_ASYNC_ZERO_COPY=1``): no snapshot; the writer
  thread serializes the *live* data, and the application must call
  ``Checkpoint.wait()`` before mutating it.

``CRAFT_ASYNC_THREAD_PIN_CPULIST`` pins the writer thread (paper: maximize
async gain by keeping the writer off the compute cores).  On Linux we honor it
via ``os.sched_setaffinity`` on the writer thread's TID; elsewhere it is a
documented no-op.
"""
from __future__ import annotations

import os
import threading
import queue
from typing import Callable, Optional, Sequence


class AsyncWriter:
    """A dedicated writer thread executing checkpoint jobs in order."""

    def __init__(self, pin_cpulist: Sequence[int] = (), name: str = "craft-writer"):
        self._queue: "queue.Queue" = queue.Queue()
        self._pin = tuple(pin_cpulist)
        self._error: Optional[BaseException] = None
        self._pending = 0
        self._cv = threading.Condition()
        self._thread = threading.Thread(target=self._loop, name=name, daemon=True)
        self._started = False

    # -- lifecycle -----------------------------------------------------------
    def _ensure_started(self) -> None:
        if not self._started:
            self._thread.start()
            self._started = True

    def _loop(self) -> None:
        if self._pin and hasattr(os, "sched_setaffinity"):
            try:
                os.sched_setaffinity(0, set(self._pin))
            except OSError:
                pass  # CPU list not available on this host — documented no-op
        while True:
            job = self._queue.get()
            if job is None:
                return
            try:
                job()
            except BaseException as exc:  # surfaced at next wait()/submit()
                with self._cv:
                    self._error = exc
            finally:
                with self._cv:
                    self._pending -= 1
                    self._cv.notify_all()

    # -- API -------------------------------------------------------------------
    def submit(self, job: Callable[[], None]) -> None:
        self._raise_pending_error()
        self._ensure_started()
        with self._cv:
            self._pending += 1
        self._queue.put(job)

    def wait(self) -> None:
        """Block until all submitted jobs finished; re-raise writer errors."""
        with self._cv:
            while self._pending > 0:
                self._cv.wait()
        self._raise_pending_error()

    def close(self) -> None:
        if self._started:
            self.wait()
            self._queue.put(None)
            self._thread.join(timeout=30)
            self._started = False

    @property
    def busy(self) -> bool:
        with self._cv:
            return self._pending > 0

    def _raise_pending_error(self) -> None:
        with self._cv:
            err, self._error = self._error, None
        if err is not None:
            raise err
