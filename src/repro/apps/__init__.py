"""Benchmark applications (the paper's showcase workloads)."""
