"""Trace recorder + deterministic replay: the record → replay loop must be
faithful enough that a replayed policy re-derives a live run's decision
sequence *exactly* — including a chaos run with degraded routing — and the
what-if simulator's accounting must stay internally consistent.

The headline test (`test_replay_matches_live_chaos_run`) is the PR's
cross-validation contract: record a real run under fault injection with
``CRAFT_TRACE`` on, replay the trace through a fresh policy, and assert
the simulated per-tier write counts / bytes / forced-full decisions match
the live ``Checkpoint.stats`` with zero decision mismatches.
"""
import json

import numpy as np
import pytest

from repro.core import Checkpoint
from repro.core import trace as trace_mod
from repro.core.env import CraftEnv
from repro.core.simulate import (
    FakeClock, SimTier, load_trace, replay, simulate_config, summarize,
)
from repro.core.tune import recommend_env_block, tune


@pytest.fixture(autouse=True)
def _tracer_cleanup():
    """Every test leaves the process-global tracer disarmed."""
    yield
    trace_mod.uninstall()


def _env(tmp_path, **extra):
    envmap = {
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_NODE_CP_PATH": str(tmp_path / "node"),
        "CRAFT_IO_BACKOFF_MS": "1",
        **{k: str(v) for k, v in extra.items()},
    }
    return CraftEnv.capture(envmap)


def _run_traced(tmp_path, n_iter=40, **extra):
    """One live run with CRAFT_TRACE armed; returns (events, stats)."""
    tpath = tmp_path / "run-trace.jsonl"
    env = _env(tmp_path, CRAFT_TRACE=tpath, **extra)
    arr = np.arange(4096, dtype=np.float64)
    cp = Checkpoint("traced", env=env)
    cp.add("arr", arr)
    cp.commit()
    cp.restart_if_needed()
    try:
        for it in range(n_iter):
            arr += 1.0
            if cp.need_checkpoint(it):
                cp.update_and_write(it)
        cp.wait()
    finally:
        cp.close()
        stats = dict(cp.stats)
        trace_mod.uninstall()          # flush + close before reading back
    return load_trace(tpath), stats


# ------------------------------------------------------------- recorder layer
class TestRecorder:
    def test_null_tracer_when_env_unset(self, tmp_path):
        env = _env(tmp_path)
        assert env.trace_path == ""
        trace_mod.maybe_install_from_env(env)
        assert not trace_mod.enabled()
        # emits on the disarmed tracer are no-ops, not errors
        trace_mod.emit("step", seconds=1.0)

    def test_install_is_idempotent_and_appends(self, tmp_path):
        p = tmp_path / "t.jsonl"
        trace_mod.install(str(p))
        first = trace_mod.TRACER
        trace_mod.install(str(p))
        assert trace_mod.TRACER is first      # same path: same writer
        trace_mod.emit("step", seconds=0.5)
        trace_mod.uninstall()
        trace_mod.install(str(p))             # re-install appends
        trace_mod.emit("step", seconds=0.7)
        trace_mod.uninstall()
        kinds = [e["kind"] for e in load_trace(p)]
        assert kinds == ["step", "step"]

    def test_load_trace_skips_torn_tail(self, tmp_path):
        p = tmp_path / "torn.jsonl"
        p.write_text(json.dumps({"t": 0.0, "kind": "step", "seconds": 1.0})
                     + "\n" + '{"t": 0.1, "kind": "ste')   # killed mid-line
        events = load_trace(p)
        assert [e["kind"] for e in events] == ["step"]

    def test_live_run_emits_config_and_decisions(self, tmp_path):
        events, stats = _run_traced(tmp_path, n_iter=10,
                                    CRAFT_USE_SCR="0",
                                    CRAFT_TIER_EVERY="pfs:3")
        kinds = [e["kind"] for e in events]
        assert kinds[0] == "config"
        cfg = events[0]
        assert cfg["env"]["CRAFT_TIER_EVERY"] == "pfs:3"
        assert cfg["payload_bytes"] == 4096 * 8
        assert kinds.count("decision") == 10
        assert kinds.count("tier_write") == stats["pfs_writes"] > 0
        # timestamps are a total order
        ts = [e["t"] for e in events]
        assert ts == sorted(ts)


# --------------------------------------------------------------- exact replay
class TestReplay:
    def test_replay_requires_config(self):
        with pytest.raises(ValueError):
            replay([{"t": 0.0, "kind": "step", "seconds": 1.0}])

    def test_replay_matches_live_clean_run(self, tmp_path):
        events, stats = _run_traced(tmp_path, n_iter=30,
                                    CRAFT_TIER_EVERY="node:2,pfs:5")
        r = replay(events)
        assert r.decisions_match, f"mismatches at {r.mismatches[:5]}"
        assert r.scheduled_writes == stats["writes"]
        assert r.tier_landed["node"] == stats["node_writes"]
        assert r.tier_landed["pfs"] == stats["pfs_writes"]
        assert r.tier_landed_bytes["pfs"] == \
            stats["pfs_writes"] * 4096 * 8

    def test_replay_matches_live_chaos_run(self, tmp_path):
        """The cross-validation contract: a chaos run (node-tier outage
        mid-run, breaker trip, degraded routing to the PFS, forced-full
        re-admission) replays with zero decision mismatches and exact
        per-tier accounting."""
        events, stats = _run_traced(
            tmp_path, n_iter=40,
            CRAFT_TIER_EVERY="node:2,pfs:4",
            CRAFT_DELTA="1",
            CRAFT_CHAOS="node:eio:p=1+after=4+count=6",
            CRAFT_IO_RETRIES="0",
            CRAFT_BREAKER_THRESHOLD="2",
            CRAFT_BREAKER_COOLDOWN_S="0.05",
        )
        assert stats["degraded_writes"] > 0      # the fault actually fired
        r = replay(events)
        assert r.decisions_match, f"mismatches at {r.mismatches[:5]}"
        assert r.scheduled_writes == stats["writes"]
        assert r.tier_landed["node"] == stats["node_writes"]
        assert r.tier_landed["pfs"] == stats["pfs_writes"]
        total_bytes = sum(r.tier_landed_bytes.values())
        assert total_bytes == sum(
            e["nbytes"] for e in events if e["kind"] == "tier_write")
        # forced-full decisions re-derived — at least the post-outage
        # re-admission write is full under CRAFT_DELTA=1
        recorded_fulls = sum(1 for e in events
                             if e["kind"] == "decision" and e.get("full"))
        assert r.full_writes == recorded_fulls

    def test_replay_is_deterministic(self, tmp_path):
        events, _ = _run_traced(tmp_path, n_iter=20,
                                CRAFT_TIER_EVERY="node:3,pfs:7")
        a, b = replay(events), replay(events)
        assert a.sim_decisions == b.sim_decisions
        assert a.tier_landed == b.tier_landed


# ------------------------------------------------------------ what-if + tune
class TestSimulateConfig:
    def _summary(self, tmp_path, **extra):
        events, _ = _run_traced(tmp_path, n_iter=20,
                                CRAFT_TIER_EVERY="node:2,pfs:5", **extra)
        return summarize(events)

    def test_summary_distills_costs_and_steps(self, tmp_path):
        s = self._summary(tmp_path)
        assert s.payload_bytes == 4096 * 8
        assert s.steps and all(x > 0 for x in s.steps)
        assert set(s.tier_full_cost) == {"node", "pfs"}
        assert all(v > 0 for v in s.tier_full_cost.values())

    def test_same_seed_same_report(self, tmp_path):
        s = self._summary(tmp_path)
        a = simulate_config(s, {}, seed=3, horizon_steps=400)
        b = simulate_config(s, {}, seed=3, horizon_steps=400)
        assert a.as_dict() == b.as_dict()

    def test_sparser_cadence_cuts_write_overhead_without_failures(
            self, tmp_path):
        s = self._summary(tmp_path, CRAFT_MTBF_SECONDS="1e12")
        dense = simulate_config(s, {"CRAFT_TIER_EVERY": "node:1,pfs:1"},
                                seed=0, horizon_steps=400)
        sparse = simulate_config(s, {"CRAFT_TIER_EVERY": "node:64,pfs:64"},
                                 seed=0, horizon_steps=400)
        assert sparse.write_seconds < dense.write_seconds
        assert sparse.overhead_seconds < dense.overhead_seconds

    def test_failures_charge_rework_and_restores(self, tmp_path):
        s = self._summary(tmp_path)
        # force a failure-rich regime: mtbf of a few simulated steps
        s.failure_gaps = [20 * s.mean_step()]
        rep = simulate_config(s, {}, seed=1, horizon_steps=600)
        assert rep.failures > 0
        assert rep.rework_seconds > 0
        assert rep.restore_seconds > 0

    def test_tune_never_regresses_as_run(self, tmp_path):
        s = self._summary(tmp_path)
        result = tune(s, seed=0, horizon_steps=400)
        assert result["recommended"]["overhead_seconds"] <= \
            result["as_run"]["overhead_seconds"] + 1e-9
        block = recommend_env_block(result)
        assert block.startswith("# craft tune recommendation")

    def test_tune_cli_end_to_end(self, tmp_path, capsys):
        events, _ = _run_traced(tmp_path, n_iter=25,
                                CRAFT_TIER_EVERY="node:1,pfs:2")
        tpath = tmp_path / "run-trace.jsonl"
        out_json = tmp_path / "BENCH_tune.json"
        from repro.tune import main as tune_main

        rc = tune_main(["--trace", str(tpath), "--json", str(out_json),
                        "--fail-on-regression"])
        assert rc == 0
        txt = capsys.readouterr().out
        assert "recommended" in txt and "export CRAFT_" in txt or \
            "already optimal" in txt
        records = json.loads(out_json.read_text())
        names = {r["name"] for r in records}
        assert {"as_run_overhead", "recommended_overhead",
                "improvement"} <= names
        for r in records:
            assert {"bench", "name", "value", "unit"} <= set(r)


# ---------------------------------------------------------------- sim pieces
class TestSimPieces:
    def test_fake_clock(self):
        c = FakeClock(5.0)
        assert c() == 5.0
        c.advance(2.5)
        assert c() == 7.5

    def test_sim_tier_is_cost_only(self):
        t = SimTier("pfs")
        assert t.write_cost() is None
        t.record_write(0.25, 100)
        assert t.write_cost() == 0.25
        with pytest.raises(NotImplementedError):
            t.stage(1)
