"""Shared benchmark helpers: CSV emission + timing + JSON artifact dump +
the ``BENCH_*.json`` record schema every artifact must satisfy."""
from __future__ import annotations

import json
import time
from contextlib import contextmanager

_ROWS = []
_RECORDS = []

#: Every ``BENCH_*.json`` artifact is a JSON array of records with at least
#: these keys; ``value`` is a number or a short string, extra keys are
#: free-form tags.  ``validate_records`` enforces it — both at dump time
#: (a malformed artifact never uploads) and as a CI post-check over
#: artifacts other tools produced (``python -m benchmarks.common FILE...``).
REQUIRED_KEYS = ("bench", "name", "value", "unit")


def validate_records(records) -> list:
    """Schema errors in a BENCH record array (empty list = valid)."""
    errors = []
    if not isinstance(records, list):
        return [f"artifact is {type(records).__name__}, expected a list"]
    for i, rec in enumerate(records):
        if not isinstance(rec, dict):
            errors.append(f"record {i}: not an object")
            continue
        missing = [k for k in REQUIRED_KEYS if k not in rec]
        if missing:
            errors.append(f"record {i}: missing {missing}")
            continue
        for key in ("bench", "name", "unit"):
            if not isinstance(rec[key], str):
                errors.append(f"record {i}: {key!r} must be a string")
        if not isinstance(rec["value"], (int, float, str)) \
                or isinstance(rec["value"], bool):
            errors.append(f"record {i}: 'value' must be a number or string")
    return errors


def validate_file(path: str) -> list:
    """Schema errors for one ``BENCH_*.json`` file on disk."""
    try:
        with open(path) as fh:
            records = json.load(fh)
    except (OSError, json.JSONDecodeError) as exc:
        return [f"{path}: unreadable ({exc})"]
    return [f"{path}: {e}" for e in validate_records(records)]


def emit(bench: str, name: str, value, unit: str, **extra) -> None:
    tags = ",".join(f"{k}={v}" for k, v in extra.items())
    line = f"{bench},{name},{value},{unit}" + (f",{tags}" if tags else "")
    _ROWS.append(line)
    _RECORDS.append({"bench": bench, "name": name, "value": value,
                     "unit": unit, **extra})
    print(line, flush=True)


def dump_json(path: str) -> None:
    """Write every record emitted so far as a JSON array (CI artifact)."""
    errors = validate_records(_RECORDS)
    if errors:
        raise SystemExit("BENCH schema violation: " + "; ".join(errors[:5]))
    with open(path, "w") as fh:
        json.dump(_RECORDS, fh, indent=1)
    print(f"wrote {len(_RECORDS)} records to {path}", flush=True)


@contextmanager
def timed():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0


def header() -> None:
    print("bench,name,value,unit,tags", flush=True)


def run_scenarios(scenarios: dict, default, argv=None) -> None:
    """Shared scenario CLI: ``[name ...] [--full] [--json OUT.json]``.

    ``scenarios`` maps names to ``fn(full: bool)``; no names runs
    ``default(full)``.  Used by the per-module ``__main__`` blocks
    (cr_overhead, recovery_scaling) so the parsing lives once.
    """
    import sys

    argv = list(sys.argv[1:] if argv is None else argv)
    run_full = "--full" in argv
    json_out = None
    if "--json" in argv:
        at = argv.index("--json")
        if at + 1 >= len(argv) or argv[at + 1].startswith("-"):
            raise SystemExit("--json needs an output path")
        json_out = argv[at + 1]
    names = [a for a in argv if not a.startswith("-")
             and (json_out is None or a != json_out)]
    bad = [n for n in names if n not in scenarios]
    if bad:
        raise SystemExit(
            f"unknown scenario(s) {bad}; choose from {sorted(scenarios)}")
    if names:
        for nm in names:
            scenarios[nm](run_full)
    else:
        default(run_full)
    if json_out:
        dump_json(json_out)


if __name__ == "__main__":
    # validate BENCH_*.json artifacts: python -m benchmarks.common FILE...
    import sys as _sys

    _paths = _sys.argv[1:]
    if not _paths:
        raise SystemExit("usage: python -m benchmarks.common BENCH_*.json...")
    _errs = [e for p in _paths for e in validate_file(p)]
    for _e in _errs:
        print(_e, file=_sys.stderr)
    if _errs:
        raise SystemExit(1)
    print(f"{len(_paths)} artifact(s) OK")
