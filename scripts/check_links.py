#!/usr/bin/env python
"""Markdown link check for README.md and docs/ (CI docs job).

Verifies that every relative markdown link resolves to an existing file or
directory in the repository.  External (http/https/mailto) links are only
syntax-checked, never fetched — CI must not depend on the network.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def doc_files():
    yield REPO / "README.md"
    yield from sorted((REPO / "docs").glob("*.md"))


def check(md: Path) -> list:
    errors = []
    for target in LINK.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):
            continue                      # intra-document anchor
        path = target.split("#", 1)[0]    # strip #Lnn / heading anchors
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(REPO)}: broken link -> {target}")
        elif REPO not in resolved.parents and resolved != REPO:
            errors.append(f"{md.relative_to(REPO)}: escapes repo -> {target}")
    return errors


def main() -> int:
    errors = []
    n = 0
    for md in doc_files():
        if md.exists():
            n += 1
            errors += check(md)
    if not n:
        print("no markdown files found", file=sys.stderr)
        return 1
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {n} files: {'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
