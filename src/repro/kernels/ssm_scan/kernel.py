"""Pallas TPU kernel: fused selective scan (the mamba recurrence).

    h_t = exp(dt_t · A) ⊙ h_{t-1} + (dt_t·x_t) ⊗ B_t
    y_t = ⟨h_t, C_t⟩_state

TPU mapping (DESIGN.md: the CUDA selective-scan kernel's core insight —
*never let the (L, state) tensors touch HBM* — transplanted to the
VMEM/VPU hierarchy):

  * mamba2 (SSD) layout: grid = (B, n_heads, L/blk); the last axis iterates
    sequentially on TPU, so the (hd, st) fp32 state lives in VMEM scratch
    and carries across the L-sweep of one (batch, head).  Each step streams
    a (blk, hd) x-tile and (blk, st) B/C tiles in, runs the recurrence as a
    ``fori_loop`` over the block's timesteps on the VPU, and writes only the
    (blk, hd) y-tile back — HBM IO is exactly the kernel boundary the
    roofline's ``pallas_equiv_ssm`` scope charges.
  * mamba1 layout: per-channel A (di, st) — grid = (B, di/blk_d, L/blk),
    state scratch (blk_d, st), decay exp(dt_t ⊗ A-tile) computed per step.
  * VMEM budget at defaults (blk=128, hd=64, st≤128): tiles ≈ blk·(hd+2·st)·4
    ≈ 0.2 MiB + state ≈ 32 KiB — double-buffered comfortably.

The sequential fori_loop form favors clarity over MXU utilization; the
matmul-form SSD (chunked attention-like) variant is the known next step and
is what the roofline's compute term would want — noted in EXPERIMENTS.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ===================================================================== mamba2
def _ssd_kernel(dtx_ref, bh_ref, ch_ref, dt_ref, a_ref, h0_ref, y_ref,
                hlast_ref, h_scr, *, blk: int, n_blk: int):
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        h_scr[...] = h0_ref[0, 0].astype(jnp.float32)

    a = a_ref[0]                                   # scalar decay rate A_h

    def step(t, h):
        dt_t = dt_ref[0, t, 0]                     # scalar Δ_t
        decay = jnp.exp(dt_t * a)
        dtx_t = dtx_ref[0, t, 0].astype(jnp.float32)      # (hd,)
        b_t = bh_ref[0, t, 0].astype(jnp.float32)         # (st,)
        c_t = ch_ref[0, t, 0].astype(jnp.float32)         # (st,)
        h = decay * h + dtx_t[:, None] * b_t[None, :]     # (hd, st)
        y_ref[0, t, 0] = (h * c_t[None, :]).sum(axis=1).astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, blk, step, h_scr[...])

    @pl.when(ib == n_blk - 1)
    def _finish():
        hlast_ref[0, 0] = h_scr[...].astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def ssd_scan(dtx, bh, ch, dt, A, h0, *, blk: int = 128,
             interpret: bool = False):
    """mamba2 selective scan.

    dtx: (B, L, nh, hd); bh/ch: (B, L, nh, st); dt: (B, L, nh); A: (nh,);
    h0: (B, nh, hd, st).  L must be a multiple of ``blk`` (callers pad —
    dt=0 padding is exact: decay=1, injection=0).
    Returns (y (B, L, nh, hd), h_last (B, nh, hd, st)).
    """
    b, l, nh, hd = dtx.shape
    st = bh.shape[-1]
    if l % blk:
        raise ValueError(f"L={l} not a multiple of blk={blk}")
    n_blk = l // blk
    grid = (b, nh, n_blk)
    kernel = functools.partial(_ssd_kernel, blk=blk, n_blk=n_blk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk, 1, hd), lambda b_, h, i: (b_, i, h, 0)),
            pl.BlockSpec((1, blk, 1, st), lambda b_, h, i: (b_, i, h, 0)),
            pl.BlockSpec((1, blk, 1, st), lambda b_, h, i: (b_, i, h, 0)),
            pl.BlockSpec((1, blk, 1), lambda b_, h, i: (b_, i, h)),
            pl.BlockSpec((1,), lambda b_, h, i: (h,)),
            pl.BlockSpec((1, 1, hd, st), lambda b_, h, i: (b_, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk, 1, hd), lambda b_, h, i: (b_, i, h, 0)),
            pl.BlockSpec((1, 1, hd, st), lambda b_, h, i: (b_, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, nh, hd), dtx.dtype),
            jax.ShapeDtypeStruct((b, nh, hd, st), jnp.float32),
        ],
        scratch_shapes=[_vmem((hd, st), jnp.float32)],
        interpret=interpret,
    )(dtx, bh, ch, dt, A, h0)


# ===================================================================== mamba1
def _s6_kernel(dtx_ref, bh_ref, ch_ref, dt_ref, a_ref, h0_ref, y_ref,
               hlast_ref, h_scr, *, blk: int, n_blk: int):
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...]                                 # (blk_d, st)

    def step(t, h):
        dt_t = dt_ref[0, t].astype(jnp.float32)            # (blk_d,)
        decay = jnp.exp(dt_t[:, None] * a)                 # (blk_d, st)
        dtx_t = dtx_ref[0, t].astype(jnp.float32)          # (blk_d,)
        b_t = bh_ref[0, t].astype(jnp.float32)             # (st,)
        c_t = ch_ref[0, t].astype(jnp.float32)             # (st,)
        h = decay * h + dtx_t[:, None] * b_t[None, :]
        y_ref[0, t] = (h * c_t[None, :]).sum(axis=1).astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, blk, step, h_scr[...])

    @pl.when(ib == n_blk - 1)
    def _finish():
        hlast_ref[0] = h_scr[...].astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk", "blk_d", "interpret"))
def s6_scan(dtx, bh, ch, dt, A, h0, *, blk: int = 128, blk_d: int = 128,
            interpret: bool = False):
    """mamba1 selective scan.

    dtx/dt: (B, L, di); bh/ch: (B, L, st); A: (di, st); h0: (B, di, st).
    L % blk == 0 and di % blk_d == 0 (callers pad).
    Returns (y (B, L, di), h_last (B, di, st)).
    """
    b, l, di = dtx.shape
    st = bh.shape[-1]
    if l % blk or di % blk_d:
        raise ValueError(f"L={l}, di={di} must tile by ({blk}, {blk_d})")
    grid = (b, di // blk_d, l // blk)
    kernel = functools.partial(_s6_kernel, blk=blk, n_blk=l // blk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk, blk_d), lambda b_, d, i: (b_, i, d)),
            pl.BlockSpec((1, blk, st), lambda b_, d, i: (b_, i, 0)),
            pl.BlockSpec((1, blk, st), lambda b_, d, i: (b_, i, 0)),
            pl.BlockSpec((1, blk, blk_d), lambda b_, d, i: (b_, i, d)),
            pl.BlockSpec((blk_d, st), lambda b_, d, i: (d, 0)),
            pl.BlockSpec((1, blk_d, st), lambda b_, d, i: (b_, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk, blk_d), lambda b_, d, i: (b_, i, d)),
            pl.BlockSpec((1, blk_d, st), lambda b_, d, i: (b_, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, di), dtx.dtype),
            jax.ShapeDtypeStruct((b, di, st), jnp.float32),
        ],
        scratch_shapes=[_vmem((blk_d, st), jnp.float32)],
        interpret=interpret,
    )(dtx, bh, ch, dt, A, h0)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
