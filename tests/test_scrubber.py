"""Background integrity scrubber: detection, repair, throttling, quarantine.

Acceptance (ISSUE 5): the scrubber detects injected chunk corruption on
every tier (mem / node / pfs) and repairs it without a restore ever
observing bad bytes.
"""
import shutil
from pathlib import Path

import numpy as np
import pytest

from repro.core import Checkpoint
from repro.core.comm_sim import SimWorld
from repro.core.cpbase import CheckpointError
from repro.core.env import CraftEnv
from repro.core.mem_level import MemFabric
from repro.core.node_level import NodeStore
from repro.core.scrubber import corrupt_file

from test_node_level import FakeComm


def _env(tmp_path, **extra):
    return CraftEnv.capture({
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_NODE_CP_PATH": str(tmp_path / "node"),
        "CRAFT_NODE_REDUNDANCY": "LOCAL",
        "CRAFT_MEM_SCRATCH": str(tmp_path / "shm"),
        **{k: str(v) for k, v in extra.items()},
    })


def _write(env, data, name="s"):
    cp = Checkpoint(name, FakeComm(0, 1), env=env)
    cp.add("arr", data.copy())
    cp.commit()
    cp.update_and_write()
    return cp


def _restore(env, like, name="s"):
    target = np.zeros_like(like)
    cp = Checkpoint(name, FakeComm(0, 1), env=env)
    cp.add("arr", target)
    cp.commit()
    ok = cp.restart_if_needed()
    return ok, target, cp


@pytest.fixture()
def data(rng):
    return rng.standard_normal(100_000).astype(np.float32)


# ======================================================== detection + repair
class TestScanRepair:
    def test_node_rot_repaired_from_pfs(self, tmp_path, data):
        env = _env(tmp_path)
        cp = _write(env, data)
        node_file = (tmp_path / "node" / "node-0" / "s" / "v-1"
                     / "arr" / "array.bin")
        good = node_file.read_bytes()
        corrupt_file(node_file)
        st = cp.scrubber.scan_once()
        assert st["corrupt_found"] == 1 and st["repaired"] == 1
        assert node_file.read_bytes() == good       # bit-identical re-encode
        ok, target, rcp = _restore(env, data)
        assert ok and np.array_equal(target, data)
        assert rcp.stats["restore_tier"] == "node"
        assert rcp.stats["read_repairs"] == 0       # nothing left to repair

    def test_pfs_rot_repaired_from_node(self, tmp_path, data):
        env = _env(tmp_path)
        cp = _write(env, data)
        pfs_file = tmp_path / "pfs" / "s" / "v-1" / "arr" / "array.bin"
        good = pfs_file.read_bytes()
        corrupt_file(pfs_file)
        st = cp.scrubber.scan_once()
        assert st["corrupt_found"] == 1 and st["repaired"] == 1
        assert pfs_file.read_bytes() == good

    def test_mem_rot_repaired_from_disk(self, tmp_path, data):
        env = _env(tmp_path, CRAFT_TIER_CHAIN="mem,node,pfs")
        cp = _write(env, data)
        MemFabric.instance().corrupt_entry("s", 0, 1)
        st = cp.scrubber.scan_once()
        assert st["corrupt_found"] == 1 and st["repaired"] == 1
        ok, target, rcp = _restore(env, data)
        assert ok and np.array_equal(target, data)
        assert rcp.stats["restore_tier"] == "mem"   # RAM serves good bytes

    def test_every_tier_corrupt_one_scan_repairs_all(self, tmp_path, rng):
        """The acceptance sweep: rot injected on mem, node and pfs at once
        (on different payloads, so each has a healthy peer copy left)."""
        env = _env(tmp_path, CRAFT_TIER_CHAIN="mem,node,pfs")
        a = rng.standard_normal(50_000).astype(np.float32)
        b = rng.standard_normal(50_000).astype(np.float32)
        cp = Checkpoint("s", FakeComm(0, 1), env=env)
        cp.add("a", a.copy())
        cp.add("b", b.copy())
        cp.commit()
        cp.update_and_write()
        corrupt_file(tmp_path / "node" / "node-0" / "s" / "v-1"
                     / "a" / "array.bin")
        corrupt_file(tmp_path / "pfs" / "s" / "v-1" / "b" / "array.bin")
        MemFabric.instance().corrupt_entry("s", 0, 1, rel="a/array.bin")
        st = cp.scrubber.scan_once()
        assert st["corrupt_found"] == 3, st
        assert st["repaired"] == 3, st
        ta, tb = np.zeros_like(a), np.zeros_like(b)
        rcp = Checkpoint("s", FakeComm(0, 1), env=env)
        rcp.add("a", ta)
        rcp.add("b", tb)
        rcp.commit()
        assert rcp.restart_if_needed()
        assert np.array_equal(ta, a) and np.array_equal(tb, b)
        assert rcp.stats["restore_tier"] == "mem"
        assert rcp.stats["read_repairs"] == 0
        # a second pass confirms the fleet is clean
        assert cp.scrubber.scan_once()["corrupt_found"] == 0

    def test_same_file_rotted_everywhere_is_unrepairable(self, tmp_path, data):
        """Every copy of one payload rotted: nothing healthy to repair from —
        the scrubber reports it instead of inventing bytes."""
        env = _env(tmp_path, CRAFT_TIER_CHAIN="mem,node,pfs")
        cp = _write(env, data)
        corrupt_file(tmp_path / "node" / "node-0" / "s" / "v-1"
                     / "arr" / "array.bin")
        corrupt_file(tmp_path / "pfs" / "s" / "v-1" / "arr" / "array.bin")
        MemFabric.instance().corrupt_entry("s", 0, 1)
        st = cp.scrubber.scan_once()
        assert st["corrupt_found"] == 3
        assert st["repaired"] == 0 and st["unrepairable"] >= 1

    def test_clean_scan_touches_everything_finds_nothing(self, tmp_path, data):
        env = _env(tmp_path, CRAFT_TIER_CHAIN="mem,node,pfs")
        cp = _write(env, data)
        st = cp.scrubber.scan_once()
        assert st["corrupt_found"] == 0
        assert st["files_scanned"] >= 3             # one payload per tier
        assert st["bytes_scanned"] >= 3 * data.nbytes

    def test_delta_base_rot_detected_and_repaired(self, tmp_path, rng):
        """Chain verification: rot in a *base* chunk that a delta version
        references is caught and fixed before any restore walks the chain."""
        env = _env(tmp_path, CRAFT_DELTA="1", CRAFT_CHUNK_BYTES=4096,
                   CRAFT_KEEP_VERSIONS="3")
        data = rng.standard_normal(32_768).astype(np.float32)
        cp = Checkpoint("d", FakeComm(0, 1), env=env)
        cp.add("arr", data)
        cp.commit()
        cp.update_and_write()                       # v1: full
        data[:16] += 1.0                            # one dirty chunk
        cp.update_and_write()                       # v2: delta onto v1
        base = (tmp_path / "node" / "node-0" / "d" / "v-1"
                / "arr" / "array.bin")
        good = base.read_bytes()
        corrupt_file(base)
        st = cp.scrubber.scan_once()
        assert st["corrupt_found"] >= 1 and st["repaired"] >= 1
        assert base.read_bytes() == good
        ok, target, rcp = _restore(env, data, name="d")
        assert ok and np.array_equal(target, data)

    def test_json_rot_repaired_by_copy(self, tmp_path, data):
        env = _env(tmp_path, CRAFT_DELTA="1")
        cp = Checkpoint("j", FakeComm(0, 1), env=env)
        cp.add("arr", data.copy())
        cp.commit()
        cp.update_and_write()
        deps = (tmp_path / "node" / "node-0" / "j" / "v-1"
                / "deltadeps-0.json")
        deps.write_text("{ not json")
        st = cp.scrubber.scan_once()
        assert st["corrupt_found"] == 1 and st["repaired"] == 1


# ======================================================== repair-on-read
class TestRepairOnRead:
    def test_restore_repairs_and_serves_good_bytes(self, tmp_path, data):
        env = _env(tmp_path)
        _write(env, data).close()
        corrupt_file(tmp_path / "node" / "node-0" / "s" / "v-1"
                     / "arr" / "array.bin")
        ok, target, rcp = _restore(env, data)
        assert ok and np.array_equal(target, data)
        assert rcp.stats["restore_tier"] == "node"
        assert rcp.stats["read_repairs"] == 1

    def test_no_source_never_serves_bad_bytes(self, tmp_path, data):
        """Every copy rotted: restore must raise, not hand back garbage."""
        env = _env(tmp_path)
        _write(env, data).close()
        corrupt_file(tmp_path / "node" / "node-0" / "s" / "v-1"
                     / "arr" / "array.bin")
        corrupt_file(tmp_path / "pfs" / "s" / "v-1" / "arr" / "array.bin")
        target = np.zeros_like(data)
        cp = Checkpoint("s", FakeComm(0, 1), env=env)
        cp.add("arr", target)
        cp.commit()
        with pytest.raises(CheckpointError):
            cp.restart_if_needed()
        assert np.all(target == 0.0)

    def test_failed_redundancy_rebuild_preserves_version_dir(self, tmp_path,
                                                             data):
        """Regression: a redundancy-backed tier whose rebuild *fails* (single
        node — the PARTNER mirror is gated on n_nodes > 1) must put the
        original directory back, healthy sibling files included, and then
        repair per-file from a peer tier instead of destroying the version.
        """
        env = _env(tmp_path, CRAFT_NODE_REDUNDANCY="PARTNER")
        other = data[::-1].copy()
        cp = Checkpoint("s", FakeComm(0, 1), env=env)
        cp.add("arr", data.copy())
        cp.add("other", other.copy())
        cp.commit()
        cp.update_and_write()
        vdir = tmp_path / "node" / "node-0" / "s" / "v-1"
        healthy = (vdir / "other" / "array.bin").read_bytes()
        corrupt_file(vdir / "arr" / "array.bin")
        st = cp.scrubber.scan_once()
        assert st["corrupt_found"] == 1 and st["repaired"] == 1
        assert vdir.is_dir()
        assert (vdir / "other" / "array.bin").read_bytes() == healthy
        ok, target, rcp = _restore(env, data)
        assert ok and np.array_equal(target, data)
        assert rcp.stats["restore_tier"] == "node"

    def test_failed_rebuild_no_peer_source_keeps_original(self, tmp_path,
                                                          data):
        """Redundancy rebuild fails AND no peer tier has the version: the
        rotted dir (with its healthy files) must survive untouched."""
        env = _env(tmp_path, CRAFT_NODE_REDUNDANCY="PARTNER",
                   CRAFT_PFS_EVERY="100")
        other = data[::-1].copy()
        cp = Checkpoint("s", FakeComm(0, 1), env=env)
        cp.add("arr", data.copy())
        cp.add("other", other.copy())
        cp.commit()
        cp.update_and_write()
        vdir = tmp_path / "node" / "node-0" / "s" / "v-1"
        healthy = (vdir / "other" / "array.bin").read_bytes()
        corrupt_file(vdir / "arr" / "array.bin")
        st = cp.scrubber.scan_once()
        assert st["corrupt_found"] == 1
        assert st["unrepairable"] == 1 and st["quarantined"] == 0
        assert vdir.is_dir()
        assert (vdir / "other" / "array.bin").read_bytes() == healthy

    def test_single_tier_unrepairable_is_not_quarantined(self, tmp_path, data):
        """The last copy — even a rotten one — is never deleted."""
        env = _env(tmp_path, CRAFT_USE_SCR="0", CRAFT_TIER_CHAIN="pfs")
        cp = _write(env, data)
        pfs_file = tmp_path / "pfs" / "s" / "v-1" / "arr" / "array.bin"
        corrupt_file(pfs_file)
        st = cp.scrubber.scan_once()
        assert st["corrupt_found"] == 1
        assert st["unrepairable"] == 1 and st["quarantined"] == 0
        assert pfs_file.exists()


# ======================================================== RS parity scrub
def _rs_group_env(tmp_path):
    return CraftEnv.capture({
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_NODE_CP_PATH": str(tmp_path / "node"),
        "CRAFT_NODE_REDUNDANCY": "RS",
        "CRAFT_XOR_GROUP_SIZE": "4",
        "CRAFT_RS_PARITY": "2",
        "CRAFT_PFS_EVERY": "100",
    })


def _write_rs_group(env, n_nodes=4):
    world = SimWorld(n_nodes, procs_per_node=1, env=env)

    def fn(comm):
        cp = Checkpoint("st", comm, env=env)
        cp.add("arr", np.full((64,), float(comm.rank + 1)))
        cp.commit()
        cp.update_and_write()
        cp.close()

    world.run(fn, timeout=120)


class TestRSScrub:
    def test_rotted_parity_shard_reencoded(self, tmp_path):
        env = _rs_group_env(tmp_path)
        _write_rs_group(env)
        shard = next((tmp_path / "node").glob(
            "node-*/rs-group-0/st/v-1/parity-*.bin"))
        good = shard.read_bytes()
        corrupt_file(shard, offset=10)
        store = NodeStore(base=env.node_cp_path, name="st",
                          comm=FakeComm(0, 4), env=env)
        stats = store.scrub_redundancy(1)
        assert stats["repaired"] == 1
        assert shard.read_bytes() == good

    def test_member_rot_repaired_via_parity_rebuild(self, tmp_path):
        env = _rs_group_env(tmp_path)
        _write_rs_group(env)
        member = (tmp_path / "node" / "node-1" / "st" / "v-1"
                  / "arr" / "array.bin")
        good = member.read_bytes()
        corrupt_file(member)
        cp = Checkpoint("st", FakeComm(1, 4), env=env)
        cp.add("arr", np.zeros((64,)))
        cp.commit()
        st = cp.scrubber.scan_once()
        assert st["corrupt_found"] == 1 and st["repaired"] == 1
        assert member.read_bytes() == good          # parity rebuild, bit-exact

    def test_rotted_member_not_laundered_into_parity(self, tmp_path):
        """scrub_redundancy refuses to re-encode parity over a rotted member."""
        env = _rs_group_env(tmp_path)
        _write_rs_group(env)
        corrupt_file(tmp_path / "node" / "node-2" / "st" / "v-1"
                     / "arr" / "array.bin")
        shard = next((tmp_path / "node").glob(
            "node-*/rs-group-0/st/v-1/parity-*.bin"))
        corrupt_file(shard, offset=10)
        store = NodeStore(base=env.node_cp_path, name="st",
                          comm=FakeComm(0, 4), env=env)
        stats = store.scrub_redundancy(1)
        assert stats["repaired"] == 0 and stats["unrepairable"] == 1


# ======================================================== scheduling/throttle
class TestScheduling:
    def _cp(self, tmp_path, clock, **extra):
        # cadence pfs:2 → every other opportunity writes, the rest are the
        # idle windows scrub slices ride on
        env = _env(tmp_path, CRAFT_USE_SCR="0", CRAFT_TIER_CHAIN="pfs",
                   CRAFT_IO_WORKERS="1", CRAFT_TIER_EVERY="pfs:2",
                   **extra)
        cp = Checkpoint("t", FakeComm(0, 1), env=env, clock=clock)
        cp.add("arr", np.ones(8192, dtype=np.float32))
        cp.commit()
        return cp

    def test_scrub_rides_idle_opportunities(self, tmp_path):
        t = [0.0]
        it = iter(range(1, 100))
        cp = self._cp(tmp_path, lambda: t[0], CRAFT_SCRUB_EVERY="10")
        assert cp.update_and_write(next(it)) or cp.update_and_write(next(it))
        for _ in range(4):                          # idle-ish steps, +4 s
            t[0] += 1.0
            cp.update_and_write(next(it))
        assert cp.scrubber.stats["slices"] == 0     # 10 s not yet elapsed
        t[0] += 10.0
        while cp.update_and_write(next(it)):        # land on a skip step
            pass
        assert cp.scrubber.stats["slices"] == 1
        assert cp.policy.stats["scrub_slices"] == 1
        assert cp.scrubber.stats["files_scanned"] >= 1

    def test_scrub_disabled_by_default(self, tmp_path):
        t = [0.0]
        cp = self._cp(tmp_path, lambda: t[0])
        cp.update_and_write(1)
        cp.update_and_write(2)
        t[0] += 1e6
        cp.update_and_write(3)
        cp.update_and_write(4)
        assert cp.scrubber.stats["slices"] == 0

    def test_bytes_per_s_throttle_slices_the_pass(self, tmp_path):
        t = [0.0]
        cp = self._cp(tmp_path, lambda: t[0], CRAFT_SCRUB_EVERY="1",
                      CRAFT_SCRUB_BYTES_PER_S="1", CRAFT_KEEP_VERSIONS="4")
        for it in range(1, 9):                      # lands 4 versions on pfs
            cp.update_and_write(it)
        assert cp.version >= 3
        # 1 B/s budget → each slice verifies exactly one version
        scanned = []
        for it in range(100, 108):
            t[0] += 2.0
            if not cp.update_and_write(it):
                scanned.append(cp.scrubber.stats["files_scanned"])
        assert cp.scrubber.stats["slices"] >= 3
        assert scanned == sorted(scanned)           # progress each slice
        assert scanned[-1] > scanned[0]             # but never all at once
        assert scanned[0] <= 2                      # first slice: one version
