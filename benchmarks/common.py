"""Shared benchmark helpers: CSV emission + timing + JSON artifact dump."""
from __future__ import annotations

import json
import time
from contextlib import contextmanager

_ROWS = []
_RECORDS = []


def emit(bench: str, name: str, value, unit: str, **extra) -> None:
    tags = ",".join(f"{k}={v}" for k, v in extra.items())
    line = f"{bench},{name},{value},{unit}" + (f",{tags}" if tags else "")
    _ROWS.append(line)
    _RECORDS.append({"bench": bench, "name": name, "value": value,
                     "unit": unit, **extra})
    print(line, flush=True)


def dump_json(path: str) -> None:
    """Write every record emitted so far as a JSON array (CI artifact)."""
    with open(path, "w") as fh:
        json.dump(_RECORDS, fh, indent=1)
    print(f"wrote {len(_RECORDS)} records to {path}", flush=True)


@contextmanager
def timed():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0


def header() -> None:
    print("bench,name,value,unit,tags", flush=True)


def run_scenarios(scenarios: dict, default, argv=None) -> None:
    """Shared scenario CLI: ``[name ...] [--full] [--json OUT.json]``.

    ``scenarios`` maps names to ``fn(full: bool)``; no names runs
    ``default(full)``.  Used by the per-module ``__main__`` blocks
    (cr_overhead, recovery_scaling) so the parsing lives once.
    """
    import sys

    argv = list(sys.argv[1:] if argv is None else argv)
    run_full = "--full" in argv
    json_out = None
    if "--json" in argv:
        at = argv.index("--json")
        if at + 1 >= len(argv) or argv[at + 1].startswith("-"):
            raise SystemExit("--json needs an output path")
        json_out = argv[at + 1]
    names = [a for a in argv if not a.startswith("-")
             and (json_out is None or a != json_out)]
    bad = [n for n in names if n not in scenarios]
    if bad:
        raise SystemExit(
            f"unknown scenario(s) {bad}; choose from {sorted(scenarios)}")
    if names:
        for nm in names:
            scenarios[nm](run_full)
    else:
        default(run_full)
    if json_out:
        dump_json(json_out)
