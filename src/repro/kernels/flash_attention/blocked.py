"""Blocked (flash-algorithm) attention in pure JAX with a custom VJP.

Why this exists: the Pallas kernel only lowers on TPU; the *naive* reference
materializes O(Lq·Lk) scores, which at the assigned 32k shapes is terabytes
— unusable even to compile against.  This module runs the flash algorithm
as a ``lax.scan`` over KV blocks (online softmax forward, recomputing
backward), so HLO memory matches the kernel's O(L·D) behavior on every
backend.  It is the non-TPU half of ``ops.attention`` and the backward used
for the Pallas forward.

Forward residuals: (q, k, v, out, lse) — exactly flash-attention's.
Backward: one scan over KV blocks accumulating dq and emitting per-block
(dk, dv); fp32 throughout the softmax.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.activations import constrain

_NEG = -1e30


def _pad_blocks(x, axis: int, block: int):
    n = x.shape[axis]
    pad = (-n) % block
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x, n


def _mask(qpos, kpos, causal, window, kv_len):
    m = kpos < kv_len
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m


def _fwd(q, k, v, causal, window, sm_scale, q_offset, kv_len, block):
    # `pallas_equiv_flash`: on the TPU target this whole blocked scan is the
    # Pallas flash kernel (kernels/flash_attention/kernel.py) whose
    # intermediates live in VMEM — the roofline analyzer charges only the
    # kernel's HBM boundary (q/k/v in, out/lse out) for ops in this scope.
    with jax.named_scope("pallas_equiv_flash"):
        return _fwd_inner(q, k, v, causal, window, sm_scale, q_offset,
                          kv_len, block)


def _fwd_inner(q, k, v, causal, window, sm_scale, q_offset, kv_len, block):
    b, hq, lq, dk_ = q.shape
    _, hkv, lk, dv = v.shape
    group = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, group, lq, dk_)
    kp, lk0 = _pad_blocks(k.astype(jnp.float32), 2, block)
    vp, _ = _pad_blocks(v.astype(jnp.float32), 2, block)
    nb = kp.shape[2] // block
    kb = jnp.moveaxis(kp.reshape(b, hkv, nb, block, dk_), 2, 0)
    vb = jnp.moveaxis(vp.reshape(b, hkv, nb, block, dv), 2, 0)
    kb = constrain(kb, None, "batch", "kv_heads", None, None)
    vb = constrain(vb, None, "batch", "kv_heads", None, None)
    qpos = q_offset + jnp.arange(lq)
    kv_len_eff = jnp.minimum(kv_len, lk0)

    def body(carry, xs):
        m, l, acc = carry
        k_b, v_b, ib = xs
        kpos = ib * block + jnp.arange(block)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k_b) * sm_scale
        msk = _mask(qpos[:, None], kpos[None, :], causal, window, kv_len_eff)
        s = jnp.where(msk[None, None, None], s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(msk[None, None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, v_b)
        return (m_new, l_new, acc_new), None

    m0 = constrain(jnp.full((b, hkv, group, lq), _NEG, jnp.float32),
                   "batch", "kv_heads", None, None)
    l0 = constrain(jnp.zeros((b, hkv, group, lq), jnp.float32),
                   "batch", "kv_heads", None, None)
    a0 = constrain(jnp.zeros((b, hkv, group, lq, dv), jnp.float32),
                   "batch", "kv_heads", None, None, None)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(nb)))
    denom = jnp.where(l == 0.0, 1.0, l)
    out = (acc / denom[..., None]).reshape(b, hq, lq, dv)
    lse = (m + jnp.log(denom)).reshape(b, hq, lq)
    return out.astype(q.dtype), lse


def _bwd(q, k, v, out, lse, g, causal, window, sm_scale, q_offset, kv_len,
         block):
    with jax.named_scope("pallas_equiv_flash"):
        return _bwd_inner(q, k, v, out, lse, g, causal, window, sm_scale,
                          q_offset, kv_len, block)


def _bwd_inner(q, k, v, out, lse, g, causal, window, sm_scale, q_offset,
               kv_len, block):
    b, hq, lq, dk_ = q.shape
    _, hkv, lk, dv = v.shape
    group = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, group, lq, dk_)
    gf = g.astype(jnp.float32).reshape(b, hkv, group, lq, dv)
    of = out.astype(jnp.float32).reshape(b, hkv, group, lq, dv)
    lsef = lse.reshape(b, hkv, group, lq)
    delta = jnp.sum(gf * of, axis=-1)                     # (b,hkv,g,lq)
    kp, lk0 = _pad_blocks(k.astype(jnp.float32), 2, block)
    vp, _ = _pad_blocks(v.astype(jnp.float32), 2, block)
    nb = kp.shape[2] // block
    kb = jnp.moveaxis(kp.reshape(b, hkv, nb, block, dk_), 2, 0)
    vb = jnp.moveaxis(vp.reshape(b, hkv, nb, block, dv), 2, 0)
    kb = constrain(kb, None, "batch", "kv_heads", None, None)
    vb = constrain(vb, None, "batch", "kv_heads", None, None)
    qpos = q_offset + jnp.arange(lq)
    kv_len_eff = jnp.minimum(kv_len, lk0)

    def body(dq, xs):
        k_b, v_b, ib = xs
        kpos = ib * block + jnp.arange(block)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k_b) * sm_scale
        msk = _mask(qpos[:, None], kpos[None, :], causal, window, kv_len_eff)
        p = jnp.exp(s - lsef[..., None])
        p = jnp.where(msk[None, None, None], p, 0.0)
        dv_b = jnp.einsum("bhgqk,bhgqd->bhkd", p, gf)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", gf, v_b)
        ds = p * (dp - delta[..., None]) * sm_scale
        dq = dq + jnp.einsum("bhgqk,bhkd->bhgqd", ds, k_b)
        dk_b = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qf)
        return dq, (dk_b, dv_b)

    dq0 = constrain(jnp.zeros((b, hkv, group, lq, dk_), jnp.float32),
                    "batch", "kv_heads", None, None, None)
    dq, (dkb, dvb) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nb)))
    dk = jnp.moveaxis(dkb, 0, 2).reshape(b, hkv, nb * block, dk_)[:, :, :lk]
    dvv = jnp.moveaxis(dvb, 0, 2).reshape(b, hkv, nb * block, dv)[:, :, :lk]
    return (dq.reshape(b, hq, lq, dk_).astype(q.dtype),
            dk.astype(k.dtype), dvv.astype(v.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def blocked_attention(q, k, v, causal=True, window=None, sm_scale=None,
                      q_offset=0, kv_len=None, block=1024,
                      use_pallas=False):
    out, _ = _dispatch_fwd(q, k, v, causal, window, sm_scale, q_offset,
                           kv_len, block, use_pallas)
    return out


def _dispatch_fwd(q, k, v, causal, window, sm_scale, q_offset, kv_len,
                  block, use_pallas):
    if sm_scale is None:
        sm_scale = q.shape[-1] ** -0.5
    if kv_len is None:
        kv_len = k.shape[2]
    if use_pallas:
        from repro.kernels.flash_attention.ops import _padded_flash

        out = _padded_flash(q, k, v, causal=causal, window=window,
                            sm_scale=sm_scale, q_offset=q_offset,
                            interpret=False)
        # lse recomputed lazily in backward via the jnp path when needed;
        # store a placeholder via one blocked fwd only under grad.
        return out, None
    out, lse = _fwd(q, k, v, causal, window, sm_scale, q_offset, kv_len,
                    block)
    return out, lse


def _vjp_fwd(q, k, v, causal, window, sm_scale, q_offset, kv_len, block,
             use_pallas):
    if sm_scale is None:
        sm_scale = q.shape[-1] ** -0.5
    if kv_len is None:
        kv_len = k.shape[2]
    # under AD we always take the jnp blocked path so lse residuals exist
    out, lse = _fwd(q, k, v, causal, window, sm_scale, q_offset, kv_len,
                    block)
    return out, (q, k, v, out, lse)


def _vjp_bwd(causal, window, sm_scale, q_offset, kv_len, block, use_pallas,
             res, g):
    q, k, v, out, lse = res
    if sm_scale is None:
        sm_scale = q.shape[-1] ** -0.5
    if kv_len is None:
        kv_len = k.shape[2]
    return _bwd(q, k, v, out, lse, g, causal, window, sm_scale, q_offset,
                kv_len, block)


blocked_attention.defvjp(_vjp_fwd, _vjp_bwd)
