"""Asynchronous checkpoint writing (paper §2.4) — sequencer + worker pool.

The paper dedicates one writer thread per process (``std::async``) with two
modes:

* **copy-based** (``CRAFT_WRITE_ASYNC=1``): ``update()`` snapshots each
  checkpointable into a private buffer, then file IO runs on the writer
  thread while the application keeps computing.
* **zero-copy** (``CRAFT_WRITE_ASYNC_ZERO_COPY=1``): no snapshot; the writer
  thread serializes the *live* data, and the application must call
  ``Checkpoint.wait()`` before mutating it.

Beyond the paper, the writer is now a two-lane construct:

* the **sequencer** — a single dedicated thread executing ``submit()`` jobs
  strictly in submission order.  ``Checkpoint`` submits one job per version,
  so version K is always fully published before K+1 starts (ordering per
  checkpoint version is a durability invariant: ``meta.json`` must never
  point at a version newer than the directories on disk).
* a **bounded worker pool** of ``workers`` threads serving
  :meth:`run_parallel` — independent jobs (per-array file writes, per-chunk
  encodes) fan out across it.  The *calling* thread always participates in
  draining its own job list, so ``run_parallel`` never deadlocks even when
  every pool worker is busy or the pool is saturated, and nested fanout
  (arrays → chunks) degrades gracefully to inline execution.

``CRAFT_ASYNC_THREAD_PIN_CPULIST`` pins all writer threads (paper: maximize
async gain by keeping the writer off the compute cores).  On Linux we honor it
via ``os.sched_setaffinity``; elsewhere it is a documented no-op.
"""
from __future__ import annotations

import os
import threading
import time
import queue
from collections import deque
from typing import Callable, List, Optional, Sequence

from . import metrics, trace


class AsyncWriter:
    """Ordered writer lane + bounded worker pool for checkpoint IO jobs."""

    def __init__(
        self,
        workers: int = 1,
        pin_cpulist: Sequence[int] = (),
        name: str = "craft-writer",
    ):
        self.workers = max(1, int(workers))
        self._name = name
        self._pin = tuple(pin_cpulist)
        self._error: Optional[BaseException] = None
        self._error_label: Optional[str] = None
        self._pending = 0
        self._cv = threading.Condition()
        # ordered lane (sequencer)
        self._queue: "queue.Queue" = queue.Queue()
        self._seq_thread = threading.Thread(
            target=self._seq_loop, name=name, daemon=True
        )
        self._seq_started = False
        # worker pool (fanout lane); bounded so a burst of fanouts cannot
        # enqueue unbounded helper entries
        self._pool_queue: "queue.Queue" = queue.Queue(maxsize=4 * self.workers)
        self._pool_threads: List[threading.Thread] = []
        self._pool_lock = threading.Lock()
        self._closed = False
        # timing taps consumed by the checkpoint scheduler: per-job wall time
        # on the ordered lane, and the high-water mark of the queue depth
        # StatsView mirrors into the registry as async_* series per writer
        self.stats = metrics.StatsView(name, {
            "jobs": 0, "job_seconds": 0.0,
            "last_job_seconds": 0.0, "max_pending": 0,
            "stall_warnings": 0,
        }, prefix="async_", label="writer")
        # stall watchdog state: submit times of in-flight ordered-lane jobs
        # (the sequencer completes them in order, so the head is the oldest),
        # and the id of the job we already warned about (one warning per job)
        self._inflight: "deque" = deque()
        self._job_seq = 0
        self._stall_warned = -1

    # -- lifecycle -----------------------------------------------------------
    def _apply_pin(self) -> None:
        if self._pin and hasattr(os, "sched_setaffinity"):
            try:
                os.sched_setaffinity(0, set(self._pin))
            except OSError:
                pass  # CPU list not available on this host — documented no-op

    def _ensure_seq_started(self) -> None:
        if not self._seq_started:
            self._seq_thread.start()
            self._seq_started = True

    def _ensure_pool_started(self) -> None:
        with self._pool_lock:
            if self._closed or self._pool_threads:
                return
            for i in range(self.workers):
                t = threading.Thread(
                    target=self._pool_loop,
                    name=f"{self._name}-pool-{i}",
                    daemon=True,
                )
                t.start()
                self._pool_threads.append(t)

    def _seq_loop(self) -> None:
        self._apply_pin()
        while True:
            item = self._queue.get()
            if item is None:
                return
            job, label = item
            t0 = time.perf_counter()
            try:
                job()
            except BaseException as exc:  # surfaced at next wait()/submit()
                with self._cv:
                    self._error = exc
                    self._error_label = label
            finally:
                dt = time.perf_counter() - t0
                with self._cv:
                    self._pending -= 1
                    pending = self._pending
                    if self._inflight:
                        self._inflight.popleft()
                    self.stats["jobs"] += 1
                    self.stats["job_seconds"] += dt
                    self.stats["last_job_seconds"] = dt
                    self._cv.notify_all()
                metrics.observe("async_job_seconds", dt)
                metrics.set_gauge("async_pending", pending)

    def _pool_loop(self) -> None:
        self._apply_pin()
        while True:
            task = self._pool_queue.get()
            if task is None:
                return
            task()  # drain-helpers never raise (errors collected per group)

    # -- ordered lane ----------------------------------------------------------
    def submit(self, job: Callable[[], None],
               label: Optional[str] = None) -> None:
        """Enqueue a job on the ordered lane (strict submission order).

        ``label`` names the job in the error surfaced at a later
        ``wait()``/``submit()`` — without it an async failure reports only
        the exception, with no hint which version/tier it came from.
        """
        self._raise_pending_error()
        self._ensure_seq_started()
        with self._cv:
            self._pending += 1
            pending = self._pending
            self._job_seq += 1
            self._inflight.append((self._job_seq, time.monotonic(), label))
            if self._pending > self.stats["max_pending"]:
                self.stats["max_pending"] = self._pending
        metrics.set_gauge("async_pending", pending)
        self._queue.put((job, label))

    def wait(self) -> None:
        """Block until all submitted jobs finished; re-raise writer errors."""
        t0 = time.perf_counter() if metrics.REGISTRY.enabled else 0.0
        with self._cv:
            while self._pending > 0:
                self._cv.wait()
        if t0:
            metrics.observe("async_fence_seconds", time.perf_counter() - t0)
        self._raise_pending_error()

    # -- stall watchdog --------------------------------------------------------
    def oldest_pending_s(self, now: Optional[float] = None) -> float:
        """Age in seconds of the oldest in-flight ordered-lane job (0 when
        the lane is drained) — the ``async_oldest_pending_s`` heartbeat."""
        with self._cv:
            if not self._inflight:
                return 0.0
            t0 = self._inflight[0][1]
        return max(0.0, (time.monotonic() if now is None else now) - t0)

    def check_stall(self, deadline_s: float = 0.0) -> float:
        """Publish the heartbeat gauge and warn (once per job, through both
        metrics and trace) when the oldest pending write has outlived
        ``CRAFT_IO_DEADLINE_S``.  Called from ``Checkpoint._decide`` every
        step — cheap: one lock, one clock read."""
        with self._cv:
            if self._inflight:
                seq, t0, label = self._inflight[0]
                age = time.monotonic() - t0
            else:
                seq, label, age = -1, None, 0.0
            pending = self._pending
        metrics.set_gauge("async_oldest_pending_s", age)
        if deadline_s > 0 and seq >= 0 and age > deadline_s \
                and seq != self._stall_warned:
            self._stall_warned = seq
            self.stats["stall_warnings"] += 1
            metrics.inc("async_stall_warnings")
            trace.emit("async_stall", label=label, age_s=round(age, 3),
                       deadline_s=deadline_s, pending=pending)
        return age

    # -- fanout lane -----------------------------------------------------------
    def run_parallel(self, jobs: Sequence[Callable[[], object]]) -> list:
        """Run independent jobs across the pool; return results in order.

        The calling thread participates in draining the job list, pool
        workers help as capacity allows; the first raised exception cancels
        all not-yet-started jobs and is re-raised after in-flight jobs drain.
        """
        jobs = list(jobs)
        if not jobs:
            return []
        if len(jobs) == 1 or self.workers == 1:
            return [job() for job in jobs]
        self._ensure_pool_started()
        results: list = [None] * len(jobs)
        errors: List[BaseException] = []
        pending = deque(enumerate(jobs))
        lock = threading.Lock()
        done = threading.Event()
        remaining = [len(jobs)]

        def drain() -> None:
            while True:
                with lock:
                    if errors and pending:  # cancel unstarted work
                        remaining[0] -= len(pending)
                        pending.clear()
                        if remaining[0] == 0:
                            done.set()
                    if not pending:
                        return
                    i, job = pending.popleft()
                try:
                    r = job()
                except BaseException as exc:
                    with lock:
                        errors.append(exc)
                        remaining[0] -= 1
                        if remaining[0] == 0:
                            done.set()
                else:
                    with lock:
                        results[i] = r
                        remaining[0] -= 1
                        if remaining[0] == 0:
                            done.set()

        for _ in range(min(self.workers, len(jobs) - 1)):
            try:
                self._pool_queue.put_nowait(drain)
            except queue.Full:
                break  # pool saturated — caller (and busy workers) drain it
        drain()      # caller participates; returns when no job is unclaimed
        done.wait()  # helpers may still be finishing their last job
        if errors:
            raise errors[0]
        return results

    # -- teardown --------------------------------------------------------------
    def close(self) -> None:
        if self._seq_started:
            self.wait()
            self._queue.put(None)
            self._seq_thread.join(timeout=30)
            self._seq_started = False
        with self._pool_lock:
            self._closed = True
            threads, self._pool_threads = self._pool_threads, []
        for _ in threads:
            self._pool_queue.put(None)
        for t in threads:
            t.join(timeout=30)

    @property
    def busy(self) -> bool:
        with self._cv:
            return self._pending > 0

    @property
    def pending(self) -> int:
        """Ordered-lane jobs submitted but not yet finished — the scheduler's
        backpressure signal: a saturated queue stretches checkpoint
        intervals instead of stacking versions behind a slow tier."""
        with self._cv:
            return self._pending

    def _raise_pending_error(self) -> None:
        with self._cv:
            err, self._error = self._error, None
            label, self._error_label = self._error_label, None
        if err is not None:
            # Deferred surfacing loses the call-site context, so attach the
            # job's identity.  OSErrors propagate unwrapped — callers match
            # on their type/errno, and the storage layer already embedded
            # tier/version/array context in the message at the fault site.
            if label and not isinstance(err, OSError):
                from repro.core.cpbase import CheckpointError

                raise CheckpointError(
                    f"async checkpoint write failed ({label}): {err}"
                ) from err
            raise err
