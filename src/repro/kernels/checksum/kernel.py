"""Pallas TPU kernel: blocked Fletcher-like checksum (checkpoint integrity).

Device-side integrity digests let the node-level tier verify a checkpoint
shard *before* the bytes ever leave HBM (beyond-paper extension of CRAFT's
crc32-on-host).  The digest is a pair of mod-2^32 sums (see ref.py); the
position-weighted ``s2`` makes it order-sensitive, unlike a plain sum.

TPU mapping: the uint32 stream is viewed as (rows, 128) so every tile is
lane-aligned; the grid walks row-blocks sequentially, each step computing the
tile-local (s1, s2) on the VPU, shifting s2 by the tile's element offset
(associativity: s2 += offset · s1, mod 2^32), and accumulating into a tiny
(1, 2) block that every grid step maps to the same location — the canonical
Pallas-TPU reduction-across-grid idiom.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128


def _checksum_kernel(x_ref, out_ref, *, block_rows: int):
    i = pl.program_id(0)
    tile = x_ref[...]                                     # (block_rows, 128)
    # local element index within the tile, 2-D iota (TPU requires >= 2-D)
    row = jax.lax.broadcasted_iota(jnp.uint32, tile.shape, 0)
    lane = jax.lax.broadcasted_iota(jnp.uint32, tile.shape, 1)
    local_pos1 = row * jnp.uint32(_LANES) + lane + jnp.uint32(1)  # 1-based
    s1 = jnp.sum(tile, dtype=jnp.uint32)
    s2_local = jnp.sum(tile * local_pos1, dtype=jnp.uint32)
    offset = (jnp.uint32(i) * jnp.uint32(block_rows * _LANES))
    s2 = s2_local + offset * s1
    contrib = jnp.stack([s1, s2]).reshape(1, 2)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(i != 0)
    def _acc():
        out_ref[...] = out_ref[...] + contrib


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def checksum(
    x: jnp.ndarray, *, block_rows: int = 512, interpret: bool = False
) -> jnp.ndarray:
    """Blocked checksum of a 1-D uint32 array; returns (2,) uint32 [s1, s2].

    ``len(x)`` must be a multiple of ``block_rows * 128`` (ops.py zero-pads —
    zero lanes contribute nothing to either sum, so padding is digest-neutral
    given the true length is recorded alongside).
    """
    if x.ndim != 1 or x.dtype != jnp.uint32:
        raise TypeError(f"expected 1-D uint32, got {x.shape} {x.dtype}")
    n = x.shape[0]
    block_n = block_rows * _LANES
    if n % block_n:
        raise ValueError(f"N={n} must be a multiple of block_rows*128={block_n}")
    x2 = x.reshape(n // _LANES, _LANES)
    grid = (n // block_n,)
    out = pl.pallas_call(
        functools.partial(_checksum_kernel, block_rows=block_rows),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 2), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 2), jnp.uint32),
        interpret=interpret,
    )(x2)
    return out[0]
