"""Per-kernel tests: Pallas (interpret=True) and blocked-jnp vs ref oracles.

Shape/dtype sweeps per the assignment; every kernel asserts allclose against
its ``ref.py`` pure-jnp oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.checksum import ops as ck_ops
from repro.kernels.checksum.ref import checksum_ref
from repro.kernels.flash_attention import blocked
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.kernel import flash_attention as fa_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.xor_parity import ops as xor_ops
from repro.kernels.xor_parity.ref import xor_reduce_ref


def _qkv(key, b, hq, hkv, lq, lk, d, dv=None, dtype=jnp.float32):
    dv = dv or d
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, lq, d), dtype)
    k = jax.random.normal(kk, (b, hkv, lk, d), dtype)
    v = jax.random.normal(kv_, (b, hkv, lk, dv), dtype)
    return q, k, v


# ======================================================== flash attention
class TestFlashPallasInterpret:
    """The Pallas kernel body executed on CPU via interpret=True."""

    CASES = [
        # (b, hq, hkv, lq, lk, d, causal, window, dtype)
        (1, 2, 2, 128, 128, 64, True, None, jnp.float32),
        (2, 4, 2, 128, 256, 64, True, None, jnp.float32),
        (1, 2, 1, 256, 128, 128, False, None, jnp.float32),
        (1, 2, 2, 128, 128, 64, True, 64, jnp.float32),
        (1, 4, 4, 128, 128, 64, True, None, jnp.bfloat16),
        (2, 8, 2, 128, 128, 32, True, None, jnp.bfloat16),
    ]

    @pytest.mark.parametrize("case", CASES)
    def test_matches_ref(self, case):
        b, hq, hkv, lq, lk, d, causal, window, dtype = case
        q, k, v = _qkv(jax.random.PRNGKey(0), b, hq, hkv, lq, lk, d,
                       dtype=dtype)
        out = fa_pallas(q, k, v, causal=causal, window=window,
                        interpret=True)
        ref = attention_ref(q, k, v, causal=causal, window=window)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=tol, atol=tol)

    def test_kv_len_masking(self):
        q, k, v = _qkv(jax.random.PRNGKey(1), 1, 2, 2, 128, 256, 64)
        out = fa_pallas(q, k, v, causal=False, kv_len=160, interpret=True)
        ref = attention_ref(q, k, v, causal=False, kv_len=jnp.int32(160))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_q_offset_decode_chunk(self):
        """Chunked prefill: q block at offset 128 attending over 256 keys."""
        q, k, v = _qkv(jax.random.PRNGKey(2), 1, 2, 2, 128, 256, 64)
        out = fa_pallas(q, k, v, causal=True, q_offset=128, interpret=True)
        ref = attention_ref(q, k, v, causal=True, q_offset=jnp.int32(128))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestBlockedJnp:
    """The scan-based flash algorithm (the CPU/backward path)."""

    @pytest.mark.parametrize("lq,lk,block", [(64, 64, 16), (100, 260, 64),
                                             (128, 512, 128)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_forward(self, lq, lk, block, causal):
        q, k, v = _qkv(jax.random.PRNGKey(3), 2, 4, 2, lq, lk, 32)
        out, lse = blocked._fwd(q, k, v, causal, None, 32 ** -0.5,
                                jnp.int32(0), jnp.int32(lk), block)
        ref = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_window(self):
        q, k, v = _qkv(jax.random.PRNGKey(4), 1, 2, 2, 96, 96, 16)
        out, _ = blocked._fwd(q, k, v, True, 24, 16 ** -0.5,
                              jnp.int32(0), jnp.int32(96), 32)
        ref = attention_ref(q, k, v, causal=True, window=24)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_gradients_match_ref(self):
        """custom-vjp backward vs autodiff through the naive reference."""
        q, k, v = _qkv(jax.random.PRNGKey(5), 1, 2, 1, 64, 64, 16)

        def f_ops(q, k, v):
            return (fa_ops.attention(q, k, v, causal=True) ** 2).sum()

        def f_ref(q, k, v):
            return (attention_ref(q, k, v, causal=True) ** 2).sum()

        g1 = jax.grad(f_ops, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)


# ======================================================== xor parity
class TestXorParity:
    @pytest.mark.parametrize("g,n", [(2, 128), (4, 512), (8, 4096)])
    def test_reduce_matches_ref(self, g, n):
        rng = np.random.default_rng(0)
        stacked = jnp.asarray(
            rng.integers(0, 2 ** 32, (g, n), dtype=np.uint32))
        ref = xor_reduce_ref(stacked)
        out = xor_ops.xor_reduce(stacked, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_pallas_interpret(self):
        from repro.kernels.xor_parity.kernel import xor_reduce as xr
        rng = np.random.default_rng(1)
        stacked = jnp.asarray(
            rng.integers(0, 2 ** 32, (4, 256), dtype=np.uint32))
        out = xr(stacked, block_n=128, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(xor_reduce_ref(stacked)))

    def test_parity_reconstruct_roundtrip(self):
        rng = np.random.default_rng(2)
        bufs = [rng.bytes(100 + 13 * i) for i in range(5)]
        parity = xor_ops.parity_of_buffers(bufs)
        for lost in range(5):
            survivors = [b for i, b in enumerate(bufs) if i != lost]
            rebuilt = xor_ops.reconstruct_member(
                parity, survivors, len(bufs[lost]))
            assert rebuilt == bufs[lost]


# ======================================================== checksum
class TestChecksum:
    def test_matches_ref_and_detects_flips(self):
        rng = np.random.default_rng(3)
        data = rng.bytes(10_000)
        d1 = ck_ops.digest_bytes(data)
        assert d1 == ck_ops.digest_bytes(data)          # deterministic
        corrupted = bytearray(data)
        corrupted[1234] ^= 0x40
        assert ck_ops.digest_bytes(bytes(corrupted)) != d1

    def test_pallas_interpret_matches_ref(self):
        from repro.kernels.checksum.kernel import checksum as ck
        rng = np.random.default_rng(4)
        n = 512 * 128 * 2
        words = jnp.asarray(rng.integers(0, 2 ** 32, n, dtype=np.uint32))
        out = np.asarray(ck(words, interpret=True))
        ref = np.asarray(jax.jit(checksum_ref)(words))
        np.testing.assert_array_equal(out, ref)

    def test_order_sensitivity(self):
        """s2 makes the digest order-sensitive (unlike a plain XOR/sum)."""
        a = np.arange(1024, dtype=np.uint32)
        b = a[::-1].copy()
        assert ck_ops.digest_array(jnp.asarray(a)) != \
            ck_ops.digest_array(jnp.asarray(b))


# ======================================================== ssm selective scan
class TestSsmScan:
    """Pallas selective-scan kernels (interpret) vs naive oracles."""

    @pytest.mark.parametrize("shape", [
        # (B, L, nh, hd, st, blk)
        (1, 64, 2, 8, 8, 32),
        (2, 160, 3, 16, 8, 32),    # L not a multiple of blk (pads)
        (1, 128, 4, 32, 16, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_ssd_matches_ref(self, shape, dtype):
        from repro.kernels.ssm_scan.ops import selective_scan
        from repro.kernels.ssm_scan.ref import ssd_scan_ref
        b, l, nh, hd, st, blk = shape
        rng = np.random.default_rng(0)
        dtx = jnp.asarray(rng.standard_normal((b, l, nh, hd)), dtype)
        bh = jnp.asarray(rng.standard_normal((b, l, nh, st)), dtype)
        ch = jnp.asarray(rng.standard_normal((b, l, nh, st)), dtype)
        dt = jnp.asarray(rng.uniform(0, 0.5, (b, l, nh)), jnp.float32)
        A = jnp.asarray(-rng.uniform(0.5, 2, (nh,)), jnp.float32)
        h0 = jnp.asarray(rng.standard_normal((b, nh, hd, st)), jnp.float32)
        y_k, h_k = selective_scan(dtx, bh, ch, dt, A, h0, blk=blk,
                                  interpret=True, use_pallas=True)
        y_r, h_r = ssd_scan_ref(dtx, bh, ch, dt, A, h0)
        tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
        np.testing.assert_allclose(np.asarray(y_k, np.float32),
                                   np.asarray(y_r, np.float32),
                                   rtol=tol, atol=tol)
        np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                                   rtol=tol, atol=tol)

    @pytest.mark.parametrize("di,blk_d", [(128, 128), (256, 128)])
    def test_s6_matches_ref(self, di, blk_d):
        from repro.kernels.ssm_scan.ops import selective_scan
        from repro.kernels.ssm_scan.ref import s6_scan_ref
        b, l, st = 2, 96, 8
        rng = np.random.default_rng(1)
        dtx = jnp.asarray(rng.standard_normal((b, l, di)), jnp.float32)
        bh = jnp.asarray(rng.standard_normal((b, l, st)), jnp.float32)
        ch = jnp.asarray(rng.standard_normal((b, l, st)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0, 0.5, (b, l, di)), jnp.float32)
        A = jnp.asarray(-rng.uniform(0.5, 2, (di, st)), jnp.float32)
        h0 = jnp.asarray(rng.standard_normal((b, di, st)), jnp.float32)
        y_k, h_k = selective_scan(dtx, bh, ch, dt, A, h0, blk=32,
                                  interpret=True, use_pallas=True)
        y_r, h_r = s6_scan_ref(dtx, bh, ch, dt, A, h0)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                                   rtol=2e-4, atol=2e-4)

    def test_model_fused_path_matches_kernel(self):
        """The model's _fused_ssd_scan == the Pallas kernel (same math)."""
        from repro.kernels.ssm_scan.ops import selective_scan
        from repro.models.ssm import _fused_ssd_scan
        b, l, nh, hd, st = 1, 64, 2, 8, 8
        rng = np.random.default_rng(2)
        dtx = jnp.asarray(rng.standard_normal((b, l, nh, hd)), jnp.float32)
        bh = jnp.asarray(rng.standard_normal((b, l, nh, st)), jnp.float32)
        ch = jnp.asarray(rng.standard_normal((b, l, nh, st)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0, 0.5, (b, l, nh)), jnp.float32)
        A = jnp.asarray(-rng.uniform(0.5, 2, (nh,)), jnp.float32)
        h0 = jnp.asarray(rng.standard_normal((b, nh, hd, st)), jnp.float32)
        y_m, h_m = _fused_ssd_scan(dtx, bh, ch, dt, A, h0, chunk=16)
        y_k, h_k = selective_scan(dtx, bh, ch, dt, A, h0, blk=32,
                                  interpret=True, use_pallas=True)
        np.testing.assert_allclose(np.asarray(y_m), np.asarray(y_k),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(h_m), np.asarray(h_k),
                                   rtol=2e-4, atol=2e-4)
