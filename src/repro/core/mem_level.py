"""Memory tier — in-RAM replicated checkpoint storage for rapid AFT recovery.

The node and PFS tiers both end on storage that survives a process death but
costs a full codec decode (and, for the PFS, real disk IO) to restore.  After
an AFT shrink the surviving processes are healthy and their RAM is intact —
ReStore (Hübner et al., 2022) observes that keeping checkpoint shards
*replicated in surviving peers' memory* makes the post-failure restore orders
of magnitude faster than draining back to disk.  ``MemStore`` is that tier:

* each rank keeps its **own shards** of the latest versions in RAM, decoded
  and ready to hand back (``IOContext.array_cache`` fast path — restore is a
  dictionary lookup, not a codec pass);
* each rank additionally holds **replicas** of ``CRAFT_MEM_REPLICAS``
  partner ranks' shards, placed round-robin over the communicator (rank
  ``r``'s shards replicate to ranks ``r+1 .. r+R`` mod size), so any ``R``
  rank failures leave every shard reachable from a survivor;
* publish/abort/materialize follow the :class:`~repro.core.tiers.StorageTier`
  invariants — a version is either completely present (every owner's shard
  set reachable) or not restorable, and a failed publish leaves nothing;
* every payload carries a Fletcher digest from the v1 codec's checksum
  kernel, computed at publish; replica payloads served for a **dead** owner
  are re-verified before use (the same stale-survivor paranoia as the XOR
  node tier), while a live owner's own shards are trusted process RAM.

Transport model.  Like the node tier — where cross-node reads through the
shared filesystem stand in for the RDMA transfers of a real fleet — the
"fabric" here is process-shared memory: with the :mod:`repro.core.comm_sim`
backend every rank is a thread, so placing a replica in a partner's slot *is*
the RAM-to-RAM transfer.  Replica placement and the budget agreement are
still genuine communicator exchanges (allgather + min-reduction), so the
control flow matches what a wire implementation would run.  With one process
per rank (the :mod:`repro.runtime` backend) the fabric degrades to a
process-local cache: a killed process loses its slots exactly as a real host
loses its RAM, and restore falls back to the node/PFS tiers.

Fail-stop modelling: ``SimWorld.kill`` fires fault-domain hooks (see
:meth:`repro.core.comm.FTComm.fault_domain`); the fabric drops the dead
rank's slot — its own shards *and* every replica it held vanish atomically
with the fail-stop.  AFT recovery additionally reports the failed ranks via
:func:`notify_rank_failures`.

Budget (``CRAFT_MEM_BUDGET_BYTES``): per-rank cap on fabric residency.  The
projected load (own shards + incoming replicas + retained older versions) is
agreed collectively before anything is inserted; a version that does not fit
raises :class:`MemTierError` on **every** rank (all-or-nothing), and
``Checkpoint`` falls back to the node/PFS tiers for that version.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time as _time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import metrics, storage, tiers
from repro.core.cpbase import CheckpointError, IOContext
from repro.core.tiers import StorageTier
from repro.kernels.checksum import ops as checksum_ops

#: single chunk per file for memory-tier staging: the staged file lives for
#: milliseconds on RAM-backed scratch, so chunked encodes buy nothing
_ONE_CHUNK = 1 << 40


class MemTierError(CheckpointError):
    """Memory-tier publish refused (budget exceeded / undecodable payload).

    Raised collectively — every rank of the communicator raises together, so
    ``Checkpoint`` skips the memory tier for the version as a whole and the
    node/PFS write-through still happens.
    """


_SCRATCH_PREFIX = "craft-mem-"
_swept_stale_scratch = False


def _sweep_stale_scratch(parent: Path) -> None:
    """Remove scratch roots left by dead processes (kill -9 mid-stage).

    The disk tiers sweep stale ``.tmp-*`` at startup; this is the cross-PID
    analog for the RAM tier — without it every crash/restart cycle leaks a
    checkpoint-sized directory on tmpfs (host RAM) until /dev/shm fills.
    Runs once per process.
    """
    global _swept_stale_scratch
    if _swept_stale_scratch:
        return
    _swept_stale_scratch = True
    for p in parent.glob(f"{_SCRATCH_PREFIX}*"):
        try:
            pid = int(p.name[len(_SCRATCH_PREFIX):])
        except ValueError:
            continue
        if pid == os.getpid():
            continue
        try:
            os.kill(pid, 0)            # 0 = liveness probe, no signal sent
        except ProcessLookupError:
            shutil.rmtree(p, ignore_errors=True)
        except PermissionError:
            pass                       # alive, owned by another user


def default_scratch_root() -> Path:
    """RAM-backed scratch for staging/materialization (tmpfs when possible).

    PID-scoped so concurrent jobs on one host never collide; stale roots of
    dead PIDs are swept on first use."""
    shm = Path("/dev/shm")
    parent = shm if shm.is_dir() and os.access(shm, os.W_OK) \
        else Path(tempfile.gettempdir())
    _sweep_stale_scratch(parent)
    return parent / f"{_SCRATCH_PREFIX}{os.getpid()}"


class _MemEntry:
    """One stored file: a decoded (read-only) array or a raw blob."""

    __slots__ = ("array", "blob", "digest", "nbytes")

    def __init__(self, array: Optional[np.ndarray], blob: Optional[bytes],
                 digest: Tuple[int, int]):
        if array is not None:
            array = array.view()
            array.setflags(write=False)
        self.array = array
        self.blob = blob
        self.digest = digest
        self.nbytes = array.nbytes if array is not None else len(blob or b"")

    def verify(self) -> bool:
        payload = self.array if self.array is not None else self.blob
        return tuple(checksum_ops.digest_bytes(payload)) == tuple(self.digest)


class _MemVersion:
    """One (owner rank, version) shard set: {relative path: _MemEntry}."""

    __slots__ = ("files", "nbytes")

    def __init__(self, files: Dict[str, _MemEntry]):
        self.files = files
        self.nbytes = sum(e.nbytes for e in files.values())


class MemFabric:
    """Process-wide RAM fabric: per-checkpoint-name rank slots.

    ``slots[name][holder_rank][(owner_rank, version)] -> _MemVersion``; the
    entry for ``holder == owner`` is the rank's own copy, other holders hold
    replicas.  ``worlds[name][version]`` records the communicator size at
    publish time so completeness (every owner reachable) can be checked after
    the world shrank or ranks were renumbered.
    """

    _instance: Optional["MemFabric"] = None
    _instance_lock = threading.Lock()

    @classmethod
    def instance(cls) -> "MemFabric":
        with cls._instance_lock:
            if cls._instance is None:
                cls._instance = MemFabric()
            return cls._instance

    def __init__(self):
        self._lock = threading.Lock()
        self.slots: Dict[str, Dict[int, Dict[Tuple[int, int], _MemVersion]]] = {}
        self.worlds: Dict[str, Dict[int, int]] = {}

    # -- write side ---------------------------------------------------------
    def insert(self, name: str, holders: List[int], owner: int, version: int,
               mv: _MemVersion, world: int) -> None:
        with self._lock:
            byname = self.slots.setdefault(name, {})
            for holder in holders:
                byname.setdefault(holder, {})[(owner, version)] = mv
            self.worlds.setdefault(name, {})[version] = world

    def prune(self, name: str, rank: int, keep_versions: List[int]) -> None:
        """Drop entries in ``rank``'s slot for versions not in the keep set."""
        keep = set(keep_versions)
        with self._lock:
            slot = self.slots.get(name, {}).get(rank, {})
            for key in [k for k in slot if k[1] not in keep]:
                del slot[key]
            worlds = self.worlds.get(name, {})
            for v in [v for v in worlds if v not in keep]:
                del worlds[v]

    # -- read side ----------------------------------------------------------
    def versions(self, name: str) -> Dict[int, int]:
        with self._lock:
            return dict(self.worlds.get(name, {}))

    def lookup(self, name: str, owner: int, version: int
               ) -> Tuple[Optional[_MemVersion], bool]:
        """(shard set, from_own_slot) for ``owner``'s shards of ``version``.

        Prefers the owner's own slot; falls back to any replica holder's slot
        (the owner died — its RAM is gone, the replica survives).
        """
        with self._lock:
            byname = self.slots.get(name, {})
            own = byname.get(owner, {}).get((owner, version))
            if own is not None:
                return own, True
            for holder, slot in byname.items():
                if holder == owner:
                    continue
                mv = slot.get((owner, version))
                if mv is not None:
                    return mv, False
        return None, False

    def complete(self, name: str, version: int) -> bool:
        """True when every publishing owner's shard set is still reachable."""
        world = self.versions(name).get(version)
        if world is None:
            return False
        return all(
            self.lookup(name, owner, version)[0] is not None
            for owner in range(world)
        )

    def held_bytes(self, name: str, rank: int,
                   versions: Optional[List[int]] = None) -> int:
        """Bytes resident in ``rank``'s slot (optionally only ``versions``)."""
        with self._lock:
            slot = self.slots.get(name, {}).get(rank, {})
            return sum(
                mv.nbytes for key, mv in slot.items()
                if versions is None or key[1] in versions
            )

    # -- scrub support (core/scrubber.py) -----------------------------------
    def entries(self, name: str) -> List[Tuple[int, int, str, "_MemEntry"]]:
        """Snapshot of every distinct resident entry: [(owner, version, rel,
        entry)].  Replicas alias the owner's ``_MemVersion`` object in this
        threads-as-ranks fabric, so each (owner, version, rel) appears once.
        """
        seen = {}
        with self._lock:
            for slot in self.slots.get(name, {}).values():
                for (owner, version), mv in slot.items():
                    for rel, entry in mv.files.items():
                        seen.setdefault((owner, version, rel), entry)
        return [(o, v, r, e) for (o, v, r), e in sorted(seen.items(),
                                                        key=lambda kv: kv[0])]

    def replace_entry(self, name: str, owner: int, version: int, rel: str,
                      entry: "_MemEntry") -> None:
        """Swap in a repaired entry for every holder of (owner, version)."""
        with self._lock:
            for slot in self.slots.get(name, {}).values():
                mv = slot.get((owner, version))
                if mv is not None and rel in mv.files:
                    mv.files[rel] = entry
                    mv.nbytes = sum(e.nbytes for e in mv.files.values())

    def drop_version(self, name: str, version: int) -> None:
        """Retract an unrepairable version so it is never served again."""
        with self._lock:
            for slot in self.slots.get(name, {}).values():
                for key in [k for k in slot if k[1] == version]:
                    del slot[key]
            self.worlds.get(name, {}).pop(version, None)

    def corrupt_entry(self, name: str, owner: int, version: int,
                      rel: Optional[str] = None) -> str:
        """Test hook: silently rot one stored payload (its recorded digest is
        kept, so the rot is detectable).  Returns the corrupted rel path."""
        mv, _ = self.lookup(name, owner, version)
        if mv is None:
            raise KeyError(f"no resident shards for owner {owner} v-{version}")
        rel = rel if rel is not None else sorted(mv.files)[0]
        entry = mv.files[rel]
        if entry.array is not None:
            rotted = entry.array.copy()
            rotted.view(np.uint8).reshape(-1)[0] ^= 0x40
            bad = _MemEntry(rotted, None, entry.digest)
        else:
            blob = bytearray(entry.blob)
            blob[0] ^= 0x40
            bad = _MemEntry(None, bytes(blob), entry.digest)
        self.replace_entry(name, owner, version, rel, bad)
        return rel

    # -- elastic rehydration (CRAFT_ELASTIC_HYDRATE / NON-SHRINKING) --------
    def reseed(self, name: str, holders: List[int], owner: int,
               version: int) -> int:
        """Re-place ``owner``'s shard set of ``version`` into every listed
        holder slot that lost it (a replacement rank re-entering the fabric
        after hydrating from peer replicas).  Returns slots seeded; 0 when
        no surviving copy exists anywhere.
        """
        with self._lock:
            byname = self.slots.get(name, {})
            mv = byname.get(owner, {}).get((owner, version))
            if mv is None:
                for holder, slot in byname.items():
                    mv = slot.get((owner, version))
                    if mv is not None:
                        break
            if mv is None:
                return 0
            placed = 0
            for holder in holders:
                slot = byname.setdefault(holder, {})
                if (owner, version) not in slot:
                    slot[(owner, version)] = mv
                    placed += 1
            return placed

    def reprotect(self, size: int, replicas: int) -> int:
        """Restore full replica placement after a topology change.

        For every resident (name, version, owner) with a surviving copy,
        re-seed the round-robin holder set ``owner, owner+1 .. owner+R`` mod
        ``size`` — the NON-SHRINKING recovery path calls this so replacement
        ranks hold the replicas their predecessors did and the fabric again
        tolerates ``R`` failures.  Returns total slots seeded.
        """
        replicas = min(max(0, replicas), max(0, size - 1))
        total = 0
        with self._lock:
            names = list(self.slots)
        for name in names:
            for version, world in self.versions(name).items():
                for owner in range(min(world, size)):
                    holders = [owner] + [
                        (owner + i) % size for i in range(1, replicas + 1)
                    ]
                    total += self.reseed(name, holders, owner, version)
        return total

    # -- fault injection / lifecycle ----------------------------------------
    def drop_rank(self, rank: int) -> None:
        """Model the fail-stop RAM loss of ``rank`` across every checkpoint."""
        with self._lock:
            for byname in self.slots.values():
                byname.pop(rank, None)

    def drop_ranks(self, ranks) -> None:
        for r in ranks or ():
            self.drop_rank(r)

    def wipe(self, name: str) -> None:
        with self._lock:
            self.slots.pop(name, None)
            self.worlds.pop(name, None)

    def reset(self) -> None:
        """Drop everything (test isolation)."""
        with self._lock:
            self.slots.clear()
            self.worlds.clear()


def notify_rank_failures(ranks) -> None:
    """AFT recovery callback: the RAM of ``ranks`` is gone (paper §3.2).

    Idempotent with the fault-domain kill hooks — in the simulator the slots
    are already dropped at ``kill()``; on backends without in-process fault
    injection this is the only signal.
    """
    MemFabric.instance().drop_ranks(ranks)


class MemStore(StorageTier):
    """RAM tier for one checkpoint name (the fastest level of the chain)."""

    label = "mem"

    # RAM writes are near-free relative to any disk tier; seeding a small
    # prior lets the scheduler give the mem tier a tight Daly interval from
    # the very first step instead of waiting for a measurement.
    cost_prior_seconds = 0.01

    def __init__(self, name: str, comm, env, fabric: Optional[MemFabric] = None):
        self.name = name
        self.comm = comm
        self.env = env
        self.fabric = fabric if fabric is not None else MemFabric.instance()
        self.rank = comm.rank
        self.size = comm.size
        self.replicas = min(max(0, env.mem_replicas), self.size - 1)
        self.budget = env.mem_budget_bytes
        self.keep_versions = max(1, env.keep_versions)
        root = env.mem_scratch if env.mem_scratch is not None \
            else default_scratch_root()
        self._scratch = Path(root) / self.name / f"r{self.rank}"
        self._caches: Dict[int, Dict[str, np.ndarray]] = {}
        tiers.sweep_tmp_dirs(self._scratch)
        domain = getattr(comm, "fault_domain", lambda: None)()
        if domain is not None:
            domain.add_kill_hook(self.fabric.drop_rank)

    # -- placement ----------------------------------------------------------
    def _holders(self, owner: int) -> List[int]:
        """Round-robin replica placement: owner itself + the next R ranks."""
        return [owner] + [
            (owner + i) % self.size for i in range(1, self.replicas + 1)
        ]

    # -- staging API (Checkpoint._write_to_store) ---------------------------
    def stage(self, version: int) -> Path:
        # rank-distinct staging: each rank's shard set is its own payload
        # (the disk tiers share one staging dir; RAM slots are per rank)
        tmp = self._scratch / tiers.staging_dir_name(version)
        tmp.mkdir(parents=True, exist_ok=True)
        return tmp

    def abort(self, staged: Path) -> None:
        shutil.rmtree(staged, ignore_errors=True)

    def write_ctx_overrides(self) -> dict:
        # single-chunk, uncompressed encode: the staged file is decoded back
        # at publish, so chunking/compression only add work.  Delta encoding
        # is forced off — the fabric stores fully-decoded arrays, so a delta
        # staged file would only add a resolve pass at publish.
        return {"chunk_bytes": _ONE_CHUNK, "compress": "none",
                "codec_version": min(self.env.codec_version, 1),
                "delta_prev": None, "chunks_db": None}

    def publish(self, staged: Path, version: int,
                extra_meta: Optional[dict] = None) -> None:
        t0 = _time.perf_counter()
        # fabric coverage for the chaos engine: an injected fault here makes
        # the RAM tier misbehave exactly like a failing fabric insert would
        self._chaos_check("fabric", path=staged)
        files, decode_err = self._slurp(staged)
        nbytes = sum(e.nbytes for e in files.values())
        # replica-placement exchange: every rank learns every owner's payload
        # size (allgather); holders can then project their slot load exactly
        entries = self.comm.allreduce((self.rank, int(nbytes)), op="list")
        if not isinstance(entries, list):      # single-rank / stub comms
            entries = [entries]
        sizes = {int(r): int(n) for r, n in entries}
        fits = decode_err is None and self._fits(version, sizes)
        ok = self.comm.allreduce(1 if fits else 0, op="min")
        self.comm.barrier()                    # all ranks decided together
        if not ok:
            self.abort(staged)
            raise MemTierError(
                f"memory tier skipped {self.name} v-{version}: "
                + (str(decode_err) if decode_err is not None else
                   f"budget exceeded ({self.budget} bytes/rank)")
            )
        self.fabric.insert(
            self.name, self._holders(self.rank), self.rank, version,
            _MemVersion(files), world=self.size,
        )
        self.comm.barrier()                    # every owner's shards placed
        kept = sorted(self.fabric.versions(self.name))[-self.keep_versions:]
        self.fabric.prune(self.name, self.rank, kept)
        shutil.rmtree(staged, ignore_errors=True)
        metrics.observe("publish_seconds", _time.perf_counter() - t0,
                        tier="mem")

    def _slurp(self, staged: Path
               ) -> Tuple[Dict[str, _MemEntry], Optional[Exception]]:
        """Decode every staged file into a fabric entry, digesting payloads.

        Decode failures don't raise here — the error is carried into the
        collective publish decision so every rank aborts together instead of
        deadlocking peers waiting in the exchange.
        """
        ctx = IOContext(
            compress="none", checksum=self.env.checksum,
            codec_version=self.env.codec_version, chunk_bytes=_ONE_CHUNK,
        )
        files: Dict[str, _MemEntry] = {}
        try:
            for p in sorted(q for q in staged.rglob("*") if q.is_file()):
                rel = str(p.relative_to(staged))
                with open(p, "rb") as fh:
                    is_array = fh.read(4) == storage._MAGIC
                if is_array:
                    arr = storage.read_array(p, ctx)  # verifies staged digest
                    files[rel] = _MemEntry(
                        arr, None, checksum_ops.digest_bytes(arr))
                else:
                    blob = p.read_bytes()
                    files[rel] = _MemEntry(
                        None, blob, checksum_ops.digest_bytes(blob))
        except (OSError, CheckpointError) as exc:
            return {}, exc
        return files, None

    def _fits(self, version: int, sizes: Dict[int, int]) -> bool:
        if self.budget <= 0:
            return True
        # incoming this version: every owner whose holder set includes me
        incoming = sum(
            sizes.get(owner, sizes.get(self.rank, 0))
            for owner in range(self.size)
            if self.rank in self._holders(owner)
        )
        kept = sorted(
            v for v in self.fabric.versions(self.name) if v != version
        )[-(self.keep_versions - 1):] if self.keep_versions > 1 else []
        retained = self.fabric.held_bytes(self.name, self.rank, kept)
        return incoming + retained <= self.budget

    # -- reading ------------------------------------------------------------
    def meta(self) -> dict:
        return {}   # per-file digests live in the fabric, not a manifest

    def latest_version(self) -> int:
        best = 0
        for v in self.fabric.versions(self.name):
            if v > best and self.fabric.complete(self.name, v):
                best = v
        return best

    def version_dir(self, version: int) -> Path:
        return self._scratch / tiers.version_dir_name(version)

    def materialize(self, version: int) -> Optional[Path]:
        """Assemble a complete restore view of ``version`` from the fabric.

        Small non-array files (manifests, pods) are written under the
        RAM-backed scratch so the checkpointables' globbing works unchanged;
        decoded arrays stay in RAM and are served through the
        ``IOContext.array_cache`` installed by :meth:`read_ctx_overrides`.
        Replica payloads standing in for a dead owner are digest-verified;
        a rank's own live copies are trusted process RAM.
        """
        world = self.fabric.versions(self.name).get(version)
        if world is None:
            return None
        union: Dict[str, Tuple[_MemEntry, bool]] = {}
        for owner in range(world):
            mv, own_slot = self.fabric.lookup(self.name, owner, version)
            if mv is None:
                return None     # owner and all its replica holders are gone
            for rel, entry in mv.files.items():
                # SPMD-identical paths (e.g. a rank-replicated array.bin)
                # collide across owners; this rank's copy wins, then owners
                # in ascending rank order — matching shared-dir semantics
                if rel not in union or owner == self.rank:
                    union[rel] = (entry, own_slot)
        vdir = self.version_dir(version)
        shutil.rmtree(vdir, ignore_errors=True)
        vdir.mkdir(parents=True, exist_ok=True)
        cache: Dict[str, np.ndarray] = {}
        for rel, (entry, own_slot) in union.items():
            if not own_slot and not entry.verify():
                shutil.rmtree(vdir, ignore_errors=True)
                raise CheckpointError(
                    f"memory tier: replica digest mismatch for {rel!r} of "
                    f"{self.name} v-{version} (stale or corrupt replica)"
                )
            if entry.array is not None:
                cache[str(vdir / rel)] = entry.array
            else:
                out = vdir / rel
                out.parent.mkdir(parents=True, exist_ok=True)
                out.write_bytes(entry.blob)
        self._caches = {version: cache}
        return vdir

    def chunk_digests(self, version: int, chunk_bytes: int) -> Optional[dict]:
        """Per-file raw chunk digests of ``version``, straight from RAM.

        Serves the delta codec's diff pass after a memory-tier restore: the
        fabric already holds every array *decoded*, so re-chunking the byte
        view at ``chunk_bytes`` granularity and digesting each slice yields
        exactly the ``rdigests`` a disk tier's v1/v2 file records — without a
        single disk read.  Returns ``{rel: {"rdigests", "ulens", "nbytes",
        "chunk_bytes"}}`` for every array entry reachable for ``version``,
        or None when the version is not completely resident.
        """
        chunk_bytes = max(1, int(chunk_bytes))
        world = self.fabric.versions(self.name).get(version)
        if world is None:
            return None
        out: Dict[str, dict] = {}
        for owner in range(world):
            mv, _ = self.fabric.lookup(self.name, owner, version)
            if mv is None:
                return None         # incomplete — caller falls back to disk
            for rel, entry in mv.files.items():
                if entry.array is None or rel in out:
                    continue
                flat = np.ascontiguousarray(entry.array)
                flat = (flat.reshape(-1).view(np.uint8).reshape(-1)
                        if flat.nbytes else np.empty(0, dtype=np.uint8))
                rdigests = checksum_ops.digest_chunks(flat, chunk_bytes)
                ulens = [
                    min(chunk_bytes, flat.size - off)
                    for off in range(0, flat.size, chunk_bytes)
                ]
                out[rel] = {"rdigests": rdigests, "ulens": ulens,
                            "nbytes": int(flat.size),
                            "chunk_bytes": chunk_bytes}
        return out

    def read_ctx_overrides(self, version: int) -> dict:
        # checksum "none": payloads were digest-verified at publish (and
        # replicas re-verified in materialize); re-hashing RAM on the fast
        # path would cost exactly the codec pass this tier exists to skip
        return {"array_cache": self._caches.get(version, {}),
                "checksum": "none"}

    def rehydrate(self, version: int) -> int:
        """Re-seed this rank's own fabric slots for ``version`` from peer
        replicas (replacement-rank hydration: after restoring through the
        fabric, the rank re-enters the redundancy group so the next failure
        is again survivable — all RAM-to-RAM, no disk).  Returns the number
        of slots seeded (0 = already whole)."""
        return self.fabric.reseed(
            self.name, self._holders(self.rank), self.rank, version)

    def retained_versions(self) -> List[int]:
        """Completely resident fabric versions (the scrubber's walk list)."""
        return sorted(
            v for v in self.fabric.versions(self.name)
            if self.fabric.complete(self.name, v)
        )

    def forget_version(self, version: int) -> None:
        """Retract an unrepairable version from the fabric (scrub quarantine
        — restore then falls through to the disk tiers)."""
        self.fabric.drop_version(self.name, version)
        self._caches.pop(version, None)

    def invalidate_all(self) -> None:
        self.fabric.wipe(self.name)
        self._caches = {}
        shutil.rmtree(self._scratch, ignore_errors=True)
