"""Paper Fig. 7: spawn+merge cost vs communicator size — plus the
elastic-hydration profile of the spawned replacements.

The paper benchmarks MPI_Comm_spawn + MPI_Intercomm_merge of 20 processes
against communicators of growing size and finds ULFM-1.1 scales poorly.
Our analogs:

fig7      — kill k members of an n-member epoch and measure the spawn+merge
            phase of the non-shrinking recovery (replacement threads
            registering into the next epoch + the join barrier), for k=1
            and a multi-failure k, across growing n.
hydration — the same spawn+merge with real checkpoint state on the memory
            tier: after recovery the replacements restore their shard from
            surviving peers' RAM-fabric replicas (zero PFS reads) and the
            fabric reseeds the failed ranks' replica slots.  Reports the
            replacement ``restart_if_needed()`` latency, the restore tier,
            the physical bytes read, and the reseeded-slot count vs n
            (docs/architecture.md §elastic restore).

Scenario CLI (mirrors ``recovery_scaling.py``)::

    PYTHONPATH=src:. python benchmarks/spawn_merge.py \
        [fig7 hydration ...] [--full] [--json OUT.json]
"""
from __future__ import annotations

import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit
from benchmarks.recovery_scaling import _recover_once


def _recover_k(n_procs: int, k: int, ppn: int = 2) -> dict:
    """One NON-SHRINKING NO-REUSE recovery after killing ``k`` members;
    returns the slowest member's recovery stats (incl. phase times)."""
    from repro.core.comm import ProcFailedError, RevokedError
    from repro.core.comm_sim import SimWorld
    from repro.core.env import CraftEnv

    env = CraftEnv.capture({
        "CRAFT_COMM_RECOVERY_POLICY": "NON-SHRINKING",
        "CRAFT_COMM_SPAWN_POLICY": "NO-REUSE",
    })
    world = SimWorld(n_procs, procs_per_node=ppn, spare_nodes=max(2, k),
                     env=env)
    victims = list(range(n_procs - k, n_procs))

    def fn(comm):
        recovered = {}
        while True:
            try:
                if comm.rank == 0 and comm.epoch == 0:
                    for v in victims:
                        world.kill(v)
                for _ in range(3):
                    comm.barrier()
                return recovered
            except (ProcFailedError, RevokedError):
                try:
                    comm.revoke()
                except Exception:
                    pass
                t0 = time.perf_counter()
                comm = comm.recover(policy="NON-SHRINKING")
                recovered = dict(comm.last_recovery_stats())
                recovered["wall_s"] = time.perf_counter() - t0

    out = world.run(fn, timeout=600)
    stats = [v for v in out.values() if v]
    stats.sort(key=lambda s: -s.get("wall_s", 0.0))
    return stats[0] if stats else {}


def fig7(sizes, multi_k: int = 4) -> None:
    for n in sizes:
        s = _recover_once(n, 2, "NON-SHRINKING", "NO-REUSE")
        emit("fig7_spawn_merge", "spawn_merge",
             round(s.get("spawn_merge_s", float("nan")), 6), "s",
             procs=n, killed=1)
        k = min(multi_k, max(1, n // 4))
        s = _recover_k(n, k)
        emit("fig7_spawn_merge", f"spawn_merge_k{k}",
             round(s.get("spawn_merge_s", float("nan")), 6), "s",
             procs=n, killed=k)


def _hydrate_once(n: int, k: int, leaf_kb: int) -> dict:
    """NON-SHRINKING recovery with live checkpoint state on the RAM tier:
    measures the replacements' peer-memory restore after spawn+merge."""
    from repro.core import Box, Checkpoint, ShardCp
    from repro.core.aft import aft_zone
    from repro.core.comm_sim import SimWorld
    from repro.core.elastic import block_index
    from repro.core.env import CraftEnv
    from repro.core.mem_level import MemFabric

    base = Path(tempfile.mkdtemp(prefix="craft-spawnmerge-"))
    env = CraftEnv.capture({
        "CRAFT_COMM_RECOVERY_POLICY": "NON-SHRINKING",
        "CRAFT_CP_PATH": str(base / "pfs"),
        "CRAFT_TIER_CHAIN": "mem,pfs",
        "CRAFT_MEM_REPLICAS": str(min(2, n - 1)),
        "CRAFT_MEM_SCRATCH": str(base / "shm"),
        "CRAFT_USE_SCR": "0",
        "CRAFT_IO_WORKERS": "1",
    })
    MemFabric.instance().reset()
    world = SimWorld(n, spare_nodes=max(2, k), env=env)
    src = np.arange(n * leaf_kb * 128, dtype=np.float64)  # leaf_kb KiB/rank
    victims = list(range(n - k, n))
    hydrated = {}   # replacement rank -> restore telemetry
    reseeded = []

    def body(comm):
        cp = Checkpoint("hyd", comm, env=env)
        it = Box(0)
        idx = block_index(src.shape, comm.rank, comm.size)
        w = Box(src[idx].copy())
        cp.add("it", it)
        cp.add("w", ShardCp(w, src.shape, idx))
        cp.commit()
        t0 = time.perf_counter()
        restored = cp.restart_if_needed()
        dt = time.perf_counter() - t0
        if restored and comm.is_replacement():
            hydrated[comm.rank] = {
                "hydrate_s": dt,
                "tier": cp.stats.get("restore_tier"),
                "read_bytes": cp.stats.get("restore_read_bytes", 0),
                "reseeded": cp.stats.get("mem_rehydrations", 0),
            }
        while it.value < 2:
            it.value += 1
            cp.update_and_write()
            if comm.rank == 0 and comm.epoch == 0 and it.value == 1:
                for v in victims:
                    world.kill(v)
            comm.barrier()
        cp.close()
        return True

    def fn(c):
        return aft_zone(
            c, body, env=env,
            on_recovery=lambda comm, stats: reseeded.append(
                stats.get("mem_reseeded", 0)))

    try:
        world.run(fn, timeout=600)
    finally:
        MemFabric.instance().reset()
        shutil.rmtree(base, ignore_errors=True)
    times = sorted(v["hydrate_s"] for v in hydrated.values())
    return {
        "replacements": len(hydrated),
        "hydrate_s": times[len(times) // 2] if times else float("nan"),
        "tiers": sorted({v["tier"] for v in hydrated.values()}),
        "read_bytes": sum(v["read_bytes"] for v in hydrated.values()),
        "mem_reseeded": sum(reseeded),
    }


def hydration(sizes, k: int = 2, leaf_kb: int = 64) -> None:
    for n in sizes:
        s = _hydrate_once(n, min(k, n - 1), leaf_kb)
        emit("fig7_hydration", "replacement_restore",
             round(s["hydrate_s"], 6), "s",
             procs=n, killed=min(k, n - 1), kb_per_rank=leaf_kb,
             tier="+".join(s["tiers"]) or "none")
        emit("fig7_hydration", "pfs_bytes_read", s["read_bytes"], "B",
             procs=n, killed=min(k, n - 1))
        emit("fig7_hydration", "mem_reseeded_slots", s["mem_reseeded"], "",
             procs=n, killed=min(k, n - 1))


def main(full: bool = False) -> None:
    sizes = [8, 16, 32, 64, 128] + ([256] if full else [])
    fig7(sizes)
    hydration([4, 8, 16] + ([32] if full else []))


_SCENARIOS = {
    "fig7": lambda full: fig7([8, 16, 32] + ([64, 128] if full else [])),
    "hydration": lambda full: hydration([4, 8] + ([16, 32] if full else [])),
    "all": main,
}


if __name__ == "__main__":
    from benchmarks.common import run_scenarios

    run_scenarios(_SCENARIOS, main)
