"""Elastic remesh: shrink-recovery resharding (beyond-paper, DESIGN.md §2).

The paper's shrinking recovery leaves domain redistribution to the user.
Here the checkpoint manifest is topology-independent (shard files + global
indices), so after a shrink the framework itself can rebuild a smaller mesh
and restore the same global state resharded — "the user redistributes the
domain" done automatically.

The data-parallel axis absorbs the shrink (every DP slice holds a full
model replica group, so dropping DP slices never strands a weight shard);
the model axis is preserved.  ``shrink_mesh`` computes the largest valid
mesh for the surviving host count; ``reshard`` moves a live pytree onto it.
A restore-from-checkpoint needs no special code at all: build the state on
the new mesh and ``Checkpoint.restart_if_needed()`` — the checkpointables
``device_put`` every leaf onto the live (new-mesh) sharding.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.sharding.logical import LogicalRules, shard_specs


def shrink_mesh(n_devices: int, model_parallel: int,
                axis_names: Tuple[str, ...] = ("data", "model")) -> Mesh:
    """Largest (data, model) mesh with the given TP degree that fits
    ``n_devices`` devices.  Raises if fewer than one model group survives."""
    if n_devices < model_parallel:
        raise ValueError(
            f"{n_devices} devices cannot hold one {model_parallel}-way "
            "model-parallel group — shrink recovery impossible; use "
            "non-shrinking recovery with spare nodes instead")
    data = n_devices // model_parallel
    devs = jax.devices()[: data * model_parallel]
    import numpy as np

    arr = np.array(devs).reshape(data, model_parallel)
    return Mesh(arr, axis_names)


def reshard(tree, logical_tree, new_mesh: Mesh,
            rules: Optional[LogicalRules] = None):
    """Move a live pytree onto ``new_mesh`` under the same logical rules."""
    rules = rules or LogicalRules(new_mesh)
    specs = shard_specs(rules, logical_tree, tree)
    return jax.tree_util.tree_map(
        lambda x, sp: jax.device_put(x, NamedSharding(new_mesh, sp)),
        tree, specs,
        is_leaf=lambda x: isinstance(x, jax.Array)), specs


def dp_degree(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("data", 1) * sizes.get("pod", 1)


# --------------------------------------------------------------------------
# host-side domain decomposition (ShardCp) + replacement-rank hydration
# --------------------------------------------------------------------------
def block_index(global_shape, rank: int, size: int, axis: int = 0):
    """Balanced contiguous block decomposition of a global array over
    ``size`` ranks along ``axis`` — the extent ``rank`` owns, as a tuple of
    slices (``()`` for 0-d arrays, which every rank replicates whole).

    The first ``shape[axis] % size`` ranks get one extra row, so any N→M
    pair of decompositions tiles the array without gaps — the geometry
    :func:`repro.core.reshard.overlap_runs` maps across topologies.
    """
    global_shape = tuple(int(s) for s in global_shape)
    if not global_shape:
        return ()
    if not 0 <= axis < len(global_shape):
        raise ValueError(f"axis {axis} out of range for {global_shape}")
    if not 0 <= rank < size:
        raise ValueError(f"rank {rank} out of range for size {size}")
    base, rem = divmod(global_shape[axis], size)
    lo = rank * base + min(rank, rem)
    hi = lo + base + (1 if rank < rem else 0)
    return tuple(
        slice(lo, hi) if d == axis else slice(0, s)
        for d, s in enumerate(global_shape)
    )


def hydrate_replacement(cp) -> dict:
    """Restore a spawned replacement rank's slice from the tier chain.

    Called in the zone body a replacement re-enters after NON-SHRINKING
    recovery: the checkpoint restores through the normal chain — with the
    memory tier chained first, the slice comes out of surviving peers'
    RAM-fabric replicas (or an RS group rebuild on the node tier) without
    touching the PFS — and the rank's own fabric slots are re-seeded
    (``CRAFT_ELASTIC_HYDRATE``).  Returns what happened, for recovery
    telemetry::

        {"restored": bool, "tier": label|None, "reseeded": int}
    """
    restored = cp.restart_if_needed()
    return {
        "restored": bool(restored),
        "tier": cp.stats.get("restore_tier"),
        "reseeded": int(cp.stats.get("mem_rehydrations", 0)),
    }
