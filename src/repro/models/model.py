"""Full language-model assembly: embed → blocks → norm → logits.

Families
  * dense / audio / vlm — transformer blocks (GQA or MLA attention),
  * moe   — ``first_dense_layers`` dense blocks, then MoE blocks,
  * ssm   — mamba1/mamba2 blocks (attention-free),
  * hybrid — zamba2: groups of ``shared_attn_every`` mamba2 blocks with ONE
    weight-shared transformer block applied between groups.

Layer stacking: homogeneous runs of blocks hold their parameters stacked on
a leading ``layers`` axis; ``cfg.scan_layers`` selects ``lax.scan`` (compact
HLO, fast compile) vs an unrolled python loop (exact per-layer cost
analysis — the dry-run uses this so `cost_analysis()` counts every layer).

Caches for serving: a pytree with the same layer-stacked structure; decode
steps thread it through the same scan.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks as blk
from repro.models.common import ModelConfig
from repro.models.layers import (
    dense_init, embed_apply, embed_init, embed_logical, rms_norm,
    unembed_apply,
)
from repro.sharding.activations import constrain


def _stack_init(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def _prepend(axis: str, tree):
    return jax.tree_util.tree_map(
        lambda dims: (axis, *dims),
        tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(d, (str, type(None))) for d in x),
    )


def _layer_slice(tree, i: int):
    return jax.tree_util.tree_map(lambda x: x[i], tree)


# ==========================================================================
# parameters
# ==========================================================================
def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    params = {"embed": embed_init(ks[0], cfg),
              "final_ln": jnp.ones((cfg.d_model,), cfg.dtype)}
    if cfg.family in ("dense", "audio", "vlm"):
        params["blocks"] = _stack_init(
            lambda k: blk.tblock_init(k, cfg), ks[1], cfg.n_layers)
    elif cfg.family == "moe":
        if cfg.first_dense_layers:
            params["dense_blocks"] = _stack_init(
                lambda k: blk.tblock_init(
                    k, cfg, d_ff=cfg.dense_d_ff or cfg.d_ff),
                ks[1], cfg.first_dense_layers)
        params["blocks"] = _stack_init(
            lambda k: blk.tblock_init(k, cfg, use_moe=True),
            ks[2], cfg.n_layers - cfg.first_dense_layers)
    elif cfg.family == "ssm":
        params["blocks"] = _stack_init(
            lambda k: blk.sblock_init(k, cfg), ks[1], cfg.n_layers)
    elif cfg.family == "hybrid":
        params["blocks"] = _stack_init(
            lambda k: blk.sblock_init(k, cfg), ks[1], cfg.n_layers)
        params["shared_block"] = blk.tblock_init(ks[2], cfg)
    else:
        raise ValueError(f"unknown family {cfg.family}")
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            ks[3], (cfg.d_model, cfg.vocab), cfg.d_model, cfg.dtype)
    if cfg.mtp:
        params["mtp"] = {
            "proj": dense_init(ks[4], (2 * cfg.d_model, cfg.d_model),
                               2 * cfg.d_model, cfg.dtype),
            "ln": jnp.ones((cfg.d_model,), cfg.dtype),
            "block": blk.tblock_init(ks[5], cfg, use_moe=cfg.family == "moe"),
        }
    return params


def param_logical(cfg: ModelConfig):
    out = {"embed": embed_logical(cfg), "final_ln": ("embed_act",)}
    if cfg.family in ("dense", "audio", "vlm"):
        out["blocks"] = _prepend("layers", blk.tblock_logical(cfg))
    elif cfg.family == "moe":
        if cfg.first_dense_layers:
            out["dense_blocks"] = _prepend("layers", blk.tblock_logical(cfg))
        out["blocks"] = _prepend("layers", blk.tblock_logical(cfg, use_moe=True))
    elif cfg.family == "ssm":
        out["blocks"] = _prepend("layers", blk.sblock_logical(cfg))
    elif cfg.family == "hybrid":
        out["blocks"] = _prepend("layers", blk.sblock_logical(cfg))
        out["shared_block"] = blk.tblock_logical(cfg)
    if not cfg.tie_embeddings:
        out["lm_head"] = ("embed", "vocab")
    if cfg.mtp:
        out["mtp"] = {
            "proj": ("embed", "embed"),
            "ln": ("embed_act",),
            "block": blk.tblock_logical(cfg, use_moe=cfg.family == "moe"),
        }
    return out


# ==========================================================================
# caches
# ==========================================================================
def _stack_cache(proto, n: int):
    return jax.tree_util.tree_map(
        lambda a: jnp.zeros((n, *a.shape), a.dtype), proto)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None):
    dtype = dtype or cfg.dtype
    if cfg.family in ("dense", "audio", "vlm"):
        proto = blk.tblock_cache_init(cfg, batch, max_len, dtype)
        return {"layers": _stack_cache(proto, cfg.n_layers)}
    if cfg.family == "moe":
        proto = blk.tblock_cache_init(cfg, batch, max_len, dtype)
        out = {"layers": _stack_cache(proto,
                                      cfg.n_layers - cfg.first_dense_layers)}
        if cfg.first_dense_layers:
            out["dense_layers"] = _stack_cache(proto, cfg.first_dense_layers)
        return out
    if cfg.family == "ssm":
        proto = blk.sblock_cache_init(cfg, batch, dtype)
        return {"layers": _stack_cache(proto, cfg.n_layers)}
    if cfg.family == "hybrid":
        sproto = blk.sblock_cache_init(cfg, batch, dtype)
        tproto = blk.tblock_cache_init(cfg, batch, max_len, dtype)
        n_shared = (cfg.n_layers // cfg.shared_attn_every
                    if cfg.shared_attn_every else 0)
        return {"layers": _stack_cache(sproto, cfg.n_layers),
                "shared": _stack_cache(tproto, max(1, n_shared))}
    raise ValueError(cfg.family)


def cache_logical(cfg: ModelConfig):
    if cfg.family in ("dense", "audio", "vlm", "moe"):
        proto = _prepend("layers", blk.tblock_cache_logical(cfg))
        out = {"layers": proto}
        if cfg.family == "moe" and cfg.first_dense_layers:
            out["dense_layers"] = proto
        return out
    if cfg.family == "ssm":
        return {"layers": _prepend("layers", blk.sblock_cache_logical(cfg))}
    if cfg.family == "hybrid":
        return {"layers": _prepend("layers", blk.sblock_cache_logical(cfg)),
                "shared": _prepend("layers", blk.tblock_cache_logical(cfg))}
    raise ValueError(cfg.family)


# ==========================================================================
# forward
# ==========================================================================
def _run_stack(block_apply, stacked_params, x, cfg, caches=None):
    """Run a homogeneous stack of blocks (scan or unrolled)."""
    n = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]
    x = constrain(x, "batch", "seq", "embed_act")
    if cfg.scan_layers:
        if caches is None:
            fn = block_apply
            if cfg.remat:
                fn = jax.checkpoint(block_apply)

            def body(carry, p):
                y, nc, aux = fn(p, carry[0], None)
                y = constrain(y, "batch", "seq", "embed_act")
                return (y, carry[1] + aux), None

            (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                       stacked_params)
            return x, None, aux

        def body(carry, pc):
            p, c = pc
            y, nc, aux = block_apply(p, carry[0], c)
            y = constrain(y, "batch", "seq", "embed_act")
            return (y, carry[1] + aux), nc

        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (stacked_params, caches))
        return x, new_caches, aux
    # unrolled (dry-run / cost-analysis mode)
    fn = block_apply
    if caches is None and cfg.remat:
        fn = jax.checkpoint(block_apply)
    aux = jnp.zeros((), jnp.float32)
    new_layers = []
    for i in range(n):
        p = _layer_slice(stacked_params, i)
        c = _layer_slice(caches, i) if caches is not None else None
        x, nc, a = fn(p, x, c)
        x = constrain(x, "batch", "seq", "embed_act")
        aux = aux + a
        if caches is not None:
            new_layers.append(nc)
    new_caches = None
    if caches is not None:
        new_caches = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *new_layers)
    return x, new_caches, aux


def unembed(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Hidden (B, L, D) → logits (B, L, V); handles tied/untied heads."""
    if cfg.tie_embeddings:
        return unembed_apply(params["embed"], x, fp32=cfg.logits_fp32)
    logits = jnp.einsum("bld,dv->blv", x, params["lm_head"])
    return logits.astype(jnp.float32) if cfg.logits_fp32 else logits


def forward_hidden(
    params,
    cfg: ModelConfig,
    tokens: Optional[jnp.ndarray] = None,     # (B, L) int32
    embeds: Optional[jnp.ndarray] = None,     # (B, P, D) modality stub
    cache=None,
    pos0=None,                                # scalar position offset
) -> Tuple[jnp.ndarray, Optional[dict], jnp.ndarray]:
    """Returns (final hidden (B, L, D), new_cache, aux_loss).

    The unembed projection is NOT applied — training computes the loss in
    sequence chunks (``train.steps.chunked_cross_entropy``) so the
    (B, L, vocab) fp32 logits tensor is never materialized (at the assigned
    train_4k shapes that tensor would be up to ~0.8 TB), and serving
    unembeds only the positions it needs.
    """
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(cfg.dtype))
    if tokens is not None:
        parts.append(embed_apply(params["embed"], tokens))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    x = constrain(x, "batch", "seq", "embed_act")
    b, l, _ = x.shape
    if pos0 is None:
        pos0 = jnp.zeros((), jnp.int32)
    positions = pos0 + jnp.arange(l)

    def t_apply(p, h, c, use_moe=False):
        return blk.tblock_apply(p, h, cfg, positions, c, use_moe=use_moe)

    def s_apply(p, h, c):
        return blk.sblock_apply(p, h, cfg, c)

    aux = jnp.zeros((), jnp.float32)
    new_cache = None

    if cfg.family in ("dense", "audio", "vlm"):
        caches = cache["layers"] if cache is not None else None
        x, nc, aux = _run_stack(t_apply, params["blocks"], x, cfg, caches)
        if cache is not None:
            new_cache = {"layers": nc}
    elif cfg.family == "moe":
        new_cache = {} if cache is not None else None
        if cfg.first_dense_layers:
            caches = cache["dense_layers"] if cache is not None else None
            x, nc, a1 = _run_stack(
                functools.partial(t_apply, use_moe=False),
                params["dense_blocks"], x, cfg, caches)
            aux = aux + a1
            if cache is not None:
                new_cache["dense_layers"] = nc
        caches = cache["layers"] if cache is not None else None
        x, nc, a2 = _run_stack(
            functools.partial(t_apply, use_moe=True),
            params["blocks"], x, cfg, caches)
        aux = aux + a2
        if cache is not None:
            new_cache["layers"] = nc
    elif cfg.family == "ssm":
        caches = cache["layers"] if cache is not None else None
        x, nc, aux = _run_stack(s_apply, params["blocks"], x, cfg, caches)
        if cache is not None:
            new_cache = {"layers": nc}
    elif cfg.family == "hybrid":
        x, new_cache, aux = _hybrid_forward(params, x, cfg, positions, cache)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    return x, new_cache, aux


def forward(
    params,
    cfg: ModelConfig,
    tokens: Optional[jnp.ndarray] = None,
    embeds: Optional[jnp.ndarray] = None,
    cache=None,
    pos0=None,
) -> Tuple[jnp.ndarray, Optional[dict], jnp.ndarray]:
    """Returns (logits (B, L, V) fp32, new_cache, aux_loss) — materializes
    the full logits tensor; use only at decode/small shapes or in tests."""
    x, new_cache, aux = forward_hidden(
        params, cfg, tokens=tokens, embeds=embeds, cache=cache, pos0=pos0)
    return unembed(params, cfg, x), new_cache, aux


def _hybrid_forward(params, x, cfg, positions, cache):
    """zamba2: groups of ``shared_attn_every`` mamba blocks, then the
    weight-shared attention block (fresh KV cache per application).

    Training (no cache) honors ``cfg.remat`` per block — without it the
    unrolled hybrid stack saves every SSM intermediate (the dry-run measured
    a 3 TB/device peak at train_4k; per-block remat + sequence-parallel
    residuals brings that down ~400×, EXPERIMENTS.md §Perf iteration 1).
    """
    every = cfg.shared_attn_every or cfg.n_layers + 1
    n_shared = cfg.n_layers // every if cfg.shared_attn_every else 0
    aux = jnp.zeros((), jnp.float32)
    new_s_layers = []
    new_shared = []

    # training path: scan over (mamba-group + shared block) super-layers —
    # compact HLO (one group body instead of 54 inlined blocks) and one
    # remat boundary per group (EXPERIMENTS.md §Perf iterations 1.1/1.2)
    if (cache is None and cfg.scan_layers and cfg.shared_attn_every
            and cfg.n_layers % every == 0 and n_shared >= 1):
        return _hybrid_scan_forward(params, x, cfg, positions, every,
                                    n_shared)

    s_fn = lambda p, h, c: blk.sblock_apply(p, h, cfg, c)
    t_fn = lambda p, h, c: blk.tblock_apply(p, h, cfg, positions, c)
    if cache is None and cfg.remat:
        s_fn = jax.checkpoint(s_fn)
        t_fn = jax.checkpoint(t_fn)

    layer = 0
    for g in range(max(1, (cfg.n_layers + every - 1) // every)):
        hi = min(layer + every, cfg.n_layers)
        for i in range(layer, hi):
            p = _layer_slice(params["blocks"], i)
            c = (_layer_slice(cache["layers"], i)
                 if cache is not None else None)
            x, nc, a = s_fn(p, x, c)
            x = constrain(x, "batch", "seq", "embed_act")
            aux = aux + a
            if cache is not None:
                new_s_layers.append(nc)
        layer = hi
        if cfg.shared_attn_every and (g < n_shared):
            c = (_layer_slice(cache["shared"], g)
                 if cache is not None else None)
            x, nc, a = t_fn(params["shared_block"], x, c)
            x = constrain(x, "batch", "seq", "embed_act")
            aux = aux + a
            if cache is not None:
                new_shared.append(nc)
    new_cache = None
    if cache is not None:
        stack = lambda items: jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *items)
        new_cache = {"layers": stack(new_s_layers),
                     "shared": (stack(new_shared) if new_shared
                                else cache["shared"])}
    return x, new_cache, aux


def _hybrid_scan_forward(params, x, cfg, positions, every: int,
                         n_shared: int):
    """Scan over super-layers: ``every`` mamba blocks + one shared block.

    Mamba parameters reshape from (n_layers, ...) to (n_shared, every, ...)
    on the scan's leading axis; the weight-shared attention block rides in
    the closure (loop-invariant — XLA hoists it).
    """
    grouped = jax.tree_util.tree_map(
        lambda a: a.reshape(n_shared, every, *a.shape[1:]), params["blocks"])

    def group_body(h, gp):
        a = jnp.zeros((), jnp.float32)
        for i in range(every):
            p = jax.tree_util.tree_map(lambda t: t[i], gp)
            h, _, ai = blk.sblock_apply(p, h, cfg, None)
            h = constrain(h, "batch", "seq", "embed_act")
            a = a + ai
        h, _, ai = blk.tblock_apply(params["shared_block"], h, cfg,
                                    positions, None)
        h = constrain(h, "batch", "seq", "embed_act")
        return h, a + ai

    fn = jax.checkpoint(group_body) if cfg.remat else group_body

    def body(carry, gp):
        h, acc = carry
        h, a = fn(h, gp)
        return (h, acc + a), None

    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), grouped)
    # trailing mamba blocks beyond the last shared application (none for
    # zamba2's 54 = 9·6, kept for config generality)
    rem = cfg.n_layers - n_shared * every
    for i in range(cfg.n_layers - rem, cfg.n_layers):
        p = _layer_slice(params["blocks"], i)
        x, _, a = blk.sblock_apply(p, x, cfg, None)
        x = constrain(x, "batch", "seq", "embed_act")
        aux = aux + a
    return x, None, aux


# ==========================================================================
# MTP head (deepseek multi-token prediction)
# ==========================================================================
def mtp_hidden(params, cfg: ModelConfig, hidden: jnp.ndarray,
               next_tokens: jnp.ndarray, positions) -> jnp.ndarray:
    """Predict token t+2 from (hidden_t, embed(token_{t+1})) — one MTP depth.

    Returns the MTP head's hidden states (B, L, D); the caller unembeds
    (chunked, like the main loss — the MTP logits tensor is just as big).
    """
    mtp = params["mtp"]
    nxt = embed_apply(params["embed"], next_tokens)
    h = jnp.concatenate(
        [rms_norm(hidden, mtp["ln"], cfg.norm_eps), nxt], axis=-1)
    h = jnp.einsum("ble,ed->bld", h, mtp["proj"])
    h, _, _ = blk.tblock_apply(mtp["block"], h, cfg, positions,
                               use_moe=cfg.family == "moe")
    return h


def mtp_logits(params, cfg: ModelConfig, hidden: jnp.ndarray,
               next_tokens: jnp.ndarray, positions) -> jnp.ndarray:
    h = mtp_hidden(params, cfg, hidden, next_tokens, positions)
    return unembed(params, cfg, h)
