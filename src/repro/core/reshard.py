"""N→M reshard planning — mapping shard extents across topologies.

A checkpoint written on N ranks stores, per rank, a rectangular *extent* of
each global array (the shard ``index`` in ``array-<rank>.json``).  Restoring
onto M≠N ranks means every new rank must assemble *its* extent from pieces of
the old ranks' files.  This module is the pure geometry: extents are tuples of
``(lo, hi)`` per dimension, and the planner turns "destination extent ×
source extents" into byte-range reads against each source file's C-order
payload — which :class:`~repro.core.storage.ChunkRangeReader` then serves
chunk by chunk.

The invariant the hypothesis property test pins down: for any chunk grid and
any disjoint tiling of the global array by source extents, the read plan for
a destination extent covers every destination byte **exactly once**, and the
assembled bytes equal the source array's slice.
"""
from __future__ import annotations

import itertools
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cpbase import CheckpointError

Extent = Tuple[Tuple[int, int], ...]     # ((lo, hi), ...) per dimension


def resolve_index(index, shape: Sequence[int]) -> Extent:
    """Normalize a stored shard ``index`` (``[[start, stop|None], ...]`` or a
    tuple of slices) into a clamped ``((lo, hi), ...)`` extent over ``shape``.

    Short indices are padded with full dimensions (numpy basic-indexing
    semantics, which is also how the writers produced them); a 0-d shape
    yields the empty extent ``()``.
    """
    shape = tuple(int(s) for s in shape)
    ext: List[Tuple[int, int]] = []
    idx = tuple(index) if index is not None else ()
    for d, size in enumerate(shape):
        if d < len(idx):
            ent = idx[d]
            if isinstance(ent, slice):
                start, stop = ent.start, ent.stop
            else:
                start, stop = ent[0], ent[1]
            lo = 0 if start is None else int(start)
            hi = size if stop is None else int(stop)
        else:
            lo, hi = 0, size
        lo = max(0, min(lo, size))
        hi = max(lo, min(hi, size))
        ext.append((lo, hi))
    return tuple(ext)


def extent_size(ext: Extent) -> int:
    """Number of elements in an extent (1 for the 0-d extent ``()``)."""
    n = 1
    for lo, hi in ext:
        n *= hi - lo
    return n


def _strides(ext: Extent) -> List[int]:
    """C-order element strides of an extent's own (packed) buffer."""
    strides = [0] * len(ext)
    acc = 1
    for d in range(len(ext) - 1, -1, -1):
        strides[d] = acc
        acc *= ext[d][1] - ext[d][0]
    return strides


def overlap_runs(src: Extent, dst: Extent) -> List[Tuple[int, int, int]]:
    """Contiguous element runs shared by two extents of one global array.

    Returns ``[(src_off, dst_off, length), ...]`` where the offsets are
    element offsets into each extent's *own* packed C-order buffer.  Runs are
    maximal along the innermost dimensions: the largest suffix of dimensions
    where the intersection spans both extents entirely collapses into the run
    length, so a 1-D overlap is always a single run and higher-dimensional
    overlaps degrade gracefully to one run per outer-coordinate tuple.
    """
    nd = len(src)
    if nd != len(dst):
        raise CheckpointError(
            f"extent rank mismatch: {len(src)} vs {len(dst)}")
    if nd == 0:
        return [(0, 0, 1)]
    inter: List[Tuple[int, int]] = []
    for (slo, shi), (dlo, dhi) in zip(src, dst):
        lo, hi = max(slo, dlo), min(shi, dhi)
        if hi <= lo:
            return []
        inter.append((lo, hi))
    # k = first dim of the maximal fully-covered suffix
    k = nd
    while k > 0:
        d = k - 1
        if inter[d] == src[d] == dst[d]:
            k = d
        else:
            break
    sstr, dstr = _strides(src), _strides(dst)
    if k == 0:
        return [(0, 0, extent_size(src))]
    run_axis = k - 1
    inner = 1
    for d in range(k, nd):
        inner *= inter[d][1] - inter[d][0]
    run_len = (inter[run_axis][1] - inter[run_axis][0]) * inner
    runs: List[Tuple[int, int, int]] = []
    outer = [range(lo, hi) for lo, hi in inter[:run_axis]]
    for coord in itertools.product(*outer):
        soff = sum((c - src[d][0]) * sstr[d] for d, c in enumerate(coord))
        doff = sum((c - dst[d][0]) * dstr[d] for d, c in enumerate(coord))
        soff += (inter[run_axis][0] - src[run_axis][0]) * sstr[run_axis]
        doff += (inter[run_axis][0] - dst[run_axis][0]) * dstr[run_axis]
        runs.append((soff, doff, run_len))
    return runs


def plan_reads(sources: Sequence[Tuple[Extent, object]], dst: Extent,
               itemsize: int) -> List[Tuple[object, int, int, int]]:
    """Byte-level read plan: ``[(key, src_byte_off, dst_byte_off, nbytes)]``
    covering ``dst`` from the given ``(extent, key)`` sources.  Purely the
    flattened form of :func:`overlap_runs`; coverage is the caller's (and the
    property test's) concern.
    """
    plan = []
    for src_ext, key in sources:
        for soff, doff, ln in overlap_runs(src_ext, dst):
            plan.append((key, soff * itemsize, doff * itemsize, ln * itemsize))
    return plan


def assemble_extent(dst: Extent, dtype, sources: Sequence[Tuple[Extent, object]],
                    open_reader: Callable[[object], object],
                    ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Assemble the destination extent from source shard files.

    ``sources`` is ``[(extent, key), ...]``; ``open_reader(key)`` returns an
    object with ``read(start, stop) -> bytes-like`` over that shard's
    uncompressed C-order payload (a :class:`ChunkRangeReader`).  Readers are
    opened lazily — a source that doesn't overlap ``dst`` is never touched.

    Returns ``(block, covered)`` where ``block`` is the packed ndarray of the
    extent's shape and ``covered`` a flat bool mask over its elements (None
    for empty extents).  Overlapping sources are tolerated — a disjoint
    tiling writes each byte exactly once, a replicated source merely
    overwrites with identical bytes.
    """
    dtype = np.dtype(dtype)
    dshape = tuple(hi - lo for lo, hi in dst)
    out = np.empty(dshape, dtype=dtype)
    n = out.size
    flat = out.reshape(-1).view(np.uint8)
    covered = np.zeros(n, dtype=bool) if n else None
    isz = dtype.itemsize
    readers: dict = {}
    for src_ext, key in sources:
        runs = overlap_runs(src_ext, dst)
        if not runs:
            continue
        reader = readers.get(id(key))
        if reader is None:
            reader = open_reader(key)
            readers[id(key)] = reader
        for soff, doff, ln in runs:
            data = reader.read(soff * isz, (soff + ln) * isz)
            flat[doff * isz:(doff + ln) * isz] = np.frombuffer(
                data, dtype=np.uint8)
            covered[doff:doff + ln] = True
    return out, covered
