"""Public ops for Reed–Solomon erasure coding: matrices, encode, decode.

The node tier groups k peers and stores m parity buffers (``CRAFT_RS_PARITY``)
so that **any** m simultaneously lost members are recoverable — the
generalization of the XOR tier's single-loss parity (``m=1`` here *is* XOR:
the coding matrix's first row is all ones).

Coding matrix.  ``rs_matrix(k, m)`` is a column-normalized Cauchy matrix
over GF(2^8): ``C[j][i] = 1 / (x_j ^ y_i)`` with distinct evaluation points,
columns scaled so row 0 is all ones.  Every square submatrix of a Cauchy
matrix is nonsingular, and row/column scaling preserves that, so the
systematic code [I; G] is MDS: any k of the k+m symbols reconstruct the
data, i.e. up to m erasures are always solvable.

Buffers are u32-lane padded exactly like the XOR ops (shared ``_pad_to_u32``
/ ``padded_len``); the heavy byte math dispatches to the Pallas kernel on
TPU and the jitted log/exp-table reference on CPU.  The tiny (≤ m×m) matrix
inversion of the erasure solve runs on the host.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.rs_erasure.kernel import gf_matmul as gf_matmul_pallas
from repro.kernels.rs_erasure.ref import GF_EXP, GF_LOG, gf_matmul_ref
from repro.kernels.xor_parity.ops import _pad_to_u32, padded_len


# --------------------------------------------------------------------------
# host-side GF(2^8) scalar/matrix algebra (tiny, numpy)
# --------------------------------------------------------------------------
def gf_mul(a: int, b: int) -> int:
    if a == 0 or b == 0:
        return 0
    return int(GF_EXP[int(GF_LOG[a]) + int(GF_LOG[b])])


def gf_inv(a: int) -> int:
    if a == 0:
        raise ZeroDivisionError("inverse of 0 in GF(2^8)")
    return int(GF_EXP[255 - int(GF_LOG[a])])


def rs_matrix(k: int, m: int) -> np.ndarray:
    """The (m, k) parity matrix: column-normalized Cauchy, row 0 all ones."""
    if k < 1 or m < 1:
        raise ValueError(f"need k >= 1 and m >= 1, got k={k} m={m}")
    if k + m > 256:
        raise ValueError(f"k + m must be <= 256 in GF(2^8), got {k + m}")
    ys = list(range(k))                   # data points: 0 .. k-1
    xs = [255 - j for j in range(m)]      # parity points: 255 .. 256-m
    cauchy = [[gf_inv(x ^ y) for y in ys] for x in xs]
    col_inv = [gf_inv(cauchy[0][i]) for i in range(k)]
    return np.array(
        [[gf_mul(cauchy[j][i], col_inv[i]) for i in range(k)]
         for j in range(m)],
        dtype=np.uint8,
    )


def gf_mat_inv(mat: np.ndarray) -> np.ndarray:
    """Invert a small GF(2^8) matrix (Gauss–Jordan; raises if singular)."""
    a = np.array(mat, dtype=np.uint8)
    n = a.shape[0]
    if a.shape != (n, n):
        raise ValueError(f"square matrix required, got {a.shape}")
    aug = np.concatenate([a, np.eye(n, dtype=np.uint8)], axis=1)
    for col in range(n):
        pivot = next((r for r in range(col, n) if aug[r, col]), None)
        if pivot is None:
            raise ValueError("singular matrix over GF(2^8)")
        if pivot != col:
            aug[[col, pivot]] = aug[[pivot, col]]
        inv = gf_inv(int(aug[col, col]))
        aug[col] = [gf_mul(inv, int(v)) for v in aug[col]]
        for r in range(n):
            if r != col and aug[r, col]:
                f = int(aug[r, col])
                aug[r] = [int(aug[r, c]) ^ gf_mul(f, int(aug[col, c]))
                          for c in range(2 * n)]
    return aug[:, n:]


# --------------------------------------------------------------------------
# bulk byte math: backend dispatch
# --------------------------------------------------------------------------
def gf_matmul(stacked_u32: np.ndarray, matrix, *,
              use_pallas: Optional[bool] = None) -> np.ndarray:
    """Apply a byte matrix to u32-packed buffers; returns (R, W) uint32.

    Pallas kernel on TPU (static-matrix xtime chains), jitted log/exp-table
    reference elsewhere — bit-identical by construction (and by test).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    mat = tuple(tuple(int(c) for c in row) for row in matrix)
    if use_pallas:
        n = stacked_u32.shape[1]
        block = 16384 if n % 16384 == 0 else 128
        out = gf_matmul_pallas(jnp.asarray(stacked_u32), matrix=mat,
                               block_n=block)
        return np.asarray(out)
    stacked_u8 = np.ascontiguousarray(stacked_u32).view(np.uint8)
    out = np.asarray(_gf_matmul_ref_jit(jnp.asarray(stacked_u8), mat))
    return np.ascontiguousarray(out).view(np.uint32)


@functools.partial(jax.jit, static_argnums=1)
def _gf_matmul_ref_jit(stacked_u8, mat):
    # one module-level wrapper so repeated calls with the same static matrix
    # reuse the compiled executable instead of re-tracing
    return gf_matmul_ref(stacked_u8, mat)


# --------------------------------------------------------------------------
# buffer-level encode / decode (what the node tier calls)
# --------------------------------------------------------------------------
def encode_parity(buffers: Sequence, m: int, *,
                  use_pallas: Optional[bool] = None) -> List[bytes]:
    """The m parity buffers of a k-member group (zero-padded to equal length).

    Each parity buffer is ``padded_len(max member size)`` bytes; row 0 is the
    plain XOR of the group (the m=1 code is the XOR tier's parity).
    """
    if not buffers:
        raise ValueError("empty erasure group")
    if m < 1:
        raise ValueError(f"need at least one parity buffer, got m={m}")
    sizes = [len(b) if isinstance(b, (bytes, bytearray)) else b.nbytes
             for b in buffers]
    n_pad = padded_len(max(sizes))
    stacked = _pad_to_u32(buffers, n_pad)
    parity = gf_matmul(stacked, rs_matrix(len(buffers), m),
                       use_pallas=use_pallas)
    return [np.ascontiguousarray(parity[j]).view(np.uint8).tobytes()
            for j in range(m)]


def decode_lost(
    k: int,
    m: int,
    present: Dict[int, object],
    parities: Dict[int, object],
    sizes: Sequence[int],
    *,
    use_pallas: Optional[bool] = None,
) -> Dict[int, bytes]:
    """Rebuild the lost members of a group from survivors + parity buffers.

    ``present`` maps surviving member positions (0..k-1) to their payloads,
    ``parities`` maps available parity rows (0..m-1) to their buffers, and
    ``sizes`` gives every member's true byte length (from the parity
    manifest).  Any ``e = k - len(present)`` erasures are solvable as long
    as ``len(parities) >= e``; returns {lost position: exact original bytes}.

    Solve: with G the coding matrix, for each chosen parity row j the
    syndrome ``S_j = P_j  XOR  Σ_{i surviving} G[j][i]·D_i`` equals
    ``Σ_{i lost} G[j][i]·D_i``; the e×e submatrix of G over (chosen rows ×
    lost columns) is nonsingular (MDS), so the lost members are its inverse
    applied to the syndromes — three ``gf_matmul`` passes in total.
    """
    lost = sorted(set(range(k)) - set(present))
    if not lost:
        return {}
    rows = sorted(parities)[: len(lost)]
    if len(rows) < len(lost):
        raise ValueError(
            f"{len(lost)} members lost but only {len(parities)} parity "
            f"buffers available (m={m})"
        )
    if len(sizes) != k:
        raise ValueError(f"sizes must name all {k} members, got {len(sizes)}")
    g_mat = rs_matrix(k, m)
    n_pad = padded_len(max(sizes))
    surv = sorted(present)
    parity_stack = _pad_to_u32([parities[j] for j in rows], n_pad)
    if surv:
        surv_stack = _pad_to_u32([present[i] for i in surv], n_pad)
        partial = gf_matmul(surv_stack, g_mat[np.ix_(rows, surv)],
                            use_pallas=use_pallas)
        syndromes = parity_stack ^ partial
    else:
        syndromes = parity_stack
    a_inv = gf_mat_inv(g_mat[np.ix_(rows, lost)])
    rebuilt = gf_matmul(syndromes, a_inv, use_pallas=use_pallas)
    return {
        pos: np.ascontiguousarray(rebuilt[t]).view(np.uint8)
        .tobytes()[: sizes[pos]]
        for t, pos in enumerate(lost)
    }
