"""llava-next-34b — VLM: yi-34b backbone + anyres vision stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]  Backbone: 60L
d_model=7168 56H (kv=8) d_ff=20480 vocab=64000.  The anyres tiling /
CLIP tower is a STUB per the assignment: ``input_specs()`` supplies
``n_patches`` precomputed patch embeddings prepended to the text tokens.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, vocab=64000,
    attn_type="gqa", n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, rope_theta=5e6,
    frontend="vision", n_patches=1152,
    tie_embeddings=False,
)

TINY = CONFIG.replace(
    n_layers=2, d_model=64, vocab=512, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, n_patches=8,
)
