"""Device-resident snapshot pipeline (CRAFT_DEVICE_SNAPSHOT).

Covers the fused snapshot kernel against its jitted oracle (bit-identical),
the entropy helpers behind the zstd gate, the DeviceSnapshotter host-mirror
machinery (dirty-chunk-only D2H, double buffering, fallbacks), restore
equivalence with the device path on vs off across codec v1/v2 for awkward
shapes/dtypes, the zstd compressibility gate's ``enc: raw`` chunks (via a
zlib-backed stand-in when zstandard is absent), and the batched-device_get
coalescing of the host path.
"""
import zlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Box, Checkpoint
from repro.core import storage
from repro.core.device_snapshot import DeviceSnapshotter
from repro.core.env import CraftEnv
from repro.kernels.checksum import ops as checksum_ops
from repro.kernels.snapshot import ops as snapshot_ops
from repro.kernels.snapshot.kernel import snapshot as snapshot_pallas
from repro.kernels.snapshot.ref import META_COLS, snapshot_ref


# ------------------------------------------------------------------ kernel
class TestSnapshotKernel:
    @pytest.mark.parametrize("shape", [(1, 128), (4, 1024), (3, 2048)])
    @pytest.mark.parametrize("with_hist", [True, False])
    def test_kernel_matches_ref_bitexact(self, rng, shape, with_hist):
        words = jnp.asarray(
            rng.integers(0, 2**32, size=shape, dtype=np.uint32))
        prev = jnp.asarray(
            rng.integers(0, 2**32, size=(shape[0], 2), dtype=np.uint32))
        ref = snapshot_ref(words, prev, with_hist=with_hist)
        ker = snapshot_pallas(words, prev, block_rows=shape[1] // 128,
                              with_hist=with_hist, interpret=True)
        np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))

    def test_digest_columns_match_checksum_kernel(self, rng):
        data = rng.bytes(4096)
        words = jnp.asarray(
            np.frombuffer(data, np.uint32).reshape(4, 256))
        out = snapshot_ops.snapshot_chunks(
            words, jnp.zeros((4, 2), jnp.uint32))
        expect = checksum_ops.digest_chunks(data, 1024)
        got = [[int(a), int(b)] for a, b in np.asarray(out)[:, :2]]
        assert got == [[int(a), int(b)] for a, b in expect]

    def test_dirty_column_semantics(self):
        words = jnp.ones((2, 256), jnp.uint32)
        first = snapshot_ops.snapshot_chunks(
            words, jnp.zeros((2, 2), jnp.uint32))
        again = snapshot_ops.snapshot_chunks(words, first[:, :2])
        assert np.asarray(first)[:, 2].tolist() == [1, 1]
        assert np.asarray(again)[:, 2].tolist() == [0, 0]

    def test_histogram_counts_sum_to_nibbles(self, rng):
        words = jnp.asarray(
            rng.integers(0, 2**32, size=(3, 512), dtype=np.uint32))
        out = np.asarray(snapshot_ops.snapshot_chunks(
            words, jnp.zeros((3, 2), jnp.uint32)))
        assert out.shape[1] == META_COLS
        # each of the 2048 bytes per chunk contributes 2 nibbles
        assert (out[:, 3:].sum(axis=1) == 2 * 512 * 4).all()

    def test_hist_matches_host_hist(self, rng):
        data = rng.bytes(2048)
        words = jnp.asarray(np.frombuffer(data, np.uint32).reshape(1, 512))
        out = np.asarray(snapshot_ops.snapshot_chunks(
            words, jnp.zeros((1, 2), jnp.uint32)))
        np.testing.assert_array_equal(
            out[0, 3:].astype(np.int64), snapshot_ops.host_nibble_hist(data))

    def test_snapshot_host_matches_kernel_ref(self, rng):
        """The numpy CPU pass and the jit oracle agree on [s1, s2, dirty]
        over the same chunk grid (including a ragged tail chunk)."""
        data = rng.bytes(4096 + 512)          # 4.5 chunks of 1024B
        prev = rng.integers(0, 2**32, (5, 2), dtype=np.uint32)
        got = snapshot_ops.snapshot_host(
            np.frombuffer(data, np.uint8), 1024, prev)
        padded = np.frombuffer(data + bytes(512), np.uint32).reshape(5, 256)
        ref = np.asarray(snapshot_ref(
            jnp.asarray(padded), jnp.asarray(prev), with_hist=False))
        np.testing.assert_array_equal(got, ref)


# ----------------------------------------------------------------- entropy
class TestEntropy:
    def test_zeros_and_random(self, rng):
        zeros = snapshot_ops.host_nibble_hist(bytes(4096))
        rand = snapshot_ops.host_nibble_hist(rng.bytes(1 << 16))
        e = snapshot_ops.chunk_entropy_bits(np.stack([zeros, rand]))
        assert e[0] == pytest.approx(0.0)
        assert e[1] > 7.99

    def test_empty_chunk_is_zero_entropy(self):
        e = snapshot_ops.chunk_entropy_bits(np.zeros((1, 16), np.int64))
        assert e[0] == 0.0


# ---------------------------------------------------------- DeviceSnapshotter
def _host_equals(host, arr):
    ref = np.asarray(arr)
    assert host.dtype == ref.dtype and host.shape == ref.shape
    np.testing.assert_array_equal(host.view(np.uint8), ref.view(np.uint8))


class TestDeviceSnapshotter:
    @pytest.mark.parametrize("staged", [None, True])
    @pytest.mark.parametrize("dtype", [
        np.float32, np.float64, np.float16, np.int8, np.uint8, np.int64,
        np.bool_,
    ])
    def test_host_view_bitexact(self, rng, dtype, staged):
        # jnp.asarray downcasts 64-bit without x64 — compare vs the jax array
        a = jnp.asarray((rng.standard_normal(512) * 8).astype(dtype))
        snap = DeviceSnapshotter(256, staged=staged)
        host, meta = snap.snapshot("k", a)
        _host_equals(host, a)
        assert meta is not None and meta["dirty"] is None

    def test_bfloat16(self):
        a = jnp.arange(512, dtype=jnp.bfloat16)
        host, meta = DeviceSnapshotter(256).snapshot("k", a)
        _host_equals(host, a)
        assert meta is not None

    def test_digests_match_host_codec(self, rng):
        a = rng.standard_normal(1024).astype(np.float32)
        _, meta = DeviceSnapshotter(512).snapshot("k", jnp.asarray(a))
        expect = checksum_ops.digest_chunks(a.view(np.uint8).tobytes(), 512)
        assert meta["rdigests"] == [[int(x), int(y)] for x, y in expect]

    @pytest.mark.parametrize("staged", [None, True])
    def test_dirty_tracking_across_rounds(self, rng, staged):
        snap = DeviceSnapshotter(256, double_buffer=False, staged=staged)
        a = rng.standard_normal(512).astype(np.float32)   # 8 chunks
        snap.snapshot("k", jnp.asarray(a))
        a[65] += 1.0                                      # chunk 1
        host, meta = snap.snapshot("k", jnp.asarray(a))
        _host_equals(host, a)
        assert meta["dirty"] == [False, True] + [False] * 6

    def test_double_buffer_mirrors_stay_exact(self, rng):
        """Alternating mirrors each patch the chunks dirtied since *they*
        were last current (two rounds ago), not just the last round's
        (staged mode — the zero-copy CPU path has no mirrors to drift)."""
        snap = DeviceSnapshotter(256, double_buffer=True, staged=True)
        a = rng.standard_normal(512).astype(np.float32)
        for r in range(6):
            a[(r * 64) % 512] += 1.0      # a different chunk every round
            host, meta = snap.snapshot("k", jnp.asarray(a))
            _host_equals(host, a)

    def test_staged_host_view_stable_across_updates(self, rng):
        """In staged mode the returned view must keep the snapshotted bytes
        until the *next-plus-one* snapshot (double buffering), so an async
        writer never sees a torn buffer."""
        snap = DeviceSnapshotter(256, double_buffer=True, staged=True)
        a = rng.standard_normal(512).astype(np.float32)
        h0, _ = snap.snapshot("k", jnp.asarray(a))
        v0 = a.copy()
        a[0] += 1.0
        snap.snapshot("k", jnp.asarray(a))     # patches the other mirror
        np.testing.assert_array_equal(h0, v0)  # h0 untouched

    def test_fallbacks_return_none_meta(self):
        snap = DeviceSnapshotter(1024)
        for arr in (jnp.zeros((0,), jnp.float32),       # empty
                    jnp.zeros((3,), jnp.float16),       # 6 bytes, not /4
                    jnp.zeros((4,), jnp.complex64)):    # complex kind
            host, meta = snap.snapshot("k", arr)
            assert meta is None
            _host_equals(host, arr)

    def test_reshape_resets_to_full_write(self, rng):
        snap = DeviceSnapshotter(256)
        snap.snapshot("k", jnp.zeros(512, jnp.float32))
        host, meta = snap.snapshot("k", jnp.zeros(1024, jnp.float32))
        assert meta["dirty"] is None     # fresh state → full literal write
        _host_equals(host, jnp.zeros(1024, jnp.float32))

    def test_tail_pad_entropy_corrected(self, rng):
        # 1200 bytes over 512-byte chunks: last chunk is 176 real bytes +
        # padding; its entropy must reflect only the real bytes (staged
        # mode — the CPU numpy pass carries no histogram).
        a = np.frombuffer(rng.bytes(1200), np.uint8).view(np.float32)
        _, meta = DeviceSnapshotter(512, staged=True).snapshot(
            "k", jnp.asarray(a))
        tail = a.view(np.uint8)[1024:]
        expect = snapshot_ops.chunk_entropy_bits(
            snapshot_ops.host_nibble_hist(tail)[None])[0]
        assert meta["entropy_bits"][2] == pytest.approx(expect)


# ------------------------------------------------- checkpoint equivalence
def _env(tmp_path, tag, **extra):
    base = {
        "CRAFT_CP_PATH": str(tmp_path / f"pfs-{tag}"),
        "CRAFT_USE_SCR": "0",
        "CRAFT_CHUNK_BYTES": "256",
        "CRAFT_KEEP_VERSIONS": "8",
    }
    base.update(extra)
    return CraftEnv.capture(base)


def _payload_cases(rng):
    return {
        "scalar0d": jnp.float32(1.25),
        "empty": jnp.zeros((0, 3), jnp.float32),
        "unaligned": jnp.asarray(
            rng.standard_normal(77).astype(np.float32)),     # 308 bytes
        "odd_f16": jnp.asarray(
            rng.standard_normal(33).astype(np.float16)),     # 66 bytes
        "multichunk": jnp.asarray(
            rng.standard_normal(512).astype(np.float32)),
        "flags": jnp.asarray(rng.integers(0, 2, 300).astype(bool)),
    }


def _run_versions(tmp_path, tag, rng, *, device, codec):
    env = _env(
        tmp_path, tag,
        CRAFT_DEVICE_SNAPSHOT="1" if device else "0",
        CRAFT_CODEC_VERSION=str(codec),
        CRAFT_DELTA="1" if codec == 2 else "0",
    )
    boxes = {k: Box(v) for k, v in _payload_cases(rng).items()}
    cp = Checkpoint(f"eq-{tag}", env=env)
    for k, b in boxes.items():
        cp.add(k, b)
    cp.commit()
    for r in range(3):
        mc = np.asarray(boxes["multichunk"].value).copy()
        mc[r * 64] += 1.0
        boxes["multichunk"].value = jnp.asarray(mc)
        cp.update_and_write()
    cp.close()
    # restore into fresh boxes
    out = {k: Box(jnp.zeros_like(v)) for k, v in _payload_cases(rng).items()}
    out["scalar0d"] = Box(jnp.float32(0))
    cp2 = Checkpoint(f"eq-{tag}", env=env)
    for k, b in out.items():
        cp2.add(k, b)
    cp2.commit()
    assert cp2.restart_if_needed()
    cp2.close()
    return {k: np.asarray(b.value) for k, b in out.items()}, boxes


@pytest.mark.parametrize("codec", [1, 2])
def test_restore_bitexact_device_on_vs_off(tmp_path, rng, codec):
    rng2 = np.random.default_rng(0)
    off, live_off = _run_versions(
        tmp_path, f"off{codec}", rng, device=False, codec=codec)
    on, live_on = _run_versions(
        tmp_path, f"on{codec}", rng2, device=True, codec=codec)
    for k in off:
        assert off[k].dtype == on[k].dtype and off[k].shape == on[k].shape, k
        assert off[k].tobytes() == on[k].tobytes(), k
        assert on[k].tobytes() == np.asarray(live_on[k].value).tobytes(), k


def test_delta_refs_written_with_device_path(tmp_path, rng):
    """With the device path on, unchanged chunks still become delta refs."""
    env = _env(tmp_path, "refs", CRAFT_DEVICE_SNAPSHOT="1",
               CRAFT_CODEC_VERSION="2", CRAFT_DELTA="1")
    box = Box(jnp.asarray(rng.standard_normal(512).astype(np.float32)))
    cp = Checkpoint("refs", env=env)
    cp.add("a", box)
    cp.commit()
    cp.update_and_write()
    a = np.asarray(box.value).copy()
    a[0] += 1.0
    box.value = jnp.asarray(a)
    cp.update_and_write()
    assert cp.stats["delta_chunks_skipped"] >= 6   # 8 chunks, 1 dirty
    cp.close()


def test_reshape_between_versions_falls_back(tmp_path, rng):
    env = _env(tmp_path, "reshape", CRAFT_DEVICE_SNAPSHOT="1",
               CRAFT_CODEC_VERSION="2", CRAFT_DELTA="1")
    box = Box(jnp.asarray(rng.standard_normal(512).astype(np.float32)))
    cp = Checkpoint("rs", env=env)
    cp.add("a", box)
    cp.commit()
    cp.update_and_write()
    final = rng.standard_normal(256).astype(np.float32)
    box.value = jnp.asarray(final)
    cp.update_and_write()
    cp.close()
    out = Box(jnp.zeros(256, jnp.float32))
    cp2 = Checkpoint("rs", env=env)
    cp2.add("a", out)
    cp2.commit()
    assert cp2.restart_if_needed()
    np.testing.assert_array_equal(np.asarray(out.value), final)
    cp2.close()


# ------------------------------------------------------------- zstd gate
class _FakeCompressor:
    def __init__(self, level=3):
        self.level = level

    def compress(self, data):
        return zlib.compress(bytes(data), 6)


class _FakeDecompressor:
    def decompress(self, data):
        return zlib.decompress(bytes(data))


class _FakeZstd:
    ZstdCompressor = staticmethod(
        lambda level=3: _FakeCompressor(level))
    ZstdDecompressor = staticmethod(_FakeDecompressor)


@pytest.fixture()
def fake_zstd(monkeypatch):
    """A zlib-backed stand-in so the gate/enc-raw paths run without the
    optional zstandard dependency (id(_zstd) keying keeps the compressor
    cache coherent across the swap)."""
    monkeypatch.setattr(storage, "_zstd", _FakeZstd)
    return _FakeZstd


class TestZstdGate:
    def _ctx(self, tmp_path, **kw):
        from repro.core.cpbase import IOContext
        kw.setdefault("compress", "zstd")
        kw.setdefault("codec_version", 1)
        kw.setdefault("chunk_bytes", 256)
        return IOContext(**kw)

    def test_incompressible_chunks_stored_raw(self, tmp_path, rng, fake_zstd):
        arr = np.frombuffer(rng.bytes(1024), np.uint8)
        p = tmp_path / "a.bin"
        # 256-byte chunks: small-sample bias puts random data at ~7.96
        # bits/byte, so gate at 7.5 to deterministically catch every chunk
        storage.write_array(
            p, arr, self._ctx(tmp_path, zstd_gate_bits=7.5))
        import json
        raw = p.read_bytes()
        hlen = int.from_bytes(raw[4:12], "little")
        chunks = json.loads(raw[12:12 + hlen])["chunks"]
        assert all(c.get("enc") == "raw" for c in chunks)
        out = storage.read_array(p, self._ctx(tmp_path))
        np.testing.assert_array_equal(out, arr)

    def test_compressible_chunks_still_zstd(self, tmp_path, fake_zstd):
        arr = np.zeros(1024, np.uint8)
        p = tmp_path / "z.bin"
        storage.write_array(
            p, arr, self._ctx(tmp_path, zstd_gate_bits=7.95))
        import json
        raw = p.read_bytes()
        hlen = int.from_bytes(raw[4:12], "little")
        chunks = json.loads(raw[12:12 + hlen])["chunks"]
        assert all("enc" not in c for c in chunks)
        assert chunks[0]["clen"] < chunks[0]["ulen"]
        out = storage.read_array(p, self._ctx(tmp_path))
        np.testing.assert_array_equal(out, arr)

    def test_gate_disabled_compresses_everything(self, tmp_path, rng,
                                                 fake_zstd):
        arr = np.frombuffer(rng.bytes(1024), np.uint8)
        p = tmp_path / "g.bin"
        storage.write_array(p, arr, self._ctx(tmp_path, zstd_gate_bits=0.0))
        import json
        raw = p.read_bytes()
        hlen = int.from_bytes(raw[4:12], "little")
        chunks = json.loads(raw[12:12 + hlen])["chunks"]
        assert all("enc" not in c for c in chunks)
        out = storage.read_array(p, self._ctx(tmp_path))
        np.testing.assert_array_equal(out, arr)

    def test_v2_ref_resolution_against_raw_base(self, tmp_path, rng,
                                                fake_zstd):
        """A v2 ref chunk whose base chunk was gated raw must resolve."""
        env = _env(tmp_path, "rawref", CRAFT_DEVICE_SNAPSHOT="1",
                   CRAFT_CODEC_VERSION="2", CRAFT_DELTA="1",
                   CRAFT_COMPRESS="zstd", CRAFT_ZSTD_GATE_BITS="7.95")
        data = np.frombuffer(rng.bytes(2048), np.uint8).view(np.float32)
        box = Box(jnp.asarray(data))
        cp = Checkpoint("rawref", env=env)
        cp.add("a", box)
        cp.commit()
        cp.update_and_write()      # v1: raw-gated full write
        a = np.asarray(box.value).copy()
        a[0] += 1.0
        box.value = jnp.asarray(a)
        cp.update_and_write()      # v2: refs against raw base chunks
        cp.close()
        out = Box(jnp.zeros_like(box.value))
        cp2 = Checkpoint("rawref", env=env)
        cp2.add("a", out)
        cp2.commit()
        assert cp2.restart_if_needed()
        np.testing.assert_array_equal(np.asarray(out.value), a)
        cp2.close()

    def test_compressor_cache_reused_per_thread(self, fake_zstd):
        c1 = storage._compressor(3)
        c2 = storage._compressor(3)
        c5 = storage._compressor(5)
        assert c1 is c2 and c1 is not c5
        assert storage._decompressor() is storage._decompressor()


# --------------------------------------------------- batched D2H coalescing
class TestBatchedDeviceGet:
    def test_jax_array_update_single_device_get(self, monkeypatch):
        from repro.core import checkpointables
        calls = []
        real = jax.device_get
        monkeypatch.setattr(
            jax, "device_get",
            lambda x: calls.append(1) or real(x))
        box = Box(jnp.arange(128, dtype=jnp.float32))
        cp = checkpointables.JaxArrayCp(box)
        calls.clear()
        cp.update()
        assert len(calls) == 1

    def test_pytree_update_single_device_get(self, monkeypatch):
        from repro.core import checkpointables
        calls = []
        real = jax.device_get
        monkeypatch.setattr(
            jax, "device_get",
            lambda x: calls.append(1) or real(x))
        box = Box({"a": jnp.zeros(64), "b": jnp.ones(32),
                   "c": np.zeros(8), "n": 3})
        cp = checkpointables.PytreeCp(box)
        calls.clear()
        cp.update()
        assert len(calls) == 1
