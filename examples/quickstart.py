"""Quickstart — the paper's Listing 2, in JAX.

A toy iterative application gains application-level checkpoint/restart with
five lines: define a Checkpoint, add() the state, commit(), restart, and
the need_checkpoint()/update_and_write() pair inside the loop — the policy
(core/scheduler.py) decides when and to which tiers a version is written;
``cp_freq`` here is the paper's fixed-frequency gate layered on top (see
docs/tuning.md for the adaptive Daly/per-tier knobs).  Run it twice to see
the restart:

    PYTHONPATH=src python examples/quickstart.py         # runs, checkpoints
    PYTHONPATH=src python examples/quickstart.py         # resumes at iter 60
"""
import jax.numpy as jnp
import numpy as np

from repro.core import Box, Checkpoint
from repro.core.env import CraftEnv

# Checkpoints land under ./craft-quickstart (CRAFT_CP_PATH analog).
env = CraftEnv.capture({"CRAFT_CP_PATH": "craft-quickstart",
                        "CRAFT_USE_SCR": "0"})


def modify_data(dbl: Box, arr: np.ndarray, state: Box) -> None:
    """The 'computation-communication loop' body of paper Listing 1."""
    dbl.value += 0.5
    arr += 1
    state.value = jnp.sin(state.value + dbl.value)


def main() -> None:
    n = 5
    iteration = Box(1)                       # paper: int iteration
    dbl = Box(0.0)                           # paper: double dbl
    data_arr = np.zeros(n)                   # paper: int* dataArr
    jax_state = Box(jnp.zeros((4, 4)))       # beyond paper: a jax.Array

    # ============ DEFINE CHECKPOINT (paper Listing 2) ============
    my_cp = Checkpoint("myCP", env=env)
    my_cp.add("dbl", dbl)
    my_cp.add("iteration", iteration)
    my_cp.add("dataArr", data_arr)
    my_cp.add("state", jax_state)
    my_cp.commit()
    restarted = my_cp.restart_if_needed()
    if restarted:
        print(f"restarted from iteration {iteration.value} "
              f"(checkpoint v-{my_cp.version})")
    # =============================================================

    cp_freq = 10
    while iteration.value <= 100:
        modify_data(dbl, data_arr, jax_state)
        if iteration.value == 55 and not restarted:
            print("simulating a crash at iteration 55 — run me again!")
            return
        iteration.value += 1
        # the policy API: probe the scheduler, then write (the probe is
        # optional — update_and_write() evaluates the same cached decision)
        if my_cp.need_checkpoint(iteration.value, cp_freq):
            my_cp.update_and_write(iteration.value, cp_freq)

    print(f"done: iteration={iteration.value - 1}, dbl={dbl.value}, "
          f"dataArr={data_arr}, |state|={float(jnp.sum(jax_state.value)):.4f}")
    print(f"checkpoint stats: {my_cp.stats}")


if __name__ == "__main__":
    main()
