"""Pure-jnp oracle for the blocked Fletcher-like checksum.

Definition over a uint32 vector ``x`` of length N (mod-2^32 wraparound):

    s1 = sum_i x[i]
    s2 = sum_i (i + 1) * x[i]
    digest = (s2 << 32) | s1          (returned as two uint32 words)

Both sums are associative under concatenation:
    s1 = s1_a + s1_b
    s2 = s2_a + (s2_b + |a| * s1_b)
which is what makes the blocked/parallel kernel possible.
"""
from __future__ import annotations

import jax.numpy as jnp


def checksum_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Return ``[s1, s2]`` as a (2,) uint32 array."""
    if x.ndim != 1 or x.dtype != jnp.uint32:
        raise TypeError(f"expected 1-D uint32, got {x.shape} {x.dtype}")
    idx = (jnp.arange(x.shape[0], dtype=jnp.uint32) + jnp.uint32(1))
    s1 = jnp.sum(x, dtype=jnp.uint32)
    s2 = jnp.sum(x * idx, dtype=jnp.uint32)
    return jnp.stack([s1, s2])
