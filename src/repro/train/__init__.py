from repro.train.steps import (  # noqa: F401
    TrainStepConfig, make_train_step, make_prefill, make_decode_step,
    cross_entropy,
)
