"""Stdlib-only HTTP exporter for the live telemetry plane
(``CRAFT_METRICS_PORT``).

Serves two endpoints from a daemon thread:

``/metrics``
    The process-local :mod:`repro.core.metrics` registry rendered in
    Prometheus text exposition format.  (Fleet totals are a *caller*
    concern: rank 0 can publish a merged view via
    :func:`repro.core.metrics.aggregate` — the exporter itself never
    touches the comm fabric, so a scrape can never deadlock a collective.)

``/healthz``
    A JSON liveness/readiness document built from every live
    :class:`~repro.core.checkpoint.Checkpoint` in the process (registered
    weakly at ``commit()``): per-tier breaker states, last-checkpoint
    version and age, async-writer backlog and oldest pending write,
    scrubber verdicts, degraded-write counters.  Returns HTTP 200 while
    every breaker is closed/half-open and 503 while any is open — i.e.
    suitable verbatim as a k8s liveness probe for ``launch/serve.py``
    replicas: a replica whose PFS tier is dark flips unhealthy, and flips
    back the moment the breaker re-admits the tier.

The server is process-global and idempotent like the trace recorder:
``maybe_start_from_env(env)`` is called from ``Checkpoint.commit()`` and
is a no-op unless ``CRAFT_METRICS_PORT`` is set.  Port ``0`` binds an
ephemeral port (tests read :func:`port` back).
"""
from __future__ import annotations

import json
import threading
import time
import weakref
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from . import metrics

__all__ = [
    "start", "stop", "port", "maybe_start_from_env",
    "register_checkpoint", "health_report",
]

# Live checkpoints, weakly held so telemetry never extends their lifetime.
_CHECKPOINTS: "weakref.WeakValueDictionary[str, object]" = \
    weakref.WeakValueDictionary()
_LOCK = threading.Lock()
_SERVER: Optional["_TelemetryServer"] = None


def register_checkpoint(cp) -> None:
    """Track ``cp`` for ``/healthz`` (called from ``Checkpoint.commit()``)."""
    _CHECKPOINTS[cp.name] = cp


def health_report(clock=time.monotonic) -> dict:
    """The ``/healthz`` document: healthy unless some breaker is open."""
    now = clock()
    checkpoints = {}
    healthy = True
    for name, cp in sorted(_CHECKPOINTS.items()):
        if cp is None or getattr(cp, "_closed", False):
            continue
        breakers = {}
        for slot, th in getattr(cp, "health", {}).items():
            state = th.breaker.state
            breakers[slot] = {"state": state, "last_error": th.last_error}
            if state == "open":
                healthy = False
        writer = getattr(cp, "_writer", None)
        last_t = getattr(cp, "_last_write_t", None)
        stats = cp.stats
        doc = {
            "version": cp.version,
            "last_write_age_s": (round(now - last_t, 3)
                                 if last_t is not None else None),
            "breakers": breakers,
            "async_backlog": writer.pending if writer is not None else 0,
            "async_oldest_pending_s": (
                round(writer.oldest_pending_s(now), 3)
                if writer is not None else 0.0),
            "degraded_writes": stats.get("degraded_writes", 0),
            "breaker_trips": stats.get("breaker_trips", 0),
            "retries": stats.get("retries", 0),
        }
        scrubber = getattr(cp, "scrubber", None)
        if scrubber is not None:
            s = scrubber.stats
            doc["scrubber"] = {
                k: s.get(k, 0)
                for k in ("corrupt_found", "repaired", "unrepairable",
                          "quarantined", "files_scanned")
            }
        checkpoints[name] = doc
    return {
        "status": "ok" if healthy else "unhealthy",
        "healthy": healthy,
        "checkpoints": checkpoints,
    }


class _Handler(BaseHTTPRequestHandler):
    server_version = "craft-telemetry"

    def do_GET(self):  # noqa: N802 - http.server API
        path = self.path.split("?", 1)[0]
        if path == "/metrics":
            body = metrics.render_prometheus(metrics.snapshot())
            self._reply(200, body, "text/plain; version=0.0.4")
        elif path == "/healthz":
            report = health_report()
            code = 200 if report["healthy"] else 503
            self._reply(code, json.dumps(report, indent=1) + "\n",
                        "application/json")
        else:
            self._reply(404, "not found\n", "text/plain")

    def _reply(self, code: int, body: str, ctype: str) -> None:
        data = body.encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        try:
            self.wfile.write(data)
        except (BrokenPipeError, ConnectionResetError):  # scraper went away
            pass

    def log_message(self, fmt, *args):  # silence per-request stderr noise
        return None


class _TelemetryServer:
    def __init__(self, port: int):
        self.httpd = ThreadingHTTPServer(("", port), _Handler)
        self.httpd.daemon_threads = True
        self.port = self.httpd.server_address[1]
        self.thread = threading.Thread(
            target=self.httpd.serve_forever, kwargs={"poll_interval": 0.25},
            name="craft-telemetry", daemon=True)
        self.thread.start()

    def stop(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()
        self.thread.join(timeout=5.0)


def start(port_no: int = 0) -> int:
    """Start (or reuse) the exporter; returns the bound port.  Arms the
    metrics registry too — an exporter with nothing to serve is useless."""
    global _SERVER
    with _LOCK:
        if _SERVER is None:
            metrics.install()
            _SERVER = _TelemetryServer(port_no)
        return _SERVER.port


def stop() -> None:
    """Shut the exporter down (tests; end of a metered run)."""
    global _SERVER
    with _LOCK:
        server, _SERVER = _SERVER, None
    if server is not None:
        server.stop()


def port() -> Optional[int]:
    """The bound port, or ``None`` while the exporter is down."""
    with _LOCK:
        return _SERVER.port if _SERVER is not None else None


def maybe_start_from_env(env) -> None:
    """Start the exporter when the captured env names a port
    (``Checkpoint.commit()`` calls this — the read-once contract)."""
    if getattr(env, "metrics_port", -1) >= 0:
        start(env.metrics_port)
