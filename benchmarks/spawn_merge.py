"""Paper Fig. 7: spawn+merge cost vs communicator size.

The paper benchmarks MPI_Comm_spawn + MPI_Intercomm_merge of 20 processes
against communicators of growing size and finds ULFM-1.1 scales poorly.
Our analog: kill k members of an n-member epoch and measure the
spawn+merge phase of the non-shrinking recovery (replacement threads
registering into the next epoch + the join barrier).
"""
from __future__ import annotations

from benchmarks.common import emit
from benchmarks.recovery_scaling import _recover_once


def main(full: bool = False) -> None:
    sizes = [8, 16, 32, 64, 128] + ([256] if full else [])
    for n in sizes:
        s = _recover_once(n, 2, "NON-SHRINKING", "NO-REUSE")
        emit("fig7_spawn_merge", "spawn_merge",
             round(s.get("spawn_merge_s", float("nan")), 6), "s", procs=n)


if __name__ == "__main__":
    main()
