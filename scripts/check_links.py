#!/usr/bin/env python
"""Markdown link check for README.md and docs/ (CI docs job).

Verifies that every relative markdown link resolves to an existing file or
directory in the repository.  External (http/https/mailto) links are only
syntax-checked, never fetched — CI must not depend on the network.

Code anchors: a link fragment of the form ``path#Lnn`` (the style
docs/paper_mapping.md and docs/tuning.md use to point into source files) is
additionally validated — the target file must exist and be at least ``nn``
lines long, so an anchor can never point past the end of the file it names.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
LINE_ANCHOR = re.compile(r"^L(\d+)(?:-L?(\d+))?$")  # Lnn or Lnn-Lmm


def doc_files():
    yield REPO / "README.md"
    yield from sorted((REPO / "docs").glob("*.md"))


def check(md: Path) -> list:
    errors = []
    for target in LINK.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):
            continue                      # intra-document anchor
        path, _, frag = target.partition("#")   # #Lnn / heading anchors
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(REPO)}: broken link -> {target}")
            continue
        if REPO not in resolved.parents and resolved != REPO:
            errors.append(f"{md.relative_to(REPO)}: escapes repo -> {target}")
            continue
        m = LINE_ANCHOR.match(frag)
        if m and resolved.is_file():
            want = max(int(g) for g in m.groups() if g is not None)
            have = sum(1 for _ in resolved.open(errors="replace"))
            if have < want:
                errors.append(
                    f"{md.relative_to(REPO)}: anchor past EOF -> {target} "
                    f"(file has {have} lines)"
                )
    return errors


def main() -> int:
    errors = []
    n = 0
    for md in doc_files():
        if md.exists():
            n += 1
            errors += check(md)
    if not n:
        print("no markdown files found", file=sys.stderr)
        return 1
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {n} files: {'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
