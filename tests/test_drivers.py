"""End-to-end driver integration: train + serve with CRAFT CR and faults."""
import numpy as np
import pytest

from repro.core.env import CraftEnv
from repro.launch import serve as serve_mod
from repro.launch import train as train_mod

pytestmark = pytest.mark.slow


def _env(tmp_path):
    return CraftEnv.capture({
        "CRAFT_CP_PATH": str(tmp_path / "pfs"), "CRAFT_USE_SCR": "0"})


ARCH = "h2o-danube-1.8b"


class TestTrainDriver:
    def test_loss_goes_down(self, tmp_path):
        tc = train_mod.TrainConfig(arch=ARCH, steps=16, cp_freq=8,
                                   global_batch=4, seq_len=32)
        out = train_mod.run(tc, env=_env(tmp_path))
        assert out["final_step"] == 16
        first, last = np.mean(out["losses"][:4]), np.mean(out["losses"][-4:])
        assert np.isfinite(out["losses"]).all()
        assert last < first
        assert out["stats"]["writes"] == 2

    def test_restart_resumes_and_matches(self, tmp_path):
        """Interrupted run + restart == uninterrupted run (exact resume:
        same data cursor, same state)."""
        env = _env(tmp_path)
        kw = dict(arch=ARCH, steps=20, cp_freq=5, global_batch=4, seq_len=32)

        # uninterrupted reference in a separate directory
        ref = train_mod.run(
            train_mod.TrainConfig(**kw),
            env=CraftEnv.capture({
                "CRAFT_CP_PATH": str(tmp_path / "ref"),
                "CRAFT_USE_SCR": "0"}))

        # interrupted at step 12 (after the v at step 10)
        with pytest.raises(KeyboardInterrupt):
            def boom(step, metrics):
                if step == 12:
                    raise KeyboardInterrupt

            train_mod.run(train_mod.TrainConfig(**kw), env=env,
                          on_step=boom)

        resumed = train_mod.run(train_mod.TrainConfig(**kw), env=env)
        # resumed run re-executes steps 11..20 (restart from v-2 @ step 10)
        assert resumed["final_step"] == 20
        np.testing.assert_allclose(
            resumed["losses"][-5:], ref["losses"][-5:], rtol=1e-4)

    def test_aft_zone_with_sim_comm(self, tmp_path):
        """Injected rank failure mid-training; AFT zone recovers and the
        final state matches the no-failure run."""
        from repro.core.comm_sim import SimWorld

        env_args = {"CRAFT_CP_PATH": str(tmp_path / "pfs"),
                    "CRAFT_USE_SCR": "0",
                    "CRAFT_COMM_RECOVERY_POLICY": "NON-SHRINKING"}
        env = CraftEnv.capture(env_args)
        world = SimWorld(2, spare_nodes=1, env=env)
        tc = train_mod.TrainConfig(arch=ARCH, steps=10, cp_freq=2,
                                   global_batch=4, seq_len=32,
                                   fail_at_step=5)

        def worker(comm):
            return train_mod.run(tc, comm=comm, env=env)

        results = world.run(worker, timeout=500)
        finals = [r["final_step"] for r in results.values()]
        assert all(f == 10 for f in finals)


class TestServeDriver:
    def test_greedy_decode_runs(self, tmp_path):
        sc = serve_mod.ServeConfig(arch=ARCH, batch=2, prompt_len=16,
                                   gen_tokens=8)
        out = serve_mod.run(sc, env=_env(tmp_path))
        assert out["tokens"].shape == (2, 8)
        assert out["resumed_at"] == 0

    def test_decode_restart_resumes_identically(self, tmp_path):
        env = _env(tmp_path)
        sc = serve_mod.ServeConfig(arch=ARCH, batch=2, prompt_len=16,
                                   gen_tokens=12, cp_freq=4)
        ref = serve_mod.run(sc, env=CraftEnv.capture({
            "CRAFT_CP_PATH": str(tmp_path / "ref"), "CRAFT_USE_SCR": "0"}))

        with pytest.raises(RuntimeError, match="injected"):
            serve_mod.run(sc, env=env, fail_at_token=9)
        out = serve_mod.run(sc, env=env)
        assert out["resumed_at"] == 8          # last v at token 8
        np.testing.assert_array_equal(out["tokens"], ref["tokens"])
