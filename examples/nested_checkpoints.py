"""Nested checkpoints — the paper's Listing 7 / Fig. 3 / Table 1.

An outer 'continuation' loop (e.g. a parameter sweep) encloses an inner
iterative solve.  Each level gets its own Checkpoint; ``sub_cp`` declares
the parent→child edge so publishing an outer version invalidates stale
inner versions — restarting can never mix outer iteration 2 with inner
state from iteration 1.

    PYTHONPATH=src python examples/nested_checkpoints.py             # crash
    PYTHONPATH=src python examples/nested_checkpoints.py             # resume
"""
import numpy as np

from repro.core import Box, Checkpoint
from repro.core.env import CraftEnv

env = CraftEnv.capture({"CRAFT_CP_PATH": "craft-nested",
                        "CRAFT_USE_SCR": "0"})

N_L1, L1_FREQ = 2, 1          # paper: nL1iter=2, L1cpFreq=1
N_L2, L2_FREQ = 30, 10        # paper: nL2iter=30, L2cpFreq=10


def main() -> None:
    l1 = Box(0)
    result = Box(np.zeros(4))
    cl1 = Checkpoint("CL1", env=env)
    cl1.add("l1", l1)
    cl1.add("result", result)
    cl1.commit()

    l2 = Box(0)
    inner = Box(np.zeros(4))
    cl2 = Checkpoint("CL2", env=env)
    cl2.add("l2", l2)
    cl2.add("inner", inner)
    cl2.commit()
    cl1.sub_cp(cl2)           # paper: CL1.subCP(CL2)

    cl1.restart_if_needed()
    crash_once = not (l1.value or l2.value)

    while l1.value < N_L1:
        # restartIfNeeded of the INNER cp runs every outer iteration but
        # only reads on the first call of a restarted run (paper §2.5)
        cl2.restart_if_needed()
        if l2.value:
            print(f"  resumed inner loop at l2={l2.value} (outer {l1.value})")
        while l2.value < N_L2:
            inner.value += 1.0
            l2.value += 1
            cl2.update_and_write(l2.value, L2_FREQ)
            if crash_once and l1.value == 1 and l2.value == 15:
                print("simulated crash at outer=1, inner=15 — run me again; "
                      "I must resume at outer=1, inner=10 (NOT inner=30 of "
                      "outer 0 — paper Table 1 stage V)")
                return
        result.value += inner.value
        inner.value[:] = 0.0
        l2.value = 0
        l1.value += 1
        cl1.update_and_write(l1.value, L1_FREQ)   # invalidates CL2 versions

    print(f"done: result={result.value} (expect "
          f"{np.full(4, float(N_L1 * N_L2))})")


if __name__ == "__main__":
    main()
