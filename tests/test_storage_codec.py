"""Array codec: chunked v1 format, legacy v0 compat, truncation, fanout pool."""
import threading

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import storage
from repro.core.async_writer import AsyncWriter
from repro.core.cpbase import CheckpointError, IOContext
from repro.core.storage import StorageTier


def ctx_v1(**kw):
    return IOContext(codec_version=1, **kw)


def ctx_v0(**kw):
    return IOContext(codec_version=0, **kw)


# ------------------------------------------------------------------ roundtrip
class TestChunkedRoundtrip:
    @pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32,
                                       np.uint8, np.bool_])
    def test_dtypes(self, tmp_path, rng, dtype):
        arr = (rng.standard_normal((33, 7)) * 10).astype(dtype)
        p = tmp_path / "a.bin"
        storage.write_array(p, arr, ctx_v1())
        out = storage.read_array(p, ctx_v1())
        np.testing.assert_array_equal(out, arr)
        assert out.dtype == arr.dtype

    def test_bfloat16(self, tmp_path):
        arr = np.asarray(jnp.asarray([[1.5, -2.25], [0.125, 7.0]],
                                     jnp.bfloat16))
        p = tmp_path / "a.bin"
        storage.write_array(p, arr, ctx_v1())
        out = storage.read_array(p, ctx_v1())
        np.testing.assert_array_equal(out.astype(np.float32),
                                      arr.astype(np.float32))

    @pytest.mark.parametrize("shape", [(0,), (1,), (), (5, 0, 3)])
    def test_degenerate_shapes(self, tmp_path, shape):
        arr = np.ones(shape, dtype=np.float32)
        p = tmp_path / "a.bin"
        storage.write_array(p, arr, ctx_v1())
        out = storage.read_array(p, ctx_v1())
        assert out.shape == arr.shape
        np.testing.assert_array_equal(out, arr)

    @pytest.mark.parametrize("n_bytes,chunk", [
        (100, 64),         # ragged tail chunk
        (128, 64),         # exact multiple
        (63, 64),          # single partial chunk
        (1024, 16),        # many chunks
    ])
    def test_chunk_boundaries(self, tmp_path, rng, n_bytes, chunk):
        arr = rng.integers(0, 255, n_bytes, dtype=np.uint8)
        p = tmp_path / "a.bin"
        storage.write_array(p, arr, ctx_v1(chunk_bytes=chunk))
        out = storage.read_array(p, ctx_v1())
        np.testing.assert_array_equal(out, arr)

    def test_header_records_chunk_metadata(self, tmp_path, rng):
        arr = rng.integers(0, 255, 100, dtype=np.uint8)
        p = tmp_path / "a.bin"
        storage.write_array(p, arr, ctx_v1(chunk_bytes=64))
        import json
        raw = p.read_bytes()
        hlen = int.from_bytes(raw[4:12], "little")
        header = json.loads(raw[12:12 + hlen])
        assert header["fmt"] == 1
        assert header["nbytes"] == 100
        assert [c["ulen"] for c in header["chunks"]] == [64, 36]
        assert all(c["digest"] != [0, 0] for c in header["chunks"])


# ------------------------------------------------------------------ v0 compat
class TestLegacyCompat:
    def test_v0_write_v1_read(self, tmp_path, rng):
        """A checkpoint written pre-refactor restores through the new reader."""
        arr = rng.standard_normal((17, 3)).astype(np.float64)
        p = tmp_path / "a.bin"
        storage.write_array(p, arr, ctx_v0())
        out = storage.read_array(p, ctx_v1())     # default reader
        np.testing.assert_array_equal(out, arr)

    def test_v0_checksum_still_verified(self, tmp_path, rng):
        arr = rng.standard_normal((64,)).astype(np.float32)
        p = tmp_path / "a.bin"
        storage.write_array(p, arr, ctx_v0())
        raw = bytearray(p.read_bytes())
        raw[-5] ^= 0xFF
        p.write_bytes(bytes(raw))
        with pytest.raises(CheckpointError, match="checksum"):
            storage.read_array(p, ctx_v1())

    def test_future_format_rejected(self, tmp_path):
        import json
        header = json.dumps({"fmt": 99, "dtype": "float32", "shape": [1],
                             "compress": "none"}).encode()
        p = tmp_path / "a.bin"
        p.write_bytes(b"CRFT" + len(header).to_bytes(8, "little") + header)
        with pytest.raises(CheckpointError, match="newer"):
            storage.read_array(p, ctx_v1())


# ------------------------------------------------------------------ integrity
class TestTruncationAndCorruption:
    @pytest.mark.parametrize("make_ctx", [ctx_v0, ctx_v1])
    def test_truncated_payload_is_explicit(self, tmp_path, rng, make_ctx):
        arr = rng.standard_normal((256,)).astype(np.float32)
        p = tmp_path / "a.bin"
        storage.write_array(p, arr, make_ctx(checksum="none"))
        raw = p.read_bytes()
        p.write_bytes(raw[: len(raw) - 40])   # short read at restore
        with pytest.raises(CheckpointError, match="truncated"):
            storage.read_array(p, make_ctx(checksum="none"))

    def test_truncated_header_is_explicit(self, tmp_path, rng):
        arr = rng.standard_normal((8,)).astype(np.float32)
        p = tmp_path / "a.bin"
        storage.write_array(p, arr, ctx_v1())
        p.write_bytes(p.read_bytes()[:7])
        with pytest.raises(CheckpointError, match="truncated header"):
            storage.read_array(p, ctx_v1())

    def test_chunk_corruption_detected(self, tmp_path, rng):
        arr = rng.integers(0, 255, 4096, dtype=np.uint8)
        p = tmp_path / "a.bin"
        storage.write_array(p, arr, ctx_v1(chunk_bytes=1024))
        raw = bytearray(p.read_bytes())
        raw[-100] ^= 0xFF                      # flip a bit in the last chunk
        p.write_bytes(bytes(raw))
        with pytest.raises(CheckpointError, match="checksum mismatch.*chunk"):
            storage.read_array(p, ctx_v1())

    def test_missing_file(self, tmp_path):
        with pytest.raises(CheckpointError, match="missing"):
            storage.read_array(tmp_path / "nope.bin", ctx_v1())


# ------------------------------------------------------------------ fanout
class TestFanoutPool:
    def test_parallel_encode_matches_serial(self, tmp_path, rng):
        arr = rng.standard_normal((1 << 18,)).astype(np.float32)  # 1 MiB
        serial, parallel = tmp_path / "s.bin", tmp_path / "p.bin"
        storage.write_array(serial, arr, ctx_v1(chunk_bytes=64 * 1024))
        pool = AsyncWriter(workers=4)
        try:
            storage.write_array(
                parallel, arr,
                ctx_v1(chunk_bytes=64 * 1024, fanout=pool.run_parallel))
        finally:
            pool.close()
        assert serial.read_bytes() == parallel.read_bytes()
        np.testing.assert_array_equal(storage.read_array(parallel, ctx_v1()), arr)

    def test_run_parallel_order_and_results(self):
        pool = AsyncWriter(workers=3)
        try:
            out = pool.run_parallel([lambda i=i: i * i for i in range(50)])
        finally:
            pool.close()
        assert out == [i * i for i in range(50)]

    def test_run_parallel_propagates_error(self):
        pool = AsyncWriter(workers=3)

        def boom():
            raise RuntimeError("disk on fire")

        try:
            with pytest.raises(RuntimeError, match="disk on fire"):
                pool.run_parallel([lambda: 1, boom, lambda: 2])
        finally:
            pool.close()

    def test_nested_fanout_no_deadlock(self):
        pool = AsyncWriter(workers=2)

        def outer(i):
            return sum(pool.run_parallel(
                [lambda j=j: i * 10 + j for j in range(4)]))

        try:
            out = pool.run_parallel([lambda i=i: outer(i) for i in range(6)])
        finally:
            pool.close()
        assert out == [sum(i * 10 + j for j in range(4)) for i in range(6)]

    def test_caller_participates_when_pool_busy(self):
        pool = AsyncWriter(workers=1)  # workers=1 → run_parallel goes inline
        seen = []
        try:
            pool.run_parallel([lambda i=i: seen.append(i) for i in range(5)])
        finally:
            pool.close()
        assert sorted(seen) == list(range(5))

    def test_ordered_lane_still_fifo(self, tmp_path):
        pool = AsyncWriter(workers=4)
        order = []
        lock = threading.Lock()

        def job(i):
            with lock:
                order.append(i)

        try:
            for i in range(20):
                pool.submit(lambda i=i: job(i))
            pool.wait()
        finally:
            pool.close()
        assert order == list(range(20))


# ------------------------------------------------------------------ zstd
class TestZstdCodec:
    """Compressed-chunk paths; run where zstandard is installed (CI)."""

    @pytest.fixture(autouse=True)
    def _need_zstd(self):
        pytest.importorskip("zstandard")

    @pytest.mark.parametrize("make_ctx", [ctx_v0, ctx_v1])
    def test_roundtrip(self, tmp_path, rng, make_ctx):
        arr = np.repeat(rng.standard_normal(64), 512).astype(np.float32)
        p = tmp_path / "a.bin"
        storage.write_array(p, arr, make_ctx(compress="zstd"))
        assert p.stat().st_size < arr.nbytes          # it actually compressed
        out = storage.read_array(p, make_ctx(compress="zstd"))
        np.testing.assert_array_equal(out, arr)

    def test_chunked_compressed_boundaries(self, tmp_path, rng):
        arr = rng.integers(0, 4, 100_000, dtype=np.uint8)
        p = tmp_path / "a.bin"
        storage.write_array(p, arr, ctx_v1(compress="zstd", chunk_bytes=16384))
        out = storage.read_array(p, ctx_v1())
        np.testing.assert_array_equal(out, arr)

    def test_corrupt_compressed_chunk_detected(self, tmp_path, rng):
        arr = rng.integers(0, 4, 50_000, dtype=np.uint8)
        p = tmp_path / "a.bin"
        storage.write_array(p, arr, ctx_v1(compress="zstd", chunk_bytes=16384))
        raw = bytearray(p.read_bytes())
        raw[-20] ^= 0xFF
        p.write_bytes(bytes(raw))
        with pytest.raises(CheckpointError, match="checksum|corrupt"):
            storage.read_array(p, ctx_v1())

    def test_corrupt_chunk_without_checksums_still_checkpoint_error(
            self, tmp_path, rng):
        """ZstdError must surface as CheckpointError so tier fallback works."""
        arr = rng.integers(0, 4, 50_000, dtype=np.uint8)
        p = tmp_path / "a.bin"
        storage.write_array(
            p, arr, ctx_v1(compress="zstd", chunk_bytes=16384, checksum="none"))
        raw = bytearray(p.read_bytes())
        raw[-20] ^= 0xFF
        p.write_bytes(bytes(raw))
        with pytest.raises(CheckpointError, match="corrupt"):
            storage.read_array(p, ctx_v1(checksum="none"))


# ------------------------------------------------------------------ manifest
class TestChecksumManifest:
    def test_manifest_persisted_and_collision_free(self, tmp_path, rng):
        from repro.core import Checkpoint
        from repro.core.env import CraftEnv
        env = CraftEnv.capture({"CRAFT_CP_PATH": str(tmp_path / "pfs"),
                                "CRAFT_USE_SCR": "0"})
        a, b = rng.standard_normal((8,)), rng.standard_normal((9,))
        cp = Checkpoint("mf", env=env)
        cp.add("a", a)
        cp.add("b", b)
        cp.commit()
        cp.update_and_write()
        cp.close()
        meta = storage.VersionStore(env.cp_path, "mf", sweep=False).meta()
        # both arrays' files appear, keyed by key-qualified relative path
        assert set(meta["checksums"]) == {"a/array.bin", "b/array.bin"}

    def test_missing_manifest_file_rejected(self, tmp_path, rng):
        from repro.core import Checkpoint
        from repro.core.env import CraftEnv
        env = CraftEnv.capture({"CRAFT_CP_PATH": str(tmp_path / "pfs"),
                                "CRAFT_USE_SCR": "0"})
        a, b = rng.standard_normal((8,)), rng.standard_normal((9,))
        cp = Checkpoint("mf", env=env)
        cp.add("a", a)
        cp.add("b", b)
        cp.commit()
        cp.update_and_write()
        cp.close()
        (env.cp_path / "mf" / "v-1" / "b" / "array.bin").unlink()
        cp2 = Checkpoint("mf", env=env)
        cp2.add("a", np.zeros(8))
        cp2.add("b", np.zeros(9))
        cp2.commit()
        with pytest.raises(CheckpointError, match="incomplete"):
            cp2.restart_if_needed()


# ------------------------------------------------------------------ tier ABC
class TestStorageTierInterface:
    def test_version_store_is_tier(self, tmp_path):
        vs = storage.VersionStore(tmp_path, "cp")
        assert isinstance(vs, StorageTier)

    def test_node_store_is_tier(self, tmp_path):
        from repro.core.env import CraftEnv
        from repro.core.node_level import NodeStore
        from repro.core.comm import NullComm
        env = CraftEnv.capture({"CRAFT_NODE_CP_PATH": str(tmp_path)})
        ns = NodeStore(base=tmp_path, name="cp", comm=NullComm(), env=env)
        assert isinstance(ns, StorageTier)

    def test_default_materialize(self, tmp_path):
        vs = storage.VersionStore(tmp_path, "cp")
        assert vs.materialize(3) is None
        staged = vs.stage(3)
        (staged / "f").write_text("x")
        vs.publish(staged, 3)
        assert vs.materialize(3) == vs.version_dir(3)
