"""Node-level checkpoint tier — the SCR analog (paper §2.4) on TPU hosts.

The paper reduces checkpoint overhead by writing frequent small checkpoints
to *node-local* storage and only occasionally to the parallel file system;
SCR adds redundancy so a single node failure does not lose the node-tier
data: *partner* (full copy on a neighbor) or *partner-XOR* (parity group).

TPU adaptation.  "Node-local" is the host-local SSD/ramdisk of each TPU host.
Here a node's storage is the directory ``<base>/node-<nid>/`` — in the test
and benchmark cluster all nodes share one filesystem, so cross-node reads
stand in for the RDMA/collective transfers a real fleet would use (the
*compute* of the XOR path is the Pallas ``xor_parity`` kernel either way).

Redundancy policies (``CRAFT_NODE_REDUNDANCY``):

  * ``LOCAL``   — no redundancy; a lost node forces a PFS restore.
  * ``PARTNER`` — the node leader mirrors the node's version directory onto
    the next node (paper: "recover restart data from the failed node's
    neighbor").
  * ``XOR``     — nodes form groups of ``CRAFT_XOR_GROUP_SIZE``; one member
    (rotating with the version number, RAID-5 style) stores the XOR parity
    of every member's payload; any single lost member is rebuilt from the
    parity + survivors (SCR's partner-XOR level).
  * ``RS``      — the same groups protected by an RS(k, m) erasure code
    (``CRAFT_RS_PARITY`` parity buffers, rotating placement): any ``m``
    simultaneously lost members rebuild bit-identically, and the parity
    manifest's per-member/per-row kernel digests let the background
    scrubber verify and repair rot (:mod:`repro.core.erasure`).

Restore goes through :meth:`NodeStore.materialize`, which transparently
rebuilds a missing local version from the partner mirror or the parity group
before handing the directory to ``Checkpoint``.

``NodeStore`` is a :class:`~repro.core.tiers.StorageTier`: the local store is
a plain :class:`~repro.core.storage.VersionStore`, and the mirror / parity
side-trees reuse the same atomic tmp→rename and retention helpers from
:mod:`repro.core.tiers` instead of re-implementing them.  XOR parity
manifests additionally record the kernel Fletcher digest of every member's
payload, so a reconstruction can tell a stale survivor from a valid one.
"""
from __future__ import annotations

import json
import shutil
import time as _time
from pathlib import Path
from typing import Dict, List, Optional

from repro.core import erasure, metrics, storage, tiers
from repro.core.cpbase import CheckpointError
from repro.core.tiers import StorageTier
from repro.kernels.xor_parity import ops as xor_ops


def _node_geometry(comm):
    ppn = max(1, comm.procs_per_node())
    n_nodes = (comm.size + ppn - 1) // ppn
    nid = comm.node_id()
    leader = comm.rank % ppn == 0
    return nid, n_nodes, leader


class NodeStore(StorageTier):
    """Node tier for one checkpoint name (the redundancy-protected tier).

    Tier-chain position (``CRAFT_TIER_CHAIN``): between the RAM tier
    (:class:`repro.core.mem_level.MemStore`, fastest, survives peer-rank
    loss via replicas) and the PFS tier (slowest, survives full-job loss) —
    reads drain mem → node → pfs, writes go through to every chained tier.
    """

    label = "node"

    def __init__(self, base: Path, name: str, comm, env):
        self.base = Path(base)
        self.name = name
        self.comm = comm
        self.env = env
        self.redundancy = env.node_redundancy
        self.group_size = max(1, env.xor_group_size)
        self.nid, self.n_nodes, self.is_leader = _node_geometry(comm)
        self._local = storage.VersionStore(
            self._node_dir(self.nid), name, keep_versions=env.keep_versions
        )

    # -- layout ---------------------------------------------------------------
    def _node_dir(self, nid: int) -> Path:
        return self.base / f"node-{nid}"

    def _mirror_root(self, owner_nid: int) -> Path:
        """Where ``owner_nid``'s partner mirror lives (on its neighbor node)."""
        holder = (owner_nid + 1) % self.n_nodes
        return self._node_dir(holder) / f"mirror-of-{owner_nid}" / self.name

    def _group(self, nid: int) -> List[int]:
        g0 = (nid // self.group_size) * self.group_size
        return [n for n in range(g0, min(g0 + self.group_size, self.n_nodes))]

    def _parity_holder(self, nid: int, version: int) -> int:
        grp = self._group(nid)
        return grp[version % len(grp)]

    def _parity_root(self, nid: int, version: int) -> Path:
        holder = self._parity_holder(nid, version)
        g0 = self._group(nid)[0]
        return self._node_dir(holder) / f"xor-group-{g0}" / self.name

    def _member_version_dir(self, member: int, version: int) -> Path:
        """Another node's v-<K> dir — path-only, no mkdir side effects."""
        return self._node_dir(member) / self.name / tiers.version_dir_name(version)

    def _peer_node_roots(self) -> List[Path]:
        """Other nodes' ``<base>/node-<nid>/<name>`` trees visible on the
        shared FS — the source of an elastic N→M restore's missing shards
        (the current geometry's node count doesn't bound the scan: a shrink
        must still see nodes past ``n_nodes``)."""
        roots = []
        for p in sorted(self.base.glob("node-*")):
            try:
                nid = int(p.name.split("-", 1)[1])
            except ValueError:
                continue
            if nid == self.nid:
                continue
            root = p / self.name
            if root.is_dir():
                roots.append(root)
        return roots

    # -- staging API (Checkpoint._write_to_store) ------------------------------
    def stage(self, version: int) -> Path:
        return self._local.stage(version)

    def abort(self, staged: Path) -> None:
        self._local.abort(staged)

    def publish(self, staged: Path, version: int, extra_meta: Optional[dict] = None) -> None:
        t0 = _time.perf_counter()
        self._chaos_check("publish", path=staged)
        self.comm.barrier()          # all ranks wrote their node-local files
        if self.is_leader:
            self._local.publish(staged, version, extra_meta)
        self.comm.barrier()          # every node's v-<K> is complete
        if self.is_leader:
            if self.redundancy == "PARTNER" and self.n_nodes > 1:
                self._chaos_check("replicate", path=staged)
                self._publish_partner(version)
            elif self.redundancy == "XOR":
                self._chaos_check("replicate", path=staged)
                self._publish_xor(version)
            elif self.redundancy == "RS":
                self._chaos_check("replicate", path=staged)
                erasure.publish_rs(self, version)
        self.comm.barrier()          # redundancy data in place
        metrics.observe("publish_seconds", _time.perf_counter() - t0,
                        tier="node")

    def _publish_partner(self, version: int) -> None:
        src = self._local.version_dir(version)
        root = self._mirror_root(self.nid)
        root.mkdir(parents=True, exist_ok=True)
        tmp = root / tiers.staging_dir_name(version)
        shutil.rmtree(tmp, ignore_errors=True)
        shutil.copytree(src, tmp)
        tiers.atomic_publish_dir(tmp, root / tiers.version_dir_name(version))
        tiers.retire_version_dirs(root, self.env.keep_versions)

    def _publish_xor(self, version: int) -> None:
        # The parity holder's leader computes the group parity.
        if self._parity_holder(self.nid, version) != self.nid:
            return
        group = self._group(self.nid)
        payloads: Dict[int, bytes] = {}
        manifest: Dict[str, dict] = {}
        for member in group:
            # same payload/manifest-entry definition as the RS path
            payloads[member], manifest[str(member)] = erasure.collect_member(
                self, member, version)
        parity = xor_ops.parity_of_buffers([payloads[m] for m in group])
        root = self._parity_root(self.nid, version)
        tmp = root / tiers.staging_dir_name(version)
        shutil.rmtree(tmp, ignore_errors=True)
        tmp.mkdir(parents=True)
        (tmp / "parity.bin").write_bytes(parity)
        storage.write_json(tmp / "manifest.json", manifest)
        tiers.atomic_publish_dir(tmp, root / tiers.version_dir_name(version))
        tiers.retire_version_dirs(root, self.env.keep_versions)

    # -- reading ----------------------------------------------------------------
    def meta(self) -> dict:
        """This node's local version metadata (manifest checks at restore)."""
        return self._local.meta()

    def latest_version(self) -> int:
        """Latest version recoverable *for this node* (local or via peers)."""
        best = self._local.latest_version()
        if self.redundancy == "PARTNER" and self.n_nodes > 1:
            for v, _ in tiers.list_version_dirs(self._mirror_root(self.nid)):
                best = max(best, v)
        elif self.redundancy == "XOR":
            # any version whose parity manifest exists is recoverable
            for holder in self._group(self.nid):
                g0 = self._group(self.nid)[0]
                root = self._node_dir(holder) / f"xor-group-{g0}" / self.name
                for v, p in tiers.list_version_dirs(root):
                    if (p / "manifest.json").exists():
                        best = max(best, v)
        elif self.redundancy == "RS":
            best = max(best, erasure.latest_rs_version(self))
        # Elastic N→M: a version any peer node holds is restorable here too —
        # either shard-by-shard through aux_read_dirs or by whole-tree copy
        for root in self._peer_node_roots():
            for v, p in tiers.list_version_dirs(root):
                if v > best and any(p.iterdir()):
                    best = max(best, v)
        return best

    def aux_read_dirs(self, version: int) -> List[Path]:
        """Peer nodes' ``v-<K>`` trees holding shards this node's ranks may
        need after a topology change (reads pull only overlapping chunk
        ranges out of them — see ``checkpointables._read_global_leaf``)."""
        out = []
        for root in self._peer_node_roots():
            d = root / tiers.version_dir_name(version)
            if d.is_dir():
                out.append(d)
        return out

    def version_dir(self, version: int) -> Path:
        return self._local.version_dir(version)

    def materialize(self, version: int) -> Optional[Path]:
        """Return a complete local v-<K> dir, recovering it if necessary."""
        vdir = self._local.version_dir(version)
        if self._complete(vdir):
            return vdir
        try:
            recovered = None
            if self.redundancy == "PARTNER" and self.n_nodes > 1:
                recovered = self._recover_partner(version)
            elif self.redundancy == "XOR":
                recovered = self._recover_xor(version)
            elif self.redundancy == "RS":
                recovered = erasure.recover_rs(self, version)
        except (OSError, CheckpointError, json.JSONDecodeError) as exc:
            raise CheckpointError(
                f"node-tier recovery of {self.name} v-{version} failed: {exc}"
            ) from exc
        if recovered is not None:
            return recovered
        # Elastic M>N: this node never wrote the version (it joined after the
        # topology change) — seed the local tree from any peer node's copy so
        # non-array files (pods, manifests) are present; array shards beyond
        # the copied node's are range-read via aux_read_dirs.
        return self._recover_from_peer(version)

    def _recover_from_peer(self, version: int) -> Optional[Path]:
        for root in self._peer_node_roots():
            src = root / tiers.version_dir_name(version)
            if src.is_dir() and any(src.iterdir()):
                dst = self._local.version_dir(version)
                shutil.rmtree(dst, ignore_errors=True)
                dst.parent.mkdir(parents=True, exist_ok=True)
                shutil.copytree(src, dst)
                return dst
        return None

    def _complete(self, vdir: Path) -> bool:
        return vdir.is_dir() and any(vdir.iterdir())

    def _recover_partner(self, version: int) -> Optional[Path]:
        src = self._mirror_root(self.nid) / tiers.version_dir_name(version)
        if not src.is_dir():
            return None
        dst = self._local.version_dir(version)
        shutil.rmtree(dst, ignore_errors=True)
        shutil.copytree(src, dst)
        return dst

    def _recover_xor(self, version: int) -> Optional[Path]:
        root = self._parity_root(self.nid, version)
        pdir = root / tiers.version_dir_name(version)
        if not (pdir / "manifest.json").exists():
            return None
        manifest = storage.read_json(pdir / "manifest.json")
        group = self._group(self.nid)
        my_entry = manifest.get(str(self.nid))
        if my_entry is None:
            return None
        survivors = []
        for member in group:
            if member == self.nid:
                continue
            # shared stale-survivor definition (erasure.read_member_payload):
            # XOR can rebuild exactly one member, so an unreadable/stale
            # survivor is fatal here, not merely "also lost" as under RS
            payload = erasure.read_member_payload(
                self, member, version, manifest[str(member)])
            if payload is None:
                raise CheckpointError(
                    f"survivor node {member} payload unreadable, short or "
                    "digest-mismatched (stale or corrupt survivor data)"
                )
            survivors.append(payload)
        parity = (pdir / "parity.bin").read_bytes()
        mine = xor_ops.reconstruct_member(parity, survivors, my_entry["size"])
        dst = self._local.version_dir(version)
        shutil.rmtree(dst, ignore_errors=True)
        offset = 0
        for ent in my_entry["files"]:
            out = dst / ent["rel"]
            out.parent.mkdir(parents=True, exist_ok=True)
            out.write_bytes(mine[offset : offset + ent["size"]])
            offset += ent["size"]
        return dst

    def invalidate_all(self) -> None:
        """Wipe this checkpoint from *every* node tree, not just our own.

        With elastic restores, peer trees are live restore sources
        (``aux_read_dirs`` / peer-copy recovery) — leaving a stale sibling
        behind after a nested-parent publish would let a topology change
        resurrect an invalidated child version.  The walk covers every
        ``node-*`` dir on the shared FS plus every mirror and parity tree
        that could name this checkpoint.
        """
        self._local.invalidate_all()
        for p in self.base.glob("node-*"):
            shutil.rmtree(p / self.name, ignore_errors=True)
            for mirror in p.glob("mirror-of-*"):
                shutil.rmtree(mirror / self.name, ignore_errors=True)
            for parity in p.glob("xor-group-*"):
                shutil.rmtree(parity / self.name, ignore_errors=True)
            for parity in p.glob("rs-group-*"):
                shutil.rmtree(parity / self.name, ignore_errors=True)
        if self.redundancy == "RS":
            erasure.invalidate_rs(self)

    # -- scrub hooks (core/scrubber.py) ---------------------------------------
    def forget_version(self, version: int) -> None:
        """Quarantine helper: drop the *local* copy of ``version`` so the
        next materialize() rebuilds it from the redundancy peers."""
        self._local.forget_version(version)

    def scrub_redundancy(self, version: int) -> dict:
        """Verify (and repair) this version's redundancy side-trees.

        RS parity shards carry manifest digests and are re-encoded in place
        when rotted (``erasure.scrub_rs``); the PARTNER mirror and XOR
        parity have no self-digest to check here — their staleness is
        caught at rebuild time against the member digests instead.
        """
        if self.redundancy == "RS":
            return erasure.scrub_rs(self, version)
        return {"bytes": 0, "checked": 0, "repaired": 0, "unrepairable": 0}
