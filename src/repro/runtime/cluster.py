"""Cluster — user-facing launcher for the fault-tolerant runtime.

    def work(comm):
        ...  # AFT zone body, Checkpoints, collectives
        return value

    cluster = Cluster(n_procs=8, procs_per_node=2, spare_nodes=2)
    cluster.start(work)
    cluster.kill(3)              # paper fault model: SIGKILL a process
    results = cluster.join()

The worker function must be a module-level (picklable) callable — workers
are spawned with the ``spawn`` start method so JAX state never crosses a
fork.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import signal
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.runtime.coordinator import Coordinator
from repro.runtime.worker import worker_entry


class Cluster:
    def __init__(
        self,
        n_procs: int,
        procs_per_node: int = 1,
        spare_nodes: int = 0,
        recovery_policy: str = "NON-SHRINKING",
        spawn_policy: str = "NO-REUSE",
        collective_deadline: Optional[float] = None,
        hb_timeout: Optional[float] = None,
        env_overrides: Optional[dict] = None,
    ):
        self.n_procs = n_procs
        self.ppn = max(1, procs_per_node)
        self.recovery_policy = recovery_policy.upper()
        self.env_overrides = dict(env_overrides or {})
        self.env_overrides.setdefault(
            "CRAFT_COMM_RECOVERY_POLICY", self.recovery_policy
        )
        self.env_overrides.setdefault(
            "CRAFT_COMM_SPAWN_POLICY", spawn_policy.upper()
        )
        self.coord = Coordinator(
            n_procs,
            procs_per_node=procs_per_node,
            spare_nodes=spare_nodes,
            spawn_policy=spawn_policy.upper(),
            collective_deadline=collective_deadline,
            hb_timeout=hb_timeout,
        )
        self.coord.set_spawner(self._spawn_replacement)
        self._ctx = mp.get_context("spawn")
        self._procs: Dict[int, List] = {}      # rank -> [(Process, eid), ...]
        self._fn: Optional[Callable] = None
        self._args: tuple = ()
        self._reaped: set = set()
        self._stop_reaper = threading.Event()

    # ------------------------------------------------------------------ start
    def start(self, fn: Callable, *args) -> None:
        self._fn = fn
        self._args = args
        for rank in range(self.n_procs):
            node = rank // self.ppn
            self._launch(rank, node, eid=0, replacement=False)
        threading.Thread(target=self._reaper, name="craft-reaper",
                         daemon=True).start()

    def _config(self) -> dict:
        return {
            "n_procs": self.n_procs,
            "recovery_policy": self.recovery_policy,
            "hb_interval": 0.2,
        }

    def _launch(self, rank: int, node: int, eid: int, replacement: bool) -> None:
        p = self._ctx.Process(
            target=worker_entry,
            args=(self.coord.address, rank, node, eid, replacement,
                  self._fn, self._args, self.env_overrides, self._config()),
            name=f"craft-worker-{rank}",
            daemon=True,
        )
        p.start()
        self._procs.setdefault(rank, []).append((p, eid))

    def _spawn_replacement(self, rank: int, node: int, eid: int) -> None:
        """Engine spawner callback (paper Table 3 phase ③)."""
        self._launch(rank, node, eid, replacement=True)

    # ------------------------------------------------------------------ faults
    def kill(self, rank: int) -> None:
        """SIGKILL the current incarnation of ``rank`` (pkill -9 analog)."""
        procs = self._procs.get(rank, [])
        for p, _eid in reversed(procs):
            if p.is_alive():
                os.kill(p.pid, signal.SIGKILL)
                return
        raise RuntimeError(f"no live process for rank {rank}")

    # ------------------------------------------------------------------ reaper
    def _reaper(self) -> None:
        """Launcher-level supervision (Borg/Pathways style): a worker that
        dies *before its first hello* has no coordinator connection to EOF,
        so only its parent can report the death.  Workers that did connect
        are handled by the connection-EOF path; the hello count per rank
        (coordinator ``_conn_gen``) tells the two cases apart."""
        while not self._stop_reaper.is_set():
            for rank, procs in list(self._procs.items()):
                for idx, (p, eid) in enumerate(procs):
                    key = (rank, idx)
                    if key in self._reaped or p.is_alive():
                        continue
                    self._reaped.add(key)
                    hellos = self.coord._conn_gen.get(rank, 0)
                    if hellos <= idx:     # died before ever connecting
                        self.coord.engine.mark_rank_dead(eid, rank)
            self._stop_reaper.wait(0.1)

    def kill_node(self, node: int) -> List[int]:
        """SIGKILL every live rank currently placed on ``node``."""
        eids = sorted(self.coord.engine._epochs)
        members = self.coord.engine.current_members(eids[-1])
        ranks = [r for r, n in members.items() if n == node]
        killed = []
        for r in ranks:
            try:
                self.kill(r)
                killed.append(r)
            except RuntimeError:
                pass
        return killed

    # ------------------------------------------------------------------ join
    def join(self, timeout: float = 300.0) -> Dict[int, object]:
        """Wait for every live worker to exit; returns {rank: result}."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            alive = [
                p for procs in self._procs.values()
                for p, _eid in procs if p.is_alive()
            ]
            if not alive:
                break
            time.sleep(0.05)
        else:
            raise TimeoutError(
                f"cluster did not drain: {[p.name for p in alive]}"
            )
        if self.coord.worker_errors:
            raise RuntimeError(
                "worker errors:\n" + "\n\n".join(self.coord.worker_errors)
            )
        return dict(self.coord.results)

    def shutdown(self) -> None:
        self._stop_reaper.set()
        for procs in self._procs.values():
            for p, _eid in procs:
                if p.is_alive():
                    p.terminate()
        for procs in self._procs.values():
            for p, _eid in procs:
                p.join(timeout=5)
        self.coord.close()

    def __enter__(self) -> "Cluster":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
