"""Node-tier checkpointing: partner and XOR recovery (the SCR analog)."""
import shutil
from pathlib import Path

import numpy as np
import pytest

from repro.core import Box, Checkpoint
from repro.core.env import CraftEnv


class FakeComm:
    """Single-process stand-in: rank r of n, one rank per node."""

    def __init__(self, rank, size):
        self._rank, self._size = rank, size

    @property
    def rank(self):
        return self._rank

    @property
    def size(self):
        return self._size

    def node_id(self):
        return self._rank

    def procs_per_node(self):
        return 1

    def barrier(self, channel="main"):
        pass

    def allreduce(self, v, op="sum", channel="main"):
        return v

    def allreduce_min(self, v):
        return v

    def bcast(self, v, root=0, channel="main"):
        return v


def _env(tmp_path, redundancy, group=4):
    return CraftEnv.capture({
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_NODE_CP_PATH": str(tmp_path / "node"),
        "CRAFT_NODE_REDUNDANCY": redundancy,
        "CRAFT_XOR_GROUP_SIZE": str(group),
        "CRAFT_PFS_EVERY": "100",     # node tier only (forces redundancy path)
    })


def write_all_ranks(tmp_path, redundancy, n_nodes, value_of, group=4):
    env = _env(tmp_path, redundancy, group)
    for rank in range(n_nodes):
        b = Box(np.full((32,), value_of(rank)))
        cp = Checkpoint("st", FakeComm(rank, n_nodes), env=env)
        cp.add("arr", b.value)
        cp.commit()
        cp.update_and_write()
    return env


def read_rank(tmp_path, redundancy, rank, n_nodes, group=4):
    env = _env(tmp_path, redundancy, group)
    arr = np.zeros((32,))
    cp = Checkpoint("st", FakeComm(rank, n_nodes), env=env)
    cp.add("arr", arr)
    cp.commit()
    assert cp.restart_if_needed()
    return arr


@pytest.mark.parametrize("redundancy", ["LOCAL", "PARTNER", "XOR"])
def test_node_tier_roundtrip(tmp_path, redundancy):
    write_all_ranks(tmp_path, redundancy, 4, lambda r: float(r + 1))
    for rank in range(4):
        arr = read_rank(tmp_path, redundancy, rank, 4)
        assert np.all(arr == rank + 1)


def test_partner_recovers_lost_node(tmp_path):
    write_all_ranks(tmp_path, "PARTNER", 4, lambda r: float(10 * (r + 1)))
    # node 2's local storage is wiped (node failure / replacement host)
    shutil.rmtree(tmp_path / "node" / "node-2")
    arr = read_rank(tmp_path, "PARTNER", 2, 4)
    assert np.all(arr == 30.0)   # rebuilt from node 3's mirror


def test_xor_recovers_lost_node(tmp_path):
    write_all_ranks(tmp_path, "XOR", 4, lambda r: float(r + 7))
    shutil.rmtree(tmp_path / "node" / "node-1" / "st")  # lose node 1's data
    arr = read_rank(tmp_path, "XOR", 1, 4)
    assert np.all(arr == 8.0)    # rebuilt from parity + survivors


def test_xor_two_losses_in_group_fail_over_to_pfs(tmp_path):
    env = CraftEnv.capture({
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_NODE_CP_PATH": str(tmp_path / "node"),
        "CRAFT_NODE_REDUNDANCY": "XOR",
        "CRAFT_XOR_GROUP_SIZE": "4",
        "CRAFT_PFS_EVERY": "1",       # PFS copy exists as the outer tier
    })
    for rank in range(4):
        cp = Checkpoint("st", FakeComm(rank, 4), env=env)
        cp.add("arr", np.full((8,), float(rank)))
        cp.commit()
        cp.update_and_write()
    # two members of the same parity group lost — XOR cannot rebuild,
    # but the PFS tier can
    shutil.rmtree(tmp_path / "node" / "node-0" / "st")
    shutil.rmtree(tmp_path / "node" / "node-1" / "st")
    arr = np.zeros((8,))
    cp = Checkpoint("st", FakeComm(0, 4), env=env)
    cp.add("arr", arr)
    cp.commit()
    assert cp.restart_if_needed()
    assert np.all(arr == 0.0)


def test_partner_double_bad_falls_through_to_pfs(tmp_path):
    """Local copy AND partner mirror both digest-mismatched: materialize's
    candidates are all rotten, so the restore must come from the PFS tier —
    stale bytes are never served."""
    from repro.core.scrubber import corrupt_file

    env = CraftEnv.capture({
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_NODE_CP_PATH": str(tmp_path / "node"),
        "CRAFT_NODE_REDUNDANCY": "PARTNER",
        "CRAFT_PFS_EVERY": "1",            # a PFS copy exists as the outer tier
    })
    for rank in range(4):
        cp = Checkpoint("st", FakeComm(rank, 4), env=env)
        cp.add("arr", np.full((32,), 30.0))
        cp.commit()
        cp.update_and_write()
    corrupt_file(tmp_path / "node" / "node-2" / "st" / "v-1"
                 / "arr" / "array.bin")
    corrupt_file(tmp_path / "node" / "node-3" / "mirror-of-2" / "st"
                 / "v-1" / "arr" / "array.bin")
    arr = read_rank(tmp_path, "PARTNER", 2, 4)
    assert np.all(arr == 30.0)


def test_partner_double_bad_raises_without_pfs(tmp_path):
    """Same double-bad state with no deeper tier: the restore must raise
    CheckpointError (and leave the target untouched), never serve the stale
    digest-mismatched bytes."""
    from repro.core.cpbase import CheckpointError
    from repro.core.scrubber import corrupt_file

    write_all_ranks(tmp_path, "PARTNER", 4, lambda r: float(10 * (r + 1)))
    corrupt_file(tmp_path / "node" / "node-2" / "st" / "v-1"
                 / "arr" / "array.bin")
    corrupt_file(tmp_path / "node" / "node-3" / "mirror-of-2" / "st"
                 / "v-1" / "arr" / "array.bin")
    env = _env(tmp_path, "PARTNER")
    arr = np.zeros((32,))
    cp = Checkpoint("st", FakeComm(2, 4), env=env)
    cp.add("arr", arr)
    cp.commit()
    with pytest.raises(CheckpointError):
        cp.restart_if_needed()
    assert np.all(arr == 0.0)


def test_disable_node_level(tmp_path):
    env = _env(tmp_path, "PARTNER")
    cp = Checkpoint("nolocal", FakeComm(0, 2), env=env)
    cp.add("x", Box(5))
    cp.disable_node_level()
    cp.commit()
    cp.update_and_write()
    assert not (tmp_path / "node" / "node-0" / "nolocal").exists()


def test_pfs_every_gating(tmp_path):
    env = CraftEnv.capture({
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_NODE_CP_PATH": str(tmp_path / "node"),
        "CRAFT_NODE_REDUNDANCY": "LOCAL",
        "CRAFT_PFS_EVERY": "3",
    })
    b = Box(0)
    cp = Checkpoint("gate", FakeComm(0, 1), env=env)
    cp.add("x", b)
    cp.commit()
    for i in range(1, 7):
        b.value = i
        cp.update_and_write()
    assert cp.stats["node_writes"] == 6
    assert cp.stats["pfs_writes"] == 2      # versions 3 and 6 only
    pfs_versions = sorted(
        p.name for p in (tmp_path / "pfs" / "gate").glob("v-*"))
    assert "v-6" in pfs_versions
