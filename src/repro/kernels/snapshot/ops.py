"""Public snapshot ops: fused per-chunk metadata with backend dispatch, plus
the host-side helpers that turn raw nibble histograms into compressibility
estimates (the zstd-vs-raw gate, ``CRAFT_ZSTD_GATE_BITS``)."""
from __future__ import annotations

from typing import Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.snapshot.kernel import snapshot as snapshot_pallas
from repro.kernels.snapshot.ref import HIST_BINS, META_COLS, snapshot_ref

_LANES = 128

_ref_jit = jax.jit(snapshot_ref, static_argnames=("with_hist",))


def _block_rows_for(rows: int) -> int:
    """Largest power-of-two tile height <= 512 that divides ``rows``."""
    for br in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if rows % br == 0:
            return br
    return 1


def snapshot_chunks(
    words2: jnp.ndarray, prev_digests: jnp.ndarray, *,
    with_hist: bool = True, use_pallas: bool = None, interpret: bool = False,
) -> jnp.ndarray:
    """Fused per-chunk ``[s1, s2, dirty, hist…]`` of a (n_chunks, wpc) uint32
    matrix — Pallas on TPU when the word grid is lane-aligned, the jitted
    oracle otherwise.  The result stays on device; callers slice the digest
    columns off as the next snapshot's ``prev_digests`` without a transfer.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    wpc = words2.shape[1]
    if use_pallas and wpc and wpc % _LANES == 0:
        return snapshot_pallas(
            words2, prev_digests, block_rows=_block_rows_for(wpc // _LANES),
            with_hist=with_hist, interpret=interpret)
    return _ref_jit(words2, prev_digests, with_hist=with_hist)


_weights_cache: dict = {}


def _word_weights(wpc: int) -> np.ndarray:
    w = _weights_cache.get(wpc)
    if w is None:
        w = _weights_cache[wpc] = np.arange(1, wpc + 1, dtype=np.uint32)
    return w


def snapshot_host(host_bytes: np.ndarray, chunk_bytes: int,
                  prev_digests: np.ndarray) -> np.ndarray:
    """Numpy snapshot pass: per-chunk ``[s1, s2, dirty]`` of a flat uint8
    buffer over the storage chunk grid (no histogram — the zstd gate falls
    back to per-dirty-chunk host counts, which is cheaper than histogramming
    every chunk here).  This is the CPU-backend twin of the fused kernel,
    mirroring the checksum ops' numpy-on-CPU dispatch; it reads the buffer
    in place (no packing copy), so on CPU the whole snapshot costs one
    digest pass over a zero-copy view."""
    nbytes = host_bytes.size
    if nbytes % 4:
        raise ValueError(f"snapshot_host needs 4-byte-aligned size, "
                         f"got {nbytes}")
    words = host_bytes.view(np.uint32)
    wpc = chunk_bytes // 4
    n_chunks = max(1, -(-nbytes // chunk_bytes))
    full = words.size // wpc          # complete chunks; the rest is tail
    out = np.zeros((n_chunks, 3), dtype=np.uint32)
    if full:
        body = words[:full * wpc].reshape(full, wpc)
        # NB: broadcasting the 1-D weights row directly is ~2x faster than a
        # (1, wpc)-shaped operand here — numpy's inner-loop stride handling
        # is better when the broadcast axis is implicit.
        with np.errstate(over="ignore"):
            out[:full, 0] = body.sum(axis=1, dtype=np.uint32)
            out[:full, 1] = (body * _word_weights(wpc)).sum(
                axis=1, dtype=np.uint32)
    tail = words[full * wpc:]
    if tail.size:        # zero-padding is digest-neutral, so weigh as-is
        with np.errstate(over="ignore"):
            out[-1, 0] = tail.sum(dtype=np.uint32)
            out[-1, 1] = (tail * _word_weights(wpc)[:tail.size]).sum(
                dtype=np.uint32)
    out[:, 2] = (out[:, :2] != prev_digests).any(axis=1)
    return out


def chunk_entropy_bits(hist: np.ndarray) -> np.ndarray:
    """Per-chunk order-0 entropy estimate in bits/byte from (n, 16) nibble
    histograms (each byte contributes its high and its low nibble, so a
    chunk's counts sum to ``2 * chunk_len``).  An upper byte entropy of 8
    bits means incompressible-looking data; long-range structure is invisible
    to an order-0 estimate, which is why the gate threshold must sit close
    to 8 (see ``CRAFT_ZSTD_GATE_BITS``)."""
    h = np.asarray(hist, dtype=np.float64)
    tot = h.sum(axis=1, keepdims=True)
    p = np.divide(h, tot, out=np.zeros_like(h), where=tot > 0)
    logp = np.log2(p, out=np.zeros_like(p), where=p > 0)
    return -2.0 * (p * logp).sum(axis=1)


def host_nibble_hist(buf: Union[bytes, bytearray, memoryview, np.ndarray]
                     ) -> np.ndarray:
    """(16,) nibble histogram of a byte buffer — the host fallback of the
    kernel's histogram columns, for gating chunks that never saw a device."""
    a = (np.frombuffer(buf, dtype=np.uint8)
         if isinstance(buf, (bytes, bytearray, memoryview))
         else np.ascontiguousarray(buf).view(np.uint8).ravel())
    if a.size == 0:
        return np.zeros(HIST_BINS, dtype=np.int64)
    return (np.bincount(a >> 4, minlength=HIST_BINS)
            + np.bincount(a & 0xF, minlength=HIST_BINS)).astype(np.int64)
