"""phi4-mini-3.8b — dense decoder, RoPE + SwiGLU + GQA.

[arXiv:2412.08905; hf]  32L d_model=3072 24H (kv=8) d_ff=8192
vocab=200064; tied embeddings.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, vocab=200064,
    attn_type="gqa", n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192,
    tie_embeddings=True,
)

TINY = CONFIG.replace(
    n_layers=2, d_model=64, vocab=512, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128,
)
