"""Benchmark harness — one benchmark per paper table/figure (DESIGN.md §6).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Emits ``bench,name,value,unit[,tags]`` CSV rows:

    table3_recovery_breakdown   paper Table 3 — recovery phase times
    fig5_recovery_scaling       paper Fig. 5 — recovery vs #procs, 3 policies
    fig6_procs_per_node         paper Fig. 6 — recovery vs procs/node
    fig7_spawn_merge            paper Fig. 7 — spawn+merge scaling
    table4_cr_overhead          paper Table 4 — none/sync/async/node CP
    fig8_failure_scenarios      paper Fig. 8 — OH_cp / OH_rec / OH_redo
    roofline                    §Roofline terms per dry-run cell
    kernel_*                    kernel micro-benchmarks
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    cr_overhead, kernel_bench, lanczos_aft, recovery_scaling,
    roofline_report, spawn_merge,
)
from benchmarks.common import emit, header

BENCHES = [
    ("recovery_scaling", recovery_scaling.main),
    ("spawn_merge", spawn_merge.main),
    ("cr_overhead", cr_overhead.main),
    ("lanczos_aft", lanczos_aft.main),
    ("roofline_report", roofline_report.main),
    ("kernel_bench", kernel_bench.main),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slower)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None,
                    help="dump every emitted record as a JSON artifact")
    args = ap.parse_args()
    header()
    failed = 0
    for name, fn in BENCHES:
        if args.only and args.only not in name:
            continue
        try:
            fn(full=args.full)
            emit("harness", f"{name}_status", "ok", "")
        except Exception:
            failed += 1
            emit("harness", f"{name}_status", "FAILED", "")
            traceback.print_exc()
    if args.json:
        from benchmarks.common import dump_json

        dump_json(args.json)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
