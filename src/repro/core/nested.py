"""Nested / multi-level checkpoint consistency (paper §2.5, Table 1, Fig. 3).

Restarting every nested level from its *latest* version can be inconsistent:
after the parent checkpoint CL1-v1 is written, the inner loop restarts from 0,
so the child's CL2-v30 (written during the *previous* outer iteration) must
not be read.  CRAFT solves this by *invalidating* all child checkpoints as
soon as the parent checkpoint is fully written — the ``subCP()`` relationship.

This module is the registry of those parent→child edges plus the invalidation
walk.  It is deliberately free of storage details: a "child" only needs an
``invalidate()`` method (``Checkpoint`` provides it).
"""
from __future__ import annotations

import threading
import weakref
from typing import List


class NestedRegistry:
    """Parent→children edges between checkpoints (weakly referenced)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._children: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()

    def link(self, parent, child) -> None:
        """Declare ``child`` nested inside ``parent`` (paper ``subCP()``)."""
        if parent is child:
            raise ValueError("a checkpoint cannot be its own sub-checkpoint")
        with self._lock:
            kids = self._children.setdefault(parent, weakref.WeakSet())
            # cycle guard: walking up from parent must never reach child
            if self._reaches(child, parent):
                raise ValueError(
                    f"subCP cycle: {getattr(child, 'name', child)!r} is already "
                    f"an ancestor of {getattr(parent, 'name', parent)!r}"
                )
            kids.add(child)

    def _reaches(self, src, dst) -> bool:
        stack = [src]
        seen = set()
        while stack:
            node = stack.pop()
            if node is dst:
                return True
            if id(node) in seen:
                continue
            seen.add(id(node))
            stack.extend(self._children.get(node, ()))
        return False

    def children(self, parent) -> List:
        with self._lock:
            return list(self._children.get(parent, ()))

    def invalidate_children(self, parent) -> None:
        """After ``parent`` published a version, wipe all descendants.

        Paper Table 1: once CL1-v1 exists, the stale CL2 versions from the
        previous outer iteration must never be restored.

        The walk completes even when one child's storage fails mid-wipe
        (first error re-raised afterwards): with elastic restores, peer node
        trees are live restore sources, so stopping early would leave a
        sibling's stale version reachable across a topology change.
        """
        stack = self.children(parent)
        seen = set()
        first_exc = None
        while stack:
            child = stack.pop()
            if id(child) in seen:
                continue
            seen.add(id(child))
            try:
                child.invalidate()
            except Exception as exc:
                if first_exc is None:
                    first_exc = exc
            stack.extend(self.children(child))
        if first_exc is not None:
            raise first_exc


#: process-global registry used by Checkpoint.sub_cp()
GLOBAL_REGISTRY = NestedRegistry()
