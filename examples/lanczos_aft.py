"""The paper's showcase (§5/§6): a Lanczos eigensolver on an on-the-fly
graphene Hamiltonian, with CRAFT checkpoint/restart AND automatic fault
tolerance.

Three modes:

    PYTHONPATH=src python examples/lanczos_aft.py                # plain CR
    PYTHONPATH=src python examples/lanczos_aft.py --fail-at 45   # crash+rerun
    PYTHONPATH=src python examples/lanczos_aft.py --aft          # AFT zone:
        2 simulated ranks, rank 0 fail-stops mid-run, the zone repairs the
        communicator (non-shrinking spawn) and the restarted body resumes
        from the latest checkpoint — paper Fig. 8's scenario.
"""
import argparse

from repro.apps.lanczos import GrapheneConfig, run_lanczos
from repro.core.env import CraftEnv


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nx", type=int, default=128)
    ap.add_argument("--iters", type=int, default=100)
    ap.add_argument("--cp-freq", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--aft", action="store_true")
    ap.add_argument("--cp-dir", default="craft-lanczos")
    args = ap.parse_args()

    cfg = GrapheneConfig(nx=args.nx, ny=args.nx, disorder=0.3)
    env = CraftEnv.capture({
        "CRAFT_CP_PATH": args.cp_dir, "CRAFT_USE_SCR": "0",
        "CRAFT_COMM_RECOVERY_POLICY": "NON-SHRINKING"})

    if args.aft:
        from repro.core.aft import aft_zone
        from repro.core.comm import ProcFailedError
        from repro.core.comm_sim import SimWorld

        world = SimWorld(2, spare_nodes=1, env=env)
        fired = {}

        def worker(comm):
            def body(c):
                def fail_hook(it):
                    if it == args.iters // 2 and c.rank == 0 \
                            and not fired.get("x"):
                        fired["x"] = True
                        print(f"  !! injecting rank-{c.rank} failure at "
                              f"iteration {it}")
                        raise ProcFailedError("injected", failed=[c.rank])

                from benchmarks.lanczos_aft import _run_with_hook
                return _run_with_hook(cfg, args.iters, args.cp_freq, c, env,
                                      fail_hook)

            return aft_zone(c, body, env=env)

        import sys
        sys.path.insert(0, ".")
        results = world.run(worker, timeout=900)
        for tok, r in results.items():
            print(f"  member {tok}: eig={r['eig']:.6f} "
                  f"wall={r['wall_s']:.2f}s resumed_from={r['resumed_from']}")
        return

    res = run_lanczos(cfg, n_iter=args.iters, cp_freq=args.cp_freq,
                      env=env, fail_at=args.fail_at)
    print(f"min eigenvalue ≈ {res.eigenvalue:.6f} "
          f"({res.iterations} iterations, {res.wall_s:.2f}s, "
          f"restarted_at={res.restarted_at})")
    if res.cp_stats:
        print(f"checkpoint stats: {res.cp_stats}")


if __name__ == "__main__":
    main()
