"""Shared layer primitives: RMSNorm, RoPE, SwiGLU MLP, embedding."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- helpers
def dense_init(key, shape, in_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(max(1, in_dim))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * weight.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- RoPE
def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding.  x: (..., L, D even); positions: (L,) or (B, L)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                              # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv    # (..., L, D/2)
    # broadcast angle to x's rank: x is (B, H, L, D); ang (L, D/2) or (B, L, D/2)
    while ang.ndim < x.ndim:
        ang = ang[None]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.stack([xf1 * cos - xf2 * sin, xf1 * sin + xf2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------- embedding
def embed_init(key, cfg):
    return {"embedding": dense_init(key, (cfg.vocab, cfg.d_model),
                                    cfg.d_model, cfg.dtype)}


def embed_logical(cfg):
    return {"embedding": ("vocab", "embed")}


def embed_apply(params, tokens: jnp.ndarray) -> jnp.ndarray:
    return params["embedding"][tokens]


def unembed_apply(params, x: jnp.ndarray, fp32: bool = True) -> jnp.ndarray:
    w = params["embedding"]
    logits = jnp.einsum("bld,vd->blv", x, w)
    return logits.astype(jnp.float32) if fp32 else logits


# ---------------------------------------------------------------- SwiGLU MLP
def mlp_init(key, cfg, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, f), d, cfg.dtype),
        "w_up": dense_init(k2, (d, f), d, cfg.dtype),
        "w_down": dense_init(k3, (f, d), f, cfg.dtype),
    }


def mlp_logical(cfg):
    return {
        "w_gate": ("embed", "mlp"),
        "w_up": ("embed", "mlp"),
        "w_down": ("mlp", "embed"),
    }


def mlp_apply(params, x: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("bld,df->blf", x, params["w_gate"])
    u = jnp.einsum("bld,df->blf", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("blf,fd->bld", h, params["w_down"])
