"""Tier health: retries, circuit breakers, and write deadlines.

Three small primitives that turn "an OSError anywhere aborts the job" into
the degraded-mode story ``Checkpoint`` implements on top:

* :func:`retry_call` — bounded retry with exponential backoff + jitter for
  *transient* OS errors (``EIO``/``EAGAIN``/``EINTR``/``ETIMEDOUT``).
  Persistent faults (``EROFS``, ``ENOSPC``) are not retried here — they
  need a different response (breaker trip / emergency retire), decided by
  the caller.
* :class:`CircuitBreaker` / :class:`TierHealth` — per-tier
  CLOSED → OPEN → HALF_OPEN state.  After ``threshold`` consecutive
  failures the tier is tripped (OPEN): `Checkpoint` stops writing to it
  and routes its payload to the next chain level.  After ``cooldown_s``
  the breaker admits exactly one probe (HALF_OPEN, driven from the
  scrubber's idle windows); a successful probe re-closes it, a failed one
  re-opens it for another cooldown.
* :func:`call_with_deadline` — run a write on a helper thread and abandon
  it (``WriteDeadlineExceeded``) if it exceeds ``CRAFT_IO_DEADLINE_S``, so
  a hung tier wedges neither the AsyncWriter sequencer nor a sync commit.
  The abandoned thread is daemonized; a chaos ``hang`` parks it on an
  event the engine releases at close.
"""
from __future__ import annotations

import errno
import random
import threading
import time
from typing import Callable, Optional

from repro.core import metrics
from repro.core.cpbase import CheckpointError

#: errno values treated as transient (worth retrying in place).
TRANSIENT_ERRNOS = frozenset(
    {errno.EIO, errno.EAGAIN, errno.EINTR, errno.ETIMEDOUT})

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


class WriteDeadlineExceeded(CheckpointError):
    """A tier write exceeded ``CRAFT_IO_DEADLINE_S`` and was abandoned."""


def is_transient(exc: BaseException) -> bool:
    return isinstance(exc, OSError) and exc.errno in TRANSIENT_ERRNOS


def retry_call(fn: Callable, retries: int, backoff_ms: float,
               on_retry: Optional[Callable[[], None]] = None,
               sleep=time.sleep):
    """Call ``fn()``; on a transient OSError retry up to ``retries`` times.

    Delay before attempt *k* (1-based retry) is
    ``backoff_ms * 2**(k-1) * uniform(0.5, 1.5)`` — exponential with
    jitter, so a fleet of ranks hammering a recovering filesystem doesn't
    retry in lockstep.  Non-transient errors propagate immediately.
    """
    attempt = 0
    while True:
        try:
            return fn()
        except OSError as exc:
            if attempt >= retries or not is_transient(exc):
                raise
            attempt += 1
            if on_retry is not None:
                on_retry()
            delay = (backoff_ms / 1000.0) * (2 ** (attempt - 1))
            delay *= 0.5 + random.random()
            if delay > 0:
                sleep(delay)


class CircuitBreaker:
    """CLOSED → OPEN (after ``threshold`` consecutive failures) →
    HALF_OPEN (one probe after ``cooldown_s``) → CLOSED/OPEN.

    Thread-safe; ``clock`` is injectable so tests and `Checkpoint`'s
    virtual clock drive cooldowns deterministically.
    """

    def __init__(self, threshold: int = 3, cooldown_s: float = 30.0,
                 clock=time.monotonic):
        self.threshold = max(1, int(threshold))
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._lock = threading.Lock()
        self.state = CLOSED
        self.failures = 0
        self.trips = 0
        self._opened_at = 0.0
        self._probing = False

    def allow(self) -> bool:
        """May the caller attempt an operation on this tier right now?

        OPEN past its cooldown transitions to HALF_OPEN and admits exactly
        one caller (the probe); everyone else is refused until the probe
        reports back.
        """
        with self._lock:
            if self.state == CLOSED:
                return True
            if self.state == OPEN:
                if self._clock() - self._opened_at >= self.cooldown_s:
                    self.state = HALF_OPEN
                    self._probing = True
                    return True
                return False
            # HALF_OPEN: only the single in-flight probe is admitted
            if not self._probing:
                self._probing = True
                return True
            return False

    def probe_due(self) -> bool:
        """True when a half-open probe should be attempted (no side effects
        beyond the OPEN→HALF_OPEN cooldown check)."""
        with self._lock:
            if self.state == OPEN:
                return self._clock() - self._opened_at >= self.cooldown_s
            return self.state == HALF_OPEN and not self._probing

    def record_success(self) -> None:
        with self._lock:
            self.state = CLOSED
            self.failures = 0
            self._probing = False

    def record_failure(self) -> bool:
        """Record one failure; returns True when this call *trips* the
        breaker (CLOSED→OPEN or a failed half-open probe re-opening it)."""
        with self._lock:
            self.failures += 1
            self._probing = False
            if self.state == HALF_OPEN or (
                    self.state == CLOSED and self.failures >= self.threshold):
                self.state = OPEN
                self._opened_at = self._clock()
                self.trips += 1
                return True
            if self.state == OPEN:
                self._opened_at = self._clock()
            return False


class TierHealth:
    """One tier's breaker plus bookkeeping `Checkpoint` reads for stats."""

    def __init__(self, slot: str, threshold: int = 3,
                 cooldown_s: float = 30.0, clock=time.monotonic):
        self.slot = slot
        self.breaker = CircuitBreaker(threshold, cooldown_s, clock=clock)
        self.last_error: Optional[str] = None

    #: breaker state as a scrapable level (worst-case-wins across ranks)
    _STATE_CODE = {CLOSED: 0.0, HALF_OPEN: 1.0, OPEN: 2.0}

    def _publish_state(self) -> None:
        metrics.set_gauge("breaker_state",
                          self._STATE_CODE.get(self.breaker.state, -1.0),
                          slot=self.slot)

    def allow(self) -> bool:
        ok = self.breaker.allow()   # may transition OPEN → HALF_OPEN
        self._publish_state()
        return ok

    def probe_due(self) -> bool:
        return self.breaker.probe_due()

    def record_success(self) -> None:
        self.last_error = None
        self.breaker.record_success()
        self._publish_state()

    def record_failure(self, exc: BaseException) -> bool:
        self.last_error = f"{type(exc).__name__}: {exc}"
        tripped = self.breaker.record_failure()
        if tripped:
            metrics.inc("breaker_trips", slot=self.slot)
        self._publish_state()
        return tripped

    @property
    def state(self) -> str:
        return self.breaker.state


def call_with_deadline(fn: Callable, seconds: float, name: str = "io"):
    """Run ``fn()`` with a wall-clock deadline.

    ``seconds <= 0`` calls inline (deadline disabled).  Otherwise ``fn``
    runs on a daemon helper thread; if it does not finish in time,
    :class:`WriteDeadlineExceeded` is raised and the thread is abandoned —
    the caller must treat the write as failed (abort staging, never
    publish).  The helper's own exception, if any, is re-raised in the
    caller.
    """
    if seconds <= 0:
        return fn()
    box: dict = {}
    done = threading.Event()

    def _run():
        try:
            box["result"] = fn()
        except BaseException as exc:  # noqa: BLE001 - relayed to caller
            box["error"] = exc
        finally:
            done.set()

    worker = threading.Thread(target=_run, name=f"deadline-{name}",
                              daemon=True)
    worker.start()
    if not done.wait(timeout=seconds):
        raise WriteDeadlineExceeded(
            f"write deadline ({seconds:g}s) exceeded: {name}")
    if "error" in box:
        raise box["error"]
    return box.get("result")
