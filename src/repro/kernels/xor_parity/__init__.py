from repro.kernels.xor_parity.ops import (  # noqa: F401
    parity_of_buffers,
    reconstruct_member,
    xor_reduce,
)
