"""Versioned, atomic checkpoint storage (paper §2.6) + the array codec.

Directory layout (paper Fig. 4):

    <base>/<cpName>/
        meta.json            -- latest complete version, history, checksums
        v-<K>/               -- one directory per checkpoint version
            <key>/...        -- one subdirectory per checkpointable object

Atomicity protocol: a version is staged in ``.tmp-v-<K>/``, every file is
fsync'd, the directory is atomically renamed to ``v-<K>``, and only then is
``meta.json`` updated (itself via tmp+rename).  A crash at any point leaves
either the previous complete version or a garbage ``.tmp-*`` dir that is swept
on the next run — never a torn checkpoint.  The shared directory mechanics
live in :mod:`repro.core.tiers`; :class:`VersionStore` is the concrete
:class:`~repro.core.tiers.StorageTier` used for the PFS path and as the local
store of the node tier.

On-disk array format (one ``.bin`` file per array / shard)
----------------------------------------------------------

Every file starts ``CRFT`` + u64(header_len) + JSON header.  The header's
``fmt`` field selects the codec:

* **v0 (legacy, fmt absent)** — monolithic: u64 crc32 digest, then the whole
  payload (optionally zstd-compressed) as one blob.  Still readable; written
  only when ``IOContext.codec_version == 0``.
* **v1 (chunked, fmt=1)** — the payload is split into fixed-size chunks
  (default 4 MiB, ``CRAFT_CHUNK_BYTES``).  Each chunk is independently
  compressed (zstd, when available and enabled) and digested with the blocked
  Fletcher checksum from ``repro.kernels.checksum`` — Pallas on TPU, the
  jitted reference on CPU — instead of host zlib.  The header records per
  chunk ``{clen, ulen, digest}`` so a reader can verify integrity chunk by
  chunk and reject truncated files explicitly.  Chunk *encoding* fans out
  across the IO worker pool via ``IOContext.fanout``.
"""
from __future__ import annotations

import json
import os
import shutil
import uuid
import zlib
from pathlib import Path
from typing import List, Optional

import numpy as np

try:  # optional transparent compression (beyond-paper extension)
    import zstandard as _zstd
except ImportError:  # pragma: no cover
    _zstd = None

from repro.core import tiers
from repro.core.cpbase import CheckpointError, IOContext
from repro.core.tiers import StorageTier, fsync_dir  # re-export (legacy API)

_MAGIC = b"CRFT"
CODEC_V0 = 0
CODEC_V1 = 1
DEFAULT_CHUNK_BYTES = 4 * 1024 * 1024


def _dtype_to_name(dt: np.dtype) -> str:
    return np.dtype(dt).name  # e.g. "float32", "bfloat16" (ml_dtypes)


def _dtype_from_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # registered by jax; covers bfloat16 / fp8 etc.

        return np.dtype(getattr(ml_dtypes, name))


def _digest_chunk(data) -> List[int]:
    """Blocked Fletcher digest [s1, s2] via the checksum kernel ops."""
    from repro.kernels.checksum import ops as checksum_ops

    s1, s2 = checksum_ops.digest_bytes(data)
    return [int(s1), int(s2)]


def _as_byte_view(arr: np.ndarray) -> np.ndarray:
    """Contiguous flat uint8 view of an array (copy only if non-contiguous)."""
    arr = np.ascontiguousarray(arr)
    if arr.nbytes == 0:
        return np.empty(0, dtype=np.uint8)
    return arr.reshape(-1).view(np.uint8).reshape(-1)


def _manifest_name(path: Path, ctx: IOContext) -> str:
    """Checksum-manifest key: path relative to the staging root (collision-
    free across checkpoint keys), falling back to the bare file name."""
    if ctx.rel_root is not None:
        try:
            return str(path.relative_to(ctx.rel_root))
        except ValueError:
            pass
    return path.name


def run_jobs(jobs, ctx: IOContext) -> list:
    """Run independent IO jobs through ``ctx.fanout`` when available, else
    inline — the single dispatch point for per-array and per-chunk fanout."""
    if ctx.fanout is not None and len(jobs) > 1:
        return ctx.fanout(jobs)
    return [job() for job in jobs]


# --------------------------------------------------------------------------
# array codec — v1 chunked writer, v0 legacy writer, version-dispatching reader
# --------------------------------------------------------------------------
def write_array(path: Path, arr: np.ndarray, ctx: IOContext) -> None:
    """Serialize ``arr`` to ``path`` using the codec ``ctx`` selects."""
    if ctx.codec_version == CODEC_V0:
        _write_array_v0(path, arr, ctx)
    else:
        _write_array_v1(path, arr, ctx)


def _write_array_v0(path: Path, arr: np.ndarray, ctx: IOContext) -> None:
    arr = np.ascontiguousarray(arr)
    payload = arr.tobytes()
    if ctx.compress == "zstd":
        if _zstd is None:  # pragma: no cover
            raise CheckpointError("CRAFT_COMPRESS=zstd but zstandard missing")
        payload = _zstd.ZstdCompressor(level=3).compress(payload)
    header = json.dumps(
        {
            "dtype": _dtype_to_name(arr.dtype),
            "shape": list(arr.shape),
            "compress": ctx.compress,
        }
    ).encode()
    digest = zlib.crc32(payload) if ctx.checksum != "none" else 0
    tmp = path.with_name(f".tmp-{path.name}-{uuid.uuid4().hex[:8]}")
    with open(tmp, "wb") as fh:
        fh.write(_MAGIC)
        fh.write(len(header).to_bytes(8, "little"))
        fh.write(header)
        fh.write(digest.to_bytes(8, "little"))
        fh.write(payload)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)
    ctx.record_checksum(_manifest_name(path, ctx), digest)


def _write_array_v1(path: Path, arr: np.ndarray, ctx: IOContext) -> None:
    shape = list(np.shape(arr))  # before ascontiguousarray 0-d→1-d promotion
    arr = np.ascontiguousarray(arr)
    flat = _as_byte_view(arr)
    chunk_bytes = max(1, int(ctx.chunk_bytes))
    compress = ctx.compress
    if compress == "zstd" and _zstd is None:  # pragma: no cover
        raise CheckpointError("CRAFT_COMPRESS=zstd but zstandard missing")
    want_digest = ctx.checksum != "none"
    n = flat.size
    offsets = range(0, n, chunk_bytes) if n else range(0)

    def encode(off: int):
        raw = flat[off: off + chunk_bytes]
        if compress == "zstd":
            stored = _zstd.ZstdCompressor(level=3).compress(raw.tobytes())
        else:
            stored = memoryview(raw)
        digest = _digest_chunk(stored) if want_digest else [0, 0]
        return stored, {"clen": len(stored), "ulen": int(raw.size),
                        "digest": digest}

    encoded = run_jobs([lambda off=off: encode(off) for off in offsets], ctx)
    chunks_meta = [meta for _, meta in encoded]
    header = json.dumps(
        {
            "fmt": CODEC_V1,
            "dtype": _dtype_to_name(arr.dtype),
            "shape": shape,
            "compress": compress,
            "checksum": "fletcher" if want_digest else "none",
            "chunk_bytes": chunk_bytes,
            "nbytes": int(n),
            "chunks": chunks_meta,
        }
    ).encode()
    tmp = path.with_name(f".tmp-{path.name}-{uuid.uuid4().hex[:8]}")
    with open(tmp, "wb") as fh:
        fh.write(_MAGIC)
        fh.write(len(header).to_bytes(8, "little"))
        fh.write(header)
        for stored, _ in encoded:
            fh.write(stored)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)
    # whole-file digest for the manifest: fold per-chunk digests
    folded = 0
    for meta in chunks_meta:
        folded = zlib.crc32(
            meta["digest"][0].to_bytes(4, "little")
            + meta["digest"][1].to_bytes(4, "little"),
            folded,
        )
    ctx.record_checksum(_manifest_name(path, ctx), folded)


def read_array(path: Path, ctx: IOContext) -> np.ndarray:
    """Read an array written by any codec version (v0 legacy or v1 chunked).

    When ``ctx.array_cache`` holds a decoded array for ``path`` (memory-tier
    restore), it is returned directly as a read-only view — callers that need
    ownership of the buffer must copy.
    """
    if ctx.array_cache is not None:
        hit = ctx.array_cache.get(str(path))
        if hit is not None:
            view = hit.view()
            view.setflags(write=False)
            return view
    if not path.exists():
        raise CheckpointError(f"missing checkpoint file {path}")
    with open(path, "rb") as fh:
        if fh.read(4) != _MAGIC:
            raise CheckpointError(f"bad magic in {path}")
        raw_hlen = fh.read(8)
        if len(raw_hlen) != 8:
            raise CheckpointError(f"truncated header in {path}")
        hlen = int.from_bytes(raw_hlen, "little")
        raw_header = fh.read(hlen)
        if len(raw_header) != hlen:
            raise CheckpointError(f"truncated header in {path}")
        try:
            header = json.loads(raw_header.decode())
        except (UnicodeDecodeError, json.JSONDecodeError) as exc:
            raise CheckpointError(f"corrupt header in {path}: {exc}") from exc
        fmt = header.get("fmt", CODEC_V0)
        if fmt == CODEC_V0:
            return _read_payload_v0(fh, header, path, ctx)
        if fmt == CODEC_V1:
            return _read_payload_v1(fh, header, path, ctx)
        raise CheckpointError(
            f"{path}: format v{fmt} is newer than this reader understands"
        )


def _restore_shape(payload: bytes, header: dict, path: Path) -> np.ndarray:
    dtype = _dtype_from_name(header["dtype"])
    shape = header["shape"]
    expected = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
    if len(payload) != expected:
        raise CheckpointError(
            f"truncated payload in {path}: got {len(payload)} bytes, "
            f"expected {expected} for {header['dtype']}{tuple(shape)}"
        )
    arr = np.frombuffer(bytearray(payload), dtype=dtype)
    return arr.reshape(shape)


def _read_payload_v0(fh, header: dict, path: Path, ctx: IOContext) -> np.ndarray:
    raw_digest = fh.read(8)
    if len(raw_digest) != 8:
        raise CheckpointError(f"truncated payload in {path}")
    digest = int.from_bytes(raw_digest, "little")
    payload = fh.read()
    if ctx.checksum != "none" and digest and zlib.crc32(payload) != digest:
        raise CheckpointError(f"checksum mismatch in {path}")
    if header["compress"] == "zstd":
        if _zstd is None:  # pragma: no cover
            raise CheckpointError("file is zstd-compressed but zstandard missing")
        try:
            payload = _zstd.ZstdDecompressor().decompress(payload)
        except _zstd.ZstdError as exc:
            raise CheckpointError(f"corrupt zstd payload in {path}: {exc}") from exc
    return _restore_shape(payload, header, path)


def _read_payload_v1(fh, header: dict, path: Path, ctx: IOContext) -> np.ndarray:
    verify = ctx.checksum != "none" and header.get("checksum", "none") != "none"
    # phase 1: sequential file IO — read every chunk's stored bytes
    raw_chunks = []
    for i, meta in enumerate(header["chunks"]):
        stored = fh.read(meta["clen"])
        if len(stored) != meta["clen"]:
            raise CheckpointError(
                f"truncated payload in {path}: chunk {i} got "
                f"{len(stored)}/{meta['clen']} bytes"
            )
        raw_chunks.append(stored)
    if fh.read(1):
        raise CheckpointError(f"trailing bytes after last chunk in {path}")

    # phase 2: digest verification + decompression fan out across the pool
    def decode(i: int) -> bytes:
        stored, meta = raw_chunks[i], header["chunks"][i]
        if verify and _digest_chunk(stored) != list(meta["digest"]):
            raise CheckpointError(f"checksum mismatch in {path} (chunk {i})")
        if header["compress"] == "zstd":
            if _zstd is None:  # pragma: no cover
                raise CheckpointError(
                    "file is zstd-compressed but zstandard missing")
            try:
                stored = _zstd.ZstdDecompressor().decompress(stored)
            except _zstd.ZstdError as exc:
                raise CheckpointError(
                    f"corrupt zstd chunk {i} in {path}: {exc}"
                ) from exc
        if len(stored) != meta["ulen"]:
            raise CheckpointError(
                f"corrupt chunk {i} in {path}: inflated to {len(stored)} "
                f"bytes, expected {meta['ulen']}"
            )
        return stored

    parts = run_jobs(
        [lambda i=i: decode(i) for i in range(len(raw_chunks))], ctx)
    out = b"".join(parts)
    if len(out) != header["nbytes"]:
        raise CheckpointError(
            f"truncated payload in {path}: got {len(out)} bytes, "
            f"expected {header['nbytes']}"
        )
    return _restore_shape(out, header, path)


def write_json(path: Path, obj) -> None:
    tmp = path.with_name(f".tmp-{path.name}-{uuid.uuid4().hex[:8]}")
    with open(tmp, "w") as fh:
        json.dump(obj, fh, indent=1)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)


def read_json(path: Path):
    with open(path) as fh:
        return json.load(fh)


# --------------------------------------------------------------------------
# version store — the concrete StorageTier over a plain directory tree
# --------------------------------------------------------------------------
class VersionStore(StorageTier):
    """One checkpoint name's versioned directory tree on one storage tier.

    Multi-process coordination: all processes of ``comm`` share one staging
    directory per version (deterministic name, rank-distinct file names
    inside); ``publish()`` barriers, then rank 0 alone performs the atomic
    rename + metadata commit, then barriers again so no process reads a
    version before it is complete.
    """

    label = "pfs"

    def __init__(
        self, base: Path, name: str, keep_versions: int = 2, comm=None,
        sweep: bool = True,
    ):
        self.root = Path(base) / name
        self.keep_versions = max(1, keep_versions)
        self.comm = comm
        self.root.mkdir(parents=True, exist_ok=True)
        if sweep and self._rank() == 0:
            tiers.sweep_tmp_dirs(self.root)

    def _rank(self) -> int:
        return 0 if self.comm is None else self.comm.rank

    def _barrier(self) -> None:
        if self.comm is not None:
            self.comm.barrier()

    # -- staging ------------------------------------------------------------
    def stage(self, version: int) -> Path:
        tmp = self.root / tiers.staging_dir_name(version)
        tmp.mkdir(parents=True, exist_ok=True)
        return tmp

    def publish(self, staged: Path, version: int, extra_meta: Optional[dict] = None) -> None:
        self._barrier()  # every process finished writing its files
        if self._rank() == 0:
            tiers.atomic_publish_dir(staged, self.root / tiers.version_dir_name(version))
            meta = self.meta()
            versions = sorted(set(meta.get("versions", [])) | {version})
            meta.update(
                {
                    "latest": version,
                    "versions": versions,
                    **(extra_meta or {}),
                }
            )
            write_json(self.root / "meta.json", meta)
            self._retire()
        self._barrier()  # version visible to everyone from here on

    def abort(self, staged: Path) -> None:
        shutil.rmtree(staged, ignore_errors=True)

    # -- reading ------------------------------------------------------------
    def meta(self) -> dict:
        p = self.root / "meta.json"
        if p.exists():
            try:
                return read_json(p)
            except (json.JSONDecodeError, OSError):
                return {}
        return {}

    def latest_version(self) -> int:
        """Latest *complete* version, 0 if none (paper: CP-version counter)."""
        meta = self.meta()
        for v in sorted(meta.get("versions", []), reverse=True):
            if (self.root / tiers.version_dir_name(v)).is_dir():
                return v
        return 0

    def version_dir(self, version: int) -> Path:
        return self.root / tiers.version_dir_name(version)

    # -- invalidation (nested checkpoints, paper §2.5) -----------------------
    def invalidate_all(self) -> None:
        meta = self.meta()
        for v in meta.get("versions", []):
            shutil.rmtree(self.root / tiers.version_dir_name(v), ignore_errors=True)
        meta["versions"] = []
        meta["latest"] = 0
        write_json(self.root / "meta.json", meta)

    # -- housekeeping --------------------------------------------------------
    def _retire(self) -> None:
        kept = tiers.retire_version_dirs(self.root, self.keep_versions)
        meta = self.meta()
        meta["versions"] = kept
        write_json(self.root / "meta.json", meta)
