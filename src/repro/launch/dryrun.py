import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any jax import (jax locks the device
# count at first init) — this file is the only place the 512 placeholder
# devices exist; tests and benches see the real single CPU device.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. builds the step function + sharded ShapeDtypeStruct inputs
     (``launch.specs``), — no device memory is ever allocated,
  3. ``jit(step).lower(...).compile()`` — a sharding mismatch, an
     unsupported collective, or an OOM-sized temp here is a bug in the
     framework, not in the arch,
  4. prints ``memory_analysis()`` (proves it fits) and the three-term
     roofline from the compiled HLO (``analysis.roofline``),
  5. optionally writes a JSON record under ``experiments/dryrun/``.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch phi4-mini-3.8b \
        --shape train_4k [--multipod] [--json-dir experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all    # whole matrix
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             json_dir, verbose: bool = True) -> dict:
    import jax

    from repro.analysis import roofline
    from repro.configs import SHAPES, cell_supported, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_step

    shape = SHAPES[shape_name]
    ok, reason = cell_supported(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    built = build_step(arch, shape, mesh)
    lowered = built.lower(mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    try:
        cost = compiled.cost_analysis()
        xla_flops = float(cost.get("flops", 0.0))
    except Exception:
        xla_flops = 0.0
    rep = roofline.analyze(compiled.as_text())
    mfl = roofline.model_flops(
        get_config(arch), shape.seq_len, shape.global_batch, shape.kind,
        n_chips)

    per_dev_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                     + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_bytes": per_dev_bytes,
            "fits_16GB": per_dev_bytes < 16e9,
        },
        "xla_cost_flops_per_device": xla_flops,
        "roofline": rep.as_dict(),
        "model_flops_per_chip": mfl,
        "model_hlo_ratio": mfl / max(rep.flops, 1.0),
    }
    if verbose:
        print(f"== {arch} × {shape_name} × {record['mesh']} "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
        print(f"  peak bytes/device     {per_dev_bytes:.3e} "
              f"({'fits' if record['memory']['fits_16GB'] else 'EXCEEDS'} "
              f"16 GB v5e)")
        print(roofline.format_report(rep, mfl))
    if json_dir is not None:
        out = json_dir / record["mesh"] / f"{arch}__{shape_name}.json"
        roofline.save_json(out, record)
    return record


def run_matrix(json_dir: Path, multipod_only: bool = False,
               archs=None, shapes=None) -> int:
    """Run every cell in a subprocess (compiles leak; isolation is safer).

    Returns the number of failed cells."""
    from repro.configs import ARCH_IDS, SHAPES, cell_supported

    failures = 0
    meshes = [True] if multipod_only else [False, True]
    for multi_pod in meshes:
        for arch in (archs or ARCH_IDS):
            for shape in (shapes or SHAPES):
                ok, reason = cell_supported(arch, shape)
                mesh_name = "2x16x16" if multi_pod else "16x16"
                if not ok:
                    print(f"-- skip {arch} × {shape} × {mesh_name}: {reason}")
                    continue
                out = json_dir / mesh_name / f"{arch}__{shape}.json"
                if out.exists():
                    print(f"-- cached {arch} × {shape} × {mesh_name}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--json-dir", str(json_dir)]
                if multi_pod:
                    cmd.append("--multipod")
                r = subprocess.run(cmd, capture_output=True, text=True)
                sys.stdout.write(r.stdout)
                if r.returncode != 0:
                    failures += 1
                    print(f"!! FAILED {arch} × {shape} × {mesh_name}")
                    sys.stdout.write(r.stderr[-3000:])
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run the full 40-cell × 2-mesh matrix (subprocesses)")
    ap.add_argument("--json-dir", default="experiments/dryrun")
    args = ap.parse_args()
    json_dir = Path(args.json_dir)
    if args.all:
        failures = run_matrix(json_dir)
        sys.exit(1 if failures else 0)
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    try:
        rec = run_cell(args.arch, args.shape, args.multipod, json_dir)
        if rec.get("skipped"):
            print(f"-- skip {args.arch} × {args.shape}: {rec['skipped']}")
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
