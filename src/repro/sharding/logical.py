"""Logical-axis sharding rules (DP / FSDP / TP / EP over the production mesh).

Every parameter and activation declares *logical* dimension names; a rule
table maps them onto mesh axes.  The production mesh is ``("data", "model")``
single-pod and ``("pod", "data", "model")`` multi-pod (launch/mesh.py).

Default placement (MaxText-style 2-D sharding):

  * ``batch``   → ("pod", "data")   — data parallelism across pods + hosts
  * ``embed``   → "data"            — FSDP: weights sharded over the DP axis
                                       (all-gathered per layer on use)
  * ``heads`` / ``mlp`` / ``vocab`` / ``kv_heads`` / ``ssm_inner`` → "model"
                                     — tensor parallelism (Megatron split)
  * ``experts`` → "model"           — expert parallelism for MoE
  * everything else (seq, head_dim, ssm_state, layers, ...) replicated.

Uneven divisions (e.g. 56 heads over 16-way model axis) are allowed —
GSPMD pads — and the padding waste is surfaced by the roofline's
MODEL_FLOPS / HLO_FLOPS ratio rather than hidden.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": "data",          # FSDP axis for weights
    "embed_act": None,        # activations keep d_model replicated
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    # NOTE (§Perf iteration 3.2, REFUTED): sharding experts over
    # (model, data) — one deepseek expert per chip — looked like it would
    # remove the per-layer FSDP all-gather of expert weights, but GSPMD
    # cannot express the token all-to-all that placement needs through the
    # one-hot dispatch einsums: it replicated the activations instead
    # (collective term 104 s → 1326 s).  True 2-D EP needs a shard_map
    # dispatch path (future work); EP stays on the model axis.
    "experts": "model",
    "expert_mlp": None,
    "layers": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
    "conv": None,
    "latent": "model",        # MLA compressed-KV dim
    "dt_rank": None,
    "capacity": None,
    "patches": None,
}


class LogicalRules:
    """A rule table bound to a mesh; filters axes the mesh doesn't have."""

    def __init__(self, mesh: Mesh, rules: Optional[Dict[str, Axis]] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES if rules is None else rules)

    def physical(self, logical: Optional[str]) -> Axis:
        if logical is None:
            return None
        if logical not in self.rules:
            raise KeyError(f"unknown logical axis {logical!r}")
        ax = self.rules[logical]
        names = set(self.mesh.axis_names)
        if ax is None:
            return None
        if isinstance(ax, str):
            return ax if ax in names else None
        filtered = tuple(a for a in ax if a in names)
        return filtered if filtered else None

    def spec(self, *logical_dims: Optional[str],
             shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for a tensor whose dims carry these logical names.

        With ``shape`` given, every candidate mesh axis must divide the dim
        size; non-dividing axes are dropped (prefix-wise for tuple rules) and
        the dim degrades gracefully toward replication.  This is how e.g. a
        ``global_batch=1`` long-context decode input or an ``n_kv_heads=2``
        cache stays lowerable on the fixed 16-way production axes — the
        resulting redundant compute is *surfaced* by the roofline's
        MODEL_FLOPS/HLO_FLOPS ratio, not hidden.
        """
        used: set = set()
        axis_sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        phys = []
        for i, dim in enumerate(logical_dims):
            ax = self.physical(dim)
            # an axis may appear at most once in a PartitionSpec
            if ax is None:
                phys.append(None)
                continue
            axs = (ax,) if isinstance(ax, str) else ax
            axs = tuple(a for a in axs if a not in used)
            if shape is not None:
                kept, prod = [], 1
                for a in axs:
                    if shape[i] % (prod * axis_sizes[a]) == 0:
                        kept.append(a)
                        prod *= axis_sizes[a]
                    else:
                        break  # keep a contiguous prefix so sizes stay exact
                axs = tuple(kept)
            used.update(axs)
            if not axs:
                phys.append(None)
            elif len(axs) == 1:
                phys.append(axs[0])
            else:
                phys.append(axs)
        return P(*phys)

    def sharding(self, *logical_dims: Optional[str]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical_dims))


def spec_for(rules: LogicalRules, logical_dims: Sequence[Optional[str]]) -> P:
    return rules.spec(*logical_dims)


def _is_dims(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(d, (str, type(None))) for d in x)


def shard_specs(rules: LogicalRules, logical_tree, shapes=None):
    """Map a pytree whose leaves are tuples of logical dim names to
    PartitionSpecs.  ``shapes``: matching pytree of array-likes (anything
    with ``.shape``) enabling the divisibility fallback of ``spec``."""
    if shapes is None:
        return jax.tree_util.tree_map(
            lambda dims: rules.spec(*dims), logical_tree, is_leaf=_is_dims)
    return jax.tree_util.tree_map(
        lambda dims, arr: rules.spec(*dims, shape=arr.shape),
        logical_tree, shapes, is_leaf=_is_dims)
