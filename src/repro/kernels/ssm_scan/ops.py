"""Public selective-scan op: padding + backend dispatch.

On TPU the Pallas kernels run (state in VMEM); on CPU/dry-run the model
uses the fused chunked jnp formulation in :mod:`repro.models.ssm`
(``_fused_ssd_scan``) whose body the roofline treats as this kernel via the
``pallas_equiv_ssm`` scope.  This wrapper is the direct kernel entry used
by tests and TPU deployments.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import s6_scan, ssd_scan
from repro.kernels.ssm_scan.ref import s6_scan_ref, ssd_scan_ref


def _pad_l(x, blk):
    pad = (-x.shape[1]) % blk
    if pad:
        widths = [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2)
        x = jnp.pad(x, widths)
    return x


def selective_scan(dtx, bh, ch, dt, A, h0, *, blk: int = 128,
                   use_pallas=None, interpret: bool = False):
    """Dispatching selective scan; mamba1 vs mamba2 inferred from ranks.

    Padding with dt=0 is exact (decay 1, injection 0); padded y rows are
    sliced away.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    l = dtx.shape[1]
    mamba2 = dtx.ndim == 4
    if not use_pallas and not interpret:
        fn = ssd_scan_ref if mamba2 else s6_scan_ref
        return fn(dtx, bh, ch, dt, A, h0)
    args = [_pad_l(a, blk) for a in (dtx, bh, ch, dt)]
    fn = ssd_scan if mamba2 else s6_scan
    y, h_last = fn(*args, A, h0, blk=blk, interpret=interpret)
    return y[:, :l], h_last
