"""Real multiprocessing runtime: coordinator + workers, kill -9 fault model.

These spawn actual OS processes (the paper's fail-stop model is
``pkill -9``); they are the integration proof that AFT works outside the
in-process simulator.
"""
import time

import pytest

from repro.runtime.cluster import Cluster

pytestmark = pytest.mark.slow


# worker functions must be module-level (spawn start method pickles them)
def _sum_ranks(comm):
    return comm.allreduce(comm.rank, op="sum")


def _resilient_barriers(comm):
    from repro.core.comm import ProcFailedError, RevokedError

    recovered = False
    while True:
        try:
            for _ in range(40):
                comm.barrier()
                time.sleep(0.01)
            return ("recovered" if recovered else "fresh", comm.size)
        except (ProcFailedError, RevokedError):
            try:
                comm.revoke()
            except Exception:
                pass
            comm = comm.recover()
            recovered = True


def _aft_counting(comm):
    from repro.core.aft import aft_zone

    def body(c):
        for _ in range(30):
            c.barrier()
            time.sleep(0.01)
        return c.size

    return aft_zone(comm, body)


def test_collectives_across_processes():
    cluster = Cluster(n_procs=3)
    cluster.start(_sum_ranks)
    results = cluster.join(timeout=60)
    assert set(results.values()) == {3}


def test_kill9_nonshrinking_recovery():
    cluster = Cluster(n_procs=3, procs_per_node=1, spare_nodes=1,
                      recovery_policy="NON-SHRINKING")
    cluster.start(_resilient_barriers)
    time.sleep(0.6)
    cluster.kill(1)                      # SIGKILL — the paper's fault model
    results = cluster.join(timeout=120)
    assert len(results) == 3
    assert {v[1] for v in results.values()} == {3}
    assert any(v[0] == "recovered" for v in results.values())
    stats = cluster.coord.last_recovery
    assert stats.get("failed") == [1]


def test_kill9_shrinking_recovery():
    cluster = Cluster(n_procs=4, recovery_policy="SHRINKING")
    cluster.start(_resilient_barriers)
    time.sleep(0.6)
    cluster.kill(2)
    results = cluster.join(timeout=120)
    assert {v[1] for v in results.values()} == {3}


def test_aft_zone_survives_kill9():
    cluster = Cluster(n_procs=3, spare_nodes=1,
                      recovery_policy="NON-SHRINKING")
    cluster.start(_aft_counting)
    time.sleep(0.5)
    cluster.kill(0)                      # even rank 0 may die
    results = cluster.join(timeout=120)
    assert set(results.values()) == {3}
