"""Reed–Solomon erasure redundancy for the node tier (``CRAFT_NODE_REDUNDANCY=RS``).

The paper's node-level redundancy (via SCR, §2.4) tops out at partner
mirrors and single-loss XOR parity; fleets past a few hundred hosts lose
two nodes of one group often enough that single-failure tolerance is the
availability ceiling (ReStore, FTHP-MPI).  ``RS`` generalizes the XOR
parity group to an RS(k, m) code: the k members of a node group
(``CRAFT_XOR_GROUP_SIZE``) are protected by ``m = CRAFT_RS_PARITY`` parity
buffers, so **any m simultaneously lost members** rebuild bit-identically —
``m=1`` degenerates to the XOR tier (the coding matrix's first row is all
ones, see :mod:`repro.kernels.rs_erasure`).

Placement rotates RAID-5 style per row *and* version: parity row ``j`` of
version ``v`` lives on group member ``(v + j) % k``, so consecutive rows
land on distinct members and no single node becomes the parity hotspot.
Layout on the holder node::

    <node-dir>/rs-group-<g0>/<name>/v-<K>/
        parity-<j>.bin      # only the rows this member holds
        manifest.json       # identical on every holder

The manifest records, per member, the file list + payload size + kernel
Fletcher digest (stale-survivor detection, like the XOR manifest) and, per
parity row, the row digest — which is what lets the background scrubber
(:mod:`repro.core.scrubber`) verify and re-encode rotted parity shards
without touching the members.

Like the XOR path, every holder reads the group members through the shared
filesystem (the test/bench cluster's stand-in for the RDMA transfers of a
real fleet); the GF(2^8) math itself is the Pallas ``rs_erasure`` kernel on
TPU and its jitted log/exp-table reference on CPU.
"""
from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.core import storage, tiers
from repro.core.cpbase import CheckpointError
from repro.kernels.checksum import ops as checksum_ops
from repro.kernels.rs_erasure import ops as rs_ops


def holder_of(group: List[int], version: int, row: int) -> int:
    """Node holding parity row ``row`` of ``version`` (rotating placement)."""
    return group[(version + row) % len(group)]


def parity_root(store, version: int) -> Dict[int, Path]:
    """{parity row: holder's rs-group side-tree root} for ``version``."""
    group = store._group(store.nid)
    g0 = group[0]
    return {
        j: store._node_dir(holder_of(group, version, j))
        / f"rs-group-{g0}" / store.name
        for j in range(store.env.rs_parity)
    }


def collect_member(store, member: int, version: int) -> Tuple[bytes, dict]:
    """A member's concatenated payload + its manifest entry (files, digest).

    The entry shape ``{"files", "size", "digest"}`` is shared with the XOR
    path (``NodeStore._publish_xor`` builds its manifest through this
    helper), so both redundancy modes agree on what a member payload is.
    """
    vdir = store._member_version_dir(member, version)
    files = sorted(p for p in vdir.rglob("*") if p.is_file())
    blob = bytearray()
    entries = []
    for p in files:
        data = p.read_bytes()
        entries.append({"rel": str(p.relative_to(vdir)), "size": len(data)})
        blob += data
    payload = bytes(blob)
    s1, s2 = checksum_ops.digest_bytes(payload)
    return payload, {
        "files": entries, "size": len(payload), "digest": [int(s1), int(s2)],
    }


def read_member_payload(store, member: int, version: int,
                        ment: dict) -> Optional[bytes]:
    """Re-read a member's payload per its manifest entry, fully verified.

    Returns ``None`` when any file is unreadable or the reassembled payload
    is short or digest-mismatched — the single definition of a *stale
    survivor* for both the XOR and RS recovery paths.
    """
    vdir = store._member_version_dir(member, version)
    try:
        blob = bytearray()
        for ent in ment["files"]:
            blob += (vdir / ent["rel"]).read_bytes()
    except OSError:
        return None
    payload = bytes(blob)
    if len(payload) != int(ment["size"]):
        return None
    if "digest" in ment:    # pre-digest manifests verify by size alone
        s1, s2 = checksum_ops.digest_bytes(payload)
        if [int(s1), int(s2)] != list(ment["digest"]):
            return None
    return payload


def publish_rs(store, version: int) -> None:
    """Encode and publish the parity rows this node holds for ``version``.

    Every holder encodes the full parity set (the group is small; encoding
    all rows lets the manifest carry every row's digest so scrub can verify
    shards it does not hold) but writes only its own rows.
    """
    group = store._group(store.nid)
    m = store.env.rs_parity
    my_rows = [j for j in range(m)
               if holder_of(group, version, j) == store.nid]
    if not my_rows:
        return
    payloads: Dict[int, bytes] = {}
    members: Dict[str, dict] = {}
    for member in group:
        payloads[member], members[str(member)] = collect_member(
            store, member, version)
    parity = rs_ops.encode_parity([payloads[n] for n in group], m)
    parity_meta = {}
    for j in range(m):
        s1, s2 = checksum_ops.digest_bytes(parity[j])
        parity_meta[str(j)] = {
            "holder": holder_of(group, version, j),
            "size": len(parity[j]),
            "digest": [int(s1), int(s2)],
        }
    manifest = {
        "k": len(group), "m": m, "group": list(group),
        "members": members, "parity": parity_meta,
    }
    root = parity_root(store, version)[my_rows[0]]
    tmp = root / tiers.staging_dir_name(version)
    shutil.rmtree(tmp, ignore_errors=True)
    tmp.mkdir(parents=True)
    for j in my_rows:
        (tmp / f"parity-{j}.bin").write_bytes(parity[j])
    storage.write_json(tmp / "manifest.json", manifest)
    tiers.atomic_publish_dir(tmp, root / tiers.version_dir_name(version))
    tiers.retire_version_dirs(root, store.env.keep_versions)


def _load_parities(store, version: int) -> Tuple[Optional[dict], Dict[int, bytes]]:
    """(manifest, {row: verified parity bytes}) readable for ``version``.

    A parity shard whose bytes no longer match the manifest digest is
    treated as lost (never fed into the solve), exactly like a stale
    survivor — rot in a parity buffer must not poison the rebuild.
    """
    manifest = None
    raw: Dict[int, bytes] = {}
    for j, root in parity_root(store, version).items():
        pdir = root / tiers.version_dir_name(version)
        mpath = pdir / "manifest.json"
        if manifest is None and mpath.exists():
            manifest = storage.read_json(mpath)
        ppath = pdir / f"parity-{j}.bin"
        if ppath.exists():
            raw[j] = ppath.read_bytes()
    if manifest is None:
        return None, {}
    parities: Dict[int, bytes] = {}
    for j, data in raw.items():
        pmeta = manifest.get("parity", {}).get(str(j))
        if pmeta is None:
            continue
        s1, s2 = checksum_ops.digest_bytes(data)
        if [int(s1), int(s2)] == list(pmeta["digest"]):
            parities[j] = data
    return manifest, parities


def _classify_members(store, manifest: dict, version: int
                      ) -> Tuple[Dict[int, bytes], List[int], List[int]]:
    """(present {position: payload}, lost positions, member sizes).

    A member whose payload is unreadable, short, or digest-mismatched
    counts as lost — a stale survivor served into the solve would rebuild
    garbage bit-exactly labeled as good.
    """
    group = list(manifest["group"])
    present: Dict[int, bytes] = {}
    lost: List[int] = []
    sizes: List[int] = []
    for pos, member in enumerate(group):
        ment = manifest["members"].get(str(member))
        if ment is None:
            raise CheckpointError(
                f"RS parity manifest is missing member {member} "
                "(malformed manifest)"
            )
        sizes.append(int(ment["size"]))
        payload = read_member_payload(store, member, version, ment)
        if payload is None:
            lost.append(pos)
        else:
            present[pos] = payload
    return present, lost, sizes


def recover_rs(store, version: int) -> Optional[Path]:
    """Rebuild this node's ``v-<version>`` directory from the RS group.

    Returns the rebuilt local directory, ``None`` when no parity manifest
    exists for the version, and raises :class:`CheckpointError` when more
    members are lost than readable parity shards can solve.
    """
    manifest, parities = _load_parities(store, version)
    if manifest is None:
        return None
    group = list(manifest["group"])
    if store.nid not in group:
        return None
    present, lost, sizes = _classify_members(store, manifest, version)
    my_pos = group.index(store.nid)
    if my_pos not in lost:
        lost.append(my_pos)          # we are here because local is incomplete
        present.pop(my_pos, None)
    if len(lost) > len(parities):
        raise CheckpointError(
            f"RS group of {store.name} v-{version}: {len(lost)} members lost "
            f"but only {len(parities)} verified parity shards available "
            f"(m={manifest['m']})"
        )
    rebuilt = rs_ops.decode_lost(
        len(group), int(manifest["m"]), present, parities, sizes)
    mine = rebuilt[my_pos]
    ment = manifest["members"][str(store.nid)]
    dst = store._local.version_dir(version)
    shutil.rmtree(dst, ignore_errors=True)
    dst.mkdir(parents=True, exist_ok=True)
    offset = 0
    for ent in ment["files"]:
        out = dst / ent["rel"]
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_bytes(mine[offset: offset + ent["size"]])
        offset += ent["size"]
    return dst


def latest_rs_version(store) -> int:
    """Newest version with a readable RS parity manifest anywhere in the group."""
    best = 0
    group = store._group(store.nid)
    g0 = group[0]
    for holder in group:
        root = store._node_dir(holder) / f"rs-group-{g0}" / store.name
        for v, p in tiers.list_version_dirs(root):
            if (p / "manifest.json").exists():
                best = max(best, v)
    return best


def invalidate_rs(store) -> None:
    group = store._group(store.nid)
    g0 = group[0]
    for holder in group:
        shutil.rmtree(store._node_dir(holder) / f"rs-group-{g0}" / store.name,
                      ignore_errors=True)


def scrub_rs(store, version: int) -> dict:
    """Verify this version's parity shards; re-encode rotted rows in place.

    Returns ``{"bytes", "checked", "repaired", "unrepairable"}``.  A row is
    only re-encoded when **every** group member's payload still matches its
    manifest digest — re-encoding over a rotted member would launder data
    corruption into fresh-looking parity.
    """
    stats = {"bytes": 0, "checked": 0, "repaired": 0, "unrepairable": 0}
    try:
        manifest, _ = _load_parities(store, version)
    except (OSError, json.JSONDecodeError):
        return stats
    if manifest is None:
        return stats
    group = list(manifest["group"])
    m = int(manifest["m"])
    bad_rows = []
    for j, root in parity_root(store, version).items():
        pdir = root / tiers.version_dir_name(version)
        ppath = pdir / f"parity-{j}.bin"
        pmeta = manifest.get("parity", {}).get(str(j))
        if pmeta is None or not pdir.is_dir():
            continue
        stats["checked"] += 1
        data = ppath.read_bytes() if ppath.exists() else b""
        stats["bytes"] += len(data)
        s1, s2 = checksum_ops.digest_bytes(data) if data else (0, 0)
        if not data or [int(s1), int(s2)] != list(pmeta["digest"]):
            bad_rows.append((j, ppath))
    if not bad_rows:
        return stats
    try:
        present, lost, _ = _classify_members(store, manifest, version)
    except CheckpointError:
        stats["unrepairable"] += len(bad_rows)
        return stats
    if lost:
        # can't re-encode without every member intact; the rotted row stays
        # flagged (recovery will simply not use it)
        stats["unrepairable"] += len(bad_rows)
        return stats
    parity = rs_ops.encode_parity([present[p] for p in range(len(group))], m)
    for j, ppath in bad_rows:
        tmp = ppath.with_name(f".tmp-{ppath.name}")
        tmp.write_bytes(parity[j])
        tmp.replace(ppath)
        stats["repaired"] += 1
    return stats
