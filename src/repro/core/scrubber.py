"""Background integrity scrubber — find and repair checkpoint rot early.

Every tier verifies payload digests *at restore time*, which is exactly the
wrong moment to learn about silent corruption: the job just failed, the rot
may have spread into delta bases and parity, and the only remaining copy may
be the one that rotted.  The :class:`Scrubber` moves that discovery to idle
time: it walks the retained versions of every chained tier, re-verifies
chunk digests (including delta-base chains and RS parity shards), and
repairs rot **in place** while healthy copies still exist.

Scheduling.  Scrub slices ride idle checkpoint opportunities: when the
:class:`~repro.core.scheduler.CheckpointPolicy` decides *not* to write and
``CRAFT_SCRUB_EVERY`` seconds have passed since the last slice
(``CheckpointPolicy.scrub_due``), a slice is queued on the
:class:`~repro.core.async_writer.AsyncWriter`'s ordered lane — serialized
against version writes, counted by the policy's backpressure signal, and run
inline when no writer exists.  ``CRAFT_SCRUB_BYTES_PER_S`` caps each slice's
verified bytes at the interval's allowance, so a multi-GB tier is scrubbed
across many slices instead of one stall.

Repair sources, in order:

1. **redundancy within the tier** — a node-tier version is quarantined and
   re-materialized from its partner mirror / XOR group / RS(k, m) parity
   (bit-identical rebuild of the whole version directory);
2. **peer tiers** — the same relative file on another chained tier (or the
   RAM fabric) that still verifies is decoded and re-encoded in place,
   preserving the chunk grid so delta refs into the file stay resolvable;
3. **quarantine** — with no healthy source left, the version is retracted
   from the tier (``forget_version``) so a restore falls back to an older
   intact version or a deeper tier instead of ever reading rot.

``Checkpoint`` also calls :meth:`Scrubber.repair_version` when a restore
read fails verification (repair-on-read), retrying the tier once after a
successful repair — a restore therefore never observes bad bytes even when
background scrubbing is disabled.

Corruption injection for tests: :func:`corrupt_file` rots one payload chunk
of a CRFT file on disk; ``MemFabric.corrupt_entry`` rots a resident RAM
payload.  Both keep the recorded digests, which is what makes the rot
silent — and detectable.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import List, Optional, Tuple

from repro.core import metrics, storage, tiers
from repro.core.cpbase import CheckpointError, IOContext

#: Unthrottled slices still stop after this many verified bytes, so a scrub
#: slice sharing the ordered lane can never starve checkpoint writes.
DEFAULT_SLICE_BYTES = 256 * 1024 * 1024


# --------------------------------------------------------------------------
# corruption injection (test hook)
# --------------------------------------------------------------------------
def corrupt_file(path: Path, offset: Optional[int] = None,
                 flip: int = 0x40) -> int:
    """Silently rot one payload byte of ``path``; returns the file offset.

    For a CRFT array file the default offset lands in the first payload
    chunk (past magic + header + any v0 digest), so the stored digests stay
    intact and the rot is exactly what a scrub pass must detect.
    """
    data = bytearray(path.read_bytes())
    if offset is None:
        offset = 0
        if data[:4] == storage._MAGIC:
            hlen = int.from_bytes(data[4:12], "little")
            offset = 4 + 8 + hlen
            header = json.loads(data[12: 12 + hlen].decode())
            if header.get("fmt", storage.CODEC_V0) == storage.CODEC_V0:
                offset += 8                       # skip the v0 digest word
        if offset >= len(data):
            offset = len(data) - 1
    data[offset] ^= flip
    path.write_bytes(bytes(data))
    return offset


class Scrubber:
    """Per-checkpoint integrity scrubber over the live tier chain."""

    def __init__(self, checkpoint):
        self.cp = checkpoint
        self.env = checkpoint.env
        self._clock = checkpoint._clock
        self._queue: List[Tuple[str, int]] = []     # pending (slot, version)
        # StatsView mirrors every counter into the live metrics registry
        # as scrub_* series (chunks verified/repaired on the scoreboard)
        self.stats = metrics.StatsView(checkpoint.name, {
            "slices": 0, "passes": 0, "errors": 0,
            "files_scanned": 0, "bytes_scanned": 0,
            "corrupt_found": 0, "repaired": 0,
            "quarantined": 0, "unrepairable": 0,
            "parity_checked": 0, "parity_repaired": 0,
        }, prefix="scrub_")

    # -------------------------------------------------------------- driving
    def opportunity(self) -> bool:
        """Idle-window hook (called by ``Checkpoint`` on every skip decision):
        schedule one throttled scrub slice when the policy says it is due.
        Tripped-tier health probes ride the same idle windows — a half-open
        circuit breaker (core/health.py) gets its cheap re-admission probe
        here, outside the write path's critical section."""
        self.cp._probe_tiers()
        policy = self.cp.policy
        if policy is None or not policy.scrub_due():
            return False
        policy.note_scrub()
        budget = self._slice_budget()
        writer = self.cp._writer
        if writer is not None:
            writer.submit(lambda: self._safe_slice(budget))
        else:
            self._safe_slice(budget)
        return True

    def _safe_slice(self, budget: int) -> None:
        """A failing scrub slice must never kill the training loop — on the
        writer's ordered lane an escaped exception would surface as a
        checkpoint-write error at the next submit()/wait()."""
        try:
            self._scan_slice(budget)
        except Exception:
            self.stats["errors"] = self.stats.get("errors", 0) + 1

    def _slice_budget(self) -> int:
        """Bytes this slice may verify: the interval's bytes/s allowance."""
        bps = self.env.scrub_bytes_per_s
        if bps <= 0:
            return DEFAULT_SLICE_BYTES
        return max(1, int(bps * max(self.env.scrub_every, 1.0)))

    def scan_once(self, budget_bytes: Optional[int] = None) -> dict:
        """One full pass over every tier's retained versions (synchronous).

        Returns this pass's counters (the delta against the cumulative
        ``self.stats``).  ``budget_bytes`` bounds the verified bytes — the
        remaining work stays queued for the next call; ``None`` scans
        everything.
        """
        before = dict(self.stats)
        self._refill()
        self._drain(budget_bytes)
        return {k: v - before[k] for k, v in self.stats.items()}

    def _scan_slice(self, budget: int) -> None:
        self.stats["slices"] += 1
        if not self._queue:
            self._refill()
        self._drain(budget)

    def _refill(self) -> None:
        self.stats["passes"] += 1
        self._queue = [
            (slot, version)
            for store, slot, _ in self.cp._chained_stores()
            if self._scrubs_here(store, slot)
            for version in store.retained_versions()
        ]

    def _scrubs_here(self, store, slot: str) -> bool:
        """One scrubbing rank per shared tree: the PFS tier is walked by
        rank 0 only and a node tier by its node leader — N ranks re-decoding
        (and worse, concurrently repairing) the same shared directory would
        multiply the IO and race the in-place rewrites.  The RAM tier is
        rank-local state and is walked by every rank.  Repair-on-read is
        not gated — any rank repairs the tier it is actively restoring from.
        """
        if slot == "pfs":
            return self.cp.comm.rank == 0
        if slot == "node":
            return bool(getattr(store, "is_leader", True))
        return True

    def _drain(self, budget: Optional[int]) -> None:
        spent = 0
        while self._queue:
            if budget is not None and spent >= budget:
                return
            slot, version = self._queue.pop(0)
            spent += self._scrub_version(slot, version)

    def _store(self, slot: str):
        return {"mem": self.cp._mem, "node": self.cp._node,
                "pfs": self.cp._pfs}[slot]

    # ------------------------------------------------------ verify + repair
    def _scrub_version(self, slot: str, version: int) -> int:
        """Verify one (tier, version); repair or quarantine rot.  Returns
        the number of bytes verified (the throttle's unit of work)."""
        store = self._store(slot)
        if store is None:
            return 0
        if slot == "mem":
            return self._scrub_mem(store, version)
        nbytes, _ = self._scrub_disk(store, slot, version)
        if hasattr(store, "scrub_redundancy"):
            pstats = store.scrub_redundancy(version)
            nbytes += pstats["bytes"]
            self.stats["bytes_scanned"] += pstats["bytes"]
            self.stats["parity_checked"] += pstats["checked"]
            self.stats["parity_repaired"] += pstats["repaired"]
            self.stats["unrepairable"] += pstats["unrepairable"]
        return nbytes

    def repair_version(self, store, slot: str, version: int) -> bool:
        """Repair-on-read entry point: verify ``version`` on ``store`` right
        now and repair what fails.  True when the tier ended the call clean
        (something was repaired or nothing was wrong to begin with)."""
        if slot == "mem":
            self._scrub_mem(store, version)
            return store.fabric.complete(store.name, version)
        _, clean = self._scrub_disk(store, slot, version)
        return clean

    # -- disk tiers ----------------------------------------------------------
    def _verify_dir(self, store, vdir: Path
                    ) -> Tuple[Optional[List[str]], int]:
        """([corrupt rel paths], bytes verified); (None, 0) if not local."""
        if not vdir.is_dir():
            return None, 0
        base_dirs = {
            b: Path(store.version_dir(b))
            for b in tiers.read_delta_deps(vdir)
            if Path(store.version_dir(b)).is_dir()
        }
        ctx = IOContext(
            checksum="fletcher",        # force verification of every digest
            codec_version=self.env.codec_version,
            chunk_bytes=self.env.chunk_bytes,
            rel_root=vdir, base_dirs=base_dirs,
        )
        bad: List[str] = []
        nbytes = 0
        for p in sorted(q for q in vdir.rglob("*") if q.is_file()):
            rel = str(p.relative_to(vdir))
            self.stats["files_scanned"] += 1
            try:
                with open(p, "rb") as fh:
                    is_array = fh.read(4) == storage._MAGIC
                if is_array:
                    nbytes += p.stat().st_size
                    # full decode == full verification: every literal chunk
                    # digest, every delta ref down its base chain
                    storage.read_array(p, ctx)
                elif p.suffix == ".json":
                    nbytes += p.stat().st_size
                    json.loads(p.read_text())
            except (CheckpointError, ValueError, OSError):
                bad.append(rel)
        self.stats["bytes_scanned"] += nbytes
        return bad, nbytes

    def _scrub_disk(self, store, slot: str, version: int
                    ) -> Tuple[int, bool]:
        """Verify + repair one disk-tier version.  Returns (bytes verified,
        tier ended clean) — callers on the restore path use the flag instead
        of re-verifying the whole directory."""
        vdir = Path(store.version_dir(version))
        bad, nbytes = self._verify_dir(store, vdir)
        if bad is None:
            return 0, False               # nothing local to serve
        if not bad:
            return nbytes, True
        self.stats["corrupt_found"] += len(bad)
        # 1) redundancy within the tier: set the rotted local copy ASIDE
        #    (never delete — a failed rebuild must leave the original, with
        #    its healthy sibling files, exactly where it was) and
        #    re-materialize from mirror/parity: a bit-identical rebuild
        if getattr(store, "redundancy", "LOCAL") != "LOCAL":
            stash = vdir.with_name(f".quarantine-{vdir.name}")
            shutil.rmtree(stash, ignore_errors=True)
            os.rename(vdir, stash)
            try:
                rebuilt = store.materialize(version)
            except CheckpointError:
                rebuilt = None
            still_bad, extra = (self._verify_dir(store, Path(rebuilt))
                                if rebuilt is not None else (None, 0))
            if still_bad is not None and not still_bad:
                shutil.rmtree(stash, ignore_errors=True)
                self.stats["repaired"] += len(bad)
                return nbytes + extra, True
            # rebuild failed or rebuilt rot: put the original back
            shutil.rmtree(vdir, ignore_errors=True)
            os.rename(stash, vdir)
        # 2) per-file re-encode from a healthy peer-tier copy
        remaining = [rel for rel in bad
                     if not self._repair_file(store, slot, version, rel)]
        if not remaining:
            self.stats["repaired"] += len(bad)
            return nbytes, True
        # 3) quarantine — but only while the version is still restorable
        #    from another tier: deleting the *last* copy would turn an
        #    explicit restore error into a silent fresh start, and a corrupt
        #    copy an operator can salvage beats no copy at all
        self.stats["repaired"] += len(bad) - len(remaining)
        self.stats["unrepairable"] += len(remaining)
        if self._version_elsewhere(slot, version):
            store.forget_version(version)
            self.stats["quarantined"] += 1
        return nbytes, False

    def _version_elsewhere(self, slot: str, version: int) -> bool:
        """Does any other chained tier still hold ``version`` locally?"""
        for peer, pslot, _ in self.cp._chained_stores():
            if pslot == slot:
                continue
            if pslot == "mem":
                if peer.fabric.complete(peer.name, version):
                    return True
            elif Path(peer.version_dir(version)).is_dir():
                return True
        return False

    def _repair_file(self, store, slot: str, version: int, rel: str) -> bool:
        """Re-encode one corrupt file from a verifying peer-tier copy."""
        path = Path(store.version_dir(version)) / rel
        good = self._read_good(slot, version, rel)
        if good is None:
            return False
        kind, payload, params = good
        try:
            if kind == "array":
                # Preserve the corrupt file's chunk grid when its header is
                # still parseable — delta refs into this file resolve by
                # chunk index, so the grid must survive the rewrite.
                mf = storage.read_chunk_manifest(path)
                ctx = IOContext(
                    compress=(mf or params).get("compress", "none"),
                    checksum="fletcher",
                    # keep the original format when the header survived (a
                    # v2 rewrite with no delta_prev is all-literal and
                    # bit-identical to the original full write); refs from
                    # newer versions into this file stay resolvable either
                    # way because the chunk grid below is preserved
                    codec_version=(mf or params).get(
                        "fmt", storage.CODEC_V1),
                    chunk_bytes=int((mf or params).get("chunk_bytes", 0))
                    or self.env.chunk_bytes,
                )
                storage.write_array(path, payload, ctx)
            else:
                tmp = path.with_name(f".tmp-scrub-{path.name}")
                tmp.write_bytes(payload)
                tmp.replace(path)
        except (CheckpointError, OSError):
            return False
        return True

    def _read_good(self, exclude_slot: str, version: int, rel: str
                   ) -> Optional[Tuple[str, object, dict]]:
        """A verified copy of ``rel`` from any other chain member.

        Returns ("array", ndarray, {chunk_bytes, compress}) or ("blob",
        bytes, {}).  The RAM fabric is consulted first (cheapest and already
        digest-guarded), then the other disk tiers, each read with its own
        delta-base chain and full verification.
        """
        if exclude_slot != "mem" and self.cp._mem is not None:
            fabric = self.cp._mem.fabric
            for owner, v, erel, entry in fabric.entries(self.cp.name):
                if v != version or erel != rel:
                    continue
                if entry.verify():
                    if entry.array is not None:
                        return "array", entry.array, {}
                    return "blob", entry.blob, {}
        for peer, pslot, _ in self.cp._chained_stores():
            if pslot in (exclude_slot, "mem"):
                continue
            vdir = Path(peer.version_dir(version))
            p = vdir / rel
            if not p.is_file():
                continue
            try:
                with open(p, "rb") as fh:
                    is_array = fh.read(4) == storage._MAGIC
                if not is_array:
                    return "blob", p.read_bytes(), {}
                base_dirs = {
                    b: Path(peer.version_dir(b))
                    for b in tiers.read_delta_deps(vdir)
                    if Path(peer.version_dir(b)).is_dir()
                }
                ctx = IOContext(checksum="fletcher",
                                codec_version=self.env.codec_version,
                                chunk_bytes=self.env.chunk_bytes,
                                rel_root=vdir, base_dirs=base_dirs)
                arr = storage.read_array(p, ctx)
                mf = storage.read_chunk_manifest(p) or {}
                return "array", arr, mf
            except (CheckpointError, OSError):
                continue
        return None

    # -- memory tier ---------------------------------------------------------
    def _scrub_mem(self, store, version: int) -> int:
        """Verify every resident RAM payload of ``version``; repair rotted
        entries from the disk tiers, retract the version if unrepairable."""
        from repro.core.mem_level import _MemEntry

        fabric = store.fabric
        nbytes = 0
        for owner, v, rel, entry in fabric.entries(store.name):
            if v != version:
                continue
            self.stats["files_scanned"] += 1
            nbytes += entry.nbytes
            if entry.verify():
                continue
            self.stats["corrupt_found"] += 1
            good = self._read_good("mem", version, rel)
            fixed = None
            if good is not None:
                kind, payload, _ = good
                cand = (_MemEntry(payload, None, entry.digest)
                        if kind == "array"
                        else _MemEntry(None, payload, entry.digest))
                # the publish-time digest is the ground truth: only a copy
                # that reproduces it may replace the rotted entry
                if cand.verify():
                    fixed = cand
            if fixed is not None:
                fabric.replace_entry(store.name, owner, version, rel, fixed)
                self.stats["repaired"] += 1
            else:
                # the RAM tier drops unconditionally: a live owner's own
                # entries are served *unverified* on the restore fast path,
                # so detected rot left resident would be served silently —
                # the disk tiers behind it are the durable copies
                self.stats["unrepairable"] += 1
                store.forget_version(version)
                self.stats["quarantined"] += 1
                break
        self.stats["bytes_scanned"] += nbytes
        return nbytes
