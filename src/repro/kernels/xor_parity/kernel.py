"""Pallas TPU kernel: XOR parity encode / reconstruct (SCR partner-XOR analog).

The node-level checkpoint tier groups G data-parallel peer hosts and stores
``parity = m_0 ^ m_1 ^ ... ^ m_{G-1}`` on a peer outside the group, so any
single lost member is recoverable as the XOR of the parity with the G-1
survivors (paper §2.4: SCR's partner-XOR level).

TPU mapping: the group dimension G is small (paper default 8) and the byte
payload N is huge (GBs), so the kernel tiles N into VMEM-resident blocks of
``block_n`` uint32 lanes and XOR-reduces the (G, block_n) tile on the VPU.
A (G=8, block_n=16384) uint32 tile is 512 KiB — far under the ~16 MiB VMEM
budget, leaving room for the Pallas pipeline's double buffering.

Alignment: uint32 lanes with ``block_n`` a multiple of 128 match the (8, 128)
int32 VREG tiling; callers pad the byte payload to 4·block_n bytes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xor_kernel(stacked_ref, out_ref):
    """XOR-reduce the (G, block_n) tile over its group axis into (1, block_n)."""
    tile = stacked_ref[...]
    g = tile.shape[0]
    acc = tile[0:1]                            # keep 2-D: (1, block_n)
    for i in range(1, g):                      # G is a small static constant
        acc = jnp.bitwise_xor(acc, tile[i : i + 1])
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def xor_reduce(
    stacked: jnp.ndarray, *, block_n: int = 16384, interpret: bool = False
) -> jnp.ndarray:
    """XOR-reduce a ``(G, N) uint32`` array over axis 0 via Pallas.

    N must be a multiple of ``block_n`` (callers pad); ``block_n`` must be a
    multiple of 128 (VREG lane alignment).  Returns a ``(N,) uint32`` parity.
    """
    if stacked.ndim != 2:
        raise ValueError(f"expected (G, N), got {stacked.shape}")
    if stacked.dtype != jnp.uint32:
        raise TypeError(f"expected uint32, got {stacked.dtype}")
    g, n = stacked.shape
    if block_n % 128:
        raise ValueError(f"block_n={block_n} must be a multiple of 128")
    if n % block_n:
        raise ValueError(f"N={n} must be a multiple of block_n={block_n}")
    grid = (n // block_n,)
    out = pl.pallas_call(
        _xor_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((g, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.uint32),
        interpret=interpret,
    )(stacked)
    return out[0]
