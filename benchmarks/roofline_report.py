"""§Roofline report: the three-term table from the dry-run JSON records.

Reads experiments/dryrun/<mesh>/<arch>__<shape>.json (produced by
``python -m repro.launch.dryrun --all``) and emits one row per cell:
compute/memory/collective seconds, the dominant term, the
MODEL_FLOPS/HLO_FLOPS ratio and the per-device memory fit.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

DRYRUN_DIR = Path("experiments/dryrun")


def main(full: bool = False) -> None:
    if not DRYRUN_DIR.exists():
        emit("roofline", "missing_dryrun_records", 0, "count")
        return
    n = 0
    for mesh_dir in sorted(DRYRUN_DIR.iterdir()):
        if not mesh_dir.is_dir():
            continue
        for f in sorted(mesh_dir.glob("*.json")):
            d = json.loads(f.read_text())
            r = d["roofline"]
            cell = f"{d['arch']}__{d['shape']}__{d['mesh']}"
            emit("roofline", cell + "__compute", round(r["compute_s"], 4),
                 "s")
            emit("roofline", cell + "__memory", round(r["memory_s"], 4), "s")
            emit("roofline", cell + "__collective",
                 round(r["collective_s"], 4), "s")
            emit("roofline", cell + "__dominant", r["dominant"], "")
            emit("roofline", cell + "__model_hlo_ratio",
                 round(d["model_hlo_ratio"], 4), "")
            emit("roofline", cell + "__peak_gb",
                 round(d["memory"]["peak_per_device_bytes"] / 1e9, 2), "GB")
            n += 1
    emit("roofline", "cells_reported", n, "count")


if __name__ == "__main__":
    main()
