"""``python -m repro.top`` — a curses-free refreshing terminal dashboard
for the live telemetry plane.

Two sources, one view:

* ``--url http://host:PORT`` — scrape a running job's exporter
  (``CRAFT_METRICS_PORT``): ``/metrics`` Prometheus text for the series,
  ``/healthz`` JSON for breaker states and checkpoint age.
* ``--trace run.jsonl`` — aggregate a ``CRAFT_TRACE`` file into the same
  panels (post-hoc ``top`` over a finished or still-appending run).

The screen redraws with plain ANSI (clear + home) every ``--interval``
seconds; ``--once`` prints a single frame and exits (tests, piping).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import urllib.error
import urllib.request
from typing import Dict, Optional

from repro.core.metrics import parse_prometheus

_CLEAR = "\x1b[2J\x1b[H"
_BOLD, _DIM, _RED, _GREEN, _YELLOW, _RESET = (
    "\x1b[1m", "\x1b[2m", "\x1b[31m", "\x1b[32m", "\x1b[33m", "\x1b[0m")


# ----------------------------------------------------------------- model
def _blank_model() -> dict:
    return {
        "source": "", "status": None, "version": None, "age_s": None,
        "tiers": {},        # slot -> {writes, bytes, seconds}
        "decisions": {},    # reason -> count
        "breakers": {},     # slot -> state string
        "degraded": {},     # slot -> count
        "restores": {},     # slot -> count
        "async": {},        # pending / oldest_pending_s / stalls
        "scrub": {},        # scrubber counters
        "counters": {},     # headline cp_* totals
    }


def _labels(label_str: str) -> Dict[str, str]:
    out = {}
    for part in label_str.split(","):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k.strip()] = v.strip().strip('"')
    return out


def model_from_url(url: str, timeout: float = 5.0) -> dict:
    m = _blank_model()
    m["source"] = url
    with urllib.request.urlopen(url.rstrip("/") + "/metrics",
                                timeout=timeout) as resp:
        series = parse_prometheus(resp.read().decode("utf-8"))
    try:
        with urllib.request.urlopen(url.rstrip("/") + "/healthz",
                                    timeout=timeout) as resp:
            health = json.loads(resp.read().decode("utf-8"))
    except urllib.error.HTTPError as exc:   # 503 == unhealthy, body is JSON
        health = json.loads(exc.read().decode("utf-8"))
    m["status"] = health.get("status")
    for name, cp in health.get("checkpoints", {}).items():
        m["version"] = cp.get("version")
        m["age_s"] = cp.get("last_write_age_s")
        for slot, b in cp.get("breakers", {}).items():
            m["breakers"][slot] = b.get("state", "?")
        m["async"].setdefault("pending", cp.get("async_backlog", 0))
        m["async"].setdefault("oldest_pending_s",
                              cp.get("async_oldest_pending_s", 0.0))
        if "scrubber" in cp:
            m["scrub"].update(cp["scrubber"])
    for lab, v in series.get("craft_tier_writes_total", {}).items():
        slot = _labels(lab).get("tier", "?")
        m["tiers"].setdefault(slot, {})["writes"] = int(v)
    for lab, v in series.get("craft_tier_write_bytes_total", {}).items():
        slot = _labels(lab).get("tier", "?")
        m["tiers"].setdefault(slot, {})["bytes"] = v
    for lab, v in series.get("craft_tier_write_seconds_sum", {}).items():
        slot = _labels(lab).get("tier", "?")
        m["tiers"].setdefault(slot, {})["seconds"] = v
    for lab, v in series.get("craft_policy_decisions_total", {}).items():
        m["decisions"][_labels(lab).get("reason", "?")] = int(v)
    for lab, v in series.get("craft_restores_total", {}).items():
        m["restores"][_labels(lab).get("slot", "?")] = int(v)
    for metric, key in (("craft_async_stall_warnings_total", "stalls"),
                        ("craft_async_pending", "pending"),
                        ("craft_async_oldest_pending_s", "oldest_pending_s")):
        for _, v in series.get(metric, {}).items():
            m["async"][key] = v
    for metric, vals in series.items():
        if metric.startswith("craft_cp_") and metric.endswith("_total"):
            key = metric[len("craft_cp_"):-len("_total")]
            m["counters"][key] = sum(vals.values())
        if metric.startswith("craft_scrub_"):
            key = metric[len("craft_scrub_"):].replace("_total", "")
            m["scrub"][key] = sum(vals.values())
    return m


def model_from_trace(path: str) -> dict:
    m = _blank_model()
    m["source"] = path
    counters = m["counters"]
    last_t = 0.0
    last_write_t = None
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except ValueError:     # torn tail of a live file
                continue
            kind = ev.get("kind")
            last_t = max(last_t, float(ev.get("t", 0.0)))
            if kind == "tier_write":
                slot = ev.get("slot", "?")
                t = m["tiers"].setdefault(
                    slot, {"writes": 0, "bytes": 0, "seconds": 0.0})
                t["writes"] = t.get("writes", 0) + 1
                t["bytes"] = t.get("bytes", 0) + ev.get("nbytes", 0)
                t["seconds"] = t.get("seconds", 0.0) + ev.get("seconds", 0.0)
                counters["writes"] = counters.get("writes", 0) + 1
                last_write_t = ev.get("t", last_t)
            elif kind == "decision":
                reason = ev.get("reason") or "skip"
                m["decisions"][reason] = m["decisions"].get(reason, 0) + 1
            elif kind == "breaker":
                slot = ev.get("slot", "?")
                m["breakers"][slot] = "open"
                counters["breaker_trips"] = \
                    counters.get("breaker_trips", 0) + 1
            elif kind == "degraded":
                slot = ev.get("slot", "?")
                m["degraded"][slot] = m["degraded"].get(slot, 0) + 1
                counters["degraded_writes"] = \
                    counters.get("degraded_writes", 0) + 1
            elif kind == "restore":
                slot = ev.get("slot", ev.get("tier", "?"))
                m["restores"][slot] = m["restores"].get(slot, 0) + 1
            elif kind == "async_stall":
                m["async"]["stalls"] = m["async"].get("stalls", 0) + 1
                m["async"]["oldest_pending_s"] = ev.get("age_s", 0.0)
            elif kind == "scheduled":
                m["version"] = ev.get("version", m["version"])
    m["status"] = "trace"
    if last_write_t is not None:
        m["age_s"] = round(last_t - last_write_t, 3)
    return m


# ------------------------------------------------------------------ view
def _fmt_bytes(n) -> str:
    n = float(n or 0)
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if n < 1024 or unit == "TiB":
            return f"{n:,.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024.0
    return f"{n:.1f} TiB"


def render(m: dict, color: bool = True) -> str:
    def c(code: str, s: str) -> str:
        return f"{code}{s}{_RESET}" if color else s

    status = m.get("status") or "?"
    scol = {_GREEN: ("ok", "trace"), _RED: ("unhealthy",)}
    col = next((k for k, v in scol.items() if status in v), _YELLOW)
    lines = [
        c(_BOLD, "craft top") + f"  —  {m.get('source', '')}",
        f"status: {c(col, status)}"
        + (f"   version: v-{m['version']}" if m.get("version") is not None
           else "")
        + (f"   last write: {m['age_s']:.1f}s ago"
           if m.get("age_s") is not None else ""),
        "",
        c(_BOLD, f"{'TIER':<8}{'WRITES':>8}{'BYTES':>14}{'SECONDS':>10}"
                 f"{'BREAKER':>11}{'DEGRADED':>10}{'RESTORES':>10}"),
    ]
    slots = sorted(set(m["tiers"]) | set(m["breakers"])
                   | set(m["degraded"]) | set(m["restores"]))
    for slot in slots:
        t = m["tiers"].get(slot, {})
        state = m["breakers"].get(slot, "-")
        bcol = {"closed": _GREEN, "open": _RED,
                "half_open": _YELLOW}.get(state, _DIM)
        lines.append(
            f"{slot:<8}{t.get('writes', 0):>8}"
            f"{_fmt_bytes(t.get('bytes', 0)):>14}"
            f"{t.get('seconds', 0.0):>10.3f}"
            + c(bcol, f"{state:>11}")
            + f"{m['degraded'].get(slot, 0):>10}"
            f"{m['restores'].get(slot, 0):>10}")
    if not slots:
        lines.append(c(_DIM, "  (no tier activity yet)"))
    lines.append("")
    if m["decisions"]:
        total = sum(m["decisions"].values())
        lines.append(c(_BOLD, "DECISIONS") + f"  ({total} total)")
        for reason, n in sorted(m["decisions"].items(),
                                key=lambda kv: -kv[1]):
            bar = "#" * max(1, int(30 * n / max(1, total)))
            lines.append(f"  {reason:<12}{n:>8}  {c(_DIM, bar)}")
        lines.append("")
    a = m["async"]
    if a:
        stall = int(a.get("stalls", 0))
        lines.append(
            c(_BOLD, "ASYNC") + f"   pending: {int(a.get('pending', 0))}"
            f"   oldest: {float(a.get('oldest_pending_s', 0.0)):.2f}s"
            f"   stalls: " + (c(_RED, str(stall)) if stall else "0"))
    if m["scrub"]:
        s = m["scrub"]
        lines.append(
            c(_BOLD, "SCRUB") + "   "
            + "   ".join(f"{k}: {int(v)}" for k, v in sorted(s.items())
                         if v))
    hl = {k: v for k, v in m["counters"].items()
          if k in ("writes", "degraded_writes", "breaker_trips", "retries",
                   "read_repairs", "abandoned_writes") and v}
    if hl:
        lines.append(
            c(_BOLD, "TOTALS") + "  "
            + "   ".join(f"{k}: {int(v)}" for k, v in sorted(hl.items())))
    return "\n".join(lines) + "\n"


# ------------------------------------------------------------------- CLI
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.top",
        description="Live (or trace-replay) dashboard for the CRAFT "
                    "telemetry plane.")
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--url", help="exporter base URL, e.g. "
                                   "http://localhost:9109")
    src.add_argument("--trace", help="CRAFT_TRACE JSONL file to aggregate")
    ap.add_argument("--interval", type=float, default=2.0,
                    help="refresh period in seconds (default 2)")
    ap.add_argument("--once", action="store_true",
                    help="print one frame and exit")
    ap.add_argument("--no-color", action="store_true",
                    help="plain text (no ANSI colors)")
    args = ap.parse_args(argv)
    color = not args.no_color and sys.stdout.isatty()

    def frame() -> str:
        if args.url:
            return render(model_from_url(args.url), color=color)
        return render(model_from_trace(args.trace), color=color)

    if args.once:
        sys.stdout.write(frame())
        return 0
    try:
        while True:
            out = frame()
            sys.stdout.write(_CLEAR + out)
            sys.stdout.flush()
            time.sleep(max(0.2, args.interval))
    except KeyboardInterrupt:
        sys.stdout.write("\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
