"""End-to-end training driver: model + optimizer + data + CRAFT CR/AFT.

This is the paper's Listing 2/9 pattern at framework scale:

    state = init (params, opt_state, step, data cursor)
    cp = Checkpoint("train", comm); cp.add("state", ...); cp.commit()
    cp.restart_if_needed()
    while step < total:
        batch = data.batch(cursor.step)
        state = train_step(state, batch)
        cp.update_and_write(step, cp_freq)

Wrapped in an AFT zone when a fault-tolerant communicator is supplied, so
process failures re-enter the loop from the latest checkpoint (shrinking or
non-shrinking recovery per CRAFT_COMM_RECOVERY_POLICY).

Runs on any mesh: the production 16×16 (dry-run), a few forced host
devices, or the single CPU device (examples/tests with ``--tiny``).
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs import get_config
from repro.core import Box, Checkpoint
from repro.core import metrics as craft_metrics
from repro.core.aft import aft_zone
from repro.data.pipeline import DataCursor, SyntheticTokens
from repro.models import model as M
from repro.models.common import ModelConfig
from repro.optim.adamw import OptimConfig, adamw_init
from repro.sharding.activations import use_rules
from repro.sharding.logical import LogicalRules, shard_specs
from repro.train.steps import StepTimer, TrainStepConfig, make_train_step

log = logging.getLogger("craft.train")


@dataclasses.dataclass
class TrainConfig:
    arch: str = "h2o-danube-1.8b"
    tiny: bool = True
    steps: int = 50
    global_batch: int = 8
    seq_len: int = 64
    cp_freq: int = 10
    cp_name: str = "train"
    seed: int = 0
    microbatches: int = 1
    lr: float = 3e-4
    sequence_parallel: bool = False
    fail_at_step: Optional[int] = None   # in-process fault injection (tests)


def _mesh_rules(mesh, sequence_parallel: bool):
    rules = LogicalRules(mesh)
    if sequence_parallel:
        rules.rules["embed_act"] = "model"
    return rules


def init_state(cfg: ModelConfig, ocfg: OptimConfig, mesh, rules, seed: int):
    """Sharded (params, opt_state) on the mesh."""
    plog = M.param_logical(cfg)
    pshapes = jax.eval_shape(lambda k: M.init_params(k, cfg),
                             jax.random.PRNGKey(seed))
    pspecs = shard_specs(rules, plog, pshapes)
    from repro.optim.adamw import opt_state_logical

    with jax.set_mesh(mesh):
        params = jax.jit(
            lambda k: M.init_params(k, cfg),
            out_shardings=jax.tree_util.tree_map(
                lambda s: jax.sharding.NamedSharding(mesh, s), pspecs,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)),
        )(jax.random.PRNGKey(seed))
        oshapes = jax.eval_shape(lambda p: adamw_init(p, ocfg), params)
        ospecs = shard_specs(
            rules, opt_state_logical(plog, ocfg, params=params), oshapes)
        opt_state = jax.jit(
            lambda p: adamw_init(p, ocfg),
            out_shardings=jax.tree_util.tree_map(
                lambda s: jax.sharding.NamedSharding(mesh, s), ospecs,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)),
        )(params)
    return params, opt_state, pspecs, ospecs


def run(tc: TrainConfig, comm=None, mesh=None,
        on_step: Optional[Callable[[int, Dict], None]] = None,
        env=None) -> Dict:
    """Train; returns {"losses": [...], "final_step": int, "stats": {...}}.

    With ``comm`` (an FTComm), the whole loop runs inside an AFT zone: the
    checkpoint is (re)opened inside the zone body (paper Listing 9) so every
    recovery re-reads the latest consistent version.
    """
    cfg = get_config(tc.arch, tiny=tc.tiny)
    if mesh is None:
        mesh = jax.make_mesh((1,), ("data",))
    rules = _mesh_rules(mesh, tc.sequence_parallel)
    ocfg = OptimConfig(lr=tc.lr, master_fp32=False, warmup_steps=5,
                       total_steps=max(tc.steps, 10))
    scfg = TrainStepConfig(microbatches=tc.microbatches, loss_chunk=32)
    step_fn = make_train_step(cfg, ocfg, scfg)

    n_shards = comm.size if comm is not None else 1
    shard = comm.rank if comm is not None else 0
    data = SyntheticTokens(
        vocab=cfg.vocab, seq_len=tc.seq_len, global_batch=tc.global_batch,
        seed=tc.seed, n_shards=1, shard=0)   # deterministic global batch
    del shard, n_shards

    def body(comm_inner):
        params, opt_state, pspecs, ospecs = init_state(
            cfg, ocfg, mesh, rules, tc.seed)
        state_box = Box({"params": params, "opt": opt_state})
        step_box = Box(0)
        cursor = DataCursor(0)

        cp = Checkpoint(tc.cp_name, comm_inner, env=env)
        cp.add("state", state_box)
        cp.add("step", step_box)
        cp.add("cursor", FuncBox(cursor))
        cp.commit()
        cp.restart_if_needed()

        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        losses: List[float] = []
        timer = StepTimer()
        t0 = time.perf_counter()
        try:
            while step_box.value < tc.steps:
                step_t0 = time.perf_counter()
                batch_np = data.batch(cursor.step)
                with jax.set_mesh(mesh):
                    bspec = rules.spec(
                        "batch", "seq", shape=batch_np["tokens"].shape)
                    batch = {
                        k: jax.device_put(
                            v, jax.sharding.NamedSharding(mesh, bspec))
                        for k, v in batch_np.items()
                    }
                    with use_rules(rules):
                        p, o, metrics = jit_step(
                            state_box.value["params"],
                            state_box.value["opt"], batch)
                state_box.value = {"params": p, "opt": o}
                cursor.step += 1
                step_box.value += 1
                loss = float(metrics["loss"])
                losses.append(loss)
                # compute-only step time (checkpoint writes excluded) feeds
                # the scheduler's rework model and the result stats
                timer.observe(time.perf_counter() - step_t0)
                if cp.policy is not None and timer.last is not None:
                    cp.policy.observe_step_seconds(timer.last)
                # live telemetry: step cadence + loss on the scoreboard
                if craft_metrics.REGISTRY.enabled:
                    craft_metrics.observe("train_step_seconds", timer.last)
                    craft_metrics.set_gauge("train_loss", loss)
                    craft_metrics.set_gauge("train_step", step_box.value)
                if on_step is not None:
                    on_step(step_box.value, metrics)
                if (tc.fail_at_step is not None
                        and step_box.value == tc.fail_at_step
                        and comm_inner is not None
                        and getattr(comm_inner, "rank", 0) == 0
                        and getattr(comm_inner, "epoch", 0) == 0):
                    # deterministic in-process fault injection (paper §5.3);
                    # epoch-0 guard: fire once, not on every AFT retry
                    raise_fault(comm_inner)
                cp.update_and_write(step_box.value, tc.cp_freq)
                if cp.should_stop:
                    # preemption flush landed or the walltime guard wrote its
                    # final checkpoint — exit the loop cleanly; the next job
                    # (or the respawned one) resumes from that version
                    break
            cp.wait()
            return {
                "losses": losses,
                "final_step": step_box.value,
                "wall_s": time.perf_counter() - t0,
                "step_seconds": timer.ewma,
                "stats": dict(cp.stats),
            }
        finally:
            cp.close()

    if comm is None:
        return body(None)
    return aft_zone(comm, body)


def raise_fault(comm) -> None:
    """Deterministic fail-stop of this rank (benchmarks use the runtime's
    kill -9 instead; this is the paper's in-program injection variant)."""
    from repro.core.comm import ProcFailedError

    raise ProcFailedError(f"injected fault at rank {comm.rank}",
                          failed=[comm.rank])


class FuncBox:
    """Adapter exposing a DataCursor as a checkpointable POD box."""

    def __init__(self, cursor: DataCursor):
        self.cursor = cursor

    @property
    def value(self) -> int:
        return self.cursor.step

    @value.setter
    def value(self, v: int) -> None:
        self.cursor.step = int(v)


# Box duck-typing: Checkpoint.add() wraps Box instances via isinstance, so
# register FuncBox through the adapter registry instead.
from repro.core.checkpointables import FuncCp, register_adapter  # noqa: E402

register_adapter(
    lambda obj: isinstance(obj, FuncBox),
    lambda obj: FuncCp(lambda: obj.value, lambda v: setattr(obj, "value", v)),
)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--full", dest="tiny", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--cp-freq", type=int, default=10)
    args = ap.parse_args()
    tc = TrainConfig(arch=args.arch, tiny=args.tiny, steps=args.steps,
                     global_batch=args.global_batch, seq_len=args.seq_len,
                     cp_freq=args.cp_freq)
    logging.basicConfig(level=logging.INFO)
    out = run(tc, on_step=lambda s, m: print(
        f"step {s:4d} loss {float(m['loss']):.4f} "
        f"gnorm {float(m['grad_norm']):.3f}"))
    print(f"done: {out['final_step']} steps in {out['wall_s']:.1f}s; "
          f"checkpoint stats {out['stats']}")


if __name__ == "__main__":
    main()
