"""CheckpointPolicy edge cases: Daly monotonicity, fake-clock walltime
guard, in-process signal flush, backpressure stretching, post-recovery
estimator reset, and the bit-identical preemption restore."""
import signal

import numpy as np
import pytest

from repro.core import Box, Checkpoint, CraftEnv
from repro.core import scheduler as sched
from repro.core.checkpointables import NdArrayCp
from repro.core.scheduler import CheckpointPolicy, daly_interval
from repro.core.tiers import StorageTier


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


class CostTier(StorageTier):
    """Cost-model stub: only the base-class write_cost surface is used."""

    def __init__(self, slot: str):
        self.label = slot

    def stage(self, version):
        raise NotImplementedError

    def publish(self, staged, version, extra_meta=None):
        raise NotImplementedError

    def abort(self, staged):
        raise NotImplementedError

    def latest_version(self) -> int:
        return 0

    def version_dir(self, version):
        raise NotImplementedError

    def invalidate_all(self) -> None:
        pass


def make_policy(envmap, slots=("pfs",), clock=None, **kw):
    env = CraftEnv.capture({"CRAFT_CP_PATH": "/unused", **envmap})
    stores = {s: CostTier(s) for s in slots}
    return CheckpointPolicy(env, stores, clock=clock or FakeClock(), **kw), \
        stores


# ---------------------------------------------------------------- the formula
class TestDalyInterval:
    def test_monotonic_in_cost(self):
        mtbf = 3600.0
        costs = [0.01, 0.1, 1.0, 10.0, 100.0]
        intervals = [daly_interval(c, mtbf) for c in costs]
        assert intervals == sorted(intervals)
        assert all(a < b for a, b in zip(intervals, intervals[1:]))

    def test_young_first_order_limit(self):
        # δ ≪ M: Daly reduces to Young's √(2δM)
        assert daly_interval(1.0, 10_000_000.0) == pytest.approx(
            (2 * 1.0 * 10_000_000.0) ** 0.5, rel=0.01)

    def test_saturates_at_mtbf(self):
        assert daly_interval(500.0, 100.0) == 500.0   # write-cost floor
        assert daly_interval(250.0, 120.0) == 250.0

    def test_monotonic_and_continuous_across_saturation(self):
        mtbf = 100.0
        costs = [50.0, 150.0, 199.9, 200.0, 200.1, 400.0]
        intervals = [daly_interval(c, mtbf) for c in costs]
        assert intervals == sorted(intervals)
        # no cliff at δ = 2M
        assert abs(daly_interval(200.0, mtbf)
                   - daly_interval(199.999, mtbf)) < 0.01

    def test_degenerate_inputs(self):
        assert daly_interval(0.0, 3600.0) == 0.0
        assert daly_interval(1.0, 0.0) == float("inf")

    def test_never_below_write_cost(self):
        assert daly_interval(50.0, 30.0) >= 50.0


# ---------------------------------------------------------------- env parsing
class TestTierEveryParsing:
    def test_bare_auto_applies_to_all(self):
        env = CraftEnv.capture({"CRAFT_TIER_EVERY": "auto"})
        for slot in ("mem", "node", "pfs"):
            assert env.tier_every_for(slot) == "auto"

    def test_counts_and_mixtures(self):
        env = CraftEnv.capture(
            {"CRAFT_TIER_EVERY": "mem:1,node:8,pfs:auto"})
        assert env.tier_every_for("mem") == 1
        assert env.tier_every_for("node") == 8
        assert env.tier_every_for("pfs") == "auto"

    def test_unnamed_slots_stay_legacy(self):
        env = CraftEnv.capture({"CRAFT_TIER_EVERY": "pfs:64"})
        assert env.tier_every_for("node") is None

    @pytest.mark.parametrize("bad", [
        "disk:3", "pfs", "pfs:0", "pfs:-2", "pfs:3,pfs:4", "pfs:x",
    ])
    def test_rejects(self, bad):
        with pytest.raises(ValueError):
            CraftEnv.capture({"CRAFT_TIER_EVERY": bad})

    def test_cp_signal_parsing(self):
        env = CraftEnv.capture({"CRAFT_CP_SIGNAL": "SIGUSR1, term"})
        assert env.cp_signal == ("SIGUSR1", "SIGTERM")
        with pytest.raises(ValueError):
            CraftEnv.capture({"CRAFT_CP_SIGNAL": "SIGNOPE"})


# ----------------------------------------------------------------- cadences
class TestCadences:
    def test_opportunity_counts(self):
        policy, _ = make_policy({"CRAFT_TIER_EVERY": "node:1,pfs:3"},
                                slots=("node", "pfs"))
        version = 0
        pfs_hits = []
        for it in range(1, 10):
            d = policy.need_checkpoint(it, next_version=version + 1)
            assert d.write                    # node:1 writes every time
            if "pfs" in d.tiers:
                pfs_hits.append(it)
            version += 1
            policy.record_written(d, version)
        assert pfs_hits == [3, 6, 9]

    def test_probe_then_write_counts_once(self):
        policy, _ = make_policy({"CRAFT_TIER_EVERY": "pfs:2"})
        d1 = policy.need_checkpoint(1, next_version=1)
        d1b = policy.need_checkpoint(1, next_version=1)   # probe again
        assert d1.write == d1b.write is False
        d2 = policy.need_checkpoint(2, next_version=1)
        assert d2.write

    def test_auto_seeds_then_spaces_out(self):
        clock = FakeClock()
        policy, stores = make_policy(
            {"CRAFT_TIER_EVERY": "auto", "CRAFT_MTBF_SECONDS": "800"},
            clock=clock)
        # no cost estimate → due immediately (the seeding write)
        d = policy.need_checkpoint(1, next_version=1)
        assert d.write
        stores["pfs"].record_write(1.0)
        policy.record_written(d, 1)
        expected = daly_interval(1.0, 800.0)
        clock.advance(expected * 0.5)
        assert not policy.need_checkpoint(2, next_version=2).write
        clock.advance(expected * 0.6)
        assert policy.need_checkpoint(3, next_version=2).write

    def test_legacy_pfs_every_preserved(self):
        # no CRAFT_TIER_EVERY → version-number modulo, bit-compatible
        policy, _ = make_policy({"CRAFT_PFS_EVERY": "4"},
                                slots=("node", "pfs"))
        tiers_by_version = {}
        for v in range(1, 9):
            d = policy.need_checkpoint(v, next_version=v)
            tiers_by_version[v] = d.tiers
            policy.record_written(d, v)
        for v, tiers in tiers_by_version.items():
            assert ("pfs" in tiers) == (v % 4 == 0)
            assert "node" in tiers


# -------------------------------------------------------------- backpressure
class TestBackpressure:
    def test_auto_interval_stretches(self):
        clock = FakeClock()
        queue = {"depth": 0}
        env = CraftEnv.capture({
            "CRAFT_CP_PATH": "/unused", "CRAFT_TIER_EVERY": "auto",
            "CRAFT_MTBF_SECONDS": "800",
        })
        stores = {"pfs": CostTier("pfs")}
        policy = CheckpointPolicy(env, stores, clock=clock,
                                  backpressure=lambda: queue["depth"])
        d = policy.need_checkpoint(1, next_version=1)
        stores["pfs"].record_write(1.0)
        policy.record_written(d, 1)
        base = daly_interval(1.0, 800.0)
        clock.advance(base * 1.5)
        queue["depth"] = 3                 # saturated → interval × 4
        assert not policy.need_checkpoint(2, next_version=2).write
        assert policy.stats["backpressure_stretches"] == 1
        queue["depth"] = 0
        assert policy.need_checkpoint(3, next_version=2).write

    def test_count_cadence_defers_and_owes(self):
        queue = {"depth": 1}
        env = CraftEnv.capture({
            "CRAFT_CP_PATH": "/unused", "CRAFT_TIER_EVERY": "pfs:2",
        })
        stores = {"pfs": CostTier("pfs")}
        policy = CheckpointPolicy(env, stores, clock=FakeClock(),
                                  backpressure=lambda: queue["depth"])
        assert not policy.need_checkpoint(1, next_version=1).write
        assert not policy.need_checkpoint(2, next_version=1).write  # deferred
        queue["depth"] = 0
        d = policy.need_checkpoint(3, next_version=1)   # debt repaid
        assert d.write and d.tiers == ("pfs",)


# ------------------------------------------------------- triggers and resets
class TestWalltimeGuard:
    def test_final_checkpoint_fires_once(self):
        clock = FakeClock()
        policy, stores = make_policy({
            "CRAFT_TIER_EVERY": "pfs:1000",       # cadence would never fire
            "CRAFT_WALLTIME_SECONDS": "100",
            "CRAFT_WALLTIME_MARGIN_SECONDS": "10",
        }, clock=clock)
        clock.advance(50.0)
        assert not policy.need_checkpoint(1, next_version=1).write
        clock.advance(41.0)                       # 91 ≥ 100 − 10
        d = policy.need_checkpoint(2, next_version=1)
        assert d.write and d.final and d.full and d.sync
        assert d.tiers == ("pfs",)
        policy.record_written(d, 1)
        assert policy.should_stop
        clock.advance(5.0)
        assert not policy.need_checkpoint(3, next_version=2).final

    def test_margin_extends_by_estimated_write_cost(self):
        clock = FakeClock()
        policy, stores = make_policy({
            "CRAFT_WALLTIME_SECONDS": "100",
            "CRAFT_WALLTIME_MARGIN_SECONDS": "10",
        }, clock=clock)
        stores["pfs"].record_write(20.0)          # expensive tier
        clock.advance(75.0)                       # 75 ≥ 100 − 10 − 20
        assert policy.need_checkpoint(1, next_version=1).final

    def test_real_checkpoint_walltime_restores(self, tmp_path):
        clock = FakeClock()
        env = CraftEnv.capture({
            "CRAFT_CP_PATH": str(tmp_path), "CRAFT_USE_SCR": "0",
            "CRAFT_WALLTIME_SECONDS": "100",
            "CRAFT_WALLTIME_MARGIN_SECONDS": "5",
            "CRAFT_TIER_EVERY": "pfs:1000000",    # only the guard can write
        })
        arr = np.arange(64, dtype=np.float64)
        with Checkpoint("wt", env=env, clock=clock) as cp:
            cp.add("it", Box(0))
            cp.add("arr", arr)
            cp.commit()
            for it in range(1, 5):
                clock.advance(30.0)
                arr += 1.0
                cp.update_and_write(it)
                if cp.should_stop:
                    break
            assert cp.stats["final_writes"] == 1
            expect = arr.copy()
        restored = np.zeros_like(expect)
        env2 = CraftEnv.capture({"CRAFT_CP_PATH": str(tmp_path),
                                 "CRAFT_USE_SCR": "0"})
        with Checkpoint("wt", env=env2) as cp2:
            cp2.add("it", Box(0))
            cp2.add("arr", restored)
            cp2.commit()
            assert cp2.restart_if_needed()
        assert np.array_equal(restored, expect)


class TestPreemption:
    def test_flag_forces_sync_full_flush_of_deepest_tier(self):
        policy, _ = make_policy(
            {"CRAFT_TIER_EVERY": "node:1000,pfs:1000"},
            slots=("node", "pfs"))
        assert not policy.need_checkpoint(1, next_version=1).write
        policy.trigger_preemption()
        d = policy.need_checkpoint(2, next_version=1)
        assert d.write and d.sync and d.full and d.reason == "preempt"
        assert d.tiers == ("pfs",)                 # deepest only
        policy.record_written(d, 1)
        assert policy.should_stop
        # once flushed, the trigger does not re-fire
        assert not policy.need_checkpoint(3, next_version=2).write

    def test_in_process_signal_sets_flag(self, tmp_path):
        env = CraftEnv.capture({
            "CRAFT_CP_PATH": str(tmp_path), "CRAFT_USE_SCR": "0",
            "CRAFT_CP_SIGNAL": "SIGUSR1",
        })
        old = signal.getsignal(signal.SIGUSR1)
        with Checkpoint("sig", env=env) as cp:
            cp.add("x", Box(1))
            cp.commit()
            assert not cp.policy.preempted
            signal.raise_signal(signal.SIGUSR1)    # no real kill in CI
            assert cp.policy.preempted
            assert cp.update_and_write(1, cp_freq=1000)   # gate overridden
            assert cp.stats["preempt_flushes"] == 1
            assert cp.should_stop
        # close() restored the previous disposition
        assert signal.getsignal(signal.SIGUSR1) == old

    def test_preempt_flush_restores_bit_identically(self, tmp_path):
        envmap = {
            "CRAFT_CP_PATH": str(tmp_path), "CRAFT_USE_SCR": "0",
            "CRAFT_WRITE_ASYNC": "1", "CRAFT_DELTA": "1",
            "CRAFT_CHUNK_BYTES": str(64 * 1024),
        }
        rng = np.random.default_rng(0)
        arr = rng.standard_normal((256 * 1024,)).astype(np.float32)
        with Checkpoint("pre", env=CraftEnv.capture(envmap)) as cp:
            cp.add("arr", NdArrayCp(arr))
            cp.commit()
            cp.update_and_write()                  # async full
            arr[::1024] += 1.0
            cp.update_and_write()                  # async delta
            arr[::512] -= 0.25                     # unflushed mutation
            expect = arr.copy()
            cp.policy.trigger_preemption()
            assert cp.update_and_write()           # sync full flush
            assert cp.stats["preempt_flushes"] == 1
        restored = np.zeros_like(expect)
        with Checkpoint("pre", env=CraftEnv.capture(envmap)) as cp2:
            cp2.add("arr", NdArrayCp(restored))
            cp2.commit()
            assert cp2.restart_if_needed()
        assert np.array_equal(restored, expect)


class TestRecoveryReset:
    def test_epoch_bump_resets_estimators_and_forces_full(self):
        policy, stores = make_policy({"CRAFT_TIER_EVERY": "pfs:1"})
        d = policy.need_checkpoint(1, next_version=1)
        stores["pfs"].record_write(2.0)
        policy.record_written(d, 1)
        assert stores["pfs"].write_cost() == 2.0
        sched.notify_recovery()                    # what aft.py does
        d2 = policy.need_checkpoint(2, next_version=2)
        assert d2.write and d2.full and d2.reason == "recovery-full"
        assert stores["pfs"].write_cost() is None  # EWMA dropped
        assert policy.stats["recovery_resets"] == 1
        policy.record_written(d2, 2)
        d3 = policy.need_checkpoint(3, next_version=3)
        assert d3.write and not d3.full            # back to deltas

    def test_empirical_mtbf_from_engine(self):
        from repro.core.ftengine import CollectiveEngine

        engine = CollectiveEngine({0: 0, 1: 1})
        assert engine.empirical_mtbf() is None
        engine.set_occupant(0, 1, "u1")
        engine.mark_dead("u1")
        mtbf = engine.empirical_mtbf()
        assert mtbf is not None and mtbf > 0
        assert engine.failure_count() == 1

    def test_policy_prefers_configured_over_empirical(self):
        policy, _ = make_policy({"CRAFT_MTBF_SECONDS": "123"},
                                mtbf_fn=lambda: 999.0)
        assert policy.mtbf() == 123.0
        policy2, _ = make_policy({}, mtbf_fn=lambda: 999.0)
        assert policy2.mtbf() == 999.0
        policy3, _ = make_policy({})
        assert policy3.mtbf() == sched.DEFAULT_MTBF_SECONDS


class TestStepTimer:
    def test_observe_and_tick(self):
        from repro.train.steps import StepTimer

        clk = FakeClock()
        t = StepTimer(alpha=0.5, clock=clk)
        assert t.tick() is None
        clk.advance(2.0)
        assert t.tick() == 2.0
        t.observe(4.0)
        assert t.ewma == pytest.approx(3.0)
        t.observe(-1.0)                            # ignored
        assert t.last == 4.0


class TestDalyDegenerateEdges:
    """The full degenerate-input contract the simulator/tuner rely on —
    a tuner grid sweep hits these corners routinely."""

    def test_negative_cost_is_zero_interval(self):
        assert daly_interval(-5.0, 3600.0) == 0.0

    def test_infinite_mtbf_never_checkpoints(self):
        import math
        assert daly_interval(1.0, math.inf) == math.inf

    def test_negative_mtbf_is_infinite(self):
        assert daly_interval(1.0, -10.0) == float("inf")

    def test_saturation_boundary_exact(self):
        # δ == 2M is the first saturated point: max(M, δ) == δ there
        assert daly_interval(200.0, 100.0) == 200.0
        # just below the boundary the closed form applies and stays ≥ δ
        assert daly_interval(199.999999, 100.0) >= 199.999999


class TestDegradedWalltimeInteraction:
    """A degraded (always-due) slot must not mask the walltime guard, and
    the guard's final full flush covers the whole chain including the
    degraded slot — the last checkpoint before the job dies is the one
    write that must not skip a tier that might be back."""

    def test_walltime_fires_with_degraded_slot(self):
        clock = FakeClock()
        policy, stores = make_policy({
            "CRAFT_TIER_EVERY": "node:4,pfs:1000",
            "CRAFT_WALLTIME_SECONDS": "100",
            "CRAFT_WALLTIME_MARGIN_SECONDS": "10",
        }, slots=("node", "pfs"), clock=clock)
        policy.note_degraded("node")
        # degraded slot is owed every opportunity while we're inside budget
        d = policy.need_checkpoint(1, next_version=1)
        assert d.write and "node" in d.tiers and not d.final
        policy.record_written(d, 1)
        clock.advance(95.0)
        d = policy.need_checkpoint(2, next_version=2)
        assert d.final and d.full and d.sync
        assert d.tiers == ("node", "pfs")        # whole chain, degraded too
        assert "node" in policy.degraded_slots()  # still owed until landed

    def test_degraded_slot_cleared_only_by_landing(self):
        clock = FakeClock()
        policy, stores = make_policy({
            "CRAFT_TIER_EVERY": "node:2,pfs:1000",
        }, slots=("node", "pfs"), clock=clock)
        policy.note_degraded("node")
        d = policy.need_checkpoint(1, next_version=1)
        assert "node" in d.tiers
        # scheduling alone (record_written) must NOT clear the debt — the
        # write may have been routed away from the slot again
        policy.record_written(d, 1)
        assert "node" in policy.degraded_slots()
        policy.note_tier_written("node")
        assert "node" not in policy.degraded_slots()


class TestRetryJitterBand:
    def test_backoff_jitter_stays_in_band(self):
        """Delay before retry k is backoff · 2^(k−1) · u with u ∈ [0.5, 1.5):
        the fleet-desynchronization contract docs/tuning.md quotes."""
        import errno as _errno

        from repro.core.health import retry_call

        delays = []
        calls = [0]

        def flaky():
            calls[0] += 1
            if calls[0] <= 40:
                raise OSError(_errno.EIO, "transient")
            return "ok"

        assert retry_call(flaky, retries=40, backoff_ms=8.0,
                          sleep=delays.append) == "ok"
        assert len(delays) == 40
        for k, d in enumerate(delays, start=1):
            base = (8.0 / 1000.0) * (2 ** (k - 1))
            assert base * 0.5 <= d < base * 1.5


class TestOnlineRetune:
    def test_retune_replaces_count_cadences_from_live_costs(self):
        clock = FakeClock()
        policy, stores = make_policy({
            "CRAFT_TIER_EVERY": "pfs:1",
            "CRAFT_TUNE_ONLINE": "1",
            "CRAFT_TUNE_EVERY_S": "10",
            "CRAFT_MTBF_SECONDS": "3600",
        }, clock=clock)
        # live estimates: 1 s steps, 2 s writes → Daly interval ≫ 1 step
        policy.observe_step_seconds(1.0)
        stores["pfs"].record_write(2.0)
        assert policy.cadence("pfs") == 1
        clock.advance(11.0)
        policy.need_checkpoint(1, next_version=1)
        expected = max(1, int(round(
            daly_interval(2.0, 3600.0) / policy.step_seconds())))
        assert policy.cadence("pfs") == expected > 1
        assert policy.stats["online_retunes"] == 1
        # stable inputs ⇒ no further retunes
        clock.advance(11.0)
        policy.need_checkpoint(2, next_version=1)
        assert policy.stats["online_retunes"] == 1

    def test_retune_off_by_default_and_gated_on_step_estimate(self):
        clock = FakeClock()
        policy, stores = make_policy({
            "CRAFT_TIER_EVERY": "pfs:1",
            "CRAFT_TUNE_ONLINE": "1",
            "CRAFT_TUNE_EVERY_S": "10",
        }, clock=clock)
        stores["pfs"].record_write(2.0)
        clock.advance(11.0)
        policy.need_checkpoint(None, next_version=1)   # no step estimate yet
        assert policy.cadence("pfs") == 1
        off, _ = make_policy({"CRAFT_TIER_EVERY": "pfs:1"}, clock=clock)
        off.observe_step_seconds(1.0)
        clock.advance(100.0)
        off.need_checkpoint(1, next_version=1)
        assert off.stats["online_retunes"] == 0
