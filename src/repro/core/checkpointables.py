"""Built-in CRAFT-checkpointable data types (paper §2.2) + extension registry.

Paper default types → JAX analogs:

    POD               → ``Box`` holding int/float/complex/bool/str
    POD array         → ``np.ndarray`` (restored in place)
    POD multi-array   → ``np.ndarray`` (any rank; optional column selection)
    MPI derived type  → pytree of arrays (``PytreeCp``) — the structured-data
                        case; snapshot (``update``) plays the role of MPI_Pack
    CpBase derived    → any user subclass of :class:`repro.core.cpbase.CpBase`

Additionally ``JaxArrayCp`` checkpoints a (possibly sharded) ``jax.Array`` by
saving each addressable shard with its global index — the manifest makes the
file set *topology independent* so a restore may land on a different mesh
(elastic restore, DESIGN.md §2).

The extension mechanism of paper §2.3 (Listing 6's "interface function") is
the :func:`register_adapter` registry: library authors map their type to a
wrapper factory once, after which ``Checkpoint.add()`` works directly on
objects of that type.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Callable, Generic, Optional, TypeVar

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.cpbase import CheckpointError, CpBase, IOContext
from repro.core import reshard, storage, tiers
from repro.core.device_snapshot import DeviceSnapshotter

T = TypeVar("T")


class Box(Generic[T]):
    """Mutable holder — the Python analog of the paper's ``&variable``.

    JAX arrays and Python scalars are immutable, so the library hands out a
    box whose ``.value`` the application reads/writes; ``restart_if_needed``
    restores into the box.
    """

    __slots__ = ("value",)

    def __init__(self, value: T):
        self.value = value

    def __repr__(self) -> str:  # pragma: no cover
        return f"Box({self.value!r})"


# --------------------------------------------------------------------------
# POD
# --------------------------------------------------------------------------
_POD_TYPES = (int, float, complex, bool, str)


class PodCp(CpBase):
    """A single plain-old-data element held in a :class:`Box`."""

    def __init__(self, box: Box):
        if not isinstance(box, Box):
            raise TypeError("PodCp expects a Box")
        self.box = box
        self._buf = box.value

    def update(self) -> None:
        self._buf = self.box.value

    def write(self, dir_path: Path, ctx: IOContext) -> None:
        val = self._buf
        kind = type(val).__name__
        if isinstance(val, complex):
            payload = {"kind": "complex", "re": val.real, "im": val.imag}
        elif isinstance(val, _POD_TYPES):
            payload = {"kind": kind, "value": val}
        else:
            raise CheckpointError(f"not a POD: {type(val)}")
        storage.write_json(dir_path / "pod.json", payload)

    def read(self, dir_path: Path, ctx: IOContext) -> None:
        p = dir_path / "pod.json"
        if not p.exists():
            raise CheckpointError(f"missing {p}")
        payload = storage.read_json(p)
        if payload["kind"] == "complex":
            self.box.value = complex(payload["re"], payload["im"])
        else:
            caster = {"int": int, "float": float, "bool": bool, "str": str}[
                payload["kind"]
            ]
            self.box.value = caster(payload["value"])
        self._buf = self.box.value

    def nbytes(self) -> int:
        return 16


# --------------------------------------------------------------------------
# numpy arrays (POD array / multi-array) — restored IN PLACE like the paper's
# pointer-to-array semantics.
# --------------------------------------------------------------------------
class NdArrayCp(CpBase):
    def __init__(self, arr: np.ndarray, to_cp_col: Optional[int] = None):
        if not isinstance(arr, np.ndarray):
            raise TypeError("NdArrayCp expects np.ndarray")
        self.arr = arr
        self.to_cp_col = to_cp_col  # paper's POD multi-array column selection
        self._buf = self._select().copy()

    def _select(self) -> np.ndarray:
        if self.to_cp_col is None:
            return self.arr
        return self.arr[:, self.to_cp_col]

    def update(self) -> None:
        np.copyto(self._buf, self._select())

    def write(self, dir_path: Path, ctx: IOContext) -> None:
        storage.write_array(dir_path / "array.bin", self._buf, ctx)

    def read(self, dir_path: Path, ctx: IOContext) -> None:
        loaded = storage.read_array(dir_path / "array.bin", ctx)
        target = self._select()
        if loaded.shape != target.shape:
            raise CheckpointError(
                f"shape mismatch: stored {loaded.shape} vs live {target.shape}"
            )
        # no _buf sync here: every write path calls update() first, so the
        # extra copy would only slow the restore hot path down
        target[...] = loaded.astype(target.dtype, copy=False)

    def nbytes(self) -> int:
        return self._buf.nbytes


# --------------------------------------------------------------------------
# jax.Array (possibly sharded) in a Box
# --------------------------------------------------------------------------
def _assign_shard(out: np.ndarray, idx, arr: np.ndarray) -> None:
    """Write a loaded shard into the assembly buffer (rank-0 safe)."""
    if out.ndim == 0:
        out[...] = np.asarray(arr, dtype=out.dtype).reshape(())
    else:
        out[idx] = arr


def _shard_slices(index) -> list:
    """Serialize a shard index (tuple of slices) as [[start, stop], ...]."""
    out = []
    for sl in index:
        out.append([0 if sl.start is None else int(sl.start),
                    None if sl.stop is None else int(sl.stop)])
    return out


# --------------------------------------------------------------------------
# elastic N→M assembly (shared by JaxArrayCp / PytreeCp / ShardCp reads)
# --------------------------------------------------------------------------
def _aux_item_dirs(dir_path: Path, ctx: IOContext) -> list:
    """This item's directory inside each peer version root (``ctx.aux_dirs``),
    as ``[(item_dir, root), ...]`` — only roots where the item exists."""
    if not ctx.aux_dirs or ctx.rel_root is None:
        return []
    try:
        rel = dir_path.relative_to(ctx.rel_root)
    except ValueError:
        return []
    out = []
    for root in ctx.aux_dirs:
        d = Path(root) / rel
        if d.is_dir():
            out.append((d, Path(root)))
    return out


def _collect_manifests(dir_path: Path, ctx: IOContext, pattern: str) -> list:
    """Union of writer manifests across the materialized dir and peer roots.

    Returns ``[(manifest, dir, root), ...]`` ordered by manifest filename;
    ``root`` is None for the main dir.  A manifest present in both (the
    restoring rank's own file, mirrored on a peer) is taken from the main
    dir — its delta refs resolve against ``ctx.base_dirs`` directly.
    """
    found = {}
    for mp in dir_path.glob(pattern):
        found[mp.name] = (storage.read_json(mp), dir_path, None)
    for d, root in _aux_item_dirs(dir_path, ctx):
        for mp in d.glob(pattern):
            if mp.name not in found:
                found[mp.name] = (storage.read_json(mp), d, root)
    return [found[k] for k in sorted(found)]


def _open_range_reader(path: Path, ctx: IOContext, root: Optional[Path]):
    """A :class:`storage.ChunkRangeReader` for a shard file — delta refs in a
    peer-root file resolve against *that* tree's sibling ``v-<B>`` dirs."""
    if root is None:
        return storage.ChunkRangeReader(path, ctx)
    rel = path.relative_to(root)
    bases = None
    if ctx.base_dirs:
        bases = {int(v): Path(root).parent / tiers.version_dir_name(int(v))
                 for v in ctx.base_dirs}
    return storage.ChunkRangeReader(path, ctx, rel=rel, base_dirs=bases)


def _read_aux_array(path: Path, ctx: IOContext, root: Path) -> np.ndarray:
    """Whole-array read of a peer-root file (full-span range read, so v2
    refs chase the peer's base chain instead of ``ctx.base_dirs``)."""
    rdr = _open_range_reader(path, ctx, root)
    payload = bytes(rdr.read(0, rdr.nbytes))
    return storage._restore_shape(payload, rdr.header, path)


def _read_global_leaf(ctx: IOContext, gshape, dtype, sources, live,
                      where: str):
    """Assemble one global array from shard files written on any topology.

    ``sources`` is ``[(index_spec, path, root), ...]`` — one entry per shard
    file across every writer's manifest (``root`` None = materialized main
    dir, else the peer version root the file lives under).  ``ctx.reshard``
    picks the strategy:

    * legacy full assembly — every file is read whole into a global buffer
      (same cost profile as before this module existed);
    * range assembly — each extent the restoring process actually needs is
      mapped onto the writers' extents (:func:`reshard.overlap_runs`) and
      only the overlapping chunk ranges are verified/decoded/fetched.

    ``auto`` takes the range path when the live value is a ``jax.Array``
    whose addressable extents don't span the global array (a real N→M or
    multi-host restore) or when shards live in peer roots; a same-topology
    single-host restore keeps the legacy path.  Returns a ``jax.Array`` on
    the live sharding when ``live`` is one, else the global ndarray.
    """
    gshape = tuple(int(s) for s in gshape)
    dtype = np.dtype(dtype)
    exts = [(reshard.resolve_index(spec, gshape), Path(path), root)
            for spec, path, root in sources]
    full_ext = tuple((0, s) for s in gshape)
    live_is_jax = isinstance(live, jax.Array)
    if live_is_jax and tuple(live.shape) != gshape:
        raise CheckpointError(
            f"shape mismatch: stored {gshape} vs live {tuple(live.shape)} "
            f"({where})"
        )
    dst_exts = None
    if live_is_jax:
        dst_exts = []
        for s in live.addressable_shards:
            e = reshard.resolve_index(s.index, gshape)
            if e not in dst_exts:
                dst_exts.append(e)
    has_aux = any(root is not None for _, _, root in exts)
    mode = getattr(ctx, "reshard", "auto")
    use_range = (mode == "range") or has_aux or (
        mode == "auto" and dst_exts is not None
        and any(e != full_ext for e in dst_exts)
    )
    if not use_range:
        out = np.empty(gshape, dtype=dtype)
        filled = np.zeros(gshape, dtype=bool) if out.size else None
        for ext, path, _root in exts:
            arr = storage.read_array(path, ctx)
            idx = tuple(slice(lo, hi) for lo, hi in ext)
            _assign_shard(out, idx, arr)
            if filled is not None:
                filled[idx] = True
        if filled is not None and not filled.all():
            raise CheckpointError(
                f"incomplete shard coverage under {where} "
                f"({int(filled.sum())}/{filled.size} elements)"
            )
        if live_is_jax:
            return jax.device_put(out, live.sharding)
        return out
    rdr_cache: dict = {}

    def open_reader(key):
        r = rdr_cache.get(key[0])
        if r is None:
            r = _open_range_reader(key[1], ctx, key[2])
            rdr_cache[key[0]] = r
        return r

    srcs = [(e, (str(p), p, root)) for e, p, root in exts]
    blocks = {}
    for e in (dst_exts if dst_exts is not None else [full_ext]):
        block, covered = reshard.assemble_extent(e, dtype, srcs, open_reader)
        if covered is not None and not covered.all():
            raise CheckpointError(
                f"incomplete shard coverage for extent {e} under {where} "
                f"({int(covered.sum())}/{covered.size} elements)"
            )
        blocks[e] = block
    if live_is_jax:
        shard_arrs = [
            jax.device_put(
                blocks[reshard.resolve_index(s.index, gshape)], s.device)
            for s in live.addressable_shards
        ]
        return jax.make_array_from_single_device_arrays(
            gshape, live.sharding, shard_arrs)
    return blocks[full_ext]


class JaxArrayCp(CpBase):
    """Checkpoint a (sharded) ``jax.Array`` held in a Box.

    Write: each *addressable* shard goes to ``shard-<r>-<i>.bin`` (r = process
    rank — paper's process-local file naming) plus ``array.json`` recording the
    global shape/dtype and every shard's global index.  Read: shards are
    assembled into the global array and ``device_put`` onto the sharding of
    the *live* box value — which may differ from the writer's topology
    (elastic restore).
    """

    def __init__(self, box: Box, *, device_snapshot: bool = False,
                 chunk_bytes: Optional[int] = None,
                 device_hist: bool = True):
        if not isinstance(box, Box):
            raise TypeError("JaxArrayCp expects a Box holding a jax.Array")
        self.box = box
        self._buf: list = []     # [(index, np.ndarray, device_meta | None)]
        self._meta: dict = {}
        self._snap = (
            DeviceSnapshotter(chunk_bytes or IOContext.chunk_bytes,
                              with_hist=device_hist)
            if device_snapshot else None
        )
        self.update()

    def update(self) -> None:
        arr = self.box.value
        if not isinstance(arr, jax.Array):
            raise CheckpointError(f"Box no longer holds a jax.Array: {type(arr)}")
        shards = arr.addressable_shards
        if self._snap is not None:
            # Fused device pass per shard: digest + dirty mask + entropy on
            # device, then only the dirty chunks cross to the host mirror.
            self._buf = []
            for i, s in enumerate(shards):
                host, dmeta = self._snap.snapshot(i, s.data)
                self._buf.append((s.index, host, dmeta))
        else:
            # Device→host snapshot of every addressable shard — one batched
            # transfer instead of a blocking per-shard np.asarray.
            hosts = jax.device_get([s.data for s in shards])
            self._buf = [
                (s.index, np.asarray(h), None)
                for s, h in zip(shards, hosts)
            ]
        self._meta = {
            "global_shape": list(arr.shape),
            "dtype": storage._dtype_to_name(arr.dtype),
        }

    def write(self, dir_path: Path, ctx: IOContext) -> None:
        shards_meta = []
        for i, (index, host, dmeta) in enumerate(self._buf):
            fname = f"shard-{ctx.proc_rank}-{i}.bin"
            if dmeta is not None:
                ctx.record_device_meta(
                    storage._manifest_name(dir_path / fname, ctx), dmeta)
            storage.write_array(dir_path / fname, host, ctx)
            shards_meta.append({"file": fname, "index": _shard_slices(index)})
        storage.write_json(
            dir_path / f"array-{ctx.proc_rank}.json",
            {**self._meta, "shards": shards_meta},
        )

    def read(self, dir_path: Path, ctx: IOContext) -> None:
        manifests = _collect_manifests(dir_path, ctx, "array-*.json")
        if not manifests:
            raise CheckpointError(f"no array manifest under {dir_path}")
        meta0 = manifests[0][0]
        gshape = tuple(meta0["global_shape"])
        dtype = storage._dtype_from_name(meta0["dtype"])
        sources = [
            (sh["index"], d / sh["file"], root)
            for m, d, root in manifests
            for sh in m["shards"]
        ]
        live = self.box.value
        value = _read_global_leaf(
            ctx, gshape, dtype, sources, live, str(dir_path))
        if isinstance(live, jax.Array):
            self.box.value = value
        else:  # no live value to infer placement from: single-device put
            self.box.value = jnp.asarray(value)

    def nbytes(self) -> int:
        return sum(h.nbytes for _, h, _ in self._buf)


# --------------------------------------------------------------------------
# pytree of arrays (train states, optimizer states, KV caches, ...)
# --------------------------------------------------------------------------
class PytreeCp(CpBase):
    """Checkpoint an arbitrary pytree held in a Box.

    The tree structure comes from the *live* value at read time (CRAFT
    semantics: state is constructed first, then restored into), so leaves are
    stored by flattened position with shape/dtype validation.  JAX leaves are
    restored onto the live leaf's sharding — restoring onto a different mesh
    reshards transparently.
    """

    def __init__(self, box: Box, *, device_snapshot: bool = False,
                 chunk_bytes: Optional[int] = None,
                 device_hist: bool = True):
        self.box = box
        self._buf: list = []
        self._treedef = None
        self._snap = (
            DeviceSnapshotter(chunk_bytes or IOContext.chunk_bytes,
                              with_hist=device_hist)
            if device_snapshot else None
        )
        self.update()

    def update(self) -> None:
        leaves, treedef = jax.tree_util.tree_flatten(self.box.value)
        self._treedef = treedef
        buf = []
        jax_shards = []      # (buf_item, shard) pairs for one batched D2H
        for i, leaf in enumerate(leaves):
            if isinstance(leaf, jax.Array):
                item = {
                    "kind": "jax",
                    "global_shape": list(leaf.shape),
                    "dtype": storage._dtype_to_name(leaf.dtype),
                    "shards": [],
                }
                for j, s in enumerate(leaf.addressable_shards):
                    if self._snap is not None:
                        host, dmeta = self._snap.snapshot((i, j), s.data)
                        item["shards"].append((s.index, host, dmeta))
                    else:
                        jax_shards.append((item, s))
                buf.append(item)
            elif isinstance(leaf, np.ndarray):
                buf.append({"kind": "np", "data": leaf.copy()})
            else:
                buf.append({"kind": "pod", "data": leaf})
        if jax_shards:
            # One batched device→host transfer for every jax leaf's shards.
            hosts = jax.device_get([s.data for _, s in jax_shards])
            for (item, s), h in zip(jax_shards, hosts):
                item["shards"].append((s.index, np.asarray(h), None))
        self._buf = buf

    def write(self, dir_path: Path, ctx: IOContext) -> None:
        manifest = {"n_leaves": len(self._buf), "leaves": []}
        for i, item in enumerate(self._buf):
            if item["kind"] == "jax":
                shards_meta = []
                for j, (index, host, dmeta) in enumerate(item["shards"]):
                    fname = f"leaf{i}-shard-{ctx.proc_rank}-{j}.bin"
                    if dmeta is not None:
                        ctx.record_device_meta(
                            storage._manifest_name(dir_path / fname, ctx),
                            dmeta)
                    storage.write_array(dir_path / fname, host, ctx)
                    shards_meta.append(
                        {"file": fname, "index": _shard_slices(index)}
                    )
                manifest["leaves"].append(
                    {
                        "kind": "jax",
                        "global_shape": item["global_shape"],
                        "dtype": item["dtype"],
                        "shards": shards_meta,
                    }
                )
            elif item["kind"] == "np":
                fname = f"leaf{i}.bin"
                storage.write_array(dir_path / fname, item["data"], ctx)
                manifest["leaves"].append({"kind": "np", "file": fname})
            else:
                manifest["leaves"].append(
                    {"kind": "pod", "value": _pod_json(item["data"])}
                )
        storage.write_json(dir_path / f"tree-{ctx.proc_rank}.json", manifest)

    def read(self, dir_path: Path, ctx: IOContext) -> None:
        # parse every writer's manifest once up front — the per-leaf shard
        # merge below would otherwise re-parse them per leaf (O(leaves²));
        # peer version roots (elastic N→M node-tier restores) contribute
        # their manifests alongside the materialized dir's
        parsed = _collect_manifests(dir_path, ctx, "tree-*.json")
        if not parsed:
            raise CheckpointError(f"no pytree manifest under {dir_path}")
        manifest = parsed[0][0]
        live_leaves, treedef = jax.tree_util.tree_flatten(self.box.value)
        if manifest["n_leaves"] != len(live_leaves):
            raise CheckpointError(
                f"pytree leaf count mismatch: stored {manifest['n_leaves']} "
                f"vs live {len(live_leaves)}"
            )
        new_leaves = []
        for i, (spec, live) in enumerate(zip(manifest["leaves"], live_leaves)):
            if spec["kind"] == "jax":
                gshape = tuple(spec["global_shape"])
                dtype = storage._dtype_from_name(spec["dtype"])
                sources = [    # merge shard sets from all writer procs
                    (sh["index"], d / sh["file"], root)
                    for m, d, root in parsed
                    for sh in m["leaves"][i].get("shards", [])
                ]
                value = _read_global_leaf(
                    ctx, gshape, dtype, sources, live,
                    f"{dir_path} (leaf {i})")
                new_leaves.append(
                    value if isinstance(live, jax.Array)
                    else jnp.asarray(value))
            elif spec["kind"] == "np":
                # every writer stores an identical copy — prefer the
                # materialized dir's, fall back to any peer root's
                _m, d, root = next(
                    (e for e in parsed if e[2] is None), parsed[0])
                if root is None:
                    arr = storage.read_array(d / spec["file"], ctx)
                else:   # replicated leaf only present in a peer's tree
                    arr = _read_aux_array(d / spec["file"], ctx, root)
                # memory-tier reads hand out read-only views of shared
                # buffers; a tree leaf is owned by the application, so copy
                new_leaves.append(arr if arr.flags.writeable else arr.copy())
            else:
                new_leaves.append(_pod_unjson(spec["value"]))
        self.box.value = jax.tree_util.tree_unflatten(treedef, new_leaves)

    def nbytes(self) -> int:
        total = 0
        for item in self._buf:
            if item["kind"] == "jax":
                total += sum(h.nbytes for _, h, _ in item["shards"])
            elif item["kind"] == "np":
                total += item["data"].nbytes
        return total


# --------------------------------------------------------------------------
# one rank's rectangular slice of a global array (host-side domain
# decomposition — the paper's redistributable-domain case)
# --------------------------------------------------------------------------
class ShardCp(CpBase):
    """Checkpoint one rank's block of a global array, held as a host ndarray.

    The on-disk format is :class:`JaxArrayCp`'s (``shard-<rank>-<i>.bin`` +
    ``array-<rank>.json``), so the file set is topology independent: a
    checkpoint written by N ``ShardCp`` ranks restores onto M ranks with any
    other block decomposition — each restoring rank range-reads exactly its
    own extent out of the writers' chunk grids, never assembling the global
    array in memory.  ``box.value`` holds the writable block.
    """

    def __init__(self, box: Box, global_shape, index):
        if not isinstance(box, Box):
            raise TypeError("ShardCp expects a Box holding an ndarray block")
        self.box = box
        self.global_shape = tuple(int(s) for s in global_shape)
        self.index = reshard.resolve_index(index, self.global_shape)
        block = np.asarray(box.value)
        want = tuple(hi - lo for lo, hi in self.index)
        if self.global_shape and tuple(block.shape) != want:
            raise CheckpointError(
                f"block shape {tuple(block.shape)} does not match extent "
                f"{self.index} of global {self.global_shape}"
            )
        self._buf = block.copy()

    def update(self) -> None:
        self._buf = np.asarray(self.box.value).copy()

    def write(self, dir_path: Path, ctx: IOContext) -> None:
        fname = f"shard-{ctx.proc_rank}-0.bin"
        storage.write_array(dir_path / fname, self._buf, ctx)
        storage.write_json(
            dir_path / f"array-{ctx.proc_rank}.json",
            {
                "global_shape": list(self.global_shape),
                "dtype": storage._dtype_to_name(self._buf.dtype),
                "shards": [{
                    "file": fname,
                    "index": [[lo, hi] for lo, hi in self.index],
                }],
            },
        )

    def read(self, dir_path: Path, ctx: IOContext) -> None:
        manifests = _collect_manifests(dir_path, ctx, "array-*.json")
        if not manifests:
            raise CheckpointError(f"no array manifest under {dir_path}")
        meta0 = manifests[0][0]
        gshape = tuple(meta0["global_shape"])
        if gshape != self.global_shape:
            raise CheckpointError(
                f"global shape mismatch: stored {gshape} vs live "
                f"{self.global_shape}"
            )
        dtype = storage._dtype_from_name(meta0["dtype"])
        srcs = [
            (reshard.resolve_index(sh["index"], gshape),
             (str(d / sh["file"]), d / sh["file"], root))
            for m, d, root in manifests
            for sh in m["shards"]
        ]
        rdr_cache: dict = {}

        def open_reader(key):
            r = rdr_cache.get(key[0])
            if r is None:
                r = _open_range_reader(key[1], ctx, key[2])
                rdr_cache[key[0]] = r
            return r

        block, covered = reshard.assemble_extent(
            self.index, dtype, srcs, open_reader)
        if covered is not None and not covered.all():
            raise CheckpointError(
                f"incomplete shard coverage for extent {self.index} under "
                f"{dir_path} ({int(covered.sum())}/{covered.size} elements)"
            )
        self.box.value = block
        self._buf = block.copy()

    def nbytes(self) -> int:
        return self._buf.nbytes


def _pod_json(v):
    if isinstance(v, complex):
        return {"kind": "complex", "re": v.real, "im": v.imag}
    return {"kind": type(v).__name__, "value": v}


def _pod_unjson(d):
    if d["kind"] == "complex":
        return complex(d["re"], d["im"])
    return {"int": int, "float": float, "bool": bool, "str": str, "NoneType": lambda v: None}[
        d["kind"]
    ](d.get("value"))


# --------------------------------------------------------------------------
# getter/setter adapter (for data not reachable via a Box, e.g. an object
# attribute or a library handle)
# --------------------------------------------------------------------------
class FuncCp(CpBase):
    def __init__(self, get: Callable[[], Any], set_: Callable[[Any], None]):
        self._get, self._set = get, set_
        self._inner: Optional[CpBase] = None
        self._box = Box(None)
        self.update()

    def _wrap(self, value) -> CpBase:
        self._box.value = value
        if isinstance(value, jax.Array):
            return JaxArrayCp(self._box)
        if isinstance(value, np.ndarray):
            return NdArrayCp(value)
        if isinstance(value, _POD_TYPES):
            return PodCp(self._box)
        return PytreeCp(self._box)

    def update(self) -> None:
        self._inner = self._wrap(self._get())
        self._inner.update()

    def write(self, dir_path: Path, ctx: IOContext) -> None:
        assert self._inner is not None
        self._inner.write(dir_path, ctx)

    def read(self, dir_path: Path, ctx: IOContext) -> None:
        assert self._inner is not None
        self._inner.read(dir_path, ctx)
        self._set(self._box.value)

    def nbytes(self) -> int:
        return self._inner.nbytes() if self._inner else 0


# --------------------------------------------------------------------------
# extension registry (paper §2.3, Listing 6)
# --------------------------------------------------------------------------
_ADAPTERS: list = []   # [(predicate, factory)]


def register_adapter(predicate: Callable[[Any], bool],
                     factory: Callable[[Any], CpBase]) -> None:
    """Register an ``add()`` adapter for a user/library data type.

    ``predicate(obj)`` decides applicability; ``factory(obj)`` returns the
    checkpointable wrapper.  This is the paper's "interface function inside
    CRAFT" (Listing 6) — after registration, end users can pass their objects
    straight to ``Checkpoint.add()``.
    """
    _ADAPTERS.append((predicate, factory))


def wrap(obj: Any, **kw) -> CpBase:
    """Dispatch an ``add()`` argument to a checkpointable (paper's overloads)."""
    if isinstance(obj, CpBase):
        return obj
    for predicate, factory in _ADAPTERS:
        if predicate(obj):
            return factory(obj)
    if isinstance(obj, Box):
        v = obj.value
        snap_kw = {
            "device_snapshot": kw.get("device_snapshot", False),
            "chunk_bytes": kw.get("chunk_bytes"),
            "device_hist": kw.get("device_hist", True),
        }
        if isinstance(v, jax.Array):
            return JaxArrayCp(obj, **snap_kw)
        if isinstance(v, _POD_TYPES):
            return PodCp(obj)
        return PytreeCp(obj, **snap_kw)
    if isinstance(obj, np.ndarray):
        return NdArrayCp(obj, to_cp_col=kw.get("to_cp_col"))
    if isinstance(obj, jax.Array):
        raise TypeError(
            "jax.Array is immutable — wrap it in repro.core.Box(arr) so the "
            "restored value can be handed back (paper's &ptr analog)"
        )
    if isinstance(obj, _POD_TYPES):
        raise TypeError(
            f"{type(obj).__name__} is immutable — wrap it in repro.core.Box(x)"
        )
    raise TypeError(
        f"don't know how to checkpoint {type(obj)}; subclass CpBase or "
        "register_adapter() it (paper §2.3)"
    )
