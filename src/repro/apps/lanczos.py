"""Lanczos eigensolver on a matrix-free graphene Hamiltonian (paper §5.1).

The paper's showcase application finds extremal eigenvalues of a sparse
matrix from the quantum-mechanical description of electron transport in
graphene, generated on the fly (never read from disk).  TPU adaptation
(DESIGN.md §2): instead of a GHOST CRS SpMV we keep the same on-the-fly
property with a *matrix-free stencil* matvec — the nearest-neighbor
tight-binding Hamiltonian of the honeycomb lattice acting on a state laid
out as an (nx, ny, 2) grid (2 = the A/B sublattices):

    (H ψ)_A(x, y) = t · [ψ_B(x, y) + ψ_B(x-1, y) + ψ_B(x, y-1)]
    (H ψ)_B(x, y) = t · [ψ_A(x, y) + ψ_A(x+1, y) + ψ_A(x, y+1)]

(periodic boundaries via jnp.roll) + an optional on-site disorder term.
Dense stencil ops, no gathers — TPU-idiomatic, same math as the paper's
benchmark family.  H is Hermitian, spectrum ⊂ [-3|t|-W, 3|t|+W].

The Lanczos loop is CRAFT-checkpointed exactly like the paper's benchmark:
the two live Lanczos vectors, α/β arrays, and the iteration counter.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Box, Checkpoint


@dataclasses.dataclass(frozen=True)
class GrapheneConfig:
    nx: int = 64
    ny: int = 64
    t: float = 1.0           # hopping
    disorder: float = 0.0    # on-site disorder amplitude W
    seed: int = 0

    @property
    def n(self) -> int:
        return self.nx * self.ny * 2


def onsite(cfg: GrapheneConfig) -> jnp.ndarray:
    if cfg.disorder == 0.0:
        return jnp.zeros((cfg.nx, cfg.ny, 2), jnp.float32)
    key = jax.random.PRNGKey(cfg.seed)
    return cfg.disorder * jax.random.uniform(
        key, (cfg.nx, cfg.ny, 2), jnp.float32, -1.0, 1.0)


def matvec(cfg: GrapheneConfig, eps: jnp.ndarray, psi: jnp.ndarray):
    """H @ psi for psi of shape (nx, ny, 2) — generated on the fly."""
    a, b = psi[..., 0], psi[..., 1]
    hb = cfg.t * (a + jnp.roll(a, -1, 0) + jnp.roll(a, -1, 1))
    ha = cfg.t * (b + jnp.roll(b, 1, 0) + jnp.roll(b, 1, 1))
    out = jnp.stack([ha, hb], axis=-1)
    return out + eps * psi


def _normalize(v):
    nrm = jnp.sqrt(jnp.sum(v * v))
    return v / nrm, nrm


@dataclasses.dataclass
class LanczosResult:
    eigenvalue: float
    alphas: np.ndarray
    betas: np.ndarray
    iterations: int
    wall_s: float
    cp_stats: Dict
    restarted_at: int


def run_lanczos(
    cfg: GrapheneConfig,
    n_iter: int = 300,
    cp_freq: int = 0,               # 0 = no checkpointing
    cp_name: str = "lanczos",
    comm=None,
    env=None,
    fail_at: Optional[int] = None,  # raise after this iteration (tests)
    extra_work_s: float = 0.0,      # pad per-iteration compute (benchmarks)
) -> LanczosResult:
    """Plain 3-term Lanczos for the extremal eigenvalue of H.

    With ``cp_freq`` > 0, the Lanczos state (v_prev, v_cur, α, β, iter) is a
    CRAFT checkpoint — exactly the paper's benchmark setup.
    """
    eps = onsite(cfg)
    mv = jax.jit(lambda p: matvec(cfg, eps, p))

    key = jax.random.PRNGKey(cfg.seed + 1)
    v0 = jax.random.normal(key, (cfg.nx, cfg.ny, 2), jnp.float32)
    v_cur, _ = _normalize(v0)
    v_prev = jnp.zeros_like(v_cur)

    state = {
        "v_prev": Box(v_prev),
        "v_cur": Box(v_cur),
        "alphas": np.zeros(n_iter, np.float64),
        "betas": np.zeros(n_iter + 1, np.float64),
        "it": Box(0),
    }
    cp = None
    restarted_at = 0
    if cp_freq:
        cp = Checkpoint(cp_name, comm, env=env)
        for k, v in state.items():
            cp.add(k, v)
        cp.commit()
        if cp.restart_if_needed():
            restarted_at = state["it"].value

    @jax.jit
    def step(v_prev, v_cur, beta):
        w = mv(v_cur)
        alpha = jnp.sum(w * v_cur)
        w = w - alpha * v_cur - beta * v_prev
        beta_new = jnp.sqrt(jnp.sum(w * w))
        v_new = w / jnp.where(beta_new == 0, 1.0, beta_new)
        return alpha, beta_new, v_cur, v_new

    t0 = time.perf_counter()
    it = state["it"].value
    while it < n_iter:
        alpha, beta, vp, vc = step(
            state["v_prev"].value, state["v_cur"].value,
            jnp.float32(state["betas"][it]))
        state["alphas"][it] = float(alpha)
        state["betas"][it + 1] = float(beta)
        state["v_prev"].value = vp
        state["v_cur"].value = vc
        it += 1
        state["it"].value = it
        if extra_work_s:
            time.sleep(extra_work_s)
        if cp is not None:
            cp.update_and_write(it, cp_freq)
        if fail_at is not None and it == fail_at:
            if cp is not None:
                cp.wait()
                cp.close()
            raise RuntimeError(f"injected failure at iteration {it}")
    wall = time.perf_counter() - t0
    stats = dict(cp.stats) if cp is not None else {}
    if cp is not None:
        cp.wait()
        cp.close()

    k = state["it"].value
    tri = np.diag(state["alphas"][:k])
    if k > 1:
        off = state["betas"][1:k]
        tri += np.diag(off, 1) + np.diag(off, -1)
    eig = float(np.min(np.linalg.eigvalsh(tri))) if k else float("nan")
    return LanczosResult(
        eigenvalue=eig, alphas=state["alphas"][:k], betas=state["betas"][:k],
        iterations=k, wall_s=wall, cp_stats=stats, restarted_at=restarted_at)


def reference_eigenvalue(cfg: GrapheneConfig) -> float:
    """Dense reference for small lattices (tests)."""
    n = cfg.n
    eps = np.asarray(onsite(cfg)).reshape(-1)
    H = np.zeros((n, n), np.float64)
    basis = np.eye(n, dtype=np.float32)
    eps_j = jnp.asarray(np.asarray(onsite(cfg)))
    for j in range(n):
        psi = jnp.asarray(basis[j].reshape(cfg.nx, cfg.ny, 2))
        H[:, j] = np.asarray(matvec(cfg, eps_j, psi)).reshape(-1)
    del eps
    return float(np.min(np.linalg.eigvalsh(H)))
