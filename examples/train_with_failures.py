"""End-to-end driver: train a ~100M-param LM with CRAFT CR + AFT.

Default preset is a ~134M-parameter llama-style model (the h2o-danube
architecture scaled down) trained for a few hundred steps on the synthetic
Zipfian pipeline, checkpointing every 25 steps.  ``--inject-failure`` runs
the whole loop inside an AFT zone on the 2-rank simulator backend and
fail-stops rank 0 mid-run: the zone recovers (non-shrinking), re-reads the
checkpoint, and finishes — the paper's Listing 9 at framework scale.

    PYTHONPATH=src python examples/train_with_failures.py --steps 200
    PYTHONPATH=src python examples/train_with_failures.py --smoke
    PYTHONPATH=src python examples/train_with_failures.py --smoke \
        --inject-failure

``--schedule daly`` replaces the fixed 25-step frequency with the adaptive
scheduler: every chained tier checkpoints on its own Young/Daly interval
derived from its measured write cost and ``--mtbf`` (docs/tuning.md), and a
``CRAFT_WALLTIME_SECONDS`` budget (``--walltime``) lands one final full
checkpoint before the job dies — the SLURM-style setup, minus SLURM.
"""
import argparse
import time

from repro.configs import get_config, register_config
from repro.core.env import CraftEnv
from repro.launch import train as T


def build_100m():
    """~134M params: 12 layers, d=768, GQA 12/4 heads, d_ff 2048."""
    base = get_config("h2o-danube-1.8b")
    return base.replace(
        arch_id="danube-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32000, window=1024,
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + 30 steps (seconds, not minutes)")
    ap.add_argument("--inject-failure", action="store_true")
    ap.add_argument("--cp-dir", default="craft-train-100m")
    ap.add_argument("--schedule", choices=("fixed", "daly"), default="fixed",
                    help="fixed = every 25 steps; daly = per-tier adaptive "
                         "intervals (CRAFT_TIER_EVERY=auto)")
    ap.add_argument("--mtbf", type=float, default=600.0,
                    help="assumed MTBF seconds feeding the Daly formula")
    ap.add_argument("--walltime", type=float, default=0.0,
                    help="job walltime budget seconds (0 = no guard); the "
                         "policy lands one final full checkpoint before it")
    args = ap.parse_args()

    if args.smoke:
        arch, tiny, steps, gb, sl = "h2o-danube-1.8b", True, 30, 4, 64
    else:
        register_config("danube-100m", build_100m())
        arch, tiny, steps, gb, sl = "danube-100m", False, args.steps, 8, 512

    envmap = {
        "CRAFT_CP_PATH": args.cp_dir,
        "CRAFT_USE_SCR": "0",
        "CRAFT_WRITE_ASYNC": "1",           # paper §2.4 async checkpointing
        "CRAFT_COMM_RECOVERY_POLICY": "NON-SHRINKING",
    }
    if args.schedule == "daly":
        envmap["CRAFT_TIER_EVERY"] = "auto"
        envmap["CRAFT_MTBF_SECONDS"] = str(args.mtbf)
    if args.walltime > 0:
        envmap["CRAFT_WALLTIME_SECONDS"] = str(args.walltime)
        envmap["CRAFT_WALLTIME_MARGIN_SECONDS"] = "5"
    env = CraftEnv.capture(envmap)
    n_params = get_config(arch, tiny=tiny).param_count()
    print(f"arch={arch} ({n_params / 1e6:.0f}M params), steps={steps}")

    tc = T.TrainConfig(
        arch=arch, tiny=tiny, steps=steps, global_batch=gb, seq_len=sl,
        # daly mode drops the fixed gate: the policy alone decides cadence
        cp_freq=1 if args.schedule == "daly" else 25,
        fail_at_step=steps // 2 if args.inject_failure else None)

    t0 = time.time()
    log_every = max(1, steps // 20)

    def on_step(step, metrics):
        if step % log_every == 0:
            print(f"  step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"({(time.time() - t0) / step:.2f}s/step)")

    if args.inject_failure:
        from repro.core.comm_sim import SimWorld

        world = SimWorld(2, spare_nodes=1, env=env)

        def worker(comm):
            return T.run(tc, comm=comm, env=env,
                         on_step=on_step if comm.rank == 0 else None)

        results = world.run(worker, timeout=3600)
        out = next(iter(results.values()))
        print(f"recovered and finished: step {out['final_step']}, "
              f"final loss {out['losses'][-1]:.4f}")
    else:
        out = T.run(tc, env=env, on_step=on_step)
        print(f"finished: step {out['final_step']}, "
              f"final loss {out['losses'][-1]:.4f}, "
              f"wall {out['wall_s']:.1f}s, cp stats {out['stats']}")


if __name__ == "__main__":
    main()
