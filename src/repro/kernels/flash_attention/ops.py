"""Public attention op: padding, backend dispatch, and training gradients.

``attention()`` is what the model code calls.  Dispatch:

  * **TPU**: the Pallas flash kernel (forward) wrapped in ``jax.custom_vjp``
    whose backward recomputes through the jnp reference — the standard
    recompute-in-backward trade (flash forward saves the O(L²) HBM round
    trip; backward re-derives the scores from the residual q/k/v).
  * **CPU / dry-run**: the jitted jnp reference (the interpreter would be
    Python-speed; the reference compiles to the same FLOPs).

Padding: Lq/Lk are padded up to the 128-lane block size and the result is
sliced back; padded key slots are excluded via ``kv_len`` masking.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention as _flash
from repro.kernels.flash_attention.ref import attention_ref

_BLOCK = 128


def _pad_len(n: int, block: int = _BLOCK) -> int:
    return ((n + block - 1) // block) * block


def _padded_flash(q, k, v, *, causal, window, sm_scale, q_offset, interpret):
    b, hq, lq, dqk = q.shape
    _, hkv, lk, dv = v.shape
    lq_p, lk_p = _pad_len(lq), _pad_len(lk)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, lq_p - lq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, lk_p - lk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, lk_p - lk), (0, 0)))
    out = _flash(
        qp, kp, vp,
        causal=causal, window=window, sm_scale=sm_scale,
        q_offset=q_offset, kv_len=lk, interpret=interpret,
    )
    return out[:, :, :lq, :]


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def _attention_trainable(q, k, v, causal, window, sm_scale, q_offset, interpret):
    return _padded_flash(
        q, k, v, causal=causal, window=window, sm_scale=sm_scale,
        q_offset=q_offset, interpret=interpret,
    )


def _attn_fwd(q, k, v, causal, window, sm_scale, q_offset, interpret):
    out = _attention_trainable(q, k, v, causal, window, sm_scale, q_offset, interpret)
    return out, (q, k, v)


def _attn_bwd(causal, window, sm_scale, q_offset, interpret, res, g):
    q, k, v = res
    # Recompute through the reference (fp32 softmax) for exact gradients.
    def f(q_, k_, v_):
        return attention_ref(
            q_, k_, v_, causal=causal, window=window,
            sm_scale=sm_scale, q_offset=q_offset,
        )

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


_attention_trainable.defvjp(_attn_fwd, _attn_bwd)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    q_offset: int = 0,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
    block: int = 1024,
) -> jnp.ndarray:
    """Multi-head attention (GQA-aware).

    Dispatch: TPU → Pallas flash forward (jnp blocked backward);
    other backends → the jnp blocked (flash-algorithm) path, which keeps
    HLO memory O(L·D) like the kernel.  ``interpret=True`` forces the
    Pallas kernel through the interpreter (kernel tests only).
    """
    from repro.kernels.flash_attention.blocked import blocked_attention

    if sm_scale is None:
        sm_scale = q.shape[-1] ** -0.5
    if interpret:
        return _attention_trainable(
            q, k, v, causal, window, float(sm_scale), int(q_offset), True
        )
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    return blocked_attention(
        q, k, v, causal, window, float(sm_scale), int(q_offset), None,
        min(block, k.shape[2]), bool(use_pallas),
    )
