"""Three-term roofline analysis from the compiled (partitioned) HLO module.

Why a custom HLO analyzer instead of ``compiled.cost_analysis()``:

  * XLA's ``HloCostAnalysis`` visits every instruction **once** — a
    ``lax.scan`` over 61 layers reports the FLOPs of *one* layer (verified
    empirically, see EXPERIMENTS.md §Roofline/Methodology).  Unrolling every
    loop purely to make the built-in counter honest would explode compile
    times across the 40-cell × 2-mesh dry-run matrix.
  * ``cost_analysis()`` is an aggregate — collective traffic cannot be
    separated from HBM traffic, and per-collective attribution (which
    all-gather dominates?) is impossible.

So this module parses ``compiled.as_text()`` (the post-SPMD, per-device
module — shapes in it are already per-chip) into a call graph, recovers
every ``while`` loop's trip count from its condition computation
(``constant(N)`` + ``compare …, direction=LT``), and walks the graph with
multipliers so an op inside a scan body is counted trip-count times:

    FLOPs       — every ``dot`` op: 2 × |output| × contracted dim size
                  (einsums, matmuls, and one-hot dispatches all lower to
                  dot; elementwise FLOPs are ignored — they are VPU-bound
                  and negligible against MXU work at these shapes).
    HBM bytes   — Σ over material ops of (operand + output bytes); fusion
                  internals are on-chip and excluded, which is exactly the
                  post-fusion HBM-traffic approximation a roofline wants.
    collective  — per-op *wire* bytes with the standard ring-algorithm
                  effective sizes:
                      all-gather       out − in        (received bytes)
                      reduce-scatter   in − out        (sent bytes)
                      all-reduce       2 × in × (g−1)/g
                      all-to-all       in × (g−1)/g
                      collective-permute  in
                  where g = replica-group size parsed from the op.

Terms (seconds, per device — the module is per-device so chips divide out):

    compute_s    = flops / PEAK_FLOPS
    memory_s     = hbm_bytes / HBM_BW
    collective_s = wire_bytes / ICI_BW

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment).
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

# --------------------------------------------------------------------- HW
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# f32[6,128,256]{2,1,0:T(8,128)}  →  dtype="f32", dims=(6,128,256)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")           # /*index=5*/ tuple comments
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"([A-Za-z][\w\-]*)\(")
_NOT_OPCODES = set(_DTYPE_BYTES) | {"T", "tuple_index"}
_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (sums tuple elements)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_shape(type_str: str) -> Tuple[str, Tuple[int, ...]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", ()
    dtype, dims = m.groups()
    return dtype, tuple(int(d) for d in dims.split(",")) if dims else ()


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    type_str: str                 # result type (tuple types included)
    args_str: str                 # operand list text (inside the op parens)
    line: str                     # comment-stripped full line
    is_root: bool = False

    @property
    def out_bytes(self) -> int:
        return _shape_bytes(self.type_str)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    # name -> result type string (params included) for operand resolution
    symbols: Dict[str, str]


def _parse_op(line: str) -> Optional[Op]:
    line = _COMMENT_RE.sub("", line).strip()
    is_root = line.startswith("ROOT ")
    nm = _NAME_RE.match(line)
    if not nm:
        return None
    name, rest = nm.groups()
    # the opcode is the first `word(` that is not a dtype/layout token —
    # tuple result types contain `(`, layouts contain `T(8,128)`
    opcode, op_match = None, None
    for m in _OPCODE_RE.finditer(rest):
        if m.group(1) not in _NOT_OPCODES:
            opcode, op_match = m.group(1), m
            break
    if opcode is None:
        return None
    type_str = rest[: op_match.start()].strip()
    after = rest[op_match.end():]
    args_str = after.split(")", 1)[0]
    return Op(name, opcode, type_str, args_str, line, is_root)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", line)
            if m:
                cur = Computation(m.group(2), [], {})
                if m.group(1):
                    entry = m.group(2)
                # (parameter types come from the `parameter(N)` body ops)
            continue
        if line.rstrip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        op = _parse_op(line)
        if op:
            cur.symbols[op.name] = op.type_str
            cur.ops.append(op)
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


def _while_trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Trip count from the condition computation: compare(iv, constant(N))."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = []
    for op in cond.ops:
        consts += [int(v) for v in _CONST_RE.findall(op.line)]
    # condition may route compare through a wrapped fusion; constants live
    # in the condition computation itself (jax scan: `lt iv N`)
    for op in cond.ops:
        called = _CALLED_RE.findall(op.line)
        for c in called:
            sub = comps.get(c)
            if sub:
                for sop in sub.ops:
                    consts += [int(v) for v in _CONST_RE.findall(sop.line)]
    return max(consts) if consts else 1


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, int]:
    """Execution count of each computation (entry=1, scan bodies=trips).

    The call graph is a DAG; edges are processed in topological order so a
    computation's multiplier is final before its callees accumulate it.
    """
    entry = comps.get("__entry__")
    if entry is None:
        return {name: 1 for name in comps}
    # edges: parent -> [(child, local_factor)]
    edges: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        for op in comp.ops:
            called = _CALLED_RE.findall(op.line)
            if not called:
                continue
            factor = 1
            if op.opcode == "while":
                cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                trips = _while_trip_count(comps, cm.group(1)) if cm else 1
                factor = max(1, trips)
            for c in called:
                if c in comps:
                    edges[cname].append((c, factor))
    return _propagate(comps, entry, edges)


def _propagate(comps, entry, edges) -> Dict[str, int]:
    # DFS topological order from entry
    order: List[str] = []
    state: Dict[str, int] = {}

    def visit(n: str) -> None:
        stack = [(n, iter(edges.get(n, ())))]
        state[n] = 1
        while stack:
            node, it = stack[-1]
            advanced = False
            for child, _ in it:
                if state.get(child, 0) == 0:
                    state[child] = 1
                    stack.append((child, iter(edges.get(child, ()))))
                    advanced = True
                    break
            if not advanced:
                state[node] = 2
                order.append(node)
                stack.pop()

    visit(entry.name)
    mult: Dict[str, int] = defaultdict(int)
    mult[entry.name] = 1
    for parent in reversed(order):          # parents before children
        base = mult[parent]
        if base == 0:
            continue
        for child, factor in edges.get(parent, ()):
            mult[child] += base * factor
    return dict(mult)


def _material_comps(comps: Dict[str, Computation]) -> set:
    """Computations whose ops touch HBM: entry + control-flow bodies.

    Computations reached from a *fusion* op via ``calls=``/``to_apply=`` are
    fusion/reducer bodies — their internal ops run on-chip and must not count
    toward HBM traffic (the *fusion op itself*, at its call site, carries the
    traffic).  A plain ``call`` op, by contrast, is a control-flow wrapper
    (recent XLA:CPU wraps thread-parallel fusions in ``call(...),
    to_apply=%parallel_...``), so its callee *is* material.
    """
    entry = comps.get("__entry__")
    if entry is None:
        return set(comps)
    material = {entry.name}
    frontier = [entry.name]
    while frontier:
        comp = comps[frontier.pop()]
        for op in comp.ops:
            attrs = ("body", "condition")
            if op.opcode == "call":
                attrs = ("to_apply", "calls")
            for attr in attrs:
                m = re.search(attr + r"=%?([\w.\-]+)", op.line)
                if m and m.group(1) in comps and m.group(1) not in material:
                    material.add(m.group(1))
                    frontier.append(m.group(1))
    return material


def _dot_flops(op: Op, comp: Computation) -> int:
    """2 × |out| × contracted-size for a dot op (operands via symbol table)."""
    _, out_dims = _first_shape(op.type_str)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    operands = _OPERAND_RE.findall(op.args_str)
    contract = 1
    if operands:
        lhs_type = comp.symbols.get(operands[0], "")
        _, lhs_dims = _first_shape(lhs_type)
        cm = _CONTRACT_RE.search(op.line)
        if cm and lhs_dims:
            for idx in (int(i) for i in cm.group(1).split(",") if i):
                if idx < len(lhs_dims):
                    contract *= lhs_dims[idx]
    return 2 * out_elems * contract


_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "while", "conditional", "call", "custom-call",
    "partition-id", "replica-id", "bitcast-convert",
}


def _fusion_param_read_bytes(comps: Dict[str, Computation],
                             fused_name: str) -> Optional[Dict[int, int]]:
    """Bytes actually read from each parameter of a fused computation.

    A scan body indexes its stacked xs arrays with ``dynamic-slice`` ops
    *inside* kLoop fusions — charging the full stacked operand (tens of GB)
    per iteration would overcount HBM traffic ~n_layers×.  If every use of
    a parameter is a (dynamic-)slice, the traffic is the slice bytes.
    """
    fused = comps.get(fused_name)
    if fused is None:
        return None
    # param index -> name
    pname_by_idx: Dict[int, str] = {}
    for fop in fused.ops:
        if fop.opcode == "parameter":
            m = re.match(r"(\d+)", fop.args_str.strip())
            if m:
                pname_by_idx[int(m.group(1))] = fop.name
    reads: Dict[int, int] = {}
    for idx, pname in pname_by_idx.items():
        slice_bytes = 0
        sliced_only = True
        used = False
        for fop in fused.ops:
            if fop.opcode == "parameter":
                continue
            ops_used = _OPERAND_RE.findall(fop.args_str)
            if pname not in ops_used:
                continue
            used = True
            if fop.opcode in ("dynamic-slice", "slice"):
                slice_bytes += fop.out_bytes
            else:
                sliced_only = False
                break
        if used and sliced_only and slice_bytes:
            reads[idx] = slice_bytes
    return reads


def _elems(type_str: str) -> int:
    _, dims = _first_shape(type_str)
    n = 1
    for d in dims:
        n *= d
    return n


def _fusion_dus_update_bytes(comps, fused_name: str) -> Optional[int]:
    """If the fused computation is an in-place stack write (root is a
    dynamic-update-slice, possibly wrapped in dtype converts/bitcasts — the
    scan-residual save pattern), return the update's byte size.

    The XLA:CPU emitter expresses these as whole-stack convert→DUS→convert
    round trips; a TPU compile aliases the buffer and touches only the
    updated slice, which is what the v5e roofline should model.
    """
    fused = comps.get(fused_name) if comps else None
    if fused is None:
        return None
    root = next((f for f in fused.ops if f.is_root), None)
    if root is None:
        return None
    # unwrap convert/bitcast chains down to the root-feeding op
    seen = 0
    while root.opcode in ("convert", "bitcast", "copy") and seen < 4:
        src = _OPERAND_RE.findall(root.args_str)
        nxt = next((f for f in fused.ops if src and f.name == src[0]), None)
        if nxt is None:
            return None
        root, seen = nxt, seen + 1
    if root.opcode != "dynamic-update-slice":
        return None
    ops_used = _OPERAND_RE.findall(root.args_str)
    if len(ops_used) >= 2:
        t = fused.symbols.get(ops_used[1])
        if t:
            return _shape_bytes(t)
    return None


def _op_hbm_bytes(op: Op, comp: Computation,
                  comps: Optional[Dict[str, Computation]] = None) -> int:
    if op.opcode in _SKIP_BYTES_OPS or op.opcode in _COLLECTIVES:
        return 0
    if op.opcode in ("dynamic-slice", "slice", "gather"):
        return 2 * op.out_bytes
    operands = _OPERAND_RE.findall(op.args_str)
    if op.opcode == "dynamic-update-slice":
        t = comp.symbols.get(operands[1]) if len(operands) > 1 else None
        return 2 * _shape_bytes(t) if t else op.out_bytes
    sliced_reads: Dict[int, int] = {}
    dus_update: Optional[int] = None
    if op.opcode == "fusion" and comps is not None:
        cm = re.search(r"calls=%?([\w.\-]+)", op.line)
        if cm:
            sliced_reads = _fusion_param_read_bytes(comps, cm.group(1)) or {}
            dus_update = _fusion_dus_update_bytes(comps, cm.group(1))
    out_elems = _elems(op.type_str)
    total = op.out_bytes if dus_update is None else 2 * dus_update
    for i, o in enumerate(operands):
        if i in sliced_reads:
            total += sliced_reads[i]
            continue
        t = comp.symbols.get(o)
        if not t:
            continue
        if dus_update is not None and _elems(t) == out_elems:
            continue  # the aliased stack buffer itself — in-place, no read
        total += _shape_bytes(t)
    return total


def _collective_wire_bytes(op: Op, comp: Computation) -> Tuple[str, int]:
    """(kind, effective wire bytes) for a collective op."""
    kind = op.opcode.replace("-start", "")
    operands = _OPERAND_RE.findall(op.args_str)
    in_bytes = sum(
        _shape_bytes(comp.symbols.get(o, "")) for o in operands)
    out_bytes = op.out_bytes
    g = 1
    gm = _GROUPS_RE.search(op.line)
    if gm:
        g = int(gm.group(2))
    if g <= 1:
        return kind, 0
    if kind == "all-gather":
        return kind, max(0, out_bytes - in_bytes)
    if kind == "reduce-scatter":
        return kind, max(0, in_bytes - out_bytes)
    if kind == "all-reduce":
        return kind, int(2 * in_bytes * (g - 1) / g)
    if kind == "all-to-all":
        return kind, int(in_bytes * (g - 1) / g)
    return kind, in_bytes      # collective-permute


@dataclasses.dataclass
class RooflineReport:
    flops: float                      # per device, trip-count corrected
    hbm_bytes: float                  # per device, VMEM-scope adjusted
    hbm_bytes_unfused: float          # per device, raw HLO traffic
    collective_bytes: float           # per device, wire-effective
    collective_by_kind: Dict[str, float]
    top_collectives: List[Tuple[str, float]]   # (description, bytes)
    n_collective_ops: int

    # ---- derived terms (seconds) ----
    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "hbm_bytes_unfused": self.hbm_bytes_unfused,
            "collective_bytes": self.collective_bytes,
            "collective_by_kind": self.collective_by_kind,
            "top_collectives": self.top_collectives[:10],
            "n_collective_ops": self.n_collective_ops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def analyze(hlo_text: str,
            vmem_scopes: Tuple[str, ...] = ("pallas_equiv",)
            ) -> RooflineReport:
    """``vmem_scopes``: ``jax.named_scope`` markers for regions the TPU
    target runs as a Pallas kernel — their intermediates live in VMEM, so
    marked ops are excluded from HBM traffic and the enclosing ``while``
    (the kernel's scan) is charged its loop-boundary bytes once per
    invocation (= the kernel's q/k/v/out HBM IO).  The unadjusted number is
    kept as ``hbm_bytes_unfused``."""
    comps = parse_module(hlo_text)
    mult = _multipliers(comps)
    material = _material_comps(comps)

    def _marked(op: Op) -> bool:
        return any(s in op.line for s in vmem_scopes)

    def _body_marked(body_name: str) -> bool:
        body = comps.get(body_name)
        if body is None:
            return False
        n = sum(1 for o in body.ops if o.opcode not in (
            "parameter", "get-tuple-element", "tuple", "constant"))
        nm = sum(1 for o in body.ops if _marked(o))
        return n > 0 and nm >= max(1, n // 2)

    flops = 0.0
    hbm = 0.0
    hbm_unfused = 0.0
    coll_total = 0.0
    coll_kind: Dict[str, float] = defaultdict(float)
    coll_list: List[Tuple[str, float]] = []
    n_coll = 0
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 0)
        if m == 0:
            continue
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                flops += m * _dot_flops(op, comp)
            if op.opcode.replace("-start", "") in _COLLECTIVES:
                if op.opcode.endswith("-done"):
                    continue
                kind, wire = _collective_wire_bytes(op, comp)
                coll_total += m * wire
                coll_kind[kind] += m * wire
                n_coll += m
                desc = f"{kind} {op.type_str.strip()[:48]} x{m}"
                coll_list.append((desc, m * wire))
            elif name in material:
                b = _op_hbm_bytes(op, comp, comps)
                hbm_unfused += m * b
                if op.opcode == "while":
                    bm = re.search(r"body=%?([\w.\-]+)", op.line)
                    if bm and _body_marked(bm.group(1)):
                        # Pallas-kernel scan: charge HBM boundary IO once
                        hbm += m * 2 * op.out_bytes
                elif not _marked(op):
                    hbm += m * b
    coll_list.sort(key=lambda kv: -kv[1])
    return RooflineReport(
        flops=flops, hbm_bytes=hbm, hbm_bytes_unfused=hbm_unfused,
        collective_bytes=coll_total,
        collective_by_kind=dict(coll_kind), top_collectives=coll_list,
        n_collective_ops=n_coll,
    )


# ------------------------------------------------------------- MODEL_FLOPS
def model_flops(cfg, seq_len: int, global_batch: int, kind: str,
                n_chips: int) -> float:
    """Per-chip useful model FLOPs: 6·N_active·D train, 2·N_active·D fwd."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = seq_len * global_batch
        total = 6.0 * n_active * tokens
    elif kind == "prefill":
        tokens = seq_len * global_batch
        total = 2.0 * n_active * tokens
    else:  # decode: one new token per sequence
        total = 2.0 * n_active * global_batch
    return total / n_chips


def format_report(rep: RooflineReport, model_fl_per_chip: float = 0.0) -> str:
    lines = [
        f"  flops/device          {rep.flops:.4e}",
        f"  hbm bytes/device      {rep.hbm_bytes:.4e} "
        f"(unfused {rep.hbm_bytes_unfused:.3e})",
        f"  collective bytes/dev  {rep.collective_bytes:.4e}",
        f"  compute term          {rep.compute_s * 1e3:10.3f} ms",
        f"  memory term           {rep.memory_s * 1e3:10.3f} ms",
        f"  collective term       {rep.collective_s * 1e3:10.3f} ms",
        f"  dominant              {rep.dominant}",
    ]
    if model_fl_per_chip:
        ratio = model_fl_per_chip / max(rep.flops, 1.0)
        lines.append(f"  MODEL/HLO flops ratio {ratio:10.3f}")
    if rep.collective_by_kind:
        kinds = ", ".join(f"{k}={v:.3e}"
                          for k, v in sorted(rep.collective_by_kind.items()))
        lines.append(f"  collectives by kind   {kinds}")
    return "\n".join(lines)


def save_json(path, payload: dict) -> None:
    from pathlib import Path

    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(payload, indent=2, default=float))
