"""Low-overhead JSONL run tracing (``CRAFT_TRACE``) — the *record* third of
the record → replay → tune loop (paper §V measures CR overhead by hand; we
measure it by instrumenting the real code paths).

Every load-bearing event on the CR path emits one JSON line:

=================  =======================================================
kind               fields (beyond ``t``, seconds since trace start)
=================  =======================================================
``config``         snapshot of the scheduling-relevant ``CRAFT_*`` knobs +
                   the checkpoint's payload size (emitted at ``commit()``)
``decision``       the policy verdict for one step: ``it``, ``pending``
                   (writer backpressure seen), ``write``, ``tiers``,
                   ``full``, ``sync``, ``reason``, plus the caller's
                   ``cp_freq``/``next_version`` gate inputs
``scheduled``      ``record_written`` fired for ``version`` (cadence state
                   advanced — on async runs this precedes the tier writes)
``step``           one measured application step (``seconds``)
``tier_write``     a tier write *landed*: ``slot``, ``version``,
                   ``seconds``, ``nbytes`` (logical payload),
                   ``phys_bytes``/``chunks``/``ref_chunks`` (codec IO),
                   ``full`` (self-contained vs delta)
``degraded``       a scheduled write did not land on ``slot`` (fault or
                   open breaker) and was routed down the chain
``breaker``        a circuit breaker tripped: ``slot``
``restore``        a restore completed: ``version``, ``tier`` (label),
                   ``slot``, ``seconds``, ``read_bytes``
``failure``        the collective engine observed one fail-stop
``kill``           a fault injector killed ``rank`` (SimWorld)
``recovery``       an AFT recovery reset live policies (epoch bump)
``retune``         online re-tuning replaced cadences: ``cadence`` map
=================  =======================================================

Overhead contract: when ``CRAFT_TRACE`` is unset the module-level
:data:`TRACER` stays the no-op :class:`_NullTracer` — every hook is a
single dynamic call that immediately returns, no branching, no string
formatting, no clock reads (``benchmarks/cr_overhead.py trace_overhead``
keeps the armed-vs-off delta on the scoreboard).  Hooks must therefore
pass only cheap, already-computed values.

The recorder is process-global (one trace file interleaves every
checkpoint, scheduler and communicator in the process — a total order of
events is exactly what the replayer needs) and append-only, so a
restarted job extends its predecessor's trace.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional

__all__ = [
    "TRACER", "emit", "enabled", "install", "uninstall", "env_snapshot",
]


class _NullTracer:
    """The ``CRAFT_TRACE``-unset tracer: every emit is a no-op."""

    enabled = False
    path = None

    def emit(self, kind: str, **fields) -> None:  # pragma: no cover - trivial
        return None

    def close(self) -> None:  # pragma: no cover - trivial
        return None


class JsonlTracer:
    """Append-only JSONL writer; thread-safe, line-at-a-time.

    ``t`` is seconds since the tracer was installed on the shared monotonic
    clock, so events from every thread (main loop, async writer, sim ranks)
    land on one comparable timeline.
    """

    enabled = True

    def __init__(self, path: str, clock=time.monotonic):
        self.path = str(path)
        self._clock = clock
        self._t0 = clock()
        self._lock = threading.Lock()
        self._closed = False
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._fh = open(self.path, "a", buffering=1, encoding="utf-8")

    def emit(self, kind: str, **fields) -> None:
        rec = {"t": round(self._clock() - self._t0, 6), "kind": kind}
        rec.update(fields)
        line = json.dumps(rec, separators=(",", ":"), default=str)
        with self._lock:
            # Re-check liveness *under the lock*: a concurrent uninstall()
            # (telemetry shutdown hook, test teardown) may have closed the
            # writer between the module-level TRACER read and here — without
            # this a mid-emit close could tear the final line or raise on a
            # closed file.
            if self._closed or self._fh.closed:
                return
            try:
                self._fh.write(line + "\n")
            except ValueError:       # closed out from under us (interp exit)
                self._closed = True

    def close(self) -> None:
        # Idempotent and thread-safe: emit() holds the same lock, so a close
        # always lands between whole lines, never inside one.
        with self._lock:
            if self._closed:
                return
            self._closed = True
            if not self._fh.closed:
                self._fh.flush()
                self._fh.close()


#: The process-wide tracer.  Hooks call ``trace.TRACER.emit(...)`` (or the
#: module-level :func:`emit` alias); both stay no-ops until :func:`install`.
TRACER = _NullTracer()


def emit(kind: str, **fields) -> None:
    """Module-level emit alias (reads :data:`TRACER` at call time, so hooks
    that imported the function still see a later install)."""
    TRACER.emit(kind, **fields)


def enabled() -> bool:
    return TRACER.enabled


def install(path: str) -> None:
    """Arm the recorder (idempotent for the same path: the existing writer
    keeps appending; a different path swaps writers)."""
    global TRACER
    if TRACER.enabled and TRACER.path == str(path):
        return
    old, TRACER = TRACER, JsonlTracer(path)
    old.close()


def uninstall() -> None:
    """Back to the no-op recorder (tests; end of a traced benchmark)."""
    global TRACER
    old, TRACER = TRACER, _NullTracer()
    old.close()


def maybe_install_from_env(env) -> None:
    """Arm the recorder when the captured env names a trace file
    (``Checkpoint.commit()`` calls this — the paper's read-once contract)."""
    if getattr(env, "trace_path", None):
        install(env.trace_path)


def env_snapshot(env, payload_bytes: int = 0,
                 comm_size: Optional[int] = None) -> dict:
    """The scheduling-relevant knobs as a re-capturable ``{CRAFT_*: str}``
    map — what the replayer feeds back into ``CraftEnv.capture`` so the
    simulated policy is configured exactly like the recorded one."""
    tier_every = ",".join(
        f"{slot}:{spec}" if slot != "*" else str(spec)
        for slot, spec in env.tier_every
    )
    snap = {
        "CRAFT_TIER_CHAIN": ",".join(env.tier_chain),
        "CRAFT_TIER_EVERY": tier_every,
        "CRAFT_PFS_EVERY": str(env.pfs_every),
        "CRAFT_MTBF_SECONDS": repr(env.mtbf_seconds),
        "CRAFT_DELTA": "1" if env.delta else "0",
        "CRAFT_DELTA_MAX_CHAIN": str(env.delta_max_chain),
        "CRAFT_KEEP_VERSIONS": str(env.keep_versions),
        "CRAFT_NODE_REDUNDANCY": env.node_redundancy,
        "CRAFT_XOR_GROUP_SIZE": str(env.xor_group_size),
        "CRAFT_RS_PARITY": str(env.rs_parity),
        "CRAFT_MEM_REPLICAS": str(env.mem_replicas),
        "CRAFT_WALLTIME_SECONDS": repr(env.walltime_seconds),
        "CRAFT_WALLTIME_MARGIN_SECONDS": repr(env.walltime_margin_seconds),
        "CRAFT_WRITE_ASYNC": "1" if env.write_async else "0",
        "CRAFT_CODEC_VERSION": str(env.codec_version),
    }
    out = {"env": snap, "payload_bytes": int(payload_bytes)}
    if comm_size is not None:
        out["comm_size"] = int(comm_size)
    return out
