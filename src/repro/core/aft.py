"""AFT zones — automatic fault tolerance (paper §3, Listings 8/9).

The paper wraps the protected region in ``AFT_BEGIN(comm)``/``AFT_END()``
macros that expand to a while-loop around a try/catch: a process failure
raises, the catch block repairs the communicator (revoke → shrink → agree,
then spawn+merge for non-shrinking recovery), and the body re-enters —
re-reading the latest checkpoint through ``restartIfNeeded()``.

Python has no macros, so the primary API is the functional zone::

    def body(comm):
        cp = Checkpoint("state", comm)        # INSIDE the zone, like Listing 9
        it = Box(0); cp.add("it", it); ...; cp.commit()
        cp.restart_if_needed()
        while it.value < n:
            ...
            cp.update_and_write(it.value, freq)
        return result

    result = aft_zone(comm, body)

Semantics preserved from the paper:
  * any member may detect the failure; ``revoke()`` makes it global,
  * recovery policy: SHRINKING or NON-SHRINKING (CRAFT_COMM_RECOVERY_POLICY),
  * spawned replacements execute the *same program* from the top and land
    directly in the zone body with the repaired communicator,
  * checkpoints must be (re-)defined inside the zone so every retry re-reads
    the latest consistent version.

A lower-level ``AftZone`` with explicit ``begin()/failed()/end()`` is also
provided for code that cannot be expressed as a callable body.
"""
from __future__ import annotations

import logging
import time
from typing import Callable, Optional, TypeVar

from repro.core.comm import CommError, FTComm, ProcFailedError, RevokedError
from repro.core.env import CraftEnv

log = logging.getLogger("craft.aft")
T = TypeVar("T")


class AftAbortedError(RuntimeError):
    """The zone exceeded ``max_recoveries`` and gave up."""


def _drop_failed_memory(stats: dict) -> None:
    """Tell the memory tier which ranks' RAM died with this recovery.

    The zone body re-created after recovery restores through
    ``restart_if_needed()``; with the memory tier chained first, survivors
    then reconstruct the failed ranks' shards from the peer replicas that
    are still resident — no disk read.  Idempotent with the simulator's
    fault-domain kill hooks.
    """
    failed = stats.get("failed")
    if failed:
        from repro.core.mem_level import notify_rank_failures

        notify_rank_failures(failed)


def _reprotect_memory(comm: FTComm, env: CraftEnv) -> int:
    """Re-establish full RAM-fabric replica placement after a NON-SHRINKING
    recovery: replacement ranks take over the failed ranks' holder slots, so
    the fabric again tolerates ``CRAFT_MEM_REPLICAS`` failures (the spawned
    ranks themselves hydrate their *own* slices lazily via
    ``restart_if_needed()`` → ``MemStore.rehydrate``).  Returns slots seeded.
    """
    from repro.core.mem_level import MemFabric

    return MemFabric.instance().reprotect(comm.size, env.mem_replicas)


def _notify_scheduler(stats: dict) -> None:
    """Bump the process-wide recovery epoch: every live checkpoint policy
    resets its write-cost estimators (the survivor layout changed) and
    forces its next write to be a full, self-contained one."""
    from repro.core import scheduler

    scheduler.notify_recovery(stats)


def aft_zone(
    comm: FTComm,
    body: Callable[[FTComm], T],
    *,
    policy: Optional[str] = None,
    max_recoveries: int = 16,
    env: Optional[CraftEnv] = None,
    on_recovery: Optional[Callable[[FTComm, dict], None]] = None,
) -> T:
    """Run ``body(comm)`` with automatic failure recovery; returns its value."""
    env = env if env is not None else CraftEnv.capture()
    policy = (policy or comm.default_recovery_policy
              or env.comm_recovery_policy).upper()
    recoveries = 0
    while True:
        try:
            result = body(comm)
            # ULFM recipe: agree on collective success before leaving the
            # zone, so no member exits while another is about to fail over.
            if not comm.agree(True):
                raise ProcFailedError("exit agreement failed")
            return result
        except (ProcFailedError, RevokedError) as exc:
            recoveries += 1
            if recoveries > max_recoveries:
                raise AftAbortedError(
                    f"gave up after {max_recoveries} recoveries"
                ) from exc
            t0 = time.perf_counter()
            try:
                comm.revoke()            # asymmetric: make the failure global
            except CommError:
                pass
            comm = comm.recover(policy=policy)
            stats = comm.last_recovery_stats()
            _drop_failed_memory(stats)
            if policy == "NON-SHRINKING":
                stats["mem_reseeded"] = _reprotect_memory(comm, env)
            _notify_scheduler(stats)
            log.warning(
                "AFT recovery #%d (%s): failed=%s, %.3fs",
                recoveries, policy, stats.get("failed"),
                time.perf_counter() - t0,
            )
            if on_recovery is not None:
                on_recovery(comm, stats)


class AftZone:
    """Explicit begin/end form (the AFT_BEGIN/AFT_END macros).

        zone = AftZone(comm)
        while zone.active():
            try:
                with zone:
                    ... body using zone.comm ...
            except zone.FAILURES:
                zone.failed()
    """

    FAILURES = (ProcFailedError, RevokedError)

    def __init__(self, comm: FTComm, policy: Optional[str] = None,
                 max_recoveries: int = 16, env: Optional[CraftEnv] = None):
        env = env if env is not None else CraftEnv.capture()
        self.comm = comm
        self.env = env
        self.policy = (policy or comm.default_recovery_policy
                       or env.comm_recovery_policy).upper()
        self.max_recoveries = max_recoveries
        self.recoveries = 0
        self._done = False

    def active(self) -> bool:
        return not self._done

    def __enter__(self) -> "AftZone":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc is None:
            if not self.comm.agree(True):
                self.failed()
                return True
            self._done = True
            return False
        return False  # propagate; caller's except zone.FAILURES handles it

    def failed(self) -> None:
        self.recoveries += 1
        if self.recoveries > self.max_recoveries:
            raise AftAbortedError(f"gave up after {self.max_recoveries} recoveries")
        try:
            self.comm.revoke()
        except CommError:
            pass
        self.comm = self.comm.recover(policy=self.policy)
        stats = self.comm.last_recovery_stats()
        _drop_failed_memory(stats)
        if self.policy == "NON-SHRINKING":
            stats["mem_reseeded"] = _reprotect_memory(self.comm, self.env)
        _notify_scheduler(stats)
