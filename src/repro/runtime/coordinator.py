"""Coordinator: accepts worker connections, serves RPCs, drives recovery.

Runs as threads inside the launching process.  Every worker connection gets
a receiver thread; blocking collective RPCs are answered from short-lived
handler threads so one blocked collective never stalls the connection's
other traffic (heartbeats, the checkpoint writer thread's barriers, ...).
"""
from __future__ import annotations

import os
import tempfile
import threading
import time
import traceback
from multiprocessing.connection import Listener
from typing import Dict, List, Optional

from repro.core.comm import ProcFailedError, RevokedError

import logging
log = logging.getLogger("craft.coord")
from repro.core.ftengine import CollectiveEngine, NodePool

_AUTHKEY = b"craft-cluster"


class Coordinator:
    def __init__(
        self,
        n_procs: int,
        procs_per_node: int = 1,
        spare_nodes: int = 0,
        spawn_policy: str = "NO-REUSE",
        collective_deadline: Optional[float] = None,
        hb_timeout: Optional[float] = None,
    ):
        self.n_procs = n_procs
        self.ppn = max(1, procs_per_node)
        n_nodes = (n_procs + self.ppn - 1) // self.ppn
        members = {r: r // self.ppn for r in range(n_procs)}
        self.engine = CollectiveEngine(members)
        self.engine.set_spawn_policy(spawn_policy)
        self.pool = NodePool(n_nodes, spare_nodes)
        self.collective_deadline = collective_deadline
        self.hb_timeout = hb_timeout
        self._lock = threading.Lock()
        self._conns: Dict[int, object] = {}        # rank -> live connection
        self._conn_gen: Dict[int, int] = {}        # rank -> incarnation count
        self._last_seen: Dict[int, float] = {}
        self.results: Dict[int, object] = {}
        self.worker_errors: List[str] = []
        self.last_recovery: dict = {}
        self._spawn_cb = None                      # set by Cluster
        self._stop = threading.Event()
        self._dir = tempfile.mkdtemp(prefix="craft-coord-")
        self.address = os.path.join(self._dir, "sock")
        self._listener = Listener(self.address, family="AF_UNIX", authkey=_AUTHKEY)
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="coord-accept", daemon=True
        )
        self._accept_thread.start()
        if hb_timeout:
            threading.Thread(
                target=self._hb_monitor, name="coord-hb", daemon=True
            ).start()

    def set_spawner(self, cb) -> None:
        self._spawn_cb = cb

    # ------------------------------------------------------------- accept/serve
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn = self._listener.accept()
            except (OSError, EOFError):
                return
            threading.Thread(
                target=self._serve, args=(conn,), daemon=True
            ).start()

    def _serve(self, conn) -> None:
        rank = None
        gen = None
        try:
            hello = conn.recv()
            assert hello["op"] == "hello", hello
            rank = hello["rank"]
            eid = hello["eid"]
            log.debug("serve: hello rank=%s eid=%s repl=%s", rank, eid,
                      hello.get("replacement"))
            with self._lock:
                self._conn_gen[rank] = self._conn_gen.get(rank, 0) + 1
                gen = self._conn_gen[rank]
                self._conns[rank] = conn
                self._last_seen[rank] = time.monotonic()
            token = f"{rank}:{gen}"
            if hello.get("replacement"):
                self.engine.register_member(eid, rank, token=token)
            else:
                self.engine.set_occupant(eid, rank, token)
            self._reply(conn, hello, {"ok": {"ppn": self.ppn}})
            while not self._stop.is_set():
                msg = conn.recv()
                with self._lock:
                    self._last_seen[rank] = time.monotonic()
                if msg["op"] == "hb":
                    continue
                if msg["op"] in ("barrier", "allreduce", "bcast", "agree",
                                 "recover"):
                    threading.Thread(
                        target=self._handle_blocking,
                        args=(conn, rank, msg),
                        daemon=True,
                    ).start()
                else:
                    self._handle_fast(conn, rank, msg)
        except (EOFError, OSError, BrokenPipeError):
            log.debug("serve: connection lost rank=%s gen=%s", rank, gen)
        finally:
            if rank is not None and gen is not None:
                with self._lock:
                    current = self._conn_gen.get(rank) == gen
                    if current:
                        self._conns.pop(rank, None)
                if current and not self._stop.is_set():
                    # fail-stop detection: the paper's "nonresponsive to any
                    # communication request"
                    self.engine.mark_dead(f"{rank}:{gen}")

    # ------------------------------------------------------------- dispatch
    def _handle_fast(self, conn, rank: int, msg: dict) -> None:
        op = msg["op"]
        try:
            if op == "revoke":
                self.engine.revoke(msg["eid"])
                self._reply(conn, msg, {"ok": None})
            elif op == "failed_ranks":
                self._reply(conn, msg, {"ok": self.engine.failed_ranks(msg["eid"])})
            elif op == "result":
                with self._lock:
                    self.results[rank] = msg["value"]
                self._reply(conn, msg, {"ok": None})
            elif op == "error":
                with self._lock:
                    self.worker_errors.append(f"rank {rank}: {msg['text']}")
                self._reply(conn, msg, {"ok": None})
            else:
                self._reply(conn, msg, {"err": ("bad_op", op)})
        except (OSError, BrokenPipeError):
            pass

    def _handle_blocking(self, conn, rank: int, msg: dict) -> None:
        op = msg["op"]
        # collectives are matched by the worker's *current* rank (ranks are
        # remapped by shrinking recovery), not its connection's hello rank
        rank = msg.get("rank", rank)
        try:
            if op == "recover":
                view = self.engine.recover(
                    msg["eid"], rank, msg["policy"], self.pool,
                    spawner=self._spawn_cb,
                )
                with self._lock:
                    self.last_recovery = view["stats"]
                self._reply(conn, msg, {"ok": view})
            elif op == "agree":
                result = self.engine.collective(
                    msg["eid"], "__agree", msg["seq"], "and", rank,
                    value=msg["value"], fault_tolerant=True,
                )
                self._reply(conn, msg, {"ok": result})
            else:
                engine_op = msg["reduce"] if op == "allreduce" else op
                result = self.engine.collective(
                    msg["eid"], msg["channel"], msg["seq"], engine_op,
                    rank, value=msg.get("value"), root=msg.get("root", 0),
                    timeout=self.collective_deadline,
                )
                self._reply(conn, msg, {"ok": result})
        except ProcFailedError as exc:
            self._reply(conn, msg, {"err": ("proc_failed", exc.failed)})
        except RevokedError:
            self._reply(conn, msg, {"err": ("revoked", None)})
        except Exception:  # pragma: no cover - defensive
            self._reply(conn, msg, {"err": ("internal", traceback.format_exc())})

    def _reply(self, conn, msg: dict, payload: dict) -> None:
        out = {"id": msg.get("id"), **payload}
        try:
            with self._lock:
                conn.send(out)
        except (OSError, BrokenPipeError):
            pass

    # ------------------------------------------------------------- hb monitor
    def _hb_monitor(self) -> None:
        while not self._stop.is_set():
            time.sleep(self.hb_timeout / 4)
            now = time.monotonic()
            with self._lock:
                stale = [
                    r for r, ts in self._last_seen.items()
                    if r in self._conns and now - ts > self.hb_timeout
                ]
            with self._lock:
                tokens = [f"{r}:{self._conn_gen.get(r)}" for r in stale]
            for tok in tokens:
                self.engine.mark_dead(tok)

    # ------------------------------------------------------------- shutdown
    def close(self) -> None:
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass
        with self._lock:
            for conn in self._conns.values():
                try:
                    conn.close()
                except OSError:
                    pass
            self._conns.clear()
