"""docs/env_reference.md must stay in sync with core/env.py.

Two-way check: every ``CRAFT_*`` knob the code reads is documented as a
table row, and no table row documents a knob the code no longer mentions.
"""
import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
ENV_PY = REPO / "src" / "repro" / "core" / "env.py"
DOC = REPO / "docs" / "env_reference.md"

_KNOB = re.compile(r"CRAFT_[A-Z0-9_]+")


def _code_knobs() -> set:
    return set(_KNOB.findall(ENV_PY.read_text()))


def _doc_row_knobs() -> set:
    rows = set()
    for line in DOC.read_text().splitlines():
        if line.startswith("| `CRAFT_"):
            rows.update(_KNOB.findall(line.split("|")[1]))
    return rows


def test_every_code_knob_documented():
    missing = _code_knobs() - _doc_row_knobs()
    assert not missing, (
        f"knobs read by core/env.py but missing from docs/env_reference.md "
        f"tables: {sorted(missing)}"
    )


def test_no_stale_doc_entries():
    stale = _doc_row_knobs() - _code_knobs()
    assert not stale, (
        f"docs/env_reference.md documents knobs core/env.py no longer "
        f"mentions: {sorted(stale)}"
    )


def test_doc_has_rows():
    assert len(_doc_row_knobs()) >= 20   # sanity: the table parser works
