"""Activation sharding constraints via an ambient LogicalRules context.

GSPMD picks shardings for loop carries and large intermediates by
propagation heuristics; at 256–512 devices a bad pick (e.g. replicating the
batch across the model axis inside the layer-scan carry — observed, see
EXPERIMENTS.md §Dry-run) costs 10× memory.  Model code therefore pins the
handful of tensors that matter (block inputs/outputs, scan carries, MoE
dispatch buffers, CE logit chunks) with ``constrain(x, *logical_dims)``.

The rules are ambient (a context var installed by the step builders /
launchers around tracing) so pure model code stays mesh-agnostic; outside
any context ``constrain`` is an exact no-op — tests and single-device runs
never see it.  Same shape-aware divisibility fallback as parameter specs.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import NamedSharding

from repro.sharding.logical import LogicalRules

_RULES: contextvars.ContextVar[Optional[LogicalRules]] = \
    contextvars.ContextVar("craft_activation_rules", default=None)


@contextlib.contextmanager
def use_rules(rules: Optional[LogicalRules]):
    token = _RULES.set(rules)
    try:
        yield rules
    finally:
        _RULES.reset(token)


def current_rules() -> Optional[LogicalRules]:
    return _RULES.get()


def constrain(x, *dims):
    """Pin ``x``'s sharding to the logical ``dims`` (no-op without rules)."""
    rules = _RULES.get()
    if rules is None or not hasattr(x, "shape"):
        return x
    if len(dims) != x.ndim:
        raise ValueError(
            f"constrain: {len(dims)} dims for rank-{x.ndim} tensor")
    spec = rules.spec(*dims, shape=x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def constrain_tree(tree, logical_tree):
    """Pin a pytree's sharding to its logical dims (no-op without rules).

    Used on gradient trees: GSPMD otherwise all-reduces weight gradients in
    full (2x wire) and slices afterwards; declaring the target (= parameter)
    sharding at the grad production site turns that into reduce-scatter
    (§Perf iteration 2.2).
    """
    rules = _RULES.get()
    if rules is None:
        return tree
    import jax as _jax

    def is_dims(x):
        return isinstance(x, tuple) and all(
            isinstance(d, (str, type(None))) for d in x)

    def apply(dims, leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim != len(dims):
            return leaf
        return constrain(leaf, *dims)

    return _jax.tree_util.tree_map(apply, logical_tree, tree,
                                   is_leaf=is_dims)
