"""Paper Table 4: checkpoint overhead — none / sync PFS / async PFS / node.

Lanczos benchmark (paper §6.2 setup, scaled to this container): fixed
iteration count, fixed checkpoint frequency; report total runtime, %
overhead vs the no-checkpoint baseline, and average time per checkpoint.

The paper's ordering to reproduce:  sync > async > node-level overhead.
Storage mapping on this container: the "PFS" tier is the disk-backed
filesystem; the node tier writes to /dev/shm — the honest analog of the
paper's node-local (RAM/SSD) storage vs parallel-filesystem split on a
single host.
"""
from __future__ import annotations

import os

import shutil
import tempfile
from pathlib import Path

from benchmarks.common import emit
from repro.apps.lanczos import GrapheneConfig, run_lanczos
from repro.core.env import CraftEnv


def _run(mode: str, base: Path, cfg, n_iter, cp_freq, extra_work_s):
    d = base / mode
    envmap = {
        "CRAFT_CP_PATH": str(d / "pfs"),
        "CRAFT_USE_SCR": "0",
    }
    if mode == "none":
        envmap["CRAFT_ENABLE"] = "0"
    elif mode == "sync_pfs":
        pass
    elif mode == "async_pfs":
        envmap["CRAFT_WRITE_ASYNC"] = "1"
    elif mode == "node_level":
        shm = Path("/dev/shm") if Path("/dev/shm").is_dir() else (d / "node")
        envmap.update({
            "CRAFT_USE_SCR": "1",
            "CRAFT_NODE_CP_PATH": str(shm / f"craft-node-{os.getpid()}"),
            "CRAFT_NODE_REDUNDANCY": "LOCAL",
            "CRAFT_PFS_EVERY": "1000000",      # node tier only
        })
    env = CraftEnv.capture(envmap)
    res = run_lanczos(cfg, n_iter=n_iter,
                      cp_freq=(0 if mode == "none" else cp_freq),
                      cp_name=f"l_{mode}", env=env,
                      extra_work_s=extra_work_s)
    return res


def main(full: bool = False) -> None:
    # checkpoint payload = 2 Lanczos vectors (nx·ny·2 fp32) ≈ 17 MB at 1024²
    # — big enough that write time is visible against ~ms-scale iterations
    cfg = GrapheneConfig(nx=1024 if full else 768,
                         ny=1024 if full else 768, disorder=0.3)
    n_iter = 200 if full else 120
    cp_freq = 20 if full else 15
    extra = 0.0
    base = Path(tempfile.mkdtemp(prefix="craft-table4-"))
    import shutil as _sh
    try:
        results = {}
        for mode in ("none", "sync_pfs", "async_pfs", "node_level"):
            res = _run(mode, base, cfg, n_iter, cp_freq, extra)
            results[mode] = res
            emit("table4_cr_overhead", f"{mode}_runtime",
                 round(res.wall_s, 4), "s")
        base_t = results["none"].wall_s
        for mode in ("sync_pfs", "async_pfs", "node_level"):
            res = results[mode]
            ov = 100.0 * (res.wall_s - base_t) / base_t
            n_cp = max(1, res.cp_stats.get("writes", 1))
            emit("table4_cr_overhead", f"{mode}_overhead",
                 round(ov, 2), "%")
            emit("table4_cr_overhead", f"{mode}_time_per_cp",
                 round(res.cp_stats.get("write_seconds", 0.0) / n_cp, 5),
                 "s")
        # correctness guard: all modes converge to the same eigenvalue
        eigs = {m: r.eigenvalue for m, r in results.items()}
        spread = max(eigs.values()) - min(eigs.values())
        emit("table4_cr_overhead", "eigenvalue_spread", f"{spread:.2e}", "")
    finally:
        shutil.rmtree(base, ignore_errors=True)
        _sh.rmtree(Path("/dev/shm") / f"craft-node-{os.getpid()}",
                   ignore_errors=True)


if __name__ == "__main__":
    main()
