"""Fused snapshot kernel family: per-chunk digest + dirty mask + histogram."""
