"""Paper Table 4: checkpoint overhead — none / sync PFS / async PFS / node.

Lanczos benchmark (paper §6.2 setup, scaled to this container): fixed
iteration count, fixed checkpoint frequency; report total runtime, %
overhead vs the no-checkpoint baseline, and average time per checkpoint.

The paper's ordering to reproduce:  sync > async > node-level overhead.
Storage mapping on this container: the "PFS" tier is the disk-backed
filesystem; the node tier writes to /dev/shm — the honest analog of the
paper's node-local (RAM/SSD) storage vs parallel-filesystem split on a
single host.
"""
from __future__ import annotations

import os

import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit
from repro.apps.lanczos import GrapheneConfig, run_lanczos
from repro.core import Checkpoint
from repro.core.env import CraftEnv


def _run(mode: str, base: Path, cfg, n_iter, cp_freq, extra_work_s):
    d = base / mode
    envmap = {
        "CRAFT_CP_PATH": str(d / "pfs"),
        "CRAFT_USE_SCR": "0",
    }
    if mode == "none":
        envmap["CRAFT_ENABLE"] = "0"
    elif mode == "sync_pfs":
        pass
    elif mode == "async_pfs":
        envmap["CRAFT_WRITE_ASYNC"] = "1"
    elif mode == "node_level":
        shm = Path("/dev/shm") if Path("/dev/shm").is_dir() else (d / "node")
        envmap.update({
            "CRAFT_USE_SCR": "1",
            "CRAFT_NODE_CP_PATH": str(shm / f"craft-node-{os.getpid()}"),
            "CRAFT_NODE_REDUNDANCY": "LOCAL",
            "CRAFT_PFS_EVERY": "1000000",      # node tier only
        })
    env = CraftEnv.capture(envmap)
    res = run_lanczos(cfg, n_iter=n_iter,
                      cp_freq=(0 if mode == "none" else cp_freq),
                      cp_name=f"l_{mode}", env=env,
                      extra_work_s=extra_work_s)
    return res


def _codec_write(base: Path, label: str, arrays, versions: int, envmap) -> float:
    """Write ``versions`` checkpoint versions; return best per-version seconds."""
    env = CraftEnv.capture({
        "CRAFT_CP_PATH": str(base / label),
        "CRAFT_USE_SCR": "0",
        "CRAFT_KEEP_VERSIONS": "2",
        **envmap,
    })
    cp = Checkpoint(f"codec_{label}", env=env)
    for k, a in arrays.items():
        cp.add(k, a)
    cp.commit()
    best = float("inf")
    try:
        # untimed warmup version: the first write pays the digest/codec jit
        # compilation, which would otherwise pollute the measured best
        cp.update_and_write()
        cp.wait()
        for _ in range(versions):
            t0 = time.perf_counter()
            cp.update_and_write()
            cp.wait()
            best = min(best, time.perf_counter() - t0)
    finally:
        cp.close()
    return best


def codec_throughput(full: bool = False) -> None:
    """Chunked+parallel (codec v1, worker pool) vs legacy single-thread (v0).

    Same multi-array checkpoint, same host, same tier — the measured delta is
    purely the write-path refactor: chunked encode fanout + parallel per-array
    flush vs one monolithic ``tobytes``+crc32 blob at a time on one thread.
    """
    rng = np.random.default_rng(0)
    n_arrays = 8
    mb = 16 if full else 8
    arrays = {
        f"a{i}": rng.standard_normal((mb * 1024 * 1024 // 4,)).astype(np.float32)
        for i in range(n_arrays)
    }
    total_mb = n_arrays * mb
    versions = 4 if full else 3
    base = Path(tempfile.mkdtemp(prefix="craft-codec-"))
    try:
        legacy_s = _codec_write(
            base, "legacy", arrays, versions,
            {"CRAFT_CODEC_VERSION": "0", "CRAFT_IO_WORKERS": "1"})
        chunked_s = _codec_write(
            base, "chunked", arrays, versions, {"CRAFT_CODEC_VERSION": "1"})
        emit("codec_throughput", "legacy_write", round(total_mb / legacy_s, 1),
             "MB/s", codec="v0", workers=1)
        emit("codec_throughput", "chunked_write", round(total_mb / chunked_s, 1),
             "MB/s", codec="v1",
             workers=CraftEnv.capture({}).io_workers)
        emit("codec_throughput", "speedup", round(legacy_s / chunked_s, 2), "x")
    finally:
        shutil.rmtree(base, ignore_errors=True)


def _tree_bytes(root: Path) -> int:
    return sum(p.stat().st_size for p in root.rglob("*") if p.is_file())


def delta_write(full: bool = False) -> None:
    """Incremental (codec v2, ``CRAFT_DELTA=1``) vs full v1 writes while the
    dirty fraction of the train state sweeps 1% → 100%.

    Model of a training loop: a multi-array state is checkpointed every
    version, but only ``dirty_frac`` of its chunks changed since the last
    version (frozen layers, embedding tables, cold optimizer moments).  The
    delta codec digests every chunk (the change detector) and writes only the
    dirty ones; reported are the bytes that physically land in the version
    directory and the best commit latency, against the same state written
    through the full v1 codec.
    """
    rng = np.random.default_rng(7)
    # Payload sized so IO dominates the commit (the cost delta writes avoid);
    # at tiny payloads per-version fixed costs (fsync, publish) flatten the
    # measured gain long before the bytes stop shrinking.
    n_arrays = 8
    mb = 24 if full else 16
    chunk_bytes = 256 * 1024    # ≥64 chunks/array so a 1% sweep is realizable
    versions = 4 if full else 3

    def fresh_state():
        return {
            f"a{i}": rng.standard_normal(
                (mb * 1024 * 1024 // 4,)).astype(np.float32)
            for i in range(n_arrays)
        }

    def run(label: str, base: Path, dirty_frac: float, envmap: dict):
        arrays = fresh_state()
        env = CraftEnv.capture({
            "CRAFT_CP_PATH": str(base),
            "CRAFT_USE_SCR": "0",
            "CRAFT_KEEP_VERSIONS": str(versions + 4),
            "CRAFT_CHUNK_BYTES": str(chunk_bytes),
            **envmap,
        })
        cp = Checkpoint(f"delta_{label}", env=env)
        for k, a in arrays.items():
            cp.add(k, a)
        cp.commit()
        n_chunks = max(1, arrays["a0"].nbytes // chunk_bytes)
        n_dirty = max(1, int(round(dirty_frac * n_chunks)))
        best_s, last_bytes = float("inf"), 0
        try:
            cp.update_and_write()      # version 1: always a full write
            cp.wait()
            for v in range(2, versions + 2):
                for a in arrays.values():    # touch n_dirty chunks per array
                    for c in range(n_dirty):
                        off = (c * n_chunks // n_dirty) * chunk_bytes // 4
                        a[off] += 1.0
                t0 = time.perf_counter()
                cp.update_and_write()
                cp.wait()
                best_s = min(best_s, time.perf_counter() - t0)
                last_bytes = _tree_bytes(env.cp_path / f"delta_{label}" / f"v-{v}")
        finally:
            cp.close()
        return best_s, last_bytes

    base = Path(tempfile.mkdtemp(prefix="craft-delta-"))
    total_mb = n_arrays * mb
    n_chunks = mb * 1024 * 1024 // chunk_bytes
    try:
        for frac in (0.01, 0.10, 0.50, 1.00):
            tag = f"{int(frac * 100)}pct"
            # the realized fraction is quantized to whole chunks — report it
            # so the artifact never claims a cleaner state than was written
            realized = max(1, int(round(frac * n_chunks))) / n_chunks
            rpct = round(100 * realized, 2)
            full_s, full_b = run(f"v1_{tag}", base / f"v1_{tag}", frac,
                                 {"CRAFT_CODEC_VERSION": "1"})
            delta_s, delta_b = run(f"v2_{tag}", base / f"v2_{tag}", frac,
                                   {"CRAFT_DELTA": "1"})
            emit("delta_write", f"bytes_full_{tag}", full_b, "B",
                 dirty_pct=rpct, payload_mb=total_mb)
            emit("delta_write", f"bytes_delta_{tag}", delta_b, "B",
                 dirty_pct=rpct, payload_mb=total_mb)
            emit("delta_write", f"bytes_ratio_{tag}",
                 round(full_b / max(1, delta_b), 2), "x", dirty_pct=rpct)
            emit("delta_write", f"commit_full_{tag}", round(full_s, 5), "s",
                 dirty_pct=rpct)
            emit("delta_write", f"commit_delta_{tag}", round(delta_s, 5), "s",
                 dirty_pct=rpct)
            emit("delta_write", f"commit_speedup_{tag}",
                 round(full_s / max(1e-9, delta_s), 2), "x", dirty_pct=rpct)
    finally:
        shutil.rmtree(base, ignore_errors=True)


def device_snapshot(full: bool = False) -> None:
    """Host write path vs the fused device-resident snapshot pipeline
    (``CRAFT_DEVICE_SNAPSHOT=1``) on a delta-checkpointed jax-array state.

    The host path transfers every shard in full and re-digests it on the
    host; the device path computes digest + dirty mask + entropy in one
    fused pass over the device-resident bytes and only moves the dirty
    chunks.  Reported per dirty fraction: effective write throughput
    (logical payload / best commit), the speedup, and the D2H byte
    reduction of the staged pipeline.

    Interpreting the numbers by backend: on an accelerator the host path
    pays a full-payload D2H copy every version, and the speedup should
    track the D2H reduction rows until IO dominates.  On the CPU backend
    both paths read the array in place (``device_get`` of a CPU jax array
    is zero-copy), so there is no transfer to eliminate and the device
    path dispatches to an equivalent-cost numpy digest pass — expect
    throughput parity (~1.0-1.1x, the residual win is the skipped
    write-path digest bookkeeping); the d2h_reduction rows then carry the
    accelerator-relevant signal.  With zstandard installed the entropy
    gate also spares the device path per-chunk compression attempts on
    incompressible payloads like this one.
    """
    import jax.numpy as jnp

    from repro.core import Box

    rng = np.random.default_rng(11)
    n_arrays = 8
    mb = 24 if full else 16
    chunk_bytes = 256 * 1024
    versions = 6 if full else 5
    n_chunks = mb * 1024 * 1024 // chunk_bytes
    total_mb = n_arrays * mb

    def run(label: str, base: Path, dirty_frac: float, device_on: bool):
        boxes = {
            f"a{i}": Box(jnp.asarray(
                rng.standard_normal((mb * 1024 * 1024 // 4,))
                .astype(np.float32)))
            for i in range(n_arrays)
        }
        env = CraftEnv.capture({
            "CRAFT_CP_PATH": str(base),
            "CRAFT_USE_SCR": "0",
            "CRAFT_KEEP_VERSIONS": str(versions + 4),
            "CRAFT_CHUNK_BYTES": str(chunk_bytes),
            "CRAFT_DELTA": "1",
            "CRAFT_DEVICE_SNAPSHOT": "1" if device_on else "0",
        })
        cp = Checkpoint(f"dsnap_{label}", env=env)
        for k, b in boxes.items():
            cp.add(k, b)
        cp.commit()
        n_dirty = max(1, int(round(dirty_frac * n_chunks)))
        offs = jnp.asarray([
            (c * n_chunks // n_dirty) * chunk_bytes // 4
            for c in range(n_dirty)
        ])
        best_s = float("inf")
        try:
            cp.update_and_write()      # v1 full write + jit warmup, untimed
            cp.wait()
            for _ in range(versions):
                for b in boxes.values():    # touch n_dirty chunks on device
                    b.value = b.value.at[offs].add(1.0)
                    b.value.block_until_ready()
                t0 = time.perf_counter()
                cp.update_and_write()
                cp.wait()
                best_s = min(best_s, time.perf_counter() - t0)
        finally:
            cp.close()
        return best_s

    # Checkpoint onto tmpfs when available: the scenario compares the two
    # snapshot/digest pipelines, and on a disk-backed tmpdir fsync jitter
    # (hundreds of ms on overlay filesystems) swamps the tens-of-ms signal.
    shm = Path("/dev/shm")
    base = Path(tempfile.mkdtemp(
        prefix="craft-dsnap-", dir=str(shm) if shm.is_dir() else None))
    try:
        for frac in (0.02, 0.10, 0.50):
            tag = f"{int(frac * 100)}pct"
            host_s = run(f"host_{tag}", base / f"host_{tag}", frac, False)
            dev_s = run(f"dev_{tag}", base / f"dev_{tag}", frac, True)
            emit("device_snapshot", f"host_write_{tag}",
                 round(total_mb / host_s, 1), "MB/s", dirty_pct=100 * frac,
                 payload_mb=total_mb)
            emit("device_snapshot", f"device_write_{tag}",
                 round(total_mb / dev_s, 1), "MB/s", dirty_pct=100 * frac,
                 payload_mb=total_mb)
            emit("device_snapshot", f"speedup_{tag}",
                 round(host_s / max(1e-9, dev_s), 2), "x",
                 dirty_pct=100 * frac)

        # D2H accounting.  On CPU both paths already read the array in
        # place (zero-copy), so the throughput rows above compare digest
        # pipelines at parity; the transfer-level win appears where a
        # PCIe/ICI link sits between the array and the writer.  That win is
        # decided by the dirty mask alone, so it can be accounted exactly on
        # any backend: the host path moves the full payload every version,
        # the staged pipeline gathers only dirty chunk rows.
        from repro.core.device_snapshot import DeviceSnapshotter

        snap = DeviceSnapshotter(chunk_bytes, with_hist=False, staged=True)
        arr = jnp.asarray(
            rng.standard_normal((mb * 1024 * 1024 // 4,))
            .astype(np.float32))
        snap.snapshot("a", arr)             # first snapshot: full transfer
        for frac in (0.02, 0.10):
            tag = f"{int(frac * 100)}pct"
            n_dirty = max(1, int(round(frac * n_chunks)))
            offs = jnp.asarray([
                (c * n_chunks // n_dirty) * chunk_bytes // 4
                for c in range(n_dirty)
            ])
            d2h = 0
            for _ in range(versions):
                arr = arr.at[offs].add(1.0)
                _, meta = snap.snapshot("a", arr)
                d2h += sum(meta["dirty"]) * chunk_bytes
            host_b = versions * mb * 1024 * 1024
            emit("device_snapshot", f"d2h_reduction_{tag}",
                 round(host_b / max(1, d2h), 1), "x", dirty_pct=100 * frac,
                 host_mb=versions * mb,
                 device_mb=round(d2h / 2**20, 2))
    finally:
        shutil.rmtree(base, ignore_errors=True)


def trace_overhead(full: bool = False) -> None:
    """Armed vs off: the ``CRAFT_TRACE`` recorder on the hot write path.

    The zero-overhead-when-unset contract is tested exactly (a disarmed
    tracer is one dynamic no-op call); this scenario keeps the *armed*
    cost on the scoreboard — same workload twice, once with ``CRAFT_TRACE``
    pointed at a JSONL file and once without, reporting the runtime delta
    and the recorder's per-event cost."""
    from repro.core import trace as trace_mod

    rng = np.random.default_rng(3)
    mb = 8 if full else 4
    n_iter = 120 if full else 60
    arr = rng.standard_normal((mb * 1024 * 1024 // 4,)).astype(np.float32)

    def run(label: str, base: Path, armed: bool):
        envmap = {
            "CRAFT_CP_PATH": str(base / label),
            "CRAFT_USE_SCR": "0",
            "CRAFT_TIER_EVERY": "pfs:5",
        }
        tpath = base / f"{label}.jsonl"
        if armed:
            envmap["CRAFT_TRACE"] = str(tpath)
        env = CraftEnv.capture(envmap)
        state = arr.copy()
        cp = Checkpoint(f"trace_{label}", env=env)
        cp.add("state", state)
        cp.commit()
        t0 = time.perf_counter()
        try:
            for it in range(n_iter):
                state += 1.0
                if cp.need_checkpoint(it):
                    cp.update_and_write(it)
            cp.wait()
        finally:
            cp.close()
            trace_mod.uninstall()
        wall = time.perf_counter() - t0
        n_events = 0
        if armed and tpath.exists():
            n_events = sum(1 for ln in tpath.read_text().splitlines() if ln)
        return wall, n_events

    base = Path(tempfile.mkdtemp(prefix="craft-trace-"))
    try:
        # off-then-armed, best of 2 each, so filesystem warmup is shared
        off_s = min(run(f"off{i}", base, False)[0] for i in range(2))
        armed = [run(f"on{i}", base, True) for i in range(2)]
        armed_s = min(w for w, _ in armed)
        n_events = max(n for _, n in armed)
        delta = armed_s - off_s
        emit("trace_overhead", "off_runtime", round(off_s, 4), "s",
             iters=n_iter, payload_mb=mb)
        emit("trace_overhead", "armed_runtime", round(armed_s, 4), "s",
             iters=n_iter, payload_mb=mb)
        emit("trace_overhead", "armed_delta",
             round(100.0 * delta / off_s, 2), "%", events=n_events)
        if n_events:
            emit("trace_overhead", "per_event",
                 round(max(0.0, delta) / n_events * 1e6, 2), "us")
    finally:
        shutil.rmtree(base, ignore_errors=True)


def metrics_overhead(full: bool = False) -> None:
    """Armed vs off: the ``CRAFT_METRICS`` registry on the hot write path.

    Mirrors ``trace_overhead``: the same checkpointed workload runs twice —
    once with the live metrics registry armed (plus a scrape at the end to
    prove it filled) and once with every hook left as the single dynamic
    no-op call — and the runtime delta lands on the scoreboard.  The
    acceptance bar is ≤1% with ``CRAFT_METRICS`` unset."""
    from repro.core import metrics as metrics_mod

    rng = np.random.default_rng(5)
    mb = 8 if full else 4
    n_iter = 120 if full else 60
    arr = rng.standard_normal((mb * 1024 * 1024 // 4,)).astype(np.float32)

    def run(label: str, base: Path, armed: bool):
        envmap = {
            "CRAFT_CP_PATH": str(base / label),
            "CRAFT_USE_SCR": "0",
            "CRAFT_TIER_EVERY": "pfs:5",
        }
        if armed:
            envmap["CRAFT_METRICS"] = "1"
        env = CraftEnv.capture(envmap)
        state = arr.copy()
        cp = Checkpoint(f"metrics_{label}", env=env)
        cp.add("state", state)
        cp.commit()
        t0 = time.perf_counter()
        try:
            for it in range(n_iter):
                state += 1.0
                if cp.need_checkpoint(it):
                    cp.update_and_write(it)
            cp.wait()
        finally:
            cp.close()
        wall = time.perf_counter() - t0
        n_series = 0
        if armed:
            snap = metrics_mod.snapshot()
            n_series = (len(snap["counters"]) + len(snap["gauges"])
                        + len(snap["histograms"]))
            assert n_series > 0, "armed registry stayed empty"
        metrics_mod.uninstall()
        return wall, n_series

    base = Path(tempfile.mkdtemp(prefix="craft-metrics-"))
    try:
        off_s = min(run(f"off{i}", base, False)[0] for i in range(2))
        armed = [run(f"on{i}", base, True) for i in range(2)]
        armed_s = min(w for w, _ in armed)
        n_series = max(n for _, n in armed)
        delta = armed_s - off_s
        emit("metrics_overhead", "off_runtime", round(off_s, 4), "s",
             iters=n_iter, payload_mb=mb)
        emit("metrics_overhead", "armed_runtime", round(armed_s, 4), "s",
             iters=n_iter, payload_mb=mb)
        emit("metrics_overhead", "armed_delta",
             round(100.0 * delta / off_s, 2), "%", series=n_series)
    finally:
        shutil.rmtree(base, ignore_errors=True)


def main(full: bool = False) -> None:
    codec_throughput(full)
    # checkpoint payload = 2 Lanczos vectors (nx·ny·2 fp32) ≈ 17 MB at 1024²
    # — big enough that write time is visible against ~ms-scale iterations
    cfg = GrapheneConfig(nx=1024 if full else 768,
                         ny=1024 if full else 768, disorder=0.3)
    n_iter = 200 if full else 120
    cp_freq = 20 if full else 15
    extra = 0.0
    base = Path(tempfile.mkdtemp(prefix="craft-table4-"))
    import shutil as _sh
    try:
        results = {}
        for mode in ("none", "sync_pfs", "async_pfs", "node_level"):
            res = _run(mode, base, cfg, n_iter, cp_freq, extra)
            results[mode] = res
            emit("table4_cr_overhead", f"{mode}_runtime",
                 round(res.wall_s, 4), "s")
        base_t = results["none"].wall_s
        for mode in ("sync_pfs", "async_pfs", "node_level"):
            res = results[mode]
            ov = 100.0 * (res.wall_s - base_t) / base_t
            n_cp = max(1, res.cp_stats.get("writes", 1))
            emit("table4_cr_overhead", f"{mode}_overhead",
                 round(ov, 2), "%")
            emit("table4_cr_overhead", f"{mode}_time_per_cp",
                 round(res.cp_stats.get("write_seconds", 0.0) / n_cp, 5),
                 "s")
        # correctness guard: all modes converge to the same eigenvalue
        eigs = {m: r.eigenvalue for m, r in results.items()}
        spread = max(eigs.values()) - min(eigs.values())
        emit("table4_cr_overhead", "eigenvalue_spread", f"{spread:.2e}", "")
    finally:
        shutil.rmtree(base, ignore_errors=True)
        _sh.rmtree(Path("/dev/shm") / f"craft-node-{os.getpid()}",
                   ignore_errors=True)


def _schedule_overhead(full: bool = False) -> None:
    """Scheduler sweep + preemption-flush proof (benchmarks/schedule_overhead
    .py) — registered here so one invocation can land every scenario in a
    single ``--json`` artifact (the CI bench-smoke job's BENCH_cr.json)."""
    from benchmarks.schedule_overhead import main as sched_main

    sched_main(full)


_SCENARIOS = {
    "codec_throughput": codec_throughput,
    "delta_write": delta_write,
    "device_snapshot": device_snapshot,
    "schedule_overhead": _schedule_overhead,
    "metrics_overhead": metrics_overhead,
    "table4": main,
    "trace_overhead": trace_overhead,
}


if __name__ == "__main__":
    from benchmarks.common import run_scenarios

    run_scenarios(_SCENARIOS, main)
