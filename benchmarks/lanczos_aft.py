"""Paper Fig. 8: Lanczos failure-recovery scenarios — overhead decomposition.

Scenarios (per checkpoint tier):
  * no CP, no failure             (baseline)
  * CP, no failure                (OH_cp)
  * CP + failure mid-interval     (OH_cp + OH_rec + OH_redo)

The failure is injected at the midpoint between two checkpoints (paper
§6.3); recovery runs through an AFT zone on the simulator backend, and the
decomposition separates communication recovery (OH_rec, from recovery
stats) from lost-work recomputation (OH_redo, re-executed iterations).
"""
from __future__ import annotations

import shutil
import tempfile
from pathlib import Path

from benchmarks.common import emit
from repro.apps.lanczos import GrapheneConfig, run_lanczos
from repro.core.aft import aft_zone
from repro.core.comm import ProcFailedError
from repro.core.comm_sim import SimWorld
from repro.core.env import CraftEnv


def _aft_lanczos(base: Path, cfg, n_iter, cp_freq, fail_at, n_procs=2):
    envmap = {
        "CRAFT_CP_PATH": str(base / "pfs"),
        "CRAFT_USE_SCR": "0",
        "CRAFT_COMM_RECOVERY_POLICY": "NON-SHRINKING",
    }
    env = CraftEnv.capture(envmap)
    world = SimWorld(n_procs, spare_nodes=1, env=env)
    fired = {}

    def worker(comm):
        def body(c):
            def maybe_fail(it):
                if (fail_at is not None and it == fail_at
                        and c.rank == 0 and not fired.get("x")):
                    fired["x"] = True
                    raise ProcFailedError("injected", failed=[c.rank])

            res = _run_with_hook(cfg, n_iter, cp_freq, c, env, maybe_fail)
            return res

        return aft_zone(comm, body, env=env)

    out = world.run(worker, timeout=600)
    return list(out.values())[0]


def _run_with_hook(cfg, n_iter, cp_freq, comm, env, hook):
    """The run_lanczos loop with a per-iteration failure hook (kept here so
    the library API stays clean)."""
    import repro.apps.lanczos as L
    import jax
    import jax.numpy as jnp
    import numpy as np
    import time as _time

    from repro.core import Box, Checkpoint

    eps = L.onsite(cfg)
    mv = jax.jit(lambda p: L.matvec(cfg, eps, p))
    key = jax.random.PRNGKey(cfg.seed + 1)
    v0 = jax.random.normal(key, (cfg.nx, cfg.ny, 2), jnp.float32)
    v_cur, _ = L._normalize(v0)
    state = {
        "v_prev": Box(jnp.zeros_like(v_cur)),
        "v_cur": Box(v_cur),
        "alphas": np.zeros(n_iter, np.float64),
        "betas": np.zeros(n_iter + 1, np.float64),
        "it": Box(0),
    }
    cp = Checkpoint("aftlan", comm, env=env)
    for k_, v_ in state.items():
        cp.add(k_, v_)
    cp.commit()
    restarted = cp.restart_if_needed()

    @jax.jit
    def step(v_prev, v_cur, beta):
        w = mv(v_cur)
        alpha = jnp.sum(w * v_cur)
        w = w - alpha * v_cur - beta * v_prev
        beta_new = jnp.sqrt(jnp.sum(w * w))
        return alpha, beta_new, v_cur, w / jnp.where(beta_new == 0, 1.0,
                                                     beta_new)

    t0 = _time.perf_counter()
    redo_iters = state["it"].value if restarted else 0
    it = state["it"].value
    try:
        while it < n_iter:
            hook(it)
            a, b, vp, vc = step(state["v_prev"].value, state["v_cur"].value,
                                jnp.float32(state["betas"][it]))
            state["alphas"][it] = float(a)
            state["betas"][it + 1] = float(b)
            state["v_prev"].value = vp
            state["v_cur"].value = vc
            it += 1
            state["it"].value = it
            cp.update_and_write(it, cp_freq)
        cp.wait()
    finally:
        cp.close()
    k = it
    tri = np.diag(state["alphas"][:k])
    if k > 1:
        off = state["betas"][1:k]
        tri += np.diag(off, 1) + np.diag(off, -1)
    return {
        "eig": float(np.min(np.linalg.eigvalsh(tri))),
        "wall_s": _time.perf_counter() - t0,
        "stats": dict(cp.stats),
        "resumed_from": redo_iters,
    }


def main(full: bool = False) -> None:
    cfg = GrapheneConfig(nx=256 if full else 128, ny=256 if full else 128,
                         disorder=0.3)
    n_iter = 200 if full else 80
    cp_freq = 40 if full else 20
    fail_at = cp_freq + cp_freq // 2          # midpoint of a CP interval
    base = Path(tempfile.mkdtemp(prefix="craft-fig8-"))
    try:
        ref = run_lanczos(cfg, n_iter=n_iter)          # no CP, no failure
        emit("fig8_failure_scenarios", "no_cp_runtime",
             round(ref.wall_s, 4), "s")

        d1 = base / "nofail"
        env1 = CraftEnv.capture({
            "CRAFT_CP_PATH": str(d1), "CRAFT_USE_SCR": "0"})
        r1 = run_lanczos(cfg, n_iter=n_iter, cp_freq=cp_freq, env=env1)
        emit("fig8_failure_scenarios", "cp_pfs_runtime",
             round(r1.wall_s, 4), "s")
        emit("fig8_failure_scenarios", "oh_cp",
             round(r1.wall_s - ref.wall_s, 4), "s")

        r2 = _aft_lanczos(base / "fail", cfg, n_iter, cp_freq, fail_at)
        emit("fig8_failure_scenarios", "cp_pfs_fail_runtime",
             round(r2["wall_s"], 4), "s")
        # redo = iterations lost between last CP and the failure point
        per_iter = ref.wall_s / n_iter
        redo = (fail_at - (fail_at // cp_freq) * cp_freq) * per_iter
        emit("fig8_failure_scenarios", "oh_redo_est",
             round(redo, 4), "s")
        assert abs(r2["eig"] - ref.eigenvalue) < 1e-6, \
            (r2["eig"], ref.eigenvalue)
        emit("fig8_failure_scenarios", "eig_matches_baseline", 1, "bool")
    finally:
        shutil.rmtree(base, ignore_errors=True)


if __name__ == "__main__":
    main()
