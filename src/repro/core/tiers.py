"""StorageTier — the common spine of every checkpoint storage backend.

CRAFT's write path (paper §2.4–§2.6) spans two tiers with very different
latency/durability trade-offs: the node-local tier (RAM/SSD, redundancy-
protected, the SCR analog) and the PFS tier (durable parallel file system).
Historically ``storage.VersionStore`` and ``node_level.NodeStore`` each
re-implemented the same directory mechanics — stage in ``.tmp-*``, fsync,
atomic rename to ``v-<K>``, retire old versions, sweep torn staging dirs.
This module extracts that spine:

* :class:`StorageTier` — the abstract staging/publish/read interface that
  ``Checkpoint`` drives.  Any future backend (object store, remote host,
  in-memory cache) implements exactly this surface.
* Module-level helpers (:func:`atomic_publish_dir`, :func:`retire_version_dirs`,
  :func:`sweep_tmp_dirs`, :func:`list_version_dirs`) — the shared
  tmp→rename→fsync and retention mechanics, used by both concrete tiers and
  by the node tier's mirror/parity side-trees.

Atomicity contract (paper Fig. 4): a version directory either exists complete
under its final ``v-<K>`` name or not at all; crashes leave only ``.tmp-*``
garbage which :func:`sweep_tmp_dirs` removes on the next start.
"""
from __future__ import annotations

import abc
import json
import os
import shutil
from pathlib import Path
from typing import List, Optional, Set, Tuple

from repro.core import metrics, trace

_TMP_PREFIX = ".tmp-"
_VERSION_PREFIX = "v-"
_DELTA_DEPS_PREFIX = "deltadeps-"


# --------------------------------------------------------------------------
# shared directory mechanics
# --------------------------------------------------------------------------
def fsync_dir(path: Path) -> None:
    """fsync a directory so a rename inside it survives power loss."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fsync_dir_tree(root: Path) -> None:
    """fsync every directory under ``root`` (and root itself).

    The durability half of the publish protocol: the staged tree's
    *directory entries* must be on stable storage before the atomic rename
    makes the version visible, or a power cut right after the rename can
    leave a complete-looking ``v-<K>`` whose entries vanish on replay.
    File *contents* are not re-synced here — every file in a staged tree
    is written through ``storage._atomic_write_file`` / ``write_json``,
    which fsync the payload before their own rename; repeating that per
    file at publish forces one journal barrier each on ext4 and measurably
    drags the write path.
    """
    for dirpath, _dirnames, _filenames in os.walk(root):
        fsync_dir(Path(dirpath))


def version_dir_name(version: int) -> str:
    return f"{_VERSION_PREFIX}{version}"


def staging_dir_name(version: int) -> str:
    return f"{_TMP_PREFIX}{_VERSION_PREFIX}{version}"


def parse_version(p: Path) -> Optional[int]:
    """``v-<K>`` → K, else None."""
    name = p.name
    if not name.startswith(_VERSION_PREFIX):
        return None
    try:
        return int(name[len(_VERSION_PREFIX):])
    except ValueError:
        return None


def list_version_dirs(root: Path) -> List[Tuple[int, Path]]:
    """Sorted [(version, dir)] of complete version directories under root."""
    out = []
    if root.is_dir():
        for p in root.glob(f"{_VERSION_PREFIX}*"):
            v = parse_version(p)
            if v is not None and p.is_dir():
                out.append((v, p))
    return sorted(out)


def atomic_publish_dir(staged: Path, final: Path) -> None:
    """Atomically promote a fully-written staging dir to its final name.

    The staged tree is fsync'd *before* the rename (payload + directory
    entries must hit stable storage before the version becomes visible — the
    rename is the commit point), a pre-existing ``final`` (same-version
    re-write, e.g. a retry) is removed first, and the parent directory is
    fsync'd after so the rename itself is durable.
    """
    fsync_dir_tree(staged)
    if final.exists():
        shutil.rmtree(final)
    final.parent.mkdir(parents=True, exist_ok=True)
    os.replace(staged, final)
    fsync_dir(final.parent)


def delta_deps_name(rank: int) -> str:
    """Per-rank delta-dependency manifest file inside a version directory."""
    return f"{_DELTA_DEPS_PREFIX}{rank}.json"


def read_delta_deps(vdir: Path) -> Set[int]:
    """Union of every rank's delta-base versions recorded in ``vdir``.

    A version written by the v2 delta codec carries ``deltadeps-<rank>.json``
    files naming the (transitive) base versions its ref chunks resolve
    through; a version with no such files is self-contained.  Unreadable
    manifests are ignored — the read path re-validates the chain anyway.
    """
    deps: Set[int] = set()
    for p in vdir.glob(f"{_DELTA_DEPS_PREFIX}*.json"):
        try:
            data = json.loads(p.read_text())
        except (OSError, ValueError):
            continue
        deps.update(int(v) for v in data.get("deps", []))
    return deps


def retire_version_dirs(root: Path, keep: int) -> List[int]:
    """Delete all but the newest ``keep`` version dirs; return kept versions.

    Delta pinning: a version directory referenced as a delta base by any
    *kept* version is never retired, however old — dropping it would strand
    every delta chained on it.  Pinning is transitive (a pinned base's own
    bases stay pinned too); pinned versions are included in the returned
    kept list so tier metadata keeps advertising them.
    """
    vdirs = list_version_dirs(root)
    keep = max(1, keep)
    pinned: Set[int] = set()
    for _, p in vdirs[-keep:]:
        pinned |= read_delta_deps(p)
    by_version = dict(vdirs)
    frontier = set(pinned)
    while frontier:             # transitive closure over recorded deps
        nxt: Set[int] = set()
        for v in frontier:
            p = by_version.get(v)
            if p is not None:
                nxt |= read_delta_deps(p) - pinned
        pinned |= nxt
        frontier = nxt
    kept = [v for v, _ in vdirs[-keep:]]
    for v, p in vdirs[:-keep]:
        if v in pinned:
            kept.append(v)
            continue
        shutil.rmtree(p, ignore_errors=True)
    return sorted(kept)


def sweep_tmp_dirs(root: Path) -> int:
    """Remove torn ``.tmp-*`` staging dirs left by a crash; return count."""
    n = 0
    if root.is_dir():
        for junk in root.glob(f"{_TMP_PREFIX}*"):
            shutil.rmtree(junk, ignore_errors=True)
            n += 1
    return n


# --------------------------------------------------------------------------
# the tier interface
# --------------------------------------------------------------------------
class StorageTier(abc.ABC):
    """Abstract storage tier driven by ``Checkpoint`` (stage→write→publish).

    Write protocol::

        staged = tier.stage(version)     # private staging directory
        ...write files under staged...
        tier.publish(staged, version)    # atomic rename + metadata commit
        # or, on error:
        tier.abort(staged)

    Read protocol::

        v = tier.latest_version()        # 0 if nothing restorable
        vdir = tier.materialize(v)       # complete local dir, recovering
                                         # from redundancy peers if needed
    """

    #: Human-readable tier name used in stats / restore-error reports; the
    #: chain order (``CRAFT_TIER_CHAIN``) is mem → node → pfs, fastest first.
    label: str = "tier"

    #: A-priori per-version write-cost guess (seconds) for tiers whose
    #: latency class is known before the first write (the RAM tier overrides
    #: this); ``None`` means "unknown until measured" — the scheduler then
    #: schedules an immediate first full write to seed the estimate.
    cost_prior_seconds = None

    #: EWMA smoothing for :meth:`record_write` — responsive enough to track a
    #: delta codec whose cost swings with the dirty fraction, damped enough
    #: that one slow fsync does not thrash the schedule.
    COST_ALPHA = 0.3

    #: Fault-injection scope (``chaos.ChaosScope``) bound by ``Checkpoint``
    #: when ``CRAFT_CHAOS`` is armed; tier-level operations (publish,
    #: redundancy replication, fabric inserts) gate through
    #: :meth:`_chaos_check`, file IO goes through the scope on ``IOContext``.
    chaos_scope = None

    def _chaos_check(self, op: str, nbytes: int = 0, path=None) -> None:
        scope = self.chaos_scope
        if scope is not None:
            scope.check(op, nbytes=nbytes, path=path)

    @abc.abstractmethod
    def stage(self, version: int) -> Path:
        """Create and return the staging directory for ``version``."""

    @abc.abstractmethod
    def publish(self, staged: Path, version: int,
                extra_meta: Optional[dict] = None) -> None:
        """Atomically promote ``staged`` to the complete version ``version``."""

    @abc.abstractmethod
    def abort(self, staged: Path) -> None:
        """Discard a staging directory after a failed write."""

    @abc.abstractmethod
    def latest_version(self) -> int:
        """Newest version this tier can restore (0 if none)."""

    @abc.abstractmethod
    def version_dir(self, version: int) -> Path:
        """Path of version ``version`` (which may not exist)."""

    @abc.abstractmethod
    def invalidate_all(self) -> None:
        """Drop every stored version (nested-checkpoint wipe, paper §2.5)."""

    def materialize(self, version: int) -> Optional[Path]:
        """Return a complete local dir for ``version``, or None.

        Tiers with redundancy (partner mirror, XOR parity) override this to
        transparently rebuild a lost local copy; the default just checks the
        local directory.
        """
        vdir = self.version_dir(version)
        return vdir if vdir.is_dir() else None

    def aux_read_dirs(self, version: int) -> List[Path]:
        """Peer version roots that complement :meth:`materialize`'s result.

        An elastic N→M restore may find its own slice scattered across shard
        files this tier stored *for other ranks* — e.g. the node tier's
        sibling ``node-<nid>`` trees on a shared filesystem.  Tiers that can
        reach those trees return their ``v-<K>`` directories here; the
        checkpointables then union shard manifests across the materialized
        dir and these roots.  Default: none (single-root tiers like the PFS
        store already hold every rank's files in one directory).
        """
        return []

    def retained_versions(self) -> List[int]:
        """Versions locally resident on this tier — the scrubber's walk list.

        The default scans the directory tree ``version_dir`` points into;
        the RAM tier overrides this with its fabric's version set.
        """
        return [v for v, _ in list_version_dirs(self.version_dir(0).parent)]

    def forget_version(self, version: int) -> None:
        """Quarantine one version this tier can no longer serve faithfully
        (scrubber last resort: corrupt with no repair source).  The default
        just drops the directory; stores with version metadata override to
        also retract the version from their manifests."""
        shutil.rmtree(self.version_dir(version), ignore_errors=True)

    def retire_for_space(self) -> bool:
        """Emergency retention squeeze on ``ENOSPC``: drop every retired-
        eligible version (keep only the newest + its pinned delta bases) to
        free space for the write in flight.  Returns True when anything was
        deleted.  Stores with version metadata override to also retract the
        dropped versions from their manifests."""
        root = self.version_dir(0).parent
        before = {v for v, _ in list_version_dirs(root)}
        if len(before) <= 1:
            return False
        kept = set(retire_version_dirs(root, keep=1))
        return kept != before

    # -- per-tier write-cost reporting ---------------------------------------
    def record_write(self, seconds: float, nbytes: int = 0) -> None:
        """Feed one observed version-write duration into this tier's cost
        model (called by ``Checkpoint`` around every landed write; the
        scheduler consumes the estimate via :meth:`write_cost`)."""
        trace.TRACER.emit("tier_cost", tier=self.label,
                          seconds=seconds, nbytes=nbytes)
        # one choke point covers every tier's write latency/throughput
        metrics.inc("tier_writes", tier=self.label)
        metrics.inc("tier_write_bytes", nbytes, tier=self.label)
        metrics.observe("tier_write_seconds", seconds, tier=self.label)
        stats = getattr(self, "io_stats", None)
        if stats is None:
            stats = self.io_stats = {
                "writes": 0, "write_seconds": 0.0,
                "last_write_seconds": 0.0, "bytes": 0,
            }
        stats["writes"] += 1
        stats["write_seconds"] += seconds
        stats["last_write_seconds"] = seconds
        stats["bytes"] += nbytes
        prev = getattr(self, "_cost_ewma", None)
        self._cost_ewma = seconds if prev is None else (
            (1.0 - self.COST_ALPHA) * prev + self.COST_ALPHA * seconds
        )
        metrics.set_gauge("tier_cost_ewma_seconds", self._cost_ewma,
                          tier=self.label)

    def write_cost(self):
        """Estimated seconds per version write: the EWMA of observed writes,
        falling back to :attr:`cost_prior_seconds` (``None`` = unknown)."""
        ewma = getattr(self, "_cost_ewma", None)
        return ewma if ewma is not None else self.cost_prior_seconds

    def reset_cost(self) -> None:
        """Drop the learned cost estimate (post-recovery: surviving ranks'
        IO behavior may have changed with the new process layout)."""
        self._cost_ewma = None

    # -- per-tier IOContext adjustments -------------------------------------
    def write_ctx_overrides(self) -> dict:
        """IOContext field overrides for writes landing on this tier.

        A tier whose durability model differs from the default on-disk codec
        assumptions (e.g. the RAM tier, which re-verifies at publish and
        wants single-chunk encodes) overrides this; the default is no change.
        """
        return {}

    def read_ctx_overrides(self, version: int) -> dict:
        """IOContext field overrides for reads served by this tier.

        Called after :meth:`materialize` succeeded for ``version``; lets a
        tier install fast paths (``array_cache``) or relax re-verification
        for payloads it already verified.
        """
        return {}
