"""Pallas selective-scan (mamba recurrence) kernel + ops + reference."""
