"""RS(k, m) erasure coding: GF(2^8) kernel vs ref, MDS property, node tier.

Acceptance (ISSUE 5): with ``CRAFT_NODE_REDUNDANCY=RS`` and
``CRAFT_RS_PARITY=2``, killing two nodes of one group restores
bit-identically from parity, and the Pallas RS encode matches the jnp
log/exp-table reference exactly.
"""
import shutil
from itertools import combinations
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Checkpoint
from repro.core.comm_sim import SimWorld
from repro.core.cpbase import CheckpointError
from repro.core.env import CraftEnv
from repro.kernels.rs_erasure import ops as rs_ops
from repro.kernels.rs_erasure.kernel import gf_matmul as gf_matmul_pallas
from repro.kernels.rs_erasure.ref import GF_EXP, GF_LOG
from repro.kernels.xor_parity import ops as xor_ops

from test_node_level import FakeComm


# ======================================================== field + matrix
class TestField:
    def test_log_exp_tables_invert(self):
        for a in range(1, 256):
            assert int(GF_EXP[int(GF_LOG[a])]) == a
        assert rs_ops.gf_mul(rs_ops.gf_inv(77), 77) == 1

    def test_mul_matches_schoolbook(self):
        """Table product == carry-less shift/reduce product (poly 0x11B)."""
        def slow_mul(a, b):
            r = 0
            while b:
                if b & 1:
                    r ^= a
                a <<= 1
                if a & 0x100:
                    a ^= 0x11B
                b >>= 1
            return r

        rng = np.random.default_rng(0)
        for a, b in rng.integers(0, 256, (200, 2)):
            assert rs_ops.gf_mul(int(a), int(b)) == slow_mul(int(a), int(b))

    @pytest.mark.parametrize("k,m", [(2, 1), (4, 2), (8, 3), (8, 4)])
    def test_matrix_first_row_is_xor(self, k, m):
        assert (rs_ops.rs_matrix(k, m)[0] == 1).all()

    @pytest.mark.parametrize("k,m", [(4, 2), (6, 3)])
    def test_every_square_submatrix_invertible(self, k, m):
        """The MDS guarantee: any erasure pattern up to m is solvable."""
        g = rs_ops.rs_matrix(k, m)
        for e in range(1, m + 1):
            for rows in combinations(range(m), e):
                for cols in combinations(range(k), e):
                    rs_ops.gf_mat_inv(g[np.ix_(rows, cols)])   # must not raise


# ======================================================== kernel vs ref
class TestGfMatmulKernel:
    @pytest.mark.parametrize("g,r,n", [(2, 1, 128), (4, 2, 256), (8, 4, 512)])
    def test_pallas_interpret_matches_ref_exactly(self, g, r, n):
        rng = np.random.default_rng(1)
        stacked = rng.integers(0, 2 ** 32, (g, n), dtype=np.uint32)
        matrix = tuple(tuple(int(c) for c in row)
                       for row in rng.integers(0, 256, (r, g)))
        out_k = np.asarray(gf_matmul_pallas(
            jnp.asarray(stacked), matrix=matrix, block_n=128, interpret=True))
        out_r = rs_ops.gf_matmul(stacked, matrix, use_pallas=False)
        np.testing.assert_array_equal(out_k, out_r)

    def test_rs_encode_matrix_matches_ref_exactly(self):
        """The acceptance check: Pallas RS encode == jnp reference, bit-exact."""
        rng = np.random.default_rng(2)
        stacked = rng.integers(0, 2 ** 32, (8, 16384), dtype=np.uint32)
        matrix = tuple(tuple(int(c) for c in row)
                       for row in rs_ops.rs_matrix(8, 2))
        out_k = np.asarray(gf_matmul_pallas(
            jnp.asarray(stacked), matrix=matrix, block_n=16384, interpret=True))
        out_r = rs_ops.gf_matmul(stacked, matrix, use_pallas=False)
        np.testing.assert_array_equal(out_k, out_r)

    def test_identity_and_zero_rows(self):
        stacked = np.arange(2 * 128, dtype=np.uint32).reshape(2, 128)
        out = np.asarray(gf_matmul_pallas(
            jnp.asarray(stacked), matrix=((1, 0), (0, 0)), block_n=128,
            interpret=True))
        np.testing.assert_array_equal(out[0], stacked[0])
        assert (out[1] == 0).all()

    def test_rejects_bad_shapes(self):
        stacked = jnp.zeros((2, 128), jnp.uint32)
        with pytest.raises(ValueError):
            gf_matmul_pallas(stacked, matrix=((1,),), block_n=128,
                             interpret=True)
        with pytest.raises(ValueError):
            gf_matmul_pallas(stacked, matrix=((1, 300),), block_n=128,
                             interpret=True)


# ======================================================== buffer encode/decode
class TestEncodeDecode:
    def test_m1_is_xor_parity(self):
        rng = np.random.default_rng(3)
        bufs = [rng.bytes(700 + 13 * i) for i in range(5)]
        assert rs_ops.encode_parity(bufs, 1)[0] == \
            xor_ops.parity_of_buffers(bufs)

    @pytest.mark.parametrize("k,m", [(4, 1), (4, 2), (5, 3)])
    def test_any_loss_pattern_rebuilds_bit_identically(self, k, m):
        rng = np.random.default_rng(4)
        bufs = [rng.bytes(900 + 77 * i) for i in range(k)]
        sizes = [len(b) for b in bufs]
        parity = rs_ops.encode_parity(bufs, m)
        parities = {j: parity[j] for j in range(m)}
        for e in range(1, m + 1):
            for lost in combinations(range(k), e):
                present = {i: bufs[i] for i in range(k) if i not in lost}
                out = rs_ops.decode_lost(k, m, present, parities, sizes)
                for i in lost:
                    assert out[i] == bufs[i]

    def test_decode_with_parity_subset(self):
        """Losing parity rows too: any e available rows solve e erasures."""
        rng = np.random.default_rng(5)
        bufs = [rng.bytes(512) for _ in range(4)]
        sizes = [512] * 4
        parity = rs_ops.encode_parity(bufs, 3)
        out = rs_ops.decode_lost(
            4, 3, {0: bufs[0], 3: bufs[3]}, {1: parity[1], 2: parity[2]},
            sizes)
        assert out[1] == bufs[1] and out[2] == bufs[2]

    def test_too_many_losses_raises(self):
        bufs = [b"a" * 64, b"b" * 64, b"c" * 64]
        parity = rs_ops.encode_parity(bufs, 1)
        with pytest.raises(ValueError, match="parity"):
            rs_ops.decode_lost(3, 1, {0: bufs[0]}, {0: parity[0]}, [64] * 3)


# ======================================================== node tier (RS)
def _rs_env(tmp_path, m=2, pfs_every=100, extra=None):
    return CraftEnv.capture({
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_NODE_CP_PATH": str(tmp_path / "node"),
        "CRAFT_NODE_REDUNDANCY": "RS",
        "CRAFT_XOR_GROUP_SIZE": "4",
        "CRAFT_RS_PARITY": str(m),
        "CRAFT_PFS_EVERY": str(pfs_every),
        **(extra or {}),
    })


def _write_group_sim(env, n_nodes, value_of, versions=1):
    """All ranks write through SimWorld so the publish barriers are real —
    every parity holder encodes from the complete group state, exactly as
    on a real fleet."""
    world = SimWorld(n_nodes, procs_per_node=1, env=env)

    def fn(comm):
        cp = Checkpoint("st", comm, env=env)
        arr = np.full((32,), value_of(comm.rank))
        cp.add("arr", arr)
        cp.commit()
        for v in range(versions):
            arr[:] = value_of(comm.rank) + v
            cp.update_and_write()
        cp.close()

    world.run(fn, timeout=120)


def _read_rank(env, rank, n_nodes):
    arr = np.zeros((32,))
    cp = Checkpoint("st", FakeComm(rank, n_nodes), env=env)
    cp.add("arr", arr)
    cp.commit()
    assert cp.restart_if_needed()
    return arr, cp


class TestNodeStoreRS:
    def test_roundtrip_no_loss(self, tmp_path):
        env = _rs_env(tmp_path)
        _write_group_sim(env, 4, lambda r: float(10 * (r + 1)))
        for rank in range(4):
            arr, cp = _read_rank(env, rank, 4)
            assert np.all(arr == 10 * (rank + 1))
            assert cp.stats["restore_tier"] == "node"

    def test_two_lost_nodes_rebuild_bit_identically(self, tmp_path):
        """The acceptance case: m=2, two members of one group killed."""
        env = _rs_env(tmp_path, m=2)
        _write_group_sim(env, 4, lambda r: float(r + 7))
        shutil.rmtree(tmp_path / "node" / "node-1" / "st")
        shutil.rmtree(tmp_path / "node" / "node-2" / "st")
        for rank in (1, 2):
            arr, cp = _read_rank(env, rank, 4)
            assert np.all(arr == rank + 7)
            assert cp.stats["restore_tier"] == "node"

    def test_rotating_parity_placement(self, tmp_path):
        """Consecutive versions place their parity rows on different members."""
        env = _rs_env(tmp_path, m=2, extra={"CRAFT_KEEP_VERSIONS": "3"})
        _write_group_sim(env, 4, lambda r: float(r), versions=2)
        holders = {
            v: sorted(
                int(p.parents[3].name.split("-")[1])
                for p in (tmp_path / "node").glob(
                    f"node-*/rs-group-0/st/v-{v}/parity-*.bin")
            )
            for v in (1, 2)
        }
        assert holders[1] != holders[2]
        assert all(len(h) == 2 for h in holders.values())

    def test_losses_beyond_m_fall_through_to_pfs(self, tmp_path):
        # the shared PFS tier stores the POD array rank-replicated, so all
        # ranks write the same value here (the node tier is per-node)
        env = _rs_env(tmp_path, m=2, pfs_every=1)
        _write_group_sim(env, 4, lambda r: 3.0)
        for n in (0, 1, 2):
            shutil.rmtree(tmp_path / "node" / f"node-{n}" / "st")
        arr, cp = _read_rank(env, 0, 4)
        assert np.all(arr == 3.0)
        assert cp.stats["restore_tier"] == "pfs"

    def test_losses_beyond_m_raise_without_pfs(self, tmp_path):
        env = _rs_env(tmp_path, m=2, pfs_every=100)
        _write_group_sim(env, 4, lambda r: float(r + 3))
        for n in (0, 1, 2):
            shutil.rmtree(tmp_path / "node" / f"node-{n}" / "st")
        arr = np.zeros((32,))
        cp = Checkpoint("st", FakeComm(0, 4), env=env)
        cp.add("arr", arr)
        cp.commit()
        with pytest.raises(CheckpointError, match="parity"):
            cp.restart_if_needed()
        assert np.all(arr == 0.0)    # never partially overwritten

    def test_stale_survivor_counts_as_lost(self, tmp_path):
        """A digest-mismatched survivor must be rebuilt, not XORed in."""
        env = _rs_env(tmp_path, m=2)
        _write_group_sim(env, 4, lambda r: float(r + 1))
        # node 1's data silently rots; node 2's is gone entirely
        from repro.core.scrubber import corrupt_file
        corrupt_file(
            tmp_path / "node" / "node-1" / "st" / "v-1" / "arr" / "array.bin")
        shutil.rmtree(tmp_path / "node" / "node-2" / "st")
        arr, cp = _read_rank(env, 2, 4)
        assert np.all(arr == 3.0)

    def test_invalidate_drops_parity_trees(self, tmp_path):
        env = _rs_env(tmp_path)
        _write_group_sim(env, 4, lambda r: float(r))
        cp = Checkpoint("st", FakeComm(0, 4), env=env)
        cp.add("arr", np.zeros((32,)))
        cp.commit()
        cp.invalidate()
        assert not list((tmp_path / "node").glob("node-*/rs-group-0/st/v-*"))
