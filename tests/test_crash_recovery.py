"""Crash-during-stage recovery: a torn write never loses the previous version.

Covers the atomicity protocol (paper Fig. 4 / §2.6) across the matrix of
{PFS tier, node tier} × {codec v0, codec v1}:

* a failure raised mid-write aborts the staged directory and the previous
  complete version stays restorable;
* a hard crash (process death — staged ``.tmp-*`` dir simply abandoned) is
  swept on the next start and the previous version restores;
* ``meta.json`` never points at an incomplete version.
"""
import numpy as np
import pytest

from repro.core import Box, Checkpoint, CheckpointError, CpBase
from repro.core.env import CraftEnv


def _env(tmp_path, tier, codec):
    envmap = {
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_CODEC_VERSION": str(codec),
    }
    if tier == "node":
        envmap["CRAFT_NODE_CP_PATH"] = str(tmp_path / "node")
    else:
        envmap["CRAFT_USE_SCR"] = "0"
    return CraftEnv.capture(envmap)


class FlakyCp(CpBase):
    """Array checkpointable that raises mid-write when armed."""

    def __init__(self, arr: np.ndarray):
        self.arr = arr
        self._buf = arr.copy()
        self.fail_next_write = False

    def update(self):
        np.copyto(self._buf, self.arr)

    def write(self, dir_path, ctx):
        from repro.core import storage
        storage.write_array(dir_path / "part1.bin", self._buf[:8], ctx)
        if self.fail_next_write:
            raise OSError("injected crash mid-stage")
        storage.write_array(dir_path / "part2.bin", self._buf[8:], ctx)

    def read(self, dir_path, ctx):
        from repro.core import storage
        a = storage.read_array(dir_path / "part1.bin", ctx)
        b = storage.read_array(dir_path / "part2.bin", ctx)
        self.arr[...] = np.concatenate([a, b])

    def nbytes(self):
        return self._buf.nbytes


def _write_v1(tmp_path, tier, codec, value):
    env = _env(tmp_path, tier, codec)
    arr = np.full((32,), value)
    cp = Checkpoint("cr", env=env)
    cp.add("arr", arr)
    cp.commit()
    cp.update_and_write()
    cp.close()
    return env


TIERS_CODECS = [("pfs", 0), ("pfs", 1), ("node", 0), ("node", 1)]


@pytest.mark.parametrize("tier,codec", TIERS_CODECS)
class TestInjectedFailure:
    def test_abort_keeps_previous_version(self, tmp_path, tier, codec):
        env = _env(tmp_path, tier, codec)
        arr = np.full((32,), 1.0)
        flaky = FlakyCp(arr)
        cp = Checkpoint("cr", env=env)
        cp.add("arr", flaky)
        cp.commit()
        cp.update_and_write()                      # v1 lands cleanly

        arr[...] = 2.0
        flaky.fail_next_write = True
        with pytest.raises(OSError, match="injected"):
            cp.update_and_write()                  # v2 dies mid-stage
        cp.close()

        # staged dirs were aborted — no .tmp-* garbage survives the failure
        roots = [env.cp_path / "cr"]
        if tier == "node":
            roots.append(env.node_cp_path / "node-0" / "cr")
        for root in roots:
            if root.is_dir():
                assert not list(root.glob(".tmp-*")), root

        # a fresh process restores the last complete version (v1)
        arr2 = np.zeros((32,))
        flaky2 = FlakyCp(arr2)
        cp2 = Checkpoint("cr", env=_env(tmp_path, tier, codec))
        cp2.add("arr", flaky2)
        cp2.commit()
        assert cp2.restart_if_needed()
        assert cp2.version == 1
        np.testing.assert_array_equal(arr2, np.full((32,), 1.0))

    def test_hard_crash_tmp_swept_and_previous_restored(self, tmp_path, tier,
                                                        codec):
        env = _write_v1(tmp_path, tier, codec, value=7.0)

        # simulate a process dying mid-stage: abandoned .tmp-v-2 + junk files
        if tier == "node":
            root = env.node_cp_path / "node-0" / "cr"
        else:
            root = env.cp_path / "cr"
        torn = root / ".tmp-v-2"
        torn.mkdir(parents=True)
        (torn / "arr").mkdir()
        (torn / "arr" / "array.bin").write_bytes(b"CRFT\x00garbage")

        arr = np.zeros((32,))
        cp = Checkpoint("cr", env=_env(tmp_path, tier, codec))
        cp.add("arr", arr)
        cp.commit()
        assert cp.restart_if_needed()
        assert cp.version == 1
        np.testing.assert_array_equal(arr, np.full((32,), 7.0))
        assert not torn.exists()                   # swept on start

    def test_meta_never_points_at_torn_version(self, tmp_path, tier, codec):
        env = _write_v1(tmp_path, tier, codec, value=3.0)
        from repro.core import storage
        if tier == "node":
            store = storage.VersionStore(env.node_cp_path / "node-0", "cr",
                                         sweep=False)
        else:
            store = storage.VersionStore(env.cp_path, "cr", sweep=False)
        meta = store.meta()
        assert meta["latest"] == 1
        for v in meta["versions"]:
            assert store.version_dir(v).is_dir()


@pytest.mark.parametrize("codec", [0, 1])
def test_async_failure_surfaces_and_previous_survives(tmp_path, codec):
    env = CraftEnv.capture({
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_USE_SCR": "0",
        "CRAFT_WRITE_ASYNC": "1",
        "CRAFT_CODEC_VERSION": str(codec),
    })
    arr = np.full((16,), 1.0)
    flaky = FlakyCp(arr)
    cp = Checkpoint("acr", env=env)
    cp.add("arr", flaky)
    cp.commit()
    cp.update_and_write()
    cp.wait()
    flaky.fail_next_write = True
    arr[...] = 2.0
    cp.update_and_write()
    with pytest.raises(OSError, match="injected"):
        cp.wait()                                  # error surfaces at fence
    cp.close()

    arr2 = np.zeros((16,))
    cp2 = Checkpoint("acr", env=CraftEnv.capture({
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_USE_SCR": "0",
        "CRAFT_CODEC_VERSION": str(codec),
    }))
    cp2.add("arr", FlakyCp(arr2))
    cp2.commit()
    assert cp2.restart_if_needed()
    assert cp2.version == 1
    np.testing.assert_array_equal(arr2, np.full((16,), 1.0))
